"""repro.io benchmarks: cache hit rate and modeled latency vs memory
budget (GoVector-style curve), a prefetch-width sweep, and the async
subsystem sweeps — queue depth and tier-2 budget share.

Caching, prefetching and async overlap never change *which* blocks the
search demands — results are bit-identical to the uncached path
(asserted here) — they change what each demand read costs. So these
benches report the hardware-independent counters (hit rate, round
trips, prefetched blocks, in-flight peaks, tier-2 hits, completion
reorders) plus modeled NVMe/TPU latency through the calibrated cost
models.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import common
from repro.configs.starling_segment import (SEGMENT_BENCH_ASYNC,
                                            SEGMENT_BENCH_CACHED)
from repro.core.iostats import IOStats, NVME_SEGMENT, TPU_HBM_SEGMENT
from repro.core.search import anns, recall_at_k
from repro.io import cached_view

# every sweep point is a variation of the checked-in cached config, so
# the benches exercise exactly the production wiring
BASE_CACHE = SEGMENT_BENCH_CACHED.cache
ASYNC_CACHE = SEGMENT_BENCH_ASYNC.cache


def _run(view, seg, q, k=10):
    ids, dd, stats = anns(view, q, k, seg.params.search)
    tot = IOStats()
    for s in stats:
        tot.merge(s)
    return ids, dd, stats, tot


def io_cache_hit_rate_sweep():
    """Hit rate / modeled latency vs cache budget (fraction of the block
    file), LRU vs LFU — the GoVector Fig.-style curve."""
    seg = common.bench_segment()
    q = common.queries()
    truth = common.ground_truth()
    ids_u, _, st_u, tot_u = _run(seg.view, seg, q)
    rec_u = recall_at_k(ids_u, truth)
    lat_u = float(np.mean([NVME_SEGMENT.latency_us(s, pipeline=True)
                           for s in st_u]))
    common.record("io_cache_sweep", budget_frac=0.0, policy="none",
                  hit_rate=0.0, recall_at_10=rec_u,
                  latency_us_nvme=lat_u, latency_reduction=0.0,
                  mean_io=common.mean_io(st_u))
    for frac in (0.02, 0.05, 0.10, 0.20, 0.40):
        for policy in ("lru", "lfu"):
            cp = dataclasses.replace(BASE_CACHE, budget_frac=frac,
                                     policy=policy)
            view = cached_view(seg.view, seg.graph, cp)
            ids, _, st, tot = _run(view, seg, q)
            assert np.array_equal(ids, ids_u), \
                "cache changed search results"
            lat = float(np.mean([NVME_SEGMENT.latency_us(s, pipeline=True)
                                 for s in st]))
            common.record(
                "io_cache_sweep", budget_frac=frac, policy=policy,
                hit_rate=tot.cache_hit_rate,
                recall_at_10=recall_at_k(ids, truth),
                latency_us_nvme=lat,
                latency_reduction=1.0 - lat / lat_u,
                mean_io=common.mean_io(st),
                round_trips_per_query=tot.io_round_trips / q.shape[0],
                prefetched_per_query=tot.prefetched_blocks / q.shape[0],
                cache_mem_bytes=view.store.memory_bytes())


def io_prefetch_width_sweep():
    """Round trips / latency vs speculative fetch width at a fixed 10%
    cache budget (page-aligned batching, arXiv:2509.25487)."""
    seg = common.bench_segment()
    q = common.queries()
    for width in (0, 1, 2, 4, 8):
        cp = dataclasses.replace(BASE_CACHE, prefetch_width=width)
        view = cached_view(seg.view, seg.graph, cp)
        _, _, st, tot = _run(view, seg, q)
        lat_nvme = float(np.mean([NVME_SEGMENT.latency_us(s, pipeline=True)
                                  for s in st]))
        lat_tpu = float(np.mean([TPU_HBM_SEGMENT.latency_us(s,
                                                            pipeline=True)
                                 for s in st]))
        common.record(
            "io_prefetch_sweep", prefetch_width=width,
            hit_rate=tot.cache_hit_rate,
            round_trips_per_query=tot.io_round_trips / q.shape[0],
            prefetched_per_query=tot.prefetched_blocks / q.shape[0],
            latency_us_nvme=lat_nvme, latency_us_tpu=lat_tpu)


def _mean_lat(st, cost=NVME_SEGMENT):
    return float(np.mean([cost.latency_us(s, pipeline=True) for s in st]))


def io_queue_depth_sweep():
    """Async + tiered vs the PR 1 synchronous prefetch at the SAME 10%
    memory budget: modeled latency vs queue depth. The acceptance bar —
    depth >= 4 must beat the synchronous baseline — is asserted, as is
    bit-identical results against the uncached oracle."""
    seg = common.bench_segment()
    q = common.queries()
    ids_u, _, st_u, _ = _run(seg.view, seg, q)
    lat_u = _mean_lat(st_u)
    # PR 1 baseline: synchronous coalesced prefetch, single tier
    view_s = cached_view(seg.view, seg.graph, BASE_CACHE)
    ids_s, _, st_s, tot_s = _run(view_s, seg, q)
    assert np.array_equal(ids_s, ids_u), "sync cache changed results"
    lat_sync = _mean_lat(st_s)
    common.record("io_queue_depth_sweep", queue_depth=0, mode="sync",
                  hit_rate=tot_s.cache_hit_rate, latency_us_nvme=lat_sync,
                  latency_reduction_vs_uncached=1.0 - lat_sync / lat_u)
    for depth in (1, 2, 4, 8, 16):
        cp = dataclasses.replace(ASYNC_CACHE, queue_depth=depth)
        view = cached_view(seg.view, seg.graph, cp)
        ids, _, st, tot = _run(view, seg, q)
        assert np.array_equal(ids, ids_u), "async path changed results"
        lat = _mean_lat(st)
        if depth >= 4:
            assert lat < lat_sync, (
                f"queue depth {depth} ({lat:.1f}us) must beat the "
                f"synchronous prefetch baseline ({lat_sync:.1f}us)")
        common.record(
            "io_queue_depth_sweep", queue_depth=depth, mode="async",
            hit_rate=tot.cache_hit_rate,
            tier2_hits_per_query=tot.tier2_hits / q.shape[0],
            inflight_peak=tot.inflight_peak,
            inflight_joins_per_query=tot.inflight_joins / q.shape[0],
            reorders_per_query=tot.completion_reorders / q.shape[0],
            latency_us_nvme=lat, latency_us_tpu=_mean_lat(
                st, TPU_HBM_SEGMENT),
            latency_reduction_vs_sync=1.0 - lat / lat_sync,
            latency_reduction_vs_uncached=1.0 - lat / lat_u)
        if depth == 8:
            # perf-trajectory artifact at the representative depth
            common.perf_artifact(
                "io_queue_depth", [
                    {"name": "latency_us_nvme", "value": lat,
                     "units": "us"},
                    {"name": "hit_rate", "value": tot.cache_hit_rate,
                     "units": "ratio"},
                    {"name": "latency_reduction_vs_sync",
                     "value": 1.0 - lat / lat_sync, "units": "ratio"}],
                config={"queue_depth": depth, "n": common.N_BASE,
                        "dim": common.DIM, "cache": "async+tier2"},
                measured=False)


def io_tier2_budget_sweep():
    """Tier-2 share of a FIXED 10% budget: how much of the block file a
    compressed PQ-space summary tier keeps reachable without a disk
    trip (GoVector, arXiv:2508.15694). tier2_frac=0 is the single-tier
    async path; every point is bit-identical to the uncached oracle."""
    seg = common.bench_segment()
    q = common.queries()
    ids_u, _, _, _ = _run(seg.view, seg, q)
    for t2 in (0.0, 0.125, 0.25, 0.5):
        cp = dataclasses.replace(ASYNC_CACHE, tier2_frac=t2)
        view = cached_view(seg.view, seg.graph, cp)
        ids, _, st, tot = _run(view, seg, q)
        assert np.array_equal(ids, ids_u), "tiered path changed results"
        common.record(
            "io_tier2_budget_sweep", tier2_frac=t2,
            hit_rate=tot.cache_hit_rate,
            tier1_hits_per_query=tot.cache_hits / q.shape[0],
            tier2_hits_per_query=tot.tier2_hits / q.shape[0],
            misses_per_query=tot.cache_misses / q.shape[0],
            latency_us_nvme=_mean_lat(st),
            cache_mem_bytes=view.store.memory_bytes())
