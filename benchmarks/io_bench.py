"""repro.io benchmarks: cache hit rate and modeled latency vs memory
budget (GoVector-style curve), plus a prefetch-width sweep.

Caching and prefetching never change *which* blocks the search demands
— results are bit-identical to the uncached path (asserted here) — they
change what each demand read costs. So these benches report the
hardware-independent counters (hit rate, round trips, prefetched
blocks) plus modeled NVMe/TPU latency through the calibrated cost
models.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import common
from repro.configs.starling_segment import SEGMENT_BENCH_CACHED
from repro.core.iostats import IOStats, NVME_SEGMENT, TPU_HBM_SEGMENT
from repro.core.search import anns, recall_at_k
from repro.io import cached_view

# every sweep point is a variation of the checked-in cached config, so
# the benches exercise exactly the production wiring
BASE_CACHE = SEGMENT_BENCH_CACHED.cache


def _run(view, seg, q, k=10):
    ids, dd, stats = anns(view, q, k, seg.params.search)
    tot = IOStats()
    for s in stats:
        tot.merge(s)
    return ids, dd, stats, tot


def io_cache_hit_rate_sweep():
    """Hit rate / modeled latency vs cache budget (fraction of the block
    file), LRU vs LFU — the GoVector Fig.-style curve."""
    seg = common.bench_segment()
    q = common.queries()
    truth = common.ground_truth()
    ids_u, _, st_u, tot_u = _run(seg.view, seg, q)
    rec_u = recall_at_k(ids_u, truth)
    lat_u = float(np.mean([NVME_SEGMENT.latency_us(s, pipeline=True)
                           for s in st_u]))
    common.record("io_cache_sweep", budget_frac=0.0, policy="none",
                  hit_rate=0.0, recall_at_10=rec_u,
                  latency_us_nvme=lat_u, latency_reduction=0.0,
                  mean_io=common.mean_io(st_u))
    for frac in (0.02, 0.05, 0.10, 0.20, 0.40):
        for policy in ("lru", "lfu"):
            cp = dataclasses.replace(BASE_CACHE, budget_frac=frac,
                                     policy=policy)
            view = cached_view(seg.view, seg.graph, cp)
            ids, _, st, tot = _run(view, seg, q)
            assert np.array_equal(ids, ids_u), \
                "cache changed search results"
            lat = float(np.mean([NVME_SEGMENT.latency_us(s, pipeline=True)
                                 for s in st]))
            common.record(
                "io_cache_sweep", budget_frac=frac, policy=policy,
                hit_rate=tot.cache_hit_rate,
                recall_at_10=recall_at_k(ids, truth),
                latency_us_nvme=lat,
                latency_reduction=1.0 - lat / lat_u,
                mean_io=common.mean_io(st),
                round_trips_per_query=tot.io_round_trips / q.shape[0],
                prefetched_per_query=tot.prefetched_blocks / q.shape[0],
                cache_mem_bytes=view.store.memory_bytes())


def io_prefetch_width_sweep():
    """Round trips / latency vs speculative fetch width at a fixed 10%
    cache budget (page-aligned batching, arXiv:2509.25487)."""
    seg = common.bench_segment()
    q = common.queries()
    for width in (0, 1, 2, 4, 8):
        cp = dataclasses.replace(BASE_CACHE, prefetch_width=width)
        view = cached_view(seg.view, seg.graph, cp)
        _, _, st, tot = _run(view, seg, q)
        lat_nvme = float(np.mean([NVME_SEGMENT.latency_us(s, pipeline=True)
                                  for s in st]))
        lat_tpu = float(np.mean([TPU_HBM_SEGMENT.latency_us(s,
                                                            pipeline=True)
                                 for s in st]))
        common.record(
            "io_prefetch_sweep", prefetch_width=width,
            hit_rate=tot.cache_hit_rate,
            round_trips_per_query=tot.io_round_trips / q.shape[0],
            prefetched_per_query=tot.prefetched_blocks / q.shape[0],
            latency_us_nvme=lat_nvme, latency_us_tpu=lat_tpu)
