"""Render the §Dry-run / §Roofline markdown tables from dryrun.jsonl.

  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh pod16x16]
"""
from __future__ import annotations

import argparse
import json
import os
from collections import OrderedDict

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.jsonl")

MOVE_HINTS = {
    ("compute_s", "moe"): ("replace dense-dispatch MoE (computes E/TP "
                           "experts per token) with capacity-based "
                           "gather dispatch"),
    ("compute_s", "*"): ("cut remat recompute (save attention outputs) "
                         "or raise arithmetic intensity via larger "
                         "microbatch"),
    ("memory_s", "train"): ("fuse attention score chain (flash kernel) "
                            "and drop f32 materializations of logits"),
    ("memory_s", "decode"): ("KV-cache traffic is the floor: quantize "
                             "cache to int8 / window local layers"),
    ("memory_s", "prefill"): ("flash-fuse attention + avoid writeback "
                              "of full-cache copies (in-place DUS)"),
    ("collective_s", "*"): ("reorder sharding so gradient reduce uses "
                            "reduce-scatter into ZeRO shards; overlap "
                            "with backward"),
}


def _latest(path: str):
    recs = OrderedDict()
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except Exception:
                continue
            recs[(r["arch"], r["shape"], r["mesh"],
                  r.get("tag", ""))] = r
    return list(recs.values())


def hint(rec) -> str:
    dom = rec.get("dominant", "")
    cfg_kind = rec.get("kind", "*")
    arch = rec.get("arch", "")
    if dom == "compute_s" and "moe" in arch:
        return MOVE_HINTS[("compute_s", "moe")]
    return MOVE_HINTS.get((dom, cfg_kind), MOVE_HINTS.get((dom, "*"), ""))


def render(path: str = DEFAULT, mesh: str = "pod16x16",
           tag: str = "") -> str:
    recs = [r for r in _latest(path)
            if r["mesh"] == mesh and r.get("tag", "") == tag]
    out = []
    out.append(f"### Roofline baseline — mesh {mesh}"
               + (f" (tag={tag})" if tag else ""))
    out.append("")
    out.append("| arch | shape | status | GiB/chip | compute_s | "
               "memory_s | collective_s | dominant | MODEL/HLO flops | "
               "what would move the dominant term |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP | | | | | "
                       f"| | {r['skip_reason'][:60]} |")
            continue
        if r["status"] == "FAIL":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | "
                       f"| | {r.get('error', '')[:60]} |")
            continue
        if "roofline" not in r:
            out.append(f"| {r['arch']} | {r['shape']} | OK | | | | "
                       f"{r.get('collective_bytes', 0)}B coll | | | |")
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | OK "
            f"| {r['bytes_per_device']['total']/2**30:.2f} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.5f} "
            f"| {r['dominant'].replace('_s', '')} "
            f"| {r.get('model_flops_ratio', 0):.3f} "
            f"| {hint(r)} |")
    return "\n".join(out)


def roofline_tables():
    """``benchmarks.run`` entry: render the roofline tables for every
    production mesh into ``results/roofline_report.md``. Skips
    gracefully when no dry-run records exist yet (the dry-run needs
    ``repro.launch.dryrun`` to have populated ``results/dryrun.jsonl``
    — it is not part of the default bench pass)."""
    if not os.path.exists(DEFAULT):
        print(f"skip: {os.path.normpath(DEFAULT)} not found — run "
              "`python -m repro.launch.dryrun` first")
        return
    sections = [render(DEFAULT, mesh) for mesh in
                ("pod16x16", "pod2x16x16")]
    out_path = os.path.join(os.path.dirname(DEFAULT),
                            "roofline_report.md")
    with open(out_path, "w") as f:
        f.write("\n\n".join(sections) + "\n")
    print(f"wrote {os.path.normpath(out_path)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=DEFAULT)
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    print(render(args.path, args.mesh, args.tag))


if __name__ == "__main__":
    main()
