"""Device-path benchmarks: batched TPU-formulation search vs host oracle,
the tier-0 VMEM hot-tile budget sweep (the device mirror of io_bench's
cache-budget sweep), kernel micro-benchmarks (interpret mode —
correctness + op counts, with modeled TPU timings from the roofline
constants)."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.configs.starling_segment import (DEVICE_SEARCH_BATCH,
                                            DEVICE_SEARCH_BENCH)
from repro.core import device_search as DS
from repro.core import distances as D
from repro.core.iostats import IOStats, TPU_HBM_SEGMENT
from repro.core.params import DeviceSearchParams
from repro.core.search import anns, recall_at_k

import dataclasses


def _mean_tpu_lat(io, t0, hops, saved=None, rounds=0):
    """Modeled TPU latency over per-query device counters (dedup joins
    priced at ``t_dedup_hit`` when the ``saved`` column is given)."""
    saved = np.zeros_like(np.asarray(io)) if saved is None \
        else np.asarray(saved)
    return float(np.mean([
        TPU_HBM_SEGMENT.latency_us(
            IOStats.from_device(i, t, h, sv, rounds), pipeline=True)
        for i, t, h, sv in zip(np.asarray(io), np.asarray(t0),
                               np.asarray(hops), saved)]))


def device_vs_host():
    seg = C.bench_segment(shuffle="bnf")
    q = C.queries()
    truth = C.ground_truth()
    ds = DS.from_segment(seg)
    r = DS.device_anns(ds, jnp.asarray(q), DEVICE_SEARCH_BENCH)
    C.record("device_anns", impl="device_batched",
             recall=recall_at_k(np.asarray(r.ids), truth),
             mean_io=float(np.asarray(r.io).mean()),
             mean_hops=float(np.asarray(r.hops).mean()))
    hids, _, hstats = anns(seg.view, q, 10, seg.params.search)
    C.record("device_anns", impl="host_oracle",
             recall=recall_at_k(hids, truth),
             mean_io=C.mean_io(hstats), mean_hops=C.mean_hops(hstats))


def device_tier0_budget_sweep():
    """Modeled DMA cut vs tier-0 VMEM budget at matched recall — the
    device mirror of io_bench's cache-budget sweep (ISSUE 3 acceptance:
    monotone modeled-DMA reduction, bit-identical results, budget
    charged into Eq. 10).

    Every budget packs a prefix of the same repro.io.hotset ranking, so
    cold DMAs are non-increasing in the budget by construction — we
    assert it anyway, along with (ids, dists) bit-identity against the
    uncached (budget-0) device path."""
    seg = C.bench_segment(shuffle="bnf")
    q = C.queries()
    truth = C.ground_truth()
    base = None
    prev_io = None
    for frac in (0.0, 0.02, 0.05, 0.10, 0.25, 0.5, 1.0):
        ds = DS.from_segment(seg, tier0_frac=frac)
        r = DS.device_anns(ds, jnp.asarray(q), DEVICE_SEARCH_BENCH)
        if base is None:
            base = r
        assert np.array_equal(np.asarray(base.ids), np.asarray(r.ids)), \
            "tier-0 pack changed search results"
        assert np.array_equal(np.asarray(base.dists),
                              np.asarray(r.dists)), \
            "tier-0 pack changed search distances"
        io_m = float(np.asarray(r.io).mean())
        if prev_io is not None:
            assert io_m <= prev_io + 1e-9, \
                f"DMA count must fall monotonically ({prev_io} -> {io_m})"
        prev_io = io_m
        t0_m = float(np.asarray(r.tier0_hits).mean())
        lat = _mean_tpu_lat(r.io, r.tier0_hits, r.hops)
        C.record(
            "device_tier0_budget_sweep", tier0_frac=frac,
            recall=recall_at_k(np.asarray(r.ids), truth),
            cold_dma_per_query=io_m, tier0_hits_per_query=t0_m,
            tier0_hit_rate=t0_m / max(io_m + t0_m, 1e-9),
            tier0_bytes=DS.tier0_bytes(ds),
            modeled_latency_us_tpu=lat,
            modeled_dma_reduction=(
                1.0 - io_m / max(float(np.asarray(base.io).mean()),
                                 1e-9)))


def device_batch_dedup_sweep():
    """ISSUE 4 acceptance: the divergence-aware batched path.

    (a) duplicate-block-rate sweep at fixed batch: a growing share of
        the batch repeats one query, so per-round block requests
        collide and the cross-query dedup absorbs them — modeled DMA
        count (io - dedup_saved) must fall STRICTLY as the dup rate
        rises, while (ids, dists) stay bit-identical per query;
    (b) batch-size sweep: queries from the same distribution share
        entry-region blocks, so bigger batches dedup more — modeled
        TPU latency per query must be non-increasing with batch size
        at fixed recall (same knobs);
    (c) bit-identity vs the singleton-batch oracle, fused AND jnp
        fetch_impl, asserted inside the sweep;
    (d) cross-tile dup-rate axis (ISSUE 8): duplicates placed in a
        DIFFERENT round tile than their twins (``round_tile_cap``
        splits the batch), where only batch-scope dedup can join them.
        The old tile-scope kernel's modeled DMA count is exactly
        ``io - (dedup_saved - dedup_cross)`` (it missed the cross-tile
        joins); the batch-scope number must sit STRICTLY below it.

    ``BENCH_SMOKE=1`` (the `make bench-batch` / CI smoke lane) shrinks
    the sweep to the two smallest batches. Skips gracefully when no
    jax backend is available."""
    try:
        jax.devices()
    except RuntimeError as e:           # no backend: record the skip
        C.record("device_batch_dedup_sweep", skipped=str(e))
        return
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    seg = C.bench_segment(shuffle="bnf")
    ds = DS.from_segment(seg, tier0_frac=0.05)
    x = C.base_data()
    from repro.data.vectors import query_set
    p = DEVICE_SEARCH_BATCH

    # --- (a) duplicate-rate sweep
    base_q = C.queries()
    qn = base_q.shape[0]
    r0 = DS.device_anns(ds, jnp.asarray(base_q[:1]), p)  # singleton oracle
    prev_dma = None
    for dup in (0.0, 0.25, 0.5, 0.75):
        q = base_q.copy()
        ndup = int(dup * qn)
        if ndup:
            q[qn - ndup:] = q[0]
        r = DS.device_anns(ds, jnp.asarray(q), p)
        io_m = float(np.asarray(r.io).mean())
        sv_m = float(np.asarray(r.dedup_saved).mean())
        dma = io_m - sv_m
        # per-query results must not care who else rides the batch
        assert np.array_equal(np.asarray(r0.ids[0]),
                              np.asarray(r.ids[0])), \
            "batch composition changed a query's results"
        if prev_dma is not None:
            assert dma < prev_dma, (
                f"dedup must cut modeled DMAs strictly as the duplicate "
                f"rate rises ({prev_dma:.2f} -> {dma:.2f})")
        prev_dma = dma
        C.record("device_dup_rate_sweep", dup_rate=dup,
                 cold_touches_per_query=io_m,
                 dedup_saved_per_query=sv_m,
                 modeled_dma_per_query=dma,
                 modeled_latency_us_tpu=_mean_tpu_lat(
                     r.io, r.tier0_hits, r.hops, r.dedup_saved,
                     int(r.rounds)))

    # --- (b) batch-size sweep + (c) singleton-oracle bit-identity
    truth_all = D.brute_force_knn(x, query_set(x, 128, seed=5), 10)
    prev_lat = None
    sizes = (8, 16) if smoke else (8, 32, 128)
    for b in sizes:
        q = query_set(x, 128, seed=5)[:b]
        r = DS.device_anns(ds, jnp.asarray(q), p)
        rj = DS.device_anns(ds, jnp.asarray(q),
                            dataclasses.replace(p, fetch_impl="jnp"))
        for f in ("ids", "dists", "io", "tier0_hits", "dedup_saved"):
            assert np.array_equal(np.asarray(getattr(r, f)),
                                  np.asarray(getattr(rj, f))), \
                f"fused vs jnp fetch_impl diverged on {f}"
        # singleton-batch oracle: same ids/dists bit-for-bit
        for qi in range(0, b, max(b // 4, 1)):
            r1 = DS.device_anns(ds, jnp.asarray(q[qi: qi + 1]), p)
            assert np.array_equal(np.asarray(r1.ids[0]),
                                  np.asarray(r.ids[qi]))
            assert np.array_equal(np.asarray(r1.dists[0]),
                                  np.asarray(r.dists[qi]))
        lat = _mean_tpu_lat(r.io, r.tier0_hits, r.hops, r.dedup_saved,
                            int(r.rounds))
        if prev_lat is not None and not smoke:
            assert lat <= prev_lat + 1e-9, (
                f"modeled latency/query must not rise with batch size "
                f"({prev_lat:.3f} -> {lat:.3f} us)")
        prev_lat = lat
        sv_m = float(np.asarray(r.dedup_saved).mean())
        io_m = float(np.asarray(r.io).mean())
        C.record("device_batch_size_sweep", batch=b,
                 recall=recall_at_k(np.asarray(r.ids), truth_all[:b]),
                 cold_touches_per_query=io_m,
                 dedup_saved_per_query=sv_m,
                 modeled_dma_per_query=io_m - sv_m,
                 rounds=int(r.rounds),
                 occupancy=float(np.asarray(r.hops).mean()
                                 / max(int(r.rounds), 1)),
                 modeled_latency_us_tpu=lat)
    # --- (d) cross-tile dup-rate axis (ISSUE 8)
    rb = r                              # untiled run of the same batch
    bx, cap = (16, 8) if smoke else (128, 64)
    qx = query_set(x, 128, seed=5)[:bx]
    pt = dataclasses.replace(p, round_tile_cap=cap)
    prev_x = None
    for dup in (0.0, 0.25, 0.5):
        ndup = int(dup * bx)            # duplicates all land in tile 1
        qd = qx.copy()
        if ndup:
            qd[bx - ndup:] = qx[:ndup]  # ...their twins stay in tile 0
        rx = DS.device_anns(ds, jnp.asarray(qd), pt)
        io_a = np.asarray(rx.io)
        sv_a = np.asarray(rx.dedup_saved)
        cx_a = np.asarray(rx.dedup_cross)
        dma_x = float((io_a - sv_a).mean())
        # what the per-tile-dedup kernel would have paid: it joined
        # only within a tile, so add the cross-tile joins back
        dma_tile = float((io_a - (sv_a - cx_a)).mean())
        if ndup:
            assert cx_a.sum() > 0, "cross-tile twins must join"
            assert dma_x < dma_tile, (
                f"batch-scope dedup must price strictly below the "
                f"tile-scope kernel ({dma_x:.2f} !< {dma_tile:.2f})")
            assert dma_x < prev_x, (
                f"modeled DMAs must fall strictly with the cross-tile "
                f"dup rate ({prev_x:.2f} -> {dma_x:.2f})")
        else:
            # tiling alone must not move results or any counter
            assert np.array_equal(np.asarray(rx.ids), np.asarray(rb.ids))
            assert np.array_equal(np.asarray(rx.dists),
                                  np.asarray(rb.dists))
            assert np.array_equal(io_a, np.asarray(rb.io))
        prev_x = dma_x
        C.record("device_cross_tile_dedup_sweep", batch=bx,
                 round_tile_cap=cap, dup_rate=dup,
                 dedup_saved_per_query=float(sv_a.mean()),
                 cross_tile_saved_per_query=float(cx_a.mean()),
                 modeled_dma_per_query=dma_x,
                 modeled_dma_per_query_tile_scope=dma_tile,
                 modeled_dma_cut_vs_tile_scope=(
                     1.0 - dma_x / max(dma_tile, 1e-9)))

    # perf-trajectory artifact: largest batch swept in this lane plus
    # the cross-tile point (dup=0.5) batch-vs-tile-scope comparison
    C.perf_artifact(
        "device_batch_dedup", [
            {"name": "modeled_dma_per_query", "value": io_m - sv_m,
             "units": "blocks"},
            {"name": "dedup_saved_per_query", "value": sv_m,
             "units": "blocks"},
            {"name": "modeled_latency_us_tpu", "value": lat,
             "units": "us"},
            {"name": "cross_tile_saved_per_query",
             "value": float(cx_a.mean()), "units": "blocks"},
            {"name": "modeled_dma_per_query_cross_tile", "value": dma_x,
             "units": "blocks"},
            {"name": "modeled_dma_per_query_tile_scope",
             "value": dma_tile, "units": "blocks"},
            {"name": "modeled_dma_cut_vs_tile_scope",
             "value": 1.0 - dma_x / max(dma_tile, 1e-9),
             "units": "ratio"}],
        config={"batch": b, "n": C.N_BASE, "dim": C.DIM,
                "tier0_frac": 0.05, "smoke": smoke,
                "cross_tile_batch": bx, "round_tile_cap": cap,
                "cross_tile_dup_rate": dup},
        measured=False)


def device_drift_repack_sweep():
    """ISSUE 5 acceptance: the adaptive serving plane under workload
    drift.

    A segment is served with its build-time tier-0 pack while the query
    stream shifts to vectors whose blocks the build-time prior left
    cold. The host path's ``CachedBlockStore.block_freq`` feeds the
    ``RepackScheduler``; once the drift clears the hysteresis gate the
    scheduler repacks the device pack from the observed union demand.
    Asserted in-sweep:

      * modeled DMA/query (io - dedup_saved) falls STRICTLY after the
        scheduled repack on the shifted distribution;
      * ``(ids, dists)`` are bit-identical to the unscheduled (static
        pack) run — a repack moves tiles between tiers, never results;
      * the repack was *scheduled* (fired by the control loop, not
        forced), and a second evaluation at the settled stream is a
        hysteresis no-op.

    ``BENCH_SMOKE=1`` (the `make bench-batch` / CI smoke lane) shrinks
    the stream. Skips gracefully when no jax backend is available."""
    try:
        jax.devices()
    except RuntimeError as e:           # no backend: record the skip
        C.record("device_drift_repack_sweep", skipped=str(e))
        return
    from repro.configs.starling_segment import (SEGMENT_BENCH_CACHED,
                                                SERVE_REPACK)
    from repro.core.segment import build_segment
    from repro.serving import (HostSegmentServer, QueryCoordinator,
                               RepackScheduler, SegmentServer)

    smoke = bool(os.environ.get("BENCH_SMOKE"))
    x = C.base_data()
    seg = build_segment(x, SEGMENT_BENCH_CACHED)   # cache-fronted host view
    p = dataclasses.replace(DEVICE_SEARCH_BATCH, max_hops=128)
    server = SegmentServer(segment=DS.from_segment(seg, tier0_frac=0.1),
                           offset=0, num_vectors=x.shape[0], host=seg,
                           params=p)
    hserver = HostSegmentServer.from_segment(seg, 0)
    sched = RepackScheduler(SERVE_REPACK)
    sched.attach_feed(seg.view.store)
    coord = QueryCoordinator([server], scheduler=sched)

    # the shifted stream: queries jittered around vectors whose blocks
    # the build-time pack left cold (maximal drift from the prior)
    hot0 = DS.hot_pack_blocks(server.segment)
    block_of = seg.view.layout.block_of
    cold_vid = np.flatnonzero(~np.isin(block_of, sorted(hot0)))
    rng = np.random.default_rng(17)
    qn = 8 if smoke else 24
    qs = (x[rng.choice(cold_vid, qn)]
          + rng.normal(0, 0.01, (qn, C.DIM))).astype(np.float32)

    # unscheduled baseline: the static pack serves the shifted stream
    ids0, dists0, io0 = server.search(qs)
    static_cols = (server.last_io, server.last_tier0_hits,
                   server.last_hops, server.last_dedup_saved,
                   int(server.last_rounds))
    dma_before = float((server.last_io - server.last_dedup_saved).mean())
    t0_before = float(server.last_tier0_hits.mean())

    # serve batches through the coordinator until the scheduler fires
    repack_at = None
    for b in range(3 * SERVE_REPACK.interval_batches):
        hserver.search(qs)                      # demand feed traffic
        _, _, stats = coord.search(qs, k=10)
        if stats.get("repack", {}).get("repacked"):
            repack_at = b
            drift = stats["repack"]["max_drift"]
            break
    assert repack_at is not None, \
        "the scheduler must fire on a fully shifted stream"

    ids1, dists1, io1 = server.search(qs)
    dma_after = float((server.last_io - server.last_dedup_saved).mean())
    t0_after = float(server.last_tier0_hits.mean())
    # bit-identity to the unscheduled run — in-sweep acceptance
    assert np.array_equal(ids0, ids1), "scheduled repack changed ids"
    assert np.array_equal(dists0, dists1), \
        "scheduled repack changed dists"
    assert dma_after < dma_before, (
        f"modeled DMA/query must fall strictly after a scheduled "
        f"repack ({dma_before:.2f} -> {dma_after:.2f})")

    # settled stream: the next evaluation is a hysteresis no-op
    before = sched.repacks
    for _ in range(SERVE_REPACK.interval_batches):
        hserver.search(qs)
        coord.search(qs, k=10)
    assert sched.repacks == before, \
        "a settled stream must not re-trigger the repack loop"
    C.record("device_drift_repack_sweep",
             batches_to_repack=repack_at + 1, drift_at_repack=drift,
             dma_per_query_static=dma_before,
             dma_per_query_adaptive=dma_after,
             modeled_dma_cut=1.0 - dma_after / max(dma_before, 1e-9),
             tier0_hits_per_query_static=t0_before,
             tier0_hits_per_query_adaptive=t0_after,
             hysteresis=SERVE_REPACK.hysteresis,
             modeled_latency_us_tpu_static=_mean_tpu_lat(*static_cols[:4],
                                                         static_cols[4]),
             modeled_latency_us_tpu_adaptive=_mean_tpu_lat(
                 server.last_io, server.last_tier0_hits,
                 server.last_hops, server.last_dedup_saved,
                 int(server.last_rounds)),
             sched_evals=sched.evals, sched_skipped=sched.skipped)
    C.perf_artifact(
        "device_drift_repack", [
            {"name": "modeled_dma_cut",
             "value": 1.0 - dma_after / max(dma_before, 1e-9),
             "units": "ratio"},
            {"name": "batches_to_repack", "value": repack_at + 1,
             "units": "batches"},
            {"name": "dma_per_query_adaptive", "value": dma_after,
             "units": "blocks"}],
        config={"n": C.N_BASE, "dim": C.DIM, "tier0_frac": 0.1,
                "hysteresis": SERVE_REPACK.hysteresis, "smoke": smoke},
        measured=False)


def hybrid_hot_tier_sweep():
    """ISSUE 10 acceptance: the hybrid hot/cold tier.

    Sweeps the hot tier's memory budget over the bench segment and
    prices the hybrid hot-first route against the pure block search
    with the NVMe cost model, splitting every modeled latency into its
    memory half (``t_hot_tier_us`` — hot-tier vertex visits inside
    t_comp) and its disk half (``t_io_us``). Asserted in-sweep, at the
    10% operating point:

      * recall within ±0.01 of the pure block search (same Γ preset —
        the hybrid narrows its own cold beam via ``cold_gamma_frac``);
      * cold I/O per query STRICTLY below the pure path — the hot tier
        absorbs the early exploration, so equal recall costs fewer
        block reads;
      * the memory work is visible: ``hot_tier_hits`` > 0 on every
        query, and none of it leaks into ``block_reads``.

    ``BENCH_SMOKE=1`` shrinks the budget axis to the 10% point. Runs
    on the host block path (the device mirror shares the seed-override
    and the accounting column; this sweep prices the tier split)."""
    try:
        jax.devices()
    except RuntimeError as e:           # no backend: record the skip
        C.record("hybrid_hot_tier_sweep", skipped=str(e))
        return
    from repro.core import delta as DL
    from repro.core.iostats import NVME_SEGMENT
    from repro.core.params import HotTierParams

    smoke = bool(os.environ.get("BENCH_SMOKE"))
    seg = C.bench_segment(shuffle="bnf")
    q = C.queries()
    truth = C.ground_truth()
    p = seg.params.search

    def split(stats):
        agg = IOStats()
        for s in stats:
            agg.merge(s)
        b = NVME_SEGMENT.breakdown(agg)
        return (b["total_us"] / len(stats), b["t_io_us"] / len(stats),
                b["t_hot_tier_us"] / len(stats))

    ids_p, _, st_p = anns(seg.view, q, 10, p)
    rec_p = recall_at_k(ids_p, truth)
    io_p = C.mean_io(st_p)
    lat_p, disk_p, mem_p = split(st_p)
    assert mem_p == 0.0
    C.record("hybrid_hot_tier_sweep", budget_frac=0.0, recall=rec_p,
             cold_io_per_query=io_p, hot_tier_hits_per_query=0.0,
             modeled_latency_us_nvme=lat_p, modeled_disk_us=disk_p,
             modeled_memory_us=mem_p)

    art = {}
    fracs = (0.10,) if smoke else (0.05, 0.10, 0.25)
    for frac in fracs:
        d = DL.DeltaSegment.wrap(seg, HotTierParams(budget_frac=frac))
        ids_h, _, st_h = d.search(q, 10, p)
        rec_h = recall_at_k(ids_h, truth)
        io_h = C.mean_io(st_h)
        hot_h = float(np.mean([s.hot_tier_hits for s in st_h]))
        lat_h, disk_h, mem_h = split(st_h)
        assert all(s.hot_tier_hits > 0 for s in st_h), \
            "hybrid route must charge its memory work"
        if abs(frac - 0.10) < 1e-9:
            # the ISSUE 10 acceptance gate at the 10% budget
            assert rec_h >= rec_p - 0.01, (
                f"hybrid recall {rec_h:.3f} not within 0.01 of pure "
                f"{rec_p:.3f} at budget 0.10")
            assert io_h < io_p, (
                f"hybrid cold I/O {io_h:.2f} must sit strictly below "
                f"pure {io_p:.2f} at equal recall")
            art = {"rec": rec_h, "io": io_h, "lat": lat_h,
                   "disk": disk_h, "mem": mem_h, "hot": hot_h,
                   "mem_bytes": d.hot.memory_bytes()}
        C.record("hybrid_hot_tier_sweep", budget_frac=frac,
                 recall=rec_h, cold_io_per_query=io_h,
                 hot_tier_hits_per_query=hot_h,
                 hot_memory_bytes=d.hot.memory_bytes(),
                 modeled_latency_us_nvme=lat_h, modeled_disk_us=disk_h,
                 modeled_memory_us=mem_h,
                 cold_io_cut=1.0 - io_h / max(io_p, 1e-9))
    C.perf_artifact(
        "hybrid_hot_tier", [
            {"name": "cold_io_per_query_hybrid", "value": art["io"],
             "units": "blocks"},
            {"name": "cold_io_per_query_pure", "value": io_p,
             "units": "blocks"},
            {"name": "cold_io_cut",
             "value": 1.0 - art["io"] / max(io_p, 1e-9),
             "units": "ratio"},
            {"name": "recall_at_10_hybrid", "value": art["rec"],
             "units": "ratio"},
            {"name": "modeled_latency_us_nvme", "value": art["lat"],
             "units": "us"},
            {"name": "modeled_disk_us", "value": art["disk"],
             "units": "us"},
            {"name": "modeled_memory_us", "value": art["mem"],
             "units": "us"},
            {"name": "hot_tier_hits_per_query", "value": art["hot"],
             "units": "vertices"}],
        config={"n": C.N_BASE, "dim": C.DIM, "budget_frac": 0.10,
                "smoke": smoke},
        measured=False)


def device_speculate_sweep():
    """ISSUE 9 acceptance: the cross-round speculative pipeline.

    At dup rate 0 (no duplicate queries — the worst case for dedup and
    the point PR 8's ``pipeline_dma`` baseline is committed at), runs
    the bench batch with speculation off (the pipelined baseline) and
    on, across the fetch-width axis (wider frontiers give the
    predictor more of round i+1's union to pre-issue):

      * ``(ids, dists)`` and every non-speculative counter must be
        bit-identical between the two runs — speculation is never
        wrong, only late (asserted in-sweep, every width);
      * the speculative modeled latency/query must sit STRICTLY below
        the pipelined baseline at the preset width — the spec-hit
        share of the DMA stream left the critical path and the
        mis-speculation surcharge did not eat the win;
      * the artifact records spec hit rate vs modeled latency at the
        bench's fixed-recall operating point, so the predictor's
        coverage is diffable across PRs.

    ``BENCH_SMOKE=1`` shrinks the width axis. Skips gracefully when no
    jax backend is available."""
    try:
        jax.devices()
    except RuntimeError as e:           # no backend: record the skip
        C.record("device_speculate_sweep", skipped=str(e))
        return
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    seg = C.bench_segment(shuffle="bnf")
    ds = DS.from_segment(seg, tier0_frac=0.05)
    q = C.queries()
    truth = C.ground_truth()

    def spec_lat(r, pipelined, speculative):
        io = np.asarray(r.io)
        rounds = int(r.rounds)
        return float(np.mean([
            TPU_HBM_SEGMENT.latency_us(IOStats.from_device(
                i, t, h, sv, rounds, cx, pipelined, sh, sw, speculative))
            for i, t, h, sv, cx, sh, sw in zip(
                io, np.asarray(r.tier0_hits), np.asarray(r.hops),
                np.asarray(r.dedup_saved), np.asarray(r.dedup_cross),
                np.asarray(r.spec_hits), np.asarray(r.spec_wasted))]))

    widths = (2,) if smoke else (1, 2, 3)
    preset_fw = DEVICE_SEARCH_BATCH.fetch_width
    art = {}
    for fw in sorted(set(widths) | {preset_fw}):
        p0 = dataclasses.replace(DEVICE_SEARCH_BATCH, fetch_width=fw)
        p1 = dataclasses.replace(p0, speculate=True)
        r0 = DS.device_anns(ds, jnp.asarray(q), p0)
        r1 = DS.device_anns(ds, jnp.asarray(q), p1)
        # speculation is never wrong, only late: results and every
        # non-speculative counter are bit-identical
        for f in ("ids", "dists", "io", "tier0_hits", "hops",
                  "dedup_saved", "dedup_cross"):
            assert np.array_equal(np.asarray(getattr(r0, f)),
                                  np.asarray(getattr(r1, f))), \
                f"speculation changed {f}"
        assert int(r0.rounds) == int(r1.rounds)
        assert int(np.asarray(r0.spec_hits).sum()) == 0
        io_a = np.asarray(r1.io)
        sv_a = np.asarray(r1.dedup_saved)
        sh_a = np.asarray(r1.spec_hits)
        sw_a = np.asarray(r1.spec_wasted)
        hit_rate = float(sh_a.sum() / max((io_a - sv_a).sum(), 1))
        lat_pipe = spec_lat(r0, pipelined=True, speculative=False)
        lat_spec = spec_lat(r1, pipelined=True, speculative=True)
        if fw == preset_fw:
            # the acceptance gate: strictly below the PR-8 pipelined
            # baseline at dup rate 0, waste surcharge included
            assert lat_spec < lat_pipe, (
                f"speculative pipeline must price strictly below the "
                f"pipelined baseline ({lat_spec:.3f} !< {lat_pipe:.3f} "
                f"us at fw={fw})")
            art = {"recall": recall_at_k(np.asarray(r1.ids), truth),
                   "hit_rate": hit_rate, "lat_pipe": lat_pipe,
                   "lat_spec": lat_spec,
                   "wasted": float(sw_a.mean()), "fw": fw}
        C.record("device_speculate_sweep", fetch_width=fw,
                 recall=recall_at_k(np.asarray(r1.ids), truth),
                 spec_hit_rate=hit_rate,
                 spec_hits_per_query=float(sh_a.mean()),
                 spec_wasted_per_query=float(sw_a.mean()),
                 modeled_dma_per_query=float((io_a - sv_a).mean()),
                 modeled_latency_us_pipeline=lat_pipe,
                 modeled_latency_us_speculative=lat_spec,
                 modeled_latency_cut=1.0 - lat_spec / max(lat_pipe,
                                                          1e-9))
    C.perf_artifact(
        "device_speculate", [
            {"name": "spec_hit_rate", "value": art["hit_rate"],
             "units": "ratio"},
            {"name": "modeled_latency_us_pipeline",
             "value": art["lat_pipe"], "units": "us"},
            {"name": "modeled_latency_us_speculative",
             "value": art["lat_spec"], "units": "us"},
            {"name": "modeled_latency_cut",
             "value": 1.0 - art["lat_spec"] / max(art["lat_pipe"], 1e-9),
             "units": "ratio"},
            {"name": "spec_wasted_per_query", "value": art["wasted"],
             "units": "blocks"},
            {"name": "recall_at_10", "value": art["recall"],
             "units": "ratio"}],
        config={"n": C.N_BASE, "dim": C.DIM, "tier0_frac": 0.05,
                "fetch_width": art["fw"], "smoke": smoke},
        measured=False)


def batched_beam_throughput():
    """Device QPS scaling with batch size (TPU analogue of the paper's
    thread sweep, Fig. 12): one batched while_loop serves B queries."""
    seg = C.bench_segment(shuffle="bnf")
    ds = DS.from_segment(seg)
    x = C.base_data()
    from repro.data.vectors import query_set
    for b in (8, 32, 128):
        q = query_set(x, b, seed=5)
        fn = lambda qq: DS.device_anns(ds, qq, DEVICE_SEARCH_BENCH)
        r = fn(jnp.asarray(q))                    # compile+run
        jax.block_until_ready(r.ids)
        t0 = time.perf_counter()
        r = fn(jnp.asarray(q))
        jax.block_until_ready(r.ids)
        wall = time.perf_counter() - t0
        truth = D.brute_force_knn(x, q, 10)
        C.record("fig12_batched_beam", batch=b,
                 recall=recall_at_k(np.asarray(r.ids), truth),
                 mean_io=float(np.asarray(r.io).mean()),
                 wall_s_cpu_interp=wall)


def starling_fetch_width():
    """§Perf cell 3 (paper-representative): multi-block fetch per DMA
    round-trip — exploits the paper's Central Assumption (a few random
    reads per round-trip cost ~one). Round trips are the latency unit;
    block reads are the bandwidth unit."""
    seg = C.bench_segment(shuffle="bnf")
    ds = DS.from_segment(seg)
    q = C.queries()
    truth = C.ground_truth()
    base_trips = None
    for fw in (1, 2, 3, 4):
        p = dataclasses.replace(DEVICE_SEARCH_BENCH, fetch_width=fw)
        r = DS.device_anns(ds, jnp.asarray(q), p)
        trips_m = float(np.asarray(r.hops).mean())
        if base_trips is None:
            base_trips = trips_m
        C.record("perf_fetch_width", fetch_width=fw,
                 recall=recall_at_k(np.asarray(r.ids), truth),
                 block_reads=float(np.asarray(r.io).mean()),
                 round_trips=trips_m,
                 modeled_latency_us_nvme=trips_m * 95.0,
                 modeled_latency_us_tpu_dma=trips_m * 1.2,
                 speedup_vs_fw1=base_trips / trips_m)


def device_range_search_rounds():
    """RS round restarts (ISSUE 3 satellite): the threaded visited/
    result state keeps block DMAs near-flat as the candidate set
    doubles — each extra round only fetches newly expanded blocks."""
    seg = C.bench_segment(shuffle="bnf")
    ds = DS.from_segment(seg)
    q = C.queries()
    x = C.base_data()
    d_gt = D.pairwise(q, x)
    radius = float(np.quantile(d_gt, 0.002))
    p = DeviceSearchParams(k=10, candidates=32, max_hops=256)
    prev = None
    for rounds in (1, 2, 3):
        r = DS.device_range_search(ds, jnp.asarray(q), radius=radius,
                                   k_cap=128, p=p, rounds=rounds)
        io_m = float(np.asarray(r.io).mean())
        C.record("device_rs_rounds", rounds=rounds,
                 mean_io=io_m,
                 io_growth_vs_prev=(io_m / prev if prev else 1.0))
        prev = io_m


def kernel_micro():
    """Kernel correctness at bench scale + modeled TPU times."""
    from repro.kernels import (block_rank, pairwise_l2, pq_adc_batch,
                               tier0_rank)
    from repro.kernels import ref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((128, C.DIM)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4096, C.DIM)), jnp.float32)
    got = pairwise_l2(q, x)
    err = float(jnp.abs(got - ref.pairwise_l2_ref(q, x)).max())
    flops = 2 * 128 * 4096 * C.DIM
    C.record("kernel_l2_tile", max_err=err, flops=flops,
             modeled_tpu_us=flops / 197e12 * 1e6)
    codes = jnp.asarray(rng.integers(0, 256, (4096, 8)), jnp.uint8)
    luts = jnp.asarray(rng.standard_normal((128, 8, 256)), jnp.float32)
    got = pq_adc_batch(codes, luts)
    err = float(jnp.abs(got - ref.pq_adc_ref(luts, codes)).max())
    flops = 2 * 4096 * 8 * 256 * 128          # one-hot matmul formulation
    C.record("kernel_pq_adc", max_err=err, flops=flops,
             modeled_tpu_us=flops / 197e12 * 1e6)
    tiles = jnp.asarray(rng.standard_normal((128, 16, C.DIM)),
                        jnp.float32)
    dd, idx = block_rank(q, tiles, 5)
    dr, _ = ref.block_rank_ref(q, tiles, 5)
    C.record("kernel_block_topk",
             max_err=float(jnp.abs(dd - dr).max()))
    # fused tier-0 probe+gather+rank vs oracle: 64 blocks, half packed
    cold = jnp.asarray(rng.standard_normal((64, 16, C.DIM)), jnp.float32)
    slot_of = np.full(64, -1, np.int32)
    hot_ids = rng.permutation(64)[:32]
    slot_of[hot_ids] = np.arange(32, dtype=np.int32)
    hot = cold[jnp.asarray(hot_ids)]
    blocks = jnp.asarray(rng.integers(0, 64, (128, 2)), jnp.int32)
    dd, hit = tier0_rank(q, blocks, jnp.asarray(slot_of), hot, cold)
    dr, hr = ref.tier0_fetch_rank_ref(q, blocks, jnp.asarray(slot_of),
                                      hot, cold)
    C.record("kernel_tier0_fetch",
             max_err=float(jnp.abs(dd - dr).max()),
             hit_mismatch=int(jnp.abs(hit - hr).sum()))
