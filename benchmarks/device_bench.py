"""Device-path benchmarks: batched TPU-formulation search vs host oracle,
kernel micro-benchmarks (interpret mode — correctness + op counts, with
modeled TPU timings from the roofline constants)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import device_search as DS
from repro.core import distances as D
from repro.core.search import anns, recall_at_k


def device_vs_host():
    seg = C.bench_segment(shuffle="bnf")
    q = C.queries()
    truth = C.ground_truth()
    ds = DS.from_segment(seg)
    ids, dd, io, hops = DS.device_anns(
        ds, jnp.asarray(q), k=10, candidates=48, max_hops=256)
    C.record("device_anns", impl="device_batched",
             recall=recall_at_k(np.asarray(ids), truth),
             mean_io=float(np.asarray(io).mean()),
             mean_hops=float(np.asarray(hops).mean()))
    hids, _, hstats = anns(seg.view, q, 10, seg.params.search)
    C.record("device_anns", impl="host_oracle",
             recall=recall_at_k(hids, truth),
             mean_io=C.mean_io(hstats), mean_hops=C.mean_hops(hstats))


def batched_beam_throughput():
    """Device QPS scaling with batch size (TPU analogue of the paper's
    thread sweep, Fig. 12): one batched while_loop serves B queries."""
    seg = C.bench_segment(shuffle="bnf")
    ds = DS.from_segment(seg)
    x = C.base_data()
    from repro.data.vectors import query_set
    for b in (8, 32, 128):
        q = query_set(x, b, seed=5)
        fn = lambda qq: DS.device_anns(ds, qq, k=10, candidates=48,
                                       max_hops=256)
        ids, dd, io, _ = fn(jnp.asarray(q))       # compile+run
        jax.block_until_ready(ids)
        t0 = time.perf_counter()
        ids, dd, io, _ = fn(jnp.asarray(q))
        jax.block_until_ready(ids)
        wall = time.perf_counter() - t0
        truth = D.brute_force_knn(x, q, 10)
        C.record("fig12_batched_beam", batch=b,
                 recall=recall_at_k(np.asarray(ids), truth),
                 mean_io=float(np.asarray(io).mean()),
                 wall_s_cpu_interp=wall)


def starling_fetch_width():
    """§Perf cell 3 (paper-representative): multi-block fetch per DMA
    round-trip — exploits the paper's Central Assumption (a few random
    reads per round-trip cost ~one). Round trips are the latency unit;
    block reads are the bandwidth unit."""
    seg = C.bench_segment(shuffle="bnf")
    ds = DS.from_segment(seg)
    q = C.queries()
    truth = C.ground_truth()
    base_trips = None
    for fw in (1, 2, 3, 4):
        ids, dd, io, trips = DS.device_anns(
            ds, jnp.asarray(q), k=10, candidates=48, max_hops=256,
            fetch_width=fw)
        trips_m = float(np.asarray(trips).mean())
        if base_trips is None:
            base_trips = trips_m
        C.record("perf_fetch_width", fetch_width=fw,
                 recall=recall_at_k(np.asarray(ids), truth),
                 block_reads=float(np.asarray(io).mean()),
                 round_trips=trips_m,
                 modeled_latency_us_nvme=trips_m * 95.0,
                 modeled_latency_us_tpu_dma=trips_m * 1.2,
                 speedup_vs_fw1=base_trips / trips_m)


def kernel_micro():
    """Kernel correctness at bench scale + modeled TPU times."""
    from repro.kernels import block_rank, pairwise_l2, pq_adc_batch
    from repro.kernels import ref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((128, C.DIM)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4096, C.DIM)), jnp.float32)
    got = pairwise_l2(q, x)
    err = float(jnp.abs(got - ref.pairwise_l2_ref(q, x)).max())
    flops = 2 * 128 * 4096 * C.DIM
    C.record("kernel_l2_tile", max_err=err, flops=flops,
             modeled_tpu_us=flops / 197e12 * 1e6)
    codes = jnp.asarray(rng.integers(0, 256, (4096, 8)), jnp.uint8)
    luts = jnp.asarray(rng.standard_normal((128, 8, 256)), jnp.float32)
    got = pq_adc_batch(codes, luts)
    err = float(jnp.abs(got - ref.pq_adc_ref(luts, codes)).max())
    flops = 2 * 4096 * 8 * 256 * 128          # one-hot matmul formulation
    C.record("kernel_pq_adc", max_err=err, flops=flops,
             modeled_tpu_us=flops / 197e12 * 1e6)
    tiles = jnp.asarray(rng.standard_normal((128, 16, C.DIM)),
                        jnp.float32)
    dd, idx = block_rank(q, tiles, 5)
    dr, _ = ref.block_rank_ref(q, tiles, 5)
    C.record("kernel_block_topk",
             max_err=float(jnp.abs(dd - dr).max()))
