"""Perf-regression gate over the BENCH_*.json artifacts (ISSUE 8/9).

Compares freshly emitted ``results/BENCH_<name>.json`` files (written
by the bench smokes that just ran, e.g. ``make bench-batch`` /
``make bench-mesh``) against the committed baselines of the same
artifacts (``git show <ref>:results/BENCH_<name>.json``) and FAILS
(exit 1) when any gated metric regressed by more than ``--threshold``
(default 10%). Gates are direction-aware: a ``lower``-is-better
metric fails when it RISES past the threshold, a ``higher``-is-better
one (e.g. the drift-repack modeled-DMA cut) when it FALLS past it.

``ARTIFACT_GATES`` names every gated artifact, its gated metrics with
their directions, and its comparability keys. Everything else shared
between the two payloads is printed as an informational delta. Metrics
present only on one side (a PR adding or retiring a metric) are
reported, never failed on, so the gate does not block schema
evolution.

The gate compares like with like or not at all: if the artifact's
comparability keys differ between the fresh and baseline configs, the
numbers come from different sweeps and the gate SKIPS (exit 0 with a
notice) instead of failing on an apples-to-oranges diff. Likewise when
the baseline does not exist at the ref (first PR emitting the
artifact) or the fresh file was never written (the sweep skipped for
lack of a jax backend).

Usage (what ``make bench-batch``/``bench-mesh`` and CI run):

    python -m benchmarks.check_regression                  # all gates
    python -m benchmarks.check_regression --artifact mesh_router \
        --threshold 0.10 --ref HEAD
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

# every gated artifact: metric -> direction ("lower" fails on a rise
# past threshold, "higher" on a fall past it), plus the config keys
# that must match for fresh and baseline to be comparable at all
ARTIFACT_GATES = {
    "device_batch_dedup": {
        "metrics": {"modeled_dma_per_query": "lower",
                    "modeled_latency_us_tpu": "lower"},
        "compare_keys": ("batch", "smoke", "n", "dim"),
    },
    "mesh_router": {
        # the mesh step is paced by its slowest rank — the one number
        # the router, the scheduler and mesh_qps_estimate all optimize
        "metrics": {"modeled_step_us_slowest_rank": "lower"},
        "compare_keys": ("ranks", "segments", "n_per_seg", "n_query",
                         "smoke", "dim"),
    },
    "device_drift_repack": {
        # higher is better: the fraction of modeled DMAs the scheduled
        # repack removed on the drifted stream
        "metrics": {"modeled_dma_cut": "higher"},
        "compare_keys": ("n", "dim", "tier0_frac", "hysteresis",
                         "smoke"),
    },
    "device_speculate": {
        "metrics": {"modeled_latency_us_speculative": "lower",
                    "spec_hit_rate": "higher"},
        "compare_keys": ("n", "dim", "tier0_frac", "fetch_width",
                         "smoke"),
    },
    "hybrid_hot_tier": {
        # the hybrid contract: cold I/O cut holds (higher is better)
        # and the memory-priced hybrid latency does not creep back up
        "metrics": {"cold_io_cut": "higher",
                    "modeled_latency_us_nvme": "lower"},
        "compare_keys": ("n", "dim", "budget_frac", "smoke"),
    },
}

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _metric_map(payload):
    return {m["name"]: float(m["value"])
            for m in payload.get("metrics", [])
            if isinstance(m.get("value"), (int, float))}


def load_fresh(artifact: str):
    path = os.path.join(REPO_ROOT, "results", f"BENCH_{artifact}.json")
    if not os.path.exists(path):
        return None, path
    with open(path) as f:
        return json.load(f), path


def load_baseline(artifact: str, ref: str):
    """The committed artifact at ``ref``, or None when it has none."""
    try:
        blob = subprocess.run(
            ["git", "show", f"{ref}:results/BENCH_{artifact}.json"],
            cwd=REPO_ROOT, capture_output=True, text=True, check=True,
        ).stdout
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None
    try:
        return json.loads(blob)
    except json.JSONDecodeError:
        return None


def check(artifact: str, threshold: float, ref: str) -> int:
    gate = ARTIFACT_GATES.get(artifact, {})
    gated_metrics = gate.get("metrics", {})
    compare_keys = gate.get("compare_keys", ())
    fresh, path = load_fresh(artifact)
    if fresh is None:
        print(f"[check_regression] SKIP: no fresh {path} (bench "
              f"skipped?) — nothing to gate")
        return 0
    base = load_baseline(artifact, ref)
    if base is None:
        print(f"[check_regression] SKIP: no committed baseline for "
              f"BENCH_{artifact}.json at {ref} — first emission passes")
        return 0
    fcfg, bcfg = fresh.get("config", {}), base.get("config", {})
    mismatched = [k for k in compare_keys
                  if fcfg.get(k) != bcfg.get(k)]
    if mismatched:
        print(f"[check_regression] SKIP BENCH_{artifact}.json: configs "
              f"differ on {mismatched} "
              f"(fresh {[fcfg.get(k) for k in mismatched]} "
              f"vs baseline {[bcfg.get(k) for k in mismatched]}) — "
              f"not comparable")
        return 0
    fm, bm = _metric_map(fresh), _metric_map(base)
    failures = []
    for name in sorted(set(fm) | set(bm)):
        if name not in fm:
            print(f"[check_regression] note: {name} retired "
                  f"(baseline {bm[name]:.4g})")
            continue
        if name not in bm:
            print(f"[check_regression] note: {name} is new "
                  f"(fresh {fm[name]:.4g})")
            continue
        f_v, b_v = fm[name], bm[name]
        rel = (f_v - b_v) / abs(b_v) if b_v else (0.0 if f_v == b_v
                                                  else float("inf"))
        direction = gated_metrics.get(name)
        tag = "GATED" if direction else "info "
        print(f"[check_regression] {tag} {name}: {b_v:.4g} -> "
              f"{f_v:.4g} ({rel:+.1%})")
        # direction-aware: "lower" metrics regress by rising, "higher"
        # metrics by falling
        regressed = (direction == "lower" and rel > threshold) or \
            (direction == "higher" and rel < -threshold)
        if regressed:
            verb = "rose" if direction == "lower" else "fell"
            failures.append(
                f"{name} {verb} {rel:+.1%} "
                f"({b_v:.4g} -> {f_v:.4g}, threshold {threshold:.0%})")
    if failures:
        print(f"[check_regression] FAIL BENCH_{artifact}.json vs {ref}:")
        for f_msg in failures:
            print(f"  - {f_msg}")
        return 1
    print(f"[check_regression] OK: BENCH_{artifact}.json within "
          f"{threshold:.0%} of the {ref} baseline")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--artifact", default="all",
                    help="BENCH_<artifact>.json to gate, or 'all' for "
                         "every ARTIFACT_GATES entry")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="max allowed relative regression of a gated "
                         "metric (direction-aware)")
    ap.add_argument("--ref", default="HEAD",
                    help="git ref holding the committed baseline")
    args = ap.parse_args(argv)
    artifacts = (sorted(ARTIFACT_GATES) if args.artifact == "all"
                 else [args.artifact])
    rc = 0
    for artifact in artifacts:
        rc |= check(artifact, args.threshold, args.ref)
    return rc


if __name__ == "__main__":
    sys.exit(main())
