"""Perf-regression gate over the BENCH_*.json artifacts (ISSUE 8).

Compares a freshly emitted ``results/BENCH_<name>.json`` (written by
the bench smoke that just ran, e.g. ``make bench-batch``) against the
committed baseline of the same artifact (``git show
<ref>:results/BENCH_<name>.json``) and FAILS (exit 1) when any gated
lower-is-better metric regressed by more than ``--threshold``
(default 10%).

Gated metrics for the batched-dedup artifact: ``modeled_dma_per_query``
and ``modeled_latency_us_tpu`` — the two numbers the whole-batch dedup
+ DMA pipelining work moves. Everything else shared between the two
artifacts is printed as an informational delta. Metrics present only
on one side (a PR adding or retiring a metric) are reported, never
failed on, so the gate does not block schema evolution.

The gate compares like with like or not at all: if the comparability
keys of the configs differ (``batch``, ``smoke``, ``n``, ``dim``) the
numbers come from different sweeps and the gate SKIPS (exit 0 with a
notice) instead of failing on an apples-to-oranges diff. Likewise when
the baseline does not exist at the ref (first PR emitting the
artifact) or the fresh file was never written (the sweep skipped for
lack of a jax backend).

Usage (what ``make bench-batch`` and the CI device lane run):

    python -m benchmarks.check_regression
    python -m benchmarks.check_regression --artifact device_batch_dedup \
        --threshold 0.10 --ref HEAD
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

# lower-is-better metrics that fail the gate when they rise >threshold
GATED_METRICS = ("modeled_dma_per_query", "modeled_latency_us_tpu")
# config keys that must match for two artifacts to be comparable
COMPARABILITY_KEYS = ("batch", "smoke", "n", "dim")

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _metric_map(payload):
    return {m["name"]: float(m["value"])
            for m in payload.get("metrics", [])
            if isinstance(m.get("value"), (int, float))}


def load_fresh(artifact: str):
    path = os.path.join(REPO_ROOT, "results", f"BENCH_{artifact}.json")
    if not os.path.exists(path):
        return None, path
    with open(path) as f:
        return json.load(f), path


def load_baseline(artifact: str, ref: str):
    """The committed artifact at ``ref``, or None when it has none."""
    try:
        blob = subprocess.run(
            ["git", "show", f"{ref}:results/BENCH_{artifact}.json"],
            cwd=REPO_ROOT, capture_output=True, text=True, check=True,
        ).stdout
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None
    try:
        return json.loads(blob)
    except json.JSONDecodeError:
        return None


def check(artifact: str, threshold: float, ref: str) -> int:
    fresh, path = load_fresh(artifact)
    if fresh is None:
        print(f"[check_regression] SKIP: no fresh {path} (bench "
              f"skipped?) — nothing to gate")
        return 0
    base = load_baseline(artifact, ref)
    if base is None:
        print(f"[check_regression] SKIP: no committed baseline for "
              f"BENCH_{artifact}.json at {ref} — first emission passes")
        return 0
    fcfg, bcfg = fresh.get("config", {}), base.get("config", {})
    mismatched = [k for k in COMPARABILITY_KEYS
                  if fcfg.get(k) != bcfg.get(k)]
    if mismatched:
        print(f"[check_regression] SKIP: configs differ on "
              f"{mismatched} (fresh {[fcfg.get(k) for k in mismatched]} "
              f"vs baseline {[bcfg.get(k) for k in mismatched]}) — "
              f"not comparable")
        return 0
    fm, bm = _metric_map(fresh), _metric_map(base)
    failures = []
    for name in sorted(set(fm) | set(bm)):
        if name not in fm:
            print(f"[check_regression] note: {name} retired "
                  f"(baseline {bm[name]:.4g})")
            continue
        if name not in bm:
            print(f"[check_regression] note: {name} is new "
                  f"(fresh {fm[name]:.4g})")
            continue
        f_v, b_v = fm[name], bm[name]
        rel = (f_v - b_v) / abs(b_v) if b_v else (0.0 if f_v == b_v
                                                  else float("inf"))
        gated = name in GATED_METRICS
        tag = "GATED" if gated else "info "
        print(f"[check_regression] {tag} {name}: {b_v:.4g} -> "
              f"{f_v:.4g} ({rel:+.1%})")
        if gated and rel > threshold:
            failures.append(
                f"{name} regressed {rel:+.1%} "
                f"({b_v:.4g} -> {f_v:.4g}, threshold +{threshold:.0%})")
    if failures:
        print(f"[check_regression] FAIL BENCH_{artifact}.json vs {ref}:")
        for f_msg in failures:
            print(f"  - {f_msg}")
        return 1
    print(f"[check_regression] OK: BENCH_{artifact}.json within "
          f"+{threshold:.0%} of the {ref} baseline")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--artifact", default="device_batch_dedup",
                    help="BENCH_<artifact>.json to gate")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="max allowed relative rise of a gated metric")
    ap.add_argument("--ref", default="HEAD",
                    help="git ref holding the committed baseline")
    args = ap.parse_args(argv)
    return check(args.artifact, args.threshold, args.ref)


if __name__ == "__main__":
    sys.exit(main())
