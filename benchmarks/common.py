"""Shared benchmark fixtures: datasets, segments, metric helpers.

Scale note: the paper's segment is 33M vectors on NVMe; this container is
one CPU core, so benchmarks run the same algorithms at 10^3-10^4 vectors
and report *I/O counts and ratios* (hardware-independent) plus *modeled*
latency/QPS through the calibrated cost models in ``core/iostats.py``
(clearly labeled modeled-not-measured).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs.starling_segment import SEGMENT_BENCH
from repro.core import distances as D
from repro.core.iostats import NVME_SEGMENT, TPU_HBM_SEGMENT, IOStats
from repro.core.segment import Segment, build_segment
from repro.data.vectors import clustered_vectors, query_set

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "bench_results.jsonl")

N_BASE = 6000
DIM = 64
N_QUERY = 32


@functools.lru_cache(maxsize=4)
def base_data(n: int = N_BASE, dim: int = DIM, seed: int = 0):
    return clustered_vectors(n, dim, num_clusters=48, seed=seed)


@functools.lru_cache(maxsize=8)
def bench_segment(shuffle: str = "bnf", algo: str = "vamana",
                  n: int = N_BASE, use_nav: bool = True) -> Segment:
    x = base_data(n)
    p = SEGMENT_BENCH
    p = dataclasses.replace(
        p, graph=dataclasses.replace(p.graph, algo=algo),
        layout=dataclasses.replace(p.layout, shuffle=shuffle),
        search=dataclasses.replace(p.search, use_nav_graph=use_nav))
    return build_segment(x, p)


@functools.lru_cache(maxsize=2)
def queries(num: int = N_QUERY, in_db: bool = False):
    return query_set(base_data(), num, in_db=in_db, seed=1)


@functools.lru_cache(maxsize=4)
def ground_truth(k: int = 10):
    return D.brute_force_knn(base_data(), queries(), k)


def mean_io(stats: List[IOStats]) -> float:
    return float(np.mean([s.block_reads for s in stats]))


def mean_xi(stats: List[IOStats]) -> float:
    return float(np.mean([s.vertex_utilization for s in stats]))


def mean_hops(stats: List[IOStats]) -> float:
    return float(np.mean([s.hops for s in stats]))


def mean_ell(stats: List[IOStats]) -> float:
    """Paper's path length: hops until the final top-1 was found."""
    return float(np.mean([s.hops_to_best for s in stats]))


def modeled(stats: List[IOStats], pipeline: bool = True,
            cost=NVME_SEGMENT) -> Dict[str, float]:
    lat = [cost.latency_us(s, pipeline=pipeline) for s in stats]
    mean_us = float(np.mean(lat))
    return {"latency_us_" + cost.name: mean_us,
            "qps_" + cost.name: 1e6 / mean_us if mean_us else 0.0}


_results: List[Dict] = []


def record(bench: str, **fields) -> Dict:
    rec = {"bench": bench, **fields}
    _results.append(rec)
    os.makedirs(os.path.dirname(os.path.abspath(RESULTS_PATH)),
                exist_ok=True)
    with open(RESULTS_PATH, "a") as f:
        f.write(json.dumps(rec) + "\n")
    flat = " ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in fields.items())
    print(f"[{bench}] {flat}", flush=True)
    return rec


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
