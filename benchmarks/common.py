"""Shared benchmark fixtures: datasets, segments, metric helpers.

Scale note: the paper's segment is 33M vectors on NVMe; this container is
one CPU core, so benchmarks run the same algorithms at 10^3-10^4 vectors
and report *I/O counts and ratios* (hardware-independent) plus *modeled*
latency/QPS through the calibrated cost models in ``core/iostats.py``
(clearly labeled modeled-not-measured).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs.starling_segment import SEGMENT_BENCH
from repro.core import distances as D
from repro.core.iostats import NVME_SEGMENT, TPU_HBM_SEGMENT, IOStats
from repro.core.segment import Segment, build_segment
from repro.data.vectors import clustered_vectors, query_set

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "bench_results.jsonl")

N_BASE = 6000
DIM = 64
N_QUERY = 32


@functools.lru_cache(maxsize=4)
def base_data(n: int = N_BASE, dim: int = DIM, seed: int = 0):
    return clustered_vectors(n, dim, num_clusters=48, seed=seed)


@functools.lru_cache(maxsize=8)
def bench_segment(shuffle: str = "bnf", algo: str = "vamana",
                  n: int = N_BASE, use_nav: bool = True) -> Segment:
    x = base_data(n)
    p = SEGMENT_BENCH
    p = dataclasses.replace(
        p, graph=dataclasses.replace(p.graph, algo=algo),
        layout=dataclasses.replace(p.layout, shuffle=shuffle),
        search=dataclasses.replace(p.search, use_nav_graph=use_nav))
    return build_segment(x, p)


@functools.lru_cache(maxsize=2)
def queries(num: int = N_QUERY, in_db: bool = False):
    return query_set(base_data(), num, in_db=in_db, seed=1)


@functools.lru_cache(maxsize=4)
def ground_truth(k: int = 10):
    return D.brute_force_knn(base_data(), queries(), k)


def mean_io(stats: List[IOStats]) -> float:
    return float(np.mean([s.block_reads for s in stats]))


def mean_xi(stats: List[IOStats]) -> float:
    return float(np.mean([s.vertex_utilization for s in stats]))


def mean_hops(stats: List[IOStats]) -> float:
    return float(np.mean([s.hops for s in stats]))


def mean_ell(stats: List[IOStats]) -> float:
    """Paper's path length: hops until the final top-1 was found."""
    return float(np.mean([s.hops_to_best for s in stats]))


def modeled(stats: List[IOStats], pipeline: bool = True,
            cost=NVME_SEGMENT) -> Dict[str, float]:
    lat = [cost.latency_us(s, pipeline=pipeline) for s in stats]
    mean_us = float(np.mean(lat))
    return {"latency_us_" + cost.name: mean_us,
            "qps_" + cost.name: 1e6 / mean_us if mean_us else 0.0}


_results: List[Dict] = []

# standardized perf artifacts (repro.obs satellite): one
# results/BENCH_<name>.json per bench smoke, schema-stable across PRs
# so the perf trajectory is diffable and CI-uploadable
ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
ARTIFACT_SCHEMA = "repro.bench.v1"


def config_hash(config: Dict) -> str:
    """Stable short hash of a bench configuration — artifacts with
    equal hashes are comparable across PRs; a hash change flags that a
    metric moved because the *config* moved."""
    import hashlib
    blob = json.dumps(config, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def perf_artifact(name: str, metrics: List[Dict],
                  config: Optional[Dict] = None,
                  measured: bool = False) -> str:
    """Write ``results/BENCH_<name>.json``.

    ``metrics`` rows carry ``name``/``value``/``units`` (and may
    override the artifact-level ``measured`` flag per row); ``measured``
    states whether values came from wall-clock (True) or the cost model
    (False) — the modeled-vs-measured flag every consumer must check
    before comparing numbers across hardware."""
    config = config or {}
    rows = []
    for m in metrics:
        row = {"name": str(m["name"]), "value": m["value"],
               "units": str(m.get("units", "")),
               "measured": bool(m.get("measured", measured))}
        rows.append(row)
    payload = {"schema": ARTIFACT_SCHEMA, "bench": name,
               "config": config, "config_hash": config_hash(config),
               "measured": bool(measured), "metrics": rows}
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"[artifact] {os.path.basename(path)}: {len(rows)} metrics "
          f"(measured={measured})", flush=True)
    return path


def validate_perf_artifact(payload: Dict) -> List[str]:
    """Schema check for BENCH_*.json (used by tests and the CI obs
    lane); returns a list of problems, empty when valid."""
    problems = []
    if payload.get("schema") != ARTIFACT_SCHEMA:
        problems.append(f"schema must be {ARTIFACT_SCHEMA!r}")
    for key in ("bench", "config", "config_hash", "measured", "metrics"):
        if key not in payload:
            problems.append(f"missing {key!r}")
    for i, m in enumerate(payload.get("metrics", [])):
        for key in ("name", "value", "units", "measured"):
            if key not in m:
                problems.append(f"metrics[{i}]: missing {key!r}")
        if "value" in m and not isinstance(m["value"], (int, float)):
            problems.append(f"metrics[{i}]: value must be a number")
    return problems


def record(bench: str, **fields) -> Dict:
    rec = {"bench": bench, **fields}
    _results.append(rec)
    os.makedirs(os.path.dirname(os.path.abspath(RESULTS_PATH)),
                exist_ok=True)
    with open(RESULTS_PATH, "a") as f:
        f.write(json.dumps(rec) + "\n")
    flat = " ".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in fields.items())
    print(f"[{bench}] {flat}", flush=True)
    return rec


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
