"""repro.obs benchmarks: the Perfetto trace smoke (a served batch
recorded end-to-end, exported, schema-validated) and the
measured-vs-modeled cost calibration harness (ROADMAP adaptive-plane
v2 items 3+4).

``obs_trace_smoke`` runs a real coordinator batch under a wall-clock
tracer + metrics registry, appends the *modeled* device-round timeline
(the ``trace_rounds`` buffer priced through the TPU cost model), and
writes ``results/trace_smoke.json`` — valid Chrome-trace-event JSON
the CI obs lane re-validates and uploads.

``cost_calibration`` fits ``CostModel`` constants per backend regime:

  * host/NVMe — replay host search batches under wall-clock timing
    (``measured=True``: real clock on this container's CPU, so the
    fitted ``t_block_io`` prices a *Python block visit*, not NVMe —
    the artifact's measured flag plus the preset's ``source`` say so);
  * device/TPU — recover known constants from synthetically priced
    device traffic (``measured=False``): real searches produce the
    counters, a perturbed ground-truth model prices them, and the fit
    must recover that model near-exactly (asserted) — the
    identifiability check that makes the wall-clock fit trustworthy.

Both presets land in ``results/CALIB_<backend>.json`` and a
``BENCH_cost_calibration.json`` perf artifact carries the residuals.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from benchmarks import common as C
from repro.core.iostats import IOStats, NVME_SEGMENT, TPU_HBM_SEGMENT
from repro.core.search import anns
from repro.obs import (CalibrationSample, MetricsRegistry, Tracer,
                       WallClock, calibrate, fold_round_log,
                       round_log_totals, timeline_from_round_log,
                       validate_chrome_trace, write_chrome_trace)

TRACE_PATH = os.path.join(C.ARTIFACT_DIR, "trace_smoke.json")


def obs_trace_smoke():
    """One served batch, fully traced: coordinator spans, host-path
    io.read spans, scheduler events, metrics registry — exported as
    Chrome-trace-event JSON and validated in-bench. Also renders the
    modeled device timeline from the round-granular trace buffer and
    asserts the buffer folds exactly to the batch counters."""
    import jax
    from repro.core import device_search as DS
    try:
        jax.devices()
    except RuntimeError as e:           # no backend: record the skip
        C.record("obs_trace_smoke", skipped=str(e))
        return
    from repro.configs.starling_segment import (DEVICE_SEARCH_BATCH,
                                                SEGMENT_BENCH_CACHED)
    from repro.core.segment import build_segment
    from repro.serving import (HostSegmentServer, QueryCoordinator,
                               SegmentServer)

    x = C.base_data()
    seg = build_segment(x, SEGMENT_BENCH_CACHED)  # cache-fronted host view
    q = C.queries()[:8]

    tracer = Tracer(clock=WallClock())
    metrics = MetricsRegistry()

    # serving plane: a device server (round-granular tracing on) behind
    # the coordinator, plus a traced host server for the io.read spans
    p = dataclasses.replace(DEVICE_SEARCH_BATCH, trace_rounds=True)
    server = SegmentServer(segment=DS.from_segment(seg, tier0_frac=0.1),
                           offset=0, num_vectors=x.shape[0], host=seg,
                           params=p)
    hserver = HostSegmentServer.from_segment(seg, 0)
    coord = QueryCoordinator([server], tracer=tracer, metrics=metrics)
    hserver.tracer = tracer
    hserver.view.store.attach_obs(tracer, metrics, target="seg0-host")

    hserver.search(q)                     # host spans + io.read spans
    _, _, stats = coord.search(q, k=10)   # coord spans + device columns

    # the round-granular buffer must fold EXACTLY to the batch counters
    records = fold_round_log(server.last_round_log, server.last_rounds)
    tot = round_log_totals(records)
    assert tot["io"] == int(server.last_io.sum())
    assert tot["hops"] == int(server.last_hops.sum())
    assert tot["tier0_hits"] == int(server.last_tier0_hits.sum())
    assert tot["dedup_saved"] == int(server.last_dedup_saved.sum())
    # modeled device timeline rides the same trace file, its own track
    timeline_from_round_log(records, TPU_HBM_SEGMENT, tracer=tracer,
                            track="device-modeled")

    write_chrome_trace(TRACE_PATH, tracer,
                       metadata={"bench": "obs_trace_smoke"})
    import json
    with open(TRACE_PATH) as f:        # validate the round-tripped file
        problems = validate_chrome_trace(json.load(f))
    assert not problems, f"invalid Perfetto export: {problems}"

    snap = metrics.snapshot()
    C.record("obs_trace_smoke",
             events=len(tracer), dropped=tracer.dropped,
             tracks=len({e.track for e in tracer.events}),
             device_rounds=tot["rounds"],
             metric_names=len(snap),
             serve_batches=metrics.value("serve.batches"),
             total_block_reads=stats["total_block_reads"],
             trace_path=os.path.basename(TRACE_PATH))
    C.perf_artifact(
        "obs_trace_smoke", [
            {"name": "trace_events", "value": len(tracer),
             "units": "events", "measured": True},
            {"name": "device_rounds", "value": tot["rounds"],
             "units": "rounds"},
            {"name": "trace_dropped", "value": tracer.dropped,
             "units": "events", "measured": True}],
        config={"n": C.N_BASE, "dim": C.DIM, "batch": int(q.shape[0]),
                "tier0_frac": 0.1},
        measured=False)


def _host_samples():
    """Replay host search batches under wall-clock timing, varying the
    beam width so the sample matrix has rank (different counter mixes
    identify different constants)."""
    seg = C.bench_segment()
    clk = WallClock()
    samples = []
    for gamma in (24, 48, 64, 96):
        sp = dataclasses.replace(seg.params.search, candidate_size=gamma)
        for nq in (8, 16, 32):
            q = C.queries()[:nq]
            t0 = clk.now_us()
            _, _, stats = anns(seg.view, q, 10, sp)
            t1 = clk.now_us()
            tot = IOStats()
            for s in stats:
                tot.merge(s)
            samples.append(CalibrationSample(tot, t1 - t0))
    return samples


def _device_samples(ground_truth):
    """Real device searches priced by a known perturbed model — the
    recovery target the fit must reproduce."""
    import jax.numpy as jnp
    from repro.configs.starling_segment import DEVICE_SEARCH_BATCH
    from repro.core import device_search as DS
    from repro.data.vectors import query_set
    seg = C.bench_segment(shuffle="bnf")
    ds = DS.from_segment(seg, tier0_frac=0.05)
    x = C.base_data()
    samples = []
    for b in (4, 8, 16, 32):
        q = query_set(x, 32, seed=5)[:b]
        r = DS.device_anns(ds, jnp.asarray(q), DEVICE_SEARCH_BATCH)
        batch = IOStats.from_device_batch(
            np.asarray(r.io), np.asarray(r.tier0_hits),
            np.asarray(r.hops), np.asarray(r.dedup_saved),
            int(r.rounds))
        samples.append(CalibrationSample(
            batch, ground_truth.latency_us(batch)))
    return samples


def cost_calibration():
    """Fit, store, and report per-backend calibration presets."""
    # --- host/NVMe regime: wall-clock measured on THIS container
    host_samples = _host_samples()
    _, preset_h, rep_h = calibrate(
        NVME_SEGMENT, host_samples,
        source="host anns replay, wall-clock, CPU container",
        preset_path=os.path.join(C.ARTIFACT_DIR, "CALIB_nvme.json"))
    C.record("cost_calibration", backend="nvme", measured=True,
             n_samples=len(host_samples),
             fitted=",".join(sorted(preset_h.constants)) or "none",
             unfit=",".join(preset_h.unfit) or "none",
             err_before=rep_h["error_before"]["mean_abs_rel_err"],
             err_after=rep_h["error_after"]["mean_abs_rel_err"])
    # the fit must not make the model WORSE on its own samples
    assert rep_h["error_after"]["mean_abs_rel_err"] <= \
        rep_h["error_before"]["mean_abs_rel_err"] + 1e-9

    # --- device/TPU regime: recover a known perturbed model
    try:
        import jax
        jax.devices()
    except RuntimeError as e:
        C.record("cost_calibration", backend="tpu-hbm", skipped=str(e))
        return
    truth = dataclasses.replace(TPU_HBM_SEGMENT, t_block_io=2.4,
                                t_batch_block=0.6, t_round=3.0,
                                t_round_comp=0.4)
    dev_samples = _device_samples(truth)
    fitted, preset_d, rep_d = calibrate(
        TPU_HBM_SEGMENT, dev_samples,
        source="device anns replay, synthetic ground-truth pricing",
        preset_path=os.path.join(C.ARTIFACT_DIR, "CALIB_tpu-hbm.json"))
    err_d = rep_d["error_after"]["mean_abs_rel_err"]
    assert err_d < 0.05, (
        f"calibration must recover the known device model "
        f"(residual {err_d:.3f})")
    C.record("cost_calibration", backend="tpu-hbm", measured=False,
             n_samples=len(dev_samples),
             fitted=",".join(sorted(preset_d.constants)) or "none",
             unfit=",".join(preset_d.unfit) or "none",
             err_before=rep_d["error_before"]["mean_abs_rel_err"],
             err_after=err_d)
    C.perf_artifact(
        "cost_calibration", [
            {"name": "nvme_mean_abs_rel_err",
             "value": rep_h["error_after"]["mean_abs_rel_err"],
             "units": "ratio", "measured": True},
            {"name": "nvme_mean_measured_us",
             "value": rep_h["error_after"]["mean_measured_us"],
             "units": "us", "measured": True},
            {"name": "tpu_recovery_mean_abs_rel_err", "value": err_d,
             "units": "ratio"}],
        config={"n": C.N_BASE, "dim": C.DIM,
                "host_samples": len(host_samples),
                "device_samples": len(dev_samples)},
        measured=False)
