"""Benchmark driver: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only tab2_io_efficiency

Rows stream to results/bench_results.jsonl; latency/QPS values are
modeled via the calibrated NVMe/TPU cost models (CPU container — see
benchmarks/common.py).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (device_bench, io_bench, mesh_bench, obs_bench,
                        paper_tables, roofline_report)

BENCHES = [
    paper_tables.fig9_block_shuffling,
    paper_tables.tab2_io_efficiency,
    paper_tables.fig6_7_anns_frontier,
    paper_tables.fig4_5_range_search,
    paper_tables.fig8_index_cost,
    paper_tables.fig10_nav_graph_ablation,
    paper_tables.fig11_block_search_opts,
    paper_tables.fig13_k_sweep,
    paper_tables.tab3_multi_segment,
    paper_tables.fig15_segment_size,
    paper_tables.fig16_graph_algos,
    paper_tables.fig17_in_database_queries,
    paper_tables.appC_bnf_params,
    paper_tables.appF_bnf_vs_bns,
    paper_tables.appG_partitioners,
    io_bench.io_cache_hit_rate_sweep,
    io_bench.io_prefetch_width_sweep,
    io_bench.io_queue_depth_sweep,
    io_bench.io_tier2_budget_sweep,
    paper_tables.mesh_qps_estimate,
    mesh_bench.mesh_router_bench,
    device_bench.device_vs_host,
    device_bench.device_tier0_budget_sweep,
    device_bench.device_batch_dedup_sweep,
    device_bench.device_drift_repack_sweep,
    device_bench.device_speculate_sweep,
    device_bench.hybrid_hot_tier_sweep,
    device_bench.starling_fetch_width,
    device_bench.device_range_search_rounds,
    device_bench.batched_beam_throughput,
    device_bench.kernel_micro,
    obs_bench.obs_trace_smoke,
    obs_bench.cost_calibration,
    roofline_report.roofline_tables,
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = 0
    for fn in BENCHES:
        if args.only and args.only != fn.__name__:
            continue
        print(f"=== {fn.__name__} ===", flush=True)
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
        print(f"=== {fn.__name__} done in "
              f"{time.perf_counter() - t0:.1f}s ===", flush=True)
    return failures


if __name__ == "__main__":
    sys.exit(main())
