"""One benchmark per paper table/figure (§6 + appendices).

Each function prints/records its rows; ``run.py`` drives them all.
Latency/QPS figures are *modeled* through the NVMe/TPU cost models (this
is a CPU container — see common.py); I/O counts, OR(G), xi, path length
and recall/AP are exact algorithm outputs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import numpy as np

from benchmarks import common as C
from repro.core import baseline as B
from repro.core import distances as D
from repro.core import layout as L
from repro.core.iostats import NVME_SEGMENT, TPU_HBM_SEGMENT
from repro.core.search import (anns, average_precision, range_search,
                               recall_at_k)


# ------------------------------------------------------------ Fig. 9

def fig9_block_shuffling():
    """OR(G) + blocks holding the top-100 NN, per layout scheme."""
    x = C.base_data()
    q = C.queries()
    top100 = D.brute_force_knn(x, q, 100)
    for scheme in ("none", "bnp", "bnf"):
        seg = C.bench_segment(shuffle=scheme)
        lay = seg.view.layout
        orr = L.overlap_ratio(seg.graph, lay)
        blocks = float(np.mean([
            len(set(lay.block_of[row].tolist())) for row in top100]))
        ids, _, stats = anns(seg.view, q, 10, seg.params.search)
        C.record("fig9_shuffling", scheme=scheme, overlap_ratio=orr,
                 blocks_for_top100=blocks, mean_io=C.mean_io(stats),
                 recall=recall_at_k(ids, top100[:, :10]))


# ------------------------------------------------------------- Tab. 2

def tab2_io_efficiency():
    """Vertex utilization xi and search path length ell: baseline vs
    Starling at matched recall."""
    seg_s = C.bench_segment(shuffle="bnf")
    seg_b = C.bench_segment(shuffle="none")
    q = C.queries()
    truth = C.ground_truth()
    p_s = seg_s.params.search
    p_b = dataclasses.replace(p_s, use_block_search=False,
                              use_nav_graph=False)
    ids_s, _, st_s = anns(seg_s.view, q, 10, p_s)
    ids_b, _, st_b = B.vertex_anns(seg_b.view, q, 10, p_b)
    C.record("tab2_io", framework="starling", xi=C.mean_xi(st_s),
             ell=C.mean_ell(st_s), mean_io=C.mean_io(st_s),
             recall=recall_at_k(ids_s, truth))
    C.record("tab2_io", framework="diskann_baseline", xi=C.mean_xi(st_b),
             ell=C.mean_ell(st_b), mean_io=C.mean_io(st_b),
             recall=recall_at_k(ids_b, truth))


# --------------------------------------------------------- Fig. 6 / 7

def fig6_7_anns_frontier():
    """Recall vs mean I/O + modeled latency/QPS, sweeping candidate size
    (the paper's frontier plots)."""
    seg_s = C.bench_segment(shuffle="bnf")
    seg_b = C.bench_segment(shuffle="none")
    q = C.queries()
    truth = C.ground_truth()
    for gamma in (16, 32, 64, 128):
        p_s = dataclasses.replace(seg_s.params.search,
                                  candidate_size=gamma)
        ids, _, st = anns(seg_s.view, q, 10, p_s)
        C.record("fig6_7_anns", framework="starling", gamma=gamma,
                 recall=recall_at_k(ids, truth), mean_io=C.mean_io(st),
                 **C.modeled(st), **C.modeled(st, cost=TPU_HBM_SEGMENT))
        p_b = dataclasses.replace(seg_b.params.search,
                                  candidate_size=gamma,
                                  use_block_search=False,
                                  use_nav_graph=False)
        ids, _, st = B.vertex_anns(seg_b.view, q, 10, p_b)
        C.record("fig6_7_anns", framework="diskann_baseline", gamma=gamma,
                 recall=recall_at_k(ids, truth), mean_io=C.mean_io(st),
                 **C.modeled(st, pipeline=False),
                 **C.modeled(st, pipeline=False, cost=TPU_HBM_SEGMENT))


# --------------------------------------------------------- Fig. 4 / 5

def fig4_5_range_search():
    """RS: AP vs mean I/O + modeled latency, Starling vs repeated-ANNS
    baseline, over radii (Fig. 14's sweep folded in)."""
    seg_s = C.bench_segment(shuffle="bnf")
    seg_b = C.bench_segment(shuffle="none")
    x, q = C.base_data(), C.queries()
    d_gt = D.pairwise(q, x)
    for quant in (0.001, 0.003, 0.01):
        radius = float(np.quantile(d_gt, quant))
        gt = D.brute_force_range(x, q, radius)
        res, st = range_search(seg_s.view, q, radius,
                               seg_s.params.search)
        C.record("fig4_5_rs", framework="starling", radius_q=quant,
                 ap=average_precision(res, gt), mean_io=C.mean_io(st),
                 **C.modeled(st))
        p_b = dataclasses.replace(seg_b.params.search,
                                  use_block_search=False,
                                  use_nav_graph=False)
        res, st = B.vertex_range_search(seg_b.view, q, radius, p_b)
        C.record("fig4_5_rs", framework="diskann_repeated_anns",
                 radius_q=quant, ap=average_precision(res, gt),
                 mean_io=C.mean_io(st), **C.modeled(st, pipeline=False))


# ------------------------------------------------------------- Fig. 8

def fig8_index_cost():
    """Index processing time breakdown (Eq. 8) + memory cost (Eq. 10)."""
    seg = C.bench_segment(shuffle="bnf")
    t = seg.build_times
    total = sum(t.values())
    C.record("fig8_index_cost", component="disk_graph",
             seconds=t["disk_graph_s"], frac=t["disk_graph_s"] / total)
    C.record("fig8_index_cost", component="shuffling",
             seconds=t["shuffling_s"], frac=t["shuffling_s"] / total,
             frac_of_graph=t["shuffling_s"] / t["disk_graph_s"])
    C.record("fig8_index_cost", component="memory_graph",
             seconds=t["memory_graph_s"],
             frac=t["memory_graph_s"] / total)
    C.record("fig8_index_cost", component="pq", seconds=t["pq_s"],
             frac=t["pq_s"] / total)
    nav = seg.view.nav
    C.record("fig8_memory", c_graph=nav.memory_bytes(),
             c_mapping=seg.view.layout.mapping_bytes(),
             c_pq=int(seg.view.pq_codes.nbytes
                      + seg.view.pq_cb.memory_bytes()),
             total=seg.memory_bytes(), disk=seg.disk_bytes())


# ------------------------------------------------------------ Fig. 10

def fig10_nav_graph_ablation():
    q = C.queries()
    truth = C.ground_truth()
    for nav in (True, False):
        seg = C.bench_segment(shuffle="bnf", use_nav=nav)
        ids, _, st = anns(seg.view, q, 10, seg.params.search)
        C.record("fig10_nav", nav_graph=nav,
                 recall=recall_at_k(ids, truth),
                 mean_io=C.mean_io(st), ell=C.mean_ell(st),
                 xi=C.mean_xi(st), **C.modeled(st))


# ------------------------------------------------------------ Fig. 11

def fig11_block_search_opts():
    """(a) pruning sweep, (b) pipeline model, (c) PQ routing I/O,
    (d) time breakdown."""
    seg = C.bench_segment(shuffle="bnf")
    q = C.queries()
    truth = C.ground_truth()
    for sigma in (0.0, 0.1, 0.3, 0.5, 1.0):
        p = dataclasses.replace(seg.params.search, pruning_ratio=sigma,
                                use_block_search=sigma > 0)
        ids, _, st = anns(seg.view, q, 10, p)
        C.record("fig11a_appK_sigma", sigma=sigma,
                 recall=recall_at_k(ids, truth), mean_io=C.mean_io(st),
                 dist_comps=float(np.mean([s.dist_comps for s in st])),
                 **C.modeled(st))
    _, _, st = anns(seg.view, q, 10, seg.params.search)
    for pipe in (False, True):
        m = C.modeled(st, pipeline=pipe)
        C.record("fig11b_pipeline", pipeline=pipe, **m)
    for pq in (True, False):
        p = dataclasses.replace(seg.params.search, use_pq_routing=pq)
        _, _, st2 = anns(seg.view, q[:8], 10, p)
        C.record("fig11c_pq_routing", pq_routing=pq,
                 mean_io=C.mean_io(st2))
    s = st[0]
    br = NVME_SEGMENT.breakdown(s)
    C.record("fig11d_breakdown", framework="starling-nvme-model",
             io_frac=br["io_frac"],
             t_io_us=br["t_io_us"], t_comp_us=br["t_comp_us"],
             t_other_us=br["t_other_us"])
    seg_b = C.bench_segment(shuffle="none")
    p_b = dataclasses.replace(seg.params.search, use_block_search=False,
                              use_nav_graph=False)
    _, _, st_b = B.vertex_anns(seg_b.view, q, 10, p_b)
    br_b = NVME_SEGMENT.breakdown(st_b[0])
    C.record("fig11d_breakdown", framework="diskann-nvme-model",
             io_frac=br_b["io_frac"], t_io_us=br_b["t_io_us"],
             t_comp_us=br_b["t_comp_us"], t_other_us=br_b["t_other_us"])


# ------------------------------------------------------------ Fig. 13

def fig13_k_sweep():
    seg = C.bench_segment(shuffle="bnf")
    x, q = C.base_data(), C.queries()
    for k in (1, 10, 50):
        truth = D.brute_force_knn(x, q, k)
        p = dataclasses.replace(seg.params.search,
                                candidate_size=max(64, 2 * k))
        ids, _, st = anns(seg.view, q, k, p)
        C.record("fig13_k", k=k, recall=recall_at_k(ids, truth),
                 mean_io=C.mean_io(st), **C.modeled(st))


# ------------------------------------------------------------- Tab. 3

def tab3_multi_segment():
    """QPS scaling with segment count on one machine (coordinator)."""
    from repro.core import device_search as DS
    from repro.serving import QueryCoordinator, SegmentServer
    from repro.configs.starling_segment import SEGMENT_BENCH
    from repro.core.segment import build_segment
    from repro.data.vectors import clustered_vectors, query_set

    all_servers = []
    xs = []
    off = 0
    for s in range(3):
        x = clustered_vectors(1500, C.DIM, num_clusters=16, seed=10 + s)
        seg = build_segment(x, SEGMENT_BENCH)
        from repro.serving.coordinator import SERVE_DEVICE_SEARCH
        all_servers.append(SegmentServer(
            segment=DS.from_segment(seg), offset=off,
            num_vectors=x.shape[0],
            params=dataclasses.replace(SERVE_DEVICE_SEARCH,
                                       candidates=48)))
        xs.append(x)
        off += x.shape[0]
    # jit warm-up so wall time reflects steady state, not compilation
    _ = all_servers[0].search(query_set(xs[0], 16, seed=3), 10)
    for num in (1, 2, 3):
        union = np.concatenate(xs[:num], axis=0)
        q = query_set(union, 16, seed=3)
        coord = QueryCoordinator(all_servers[:num])
        t0 = time.perf_counter()
        gi, gd, stats = coord.search(q, k=10)
        wall = time.perf_counter() - t0
        truth = D.brute_force_knn(union, q, 10)
        C.record("tab3_segments", segments=num,
                 recall=recall_at_k(gi, truth),
                 mean_io=stats["mean_block_reads_per_query"],
                 wall_s_cpu=wall)


# ----------------------------------------------- mesh-level QPS model

def mesh_qps_estimate():
    """Fold the per-rank io/hops/tier0/dedup columns of the production
    search step into a mesh-level QPS estimate (ROADMAP open item).

    ``make_search_step``'s layout: every ``model`` rank owns an
    independent sub-segment and sees the full (replicated) query batch;
    the per-segment top-k merge is one all-gather — a barrier, so a
    batch's step time is gated by the slowest rank. We run the batched
    search per rank (same counters the step's ``(data, model)``-sharded
    output columns carry) and price each rank with the *round-granular*
    cost model (PR 5, ROADMAP (d)): ``IOStats.from_device_batch`` folds
    the columns, then ``CostModel.latency_us`` charges the lockstep
    chain (``batch_rounds x t_round``), cold DMAs at the
    ``t_batch_block`` bandwidth rate, tier-0/dedup broadcast touches,
    and occupancy-weighted compute (``batch_rounds x
    rounds_active_weight x t_round_comp`` — a converged query's idle
    rounds are free). This is the SAME fold the serving
    ``RepackScheduler`` uses as its objective — and, since the mesh
    router landed, the SAME rank-keyed fold
    (``IOStats.fold_rank_batches`` + ``merge_ranks``) the
    ``MeshQueryRouter`` accounts a served step with, so the control
    loop, the router and the benchmark optimize one number
    (``benchmarks/mesh_bench.py`` pins modeled == served per rank).
    QPS = batch x data ranks / max_rank(step time); the step time is
    asserted monotone in ``rounds_active_weight`` in-bench (the
    acceptance invariant). Pricing uses the TPU-HBM preset with any
    calibrated ``results/CALIB_*.json`` constants applied
    (``obs.calibrate.load_calibrated``); all latencies stay modeled on
    this CPU container."""
    import dataclasses as dc

    import jax.numpy as jnp
    from repro.configs.starling_segment import DEVICE_SEARCH_BATCH
    from repro.core import device_search as DS
    from repro.core.iostats import IOStats
    from repro.core.segment import build_segment
    from repro.data.vectors import clustered_vectors, query_set
    from repro.obs.calibrate import load_calibrated

    cm = load_calibrated(TPU_HBM_SEGMENT)
    assert cm.t_round > 0 and cm.t_round_comp > 0, \
        "mesh QPS fold needs the round-granular terms"
    model_ranks, data_ranks, batch = 4, 16, 32
    xs = [clustered_vectors(1500, C.DIM, num_clusters=16, seed=20 + s)
          for s in range(model_ranks)]
    q = query_set(np.concatenate(xs), batch, seed=9)
    p = DEVICE_SEARCH_BATCH
    pipelined = p.pipeline_dma and p.fetch_impl == "fused"
    rank_cols = {}
    for s, x in enumerate(xs):
        seg = build_segment(x, C.SEGMENT_BENCH)
        ds = DS.from_segment(seg, tier0_frac=0.1)
        r = DS.device_anns(ds, jnp.asarray(q), p)
        # the FULL fold tuple — dedup_cross, the DMA-overlap flag and
        # the speculation columns travel with the classic five, so this
        # estimate prices exactly what the router fold prices (zeros
        # when the preset does not speculate)
        rank_cols[s] = (np.asarray(r.io), np.asarray(r.tier0_hits),
                        np.asarray(r.hops), np.asarray(r.dedup_saved),
                        int(r.rounds), np.asarray(r.dedup_cross),
                        pipelined, np.asarray(r.spec_hits),
                        np.asarray(r.spec_wasted), p.speculate)
    per_rank = IOStats.fold_rank_batches(rank_cols)
    step_us = []
    for s in range(model_ranks):
        agg = per_rank[s]
        io, t0, hops, sv, rounds = rank_cols[s][:5]
        t_rank = cm.latency_us(agg)
        # acceptance invariant: the round-granular step time is strictly
        # monotone in the occupancy (rounds_active_weight) — a batch
        # whose queries stay live longer must model slower
        denser = dc.replace(agg, rounds_active_weight=
                            agg.rounds_active_weight * 1.5)
        assert cm.latency_us(denser) > t_rank, \
            "step time must rise with rounds_active_weight"
        step_us.append(t_rank)
        br = cm.breakdown(agg, pipeline=True)
        C.record("mesh_qps_rank", rank=s, rounds=rounds,
                 step_us_modeled=t_rank,
                 occupancy=float(hops.mean() / max(rounds, 1)),
                 rounds_active_weight=agg.rounds_active_weight,
                 dma_per_query=float((io - sv).mean()),
                 dedup_saved_per_query=float(sv.mean()),
                 tier0_hits_per_query=float(t0.mean()),
                 t_round_chain_us=br["t_round_chain_us"],
                 t_round_comp_us=br["t_round_comp_us"],
                 t_io_us=br["t_io_us"], t_other_us=br["t_other_us"])
    # the mesh total is DEFINED as the merge of the per-rank folds
    # (rounds_active_weight is not additive across ranks) — the same
    # identity the router's accounting tests pin
    total = IOStats.merge_ranks(per_rank)
    assert total.block_reads == sum(per_rank[s].block_reads
                                    for s in per_rank)
    worst = max(step_us)
    qps = batch * data_ranks / (worst * 1e-6)
    C.record("mesh_qps", mesh=f"model{model_ranks}xdata{data_ranks}",
             batch=batch, slowest_rank_step_us=worst,
             rank_skew=worst / max(min(step_us), 1e-9),
             qps_modeled=qps)
    C.perf_artifact(
        "mesh_qps", [
            {"name": "qps_modeled", "value": qps, "units": "qps"},
            {"name": "slowest_rank_step_us", "value": worst,
             "units": "us"},
            {"name": "rank_skew",
             "value": worst / max(min(step_us), 1e-9),
             "units": "ratio"}],
        config={"model_ranks": model_ranks, "data_ranks": data_ranks,
                "batch": batch, "cost_model": cm.name},
        measured=False)


# ------------------------------------------------------------ Fig. 15

def fig15_segment_size():
    q = C.queries()
    for n in (2000, 4000, 6000):
        seg = C.bench_segment(shuffle="bnf", n=n)
        x = C.base_data(n)
        truth = D.brute_force_knn(x, C.queries(), 10)
        ids, _, st = anns(seg.view, q, 10, seg.params.search)
        C.record("fig15_segment_size", n=n,
                 recall=recall_at_k(ids, truth),
                 mean_io=C.mean_io(st), **C.modeled(st))


# ------------------------------------------------------------ Fig. 16

def fig16_graph_algos():
    """Starling generality: vamana / nsg / hnsw disk graphs."""
    q = C.queries()
    truth = C.ground_truth()
    for algo in ("vamana", "nsg", "hnsw"):
        for shuffle in ("bnf", "none"):
            seg = C.bench_segment(shuffle=shuffle, algo=algo)
            ids, _, st = anns(seg.view, q, 10, seg.params.search)
            C.record("fig16_graph_algos", algo=algo, shuffle=shuffle,
                     recall=recall_at_k(ids, truth),
                     mean_io=C.mean_io(st), **C.modeled(st))


# ------------------------------------------------------------- Fig. 17

def fig17_in_database_queries():
    seg = C.bench_segment(shuffle="bnf")
    x = C.base_data()
    for in_db in (False, True):
        q = C.queries(in_db=in_db)
        truth = D.brute_force_knn(x, q, 10)
        ids, _, st = anns(seg.view, q, 10, seg.params.search)
        C.record("fig17_query_dist", in_database=in_db,
                 recall=recall_at_k(ids, truth),
                 mean_io=C.mean_io(st), **C.modeled(st))


# ----------------------------------------------------------- App. C/F

def appC_bnf_params():
    seg = C.bench_segment(shuffle="none")        # need raw graph
    g = seg.graph
    eps = seg.view.layout.verts_per_block
    for beta in (1, 2, 4, 8):
        with C.Timer() as t:
            lay, hist = L.layout_bnf(g, eps, iters=beta, tau=0.0)
        C.record("appC_bnf_beta", beta=beta,
                 overlap_ratio=L.overlap_ratio(g, lay),
                 seconds=t.seconds, rounds_run=len(hist) - 1)


def appF_bnf_vs_bns():
    import dataclasses as dc
    x = C.base_data(1200)
    from repro.core.segment import build_segment
    p = dc.replace(C.SEGMENT_BENCH,
                   layout=dc.replace(C.SEGMENT_BENCH.layout,
                                     shuffle="none"))
    seg = build_segment(x, p)
    g = seg.graph
    eps = seg.view.layout.verts_per_block
    with C.Timer() as t_bnf:
        lay_bnf, _ = L.layout_bnf(g, eps, iters=8)
    with C.Timer() as t_bns:
        lay_bns, hist = L.layout_bns(g, eps, iters=1,
                                     init=lay_bnf)
    C.record("appF_bnf_vs_bns", algo="bnf",
             overlap_ratio=L.overlap_ratio(g, lay_bnf),
             seconds=t_bnf.seconds)
    C.record("appF_bnf_vs_bns", algo="bns(+bnf init)",
             overlap_ratio=L.overlap_ratio(g, lay_bns),
             seconds=t_bns.seconds)


def appG_partitioners():
    x = C.base_data()
    seg = C.bench_segment(shuffle="none")
    g = seg.graph
    eps = seg.view.layout.verts_per_block
    for name, fn in (
            ("bnf", lambda: L.layout_bnf(g, eps, iters=8)[0]),
            ("gp3_gain_order", lambda: L.layout_bnf(
                g, eps, iters=8, gain_order=True)[0]),
            ("kmeans_gp1", lambda: L.layout_kmeans(x, g, eps))):
        with C.Timer() as t:
            lay = fn()
        C.record("appG_partitioners", method=name,
                 overlap_ratio=L.overlap_ratio(g, lay),
                 seconds=t.seconds)
