"""Mesh router bench (ISSUE 7): modeled vs served step time per rank.

Routes query batches through a ``MeshQueryRouter`` over 4 sharded
segments on a forced multi-device host mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the ``make
bench-mesh`` lane) and emits ``results/BENCH_mesh_router.json``:

  * per-rank ``modeled_step_us`` — the calibrated ``CostModel`` priced
    from THE shared per-rank ``IOStats`` fold
    (``IOStats.fold_rank_batches``, the same fold
    ``mesh_qps_estimate`` and the ``RepackScheduler`` consume), plus
    the slowest-rank gate the mesh step is paced by;
  * ``served_step_us`` — wall-clock per routed batch on this host
    (``measured: true`` rows; a CPU host mesh, so the absolute value
    is NOT comparable to the modeled TPU figures — the artifact's
    per-row ``measured`` flags keep the two regimes apart);
  * the routed-vs-single-target bit-identity and fold-exactness
    checks, asserted before anything is written — the artifact never
    ships numbers from a step whose results or accounting are wrong.

Skips gracefully (writes nothing, returns) on worlds smaller than 8
devices or without a usable jax backend.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from benchmarks.common import perf_artifact, record

SMOKE = bool(os.environ.get("BENCH_SMOKE"))
N_SEG = 4
N_PER_SEG = 400 if SMOKE else 1500
N_QUERY = 16 if SMOKE else 64
N_BATCH = 3 if SMOKE else 8
DIM = 32


def mesh_router_bench() -> None:
    try:
        import jax
        world = jax.device_count()
    except Exception:
        print("[mesh_router] no jax backend; skipping", flush=True)
        return
    if world < 8:
        print(f"[mesh_router] {world} devices < 8 — run under "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "(make bench-mesh); skipping", flush=True)
        return

    from repro.core import device_search as DS
    from repro.core.iostats import IOStats, TPU_HBM_SEGMENT
    from repro.core.segment import build_segment
    from repro.core.params import (GraphParams, LayoutParams,
                                   NavGraphParams, PQParams,
                                   RouterParams, SegmentParams)
    from repro.data.vectors import clustered_vectors, query_set
    from repro.obs.calibrate import load_calibrated
    from repro.serving import MeshQueryRouter, SegmentServer
    from repro.serving.coordinator import SERVE_DEVICE_SEARCH, merge_topk

    seg_params = SegmentParams(
        graph=GraphParams(max_degree=16, build_beam=48),
        layout=LayoutParams(block_kb=1.0, shuffle="bnf", bnf_iters=4),
        pq=PQParams(num_subspaces=8, train_iters=6, train_sample=2048),
        nav=NavGraphParams(sample_ratio=0.1, max_degree=8,
                           build_beam=24))
    sp = dataclasses.replace(SERVE_DEVICE_SEARCH, candidates=48,
                             fetch_impl="jnp")
    servers, xs, off = [], [], 0
    for s in range(N_SEG):
        x = clustered_vectors(N_PER_SEG, DIM, num_clusters=12,
                              seed=40 + s)
        seg = build_segment(x, seg_params)
        servers.append(SegmentServer(
            segment=DS.from_segment(seg, tier0_frac=0.1),
            offset=off, num_vectors=x.shape[0], params=sp, host=seg))
        xs.append(x)
        off += x.shape[0]
    q = query_set(np.concatenate(xs), N_QUERY, seed=9)

    cm = load_calibrated(TPU_HBM_SEGMENT)
    router = MeshQueryRouter(servers, params=RouterParams(),
                             cost_model=cm)

    # correctness gate: routed+merged == concatenated single-target
    ri, rd, stats = router.route(q, k=10)
    ids, dd, offs = [], [], []
    for s in servers:
        i, d, _ = s.search(q, 10)
        ids.append(i)
        dd.append(d)
        offs.append(s.offset)
    gi, gd = merge_topk(ids, dd, offs, 10)
    assert np.array_equal(ri, gi) and np.array_equal(rd, gd), \
        "routed result diverged from the single-target path"
    assert IOStats.merge_ranks(stats["per_rank"]) == stats["total"], \
        "per-rank fold does not merge to the router total"

    served_us = np.zeros((N_BATCH, 1))
    modeled = np.zeros((N_BATCH, router.world))
    for b in range(N_BATCH):
        t0 = time.perf_counter()
        _, _, st = router.route(q, k=10)
        served_us[b] = (time.perf_counter() - t0) * 1e6
        modeled[b] = [st["per_rank_modeled_us"][r]
                      for r in range(router.world)]

    metrics = []
    for r in range(router.world):
        metrics.append({"name": f"rank{r}_modeled_step_us",
                        "value": float(modeled[:, r].mean()),
                        "units": "us", "measured": False})
    metrics += [
        {"name": "modeled_step_us_slowest_rank",
         "value": float(modeled.max(axis=1).mean()), "units": "us",
         "measured": False},
        {"name": "served_step_us",
         "value": float(served_us.mean()), "units": "us",
         "measured": True},
        {"name": "modeled_qps",
         "value": float(N_QUERY / (modeled.max(axis=1).mean() / 1e6)),
         "units": "qps", "measured": False},
        {"name": "served_qps",
         "value": float(N_QUERY / (served_us.mean() / 1e6)),
         "units": "qps", "measured": True},
        {"name": "total_block_reads",
         "value": float(stats["total_block_reads"]), "units": "blocks",
         "measured": False},
        {"name": "rebalances", "value": float(router.rebalances),
         "units": "count", "measured": False},
    ]
    record("mesh_router", ranks=router.world, segments=N_SEG,
           n_query=N_QUERY,
           modeled_step_us=float(modeled.max(axis=1).mean()),
           served_step_us=float(served_us.mean()),
           modeled_qps=float(N_QUERY / (modeled.max(axis=1).mean()
                                        / 1e6)))
    perf_artifact(
        "mesh_router", metrics,
        config={"ranks": router.world, "segments": N_SEG,
                "n_per_seg": N_PER_SEG, "n_query": N_QUERY,
                "n_batch": N_BATCH, "k": 10, "dim": DIM,
                "cost_model": cm.name, "smoke": SMOKE})


if __name__ == "__main__":
    mesh_router_bench()
