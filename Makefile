# Tier-1 verification and common dev entry points.

PY ?= python

.PHONY: test test-fast test-device test-e2e test-obs test-mesh \
	test-hybrid bench bench-io bench-device bench-batch bench-obs \
	bench-mesh bench-hybrid dev-deps

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# fast lane: skips the build-heavy tests marked @pytest.mark.slow
# (full-size segment builds, jit compiles); the full suite still runs
# via `make test` and the scheduled CI lane
test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow"

# interpret-mode device lane: the Pallas kernels + the non-compiling
# device-search helpers (the CI device lane runs exactly this)
test-device:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow" \
		tests/test_kernels.py tests/test_device_search.py

# the end-to-end conformance suite (ISSUE 5): one segment, every search
# path (host, device fused/jnp, served/batched) against the brute-force
# oracle, cross-path bit-identity, golden IOStats totals. Runs the
# Pallas kernels in interpret mode (the CPU default); includes the
# build-heavy slow cases — its own CI lane
test-e2e:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_e2e_conformance.py

# the hybrid hot/cold tier (ISSUE 10): the hotset-bugfix regressions,
# hot-tier/delta-segment units, seed-override bit-identity and the
# scheduler layout-swap invalidation, plus the hybrid slice of the e2e
# conformance suite (recall + strict cold-I/O cut, tombstone masking,
# compaction bit-identity)
test-hybrid:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_hybrid.py \
		tests/test_e2e_conformance.py -k "hybrid or delta"

# the hybrid budget sweep: memory-vs-disk modeled latency split at
# fixed recall, with the strict cold-I/O-cut acceptance asserted
# in-sweep; the fresh BENCH_hybrid_hot_tier.json is gated against the
# committed baseline
bench-hybrid:
	BENCH_SMOKE=1 PYTHONPATH=src $(PY) -m benchmarks.run \
		--only hybrid_hot_tier_sweep
	PYTHONPATH=src $(PY) -m benchmarks.check_regression \
		--artifact hybrid_hot_tier

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

bench-io:
	PYTHONPATH=src $(PY) -m benchmarks.run --only io_cache_hit_rate_sweep
	PYTHONPATH=src $(PY) -m benchmarks.run --only io_prefetch_width_sweep
	PYTHONPATH=src $(PY) -m benchmarks.run --only io_queue_depth_sweep
	PYTHONPATH=src $(PY) -m benchmarks.run --only io_tier2_budget_sweep

# the device sweeps: tier-0 VMEM budget (modeled DMA cut at matched
# recall), fetch width, RS round restarts, kernel micro, roofline render
bench-device:
	PYTHONPATH=src $(PY) -m benchmarks.run --only device_vs_host
	PYTHONPATH=src $(PY) -m benchmarks.run --only device_tier0_budget_sweep
	PYTHONPATH=src $(PY) -m benchmarks.run --only starling_fetch_width
	PYTHONPATH=src $(PY) -m benchmarks.run --only device_range_search_rounds
	PYTHONPATH=src $(PY) -m benchmarks.run --only kernel_micro
	PYTHONPATH=src $(PY) -m benchmarks.run --only roofline_tables

# smoke lane for the divergence-aware batched path (ISSUE 4), the
# adaptive repack control loop (ISSUE 5) and the cross-round
# speculative pipeline (ISSUE 9): tiny sweeps with the bit-identity /
# strict-DMA-cut / strict-latency-win assertions on (BENCH_SMOKE
# shrinks them; all skip gracefully with no jax backend). The fresh
# BENCH_*.json artifacts are then gated against the committed
# baselines (benchmarks/check_regression.py ARTIFACT_GATES,
# direction-aware: >10% regression of any gated metric fails the lane)
bench-batch:
	BENCH_SMOKE=1 PYTHONPATH=src $(PY) -m benchmarks.run \
		--only device_batch_dedup_sweep
	BENCH_SMOKE=1 PYTHONPATH=src $(PY) -m benchmarks.run \
		--only device_drift_repack_sweep
	BENCH_SMOKE=1 PYTHONPATH=src $(PY) -m benchmarks.run \
		--only device_speculate_sweep
	PYTHONPATH=src $(PY) -m benchmarks.check_regression

# the observability plane (repro.obs): trace/metrics/export/roundlog/
# calibration unit + property tests, then the Perfetto-exporting trace
# smoke and the cost-calibration harness (BENCH_* perf artifacts +
# results/trace_smoke.json + CALIB_*.json presets land in results/)
test-obs:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow" \
		tests/test_obs.py tests/test_trace_roundlog.py

bench-obs:
	BENCH_SMOKE=1 PYTHONPATH=src $(PY) -m benchmarks.run \
		--only obs_trace_smoke
	BENCH_SMOKE=1 PYTHONPATH=src $(PY) -m benchmarks.run \
		--only cost_calibration

# the mesh-serving plane (ISSUE 7): shard_map fan-out router over a
# forced 8-device host mesh — XLA_FLAGS must be set before jax
# initializes, hence the dedicated lane. Asserts routed-vs-single-
# target bit-identity, per-rank IOStats fold exactness, and the
# rebalance fire/quiet behaviour; skips (rather than fails) on worlds
# smaller than 8 devices
test-mesh:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		PYTHONPATH=src $(PY) -m pytest -x -q tests/test_router.py

# modeled-vs-served per-rank step time on the same forced mesh
# (results/BENCH_mesh_router.json, uploaded by the CI mesh lane), then
# the slowest-rank step-time gate against the committed baseline
bench-mesh:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		BENCH_SMOKE=1 PYTHONPATH=src $(PY) -m benchmarks.run \
		--only mesh_router_bench
	PYTHONPATH=src $(PY) -m benchmarks.check_regression \
		--artifact mesh_router

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt
