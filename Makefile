# Tier-1 verification and common dev entry points.

PY ?= python

.PHONY: test bench bench-io dev-deps

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

bench-io:
	PYTHONPATH=src $(PY) -m benchmarks.run --only io_cache_hit_rate_sweep
	PYTHONPATH=src $(PY) -m benchmarks.run --only io_prefetch_width_sweep

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt
