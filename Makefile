# Tier-1 verification and common dev entry points.

PY ?= python

.PHONY: test test-fast bench bench-io dev-deps

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# fast lane: skips the build-heavy tests marked @pytest.mark.slow
# (full-size segment builds, jit compiles); the full suite still runs
# via `make test` and the scheduled CI lane
test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow"

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run

bench-io:
	PYTHONPATH=src $(PY) -m benchmarks.run --only io_cache_hit_rate_sweep
	PYTHONPATH=src $(PY) -m benchmarks.run --only io_prefetch_width_sweep
	PYTHONPATH=src $(PY) -m benchmarks.run --only io_queue_depth_sweep
	PYTHONPATH=src $(PY) -m benchmarks.run --only io_tier2_budget_sweep

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt
