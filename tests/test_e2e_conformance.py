"""End-to-end conformance suite (ISSUE 5): every search path, one
segment, one set of queries, locked to a brute-force oracle and to each
other.

The segment is the session-scoped ``small_segment`` (built ONCE per
pytest session, shared with the rest of the suite); the served host
path wraps the same view cache-fronted (a cheap wrap, not a rebuild).
What is pinned down:

  * recall@10 against the brute-force oracle for the host oracle, the
    device search (fused AND jnp fetch stages), and the served/batched
    plane — the algorithms must stay *good*, not just self-consistent;
  * exact cross-path ``(ids, dists)`` bit-identity within the device
    family: fused == jnp == served batch == batcher-padded batch ==
    singleton loop. (The host oracle is a different algorithm — it gets
    the recall bound, not bit-identity — but host cached == host
    uncached IS asserted: tiers never change results.)
  * golden ``IOStats`` counter totals under the fixed session seed —
    the accounting spine is part of the contract; a change that moves
    these totals is a behavior change, not noise, and must be a
    conscious golden update.

Build-heavy cases are ``pytest.mark.slow`` per repo convention; `make
test-e2e` (and the CI e2e lane) runs the whole file.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import device_search as DS
from repro.core import distances as D
from repro.core.iostats import IOStats
from repro.core.params import CacheParams, DeviceSearchParams
from repro.core.search import anns, recall_at_k
from repro.io.cached_store import CachedBlockStore, cached_view
from repro.serving import RequestBatcher, SegmentServer

# the conformance knobs: the batched serving shape (wide fetch +
# compaction) at a beam the small segment resolves well
P_CONF = DeviceSearchParams(k=10, candidates=48, max_hops=64,
                            fetch_width=2, compact_frac=0.25)
P_SINGLE = dataclasses.replace(P_CONF, compact_frac=0.0)


@pytest.fixture(scope="module")
def oracle(small_data):
    x, q = small_data
    return D.brute_force_knn(x, q, 10)


@pytest.fixture(scope="module")
def device_seg(small_segment):
    return DS.from_segment(small_segment, tier0_frac=0.1)


@pytest.fixture(scope="module")
def cached_host_view(small_segment):
    """The served host path: the same view, cache-fronted (fresh store,
    so lifetime counters start at zero for the golden totals)."""
    return cached_view(
        small_segment.view, small_segment.graph,
        CacheParams(budget_frac=0.10, pin_fraction=0.25,
                    prefetch_width=4))


# ------------------------------------------------------------- recall

@pytest.mark.slow
def test_all_paths_clear_the_oracle(small_segment, small_data, oracle,
                                    device_seg, cached_host_view):
    x, q = small_data
    paths = {}
    ids, _, _ = anns(small_segment.view, q, 10,
                     small_segment.params.search)
    paths["host"] = ids
    ids, _, _ = anns(cached_host_view, q, 10,
                     small_segment.params.search)
    paths["host_cached"] = ids
    paths["device_fused"] = np.asarray(
        DS.device_anns(device_seg, jnp.asarray(q), P_CONF).ids)
    paths["device_jnp"] = np.asarray(DS.device_anns(
        device_seg, jnp.asarray(q),
        dataclasses.replace(P_CONF, fetch_impl="jnp")).ids)
    srv = SegmentServer(segment=device_seg, offset=0,
                        num_vectors=x.shape[0], params=P_CONF)
    paths["served"], _, _ = srv.search(q, 10)
    for name, got in paths.items():
        r = recall_at_k(got, oracle)
        assert r >= 0.8, f"{name} recall {r:.3f} below conformance floor"


# ------------------------------------------------- cross-path identity

@pytest.mark.slow
def test_device_family_bit_identity(small_segment, small_data,
                                    device_seg):
    """fused == jnp == served == padded == singleton loop, to the bit."""
    x, q = small_data
    rf = DS.device_anns(device_seg, jnp.asarray(q), P_CONF)
    rj = DS.device_anns(device_seg, jnp.asarray(q),
                        dataclasses.replace(P_CONF, fetch_impl="jnp"))
    r2p = DS.device_anns(device_seg, jnp.asarray(q),
                         dataclasses.replace(P_CONF, fuse_union=False))
    rsp = DS.device_anns(device_seg, jnp.asarray(q),
                         dataclasses.replace(P_CONF, speculate=True))
    srv = SegmentServer(segment=device_seg, offset=0,
                        num_vectors=x.shape[0], params=P_CONF)
    si, sd, _ = srv.search(q, 10)
    for name, (ids, dd) in {
            "jnp": (np.asarray(rj.ids), np.asarray(rj.dists)),
            "two-pass-union": (np.asarray(r2p.ids),
                               np.asarray(r2p.dists)),
            "speculate": (np.asarray(rsp.ids), np.asarray(rsp.dists)),
            "served": (si, sd)}.items():
        np.testing.assert_array_equal(np.asarray(rf.ids), ids,
                                      err_msg=f"ids: fused vs {name}")
        np.testing.assert_array_equal(np.asarray(rf.dists), dd,
                                      err_msg=f"dists: fused vs {name}")
    # batcher-padded ragged batch: rows must match the full-batch rows
    n = 5
    b = RequestBatcher(dim=q.shape[1], buckets=(8, 32))
    for row in q[:n]:
        b.submit(row)
    padded, _, valid = b.next_batch()
    assert valid == n and b.batches_emitted == 1
    pi, pd, _ = srv.search(padded, 10)
    np.testing.assert_array_equal(pi[:n], np.asarray(rf.ids)[:n])
    np.testing.assert_array_equal(pd[:n], np.asarray(rf.dists)[:n])
    # singleton loop: per-query state is row-independent
    for qi in (0, 7, 16, 23):
        r1 = DS.device_anns(device_seg, jnp.asarray(q[qi: qi + 1]),
                            P_SINGLE)
        np.testing.assert_array_equal(np.asarray(r1.ids[0]),
                                      np.asarray(rf.ids[qi]))
        np.testing.assert_array_equal(np.asarray(r1.dists[0]),
                                      np.asarray(rf.dists[qi]))


@pytest.mark.slow
def test_host_cached_equals_uncached(small_segment, small_data,
                                     cached_host_view):
    """Tiers change what a touch costs, never what the search returns."""
    _, q = small_data
    i0, d0, _ = anns(small_segment.view, q, 10,
                     small_segment.params.search)
    i1, d1, _ = anns(cached_host_view, q, 10,
                     small_segment.params.search)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(d0, d1)


# ------------------------------------------------- hybrid hot/cold tier

@pytest.fixture(scope="module")
def delta_seg(small_segment):
    from repro.core import delta as DL
    from repro.core.params import HotTierParams
    return DL.DeltaSegment.wrap(small_segment,
                                HotTierParams(budget_frac=0.10))


@pytest.mark.slow
def test_hybrid_recall_and_cold_io_reduction(small_segment, small_data,
                                             oracle, delta_seg):
    """The tentpole contract (DESIGN.md §10): hot-first routing at a
    10% hot-set budget clears the oracle floor, stays within ±0.01
    recall of the pure block search, and STRICTLY reduces the cold I/O
    per query — the hot tier absorbs the early exploration, so the
    seeded, Γ-narrowed block search touches fewer blocks for the same
    answer quality. The memory work is visible (and nonzero) in the
    ``hot_tier_hits`` column, never in ``block_reads``."""
    _, q = small_data
    p = small_segment.params.search
    ids_p, _, st_p = anns(small_segment.view, q, 10, p)
    ids_h, _, st_h = delta_seg.search(q, 10, p)
    rec_p = recall_at_k(ids_p, oracle)
    rec_h = recall_at_k(ids_h, oracle)
    assert rec_h >= 0.8, f"hybrid recall {rec_h:.3f} below floor"
    assert rec_h >= rec_p - 0.01, \
        f"hybrid recall {rec_h:.3f} not within 0.01 of pure {rec_p:.3f}"
    io_p = sum(s.block_reads for s in st_p)
    io_h = sum(s.block_reads for s in st_h)
    assert io_h < io_p, \
        f"hybrid cold I/O {io_h} not strictly below pure {io_p}"
    assert sum(s.hot_tier_hits for s in st_h) > 0
    assert all(s.hot_tier_hits == 0 for s in st_p)


@pytest.mark.slow
def test_hybrid_tombstones_never_surface(small_segment, small_data,
                                         oracle, delta_seg):
    """Deleted ids are masked in BOTH tiers: delete every query's
    current best answer and none of them may reappear, while recall on
    the surviving ground truth holds."""
    _, q = small_data
    p = small_segment.params.search
    victims = sorted(set(int(v) for v in oracle[:, 0]))
    for v in victims:
        assert delta_seg.delete(v)
    try:
        ids, _, _ = delta_seg.search(q, 10, p)
        assert not np.isin(ids, victims).any(), \
            "tombstoned ids surfaced in hybrid results"
        # surviving ground truth still found: compare against the
        # oracle minus the victims
        surviving = np.array([[v for v in row if v not in set(victims)]
                              [:5] for row in oracle])
        rec = recall_at_k(ids[:, :5], surviving[:, :5])
        assert rec >= 0.7, f"post-delete recall collapsed: {rec:.3f}"
    finally:
        # un-tombstone: the module-scoped delta is shared with the
        # recall test above (order-independent either way — deletes
        # only mask, never mutate the base segment)
        delta_seg.tomb[victims] = False
        delta_seg.hot.dead[[delta_seg.hot._local_of[v]
                            for v in victims
                            if v in delta_seg.hot._local_of]] = False


@pytest.mark.slow
def test_hybrid_compact_round_trip_bit_identity(small_segment,
                                                small_data):
    """insert → delete → compact → search ≡ fresh build of the same
    live vectors, to the bit — compaction goes through the full
    offline pipeline (graph, ``core/layout`` reorder, nav, PQ), so
    there is no incremental state to drift."""
    from repro.core import delta as DL
    from repro.core.params import HotTierParams
    from repro.core.segment import build_segment
    x, q = small_data
    d = DL.DeltaSegment.wrap(small_segment,
                             HotTierParams(budget_frac=0.10))
    rng = np.random.default_rng(13)
    new = rng.standard_normal((8, x.shape[1])).astype(np.float32)
    gids = d.insert(new)
    dead_base = [3, 77, 1200, 2400]
    for g in dead_base + [int(gids[5])]:
        assert d.delete(g)
    compacted, live_gids = d.compact()
    keep = np.ones(x.shape[0], bool)
    keep[dead_base] = False
    x_live = np.concatenate(
        [x[keep], np.delete(new, 5, axis=0)], axis=0).astype(np.float32)
    assert compacted.num_vectors == x_live.shape[0] == live_gids.shape[0]
    fresh = build_segment(x_live, small_segment.params)
    ic, dc, _ = anns(compacted.view, q, 10, small_segment.params.search)
    iff, df, _ = anns(fresh.view, q, 10, small_segment.params.search)
    np.testing.assert_array_equal(ic, iff)
    np.testing.assert_array_equal(dc, df)


# -------------------------------------------------------- golden totals

@pytest.mark.slow
def test_golden_host_iostats_totals(small_segment, small_data):
    """The host oracle's accounting spine under the fixed session seed.

    These totals ARE the contract: block_reads is the paper's mean-I/O
    numerator, hops the path-length total, dist/pq comps the DC side.
    If an intentional algorithm change moves them, update the goldens
    in the same commit and say why."""
    _, q = small_data
    _, _, stats = anns(small_segment.view, q, 10,
                       small_segment.params.search)
    agg = IOStats()
    for s in stats:
        agg.merge(s)
    golden = GOLDEN_HOST
    got = {k: getattr(agg, k) for k in golden}
    assert got == golden, f"host IOStats drifted: {got} != {golden}"


@pytest.mark.slow
def test_golden_cached_host_iostats_totals(small_segment, small_data):
    """The cache-fronted host path: same spine plus the tier counters,
    and the structural invariants the cost model prices by. A FRESH
    store (not the module fixture — earlier tests warm that cache, and
    golden totals are only meaningful from cold)."""
    _, q = small_data
    view = cached_view(
        small_segment.view, small_segment.graph,
        CacheParams(budget_frac=0.10, pin_fraction=0.25,
                    prefetch_width=4))
    _, _, stats = anns(view, q, 10, small_segment.params.search)
    agg = IOStats()
    for s in stats:
        agg.merge(s)
    assert isinstance(view.store, CachedBlockStore)
    assert agg.io_round_trips <= agg.block_reads
    assert (agg.cache_hits + agg.tier2_hits + agg.cache_misses
            == agg.block_reads)
    golden = GOLDEN_HOST_CACHED
    got = {k: getattr(agg, k) for k in golden}
    assert got == golden, f"cached IOStats drifted: {got} != {golden}"


@pytest.mark.slow
def test_golden_device_counter_totals(small_data, device_seg):
    """Device-side totals: io + tier0_hits (block touches) is invariant
    across pack budgets, so the touch total, the hop total and the
    round count are pinned; the io/tier0 split is pinned for THIS
    (tier0_frac=0.1) pack."""
    _, q = small_data
    r = DS.device_anns(device_seg, jnp.asarray(q), P_CONF)
    got = {"touches": int((np.asarray(r.io)
                           + np.asarray(r.tier0_hits)).sum()),
           "io": int(np.asarray(r.io).sum()),
           "tier0_hits": int(np.asarray(r.tier0_hits).sum()),
           "dedup_saved": int(np.asarray(r.dedup_saved).sum()),
           "hops": int(np.asarray(r.hops).sum()),
           "rounds": int(r.rounds)}
    assert got == GOLDEN_DEVICE, \
        f"device counters drifted: {got} != {GOLDEN_DEVICE}"
    # and the merged IOStats fold agrees with the raw columns
    agg = IOStats.from_device_batch(
        np.asarray(r.io), np.asarray(r.tier0_hits), np.asarray(r.hops),
        np.asarray(r.dedup_saved), int(r.rounds))
    assert agg.block_reads == got["touches"]
    assert agg.batch_rounds == got["rounds"]
    assert agg.io_round_trips == got["io"] - got["dedup_saved"]


# Golden counter totals under the session seed (clustered_vectors
# seed=0, query_set seed=1, SMALL_SEGMENT build). Regenerate by running
# the paths above and reading the totals — intentionally hard-coded.
GOLDEN_HOST = {
    "block_reads": 1210,
    "io_round_trips": 0,       # uncached seed path issues no batched trips
    "hops": 1210,              # block search: one expansion per read
    "dist_comps": 6050,
    "pq_comps": 26849,
}
GOLDEN_HOST_CACHED = {
    "block_reads": 1210,       # identical demand stream to the uncached run
    "io_round_trips": 666,
    "cache_hits": 801,
    "cache_misses": 409,
    "prefetched_blocks": 1165,
}
GOLDEN_DEVICE = {
    "touches": 912,            # io + tier0_hits: invariant in the pack budget
    "io": 817,
    "tier0_hits": 95,
    "dedup_saved": 74,
    "hops": 464,
    "rounds": 23,
}
