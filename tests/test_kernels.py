"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import block_rank, pairwise_l2, pq_adc_batch, tier0_rank
from repro.kernels import ref


@pytest.mark.parametrize("q,n,d", [(8, 64, 16), (37, 203, 64),
                                   (128, 512, 128), (1, 9, 8)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_l2_tile_sweep(q, n, d, dtype, metric):
    rng = np.random.default_rng(q * n)
    qa = jnp.asarray(rng.standard_normal((q, d)), dtype)
    xa = jnp.asarray(rng.standard_normal((n, d)), dtype)
    got = pairwise_l2(qa, xa, metric=metric)
    want = ref.pairwise_l2_ref(qa, xa, metric=metric)
    tol = 1e-3 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * d)


@pytest.mark.parametrize("n,m,k,b", [(64, 4, 16, 1), (133, 8, 256, 5),
                                     (256, 16, 256, 3), (17, 2, 64, 2)])
def test_pq_adc_sweep(n, m, k, b):
    rng = np.random.default_rng(n * m)
    codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.uint8)
    luts = jnp.asarray(rng.standard_normal((b, m, k)), jnp.float32)
    got = pq_adc_batch(codes, luts)
    want = ref.pq_adc_ref(luts, codes)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("q,eps,d,top", [(19, 8, 32, 3), (64, 16, 128, 5),
                                         (5, 4, 16, 4), (128, 12, 64, 1)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_block_rank_sweep(q, eps, d, top, metric):
    rng = np.random.default_rng(q * eps)
    qs = jnp.asarray(rng.standard_normal((q, d)), jnp.float32)
    tiles = jnp.asarray(rng.standard_normal((q, eps, d)), jnp.float32)
    dd, idx = block_rank(qs, tiles, top, metric=metric)
    dr, idxr = ref.block_rank_ref(qs, tiles, top, metric=metric)
    np.testing.assert_allclose(dd, dr, rtol=1e-3, atol=1e-3)
    # indices must agree where distances are distinct
    got_d = np.take_along_axis(np.asarray(dd), np.asarray(idx), axis=1)
    want_d = np.take_along_axis(np.asarray(dr), np.asarray(idxr), axis=1)
    np.testing.assert_allclose(got_d, want_d, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("q,rho,eps,d,f,hot_n",
                         [(16, 32, 4, 16, 1, 8), (37, 64, 8, 32, 2, 0),
                          (8, 16, 6, 24, 3, 16), (128, 96, 5, 64, 2, 40)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_tier0_fetch_rank_sweep(q, rho, eps, d, f, hot_n, metric):
    """Fused probe+gather+rank vs the jnp oracle, including hot_n=0
    (sentinel pack, map all cold) and hot_n=rho (all hot)."""
    rng = np.random.default_rng(q * rho)
    qs = jnp.asarray(rng.standard_normal((q, d)), jnp.float32)
    cold = jnp.asarray(rng.standard_normal((rho, eps, d)), jnp.float32)
    slot_of = np.full(rho, -1, np.int32)
    if hot_n > 0:
        hot_ids = rng.permutation(rho)[:hot_n]
        slot_of[hot_ids] = np.arange(hot_n, dtype=np.int32)
        hot = cold[jnp.asarray(hot_ids)]
    else:
        hot = jnp.zeros((1, eps, d), jnp.float32)
    blocks = jnp.asarray(rng.integers(0, rho, (q, f)), jnp.int32)
    got_d, got_h = tier0_rank(qs, blocks, jnp.asarray(slot_of), hot,
                              cold, metric=metric)
    want_d, want_h = ref.tier0_fetch_rank_ref(
        qs, blocks, jnp.asarray(slot_of), hot, cold, metric=metric)
    np.testing.assert_array_equal(np.asarray(got_h), np.asarray(want_h))
    np.testing.assert_allclose(got_d, want_d, rtol=1e-4, atol=1e-4)
    # hot slots hold copies of the cold blocks -> distances must equal
    # an all-cold rank of the same blocks exactly
    all_cold, _ = ref.tier0_fetch_rank_ref(
        qs, blocks, jnp.asarray(np.full(rho, -1, np.int32)),
        jnp.zeros((1, eps, d), jnp.float32), cold, metric=metric)
    np.testing.assert_allclose(want_d, all_cold, rtol=0, atol=0)


def test_tier0_fetch_rank_matches_dists_form():
    """The kernel's distance form is the device search's `_dists` (f32
    sum of squared differences) — bit-compatible with the jnp fetch
    stage, so fused vs jnp fetch never changes search results."""
    from repro.core.device_search import _dists
    rng = np.random.default_rng(3)
    qs = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    cold = jnp.asarray(rng.standard_normal((10, 4, 16)), jnp.float32)
    blocks = jnp.asarray(rng.integers(0, 10, (8, 2)), jnp.int32)
    got_d, _ = tier0_rank(qs, blocks,
                          jnp.asarray(np.full(10, -1, np.int32)),
                          jnp.zeros((1, 4, 16), jnp.float32), cold)
    want = _dists(qs, cold[blocks].reshape(8, 8, 16), "l2")
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want))


def test_block_rank_matches_search_semantics():
    """The kernel's top-m selection equals the block-pruning selection of
    the host search (ascending distance, ties by slot order)."""
    rng = np.random.default_rng(0)
    qs = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    tiles = jnp.asarray(rng.standard_normal((16, 6, 24)), jnp.float32)
    dd, idx = block_rank(qs, tiles, 6)
    order = np.argsort(np.asarray(dd), axis=1)
    np.testing.assert_array_equal(np.asarray(idx), order)
