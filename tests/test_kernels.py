"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (block_rank, fused_round, pairwise_l2,
                           pq_adc_batch, tier0_rank)
from repro.kernels import ref


@pytest.mark.parametrize("q,n,d", [(8, 64, 16), (37, 203, 64),
                                   (128, 512, 128), (1, 9, 8)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_l2_tile_sweep(q, n, d, dtype, metric):
    rng = np.random.default_rng(q * n)
    qa = jnp.asarray(rng.standard_normal((q, d)), dtype)
    xa = jnp.asarray(rng.standard_normal((n, d)), dtype)
    got = pairwise_l2(qa, xa, metric=metric)
    want = ref.pairwise_l2_ref(qa, xa, metric=metric)
    tol = 1e-3 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * d)


@pytest.mark.parametrize("n,m,k,b", [(64, 4, 16, 1), (133, 8, 256, 5),
                                     (256, 16, 256, 3), (17, 2, 64, 2)])
def test_pq_adc_sweep(n, m, k, b):
    rng = np.random.default_rng(n * m)
    codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.uint8)
    luts = jnp.asarray(rng.standard_normal((b, m, k)), jnp.float32)
    got = pq_adc_batch(codes, luts)
    want = ref.pq_adc_ref(luts, codes)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("q,eps,d,top", [(19, 8, 32, 3), (64, 16, 128, 5),
                                         (5, 4, 16, 4), (128, 12, 64, 1)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_block_rank_sweep(q, eps, d, top, metric):
    rng = np.random.default_rng(q * eps)
    qs = jnp.asarray(rng.standard_normal((q, d)), jnp.float32)
    tiles = jnp.asarray(rng.standard_normal((q, eps, d)), jnp.float32)
    dd, idx = block_rank(qs, tiles, top, metric=metric)
    dr, idxr = ref.block_rank_ref(qs, tiles, top, metric=metric)
    np.testing.assert_allclose(dd, dr, rtol=1e-3, atol=1e-3)
    # indices must agree where distances are distinct
    got_d = np.take_along_axis(np.asarray(dd), np.asarray(idx), axis=1)
    want_d = np.take_along_axis(np.asarray(dr), np.asarray(idxr), axis=1)
    np.testing.assert_allclose(got_d, want_d, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("q,rho,eps,d,f,hot_n",
                         [(16, 32, 4, 16, 1, 8), (37, 64, 8, 32, 2, 0),
                          (8, 16, 6, 24, 3, 16), (128, 96, 5, 64, 2, 40)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_tier0_fetch_rank_sweep(q, rho, eps, d, f, hot_n, metric):
    """Fused probe+gather+rank vs the jnp oracle, including hot_n=0
    (sentinel pack, map all cold) and hot_n=rho (all hot)."""
    rng = np.random.default_rng(q * rho)
    qs = jnp.asarray(rng.standard_normal((q, d)), jnp.float32)
    cold = jnp.asarray(rng.standard_normal((rho, eps, d)), jnp.float32)
    slot_of = np.full(rho, -1, np.int32)
    if hot_n > 0:
        hot_ids = rng.permutation(rho)[:hot_n]
        slot_of[hot_ids] = np.arange(hot_n, dtype=np.int32)
        hot = cold[jnp.asarray(hot_ids)]
    else:
        hot = jnp.zeros((1, eps, d), jnp.float32)
    blocks = jnp.asarray(rng.integers(0, rho, (q, f)), jnp.int32)
    got_d, got_h = tier0_rank(qs, blocks, jnp.asarray(slot_of), hot,
                              cold, metric=metric)
    want_d, want_h = ref.tier0_fetch_rank_ref(
        qs, blocks, jnp.asarray(slot_of), hot, cold, metric=metric)
    np.testing.assert_array_equal(np.asarray(got_h), np.asarray(want_h))
    np.testing.assert_allclose(got_d, want_d, rtol=1e-4, atol=1e-4)
    # hot slots hold copies of the cold blocks -> distances must equal
    # an all-cold rank of the same blocks exactly
    all_cold, _ = ref.tier0_fetch_rank_ref(
        qs, blocks, jnp.asarray(np.full(rho, -1, np.int32)),
        jnp.zeros((1, eps, d), jnp.float32), cold, metric=metric)
    np.testing.assert_allclose(want_d, all_cold, rtol=0, atol=0)


def test_tier0_fetch_rank_matches_dists_form():
    """The kernel's distance form is the device search's `_dists` (f32
    sum of squared differences) — bit-compatible with the jnp fetch
    stage, so fused vs jnp fetch never changes search results."""
    from repro.core.device_search import _dists
    rng = np.random.default_rng(3)
    qs = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    cold = jnp.asarray(rng.standard_normal((10, 4, 16)), jnp.float32)
    blocks = jnp.asarray(rng.integers(0, 10, (8, 2)), jnp.int32)
    got_d, _ = tier0_rank(qs, blocks,
                          jnp.asarray(np.full(10, -1, np.int32)),
                          jnp.zeros((1, 4, 16), jnp.float32), cold)
    want = _dists(qs, cold[blocks].reshape(8, 8, 16), "l2")
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want))


def _fused_round_case(q, rho, eps, d, f, hot_n, lam=5, seed=None,
                      idle_rows=0):
    rng = np.random.default_rng(q * rho if seed is None else seed)
    n = rho * eps
    qs = jnp.asarray(rng.standard_normal((q, d)), jnp.float32)
    cold = jnp.asarray(rng.standard_normal((rho, eps, d)), jnp.float32)
    vid = jnp.asarray(rng.permutation(n).reshape(rho, eps), jnp.int32)
    nbrs = jnp.asarray(rng.integers(-1, n, (rho, eps, lam)), jnp.int32)
    block_of = np.zeros(n, np.int32)
    block_of[np.asarray(vid).reshape(-1)] = np.repeat(
        np.arange(rho, dtype=np.int32), eps)
    slot_of = np.full(rho, -1, np.int32)
    if hot_n > 0:
        hot_ids = rng.permutation(rho)[:hot_n]
        slot_of[hot_ids] = np.arange(hot_n, dtype=np.int32)
        hot_v = cold[jnp.asarray(hot_ids)]
        hot_i = vid[jnp.asarray(hot_ids)]
        hot_n_arr = nbrs[jnp.asarray(hot_ids)]
    else:
        hot_v = jnp.zeros((1, eps, d), jnp.float32)
        hot_i = jnp.full((1, eps), -1, jnp.int32)
        hot_n_arr = jnp.full((1, eps, lam), -1, jnp.int32)
    u = rng.integers(0, n, (q, f)).astype(np.int32)
    u[rng.random((q, f)) < 0.2] = -1           # converged/empty slots
    if idle_rows:
        u[-idle_rows:] = -1                    # fully-converged queries
    u = jnp.asarray(u)
    args = (qs, u, jnp.asarray(block_of), jnp.asarray(slot_of),
            hot_v, hot_i, hot_n_arr, cold, vid, nbrs)
    return args


@pytest.mark.parametrize("q,rho,eps,d,f,hot_n",
                         [(16, 32, 4, 16, 1, 8), (37, 64, 8, 32, 2, 0),
                          (8, 16, 6, 24, 3, 16), (128, 96, 5, 64, 2, 40)])
def test_fused_round_matches_ref(q, rho, eps, d, f, hot_n):
    """The fused per-round kernel (cross-query-deduped gather) matches
    the straight-gather oracle: dedup only changes which gather
    produced a tile, never its payload — block metadata and the hit
    mask are exact; distances match to float tolerance here (this
    standalone comparison pits a jit-fused graph against the eager
    oracle, like the other kernel sweeps — inside the search jit the
    two fetch_impls are bit-identical, asserted in test_device_search);
    the expansion order walks the same non-decreasing key sequence.
    Duplicate requests and converged (-1) slots included."""
    args = _fused_round_case(q, rho, eps, d, f, hot_n)
    n_expand = f * 2
    dd, vid, nbrs, hit, order = fused_round(*args, n_expand)
    dd_r, vid_r, nbrs_r, hit_r, order_r = ref.fused_round_ref(
        *args, n_expand)
    np.testing.assert_array_equal(np.asarray(vid), np.asarray(vid_r))
    np.testing.assert_array_equal(np.asarray(nbrs), np.asarray(nbrs_r))
    np.testing.assert_array_equal(np.asarray(hit), np.asarray(hit_r))
    np.testing.assert_allclose(np.asarray(dd), np.asarray(dd_r),
                               rtol=1e-4, atol=1e-4)
    # reconstruct the masked selection key (the ref formula) and check
    # both orders rank it identically up to float-tolerance ties
    u = np.asarray(args[1])
    f_valid = np.repeat(u >= 0, eps, axis=1)
    dd_m = np.where((np.asarray(vid_r) >= 0) & f_valid,
                    np.asarray(dd_r), np.inf)
    is_t = ((np.asarray(vid_r)[:, :, None] == u[:, None, :]).any(-1)
            & (np.asarray(vid_r) >= 0))
    sel = np.where(is_t, -np.inf, dd_m)
    got_keys = np.take_along_axis(sel, np.asarray(order), axis=1)
    want_keys = np.take_along_axis(sel, np.asarray(order_r), axis=1)
    np.testing.assert_allclose(got_keys, want_keys, rtol=1e-4,
                               atol=1e-4)


def test_fused_round_idle_tile_emits_masked_sentinels():
    """A query tile whose rows are all converged takes the kernel's
    skip path: hit stays 0 and vid is the -1 sentinel, so the search
    loop (which gates every consumer on u >= 0) folds in nothing."""
    args = _fused_round_case(16, 32, 4, 16, 2, 8, idle_rows=16)
    dd, vid, nbrs, hit, order = fused_round(*args, 4)
    assert (np.asarray(hit) == 0).all()
    assert (np.asarray(vid) == -1).all()
    assert (np.asarray(dd) == 0).all()
    # live rows in the same call are unaffected: re-run with the idle
    # rows live and check the live half is unchanged
    args2 = _fused_round_case(16, 32, 4, 16, 2, 8, idle_rows=8)
    dd2, vid2, *_ = fused_round(*args2, 4)
    want = ref.fused_round_ref(*args2, 4)
    np.testing.assert_array_equal(np.asarray(dd2[:8]),
                                  np.asarray(want[0][:8]))


@pytest.mark.parametrize("r,hi,seed", [(8, 4, 0), (64, 12, 1),
                                       (96, 96, 2), (128, 3, 3),
                                       (16, 1, 4)])
def test_union_slot_map_matches_sorted_unique_oracle(r, hi, seed):
    """DESIGN.md §9: the sort-free O(R^2) in-kernel union twin is
    bit-identical to the argsort+scatter pass-1 implementation — same
    ascending uniq with 0 placeholders past the distinct count, same
    flat-slot -> unique-rank map — across duplicate densities from
    all-distinct to all-equal."""
    from repro.kernels.dedup import sorted_unique_ranks, union_slot_map
    rng = np.random.default_rng(seed)
    flat = jnp.asarray(rng.integers(0, hi, (r,)), jnp.int32)
    uniq_s, rank_s = sorted_unique_ranks(flat)
    uniq_m, rank_m = union_slot_map(flat)
    np.testing.assert_array_equal(np.asarray(uniq_s),
                                  np.asarray(uniq_m))
    np.testing.assert_array_equal(np.asarray(rank_s),
                                  np.asarray(rank_m))
    # the defining identity both must satisfy
    np.testing.assert_array_equal(np.asarray(uniq_m)[np.asarray(rank_m)],
                                  np.asarray(flat))


@pytest.mark.parametrize("force_dma", [False, True])
def test_gather_union_matches_two_pass(force_dma):
    """The fused pass 1+2a kernel (in-kernel union + cold gather,
    straight-line and double-buffered-DMA schedules) hands pass 2b the
    same five values as host-side pass 1 + ``gather_unique``,
    bit-identically — including the 0-placeholder tail rows past the
    distinct count, which both paths gather harmlessly."""
    from repro.kernels.dedup import sorted_unique_ranks as sur
    from repro.kernels.tier0_fetch import gather_union
    rng = np.random.default_rng(7)
    qn, f, rho, eps, d, lam = 16, 3, 24, 4, 16, 5
    b = jnp.asarray(rng.integers(0, rho, (qn, f)), jnp.int32)
    vecs = jnp.asarray(rng.standard_normal((rho, eps, d)), jnp.float32)
    vid = jnp.asarray(rng.permutation(rho * eps).reshape(rho, eps),
                      jnp.int32)
    nbrs = jnp.asarray(rng.integers(-1, rho * eps, (rho, eps, lam)),
                       jnp.int32)
    uniq, rank2d, tv, ti, tn = gather_union(b, vecs, vid, nbrs,
                                            _force_dma=force_dma)
    uniq_w, rank_w = sur(b.reshape(-1))
    np.testing.assert_array_equal(np.asarray(uniq), np.asarray(uniq_w))
    np.testing.assert_array_equal(np.asarray(rank2d),
                                  np.asarray(rank_w).reshape(qn, f))
    np.testing.assert_array_equal(np.asarray(tv),
                                  np.asarray(vecs)[np.asarray(uniq_w)])
    np.testing.assert_array_equal(np.asarray(ti),
                                  np.asarray(vid)[np.asarray(uniq_w)])
    np.testing.assert_array_equal(np.asarray(tn),
                                  np.asarray(nbrs)[np.asarray(uniq_w)])


@pytest.mark.parametrize("q,rho,eps,d,f,hot_n",
                         [(16, 32, 4, 16, 1, 8), (37, 64, 8, 32, 2, 0),
                          (8, 16, 6, 24, 3, 16)])
@pytest.mark.parametrize("force_dma", [False, True])
def test_fused_round_union_fusion_is_bit_identical(q, rho, eps, d, f,
                                                   hot_n, force_dma):
    """ISSUE 9 acceptance: fused-union vs two-pass ``fused_round`` is
    bit-identical on every output — the two-pass path stays available
    as the conformance oracle twin, under both gather schedules."""
    args = _fused_round_case(q, rho, eps, d, f, hot_n)
    n_expand = f * 2
    base = fused_round(*args, n_expand, _force_dma=force_dma)
    fused = fused_round(*args, n_expand, fuse_union=True,
                        _force_dma=force_dma)
    for name, a, b in zip(("dists", "vid", "nbrs", "hit", "order"),
                          base, fused):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"fuse_union changed {name}")


def test_block_rank_matches_search_semantics():
    """The kernel's top-m selection equals the block-pruning selection of
    the host search (ascending distance, ties by slot order)."""
    rng = np.random.default_rng(0)
    qs = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    tiles = jnp.asarray(rng.standard_normal((16, 6, 24)), jnp.float32)
    dd, idx = block_rank(qs, tiles, 6)
    order = np.argsort(np.asarray(dd), axis=1)
    np.testing.assert_array_equal(np.asarray(idx), order)
