"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import block_rank, pairwise_l2, pq_adc_batch
from repro.kernels import ref


@pytest.mark.parametrize("q,n,d", [(8, 64, 16), (37, 203, 64),
                                   (128, 512, 128), (1, 9, 8)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_l2_tile_sweep(q, n, d, dtype, metric):
    rng = np.random.default_rng(q * n)
    qa = jnp.asarray(rng.standard_normal((q, d)), dtype)
    xa = jnp.asarray(rng.standard_normal((n, d)), dtype)
    got = pairwise_l2(qa, xa, metric=metric)
    want = ref.pairwise_l2_ref(qa, xa, metric=metric)
    tol = 1e-3 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * d)


@pytest.mark.parametrize("n,m,k,b", [(64, 4, 16, 1), (133, 8, 256, 5),
                                     (256, 16, 256, 3), (17, 2, 64, 2)])
def test_pq_adc_sweep(n, m, k, b):
    rng = np.random.default_rng(n * m)
    codes = jnp.asarray(rng.integers(0, k, (n, m)), jnp.uint8)
    luts = jnp.asarray(rng.standard_normal((b, m, k)), jnp.float32)
    got = pq_adc_batch(codes, luts)
    want = ref.pq_adc_ref(luts, codes)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("q,eps,d,top", [(19, 8, 32, 3), (64, 16, 128, 5),
                                         (5, 4, 16, 4), (128, 12, 64, 1)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_block_rank_sweep(q, eps, d, top, metric):
    rng = np.random.default_rng(q * eps)
    qs = jnp.asarray(rng.standard_normal((q, d)), jnp.float32)
    tiles = jnp.asarray(rng.standard_normal((q, eps, d)), jnp.float32)
    dd, idx = block_rank(qs, tiles, top, metric=metric)
    dr, idxr = ref.block_rank_ref(qs, tiles, top, metric=metric)
    np.testing.assert_allclose(dd, dr, rtol=1e-3, atol=1e-3)
    # indices must agree where distances are distinct
    got_d = np.take_along_axis(np.asarray(dd), np.asarray(idx), axis=1)
    want_d = np.take_along_axis(np.asarray(dr), np.asarray(idxr), axis=1)
    np.testing.assert_allclose(got_d, want_d, rtol=1e-3, atol=1e-3)


def test_block_rank_matches_search_semantics():
    """The kernel's top-m selection equals the block-pruning selection of
    the host search (ascending distance, ties by slot order)."""
    rng = np.random.default_rng(0)
    qs = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    tiles = jnp.asarray(rng.standard_normal((16, 6, 24)), jnp.float32)
    dd, idx = block_rank(qs, tiles, 6)
    order = np.argsort(np.asarray(dd), axis=1)
    np.testing.assert_array_equal(np.asarray(idx), order)
