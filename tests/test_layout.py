"""Block-layout invariants + shuffling properties (§4.1)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; rest of the suite runs without")
from hypothesis import given, settings, strategies as st

from repro.core import layout as L
from repro.core.graph import Graph
from repro.core.params import GraphParams


def random_graph(n: int, deg: int, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    adj = np.full((n, deg), -1, np.int32)
    degs = rng.integers(1, deg + 1, size=n).astype(np.int32)
    for u in range(n):
        nbrs = rng.choice(n - 1, size=degs[u], replace=False)
        nbrs[nbrs >= u] += 1                  # no self loops
        adj[u, : degs[u]] = nbrs
    return Graph(adj=adj, deg=degs, entry=0)


@settings(deadline=None, max_examples=20)
@given(n=st.integers(10, 200), eps=st.integers(2, 9),
       deg=st.integers(2, 8), seed=st.integers(0, 10_000))
def test_layout_bijection_property(n, eps, deg, seed):
    """Every shuffle scheme yields a bijection V -> (block, slot)."""
    g = random_graph(n, deg, seed)
    for scheme in ("none", "bnp", "bnf"):
        lay = L.make_layout(g, eps, scheme, bnf_iters=2)
        lay.validate()
        orr = L.overlap_ratio(g, lay)
        assert 0.0 <= orr <= 1.0


@settings(deadline=None, max_examples=10)
@given(n=st.integers(20, 120), eps=st.integers(2, 6),
       seed=st.integers(0, 1000))
def test_bnf_improves_over_sequential(n, eps, seed):
    g = random_graph(n, 6, seed)
    base = L.overlap_ratio(g, L.layout_sequential(g, eps))
    bnf = L.overlap_ratio(g, L.layout_bnf(g, eps, iters=4)[0])
    assert bnf >= base - 1e-9


def test_bns_monotone_lemma42():
    """Lemma 4.2: OR(G) non-decreasing over BNS iterations."""
    g = random_graph(60, 5, seed=3)
    _, history = L.layout_bns(g, eps=4, iters=3, tau=-1.0)
    for a, b in zip(history, history[1:]):
        assert b >= a - 1e-9


def test_bnp_neighbors_padded():
    """BNP puts the first vertex's neighbors in its block (Example 4)."""
    g = random_graph(50, 3, seed=1)
    lay = L.layout_bnp(g, eps=4)
    b0 = set(lay.blocks[lay.block_of[0]].tolist())
    nbrs = set(g.adj[0, : g.deg[0]].tolist())
    assert 0 in b0
    assert len(b0 & nbrs) >= min(len(nbrs), 3)


def test_shuffling_beats_baseline_on_real_graph(small_segment):
    """Paper Fig. 9: BNF locality >> ID-contiguous baseline on a real
    vector graph; the built segment's stored OR must match recompute."""
    seg = small_segment
    g = seg.graph
    eps = seg.view.layout.verts_per_block
    seq_or = L.overlap_ratio(g, L.layout_sequential(g, eps))
    assert seg.overlap_ratio > seq_or + 0.05
    assert seg.overlap_ratio == pytest.approx(
        L.overlap_ratio(g, seg.view.layout), abs=1e-5)


def test_kmeans_packer_worse_than_bnf(small_segment, small_data):
    """§7: naive k-means packing loses to graph-aware shuffling."""
    x, _ = small_data
    seg = small_segment
    eps = seg.view.layout.verts_per_block
    km = L.overlap_ratio(seg.graph, L.layout_kmeans(x, seg.graph, eps))
    assert seg.overlap_ratio > km


def test_gp3_gain_order_variant(small_segment):
    g = small_segment.graph
    eps = small_segment.view.layout.verts_per_block
    lay = L.make_layout(g, eps, "gp3", bnf_iters=2)
    lay.validate()
