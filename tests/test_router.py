"""Mesh router (DESIGN.md §7): shard_map fan-out over sharded
segments, replica-slice routing, per-rank accounting, elastic
rebalance.

The mesh-dependent tests run in the ``make test-mesh`` lane
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and skip on
smaller worlds; the planning/validation tests run everywhere."""
import dataclasses

import numpy as np
import pytest

import jax

from repro.core import device_search as DS
from repro.core.iostats import IOStats
from repro.core.params import RouterParams
from repro.core.segment import build_segment
from repro.data.vectors import clustered_vectors, query_set
from repro.serving import MeshQueryRouter, QueryCoordinator, SegmentServer
from repro.serving.coordinator import SERVE_DEVICE_SEARCH, merge_topk
from repro.serving.target import BATCH_STAT_KEYS, SegmentTarget, is_target
from tests.conftest import SMALL_SEGMENT

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the make test-mesh lane)")

N_SEG = 4
N_PER_SEG = 600


@pytest.fixture(scope="module")
def mesh_servers():
    """Four shape-identical segments (stack_segments requires it) with
    global id bases, plus queries drawn over their union."""
    if jax.device_count() < 8:
        pytest.skip("mesh fixture needs 8 host devices")
    p = dataclasses.replace(SERVE_DEVICE_SEARCH, candidates=48,
                            fetch_impl="jnp")
    servers, xs, off = [], [], 0
    for s in range(N_SEG):
        x = clustered_vectors(N_PER_SEG, 32, num_clusters=8, seed=30 + s)
        seg = build_segment(x, SMALL_SEGMENT)
        servers.append(SegmentServer(
            segment=DS.from_segment(seg, tier0_frac=0.1),
            offset=off, num_vectors=x.shape[0], params=p, host=seg))
        xs.append(x)
        off += x.shape[0]
    q = query_set(np.concatenate(xs), 16, seed=7)
    return servers, q


@pytest.fixture()
def router(mesh_servers):
    servers, _ = mesh_servers
    return MeshQueryRouter(
        servers, params=RouterParams(window_batches=8,
                                     rebalance_interval=4, min_window=2,
                                     skew_threshold=1.2))


# ------------------------------------------------------ acceptance core

@needs_mesh
def test_route_bit_identical_to_single_target(router, mesh_servers):
    """THE mesh invariant: routed + device-merged (ids, dists) ==
    merge_topk over the per-segment single-target paths. Exact
    equality — both merges sort the same (dist, global id) key."""
    servers, q = mesh_servers
    assert len(servers) >= 4 and router.world >= 8
    ri, rd, stats = router.route(q, k=10)

    ids, dd, offs = [], [], []
    for s in servers:
        i, d, _ = s.search(q, 10)
        ids.append(i)
        dd.append(d)
        offs.append(s.offset)
    gi, gd = merge_topk(ids, dd, offs, 10)
    np.testing.assert_array_equal(ri, gi)
    np.testing.assert_array_equal(rd, gd)
    assert stats["segments"] == N_SEG and stats["ranks"] == 8


@needs_mesh
def test_per_rank_fold_is_exact(router, mesh_servers):
    """Per-rank IOStats fold to the router totals exactly:
    merge_ranks(per_rank) == stats['total'], and the additive counters
    sum across ranks (rounds_active_weight deliberately does not —
    totals are DEFINED as the merge, nothing else)."""
    _, q = mesh_servers
    _, _, stats = router.route(q, k=10)
    per_rank = stats["per_rank"]
    assert set(per_rank) == set(range(router.world))
    assert IOStats.merge_ranks(per_rank) == stats["total"]
    for field in ("cache_misses", "tier0_hits", "dedup_saved_fetches"):
        assert sum(getattr(r, field) for r in per_rank.values()) \
            == getattr(stats["total"], field)
    # slowest-rank gating: batch_rounds merges by max
    assert stats["rounds_max"] == max(
        r.batch_rounds for r in per_rank.values())
    assert stats["modeled_step_us"] == max(
        stats["per_rank_modeled_us"].values())
    assert stats["total_block_reads"] > 0


@needs_mesh
def test_replica_slices_partition_batch(router):
    """Every segment's replica group partitions [0, q) into disjoint
    contiguous slices — each (query, segment) pair owned exactly
    once."""
    for q in (1, 7, 16, 33):
        meta = router._rank_meta(q)
        for si, ranks in router._seg_ranks().items():
            lo = 0
            for r in ranks:
                assert meta[r, 1] == lo
                assert meta[r, 2] >= meta[r, 1]
                lo = int(meta[r, 2])
            assert lo == q


@needs_mesh
def test_routed_speculation_is_bit_identical(router, mesh_servers):
    """ISSUE 9 mesh acceptance: the cross-round speculative pipeline
    under shard_map fan-out changes nothing the user sees — routed
    (ids, dists) and every shared counter are bit-identical — while
    the spec counters flow through the per-rank fold, the batch_stats
    schema and the dma_speculative flag."""
    servers, q = mesh_servers
    spec_servers = [dataclasses.replace(
        s, params=dataclasses.replace(s.params, speculate=True))
        for s in servers]
    spec_router = MeshQueryRouter(spec_servers, params=router.params)
    ri, rd, stats = router.route(q, k=10)
    si, sd, sstats = spec_router.route(q, k=10)
    np.testing.assert_array_equal(ri, si)
    np.testing.assert_array_equal(rd, sd)
    for field in ("cache_misses", "tier0_hits", "hops",
                  "dedup_saved_fetches", "dedup_cross_tile"):
        assert getattr(stats["total"], field) \
            == getattr(sstats["total"], field), field
    assert stats["rounds_max"] == sstats["rounds_max"]
    # off-run counters are zero; on-run counters fold rank-additively
    assert stats["total_spec_hits"] == 0
    assert stats["total_spec_wasted"] == 0
    assert stats["total"].dma_speculative == 0
    assert sstats["total"].dma_speculative == 1
    assert sstats["total_spec_hits"] == sum(
        r.spec_hits for r in sstats["per_rank"].values())
    assert sstats["total_spec_hits"] > 0, \
        "this workload should speculate successfully"
    # the schema rides batch_stats: spec columns sum to the totals
    bs = spec_router.batch_stats()
    assert int(np.sum(bs["spec_hits"])) == sstats["total_spec_hits"]
    assert int(np.sum(bs["spec_wasted"])) == sstats["total_spec_wasted"]
    assert bs["dma_speculative"] is True
    assert spec_router.batch_stats() is not None


@needs_mesh
def test_router_is_segment_target(router, mesh_servers):
    """The router IS a SegmentTarget: protocol surface + batch_stats
    schema + per-query io that sums each (query, segment) once."""
    servers, q = mesh_servers
    assert isinstance(router, SegmentTarget) and is_target(router)
    assert router.offset == 0
    assert router.num_vectors == sum(s.num_vectors for s in servers)
    ids, dists, io = router.search(q, k=10)
    assert ids.shape == (q.shape[0], 10) and io.shape == (q.shape[0],)
    bs = router.batch_stats()
    assert set(BATCH_STAT_KEYS) <= set(bs)
    assert int(np.sum(bs["io"])) == router.last_stats.cache_misses
    np.testing.assert_array_equal(np.asarray(bs["io"], np.int64), io)


@needs_mesh
def test_router_through_coordinator(router, mesh_servers):
    """The coordinator speaks only the protocol, so a mesh router drops
    in as a single target — ids already global (offset 0)."""
    _, q = mesh_servers
    ri, rd, _ = router.route(q, k=10)
    coord = QueryCoordinator([router])
    ci, cd, stats = coord.search(q, k=10)
    np.testing.assert_array_equal(ci, ri)
    np.testing.assert_array_equal(cd, rd)
    assert stats["segments_searched"] == 1
    assert stats["total_block_reads"] == router.last_stats.cache_misses


# --------------------------------------------------------- rebalance

@needs_mesh
def test_rebalance_quiet_on_settled_stream(router, mesh_servers):
    """A settled stream (same batch over and over) must NOT fire: the
    rank loads stay proportional, the re-plan keeps the placement."""
    _, q = mesh_servers
    before = router.placement
    fired = []
    for _ in range(router.params.rebalance_interval * 2):
        _, _, stats = router.route(q, k=10)
        if "rebalance" in stats:
            fired.append(stats["rebalance"]["fired"])
    assert fired and not any(fired)
    assert router.placement == before and router.rebalances == 0


@needs_mesh
def test_rebalance_fires_on_skew_then_settles(router, mesh_servers):
    """A sustained skewed window fires a rebalance that grants the hot
    segment extra replicas; re-planning from the settled post-move
    loads is idempotent (zero moves)."""
    _, q = mesh_servers
    _, _, _ = router.route(q, k=10)      # populate shapes/window
    hot = 0
    w = router.world
    skewed_rank = np.asarray(
        [40.0 if router.placement[r] == hot else 1.0 for r in range(w)])
    seg = np.zeros(N_SEG)
    for r in range(w):
        seg[router.placement[r]] += skewed_rank[r]
    router._window.clear()
    for _ in range(router.params.min_window):
        router._window.append((skewed_rank, seg, np.ones(w)))
    plan = router.maybe_rebalance(force=True)
    assert plan is not None and plan.fired and len(plan.moves) > 0
    assert plan.skew >= router.params.skew_threshold
    counts = np.bincount(router.placement, minlength=N_SEG)
    assert counts[hot] > counts[1:].max()     # hot segment gained ranks
    assert counts.min() >= 1                  # every segment still held
    assert router.rebalances == 1
    assert len(router._window) == 0           # stale attribution dropped

    # idempotence: balanced per-rank loads under the new placement
    settled = np.ones(w)
    seg2 = np.bincount(router.placement, minlength=N_SEG).astype(float)
    for _ in range(router.params.min_window):
        router._window.append((settled, seg2, np.ones(w)))
    plan2 = router.maybe_rebalance(force=True)
    assert plan2 is not None and not plan2.fired


@needs_mesh
def test_rebalanced_placement_serves_identically(router, mesh_servers):
    """Placement changes must not change results: after a forced move
    the restacked tree serves the same (ids, dists) — same compiled
    step, different shard contents."""
    servers, q = mesh_servers
    ri, rd, _ = router.route(q, k=10)
    new = [0, 0, 0, 0, 1, 1, 2, 3][: router.world]
    router._placement = list(new)
    router._restack()
    ri2, rd2, _ = router.route(q, k=10)
    np.testing.assert_array_equal(ri2, ri)
    np.testing.assert_array_equal(rd2, rd)


# ---------------------------------------------- unguarded validation

def test_router_params_validation():
    with pytest.raises(ValueError):
        RouterParams(window_batches=0)
    with pytest.raises(ValueError):
        RouterParams(rebalance_interval=0)
    with pytest.raises(ValueError):
        RouterParams(min_window=32, window_batches=16)
    with pytest.raises(ValueError):
        RouterParams(skew_threshold=0.5)


class _Stub:
    def __init__(self, params, metric="l2", num_vectors=10, offset=0):
        self.params = params
        self.metric = metric
        self.num_vectors = num_vectors
        self.offset = offset


def test_router_rejects_mismatched_members():
    p = SERVE_DEVICE_SEARCH
    other = dataclasses.replace(p, candidates=p.candidates * 2)
    with pytest.raises(ValueError, match="share DeviceSearchParams"):
        MeshQueryRouter([_Stub(p), _Stub(other)])
    with pytest.raises(ValueError, match="share DeviceSearchParams"):
        MeshQueryRouter([_Stub(p, metric="l2"), _Stub(p, metric="mips")])
    with pytest.raises(ValueError, match="at least one"):
        MeshQueryRouter([])


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_router_rejects_undersized_world():
    p = SERVE_DEVICE_SEARCH
    with pytest.raises(ValueError, match="cannot hold"):
        MeshQueryRouter([_Stub(p), _Stub(p)],
                        mesh=_FakeMesh({"data": 1, "model": 1}))


def test_router_rejects_nonmodel_sharding():
    p = SERVE_DEVICE_SEARCH
    with pytest.raises(ValueError, match="'model' only"):
        MeshQueryRouter([_Stub(p)],
                        mesh=_FakeMesh({"data": 2, "model": 2}))
