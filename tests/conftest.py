import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: build-heavy test (segment/graph builds, jit compiles); "
        "deselected by `make test-fast` / the fast CI lane")

from repro.core.params import (GraphParams, LayoutParams, NavGraphParams,
                               PQParams, SegmentParams)
from repro.data.vectors import clustered_vectors, query_set


SMALL_SEGMENT = SegmentParams(
    graph=GraphParams(max_degree=16, build_beam=48),
    layout=LayoutParams(block_kb=1.0, shuffle="bnf", bnf_iters=4),
    pq=PQParams(num_subspaces=8, train_iters=6, train_sample=2048),
    nav=NavGraphParams(sample_ratio=0.1, max_degree=8, build_beam=24),
)


@pytest.fixture(scope="session")
def small_data():
    x = clustered_vectors(2500, 32, num_clusters=24, seed=0)
    q = query_set(x, 24, seed=1)
    return x, q


@pytest.fixture(scope="session")
def small_segment(small_data):
    from repro.core.segment import build_segment
    x, _ = small_data
    return build_segment(x, SMALL_SEGMENT)
