"""Adaptive serving plane: the feedback-driven tier-0 repack scheduler
(ISSUE 5 tentpole). Deterministic twins of the hypothesis properties in
``test_scheduler_props.py`` — these always run.

The invariants under test (DESIGN.md §5):

  * a scheduled repack NEVER changes ``(ids, dists)`` — exact copies
    either way, only the io/tier0_hits split moves;
  * hysteresis: a drift that would change fewer than ``hysteresis x H``
    pack slots fires ZERO repacks (the no-op is free — nothing is
    rebuilt);
  * idempotence: at fixed observed frequencies the second evaluation
    plans the live pack (drift 0) and does nothing;
  * the demand signal is the *union* across feeds, windowed by
    ``freq_delta`` watermarks.
"""
import dataclasses
from collections import Counter

import numpy as np
import pytest

from repro.core.blockstore import BlockStore
from repro.core.params import CacheParams, DeviceSearchParams, RepackParams
from repro.io import hotset
from repro.io.cache import BlockCache
from repro.io.cached_store import CachedBlockStore, cached_view
from repro.serving import (HostSegmentServer, QueryCoordinator,
                           RepackScheduler, SegmentServer,
                           attach_shared_fetch_queue)

P_SRV = DeviceSearchParams(k=10, candidates=48, max_hops=64,
                           fetch_width=2, compact_frac=0.25)


def _tiny_store() -> CachedBlockStore:
    """A 4-block store just big enough to exercise freq accounting."""
    base = BlockStore(vid=np.arange(8, dtype=np.int32).reshape(4, 2),
                      vecs=np.zeros((4, 2, 8), np.float32),
                      meta=np.full((4, 2, 5), -1, np.int32),
                      block_kb=1.0)
    return CachedBlockStore(base, BlockCache(4096, 1024))


def _device_server(seg, tier0_blocks=8) -> SegmentServer:
    from repro.core import device_search as DS
    return SegmentServer(
        segment=DS.from_segment(seg, tier0_blocks=tier0_blocks),
        offset=0, num_vectors=seg.num_vectors, host=seg, params=P_SRV)


def _hot_set(ds) -> set:
    from repro.core.device_search import hot_pack_blocks
    return hot_pack_blocks(ds)


# ---------------------------------------------------------- freq window

def test_freq_delta_windowing():
    store = _tiny_store()
    store.block_freq.update({0: 3, 2: 1})
    mark = Counter(store.block_freq)
    assert store.freq_delta(mark) == Counter()
    store.block_freq.update({0: 2, 1: 5})
    assert store.freq_delta(mark) == Counter({1: 5, 0: 2})
    # lifetime view when no watermark is given; the store never forgets
    assert store.freq_delta() == Counter({0: 5, 1: 5, 2: 1})
    assert store.block_freq[0] == 5


def test_attach_feed_rejects_bare_stores():
    sched = RepackScheduler()
    with pytest.raises(TypeError):
        sched.attach_feed(object())
    store = _tiny_store()
    sched.attach_feed(store)
    sched.attach_feed(store)              # idempotent attach
    assert len(sched._feeds) == 1


def test_demand_union_across_feeds():
    s1, s2 = _tiny_store(), _tiny_store()
    sched = RepackScheduler()
    sched.attach_feed(s1)
    sched.attach_feed(s2)
    s1.block_freq.update({0: 2, 1: 1})
    s2.block_freq.update({1: 4, 3: 2})
    assert sched.demand_union() == Counter({0: 2, 1: 5, 3: 2})


# ------------------------------------------------------ plan invariants

def test_plan_matches_materialized_pack(small_segment):
    """hotset.plan_tier0 and the pack from_segment builds must select
    the same blocks — the hysteresis gate prices the real repack."""
    from repro.core import device_search as DS
    seg = small_segment
    v = seg.view
    rho = v.store.num_blocks
    ranking = hotset.hot_block_ranking(
        v.layout.block_of, seg.graph.adj, seg.graph.deg,
        hotset.view_seed_ids(v))
    obs = {b: rho - b for b in range(0, rho, 3)}
    plan = hotset.plan_tier0(ranking, obs, 8, rho)
    ds = DS.from_segment(seg, tier0_blocks=8, observed=obs)
    assert set(plan) == _hot_set(ds)


def test_pack_drift_edges():
    assert hotset.pack_drift(set(), []) == 0.0
    assert hotset.pack_drift({1, 2}, [1, 2]) == 0.0
    assert hotset.pack_drift({1, 2}, [3, 4]) == 1.0
    assert hotset.pack_drift({1, 2, 3, 4}, [1, 2, 3, 9]) == 0.25
    # growing / shrinking plans register too
    assert hotset.pack_drift({1, 2}, [1, 2, 3]) == pytest.approx(1 / 3)


def test_repack_idempotent_at_fixed_frequencies():
    """Planning is deterministic: plan(obs) re-planned under the same
    obs is itself — so the decision after a repack is drift 0."""
    ranking = [5, 3, 8, 1, 9, 0]
    obs = {8: 7, 0: 7, 4: 2}
    p1 = hotset.plan_tier0(ranking, obs, 4, 12)
    p2 = hotset.plan_tier0(ranking, obs, 4, 12)
    assert p1 == p2
    assert hotset.pack_drift(set(p1), p2) == 0.0


# ----------------------------------------------- the control loop itself

@pytest.mark.slow
def test_scheduled_repack_fires_and_is_bit_identical(small_segment,
                                                     small_data):
    """Drifted stream -> scheduler fires at its interval -> modeled
    block touches move into tier 0 -> (ids, dists) bit-identical."""
    _, q = small_data
    seg = small_segment
    cview = cached_view(seg.view, seg.graph,
                        CacheParams(budget_frac=0.10))
    hserver = HostSegmentServer(view=cview, params=seg.params.search,
                                offset=0, num_vectors=seg.num_vectors)
    server = _device_server(seg)
    sched = RepackScheduler(RepackParams(interval_batches=2,
                                         hysteresis=0.2))
    sched.attach_feed(cview.store)
    coord = QueryCoordinator([server], scheduler=sched)

    # a stream aimed at vectors whose blocks the build-time pack left
    # cold: maximal drift against the entry-neighborhood prior
    x = small_data[0]
    cold_vid = np.flatnonzero(~np.isin(
        seg.view.layout.block_of, sorted(_hot_set(server.segment))))
    rng = np.random.default_rng(3)
    qs = (x[rng.choice(cold_vid, 16)]
          + rng.normal(0, 0.01, (16, x.shape[1]))).astype(np.float32)

    hserver.search(qs)                        # demand feed
    gi0, gd0, st0 = coord.search(qs, k=10)    # batch 1: not due yet
    assert "repack" not in st0 and sched.repacks == 0
    old_pack = _hot_set(server.segment)
    gi1, gd1, st1 = coord.search(qs, k=10)    # batch 2: evaluation due
    assert st1["repack"]["repacked"] == 1
    assert sched.repacks == 1
    assert _hot_set(server.segment) != old_pack
    gi2, gd2, st2 = coord.search(qs, k=10)
    np.testing.assert_array_equal(gi0, gi2)
    np.testing.assert_array_equal(gd0, gd2)
    # the repacked pack absorbs more touches on the shifted stream
    assert (st2.get("total_tier0_hits", 0)
            > st0.get("total_tier0_hits", 0))
    assert st2["total_block_reads"] < st0["total_block_reads"]


@pytest.mark.slow
def test_hysteresis_below_threshold_fires_nothing(small_segment,
                                                  small_data):
    """Deterministic twin of the hypothesis property: a drift that
    would change fewer than hysteresis x H slots is a free no-op."""
    seg = small_segment
    server = _device_server(seg, tier0_blocks=8)
    pack = sorted(_hot_set(server.segment))
    store = _tiny_store()
    sched = RepackScheduler(RepackParams(interval_batches=1,
                                         hysteresis=0.5))
    sched.attach_feed(store)
    sched.attach_target(server)
    # observed traffic = the pack itself plus ONE outside block: drift
    # 1/8 < 0.5. (The tiny feed store only supplies the counter — the
    # scheduler unions counters, it never reads feed arrays.)
    rho = seg.view.store.num_blocks
    outside = next(b for b in range(rho) if b not in pack)
    store.block_freq.update({b: 10 for b in pack})
    store.block_freq[outside] = 100
    before = np.asarray(server.segment.hot_slot_of).copy()
    sched.note_batch([server])
    d = sched.maybe_repack()
    assert d is not None and d.repacked == 0 and d.evaluated == 1
    assert 0.0 < d.max_drift < 0.5
    assert sched.repacks == 0 and sched.skipped == 1
    np.testing.assert_array_equal(
        before, np.asarray(server.segment.hot_slot_of))
    # ...and the window survives, so drift can still accumulate later
    assert len(sched._window) > 0


@pytest.mark.slow
def test_hit_rate_ceiling_suppresses_churn(small_segment, small_data):
    """A pack already absorbing the stream is left alone even at full
    drift (the device columns are a real input to the decision)."""
    _, q = small_data
    seg = small_segment
    server = _device_server(seg, tier0_blocks=8)
    store = _tiny_store()
    sched = RepackScheduler(RepackParams(interval_batches=1,
                                         hysteresis=0.1,
                                         hit_rate_ceiling=0.0))
    sched.attach_feed(store)
    sched.attach_target(server)
    rho = seg.view.store.num_blocks
    drifted = [b for b in range(rho)
               if b not in _hot_set(server.segment)][:8]
    store.block_freq.update({b: 50 for b in drifted})
    server.search(q[:8], 10)      # real columns: hit rate < 1.0 is
    sched.note_batch([server])    # still >= ceiling 0.0 -> suppressed
    d = sched.maybe_repack()
    assert d.repacked == 0 and d.max_drift >= 0.1
    assert d.tier0_hit_rate >= 0.0


@pytest.mark.slow
def test_cache_stats_consistent_after_scheduled_repack(small_segment,
                                                       small_data):
    """ISSUE 5 coverage gap: HostSegmentServer.cache_stats() keeps its
    lifetime counters across a scheduled repack — the scheduler windows
    via watermarks, it never resets the store."""
    _, q = small_data
    seg = small_segment
    cview = cached_view(seg.view, seg.graph,
                        CacheParams(budget_frac=0.10, queue_depth=4))
    hserver = HostSegmentServer(view=cview, params=seg.params.search,
                                offset=0, num_vectors=seg.num_vectors)
    server = _device_server(seg)
    sched = RepackScheduler(RepackParams(interval_batches=1,
                                         hysteresis=0.05))
    attach_shared_fetch_queue([hserver], scheduler=sched)
    assert len(sched._feeds) == 1         # queue wiring registered it
    sched.attach_target(server)
    hserver.search(q[:8])
    before = hserver.cache_stats()
    assert before["cache_hits"] + before["cache_misses"] > 0
    freq_before = dict(cview.store.block_freq)
    sched.note_batch([server])
    d = sched.maybe_repack()
    assert d is not None
    after = hserver.cache_stats()
    # lifetime counters monotone and untouched by the decision
    assert after == before
    assert dict(cview.store.block_freq) == freq_before
    hserver.search(q[8:16])
    later = hserver.cache_stats()
    assert later["cache_hits"] >= after["cache_hits"]
    assert (later["cache_hits"] + later["cache_misses"]
            > after["cache_hits"] + after["cache_misses"])


@pytest.mark.slow
def test_partial_repack_keeps_window_for_lagging_targets(small_segment):
    """Multi-target invariant: one target's repack must NOT wipe the
    shared demand window — a sibling still under the hysteresis gate
    keeps accumulating drift (else slow drifters starve forever)."""
    from repro.core import device_search as DS
    seg = small_segment
    rho = seg.view.store.num_blocks
    srv_a = _device_server(seg, tier0_blocks=8)     # build-time pack
    drifted = [b for b in range(rho)
               if b not in _hot_set(srv_a.segment)][:8]
    window = Counter({b: 50 for b in drifted})
    # target B already sits on the observed-hot pack: its drift is 0
    srv_b = SegmentServer(
        segment=DS.from_segment(seg, tier0_blocks=8, observed=window),
        offset=0, num_vectors=seg.num_vectors, host=seg, params=P_SRV)
    assert _hot_set(srv_b.segment) == set(drifted)
    sched = RepackScheduler(RepackParams(interval_batches=1,
                                         hysteresis=0.25))
    sched.attach_target(srv_a)
    sched.attach_target(srv_b)
    sched._window.update(window)
    sched.batches = 1
    d = sched.maybe_repack()
    assert d.evaluated == 2 and d.repacked == 1     # A fired, B held
    assert _hot_set(srv_a.segment) == set(drifted)
    assert sched.repacks == 1 and sched.skipped == 1
    # the window survived the partial repack
    assert sched.demand_union() == window


def test_attach_target_requires_host(small_segment):
    from repro.core import device_search as DS
    sched = RepackScheduler()
    orphan = SegmentServer(segment=DS.from_segment(small_segment,
                                                   tier0_blocks=4),
                           offset=0,
                           num_vectors=small_segment.num_vectors)
    with pytest.raises(ValueError):
        sched.attach_target(orphan)
    with pytest.raises(ValueError):
        orphan.repack({0: 1})


def test_repack_params_validation():
    with pytest.raises(ValueError):
        RepackParams(interval_batches=0)
    with pytest.raises(ValueError):
        RepackParams(hysteresis=1.5)
    with pytest.raises(ValueError):
        RepackParams(min_observed=0)
    with pytest.raises(ValueError):
        RepackParams(hit_rate_ceiling=-0.1)
