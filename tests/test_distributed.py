"""Distribution substrate: sharding rules, HLO analyzer, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.distributed.compress import (compress_with_feedback, dequantize,
                                        ef_init, quantize)
from repro.distributed.hlo import HloAnalyzer, analyze_hlo
from repro.distributed.sharding import (SINGLE_POD_RULES, logical_spec)


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_logical_spec_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    # divisible dims shard; indivisible fall back to replication
    spec = logical_spec((256, 4096), ("vocab", "fsdp"),
                        SINGLE_POD_RULES, mesh)
    assert spec == PartitionSpec("model", "data")
    spec = logical_spec((4, 100), ("heads", "ff"), SINGLE_POD_RULES, mesh)
    assert spec == PartitionSpec(None, None)      # 4 % 16, 100 % 16 != 0


def test_logical_spec_no_axis_reuse():
    mesh = FakeMesh({"data": 16, "model": 16})
    # both dims map to model -> second dim must not reuse the axis
    spec = logical_spec((64, 32), ("heads", "ff"), SINGLE_POD_RULES, mesh)
    assert spec == PartitionSpec("model", None)


def test_hlo_analyzer_scan_flops_exact():
    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    x = jnp.ones((64, 128), jnp.float32)
    w = jnp.ones((128, 128), jnp.float32)
    txt = jax.jit(scanned).lower(x, w).compile().as_text()
    t = analyze_hlo(txt)
    assert t.flops == pytest.approx(7 * 2 * 64 * 128 * 128)


def test_hlo_analyzer_collectives_synthetic():
    hlo = """
HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main.1 () -> f32[] {
  %x = f32[1024]{0} parameter(0)
  %ag = f32[16384]{0} all-gather(%x), replica_groups=[8,16]<=[128], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), replica_groups=[8,16]<=[128], to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%x), replica_groups=[8,16]<=[128], dimensions={0}
}
"""
    t = analyze_hlo(hlo)
    per = t.per_collective
    assert per["all-gather"]["bytes"] == 16384 * 4 // 16
    assert per["all-reduce"]["bytes"] == 1024 * 4
    assert per["reduce-scatter"]["bytes"] == 64 * 4 * 16


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = quantize(g)
    err = jnp.abs(dequantize(q, s) - g)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_preserves_signal():
    """Sum of dequantized grads + final error == sum of raw grads."""
    rng = np.random.default_rng(1)
    grads = [jnp.asarray(rng.standard_normal(64) * 10 ** i, jnp.float32)
             for i in range(3)]
    errors = ef_init({"g": grads[0]})
    total_sent = jnp.zeros(64)
    total_true = jnp.zeros(64)
    e = errors["g"]
    for g in grads:
        q, s, e = (lambda out: (out[0]["x"], out[1]["x"], out[2]["x"]))(
            compress_with_feedback({"x": g}, {"x": e}))
        total_sent = total_sent + dequantize(q, s)
        total_true = total_true + g
    np.testing.assert_allclose(np.asarray(total_sent + e),
                               np.asarray(total_true), rtol=1e-5,
                               atol=1e-4)


def test_compressed_psum_shardmap():
    """int8 gradient all-reduce under shard_map on a 1-device mesh."""
    from jax.experimental.shard_map import shard_map
    from repro.distributed.compress import compressed_psum
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.arange(8, dtype=jnp.float32)}
    e = ef_init(g)

    def f(g, e):
        return compressed_psum(g, e, "data")

    out, new_e = shard_map(
        f, mesh=mesh,
        in_specs=(PartitionSpec(), PartitionSpec()),
        out_specs=(PartitionSpec(), PartitionSpec()))(g, e)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.arange(8), atol=0.05)
