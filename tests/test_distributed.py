"""Distribution substrate: sharding rules, HLO analyzer, compression,
re-mesh and segment-placement planning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.distributed.compress import (compress_with_feedback, dequantize,
                                        ef_init, quantize)
from repro.distributed.elastic import (plan_placement, plan_rebalance,
                                       plan_remesh)
from repro.distributed.hlo import HloAnalyzer, analyze_hlo
from repro.distributed.sharding import (SEGMENT_SERVE_RULES,
                                        SINGLE_POD_RULES, logical_spec)


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_logical_spec_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    # divisible dims shard; indivisible fall back to replication
    spec = logical_spec((256, 4096), ("vocab", "fsdp"),
                        SINGLE_POD_RULES, mesh)
    assert spec == PartitionSpec("model", "data")
    spec = logical_spec((4, 100), ("heads", "ff"), SINGLE_POD_RULES, mesh)
    assert spec == PartitionSpec(None, None)      # 4 % 16, 100 % 16 != 0


def test_logical_spec_no_axis_reuse():
    mesh = FakeMesh({"data": 16, "model": 16})
    # both dims map to model -> second dim must not reuse the axis
    spec = logical_spec((64, 32), ("heads", "ff"), SINGLE_POD_RULES, mesh)
    assert spec == PartitionSpec("model", None)


def test_segment_serve_rules_shard_segment_axis_only():
    """The serving placement rules: one segment shard per model rank,
    everything below the leading axis replicated within a rank."""
    mesh = FakeMesh({"data": 1, "model": 8})
    spec = logical_spec((8, 64, 32), ("segment", "block", "dim"),
                        SEGMENT_SERVE_RULES, mesh)
    assert spec == PartitionSpec("model", None, None)
    # indivisible segment axis falls back to replication, not an error
    spec = logical_spec((3, 64), ("segment", "vertex"),
                        SEGMENT_SERVE_RULES, mesh)
    assert spec == PartitionSpec(None, None)


# ------------------------------------------------ elastic re-mesh plans

def test_plan_remesh_non_power_of_two_survivors():
    """12 survivors at TP=4: data shrinks to the largest power of two
    (2), the 4 chips that don't fit the mesh are dropped."""
    plan = plan_remesh(12, model=4, global_batch=64)
    assert (plan.data, plan.model, plan.pods) == (2, 4, 1)
    assert plan.chips == 8 and plan.dropped_chips == 4
    assert plan.per_device_batch * plan.data * plan.grad_accum == 64


def test_plan_remesh_pod_fallback_recursion():
    """Survivors below the 2-pod minimum recurse into a 1-pod plan
    rather than failing."""
    plan = plan_remesh(6, model=4, global_batch=32, pods=2)
    assert plan is not None and plan.pods == 1
    assert (plan.data, plan.model) == (1, 4)
    assert plan.dropped_chips == 2
    # and below even the 1-pod minimum there is no plan at all
    assert plan_remesh(3, model=4, global_batch=32, pods=2) is None


def test_plan_remesh_grad_accum_divisibility():
    """base_grad_accum that does not divide the global batch climbs
    until dp_ways * accum does; per-device batch rescales to keep the
    global batch constant."""
    plan = plan_remesh(17, model=4, global_batch=32, base_grad_accum=3)
    assert (plan.data, plan.model) == (4, 4)
    assert plan.grad_accum == 4                  # 32 % (4*3) != 0 -> 4
    assert plan.per_device_batch == 2            # 32 / (4 dp * 4 accum)
    assert plan.per_device_batch * plan.data * plan.grad_accum == 32


# ------------------------------------------- serving segment placement

def test_plan_placement_uniform_and_proportional():
    assert plan_placement([1.0] * 4, 8) == [0, 0, 1, 1, 2, 2, 3, 3]
    # hot segment takes the surplus ranks, every segment keeps >= 1
    hot = plan_placement([10.0, 1.0, 1.0, 1.0], 8)
    counts = np.bincount(hot, minlength=4)
    assert counts[0] > counts[1:].max() and counts.min() >= 1
    # no load signal (all zero) degrades to uniform replicas
    assert plan_placement([0.0, 0.0], 4) == [0, 0, 1, 1]


def test_plan_placement_validation():
    with pytest.raises(ValueError):
        plan_placement([1.0, 1.0, 1.0], 2)       # ranks < segments
    with pytest.raises(ValueError):
        plan_placement([], 4)


def test_plan_placement_move_minimizing_and_idempotent():
    cur = [0, 0, 1, 1, 2, 2, 3, 3]
    new = plan_placement([10.0, 1.0, 1.0, 1.0], 8, current=cur)
    # ranks whose segment keeps quota stay put; only surplus ranks move
    moved = [r for r in range(8) if new[r] != cur[r]]
    assert moved and all(cur[r] != 0 for r in moved)
    # planning again from the same loads changes nothing
    assert plan_placement([10.0, 1.0, 1.0, 1.0], 8, current=new) == new


def test_plan_rebalance_gates_on_skew():
    cur = [0, 0, 1, 1]
    quiet = plan_rebalance(cur, [1.0, 1.0], [1.0, 1.1, 1.0, 0.9],
                           skew_threshold=1.5)
    assert not quiet.fired and quiet.placement == tuple(cur)
    loud = plan_rebalance(cur, [9.0, 1.0], [9.0, 9.0, 1.0, 1.0],
                          skew_threshold=1.5)
    assert loud.fired and loud.skew == pytest.approx(9.0 / 5.0)
    assert set(loud.placement) == {0, 1}         # seg 1 still held
    # applying the fired plan and re-evaluating settled loads is a
    # no-op — the rebalance-idempotence invariant
    again = plan_rebalance(list(loud.placement), [9.0, 1.0],
                           [3.0, 3.0, 3.0, 3.0], skew_threshold=1.5)
    assert not again.fired and again.placement == loud.placement


def test_hlo_analyzer_scan_flops_exact():
    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out
    x = jnp.ones((64, 128), jnp.float32)
    w = jnp.ones((128, 128), jnp.float32)
    txt = jax.jit(scanned).lower(x, w).compile().as_text()
    t = analyze_hlo(txt)
    assert t.flops == pytest.approx(7 * 2 * 64 * 128 * 128)


def test_hlo_analyzer_collectives_synthetic():
    hlo = """
HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main.1 () -> f32[] {
  %x = f32[1024]{0} parameter(0)
  %ag = f32[16384]{0} all-gather(%x), replica_groups=[8,16]<=[128], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), replica_groups=[8,16]<=[128], to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%x), replica_groups=[8,16]<=[128], dimensions={0}
}
"""
    t = analyze_hlo(hlo)
    per = t.per_collective
    assert per["all-gather"]["bytes"] == 16384 * 4 // 16
    assert per["all-reduce"]["bytes"] == 1024 * 4
    assert per["reduce-scatter"]["bytes"] == 64 * 4 * 16


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = quantize(g)
    err = jnp.abs(dequantize(q, s) - g)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_preserves_signal():
    """Sum of dequantized grads + final error == sum of raw grads."""
    rng = np.random.default_rng(1)
    grads = [jnp.asarray(rng.standard_normal(64) * 10 ** i, jnp.float32)
             for i in range(3)]
    errors = ef_init({"g": grads[0]})
    total_sent = jnp.zeros(64)
    total_true = jnp.zeros(64)
    e = errors["g"]
    for g in grads:
        q, s, e = (lambda out: (out[0]["x"], out[1]["x"], out[2]["x"]))(
            compress_with_feedback({"x": g}, {"x": e}))
        total_sent = total_sent + dequantize(q, s)
        total_true = total_true + g
    np.testing.assert_allclose(np.asarray(total_sent + e),
                               np.asarray(total_true), rtol=1e-5,
                               atol=1e-4)


def test_compressed_psum_shardmap():
    """int8 gradient all-reduce under shard_map on a 1-device mesh."""
    from jax.experimental.shard_map import shard_map
    from repro.distributed.compress import compressed_psum
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.arange(8, dtype=jnp.float32)}
    e = ef_init(g)

    def f(g, e):
        return compressed_psum(g, e, "data")

    out, new_e = shard_map(
        f, mesh=mesh,
        in_specs=(PartitionSpec(), PartitionSpec()),
        out_specs=(PartitionSpec(), PartitionSpec()))(g, e)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.arange(8), atol=0.05)
