"""Per-arch smoke tests (assignment requirement): reduced config, one
forward + one train step on CPU, asserting output shapes + no NaNs; plus
decode-path consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SMOKE_CONFIGS
from repro.models import lm

# every test here jit-compiles a model family — ~3 min of the suite's
# ~4.5, and none of it touches the Starling search/IO paths. Runs in
# `make test` and the scheduled full CI lane; skipped by `make test-fast`.
pytestmark = pytest.mark.slow


def _batch(cfg, key, b=2, s=32):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (b, cfg.patch_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.num_mem_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = SMOKE_CONFIGS[arch]
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, _, aux = lm.forward(
        cfg, params, batch["tokens"],
        patch_embeds=batch.get("patch_embeds"),
        frames=batch.get("frames"))
    assert logits.shape == (2, batch["tokens"].shape[1],
                            cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One optimizer step: loss finite, params change, no NaNs."""
    from repro.launch.train import default_optimizer, make_train_step
    from repro.optim import adamw_init
    cfg = SMOKE_CONFIGS[arch]
    key = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, key)
    opt_state = adamw_init(params)
    step = make_train_step(cfg, default_optimizer())
    batch = _batch(cfg, key)
    new_params, new_opt, metrics = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    leaves0 = jax.tree.leaves(params)
    leaves1 = jax.tree.leaves(new_params)
    changed = any(not np.allclose(np.asarray(a), np.asarray(b))
                  for a, b in zip(leaves0, leaves1))
    assert changed
    for leaf in leaves1:
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    """prefill + token-by-token decode == teacher-forced forward."""
    cfg = SMOKE_CONFIGS[arch]
    key = jax.random.PRNGKey(2)
    params = lm.init_params(cfg, key)
    b, s, mx = 2, 16, 24
    batch = _batch(cfg, key, b, s)
    tokens = batch["tokens"]
    kw = {k: v for k, v in batch.items()
          if k in ("patch_embeds", "frames")}
    full, _, _ = lm.forward(cfg, params, tokens, **kw)
    pre = s - 4
    lp, cache = lm.prefill(cfg, params, tokens[:, :pre], mx,
                           cache_dtype=jnp.float32, **kw)
    outs = [lp]
    for t in range(pre, s):
        lg, cache = lm.decode_step(cfg, params, cache, tokens[:, t:t + 1])
        outs.append(lg)
    inc = jnp.concatenate(outs, axis=1)
    diff = jnp.max(jnp.abs(full.astype(jnp.float32)
                           - inc.astype(jnp.float32)))
    scale = jnp.max(jnp.abs(full.astype(jnp.float32))) + 1e-6
    assert float(diff) <= 0.05 * float(scale) + 0.05


def test_grad_accum_equivalence():
    """accum=2 must equal accum=1 up to numerical noise."""
    import dataclasses
    from repro.launch.train import default_optimizer, make_train_step
    from repro.optim import adamw_init
    cfg = SMOKE_CONFIGS["stablelm-3b"]
    key = jax.random.PRNGKey(3)
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key, b=4, s=16)
    opt = default_optimizer()
    p1, _, m1 = make_train_step(cfg, opt)(params, adamw_init(params),
                                          batch)
    cfg2 = dataclasses.replace(cfg, grad_accum=2)
    p2, _, m2 = make_train_step(cfg2, opt)(params, adamw_init(params),
                                           batch)
    assert float(m1["grad_norm"]) == pytest.approx(
        float(m2["grad_norm"]), rel=2e-2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-4)


def test_blockwise_attention_matches_plain():
    from repro.models import layers as Lyr
    rng = np.random.default_rng(0)
    b, sq, h, hkv, hd = 2, 256, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, sq, hkv, h // hkv, hd)),
                    jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sq, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sq, hkv, hd)), jnp.float32)
    pos = jnp.arange(sq)
    for window in (0, 32):
        w = jnp.asarray(window, jnp.int32)
        plain = Lyr._plain_attention(q, k, v, pos, pos, None, w, True)
        block = Lyr._blockwise_attention(q, k, v, pos, pos, None, w, True)
        np.testing.assert_allclose(np.asarray(plain, np.float32),
                                   np.asarray(block, np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_chunked_ce_matches_direct():
    """_chunked_ce == naive CE over full logits."""
    cfg = SMOKE_CONFIGS["minitron-8b"]
    key = jax.random.PRNGKey(4)
    params = lm.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, 1)
    x, _, _ = lm._forward_hidden(cfg, params, tokens)
    ce = lm._chunked_ce(cfg, params, x, labels)
    logits, _, _ = lm.forward(cfg, params, tokens)
    lg = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    naive = jnp.mean(logz - gold)
    assert float(ce) == pytest.approx(float(naive), rel=1e-3)


def test_num_params_analytic_close_to_actual():
    for arch in ("stablelm-3b", "rwkv6-1.6b", "zamba2-1.2b"):
        cfg = SMOKE_CONFIGS[arch]
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
        analytic = cfg.num_params()
        assert abs(actual - analytic) / actual < 0.35, arch


def test_capacity_dispatch_matches_dense_at_full_capacity():
    """§Perf it2: capacity gather dispatch == dense dispatch when the
    per-expert capacity covers every token (no drops). Compared at the
    block level: full-model comparison is chaotic because bf16 noise
    flips later layers' discrete top-k routing decisions."""
    import dataclasses
    from repro.models import layers as Lyr
    cfg = SMOKE_CONFIGS["qwen3-moe-235b-a22b"]
    key = jax.random.PRNGKey(7)
    params = lm.init_params(cfg, key)
    moe_params = jax.tree.map(lambda a: a[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 64, cfg.d_model),
                          jnp.float32)
    cfg_cap = dataclasses.replace(
        cfg, moe_dispatch="capacity",
        moe_capacity_factor=float(cfg.num_experts)
        / cfg.experts_per_token)
    dense, aux1 = Lyr.moe_block(moe_params, x, cfg)
    cap, aux2 = Lyr.moe_block(moe_params, x, cfg_cap)
    np.testing.assert_allclose(np.asarray(dense, np.float32),
                               np.asarray(cap, np.float32),
                               rtol=2e-3, atol=2e-3)
    assert float(aux1) == pytest.approx(float(aux2), rel=1e-4)


def test_capacity_dispatch_trains_with_drops():
    import dataclasses
    cfg = dataclasses.replace(SMOKE_CONFIGS["moonshot-v1-16b-a3b"],
                              moe_dispatch="capacity",
                              moe_capacity_factor=1.25)
    key = jax.random.PRNGKey(8)
    params = lm.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    loss, _ = lm.loss_fn(cfg, params, batch)
    g = jax.grad(lambda p: lm.loss_fn(cfg, p, batch)[0])(params)
    assert bool(jnp.isfinite(loss))
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())
