"""Host search-path tests: ANNS recall, RS, baseline comparison, I/O
accounting (§5, §6.2, §6.3)."""
import dataclasses

import numpy as np
import pytest

from repro.core import baseline as B
from repro.core import distances as D
from repro.core.iostats import IOStats
from repro.core.search import (_CandidateSet, anns, average_precision,
                               block_search_query, range_search,
                               recall_at_k)


@pytest.fixture(scope="module")
def truth(small_data):
    x, q = small_data
    return D.brute_force_knn(x, q, 10)


def test_anns_recall_floor(small_segment, small_data, truth):
    x, q = small_data
    ids, _, stats = anns(small_segment.view, q, 10,
                         small_segment.params.search)
    assert recall_at_k(ids, truth) >= 0.85
    assert all(s.block_reads > 0 for s in stats)


def test_block_search_beats_vertex_baseline_io(small_segment, small_data,
                                               truth):
    """Tab. 2: Starling's vertex utilization is far above the baseline's
    1/eps, and recall is comparable at the same candidate budget."""
    x, q = small_data
    seg = small_segment
    ids_s, _, st_s = anns(seg.view, q, 10, seg.params.search)
    p_base = dataclasses.replace(seg.params.search, use_block_search=False,
                                 use_nav_graph=False)
    ids_b, _, st_b = B.vertex_anns(seg.view, q, 10, p_base)
    xi_s = np.mean([s.vertex_utilization for s in st_s])
    xi_b = np.mean([s.vertex_utilization for s in st_b])
    eps = seg.view.store.verts_per_block
    assert xi_b == pytest.approx(1.0 / eps, abs=0.02)
    assert xi_s > 2.0 * xi_b
    assert recall_at_k(ids_s, truth) >= recall_at_k(ids_b, truth) - 0.05


def test_nav_graph_shortens_path(small_segment, small_data):
    """Fig. 10: query-aware entry points cut hops/IOs."""
    x, q = small_data
    seg = small_segment
    p_on = seg.params.search
    p_off = dataclasses.replace(p_on, use_nav_graph=False)
    _, _, st_on = anns(seg.view, q, 10, p_on)
    _, _, st_off = anns(seg.view, q, 10, p_off)
    hops_on = np.mean([s.hops for s in st_on])
    hops_off = np.mean([s.hops for s in st_off])
    assert hops_on <= hops_off * 1.05


def test_range_search_ap(small_segment, small_data):
    x, q = small_data
    d_gt = D.pairwise(q, x)
    radius = float(np.quantile(d_gt, 0.002))
    gt = D.brute_force_range(x, q, radius)
    res, stats = range_search(seg := small_segment.view, q, radius,
                              small_segment.params.search)
    # all returned results must truly be in range (AP definition Eq. 3)
    for r, qi in zip(res, range(q.shape[0])):
        if r.size:
            dd = D.point_to_points(q[qi], x[r])
            assert (dd <= radius + 1e-4).all()
    ap = average_precision(res, gt)
    assert ap >= 0.7


def test_rs_resume_does_not_reexpand_blocks(small_segment, small_data):
    """Regression (PR 2): the RS driver threads the ``expanded`` set
    through resumes. Reseeding an already-expanded vertex (what §5.3
    step 4 does with kicked vertices) must not re-read its block —
    before the fix every round rebuilt ``expanded`` empty and
    ``block_reads`` re-counted prior rounds' expansions."""
    x, q = small_data
    seg = small_segment
    p = seg.params.search
    st = IOStats()
    C = _CandidateSet(p.candidate_size)
    R, P, E = {}, [], set()
    block_search_query(seg.view, q[0], k=1, p=p, cand=C, result=R,
                       kicked=P, expanded=E, stats=st)
    assert E, "first round expanded nothing"
    reads_round1 = st.block_reads
    # reseed every expanded vertex still in C as unvisited — exactly the
    # state a kicked-then-reseeded vertex comes back in
    reseeded = 0
    for i, vid in enumerate(C.ids):
        if vid in E and C.visited[i]:
            C.visited[i] = False
            reseeded += 1
    assert reseeded > 0
    block_search_query(seg.view, q[0], k=1, p=p, cand=C, result=R,
                       kicked=P, expanded=E, stats=st)
    assert st.block_reads == reads_round1, (
        "resumed round re-read blocks of already-expanded vertices")


def test_rs_cheaper_than_repeated_anns(small_segment, small_data):
    """§5.3: native RS avoids the baseline's repeated re-traversal."""
    x, q = small_data
    d_gt = D.pairwise(q, x)
    radius = float(np.quantile(d_gt, 0.004))
    seg = small_segment
    _, st_rs = range_search(seg.view, q, radius, seg.params.search)
    p_base = dataclasses.replace(seg.params.search,
                                 use_block_search=False,
                                 use_nav_graph=False)
    _, st_rep = B.vertex_range_search(seg.view, q, radius, p_base)
    io_rs = np.mean([s.block_reads for s in st_rs])
    io_rep = np.mean([s.block_reads for s in st_rep])
    assert io_rs < io_rep


def test_pq_routing_reduces_io(small_segment, small_data):
    """Fig. 11(c): exact-distance routing costs far more block reads."""
    x, q = small_data
    seg = small_segment
    p_pq = seg.params.search
    p_exact = dataclasses.replace(p_pq, use_pq_routing=False)
    _, _, st_pq = anns(seg.view, q[:6], 10, p_pq)
    _, _, st_ex = anns(seg.view, q[:6], 10, p_exact)
    io_pq = np.mean([s.block_reads for s in st_pq])
    io_ex = np.mean([s.block_reads for s in st_ex])
    assert io_pq < io_ex


def test_hot_cache_reduces_baseline_io(small_segment, small_data):
    x, q = small_data
    seg = small_segment
    p = dataclasses.replace(seg.params.search, use_block_search=False,
                            use_nav_graph=False)
    hot = B.build_hot_cache(seg.view, ratio=0.2)
    _, _, st_cold = B.vertex_anns(seg.view, q, 10, p)
    _, _, st_hot = B.vertex_anns(seg.view, q, 10, p, hot=hot)
    assert (np.mean([s.block_reads for s in st_hot])
            <= np.mean([s.block_reads for s in st_cold]))


def test_iostats_latency_model(small_segment, small_data):
    from repro.core.iostats import NVME_SEGMENT, TPU_HBM_SEGMENT
    x, q = small_data
    _, _, stats = anns(small_segment.view, q[:4], 10,
                       small_segment.params.search)
    s = stats[0]
    for cm in (NVME_SEGMENT, TPU_HBM_SEGMENT):
        serial = cm.latency_us(s, pipeline=False)
        piped = cm.latency_us(s, pipeline=True)
        assert piped <= serial
        br = cm.breakdown(s)
        assert br["total_us"] == pytest.approx(serial, rel=1e-6)
