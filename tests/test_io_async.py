"""repro.io.async_fetch + TieredBlockCache: event-clock queue semantics,
tier invariants, occupancy pricing, and the bit-identical guarantee of
the async + tiered search path (PR 2)."""
import dataclasses

import numpy as np
import pytest

from repro.core.iostats import IOStats, NVME_SEGMENT
from repro.core.params import CacheParams
from repro.core.search import anns
from repro.io import (AsyncFetchQueue, BlockCache, CachedBlockStore,
                      TieredBlockCache, cached_view, make_cached_store)
from repro.io.async_fetch import SERVICE_TICKS

KB = 1024


def _wrap(seg, cp: CacheParams, **kw):
    return cached_view(seg.view, seg.graph, cp, **kw)


# ------------------------------------------------------- AsyncFetchQueue

def test_queue_submit_wait_delivers_in_completion_order():
    # jitter forces completion order 2, 1, 3 regardless of submit order
    jit = {1: 10.0, 2: 0.0, 3: 20.0}
    q = AsyncFetchQueue(depth=4, jitter_fn=lambda b: jit[b])
    t1, o1 = q.submit(1, "demand")
    t2, o2 = q.submit(2)
    t3, o3 = q.submit(3)
    assert (o1, o2, o3) == (1, 2, 3)
    assert q.inflight_peak == 3
    done = q.wait(t3)
    assert [t.block for t in done] == [2, 1, 3]
    # 2 overtook 1 (and 1 overtook nothing still outstanding at its turn)
    assert done[0].reordered and q.reorders >= 1
    assert len(q) == 0 and q.delivered == 3


def test_queue_dedups_inflight_and_prices_residual():
    q = AsyncFetchQueue(depth=4, jitter_fn=lambda b: 0.0)
    t, _ = q.submit(7, "demand")
    assert q.in_flight(7) and q.get(7) is t
    with pytest.raises(ValueError):
        q.submit(7)                      # joins must go through get()
    r = t.residual(q.clock)
    assert 0.0 < r <= 1.0                # service still outstanding
    q.wait(t)
    assert t.residual(q.clock) == 0.0    # delivered → nothing left to wait

def test_queue_depth_bounds_inflight():
    q = AsyncFetchQueue(depth=2, jitter_fn=lambda b: 0.0)
    q.submit(1)
    q.submit(2)
    assert q.free_slots == 0
    with pytest.raises(ValueError):
        q.submit(3)
    q.wait_any()                         # make room
    assert q.free_slots >= 1
    q.submit(3)
    assert q.inflight_peak == 2


def test_queue_drain_empties():
    q = AsyncFetchQueue(depth=8)
    for b in range(5):
        q.submit(b)
    out = q.drain()
    assert sorted(t.block for t in out) == list(range(5))
    assert len(q) == 0


# ------------------------------------------------------ TieredBlockCache

def test_tier2_admit_on_tier1_evict():
    c = TieredBlockCache(tier1_bytes=2 * KB, tier2_bytes=KB,
                         block_bytes=KB, compression=16)
    c.admit(1)
    c.admit(2)
    c.admit(3)                           # evicts 1 from t1 → demotes to t2
    assert 1 in c.tier2 and 1 not in c.tier1
    assert c.lookup_tier(2) == 1 and c.lookup_tier(3) == 1
    assert c.tier2_admits >= 1


def test_tier2_hit_promotes_to_tier1():
    c = TieredBlockCache(tier1_bytes=2 * KB, tier2_bytes=KB,
                         block_bytes=KB, compression=16)
    for b in (1, 2, 3):
        c.admit(b)
    assert c.lookup_tier(1) == 2         # summary hit
    assert 1 in c.tier1 and 1 not in c.tier2
    assert c.tier2_promotions == 1
    # the promotion displaced a t1 resident into t2
    assert len(c.tier1) <= c.tier1.capacity_blocks


def test_tier2_capacity_is_compressed():
    c = TieredBlockCache(tier1_bytes=KB, tier2_bytes=KB,
                         block_bytes=KB, compression=16)
    assert c.tier2.capacity_blocks == 16 * c.tier1.capacity_blocks
    assert c.memory_bytes() == 2 * KB    # Eq. 10: both budgets reserved


def test_tiered_pinned_never_evicted():
    c = TieredBlockCache(tier1_bytes=2 * KB, tier2_bytes=KB,
                         block_bytes=KB, pinned=[42])
    for b in range(60):
        c.lookup_tier(b)
        c.admit(b)
    assert 42 in c.tier1
    assert len(c.tier1) <= c.tier1.capacity_blocks
    assert len(c.tier2) <= c.tier2.capacity_blocks


def test_block_never_resident_in_both_tiers():
    c = TieredBlockCache(tier1_bytes=2 * KB, tier2_bytes=2 * KB,
                         block_bytes=KB, compression=2)
    for b in (1, 2, 3, 4, 1, 2, 5):      # mix of misses, hits, promotions
        c.lookup_tier(b)
        c.admit(b)
    both = {b for b in range(8) if b in c.tier1 and b in c.tier2}
    assert both == set()


# -------------------------------------------------- accounting + pricing

def test_occupancy_pricing_amortizes_with_depth():
    """Async speculative fetches: Σ1/o serial share — a deep queue
    (small occ weight) must price below a shallow one (large weight)."""
    base = dict(block_reads=10, cache_misses=10, io_round_trips=10,
                queue_fetches=18)
    shallow = IOStats(**base, queue_occ_weight=8.0)   # o ≈ 1
    deep = IOStats(**base, queue_occ_weight=1.5)      # o ≈ 5–8
    assert NVME_SEGMENT._io_time(deep) < NVME_SEGMENT._io_time(shallow)
    # shallow degrades to (at most) the flat synchronous price
    flat = IOStats(block_reads=10, cache_misses=10, io_round_trips=10,
                   prefetched_blocks=8)
    assert NVME_SEGMENT._io_time(shallow) == pytest.approx(
        NVME_SEGMENT._io_time(flat))


def test_tier2_hit_cheaper_than_miss_dearer_than_tier1():
    cm = NVME_SEGMENT
    t1 = IOStats(block_reads=1, cache_hits=1)
    t2 = IOStats(block_reads=1, tier2_hits=1)
    miss = IOStats(block_reads=1, cache_misses=1, io_round_trips=1)
    assert (cm._io_time(t1) < cm._io_time(t2) < cm._io_time(miss))


def test_join_prices_residual_not_full_trip():
    cm = NVME_SEGMENT
    join = IOStats(block_reads=1, cache_misses=1, inflight_joins=1,
                   join_residual=0.5)
    cold = IOStats(block_reads=1, cache_misses=1, io_round_trips=1)
    assert cm._io_time(join) == pytest.approx(0.5 * cm.t_block_io)
    assert cm._io_time(join) < cm._io_time(cold)


def test_merge_maxes_inflight_peak_and_adds_async_counters():
    a = IOStats(block_reads=2, cache_misses=2, io_round_trips=2,
                inflight_peak=3, completion_reorders=1, tier2_hits=0,
                queue_occ_weight=0.5)
    b = IOStats(block_reads=1, tier2_hits=1, inflight_peak=5,
                completion_reorders=2, queue_occ_weight=0.25)
    a.merge(b)
    assert a.inflight_peak == 5                      # max, not sum
    assert a.completion_reorders == 3
    assert a.queue_occ_weight == pytest.approx(0.75)
    assert a.tier2_hits == 1
    assert a.cache_hit_rate == pytest.approx(1 / 3)  # t2 counts as hit


# --------------------------------------------- async search integration

@pytest.fixture(scope="module")
def async_view(small_segment):
    return _wrap(small_segment,
                 CacheParams(budget_frac=0.15, policy="lru",
                             pin_fraction=0.25, prefetch_width=4,
                             tier2_frac=0.25, queue_depth=8))


def test_async_tiered_search_identical_to_uncached(async_view,
                                                   small_segment,
                                                   small_data):
    _, q = small_data
    p = small_segment.params.search
    ids_u, dd_u, _ = anns(small_segment.view, q, 10, p)
    ids_a, dd_a, _ = anns(async_view, q, 10, p)
    np.testing.assert_array_equal(ids_u, ids_a)
    np.testing.assert_allclose(dd_u, dd_a)


def test_async_accounting_invariants(async_view, small_segment,
                                     small_data):
    _, q = small_data
    _, _, stats = anns(async_view, q, 10, small_segment.params.search)
    merged = IOStats()
    for s in stats:
        assert s.block_reads == (s.cache_hits + s.tier2_hits
                                 + s.cache_misses)
        assert s.io_round_trips <= s.block_reads    # enforced in merge too
        assert s.inflight_joins <= s.cache_misses
        assert s.inflight_peak <= async_view.store.queue.depth
        merged.merge(s)
    assert merged.tier2_hits > 0
    assert merged.queue_fetches > 0
    assert 0.0 < merged.cache_hit_rate < 1.0


def test_async_never_fetches_twice(small_segment, small_data):
    """Eviction-free budget: every block goes to 'disk' at most once,
    whether by demand submission or speculative in-flight fetch."""
    _, q = small_data
    view = _wrap(small_segment,
                 CacheParams(budget_frac=1.0, prefetch_width=4,
                             queue_depth=8),
                 record_fetches=True)
    anns(view, q, 10, small_segment.params.search)
    view.store.queue.drain()
    blocks = [b for _, b in view.store.fetch_log]
    assert len(blocks) == len(set(blocks))
    assert any(k == "prefetch" for k, _ in view.store.fetch_log)


def test_cross_query_join_of_inflight_fetch(small_segment):
    """The serving-plane dedup seam: a demand read of a block another
    query left in flight joins the ticket — no new round trip."""
    store = make_cached_store(small_segment.view.store,
                              CacheParams(budget_frac=0.5,
                                          prefetch_width=0,
                                          queue_depth=8))
    q = store.queue
    # "another query's" speculation, submitted under the store's key
    q.submit(11, kind="speculative", key=store._key(11), owner=store)
    s = IOStats()
    store.read_demand(11, s)
    assert s.inflight_joins == 1 and s.io_round_trips == 0
    assert s.cache_misses == 1           # it did miss the cache
    assert 0.0 < s.join_residual <= 1.0
    # block was admitted on delivery: a re-read is now a cache hit
    s2 = IOStats()
    store.read_demand(11, s2)
    assert s2.cache_hits == 1


def test_shared_queue_keeps_store_namespaces_apart(small_segment):
    """Equal block ids of DIFFERENT backing stores must not conflate on
    a shared queue: no bogus joins, and each store's fetch lands in its
    own cache."""
    base1 = small_segment.view.store
    base2 = dataclasses.replace(base1)   # distinct store, same shapes
    cp = CacheParams(budget_frac=0.5, prefetch_width=0, queue_depth=8)
    s1 = make_cached_store(base1, cp, record_fetches=True)
    s2 = make_cached_store(base2, cp, record_fetches=True)
    s2.attach_queue(s1.queue)            # share one queue
    q = s1.queue
    q.submit(7, kind="speculative", key=s1._key(7), owner=s1)
    st = IOStats()
    s2.read_demand(7, st)                # other store's block 7
    assert st.inflight_joins == 0        # different namespace: no join
    assert st.io_round_trips == 1        # a real fetch of its own
    # s2's demand wait advanced the clock past s1's earlier-submitted
    # speculation: that ticket delivered into its OWNER's cache (the
    # owner-aware delivery seam), never into s2's accounting as a join
    assert 7 in s1.cache and 7 in s2.cache
    s1_stats = IOStats()
    s1.read_demand(7, s1_stats)          # its own copy: plain hit
    assert s1_stats.cache_hits == 1 and s1_stats.io_round_trips == 0
    # never-fetch-twice per store: block 7 went to disk once per store
    assert s2.fetch_log == [("miss", 7)]


def test_joined_ticket_admits_into_both_caches(small_segment):
    """Two views over the SAME base dedup in flight — and the joiner
    must end up with the block resident too, or it re-fetches."""
    base = small_segment.view.store
    cp = CacheParams(budget_frac=0.5, prefetch_width=0, queue_depth=8)
    s1 = make_cached_store(base, cp)
    s2 = make_cached_store(base, cp)
    s2.attach_queue(s1.queue)
    s1.queue.submit(5, kind="speculative", key=s1._key(5), owner=s1)
    st = IOStats()
    s2.read_demand(5, st)                # same base: genuine join
    assert st.inflight_joins == 1 and st.io_round_trips == 0
    assert 5 in s1.cache                 # submitter got its delivery
    assert 5 in s2.cache                 # joiner admitted the payload
    st2 = IOStats()
    s2.read_demand(5, st2)
    assert st2.cache_hits == 1           # no re-fetch


def test_attach_queue_drains_private_inflight(small_segment):
    """Replacing a store's private queue must deliver its outstanding
    fetches, not orphan them (they'd be silently re-fetched later)."""
    base = small_segment.view.store
    cp = CacheParams(budget_frac=0.5, prefetch_width=0, queue_depth=8)
    s = make_cached_store(base, cp, record_fetches=True)
    old = s.queue
    old.submit(3, kind="speculative", key=s._key(3), owner=s)
    assert 3 not in s.cache              # still in flight
    s.attach_queue(AsyncFetchQueue(depth=8))
    assert len(old) == 0                 # drained...
    assert 3 in s.cache                  # ...and delivered, not dropped
    st = IOStats()
    s.read_demand(3, st)                 # no re-fetch after the switch
    assert st.cache_hits == 1 and st.io_round_trips == 0


def test_fully_pinned_tier1_falls_back_to_tier2():
    """A tier 1 with no evictable victim (all pinned) must summarize
    fetched blocks into tier 2 instead of dropping them — the tier-2
    budget is charged into Eq. 10 and must be usable."""
    c = TieredBlockCache(tier1_bytes=2 * KB, tier2_bytes=4 * KB,
                         block_bytes=KB, compression=4, pinned=[100, 101])
    assert not c.tier1.can_admit(5)
    c.admit(5)
    assert 5 in c.tier2                  # not lost
    assert c.lookup_tier(5) == 2         # served without a disk trip...
    assert 5 in c.tier2 and 5 not in c.tier1   # ...and NOT promoted out
    assert 100 in c.tier1 and 101 in c.tier1


def test_shared_queue_across_servers(small_segment, small_data):
    from repro.serving import (HostSegmentServer, QueryCoordinator,
                               attach_shared_fetch_queue)
    _, q = small_data
    views = [_wrap(small_segment,
                   CacheParams(budget_frac=0.2, prefetch_width=4,
                               tier2_frac=0.25, queue_depth=8))
             for _ in range(2)]
    servers = [HostSegmentServer(view=v,
                                 params=small_segment.params.search,
                                 offset=off,
                                 num_vectors=small_segment.num_vectors)
               for v, off in zip(views, (0, small_segment.num_vectors))]
    shared = attach_shared_fetch_queue(servers, depth=8)
    assert all(s.view.store.queue is shared for s in servers)
    coord = QueryCoordinator(servers)
    _, _, stats = coord.search(q[:8], k=10)
    assert shared.submitted > 0
    assert stats["cache_hits"] + stats["cache_misses"] > 0
    tot = IOStats()
    for s in servers:
        tot.merge(s.view.store.total)    # merge invariant across servers
    assert tot.io_round_trips <= tot.block_reads


# ---------------------------------------------- permutation determinism
# (the hypothesis-driven generalizations live in test_io_props.py, which
# skips wholesale when hypothesis is absent — these stay always-on)

@pytest.mark.parametrize("salt", [0, 3, 7])
def test_completion_permutations_leave_results_identical(
        salt, small_segment, small_data):
    """Different jitter seeds permute completion order (reorder counts
    differ) but search ids/dists are bit-identical: delivery timing only
    moves residency and counters, never payloads."""
    _, q = small_data
    p = small_segment.params.search
    ids_u, dd_u, _ = anns(small_segment.view, q[:6], 10, p)
    queue = AsyncFetchQueue(depth=8, jitter_salt=salt)
    view = _wrap(small_segment,
                 CacheParams(budget_frac=0.15, prefetch_width=4,
                             tier2_frac=0.25, queue_depth=8),
                 queue=queue)
    ids, dd, _ = anns(view, q[:6], 10, p)
    np.testing.assert_array_equal(ids_u, ids)
    np.testing.assert_allclose(dd_u, dd)
