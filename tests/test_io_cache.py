"""repro.io: block cache, cached store, batched prefetch (Eq. 4/Eq. 10
accounting; caching must never change search results)."""
import dataclasses

import numpy as np
import pytest

from repro.core import distances as D
from repro.core.iostats import IOStats, NVME_SEGMENT
from repro.core.params import CacheParams
from repro.core.search import anns, recall_at_k
from repro.io import (BlockCache, CachedBlockStore, LFUPolicy, LRUPolicy,
                      PrefetchEngine, cached_view, hot_block_pin_set,
                      make_cached_store)
from tests.conftest import SMALL_SEGMENT


def _wrap(seg, cp: CacheParams, record_fetches: bool = False):
    return cached_view(seg.view, seg.graph, cp,
                       record_fetches=record_fetches)


@pytest.fixture(scope="module")
def cached_small_view(small_segment):
    return _wrap(small_segment,
                 CacheParams(budget_frac=0.15, policy="lru",
                             pin_fraction=0.25, prefetch_width=4))


# ------------------------------------------------------------ BlockCache

def test_lru_eviction_order():
    c = BlockCache(capacity_bytes=3 * 1024, block_bytes=1024, policy="lru")
    for b in (1, 2, 3):
        assert not c.lookup(b)
        c.admit(b)
    assert c.lookup(1)            # 1 becomes most-recent; LRU is now 2
    c.admit(4)
    assert 2 not in c and 1 in c and 3 in c and 4 in c
    assert c.evictions == 1


def test_lfu_eviction_prefers_cold_blocks():
    c = BlockCache(capacity_bytes=3 * 1024, block_bytes=1024, policy="lfu")
    for b in (1, 2, 3):
        c.admit(b)
    for _ in range(3):
        c.lookup(1)
    c.lookup(3)
    c.admit(4)                    # 2 has the lowest frequency
    assert 2 not in c and 1 in c and 3 in c and 4 in c


def test_pinned_blocks_never_evicted():
    c = BlockCache(capacity_bytes=2 * 1024, block_bytes=1024, policy="lru",
                   pinned=[7])
    assert 7 in c                 # preloaded at build time
    for b in range(20):
        c.lookup(b)
        c.admit(b)
    assert 7 in c
    assert len(c) <= c.capacity_blocks


def test_zero_budget_cache_never_hits():
    c = BlockCache(capacity_bytes=0, block_bytes=1024)
    c.admit(1)
    assert not c.lookup(1) and len(c) == 0


def test_hot_pin_set_covers_seed_blocks(small_segment):
    seg = small_segment
    lay, g = seg.view.layout, seg.graph
    seeds = seg.view.nav.sample_ids[:8]
    pins = hot_block_pin_set(lay.block_of, g.adj, g.deg, seeds,
                             max_blocks=1000)
    seed_blocks = {int(lay.block_of[v]) for v in seeds}
    assert seed_blocks <= set(pins)


# ------------------------------------------------- accounting invariants

def test_repack_from_frequencies_orders_by_observed_traffic():
    """ISSUE 4 satellite: observed blocks lead, by count desc (ties by
    build-ranking position), then the untouched build tail in order;
    an empty observation is the identity."""
    from repro.io.hotset import repack_from_frequencies
    ranking = [7, 3, 9, 1, 4]
    assert repack_from_frequencies(ranking, {}) == ranking
    got = repack_from_frequencies(ranking, {1: 5, 9: 5, 4: 2, 12: 9,
                                            3: 0})
    # 12 (count 9) first; 9 before 1 at equal count (earlier in build
    # ranking); 3 had zero observations -> stays in the tail, in order
    assert got == [12, 9, 1, 4, 7, 3]


def test_cached_store_tracks_block_frequencies(small_segment):
    """Every demand read lands in block_freq — the observed-traffic
    feed for the dynamic tier-0 repack."""
    store = make_cached_store(small_segment.view.store,
                              CacheParams(budget_frac=0.1))
    store.read_block(3)
    store.read_block(3)
    store.read_demand(5, IOStats())
    assert store.block_freq[3] == 2 and store.block_freq[5] == 1
    assert 4 not in store.block_freq


def test_hit_miss_accounting_invariant(cached_small_view, small_segment,
                                       small_data):
    _, q = small_data
    _, _, stats = anns(cached_small_view, q, 10,
                        small_segment.params.search)
    merged = IOStats()
    for s in stats:
        assert s.block_reads == s.cache_hits + s.cache_misses
        assert s.io_round_trips <= s.block_reads
        assert s.io_round_trips >= 1 and s.block_reads >= 1
        merged.merge(s)
    assert merged.block_reads == merged.cache_hits + merged.cache_misses
    assert 0.0 < merged.cache_hit_rate < 1.0
    total = cached_small_view.store.total
    assert total.block_reads >= merged.block_reads  # lifetime ≥ this batch


def test_merge_rejects_excess_round_trips():
    a = IOStats(block_reads=2, io_round_trips=2)
    with pytest.raises(ValueError):
        a.merge(IOStats(block_reads=0, io_round_trips=1))


def test_cached_search_identical_to_uncached(cached_small_view, small_segment,
                                             small_data):
    """The cache is transparent: exact same ids and distances."""
    _, q = small_data
    p = small_segment.params.search
    ids_u, dd_u, _ = anns(small_segment.view, q, 10, p)
    ids_c, dd_c, _ = anns(cached_small_view, q, 10, p)
    np.testing.assert_array_equal(ids_u, ids_c)
    np.testing.assert_allclose(dd_u, dd_c)


def test_prefetch_never_fetches_twice(small_segment, small_data):
    """With an eviction-free budget every block reaches 'disk' at most
    once, whether by demand miss or speculative prefetch."""
    _, q = small_data
    view = _wrap(small_segment,
                 CacheParams(budget_frac=1.0, prefetch_width=4),
                 record_fetches=True)
    anns(view, q, 10, small_segment.params.search)
    log = view.store.fetch_log
    blocks = [b for _, b in log]
    assert len(blocks) == len(set(blocks))
    assert any(kind == "prefetch" for kind, _ in log)


def test_prefetch_engine_targets_top_unvisited(small_segment):
    store = make_cached_store(small_segment.view.store,
                              CacheParams(budget_frac=0.5,
                                          prefetch_width=2))
    block_of = small_segment.view.layout.block_of
    eng = PrefetchEngine(store, block_of)

    class Cand:
        ids = [5, 9, 17, 23]
        visited = [True, False, False, False]
    t1 = eng.targets(Cand)
    assert len(t1) <= 2
    assert int(block_of[5]) not in t1        # visited candidate skipped
    t2 = eng.targets(Cand)                   # same query: nothing re-issued
    assert not set(t1) & set(t2)
    # the engine is per-query by construction: a fresh engine (what
    # block_search_query builds) starts with a clean issued set
    fresh = PrefetchEngine(store, block_of)
    assert fresh.issued == set()
    assert set(fresh.targets(Cand)) == set(t1)


# ----------------------------------------------------------- cost model

def test_cost_model_prices_hits_at_memory_latency():
    miss_only = IOStats(block_reads=10, cache_misses=10, io_round_trips=10,
                        hops=10)
    half_hits = IOStats(block_reads=10, cache_hits=5, cache_misses=5,
                        io_round_trips=5, hops=10)
    lat_miss = NVME_SEGMENT.latency_us(miss_only)
    lat_hits = NVME_SEGMENT.latency_us(half_hits)
    assert lat_hits < lat_miss
    # untracked stats price like all-miss (seed behavior unchanged)
    legacy = IOStats(block_reads=10, hops=10)
    assert NVME_SEGMENT.latency_us(legacy) == pytest.approx(lat_miss)


def test_coalesced_prefetch_cheaper_than_extra_trips():
    s = IOStats(block_reads=10, cache_hits=4, cache_misses=6,
                io_round_trips=6, prefetched_blocks=8)
    batched = NVME_SEGMENT._io_time(s)
    unbatched = ((s.cache_misses + s.prefetched_blocks)
                 * NVME_SEGMENT.t_block_io)
    assert batched < unbatched


def test_speculative_only_trip_pays_full_first_block():
    """A round trip with no demand miss — a cache hit whose prefetch
    targets forced the trip — prices its first block at t_block_io:
    the trip cannot be cheaper than the queue submission it models."""
    cm = NVME_SEGMENT
    s = IOStats(block_reads=1, cache_hits=1, io_round_trips=1,
                prefetched_blocks=3)
    want = (cm.t_cache_hit + cm.t_block_io + 2 * cm.t_batch_block)
    assert cm._io_time(s) == pytest.approx(want)
    # with a demand miss on the trip, the speculative blocks all ride
    # at t_batch_block — the miss already paid the round trip
    s2 = IOStats(block_reads=1, cache_misses=1, io_round_trips=1,
                 prefetched_blocks=3)
    want2 = cm.t_block_io + 3 * cm.t_batch_block
    assert cm._io_time(s2) == pytest.approx(want2)


def test_device_dedup_pricing():
    """ISSUE 4: a cold touch that joined another query's same-round
    gather prices at t_dedup_hit (VMEM broadcast), not t_block_io —
    and from_device keeps the trips <= reads invariant under dedup."""
    cm = NVME_SEGMENT
    s = IOStats.from_device(10, tier0_hits=2, hops=8, dedup_saved=4,
                            rounds=16)
    assert s.block_reads == 12 and s.cache_misses == 10
    assert s.io_round_trips == 6          # only 10 - 4 DMAs issued
    assert s.dedup_saved_fetches == 4
    assert s.rounds_active_weight == pytest.approx(0.5)
    want = (6 * cm.t_block_io + 4 * cm.t_dedup_hit
            + 2 * cm.t_tier0_hit)
    assert cm._io_time(s) == pytest.approx(want)
    # merge stays additive and valid
    s2 = IOStats.from_device(3, dedup_saved=1, hops=3, rounds=16)
    s.merge(s2)
    assert s.dedup_saved_fetches == 5
    assert s.io_round_trips <= s.block_reads
    # saved can never exceed the cold touches it joins
    s3 = IOStats.from_device(2, dedup_saved=5)
    assert s3.dedup_saved_fetches == 2 and s3.io_round_trips == 0


def test_hit_plus_prefetch_issues_priced_trip(small_segment):
    """End to end: read_demand on a HIT with prefetch targets issues one
    round trip whose pricing includes a full t_block_io."""
    store = make_cached_store(small_segment.view.store,
                              CacheParams(budget_frac=1.0,
                                          prefetch_width=4))
    s = IOStats()
    store.read_demand(3, s)                    # miss: admits block 3
    s = IOStats()
    store.read_demand(3, s, prefetch=[5, 7])   # hit + speculative trip
    assert s.cache_hits == 1 and s.cache_misses == 0
    assert s.io_round_trips == 1 and s.prefetched_blocks == 2
    t = NVME_SEGMENT._io_time(s)
    assert t >= NVME_SEGMENT.t_block_io       # first spec block full price


# ----------------------------------------------- segment integration

@pytest.fixture(scope="module")
def tiny_cached_segment():
    from repro.core.segment import build_segment
    from repro.data.vectors import clustered_vectors
    x = clustered_vectors(600, 16, num_clusters=8, seed=2)
    p = dataclasses.replace(
        SMALL_SEGMENT,
        cache=CacheParams(budget_frac=0.2, policy="lfu",
                          pin_fraction=0.5, prefetch_width=2))
    return build_segment(x, p), x


def test_build_segment_charges_cache_against_eq10(tiny_cached_segment):
    seg, x = tiny_cached_segment
    store = seg.view.store
    assert isinstance(store, CachedBlockStore)
    uncached = dataclasses.replace(seg, view=dataclasses.replace(
        seg.view, store=store.base))
    assert (seg.memory_bytes()
            == uncached.memory_bytes() + store.memory_bytes())
    assert store.memory_bytes() == store.cache.capacity_bytes
    assert seg.check_budget()["memory_ok"]


def test_cached_segment_save_load_roundtrip(tiny_cached_segment, tmp_path):
    from repro.core.segment import load_segment, save_segment
    seg, x = tiny_cached_segment
    path = str(tmp_path / "seg.npz")
    save_segment(seg, path)
    seg2 = load_segment(path, seg.params)
    assert isinstance(seg2.view.store, CachedBlockStore)
    q = x[:4] + 0.01
    ids1, _, _ = anns(seg.view, q, 5, seg.params.search)
    ids2, _, _ = anns(seg2.view, q, 5, seg.params.search)
    np.testing.assert_array_equal(ids1, ids2)


def test_shared_cache_warms_across_batches(small_segment, small_data):
    """Serving plane: one cache per segment server — the second batch
    benefits from blocks resident after the first."""
    from repro.serving import HostSegmentServer, QueryCoordinator
    _, q = small_data
    view = _wrap(small_segment,
                 CacheParams(budget_frac=0.3, prefetch_width=4))
    server = HostSegmentServer(view=view,
                               params=small_segment.params.search,
                               offset=0,
                               num_vectors=small_segment.num_vectors)
    coord = QueryCoordinator([server])
    _, _, stats1 = coord.search(q[:12], k=10)
    rate1 = stats1["cache_hit_rate"]
    _, _, stats2 = coord.search(q[:12], k=10)   # identical batch, warm
    assert stats2["cache_hit_rate"] > rate1
    assert stats2["cache_hits"] > stats1["cache_hits"]
