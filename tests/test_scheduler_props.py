"""Property tests for the repack scheduler (ISSUE 5 satellite).

Guarded hypothesis import, matching test_batch_props/test_io_props:
the whole module skips when hypothesis is absent; deterministic twins
of every property live in test_scheduler.py and always run.

Properties:

  * ANY observed-frequency map leaves ``(ids, dists)`` bit-identical
    across a repack — the pack holds exact copies, frequencies only
    steer which blocks get them (batch pinned to one compiled shape);
  * planning is idempotent at fixed frequencies: the pack a repack
    selects, re-planned under the same window, is itself (drift 0);
  * hysteresis: a window whose plan changes fewer than ``hysteresis x
    H`` slots fires zero repacks and leaves the pack arrays untouched.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; rest of the suite runs without")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import device_search as DS
from repro.core.params import DeviceSearchParams, RepackParams
from repro.io import hotset
from repro.serving import RepackScheduler, SegmentServer

BATCH = 8
P_PROP = DeviceSearchParams(k=5, candidates=24, max_hops=48,
                            fetch_width=2)

freq_maps = st.dictionaries(st.integers(0, 300), st.integers(1, 1000),
                            max_size=24)


@pytest.mark.slow
@given(observed=freq_maps)
@settings(max_examples=6, deadline=None)
def test_repack_never_changes_results(observed, small_segment,
                                      small_data):
    _, q = small_data
    qb = jnp.asarray(q[:BATCH])
    rho = small_segment.view.store.num_blocks
    observed = {b % rho: c for b, c in observed.items()}
    base = DS.device_anns(DS.from_segment(small_segment, tier0_blocks=8),
                          qb, P_PROP)
    ds = DS.from_segment(small_segment, tier0_blocks=8,
                         observed=observed)
    r = DS.device_anns(ds, qb, P_PROP)
    np.testing.assert_array_equal(np.asarray(base.ids), np.asarray(r.ids))
    np.testing.assert_array_equal(np.asarray(base.dists),
                                  np.asarray(r.dists))
    # block touches are conserved: only the io/tier0 split moves
    np.testing.assert_array_equal(
        np.asarray(base.io) + np.asarray(base.tier0_hits),
        np.asarray(r.io) + np.asarray(r.tier0_hits))


@given(observed=freq_maps, budget=st.integers(1, 16))
@settings(max_examples=30, deadline=None)
def test_plan_idempotent_at_fixed_frequencies(observed, budget):
    ranking = list(range(0, 40, 2))
    p1 = hotset.plan_tier0(ranking, observed, budget, 40)
    p2 = hotset.plan_tier0(ranking, observed, budget, 40)
    assert p1 == p2
    assert hotset.pack_drift(set(p1), p2) == 0.0
    assert len(p1) == min(budget, 40) == len(set(p1))


@pytest.mark.slow
@given(outside=st.integers(0, 1), weights=st.lists(
    st.integers(1, 50), min_size=8, max_size=8))
@settings(max_examples=10, deadline=None)
def test_below_threshold_drift_fires_zero_repacks(outside, weights,
                                                  small_segment):
    """Traffic over the live pack plus at most ONE outside block can
    move at most one of 8 slots (drift <= 1/8), which sits under the
    0.5 hysteresis gate — so no repack, no array churn, ever."""
    server = SegmentServer(
        segment=DS.from_segment(small_segment, tier0_blocks=8),
        offset=0, num_vectors=small_segment.num_vectors,
        host=small_segment, params=P_PROP)
    pack = sorted(DS.hot_pack_blocks(server.segment))
    rho = small_segment.view.store.num_blocks
    sched = RepackScheduler(RepackParams(interval_batches=1,
                                         hysteresis=0.5))
    sched.attach_target(server)
    window = {b: w for b, w in zip(pack, weights)}
    if outside:
        window[next(b for b in range(rho) if b not in pack)] = 1000
    sched._window.update(window)
    before = np.asarray(server.segment.hot_slot_of).copy()
    sched.batches = sched.params.interval_batches
    d = sched.maybe_repack()
    assert d is not None and d.repacked == 0
    assert d.max_drift <= 1 / 8 + 1e-9
    np.testing.assert_array_equal(
        before, np.asarray(server.segment.hot_slot_of))
