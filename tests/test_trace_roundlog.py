"""Round-granular device search tracing (repro.obs.roundlog +
``DeviceSearchParams.trace_rounds``).

The two contracts this suite pins:

  * **zero-cost invariance** — tracing is pure observation: with
    ``trace_rounds`` on, ``(ids, dists)`` and every counter column are
    bit-identical to the untraced run, under every combination of
    compaction and fetch_impl;
  * **lossless refinement** — the ``[rounds, 5]`` buffer folds exactly
    to the ``IOStats`` totals the serving plane accounts with:
    per-round ``live``/``cold``/``tier0``/``joins`` sums equal the
    batch's hops/io/tier0_hits/dedup_saved, and the fold reproduces
    ``IOStats.from_device_batch``'s ``rounds_active_weight``.

Deterministic versions always run (slow — they build the session
segment); the hypothesis property sweeps batch compositions with the
pinned batch size so each example reuses one compiled executable.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import device_search as DS
from repro.core.iostats import IOStats
from repro.core.params import DeviceSearchParams
from repro.obs import ROUND_LOG_COLS, fold_round_log, round_log_totals

P = DeviceSearchParams(k=5, candidates=24, max_hops=48, fetch_width=2)


@pytest.fixture(scope="module")
def packed_seg(small_segment):
    return DS.from_segment(small_segment, tier0_frac=0.1)


def test_round_log_cols_pinned_to_device_search():
    """The obs-side column schema and the loop's write order are the
    same tuple — the import-free mirror in device_search cannot drift
    from repro.obs.roundlog."""
    assert DS._ROUND_LOG_COLS == ROUND_LOG_COLS


@pytest.mark.slow
@pytest.mark.parametrize("compact_frac", [0.0, 0.5])
def test_trace_on_off_bit_identical(packed_seg, small_data,
                                    compact_frac):
    _, q = small_data
    qb = jnp.asarray(q[:8])
    p_off = dataclasses.replace(P, compact_frac=compact_frac)
    p_on = dataclasses.replace(p_off, trace_rounds=True)
    r_off = DS.device_anns(packed_seg, qb, p_off)
    r_on = DS.device_anns(packed_seg, qb, p_on)
    for f in ("ids", "dists", "io", "tier0_hits", "hops",
              "dedup_saved"):
        np.testing.assert_array_equal(
            np.asarray(getattr(r_off, f)), np.asarray(getattr(r_on, f)),
            err_msg=f"trace_rounds changed {f}")
    assert int(r_off.rounds) == int(r_on.rounds)
    assert r_off.round_log is None
    assert r_on.round_log is not None
    assert r_on.round_log.shape == (P.max_hops, len(ROUND_LOG_COLS))


@pytest.mark.slow
@pytest.mark.parametrize("compact_frac", [0.0, 0.5])
def test_round_log_folds_exactly_to_iostats(packed_seg, small_data,
                                            compact_frac):
    _, q = small_data
    p = dataclasses.replace(P, compact_frac=compact_frac,
                            trace_rounds=True)
    r = DS.device_anns(packed_seg, jnp.asarray(q[:8]), p)
    rounds = int(r.rounds)
    records = fold_round_log(r.round_log, rounds)
    tot = round_log_totals(records)
    assert tot["rounds"] == rounds
    assert tot["hops"] == int(np.asarray(r.hops).sum())
    assert tot["io"] == int(np.asarray(r.io).sum())
    assert tot["tier0_hits"] == int(np.asarray(r.tier0_hits).sum())
    assert tot["dedup_saved"] == int(np.asarray(r.dedup_saved).sum())
    # unwritten rows beyond the trip count stay zero padding
    tail = np.asarray(r.round_log)[rounds:]
    assert not tail.any()
    # per-round live counts never exceed the batch width and only fall
    live = np.array([rec.live for rec in records])
    assert (live <= 8).all() and (np.diff(live) <= 0).all()
    # the fold reproduces the coarse batch accounting exactly
    batch = IOStats.from_device_batch(
        np.asarray(r.io), np.asarray(r.tier0_hits), np.asarray(r.hops),
        np.asarray(r.dedup_saved), rounds)
    assert batch.batch_rounds == tot["rounds"]
    assert batch.rounds_active_weight == pytest.approx(
        tot["live_weight"] / max(rounds, 1))
    # compaction flags only appear when compaction is enabled
    if compact_frac == 0.0:
        assert tot["compactions"] == 0


@pytest.mark.slow
def test_round_log_spec_columns_tie_exactly(packed_seg, small_data):
    """ISSUE 9: the speculation columns are charged at consume time, so
    the folded rows tie bit-exactly to the ``DeviceSearchResult``
    counters — and a non-speculating run logs all-zero spec columns
    while every other column (and the results) stay bit-identical."""
    _, q = small_data
    p = dataclasses.replace(P, trace_rounds=True, speculate=True)
    r = DS.device_anns(packed_seg, jnp.asarray(q[:8]), p)
    records = fold_round_log(r.round_log, int(r.rounds))
    tot = round_log_totals(records)
    assert tot["spec_hits"] == int(np.asarray(r.spec_hits).sum())
    assert tot["spec_wasted"] == int(np.asarray(r.spec_wasted).sum())
    assert tot["spec_hits"] > 0, \
        "this workload should speculate successfully"
    # a round's hits are a subset of its paying gathers by construction
    for rec in records:
        assert rec.spec_hits <= rec.cold - rec.joins
    r0 = DS.device_anns(packed_seg, jnp.asarray(q[:8]),
                        dataclasses.replace(p, speculate=False))
    log0 = np.asarray(r0.round_log)
    assert not log0[:, 6:8].any()
    for f in ("ids", "dists", "io", "tier0_hits", "hops",
              "dedup_saved"):
        np.testing.assert_array_equal(
            np.asarray(getattr(r0, f)), np.asarray(getattr(r, f)),
            err_msg=f"speculation changed {f}")
    # the non-spec columns of the two logs agree row for row
    np.testing.assert_array_equal(log0[:, :6],
                                  np.asarray(r.round_log)[:, :6])


# ----------------------------------------------------------- property form
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    HAVE_HYPOTHESIS = False

BATCH = 8
P_TRACE = dataclasses.replace(P, compact_frac=0.5, trace_rounds=True)
P_PLAIN = dataclasses.replace(P_TRACE, trace_rounds=False)

if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @given(rows=st.lists(st.integers(0, 23), min_size=BATCH,
                         max_size=BATCH))
    @settings(max_examples=6, deadline=None)
    def test_trace_invariance_and_fold_property(rows, packed_seg,
                                                small_data):
        """ANY batch composition: tracing never perturbs results, and
        the round log folds exactly to the counter totals."""
        _, q = small_data
        qb = jnp.asarray(q[np.asarray(rows)])
        r0 = DS.device_anns(packed_seg, qb, P_PLAIN)
        r1 = DS.device_anns(packed_seg, qb, P_TRACE)
        np.testing.assert_array_equal(np.asarray(r0.ids),
                                      np.asarray(r1.ids))
        np.testing.assert_array_equal(np.asarray(r0.dists),
                                      np.asarray(r1.dists))
        tot = round_log_totals(fold_round_log(r1.round_log,
                                              int(r1.rounds)))
        assert tot["io"] == int(np.asarray(r1.io).sum())
        assert tot["hops"] == int(np.asarray(r1.hops).sum())
        assert tot["tier0_hits"] == int(np.asarray(r1.tier0_hits).sum())
        assert tot["dedup_saved"] == int(
            np.asarray(r1.dedup_saved).sum())
