"""Serving plane: multi-segment coordinator, merge, batcher (Fig. 1(b),
§6.7 scalability structure)."""
import numpy as np
import pytest

import dataclasses

from repro.core import device_search as DS
from repro.core import distances as D
from repro.core.segment import build_segment
from repro.core.search import recall_at_k
from repro.data.vectors import clustered_vectors, query_set
from repro.serving import QueryCoordinator, RequestBatcher, SegmentServer
from repro.serving.coordinator import SERVE_DEVICE_SEARCH, merge_topk
from tests.conftest import SMALL_SEGMENT


@pytest.fixture(scope="module")
def two_segments():
    xs = [clustered_vectors(1200, 32, num_clusters=12, seed=s)
          for s in (0, 1)]
    servers = []
    off = 0
    for si, x in enumerate(xs):
        seg = build_segment(x, SMALL_SEGMENT)
        # second segment carries a tier-0 hot-tile pack — results must
        # merge identically either way, the pack only moves touches off
        # the DMA counter
        servers.append(SegmentServer(
            segment=DS.from_segment(seg, tier0_frac=0.1 * si),
            offset=off, num_vectors=x.shape[0],
            params=dataclasses.replace(SERVE_DEVICE_SEARCH,
                                       candidates=48)))
        off += x.shape[0]
    return xs, servers


def test_merge_topk_correct():
    ids = [np.asarray([[0, 1]]), np.asarray([[0, -1]])]
    dd = [np.asarray([[0.5, 2.0]]), np.asarray([[1.0, np.inf]])]
    gi, gd = merge_topk(ids, dd, offsets=[0, 100], k=3)
    np.testing.assert_array_equal(gi[0], [0, 100, 1])
    np.testing.assert_allclose(gd[0], [0.5, 1.0, 2.0])


def test_merge_topk_duplicate_distance_tiebreak():
    """ISSUE 7 satellite regression: equal distances break ties by
    ascending GLOBAL id — the same (dist, id) total order the on-device
    ``merge_shard_topk`` sorts by, so host and mesh merges agree no
    matter which segment a duplicate lands in."""
    ids = [np.asarray([[3, 1]]), np.asarray([[2, 0]])]
    dd = [np.asarray([[1.0, 1.0]]), np.asarray([[1.0, 1.0]])]
    gi, gd = merge_topk(ids, dd, offsets=[0, 100], k=4)
    np.testing.assert_array_equal(gi[0], [1, 3, 100, 102])
    np.testing.assert_allclose(gd[0], [1.0, 1.0, 1.0, 1.0])
    # invalid slots (-1) always sort last, even against inf distances
    ids = [np.asarray([[5, -1]]), np.asarray([[7, -1]])]
    dd = [np.asarray([[2.0, np.inf]]), np.asarray([[2.0, np.inf]])]
    gi, gd = merge_topk(ids, dd, offsets=[0, 100], k=4)
    np.testing.assert_array_equal(gi[0], [5, 107, -1, -1])


def test_merge_topk_matches_device_merge_on_ties():
    """Host merge == device merge on the same duplicate-heavy inputs:
    both sort the shared (dist, global id) key, so the mesh router's
    on-device merge is bit-identical to the coordinator's."""
    import jax.numpy as jnp

    from repro.core.device_search import merge_shard_topk
    rng = np.random.default_rng(5)
    s, qn, kk, k = 3, 6, 8, 5
    ids = [rng.integers(0, 40, (qn, kk)) for _ in range(s)]
    # quantized dists force plenty of cross-segment ties
    dd = [rng.integers(0, 4, (qn, kk)).astype(np.float64)
          for _ in range(s)]
    for i, d in zip(ids, dd):                     # some invalid slots
        mask = rng.random((qn, kk)) < 0.2
        i[mask] = -1
        d[mask] = np.inf
    offsets = [0, 100, 200]
    hi, hd = merge_topk(ids, dd, offsets, k)
    gids = np.stack([np.where(i >= 0, i + off, -1)
                     for i, off in zip(ids, offsets)])
    di, dv = merge_shard_topk(jnp.asarray(gids),
                              jnp.asarray(np.stack(dd)), k)
    np.testing.assert_array_equal(hi, np.asarray(di))
    np.testing.assert_array_equal(hd, np.asarray(dv))


@pytest.mark.slow
def test_coordinator_recall_over_union(two_segments):
    xs, servers = two_segments
    union = np.concatenate(xs, axis=0)
    q = query_set(union, 16, seed=3)
    coord = QueryCoordinator(servers)
    gi, gd, stats = coord.search(q, k=10)
    truth = D.brute_force_knn(union, q, 10)
    assert recall_at_k(gi, truth) >= 0.75
    assert stats["segments_searched"] == 2
    assert stats["total_block_reads"] > 0
    # the tier-0-packed segment absorbed some touches into VMEM
    assert stats.get("total_tier0_hits", 0) > 0


@pytest.mark.slow
def test_server_k_above_beam_widens(two_segments):
    """A per-request k above the configured candidate beam widens Γ
    instead of tripping DeviceSearchParams validation."""
    xs, servers = two_segments
    q = query_set(xs[0], 4, seed=7)
    ids, dists, io = servers[0].search(q, k=96)
    assert ids.shape == (4, 96) and dists.shape == (4, 96)
    assert (io > 0).all()


@pytest.mark.slow
def test_coordinator_pruning_hook(two_segments):
    xs, servers = two_segments
    q = query_set(xs[0], 4, seed=4)
    coord = QueryCoordinator(servers, prune_fn=lambda queries: [0])
    _, _, stats = coord.search(q, k=5)
    assert stats["segments_searched"] == 1


def test_batcher_buckets():
    # tile=1 opts out of kernel-tile coercion: buckets used verbatim
    b = RequestBatcher(dim=8, buckets=(4, 16), tile=1)
    for _ in range(6):
        b.submit(np.zeros(8))
    q, ids, n = b.next_batch()
    assert n == 6 and q.shape == (16, 8) and len(ids) == 6
    q, ids, n = b.next_batch() if b.queue else (None, [], 0)
    assert n == 0


def test_batcher_buckets_align_to_kernel_tiles():
    """ISSUE 4 satellite: bucket sizes are coerced up to multiples of
    the fused round kernel's tile granularity, so a padded batch fills
    whole kernel tiles (and the coerced sizes dedupe)."""
    b = RequestBatcher(dim=4, buckets=(3, 5, 8, 30), tile=8)
    assert b.buckets == (8, 32)          # 3,5,8 -> 8 (deduped), 30 -> 32
    from repro.kernels import round_tile
    # every bucket is a whole number of kernel tiles
    assert all(x % round_tile(x) == 0 for x in b.buckets)
    with pytest.raises(ValueError):
        RequestBatcher(dim=4, buckets=(4,), tile=0)


@pytest.mark.slow
def test_ragged_batch_padding_is_result_invariant(two_segments):
    """ISSUE 4 satellite regression: a ragged final batch padded up to
    its bucket returns bit-identical per-request results to singleton
    searches — zero-padded rows converge on their own and (with the
    serving preset's compaction) drop out of the rounds; they never
    leak into real rows."""
    xs, servers = two_segments
    q5 = query_set(xs[0], 5, seed=11)         # ragged: 5 of bucket 8
    batcher = RequestBatcher(dim=q5.shape[1], buckets=(8, 32))
    for row in q5:
        batcher.submit(row)
    padded, ids, n = batcher.next_batch()
    assert padded.shape[0] == 8 and n == 5
    ib, db, _ = servers[0].search(padded, k=10)
    for row in range(n):
        i1, d1, _ = servers[0].search(q5[row: row + 1], k=10)
        np.testing.assert_array_equal(i1[0], ib[row])
        np.testing.assert_array_equal(d1[0], db[row])


def test_batcher_single_request_pads_to_smallest_bucket():
    b = RequestBatcher(dim=4, buckets=(8, 32))
    b.submit(np.ones(4))
    q, ids, n = b.next_batch()
    assert q.shape == (8, 4) and n == 1
    assert np.allclose(q[0], 1.0) and np.allclose(q[1:], 0.0)


def test_batcher_ready_waits_for_deadline():
    """A partial batch is NOT ready until max_wait polls elapse."""
    b = RequestBatcher(dim=4, buckets=(4, 8), max_wait=3)
    assert not b.ready()                  # empty queue: never ready
    b.submit(np.zeros(4))
    assert not b.ready() and not b.ready()
    assert b.ready()                      # deadline flush on 3rd poll
    _, _, n = b.next_batch()
    assert n == 1
    assert not b.ready()                  # wait counter reset


def test_batcher_ready_immediate_on_full_bucket():
    b = RequestBatcher(dim=4, buckets=(4, 8), max_wait=1000)
    for _ in range(8):
        b.submit(np.zeros(4))
    assert b.ready()                      # largest bucket full: no wait
