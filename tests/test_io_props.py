"""Property-style tests for the PR 2 async/tiered I/O subsystem.

Guarded hypothesis import, matching test_layout/test_pq: the whole
module skips when hypothesis is absent; the deterministic versions of
these checks live in test_io_async.py and always run.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; rest of the suite runs without")
from hypothesis import given, settings, strategies as st

from repro.core.params import CacheParams
from repro.core.search import anns
from repro.io import AsyncFetchQueue, TieredBlockCache, cached_view

KB = 1024


@given(ops=st.lists(st.tuples(st.sampled_from(["admit", "lookup"]),
                              st.integers(0, 40)),
                    min_size=1, max_size=120),
       t1_blocks=st.integers(1, 4), t2_blocks=st.integers(0, 8),
       pinned=st.lists(st.integers(0, 40), max_size=2))
@settings(max_examples=60, deadline=None)
def test_tiered_cache_invariants_hold(ops, t1_blocks, t2_blocks, pinned):
    """Under arbitrary admit/lookup interleavings: residency stays
    within each tier's budget, pinned blocks never leave tier 1,
    tier-1 evictions land in tier 2, and no block is resident in both
    tiers at once."""
    c = TieredBlockCache(tier1_bytes=t1_blocks * KB,
                         tier2_bytes=t2_blocks * KB,
                         block_bytes=KB, compression=16, pinned=pinned)
    for op, b in ops:
        was_t1_full = len(c.tier1) >= c.tier1.capacity_blocks
        t1_before = set(c.tier1.resident)
        if op == "admit":
            c.admit(b)
            if (was_t1_full and b not in t1_before
                    and c.tier2.capacity_blocks > 0
                    and c.tier1.capacity_blocks > 0):
                evicted = t1_before - set(c.tier1.resident)
                # tier-1 victims demote into tier 2 (may then be evicted
                # from tier 2, but they must have been admitted)
                assert all(v in c.tier2 or c.tier2.evictions > 0
                           for v in evicted)
        else:
            c.lookup_tier(b)
        assert len(c.tier1) <= c.tier1.capacity_blocks
        assert len(c.tier2) <= c.tier2.capacity_blocks
        assert c.resident_bytes() <= c.memory_bytes()
        for pb in c.tier1.pinned:
            assert pb in c.tier1
        assert not (c.tier1.resident & c.tier2.resident)


@given(salt=st.integers(0, 63))
@settings(max_examples=10, deadline=None)
def test_completion_order_permutations_bit_identical(salt, small_segment,
                                                     small_data):
    """Any completion-order permutation (jitter seed) leaves search
    ids/dists bit-identical to the uncached oracle: delivery timing
    moves residency and counters, never payloads."""
    _, q = small_data
    p = small_segment.params.search
    ids_u, dd_u, _ = anns(small_segment.view, q[:4], 10, p)
    queue = AsyncFetchQueue(depth=8, jitter_salt=salt)
    view = cached_view(small_segment.view, small_segment.graph,
                       CacheParams(budget_frac=0.15, prefetch_width=4,
                                   tier2_frac=0.25, queue_depth=8),
                       queue=queue)
    ids, dd, _ = anns(view, q[:4], 10, p)
    np.testing.assert_array_equal(ids_u, ids)
    np.testing.assert_allclose(dd_u, dd)
