"""Property tests for the divergence-aware batched device search.

Guarded hypothesis import, matching test_io_props/test_layout/test_pq:
the whole module skips when hypothesis is absent; the deterministic
versions of these checks live in test_device_search.py and always run.

The property: a batched, deduped, compacted ``device_anns`` is
bit-identical, per query, to a loop of singleton-batch searches — for
ANY query permutation and ANY duplication pattern. Per-query state is
row-independent; dedup and compaction only move counters and tiles.
The batch size is pinned so every hypothesis example reuses the same
two compiled executables (batch of 8, singleton).
"""
import dataclasses

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; rest of the suite runs without")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import device_search as DS
from repro.core.params import DeviceSearchParams

BATCH = 8
P_BATCH = DeviceSearchParams(k=5, candidates=24, max_hops=48,
                             fetch_width=2, compact_frac=0.5)
P_SINGLE = dataclasses.replace(P_BATCH, compact_frac=0.0)
# force two 4-row round tiles so duplicate rows can straddle the tile
# boundary: batch-scope dedup must still absorb them (ISSUE 8)
P_TILED = dataclasses.replace(P_BATCH, round_tile_cap=4)


@pytest.fixture(scope="module")
def packed_seg(small_segment):
    return DS.from_segment(small_segment, tier0_frac=0.1)


@pytest.mark.slow
@given(rows=st.lists(st.integers(0, 23), min_size=BATCH,
                     max_size=BATCH))
@settings(max_examples=6, deadline=None)
def test_batched_bit_identical_to_singletons(rows, packed_seg,
                                             small_data):
    """Random permutations + duplicates: every batch row's (ids,
    dists) equals the singleton search of that query, and a row whose
    query also appears earlier in the batch has its entire cold
    traffic absorbed by dedup."""
    _, q = small_data
    qb = q[np.asarray(rows)]
    r = DS.device_anns(packed_seg, jnp.asarray(qb), P_BATCH)
    singles = {}
    for row, qi in enumerate(rows):
        if qi not in singles:
            singles[qi] = DS.device_anns(
                packed_seg, jnp.asarray(q[qi: qi + 1]), P_SINGLE)
        r1 = singles[qi]
        np.testing.assert_array_equal(np.asarray(r1.ids[0]),
                                      np.asarray(r.ids[row]))
        np.testing.assert_array_equal(np.asarray(r1.dists[0]),
                                      np.asarray(r.dists[row]))
    io = np.asarray(r.io)
    saved = np.asarray(r.dedup_saved)
    assert (saved <= io).all()
    for row in range(BATCH):
        if rows[row] in rows[:row]:       # duplicate of an earlier row
            assert saved[row] == io[row], (
                f"duplicate row {row} must join every gather "
                f"(saved {saved[row]} of {io[row]})")


@pytest.mark.slow
@given(rows=st.lists(st.integers(0, 23), min_size=BATCH,
                     max_size=BATCH))
@settings(max_examples=6, deadline=None)
def test_tiled_batch_bit_identical_across_tile_boundary(rows, packed_seg,
                                                        small_data):
    """ISSUE 8 tentpole property: with the batch forced onto multiple
    round tiles (``round_tile_cap=4`` -> two tiles of 4), dedup is
    BATCH-scope — any permutation/duplication pattern, including twins
    straddling the tile boundary, is bit-identical to the singleton
    loop and a duplicate of an earlier row still has its whole cold
    traffic absorbed. Cross-tile joins are a subset of the total."""
    _, q = small_data
    qb = q[np.asarray(rows)]
    r = DS.device_anns(packed_seg, jnp.asarray(qb), P_TILED)
    singles = {}
    for row, qi in enumerate(rows):
        if qi not in singles:
            singles[qi] = DS.device_anns(
                packed_seg, jnp.asarray(q[qi: qi + 1]), P_SINGLE)
        r1 = singles[qi]
        np.testing.assert_array_equal(np.asarray(r1.ids[0]),
                                      np.asarray(r.ids[row]))
        np.testing.assert_array_equal(np.asarray(r1.dists[0]),
                                      np.asarray(r.dists[row]))
    io = np.asarray(r.io)
    saved = np.asarray(r.dedup_saved)
    cross = np.asarray(r.dedup_cross)
    assert (cross >= 0).all() and (cross <= saved).all()
    assert (saved <= io).all()
    for row in range(BATCH):
        if rows[row] in rows[:row]:       # twin possibly in other tile
            assert saved[row] == io[row], (
                f"duplicate row {row} straddling a tile boundary must "
                f"still join every gather (saved {saved[row]} of "
                f"{io[row]})")
