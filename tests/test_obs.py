"""Unit tests for the observability plane (repro.obs, DESIGN.md §6):
clocks, tracer, metrics registry, Chrome-trace export, perf artifacts,
and the measured-vs-modeled cost calibration. All fast — no segment
builds, no jax; the device round-log integration lives in
tests/test_trace_roundlog.py."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core.iostats import IOStats, NVME_SEGMENT, TPU_HBM_SEGMENT
from repro.obs import (CalibrationPreset, CalibrationSample, Counter,
                       Gauge, Histogram, ManualClock, MetricsRegistry,
                       RoundRecord, Tracer, WallClock, calibrate,
                       chrome_trace, fit_cost_model, fold_round_log,
                       manual_tracer, round_log_totals,
                       timeline_from_round_log, validate_chrome_trace,
                       write_chrome_trace)


# ------------------------------------------------------------------ clocks
def test_wall_clock_monotone():
    c = WallClock()
    ts = [c.now_us() for _ in range(100)]
    assert all(b >= a for a, b in zip(ts, ts[1:]))


def test_manual_clock_advance_and_set():
    c = ManualClock(start_us=10.0)
    assert c.now_us() == 10.0
    c.advance(5.0)
    assert c.now_us() == 15.0
    c.set(100.0)
    assert c.now_us() == 100.0
    with pytest.raises(ValueError):
        c.advance(-1.0)
    with pytest.raises(ValueError):
        c.set(0.0)                        # clocks only move forward


def test_manual_clock_auto_tick():
    c = ManualClock(auto_tick_us=2.0)
    assert (c.now_us(), c.now_us(), c.now_us()) == (0.0, 2.0, 4.0)


# ------------------------------------------------------------------ tracer
def test_tracer_span_records_duration_and_outcome_args():
    tr = Tracer(clock=ManualClock())
    with tr.span("host.search", cat="serve", track="seg0", k=10) as sp:
        tr.clock.advance(7.0)
        sp["block_reads"] = 42
    (ev,) = tr.events
    assert ev.name == "host.search" and ev.ph == "X"
    assert ev.ts_us == 0.0 and ev.dur_us == 7.0
    assert ev.args == {"k": 10, "block_reads": 42}
    assert ev.track == "seg0"


def test_tracer_span_records_on_exception():
    tr = Tracer(clock=ManualClock())
    with pytest.raises(RuntimeError):
        with tr.span("coord.batch"):
            tr.clock.advance(3.0)
            raise RuntimeError("boom")
    assert len(tr) == 1 and tr.events[0].dur_us == 3.0


def test_tracer_event_and_slice():
    tr = manual_tracer(auto_tick_us=1.0)
    tr.event("sched.repack", cat="sched", target="seg0")
    tr.slice("device.round", ts_us=100.0, dur_us=5.0, live=8)
    inst, sl = tr.events
    assert inst.ph == "i" and inst.args == {"target": "seg0"}
    assert sl.ph == "X" and sl.ts_us == 100.0 and sl.dur_us == 5.0


def test_tracer_head_capture_drops_past_max_events():
    tr = Tracer(clock=ManualClock(auto_tick_us=1.0), max_events=3)
    for i in range(10):
        tr.event("e", i=i)
    assert len(tr) == 3 and tr.dropped == 7
    assert [e.args["i"] for e in tr.events] == [0, 1, 2]  # head, not ring
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_by_name():
    tr = manual_tracer()
    tr.event("a")
    tr.event("b")
    tr.event("a")
    assert len(tr.by_name("a")) == 2 and len(tr.by_name("c")) == 0


# ----------------------------------------------------------------- metrics
def test_counter_monotone():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_histogram_window_quantiles():
    h = Histogram(window=4)
    for v in (1.0, 2.0, 3.0, 4.0, 100.0):  # 1.0 fell out of the window
        h.observe(v)
    assert h.count == 5 and h.total == 110.0
    assert h.quantile(0.0) == 2.0
    assert h.quantile(0.99) == 100.0
    s = h.summary()
    assert s["count"] == 5 and s["window"] == 4
    assert s["mean"] == 22.0 and s["max"] == 100.0
    assert s["p50"] == 4.0                 # nearest-rank over [2,3,4,100]
    assert Histogram().quantile(0.5) == 0.0


def test_registry_create_on_first_use_and_per_target():
    m = MetricsRegistry()
    m.counter("serve.block_reads", "seg0").inc(10)
    m.counter("serve.block_reads", "seg1").inc(20)
    m.gauge("serve.cache_hit_rate").set(0.5)
    m.histogram("serve.batch_block_reads").observe(30)
    assert m.value("serve.block_reads", "seg0") == 10
    assert m.value("serve.block_reads", "seg1") == 20
    assert m.value("nope") is None
    assert m.targets("serve.block_reads") == ["seg0", "seg1"]
    snap = m.snapshot()
    assert snap["serve.block_reads"] == {"seg0": 10, "seg1": 20}
    assert snap["serve.cache_hit_rate"][""] == 0.5
    assert snap["serve.batch_block_reads"][""]["count"] == 1


def test_registry_kind_mismatch_raises():
    m = MetricsRegistry()
    m.counter("serve.batches")
    with pytest.raises(TypeError):
        m.gauge("serve.batches")
    with pytest.raises(TypeError):
        m.histogram("serve.batches")
    # same name under a DIFFERENT target is a separate instrument
    assert isinstance(m.gauge("serve.batches", "segX"), Gauge)


# ------------------------------------------------------------------ export
def _demo_tracer():
    tr = Tracer(clock=ManualClock(auto_tick_us=1.0))
    with tr.span("coord.batch", track="coord", n_queries=8):
        tr.event("io.read", cat="io", track="io", block=3)
    return tr


def test_chrome_trace_structure():
    tr = _demo_tracer()
    obj = chrome_trace(tr, metadata={"run": "t"})
    assert validate_chrome_trace(obj) == []
    evs = obj["traceEvents"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert {m["args"]["name"] for m in metas} == {"coord", "io"}
    tids = {m["args"]["name"]: m["tid"] for m in metas}
    x = next(e for e in evs if e["ph"] == "X")
    i = next(e for e in evs if e["ph"] == "i")
    assert x["tid"] == tids["coord"] and i["tid"] == tids["io"]
    assert x["dur"] >= 0 and i["s"] == "t"
    assert obj["metadata"] == {"run": "t"}


def test_chrome_trace_reports_dropped():
    tr = Tracer(clock=ManualClock(auto_tick_us=1.0), max_events=1)
    tr.event("a")
    tr.event("b")
    assert chrome_trace(tr)["obs_dropped_events"] == 1


def test_write_chrome_trace_round_trip(tmp_path):
    path = tmp_path / "deep" / "trace.json"   # parent dir is created
    write_chrome_trace(path, _demo_tracer())
    with open(path) as f:
        assert validate_chrome_trace(json.load(f)) == []


def test_validate_chrome_trace_catches_corruption():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": 3}) != []
    bad = {"traceEvents": [
        {"ph": "Q", "name": "x", "pid": 1, "tid": 1},          # bad ph
        {"ph": "X", "pid": 1, "tid": 1, "ts": 0, "dur": 1},    # no name
        {"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": 0,
         "dur": -1},                                           # bad dur
        {"ph": "i", "name": "x", "pid": 1, "tid": 1, "ts": "a"}]}
    assert len(validate_chrome_trace(bad)) == 4


def test_timeline_from_round_log_modeled_durations():
    records = [RoundRecord(0, live=8, cold=10, tier0=2, joins=3,
                           joins_x=1, compacted=False),
               RoundRecord(1, live=4, cold=6, tier0=1, joins=1,
                           joins_x=0, compacted=True)]
    cm = TPU_HBM_SEGMENT
    tr = timeline_from_round_log(records, cm)
    a, b = tr.by_name("device.round")
    t_stream = cm.t_batch_block if cm.t_batch_block else cm.t_block_io
    want0 = (cm.t_round + 8 * cm.t_round_comp + 7 * t_stream
             + 2 * cm.t_tier0_hit + 3 * cm.t_dedup_hit)
    assert a.ts_us == 0.0 and a.dur_us == pytest.approx(want0)
    assert b.ts_us == pytest.approx(a.dur_us)   # back-to-back slices
    assert a.args["live"] == 8 and b.args["compacted"] is True
    assert validate_chrome_trace(chrome_trace(tr)) == []


def test_timeline_dma_track_renders_speculative_overlap():
    """``dma_track=True`` puts the gather stream on its own row: a
    round's demand DMAs overlap its own round slice, while its
    speculatively pre-issued blocks render back in the PREVIOUS round
    (where the copies were actually in flight). The round slices stay
    bit-compatible with the default rendering."""
    records = [RoundRecord(0, live=8, cold=10, tier0=2, joins=3,
                           joins_x=1, compacted=False),
               RoundRecord(1, live=4, cold=6, tier0=1, joins=1,
                           joins_x=0, compacted=True, spec_hits=2,
                           spec_wasted=1)]
    cm = TPU_HBM_SEGMENT
    base = timeline_from_round_log(records, cm)
    tr = timeline_from_round_log(records, cm, dma_track=True)
    for a, b in zip(base.by_name("device.round"),
                    tr.by_name("device.round")):
        assert a.ts_us == b.ts_us and a.dur_us == b.dur_us
        assert a.args["spec_hits"] == b.args["spec_hits"]
    t_stream = cm.t_batch_block if cm.t_batch_block else cm.t_block_io
    r0, r1 = tr.by_name("device.round")
    d0, d1 = tr.by_name("device.dma")
    # demand streams start WITH their round (overlapping its compute)
    assert d0.ts_us == r0.ts_us and d0.args["blocks"] == 10 - 3
    assert d1.ts_us == pytest.approx(r1.ts_us)
    assert d1.args["blocks"] == 6 - 1 - 2      # spec hits left the demand
    assert d1.dur_us == pytest.approx(3 * t_stream)
    # round 1's speculative copies render back in round 0
    spec, = tr.by_name("device.dma.spec")
    assert spec.ts_us == r0.ts_us
    assert spec.dur_us == pytest.approx((2 + 1) * t_stream)
    assert spec.args["spec_hits"] == 2 and spec.args["spec_wasted"] == 1
    assert {d0.track, d1.track, spec.track} == {"device.dma"}
    assert validate_chrome_trace(chrome_trace(tr)) == []


# ---------------------------------------------------------- round-log fold
def test_fold_round_log_drops_padding_and_validates_shape():
    log = np.zeros((6, 8), np.int32)
    log[0] = [8, 10, 2, 3, 1, 0, 2, 1]
    log[1] = [4, 6, 1, 1, 0, 1, 0, 3]
    recs = fold_round_log(log, rounds=2)
    assert len(recs) == 2
    assert recs[1] == RoundRecord(1, 4, 6, 1, 1, 0, True,
                                  spec_hits=0, spec_wasted=3)
    tot = round_log_totals(recs)
    assert tot == {"rounds": 2, "hops": 12, "io": 16, "tier0_hits": 3,
                   "dedup_saved": 4, "dedup_cross": 1, "compactions": 1,
                   "spec_hits": 2, "spec_wasted": 4,
                   "live_weight": 12}
    with pytest.raises(ValueError):
        fold_round_log(np.zeros((6, 6), np.int32), 2)


# ----------------------------------------------------------- perf artifact
def test_perf_artifact_schema_round_trip(tmp_path, monkeypatch):
    from benchmarks import common as C
    monkeypatch.setattr(C, "ARTIFACT_DIR", str(tmp_path))
    path = C.perf_artifact(
        "t_bench", [{"name": "lat", "value": 1.5, "units": "us"},
                    {"name": "hits", "value": 3, "units": "blocks",
                     "measured": True}],
        config={"n": 10}, measured=False)
    with open(path) as f:
        payload = json.load(f)
    assert C.validate_perf_artifact(payload) == []
    assert payload["bench"] == "t_bench"
    assert payload["config_hash"] == C.config_hash({"n": 10})
    assert payload["metrics"][0]["measured"] is False
    assert payload["metrics"][1]["measured"] is True   # per-row override


def test_validate_perf_artifact_catches_problems():
    from benchmarks import common as C
    assert C.validate_perf_artifact({}) != []
    bad = {"schema": C.ARTIFACT_SCHEMA, "bench": "b", "config": {},
           "config_hash": "x", "measured": False,
           "metrics": [{"name": "m", "value": "NaNstr", "units": "",
                        "measured": False}]}
    assert any("number" in p for p in C.validate_perf_artifact(bad))


def test_config_hash_stable_and_order_independent():
    from benchmarks import common as C
    assert C.config_hash({"a": 1, "b": 2}) == C.config_hash({"b": 2,
                                                             "a": 1})
    assert C.config_hash({"a": 1}) != C.config_hash({"a": 2})


# ------------------------------------------------------------- calibration
def _device_stats(io, t0, hops, saved, rounds):
    return IOStats.from_device(io, t0, hops, saved, rounds)


def test_calibration_recovers_known_device_constants():
    truth = dataclasses.replace(TPU_HBM_SEGMENT, t_batch_block=0.7,
                                t_round=2.5, t_round_comp=0.3)
    rng = [(40, 5, 30, 4, 12), (80, 9, 55, 10, 20), (25, 2, 18, 1, 9),
           (60, 7, 44, 6, 16)]
    samples = [CalibrationSample(_device_stats(*r),
                                 truth.latency_us(_device_stats(*r)))
               for r in rng]
    fields = ("t_batch_block", "t_round", "t_round_comp")
    model, report = fit_cost_model(TPU_HBM_SEGMENT, samples, fields)
    for f in fields:
        assert getattr(model, f) == pytest.approx(getattr(truth, f),
                                                  abs=1e-6)
    assert report["unfit"] == []
    assert report["error_after"]["mean_abs_rel_err"] < 1e-9


def test_calibration_reports_unidentifiable_fields():
    # host-regime samples never exercise the round chain: t_round /
    # t_round_comp columns are all-zero and must come back unfit with
    # base values, never silently "fitted"
    samples = [CalibrationSample(IOStats(block_reads=r, cache_misses=r,
                                         hops=r), float(100 * r))
               for r in (5, 11, 23)]
    model, report = fit_cost_model(
        NVME_SEGMENT, samples,
        fields=("t_block_io", "t_round", "t_round_comp"))
    assert set(report["unfit"]) == {"t_round", "t_round_comp"}
    assert model.t_round == NVME_SEGMENT.t_round
    assert "t_block_io" in report["fitted"]
    assert model.t_block_io >= 0.0


def test_calibration_clips_negative_constants_and_needs_samples():
    with pytest.raises(ValueError):
        fit_cost_model(NVME_SEGMENT, [])
    s = [CalibrationSample(IOStats(block_reads=r, cache_misses=r),
                           0.0)           # measured 0 → raw fit < base
         for r in (3, 7)]
    model, _ = fit_cost_model(NVME_SEGMENT, s, fields=("t_block_io",))
    assert model.t_block_io >= 0.0


def test_preset_save_load_apply(tmp_path):
    truth = dataclasses.replace(TPU_HBM_SEGMENT, t_round=4.0)
    stats = [_device_stats(40, 5, 30, 4, 12), _device_stats(70, 6, 50,
                                                            8, 18)]
    samples = [CalibrationSample(s, truth.latency_us(s)) for s in stats]
    path = tmp_path / "preset.json"
    model, preset, report = calibrate(
        TPU_HBM_SEGMENT, samples, fields=("t_round",),
        source="unit test", preset_path=str(path))
    loaded = CalibrationPreset.load(path)
    assert loaded == preset
    applied = loaded.apply(TPU_HBM_SEGMENT)
    assert applied.t_round == pytest.approx(4.0, abs=1e-6)
    assert applied.t_block_io == TPU_HBM_SEGMENT.t_block_io  # untouched
    with pytest.raises(ValueError):
        loaded.apply(NVME_SEGMENT)         # backend mismatch


def test_load_calibrated_applies_stored_preset(tmp_path):
    """ISSUE 7 satellite: the scheduler/router/mesh-bench default
    pricing path — a stored CALIB_<backend>.json overlays its fitted
    constants on the shipped base model."""
    from repro.obs import load_calibrated
    preset = CalibrationPreset(
        backend=TPU_HBM_SEGMENT.name, constants={"t_round": 9.5},
        unfit=[], n_samples=4, error={})
    preset.save(tmp_path / f"CALIB_{TPU_HBM_SEGMENT.name}.json")
    cm = load_calibrated(TPU_HBM_SEGMENT, results_dir=str(tmp_path))
    assert cm.t_round == pytest.approx(9.5)
    assert cm.t_block_io == TPU_HBM_SEGMENT.t_block_io   # unfit kept


def test_load_calibrated_falls_back_on_mismatch_or_garbage(tmp_path):
    """Any way the preset cannot be honored falls back to the base
    model — missing file, wrong backend, unparseable JSON — so callers
    can default to calibrated pricing unconditionally."""
    from repro.obs import load_calibrated
    # missing file
    assert load_calibrated(NVME_SEGMENT,
                           results_dir=str(tmp_path)) == NVME_SEGMENT
    # preset fitted for a different backend than the file name claims
    wrong = CalibrationPreset(
        backend=TPU_HBM_SEGMENT.name, constants={"t_round": 9.5},
        unfit=[], n_samples=4, error={})
    wrong.save(tmp_path / f"CALIB_{NVME_SEGMENT.name}.json")
    assert load_calibrated(NVME_SEGMENT,
                           results_dir=str(tmp_path)) == NVME_SEGMENT
    # unparseable file
    (tmp_path / f"CALIB_{NVME_SEGMENT.name}.json").write_text("{nope")
    assert load_calibrated(NVME_SEGMENT,
                           results_dir=str(tmp_path)) == NVME_SEGMENT
    # unknown constant name inside an otherwise valid preset
    bad = CalibrationPreset(
        backend=NVME_SEGMENT.name, constants={"t_warp_drive": 1.0},
        unfit=[], n_samples=1, error={})
    bad.save(tmp_path / f"CALIB_{NVME_SEGMENT.name}.json")
    assert load_calibrated(NVME_SEGMENT,
                           results_dir=str(tmp_path)) == NVME_SEGMENT


# -------------------------------------------- coordinator stats/obs wiring
class _FakeServer:
    """Duck-typed device-less server: fixed results, zero traffic."""

    def __init__(self, offset=0):
        self.offset = offset

    def search(self, queries, k):
        n = queries.shape[0]
        ids = np.tile(np.arange(k, dtype=np.int64), (n, 1))
        dists = np.ones((n, k), np.float32)
        return ids, dists, np.zeros(n, np.int64)


def test_coordinator_stats_schema_complete_on_cold_batch():
    """Every STATS_SCHEMA key is present with zeros included — a
    downstream consumer must never KeyError on a batch that hit no
    cache and saved no dedup (the PR 6 stats-shape fix)."""
    from repro.serving import QueryCoordinator
    coord = QueryCoordinator([_FakeServer()])
    q = np.zeros((4, 8), np.float32)
    _, _, stats = coord.search(q, k=3)
    for key in QueryCoordinator.STATS_SCHEMA:
        assert key in stats, f"stats dict missing {key!r}"
    assert stats["total_tier0_hits"] == 0
    assert stats["total_dedup_saved"] == 0
    assert stats["deduped_block_reads"] == 0
    assert stats["cache_hits"] == 0 and stats["cache_misses"] == 0
    assert stats["cache_hit_rate"] == 0.0
    assert stats["segments_searched"] == 1


def test_coordinator_emits_spans_and_metrics():
    from repro.serving import QueryCoordinator
    tr = manual_tracer()
    m = MetricsRegistry()
    coord = QueryCoordinator([_FakeServer(0), _FakeServer(100)],
                             tracer=tr, metrics=m)
    q = np.zeros((4, 8), np.float32)
    coord.search(q, k=3)
    coord.search(q, k=3)
    assert len(tr.by_name("coord.batch")) == 2
    assert len(tr.by_name("coord.segment")) == 4   # 2 segments x 2
    batch = tr.by_name("coord.batch")[0]
    assert batch.args["n_queries"] == 4 and "block_reads" in batch.args
    assert m.value("serve.batches") == 2
    assert m.value("serve.queries") == 8
    assert m.value("serve.block_reads", "seg0") == 0
    assert m.snapshot()["serve.batch_block_reads"][""]["count"] == 2
    # registry view and stats dict can never disagree
    assert m.value("serve.total_block_reads") == 0
