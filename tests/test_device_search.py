"""Device-side batched search vs host oracle + ground truth.

The jit-compiling searches (full ``device_anns``/``device_range_search``
traces) are marked ``slow``; the fast lane (`make test-fast` / CI's
device lane) keeps the pure-helper tests and the kernel suite.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import device_search as DS
from repro.core import distances as D
from repro.core.params import DeviceSearchParams
from repro.core.search import anns, recall_at_k

P48 = DeviceSearchParams(k=10, candidates=48, max_hops=256)


@pytest.fixture(scope="module")
def device_seg(small_segment):
    return DS.from_segment(small_segment)


@pytest.mark.slow
def test_device_anns_recall(device_seg, small_data):
    x, q = small_data
    r = DS.device_anns(device_seg, jnp.asarray(q), P48)
    truth = D.brute_force_knn(x, q, 10)
    assert recall_at_k(np.asarray(r.ids), truth) >= 0.8
    assert (np.asarray(r.io) > 0).all()
    # no tier-0 budget -> every touch is a cold DMA
    assert (np.asarray(r.tier0_hits) == 0).all()
    # distances must be the true distances of the returned ids
    for qi in range(4):
        valid = np.asarray(r.ids[qi]) >= 0
        dd = D.point_to_points(q[qi], x[np.asarray(r.ids[qi])[valid]])
        np.testing.assert_allclose(np.asarray(r.dists[qi])[valid], dd,
                                   rtol=1e-3, atol=1e-2)


@pytest.mark.slow
def test_device_io_comparable_to_host(device_seg, small_segment,
                                      small_data):
    x, q = small_data
    r = DS.device_anns(device_seg, jnp.asarray(q), P48)
    _, _, host_stats = anns(small_segment.view, q, 10,
                            small_segment.params.search)
    host_io = np.mean([s.block_reads for s in host_stats])
    assert np.asarray(r.io).mean() <= host_io * 1.5


# ------------------------------------------------------------ tier 0

@pytest.mark.slow
@pytest.mark.parametrize("fetch_width", [1, 2, 4])
def test_tier0_bit_identity_across_budgets(small_segment, small_data,
                                           fetch_width):
    """ISSUE 3 acceptance: tier-0-cached device_anns returns identical
    (ids, dists) to the uncached path for every fetch width and budget
    — including budget 0 and budget >= all blocks — while block touches
    (io + tier0_hits) stay constant and only migrate between tiers."""
    _, q = small_data
    p = dataclasses.replace(P48, max_hops=64, fetch_width=fetch_width)
    base = None
    prev_io = None
    for frac in (0.0, 0.1, 0.5, 1.0):
        ds = DS.from_segment(small_segment, tier0_frac=frac)
        r = DS.device_anns(ds, jnp.asarray(q), p)
        if base is None:
            base = r
        np.testing.assert_array_equal(np.asarray(base.ids),
                                      np.asarray(r.ids))
        np.testing.assert_array_equal(np.asarray(base.dists),
                                      np.asarray(r.dists))
        np.testing.assert_array_equal(np.asarray(base.hops),
                                      np.asarray(r.hops))
        np.testing.assert_array_equal(
            np.asarray(base.io) + np.asarray(base.tier0_hits),
            np.asarray(r.io) + np.asarray(r.tier0_hits))
        io_m = float(np.asarray(r.io).mean())
        if prev_io is not None:
            assert io_m <= prev_io + 1e-9      # monotone DMA reduction
        prev_io = io_m
    # budget >= all blocks: every touch is a tier-0 hit, zero DMAs
    assert prev_io == 0.0


@pytest.mark.slow
def test_tier0_fused_matches_jnp_fetch(small_segment, small_data):
    """The fused Pallas probe+gather+rank stage and the pure-jnp
    reference fetch stage are interchangeable."""
    _, q = small_data
    ds = DS.from_segment(small_segment, tier0_frac=0.2)
    p = dataclasses.replace(P48, max_hops=64)
    rf = DS.device_anns(ds, jnp.asarray(q), p)
    rj = DS.device_anns(ds, jnp.asarray(q),
                        dataclasses.replace(p, fetch_impl="jnp"))
    np.testing.assert_array_equal(np.asarray(rf.ids), np.asarray(rj.ids))
    np.testing.assert_array_equal(np.asarray(rf.dists),
                                  np.asarray(rj.dists))
    np.testing.assert_array_equal(np.asarray(rf.io), np.asarray(rj.io))
    np.testing.assert_array_equal(np.asarray(rf.tier0_hits),
                                  np.asarray(rj.tier0_hits))


def test_tier0_pack_is_nested_and_charged(small_segment):
    """Budget selection is prefix-nested (hotset ranking + id-order
    fill) and tier0_bytes reports the packed charge."""
    ds_small = DS.from_segment(small_segment, tier0_blocks=8)
    ds_big = DS.from_segment(small_segment, tier0_blocks=32)
    hot_small = set(np.flatnonzero(
        np.asarray(ds_small.hot_slot_of) >= 0).tolist())
    hot_big = set(np.flatnonzero(
        np.asarray(ds_big.hot_slot_of) >= 0).tolist())
    assert len(hot_small) == 8 and len(hot_big) == 32
    assert hot_small < hot_big
    assert DS.tier0_bytes(ds_big) > DS.tier0_bytes(ds_small) > 0
    # the pack holds exact copies of the packed blocks
    b = next(iter(hot_small))
    s = int(np.asarray(ds_small.hot_slot_of)[b])
    np.testing.assert_array_equal(np.asarray(ds_small.hot_vecs[s]),
                                  np.asarray(ds_small.vecs[b]))
    np.testing.assert_array_equal(np.asarray(ds_small.hot_vid[s]),
                                  np.asarray(ds_small.vid[b]))
    ds_off = DS.from_segment(small_segment, tier0_blocks=0)
    assert DS.tier0_bytes(ds_off) == 0
    assert (np.asarray(ds_off.hot_slot_of) == -1).all()


# ------------------------------------------- divergence-aware batching

@pytest.mark.slow
def test_batched_matches_singletons_with_duplicates(device_seg,
                                                    small_data):
    """ISSUE 4 acceptance (deterministic twin of the hypothesis
    property test): the deduped, compacted batched search is
    bit-identical to a loop of singleton-batch searches, under a query
    permutation and with duplicate queries in the batch."""
    _, q = small_data
    p = dataclasses.replace(P48, max_hops=64, fetch_width=2,
                            compact_frac=0.5)
    perm = [5, 0, 3, 0, 7, 5, 1, 2]          # dups + shuffled order
    qb = q[perm]
    r = DS.device_anns(device_seg, jnp.asarray(qb), p)
    p1 = dataclasses.replace(p, compact_frac=0.0)
    for row, qi in enumerate(perm):
        r1 = DS.device_anns(device_seg, jnp.asarray(q[qi: qi + 1]), p1)
        np.testing.assert_array_equal(np.asarray(r1.ids[0]),
                                      np.asarray(r.ids[row]))
        np.testing.assert_array_equal(np.asarray(r1.dists[0]),
                                      np.asarray(r.dists[row]))
    # a duplicated query's cold traffic fully joins its twin's gathers
    saved = np.asarray(r.dedup_saved)
    io = np.asarray(r.io)
    assert saved[3] == io[3] and io[3] > 0    # row 3 duplicates row 1
    assert saved[5] == io[5] and io[5] > 0    # row 5 duplicates row 0
    assert saved.sum() > 0 and (saved <= io).all()


@pytest.mark.slow
def test_compaction_is_result_invariant(device_seg, small_data):
    """Active-query compaction (any threshold) never changes results or
    per-query io/tier0/hops — it only repacks rows mid-loop (and with
    it the dedup tile grouping, so only dedup_saved may move)."""
    _, q = small_data
    base = None
    for cf in (0.0, 0.25, 1.0):
        r = DS.device_anns(
            device_seg, jnp.asarray(q),
            dataclasses.replace(P48, max_hops=64,
                                compact_frac=cf))
        if base is None:
            base = r
            continue
        for f in ("ids", "dists", "io", "hops", "tier0_hits"):
            np.testing.assert_array_equal(
                np.asarray(getattr(base, f)),
                np.asarray(getattr(r, f)), err_msg=f"compact={cf} {f}")
        assert int(r.rounds) == int(base.rounds)


def test_compaction_gathers_are_cond_gated(device_seg, small_data):
    """ROADMAP (a) regression (ISSUE 5): compaction must cost nothing
    on rounds that do not compact. The permuted ``queries``/``lut``
    rows are carried in the loop state and every permutation gather
    sits behind a ``lax.cond``, so the while-loop body's *top-level*
    gather count is identical with compaction on or off — a
    no-compaction trace issues zero extra gathers per round. (Before
    the fix the compact body re-gathered queries/lut plus all eleven
    state arrays unconditionally: ~13 extra top-level gathers.)"""
    import jax

    _, q = small_data

    def while_body_gathers(p):
        closed = jax.make_jaxpr(
            lambda qq: DS.device_anns(device_seg, qq, p))(jnp.asarray(q))
        counts = []

        def walk(jaxpr):
            for eqn in jaxpr.eqns:
                if eqn.primitive.name == "while":
                    body = eqn.params["body_jaxpr"].jaxpr
                    # top level only: gathers inside cond branches are
                    # exactly the ones a non-compacting round skips
                    counts.append(sum(1 for e in body.eqns
                                      if e.primitive.name == "gather"))
                    walk(body)
                elif eqn.primitive.name in ("pjit", "scan"):
                    walk(eqn.params["jaxpr"].jaxpr)
        walk(closed.jaxpr)
        return counts

    p = dataclasses.replace(P48, max_hops=64)
    off = while_body_gathers(p)
    on = while_body_gathers(dataclasses.replace(p, compact_frac=0.5))
    assert len(off) == len(on) == 1      # one batched block-search loop
    assert on[0] == off[0], (
        f"compaction added {on[0] - off[0]} unconditional gathers per "
        f"round — the permutation must stay cond-gated")


@pytest.mark.slow
def test_dedup_counters_consistent(device_seg, small_data):
    """dedup_saved counts a subset of cold touches (io keeps its seed
    semantics: every cold touch), and duplicate queries drive it up."""
    _, q = small_data
    p = dataclasses.replace(P48, max_hops=64)
    r = DS.device_anns(device_seg, jnp.asarray(q), p)
    io, sv = np.asarray(r.io), np.asarray(r.dedup_saved)
    assert (sv >= 0).all() and (sv <= io).all()
    assert (np.asarray(r.hops) <= int(r.rounds)).all()
    qd = np.repeat(q[:4], 3, axis=0)          # heavy duplication
    rd = DS.device_anns(device_seg, jnp.asarray(qd), p)
    assert (np.asarray(rd.dedup_saved).mean()
            > sv.mean()), "duplicate-heavy batch must dedup more"


def test_cross_tile_dedup_on_duplicate_heavy_batch(device_seg,
                                                   small_data):
    """ISSUE 8 satellite (deterministic twin of the tiled hypothesis
    property): with ``round_tile_cap=8`` a 16-row batch runs as two
    round tiles, and rows 8..15 duplicating rows 0..7 sit in the
    OTHER tile — their cold traffic joins batch-wide, and every one of
    those joins is accounted in the cross-tile split."""
    _, q = small_data
    p = dataclasses.replace(P48, max_hops=64, fetch_width=2,
                            compact_frac=0.0, round_tile_cap=8)
    perm = list(range(8)) + list(range(8))    # tile 1 duplicates tile 0
    r = DS.device_anns(device_seg, jnp.asarray(q[perm]), p)
    io, sv, cx = (np.asarray(r.io), np.asarray(r.dedup_saved),
                  np.asarray(r.dedup_cross))
    assert (0 <= cx).all() and (cx <= sv).all() and (sv <= io).all()
    # a duplicate row's every request was already issued by its twin in
    # tile 0, so ALL its gathers join; the joins a tile-scope dedup
    # could not have seen (earliest requester in the other tile) land
    # in the cross-tile split — strictly positive for every dup row
    assert io[8:].sum() > 0
    np.testing.assert_array_equal(sv[8:], io[8:])
    assert (cx[8:] > 0).all()
    # tile-0 rows are the earliest requesters of every block they touch:
    # any join they make is with another tile-0 row (intra-tile only)
    assert (cx[:8] == 0).all()
    # results are invariant to the tiling itself
    r0 = DS.device_anns(device_seg, jnp.asarray(q[perm]),
                        dataclasses.replace(p, round_tile_cap=0))
    np.testing.assert_array_equal(np.asarray(r.ids), np.asarray(r0.ids))
    np.testing.assert_array_equal(np.asarray(r.dists),
                                  np.asarray(r0.dists))
    np.testing.assert_array_equal(io, np.asarray(r0.io))
    # single-tile run sees the same joins, just none of them cross-tile
    np.testing.assert_array_equal(sv, np.asarray(r0.dedup_saved))
    assert int(np.asarray(r0.dedup_cross).sum()) == 0


def test_pipeline_dma_knob_is_payload_invariant(device_seg, small_data):
    """ISSUE 8: ``pipeline_dma`` schedules the cold gather's DMAs — it
    must never change results or any per-query counter (the kernel-
    level payload identity of the double-buffered gather is pinned in
    test_kernels; this guards the end-to-end wiring)."""
    _, q = small_data
    p = dataclasses.replace(P48, max_hops=64, fetch_width=2)
    qb = jnp.asarray(q[:8])
    r_on = DS.device_anns(device_seg, qb,
                          dataclasses.replace(p, pipeline_dma=True))
    r_off = DS.device_anns(device_seg, qb,
                           dataclasses.replace(p, pipeline_dma=False))
    for f in ("ids", "dists", "io", "tier0_hits", "hops",
              "dedup_saved", "dedup_cross"):
        np.testing.assert_array_equal(
            np.asarray(getattr(r_on, f)), np.asarray(getattr(r_off, f)),
            err_msg=f"pipeline_dma changed {f}")
    assert int(r_on.rounds) == int(r_off.rounds)


@pytest.mark.slow
def test_speculation_is_result_and_counter_invariant(device_seg,
                                                     small_data):
    """ISSUE 9 acceptance (deterministic twin of the hypothesis
    property): the cross-round speculative pipeline never changes
    results or any non-speculative counter — a mis-speculated block is
    re-gathered by the authoritative path, never trusted — across
    batch sizes, round tilings and fetch widths. Its own counters obey
    hits <= paying gathers, are zero with the knob off, and are
    invariant to the round tiling (prediction runs on whole-batch
    state, unlike the dedup intra/cross split)."""
    _, q = small_data
    base_p = dataclasses.replace(P48, max_hops=64)
    last_spec = None
    for b, cap, fw in ((4, 0, 1), (8, 0, 2), (16, 0, 2), (16, 8, 2)):
        p = dataclasses.replace(base_p, round_tile_cap=cap,
                                fetch_width=fw)
        qb = jnp.asarray(q[:b])
        r0 = DS.device_anns(device_seg, qb, p)
        r1 = DS.device_anns(device_seg, qb,
                            dataclasses.replace(p, speculate=True))
        for f in ("ids", "dists", "io", "tier0_hits", "hops",
                  "dedup_saved", "dedup_cross"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r0, f)),
                np.asarray(getattr(r1, f)),
                err_msg=f"speculate changed {f} (b={b}, cap={cap}, "
                        f"fw={fw})")
        assert int(r0.rounds) == int(r1.rounds)
        assert (np.asarray(r0.spec_hits) == 0).all()
        assert (np.asarray(r0.spec_wasted) == 0).all()
        io, sv = np.asarray(r1.io), np.asarray(r1.dedup_saved)
        sh, sw = np.asarray(r1.spec_hits), np.asarray(r1.spec_wasted)
        assert (sh >= 0).all() and (sw >= 0).all()
        # a hit is a paying gather pre-issued early — never more of
        # them than the batch actually paid for
        assert (sh <= io - sv).all()
        if (b, fw) == (16, 2):
            # tiling must not move the speculation counters (cap 0 and
            # cap 8 run in consecutive iterations here)
            if last_spec is not None:
                np.testing.assert_array_equal(last_spec[0], sh)
                np.testing.assert_array_equal(last_spec[1], sw)
            last_spec = (sh, sw)
    assert sh.sum() > 0, "this workload should speculate successfully"


@pytest.mark.slow
def test_fuse_union_is_payload_invariant(device_seg, small_data):
    """ISSUE 9: the in-kernel union fusion (``fuse_union``) removes the
    host-visible pass-1 launch but must keep every result and counter
    bit-identical to the two-pass path (the kernel-level identity is
    pinned in test_kernels; this guards the end-to-end wiring)."""
    _, q = small_data
    p = dataclasses.replace(P48, max_hops=64, fetch_width=2)
    qb = jnp.asarray(q[:8])
    r_on = DS.device_anns(device_seg, qb,
                          dataclasses.replace(p, fuse_union=True))
    r_off = DS.device_anns(device_seg, qb,
                           dataclasses.replace(p, fuse_union=False))
    for f in ("ids", "dists", "io", "tier0_hits", "hops",
              "dedup_saved", "dedup_cross"):
        np.testing.assert_array_equal(
            np.asarray(getattr(r_on, f)), np.asarray(getattr(r_off, f)),
            err_msg=f"fuse_union changed {f}")
    assert int(r_on.rounds) == int(r_off.rounds)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @given(rows=st.lists(st.integers(0, 23), min_size=8, max_size=8),
           cap=st.sampled_from([0, 4]),
           fw=st.sampled_from([1, 2]))
    @settings(max_examples=6, deadline=None)
    def test_speculation_invariance_property(rows, cap, fw, device_seg,
                                             small_data):
        """ANY batch composition x tiling x fetch width: speculation
        on/off ``(ids, dists)`` and every shared counter (including
        the zeroed spec columns of the off run) are bit-identical."""
        _, q = small_data
        p = dataclasses.replace(P48, max_hops=64, round_tile_cap=cap,
                                fetch_width=fw)
        qb = jnp.asarray(q[np.asarray(rows)])
        r0 = DS.device_anns(device_seg, qb, p)
        r1 = DS.device_anns(device_seg, qb,
                            dataclasses.replace(p, speculate=True))
        for f in ("ids", "dists", "io", "tier0_hits", "hops",
                  "dedup_saved", "dedup_cross"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r0, f)), np.asarray(getattr(r1, f)))
        assert int(r0.rounds) == int(r1.rounds)
        assert not np.asarray(r0.spec_hits).any()
        assert not np.asarray(r0.spec_wasted).any()
        sh = np.asarray(r1.spec_hits)
        assert (sh <= np.asarray(r1.io)
                - np.asarray(r1.dedup_saved)).all()


def test_tier0_repack_from_observed_frequencies(small_segment):
    """ISSUE 4 satellite (dynamic tier-0 admission): a drifted observed
    frequency profile re-ranks the pack — the observed-hot blocks enter
    at a budget that would otherwise exclude them — while search
    results stay bit-identical (exact copies either way)."""
    rho = small_segment.view.store.num_blocks
    ds_static = DS.from_segment(small_segment, tier0_blocks=4)
    static_hot = set(np.flatnonzero(
        np.asarray(ds_static.hot_slot_of) >= 0).tolist())
    drifted = [b for b in range(rho) if b not in static_hot][:4]
    observed = {b: 100 + i for i, b in enumerate(drifted)}
    ds_dyn = DS.from_segment(small_segment, tier0_blocks=4,
                             observed=observed)
    dyn_hot = set(np.flatnonzero(
        np.asarray(ds_dyn.hot_slot_of) >= 0).tolist())
    assert dyn_hot == set(drifted), \
        "observed-hot blocks must displace the build-time pack"
    # higher observed count -> earlier slot (frequency-desc ranking)
    slots = np.asarray(ds_dyn.hot_slot_of)[drifted]
    assert (np.argsort(-np.asarray(
        [observed[b] for b in drifted])) == np.argsort(slots)).all()
    # the pack still holds exact copies
    b = drifted[0]
    s = int(np.asarray(ds_dyn.hot_slot_of)[b])
    np.testing.assert_array_equal(np.asarray(ds_dyn.hot_vecs[s]),
                                  np.asarray(ds_dyn.vecs[b]))


@pytest.mark.slow
def test_tier0_repack_results_bit_identical(small_segment, small_data):
    _, q = small_data
    p = dataclasses.replace(P48, max_hops=64)
    r0 = DS.device_anns(DS.from_segment(small_segment, tier0_blocks=8),
                        jnp.asarray(q[:8]), p)
    rho = small_segment.view.store.num_blocks
    r1 = DS.device_anns(
        DS.from_segment(small_segment, tier0_blocks=8,
                        observed={b: rho - b for b in range(rho)}),
        jnp.asarray(q[:8]), p)
    np.testing.assert_array_equal(np.asarray(r0.ids), np.asarray(r1.ids))
    np.testing.assert_array_equal(np.asarray(r0.dists),
                                  np.asarray(r1.dists))
    np.testing.assert_array_equal(
        np.asarray(r0.io) + np.asarray(r0.tier0_hits),
        np.asarray(r1.io) + np.asarray(r1.tier0_hits))


# -------------------------------------------------------- range search

@pytest.mark.slow
def test_device_range_search(device_seg, small_data):
    x, q = small_data
    d_gt = D.pairwise(q, x)
    radius = float(np.quantile(d_gt, 0.002))
    r = DS.device_range_search(
        device_seg, jnp.asarray(q), radius=radius, k_cap=64,
        p=DeviceSearchParams(k=10, candidates=32, max_hops=256))
    gt = D.brute_force_range(x, q, radius)
    hits = 0
    total = 0
    for qi in range(q.shape[0]):
        got = set(np.asarray(r.ids[qi])[np.asarray(
            r.in_range[qi])].tolist())
        want = set(gt[qi].tolist())
        if want:
            hits += len(got & want)
            total += len(want)
    assert total == 0 or hits / total >= 0.6


@pytest.mark.slow
def test_device_range_search_io_flat_across_rounds(device_seg,
                                                   small_data):
    """ISSUE 3 satellite regression: RS rounds thread the visited/
    result state, so a later round must NOT re-read (and re-count in
    ``io``) the blocks earlier rounds already fetched.

    Before the fix every round re-ran ``device_anns`` from scratch, so
    round r's DMA count matched a fresh search at that round's beam.
    After the fix each round only pays for *newly expanded* blocks: its
    DMA increment must stay well under the from-scratch cost, and the
    3-round total well under the pre-fix sum of scratch runs."""
    x, q = small_data
    d_gt = D.pairwise(q, x)
    radius = float(np.quantile(d_gt, 0.002))
    p = DeviceSearchParams(k=10, candidates=32, max_hops=256)
    io = {}
    for rounds in (1, 2, 3):
        r = DS.device_range_search(device_seg, jnp.asarray(q),
                                   radius=radius, k_cap=128, p=p,
                                   rounds=rounds)
        io[rounds] = float(np.asarray(r.io).mean())
    # the pre-fix behavior: a fresh search per round at the doubled beam
    scratch = {}
    for c in (32, 64, 128):
        rs = DS.device_anns(
            device_seg, jnp.asarray(q),
            DeviceSearchParams(k=c, candidates=c, max_hops=256))
        scratch[c] = float(np.asarray(rs.io).mean())
    assert io[1] == scratch[32]            # round 1 is a plain search
    # each resumed round fetches far fewer blocks than a scratch run at
    # the same beam (it skips everything already expanded)
    assert io[2] - io[1] <= 0.75 * scratch[64]
    assert io[3] - io[2] <= 0.75 * scratch[128]
    # and the total stays well under the pre-fix sum
    pre_fix_total = scratch[32] + scratch[64] + scratch[128]
    assert io[3] <= 0.65 * pre_fix_total, (
        f"RS DMAs must stay near-flat across rounds (threaded total "
        f"{io[3]:.1f} vs pre-fix {pre_fix_total:.1f})")


# ------------------------------------------------------------- helpers

def test_visited_bitmask_helpers():
    mask = jnp.zeros((2, 4), jnp.uint32)
    ids = jnp.asarray([5, 97])
    mask = DS._bit_set(mask, ids, jnp.asarray([True, True]))
    got = DS._bit_get(mask, jnp.asarray([[5, 6, 97], [97, 5, 0]]))
    np.testing.assert_array_equal(
        np.asarray(got), [[True, False, False], [True, False, False]])


def test_merge_top_dedup():
    keys = jnp.asarray([[1.0, 3.0, jnp.inf]])
    ids = jnp.asarray([[7, 9, -1]], jnp.int32)
    nk = jnp.asarray([[0.5, 1.0, 2.0]])
    ni = jnp.asarray([[9, 7, 11]], jnp.int32)
    k, i = DS._merge_top(keys, ids, nk, ni, 4)
    # 9 appears twice (3.0 and 0.5): keep 0.5; 7 twice (1.0 both)
    assert i[0, 0] == 9 and float(k[0, 0]) == 0.5
    assert 11 in np.asarray(i[0]).tolist()
    vals = np.asarray(i[0]).tolist()
    assert len([v for v in vals if v == 9]) == 1


def test_device_search_params_validation():
    with pytest.raises(ValueError):
        DeviceSearchParams(k=0)
    with pytest.raises(ValueError):
        DeviceSearchParams(k=10, candidates=4)
    with pytest.raises(ValueError):
        DeviceSearchParams(fetch_impl="mosaic")
    with pytest.raises(ValueError):
        DeviceSearchParams(tier0_frac=1.5)


@pytest.mark.slow
def test_fetch_width_cuts_round_trips(device_seg, small_data):
    """§Perf cell 3: F blocks per round trip -> ~F-fold fewer trips at
    comparable recall and block reads."""
    x, q = small_data
    truth = D.brute_force_knn(x, q, 10)
    res = {}
    for fw in (1, 2):
        r = DS.device_anns(
            device_seg, jnp.asarray(q),
            dataclasses.replace(P48, fetch_width=fw))
        res[fw] = (recall_at_k(np.asarray(r.ids), truth),
                   float(np.asarray(r.io).mean()),
                   float(np.asarray(r.hops).mean()))
    assert res[2][0] >= res[1][0] - 0.05          # recall preserved
    assert res[2][2] <= 0.62 * res[1][2]          # trips ~halve
    assert res[2][1] <= 1.5 * res[1][1]           # bandwidth bounded
