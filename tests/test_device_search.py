"""Device-side batched search vs host oracle + ground truth."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import device_search as DS
from repro.core import distances as D
from repro.core.search import anns, recall_at_k


@pytest.fixture(scope="module")
def device_seg(small_segment):
    return DS.from_segment(small_segment)


def test_device_anns_recall(device_seg, small_data):
    x, q = small_data
    ids, dists, io, hops = DS.device_anns(
        device_seg, jnp.asarray(q), k=10, candidates=48, max_hops=256)
    truth = D.brute_force_knn(x, q, 10)
    assert recall_at_k(np.asarray(ids), truth) >= 0.8
    assert (np.asarray(io) > 0).all()
    # distances must be the true distances of the returned ids
    for qi in range(4):
        valid = np.asarray(ids[qi]) >= 0
        dd = D.point_to_points(q[qi], x[np.asarray(ids[qi])[valid]])
        np.testing.assert_allclose(np.asarray(dists[qi])[valid], dd,
                                   rtol=1e-3, atol=1e-2)


def test_device_io_comparable_to_host(device_seg, small_segment,
                                      small_data):
    x, q = small_data
    _, _, io, _ = DS.device_anns(device_seg, jnp.asarray(q), k=10,
                                 candidates=48, max_hops=256)
    _, _, host_stats = anns(small_segment.view, q, 10,
                            small_segment.params.search)
    host_io = np.mean([s.block_reads for s in host_stats])
    assert np.asarray(io).mean() <= host_io * 1.5


def test_device_range_search(device_seg, small_data):
    x, q = small_data
    d_gt = D.pairwise(q, x)
    radius = float(np.quantile(d_gt, 0.002))
    ids, dists, in_range, io = DS.device_range_search(
        device_seg, jnp.asarray(q), radius=radius, k_cap=64,
        max_hops=256)
    gt = D.brute_force_range(x, q, radius)
    hits = 0
    total = 0
    for qi in range(q.shape[0]):
        got = set(np.asarray(ids[qi])[np.asarray(in_range[qi])].tolist())
        want = set(gt[qi].tolist())
        if want:
            hits += len(got & want)
            total += len(want)
    assert total == 0 or hits / total >= 0.6


def test_visited_bitmask_helpers():
    mask = jnp.zeros((2, 4), jnp.uint32)
    ids = jnp.asarray([5, 97])
    mask = DS._bit_set(mask, ids, jnp.asarray([True, True]))
    got = DS._bit_get(mask, jnp.asarray([[5, 6, 97], [97, 5, 0]]))
    np.testing.assert_array_equal(
        np.asarray(got), [[True, False, False], [True, False, False]])


def test_merge_top_dedup():
    keys = jnp.asarray([[1.0, 3.0, jnp.inf]])
    ids = jnp.asarray([[7, 9, -1]], jnp.int32)
    nk = jnp.asarray([[0.5, 1.0, 2.0]])
    ni = jnp.asarray([[9, 7, 11]], jnp.int32)
    k, i = DS._merge_top(keys, ids, nk, ni, 4)
    # 9 appears twice (3.0 and 0.5): keep 0.5; 7 twice (1.0 both)
    assert i[0, 0] == 9 and float(k[0, 0]) == 0.5
    assert 11 in np.asarray(i[0]).tolist()
    vals = np.asarray(i[0]).tolist()
    assert len([v for v in vals if v == 9]) == 1


def test_fetch_width_cuts_round_trips(device_seg, small_data):
    """§Perf cell 3: F blocks per round trip -> ~F-fold fewer trips at
    comparable recall and block reads."""
    import jax.numpy as jnp
    x, q = small_data
    truth = D.brute_force_knn(x, q, 10)
    res = {}
    for fw in (1, 2):
        ids, _, io, trips = DS.device_anns(
            device_seg, jnp.asarray(q), k=10, candidates=48,
            max_hops=256, fetch_width=fw)
        res[fw] = (recall_at_k(np.asarray(ids), truth),
                   float(np.asarray(io).mean()),
                   float(np.asarray(trips).mean()))
    assert res[2][0] >= res[1][0] - 0.05          # recall preserved
    assert res[2][2] <= 0.62 * res[1][2]          # trips ~halve
    assert res[2][1] <= 1.5 * res[1][1]           # bandwidth bounded
