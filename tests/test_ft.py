"""Fault tolerance: checkpoint/restart exactness, retention, straggler
detection, elastic re-mesh planning."""
import os

import jax
import numpy as np
import pytest

from repro.configs import SMOKE_CONFIGS
from repro.data.pipeline import TokenPipeline
from repro.distributed.elastic import plan_remesh
from repro.ft.checkpoint import CheckpointManager
from repro.ft.straggler import HeartbeatMonitor
from repro.launch.train import default_optimizer, make_train_step
from repro.models import lm
from repro.optim import adamw_init


def _train(cfg, step_fn, params, opt_state, pipe, steps):
    for _ in range(steps):
        batch = pipe.next_batch(cfg)
        params, opt_state, _ = step_fn(params, opt_state, batch)
    return params, opt_state


@pytest.mark.slow   # jit-compiles a full train step (~6s)
def test_checkpoint_restart_bitexact(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    cfg = SMOKE_CONFIGS["gemma3-1b"]
    step_fn = jax.jit(make_train_step(cfg, default_optimizer()))
    params0 = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt0 = adamw_init(params0)

    pipe_a = TokenPipeline(cfg.vocab_size, batch=2, seq=16, seed=0)
    pa, oa = _train(cfg, step_fn, params0, opt0, pipe_a, 6)

    pipe_b = TokenPipeline(cfg.vocab_size, batch=2, seq=16, seed=0)
    pb, ob = _train(cfg, step_fn, params0, opt0, pipe_b, 3)
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    ckpt.save(3, pb, ob, pipe_b.get_state())

    # simulate restart: fresh trees, restore, resume
    pipe_c = TokenPipeline(cfg.vocab_size, batch=2, seq=16, seed=0)
    pr, orr, pipe_state, step = ckpt.restore(params0, opt0)
    pipe_c.set_state(pipe_state)
    assert step == 3
    pc, oc = _train(cfg, step_fn, pr, orr, pipe_c, 3)

    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    cfg = SMOKE_CONFIGS["whisper-base"]
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ckpt.save(s, params, opt, {"step": s, "seed": 0})
    assert ckpt.steps() == [3, 4]
    assert ckpt.latest_step() == 4


def test_checkpoint_atomicity_no_tmp_visible(tmp_path):
    cfg = SMOKE_CONFIGS["whisper-base"]
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ckpt = CheckpointManager(str(tmp_path), keep=3)
    ckpt.save(7, params, opt, {"step": 7, "seed": 0})
    names = os.listdir(tmp_path)
    assert not any(".tmp" in n for n in names)
    assert "step_00000007" in names


def test_straggler_detection():
    mon = HeartbeatMonitor(num_nodes=8, timeout=10.0,
                           straggler_factor=2.0)
    now = 100.0
    for node in range(6):
        mon.beat(node, step_s=1.0, now=now)
    mon.beat(6, step_s=5.0, now=now)          # straggler
    # node 7 never beats -> dead
    rep = mon.report(now=now + 1.0)
    assert rep.dead == [7]
    assert rep.stragglers == [6]
    assert set(rep.healthy) == set(range(6))


def test_elastic_remesh_plan():
    # full fleet: 512 chips = 2 pods x 16 x 16
    p = plan_remesh(512, model=16, global_batch=256, pods=2)
    assert p.chips == 512 and p.data == 16
    # lose 17 chips: shrink data axis to 8 per pod
    p = plan_remesh(495, model=16, global_batch=256, pods=2)
    assert p.chips == 256 and p.data == 8
    assert p.per_device_batch * p.data * p.pods * p.grad_accum == 256
    # heavy loss: largest power-of-two data axis that fits (pods may
    # shrink or data may — both land on 128 chips here)
    p = plan_remesh(250, model=16, global_batch=256, pods=2)
    assert p.chips == 128
    assert p.per_device_batch * p.data * p.pods * p.grad_accum == 256
    # not even one TP group left
    assert plan_remesh(8, model=16, global_batch=256, pods=1) is None


def test_pipeline_state_resume():
    a = TokenPipeline(1000, batch=2, seq=8, seed=5)
    for _ in range(4):
        a.next_batch()
    state = a.get_state()
    b1 = a.next_batch()
    b = TokenPipeline(1000, batch=2, seq=8, seed=5)
    b.set_state(state)
    b2 = b.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
