"""Product-quantization properties (§5.1 PQ routing)."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; rest of the suite runs without")
from hypothesis import given, settings, strategies as st

from repro.core import distances as D
from repro.core.params import PQParams
from repro.pq import (adc_distance, adc_lut, adc_lut_batch, encode_pq,
                      reconstruct, train_pq)


def test_pq_roundtrip_error_small():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4000, 32)).astype(np.float32)
    cb = train_pq(x, PQParams(num_subspaces=8, train_iters=8))
    codes = encode_pq(x, cb)
    rec = reconstruct(codes, cb)
    rel = np.linalg.norm(x - rec, axis=1) / np.linalg.norm(x, axis=1)
    assert rel.mean() < 0.6            # 4 dims/subspace @ 256 centroids


def test_adc_matches_reconstructed_distance():
    """ADC(q, code) == ||q - reconstruct(code)||^2 exactly (L2)."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((1000, 16)).astype(np.float32)
    cb = train_pq(x, PQParams(num_subspaces=4, train_iters=6))
    codes = encode_pq(x, cb)
    q = rng.standard_normal(16).astype(np.float32)
    lut = adc_lut(q, cb)
    adc = adc_distance(lut, codes[:50])
    exact = D.point_to_points(q, reconstruct(codes[:50], cb))
    np.testing.assert_allclose(adc, exact, rtol=2e-4, atol=1e-4)


def test_adc_ranking_correlates_with_exact():
    rng = np.random.default_rng(2)
    x = rng.standard_normal((2000, 32)).astype(np.float32)
    cb = train_pq(x, PQParams(num_subspaces=8, train_iters=8))
    codes = encode_pq(x, cb)
    q = rng.standard_normal(32).astype(np.float32)
    adc = adc_distance(adc_lut(q, cb), codes)
    exact = D.point_to_points(q, x)
    # top-50 by ADC should capture most of exact top-10
    top_adc = set(np.argsort(adc)[:50].tolist())
    top_exact = set(np.argsort(exact)[:10].tolist())
    assert len(top_adc & top_exact) >= 7


@settings(deadline=None, max_examples=10)
@given(m=st.sampled_from([2, 4, 8]), metric=st.sampled_from(["l2", "ip"]))
def test_lut_batch_consistency(m, metric):
    rng = np.random.default_rng(3)
    x = rng.standard_normal((600, 16)).astype(np.float32)
    cb = train_pq(x, PQParams(num_subspaces=m, train_iters=4), metric)
    q = rng.standard_normal((5, 16)).astype(np.float32)
    batch = adc_lut_batch(q, cb)
    for i in range(5):
        np.testing.assert_allclose(batch[i], adc_lut(q[i], cb),
                                   rtol=1e-5, atol=1e-5)
