"""Graph-index construction invariants (Vamana/NSG/HNSW flavours)."""
import numpy as np
import pytest

from repro.core import distances as D
from repro.core import graph as G
from repro.core.params import GraphParams
from repro.data.vectors import clustered_vectors


@pytest.fixture(scope="module")
def vecs():
    return clustered_vectors(600, 16, num_clusters=8, seed=7)


@pytest.mark.parametrize("algo", ["vamana", "nsg", "hnsw"])
def test_build_invariants(vecs, algo):
    p = GraphParams(max_degree=12, build_beam=24, algo=algo)
    g = G.build_graph(vecs, p)
    n = g.num_vertices
    assert n == vecs.shape[0]
    assert (g.deg >= 0).all() and (g.deg <= g.max_degree).all()
    valid = g.adj[g.adj >= 0]
    assert valid.max() < n
    # no self loops
    rows = np.repeat(np.arange(n), g.deg)
    assert not (g.adj[g.adj >= 0] == rows).any()
    assert g.deg.mean() >= 2


def test_greedy_search_finds_near_neighbor(vecs):
    p = GraphParams(max_degree=16, build_beam=32, algo="vamana")
    g = G.build_graph(vecs, p)
    q = vecs[:8] + 0.01
    ids, dists, _ = G.greedy_search_batch(
        vecs, g.adj, g.deg, g.entry, q, beam=24)
    truth = D.brute_force_knn(vecs, q, 1)
    hits = sum(int(truth[i, 0]) in set(ids[i].tolist()) for i in range(8))
    assert hits >= 7


def test_robust_prune_degree_bound(vecs):
    cand = np.arange(1, 100, dtype=np.int32)
    cd = D.point_to_points(vecs[0], vecs[cand]).astype(np.float32)
    sel = G.robust_prune(0, cand, cd, vecs, max_degree=8, alpha=1.2)
    assert sel.shape[0] <= 8
    assert 0 not in sel.tolist()
    assert len(set(sel.tolist())) == sel.shape[0]


def test_nsg_reachability(vecs):
    p = GraphParams(max_degree=10, build_beam=20, algo="nsg")
    g = G.build_graph(vecs, p)
    seen = np.zeros(g.num_vertices, bool)
    stack = [g.entry]
    seen[g.entry] = True
    while stack:
        u = stack.pop()
        for v in g.adj[u, : g.deg[u]]:
            if not seen[v]:
                seen[v] = True
                stack.append(int(v))
    assert seen.all()


def test_hnsw_layers(vecs):
    p = GraphParams(max_degree=12, build_beam=24, algo="hnsw")
    h = G.build_hnsw(vecs, p)
    assert len(h.layers) >= 1
    sizes = [ids.size for ids in h.level_ids]
    assert sizes == sorted(sizes, reverse=True)
