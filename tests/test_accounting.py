"""Space/byte accounting: Example 2, Eq. 8/10, segment budgets (§2.2,
§4.1, §6.4) — plus the IOStats merge semantics and the round-granular
cost model (ISSUE 5)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.starling_segment import PAPER_DATASETS
from repro.core.iostats import IOStats, NVME_SEGMENT, TPU_HBM_SEGMENT
from repro.core.params import LayoutParams


@pytest.mark.parametrize("name", list(PAPER_DATASETS))
def test_example2_block_math(name):
    """Reproduce the paper's per-dataset (gamma, eps, rho) exactly
    (Example 2 + Tab. 16)."""
    n, dim, dtype_b, lam, eta, eps_want, rho_want = PAPER_DATASETS[name]
    lp = LayoutParams(block_kb=eta)
    eps = lp.verts_per_block(dim, lam, dtype_bytes=dtype_b)
    assert eps == eps_want
    rho = lp.num_blocks(n, dim, lam, dtype_bytes=dtype_b)
    assert rho == rho_want


def test_bigann_example2_exact_numbers():
    """BIGANN: gamma = (128 + 4 + 31*4)/1024 KB -> eps=16, rho=2,062,500."""
    lp = LayoutParams(block_kb=4)
    gamma_bytes = 128 * 1 + 4 + 31 * 4
    assert gamma_bytes == 256
    assert lp.verts_per_block(128, 31, dtype_bytes=1) == 16
    assert lp.num_blocks(33_000_000, 128, 31, dtype_bytes=1) == 2_062_500


def test_segment_budget_accounting(small_segment):
    seg = small_segment
    # Eq. 10 components all positive and memory < disk
    mem = seg.memory_bytes()
    disk = seg.disk_bytes()
    assert 0 < mem < disk
    ok = seg.check_budget()
    assert ok["memory_ok"] and ok["disk_ok"]
    # mapping charge is exactly 8 bytes/vertex (block id + slot, int32)
    assert seg.view.layout.mapping_bytes() == seg.num_vectors * 8


def test_tier0_budget_charged_into_eq10(small_segment):
    """ISSUE 3 acceptance: the device hot-tile budget is a C_tier0 term
    of Eq. 10 and is capped by the VMEM budget."""
    import dataclasses
    from repro.core.params import CacheParams
    seg = small_segment
    base_mem = seg.memory_bytes()
    assert seg.tier0_bytes() == 0
    seg10 = dataclasses.replace(
        seg, params=dataclasses.replace(
            seg.params, cache=CacheParams(tier0_frac=0.10)))
    want = int(0.10 * seg.disk_bytes())
    assert seg10.tier0_bytes() == want
    assert seg10.memory_bytes() == base_mem + want
    ok = seg10.check_budget()
    assert ok["tier0_ok"] and ok["memory_ok"]
    # the packed device arrays respect the same budget (block-rounded)
    from repro.core import device_search as DS
    ds = DS.from_segment(seg10)
    assert 0 < DS.tier0_bytes(ds) <= want
    assert DS.tier0_bytes(ds) <= seg.params.budget.tier0_vmem_bytes


def test_disk_bytes_are_block_aligned(small_segment):
    seg = small_segment
    store = seg.view.store
    assert seg.disk_bytes() == int(store.num_blocks * store.block_kb
                                   * 1024)


def test_build_times_recorded(small_segment):
    t = small_segment.build_times
    for key in ("disk_graph_s", "shuffling_s", "memory_graph_s", "pq_s"):
        assert key in t and t[key] >= 0
    # paper: shuffling is a small fraction of graph construction
    assert t["shuffling_s"] < t["disk_graph_s"]


def test_save_load_roundtrip(small_segment, tmp_path, small_data):
    from repro.core.segment import load_segment, save_segment
    from repro.core.search import anns
    x, q = small_data
    path = str(tmp_path / "seg.npz")
    save_segment(small_segment, path)
    seg2 = load_segment(path, small_segment.params)
    ids1, _, _ = anns(small_segment.view, q[:4], 5,
                      small_segment.params.search)
    ids2, _, _ = anns(seg2.view, q[:4], 5, small_segment.params.search)
    np.testing.assert_array_equal(ids1, ids2)


# -------------------------------------- IOStats merge semantics (PR 2–5)

def test_iostats_merge_additive_and_max_counters():
    """ISSUE 5 coverage gap: the PR 2–4 counters' merge semantics.
    dedup_saved_fetches and rounds_active_weight are additive across
    queries; inflight_peak and batch_rounds are level/shared values and
    merge by max."""
    a = IOStats(block_reads=5, cache_misses=5, dedup_saved_fetches=2,
                rounds_active_weight=0.5, inflight_peak=3,
                batch_rounds=10, hops=4, hops_to_best=2)
    b = IOStats(block_reads=3, cache_misses=3, dedup_saved_fetches=1,
                rounds_active_weight=0.75, inflight_peak=7,
                batch_rounds=6, hops=6, hops_to_best=5)
    a.merge(b)
    assert a.dedup_saved_fetches == 3          # additive
    assert a.rounds_active_weight == 1.25      # additive (occupancy sum)
    assert a.inflight_peak == 7                # max-merge
    assert a.batch_rounds == 10                # max-merge (shared level)
    assert a.hops_to_best == 5                 # max-merge
    assert a.hops == 10 and a.block_reads == 8


def test_iostats_merge_still_validates_trip_invariant():
    a = IOStats(block_reads=2, io_round_trips=2)
    bad = IOStats(block_reads=0, io_round_trips=1)
    with pytest.raises(ValueError):
        a.merge(bad)
    # the failed merge left the accumulator untouched
    assert a.io_round_trips == 2 and a.block_reads == 2


def test_from_device_sets_round_columns():
    s = IOStats.from_device(10, 3, 6, 2, 8)
    assert s.block_reads == 13 and s.cache_misses == 10
    assert s.io_round_trips == 8               # io - dedup_saved
    assert s.batch_rounds == 8
    assert s.rounds_active_weight == 6 / 8
    s0 = IOStats.from_device(4, 0, 4, 0, 0)    # no round count carried
    assert s0.batch_rounds == 0 and s0.rounds_active_weight == 0.0


def test_from_device_batch_fold():
    """The batch fold = per-query from_device merged: counters sum,
    batch_rounds is the shared round count, rounds_active_weight the
    mean live queries per round."""
    io, t0 = [10, 4, 0], [3, 1, 0]
    hops, sv = [6, 8, 0], [2, 0, 0]
    agg = IOStats.from_device_batch(io, t0, hops, sv, 8)
    assert agg.block_reads == 18 and agg.cache_misses == 14
    assert agg.io_round_trips == 12
    assert agg.batch_rounds == 8
    assert agg.rounds_active_weight == pytest.approx(14 / 8)
    assert agg.hops == 14 and agg.dedup_saved_fetches == 2


# ---------------------- split dedup counters + DMA pipelining (ISSUE 8)

def test_dedup_split_counters_are_additive_and_bounded():
    """ISSUE 8 satellite: dedup_saved_fetches is BATCH scope (the whole
    union the kernel dedups across) and dedup_cross_tile is its
    cross-tile subset — both additive under merge (a sum of queries'
    splits is the batch's split), with the subset clamped to the
    total."""
    a = IOStats.from_device(10, 0, 5, 4, 8, dedup_cross=3)
    b = IOStats.from_device(6, 0, 4, 2, 8, dedup_cross=1)
    assert a.dedup_saved_fetches == 4 and a.dedup_cross_tile == 3
    a.merge(b)
    assert a.dedup_saved_fetches == 6          # additive
    assert a.dedup_cross_tile == 4             # additive
    # the subset can never exceed the total it refines
    c = IOStats.from_device(5, 0, 3, 2, 8, dedup_cross=9)
    assert c.dedup_cross_tile == c.dedup_saved_fetches == 2
    # per-tile dedup's modeled DMAs are reconstructible from the split:
    # io - (saved - cross) >= io - saved (batch scope saves more)
    tile_dma = a.cache_misses - (a.dedup_saved_fetches
                                 - a.dedup_cross_tile)
    assert tile_dma == 14 > a.io_round_trips == 10


def test_dma_pipelined_flag_merges_by_max():
    """dma_pipelined is a flag (the batch ran double-buffered), not a
    count: max-merged like batch_rounds, never summed."""
    a = IOStats.from_device(4, 0, 2, 0, 4, pipelined=True)
    b = IOStats.from_device(4, 0, 2, 0, 4, pipelined=True)
    a.merge(b)
    assert a.dma_pipelined == 1
    off = IOStats.from_device(4, 0, 2, 0, 4)
    assert off.dma_pipelined == 0


def test_from_device_batch_folds_cross_column():
    io, t0 = [10, 4, 0], [3, 1, 0]
    hops, sv, cx = [6, 8, 0], [2, 1, 0], [1, 1, 0]
    agg = IOStats.from_device_batch(io, t0, hops, sv, 8, cx, True)
    assert agg.dedup_saved_fetches == 3
    assert agg.dedup_cross_tile == 2
    assert agg.dma_pipelined == 1
    # pre-split callers (5-column folds) price the subset as zero
    legacy = IOStats.from_device_batch(io, t0, hops, sv, 8)
    assert legacy.dedup_cross_tile == 0
    assert legacy.dedup_saved_fetches == 3
    assert legacy.dma_pipelined == 0


def test_pipelined_pricing_overlaps_stream_with_round_comp():
    """DESIGN.md §8: with dma_pipelined set, the round-granular model
    prices the streaming cold-DMA term against the occupancy-weighted
    round compute as max(dma, compute) — strictly cheaper than the
    serial sum whenever both are positive, never cheaper than the
    bigger of the two, and a no-op on unflagged stats."""
    cm = TPU_HBM_SEGMENT
    cols = ([10, 4], [3, 1], [6, 8], [2, 0], 8)
    serial = IOStats.from_device_batch(*cols)
    piped = IOStats.from_device_batch(*cols, pipelined=True)
    t_serial = cm.latency_us(serial)
    t_piped = cm.latency_us(piped)
    stream = cm._stream_dma(piped)
    rcomp = cm.breakdown(piped)["t_round_comp_us"]
    assert stream > 0 and rcomp > 0
    assert t_piped == pytest.approx(t_serial - min(stream, rcomp))
    assert t_piped < t_serial
    # the outer §5.1 pipeline (max of whole t_io/t_comp) still wins —
    # the per-round overlap never double-counts with it
    assert cm.latency_us(piped, pipeline=True) == pytest.approx(
        cm.latency_us(serial, pipeline=True))
    # breakdown exposes the overlapped term
    br = cm.breakdown(piped)
    assert br["dma_pipelined"] is True
    assert br["t_dma_stream_us"] == pytest.approx(stream)


# ------------------------------------- round-granular cost model (d)

def test_round_granular_pricing_monotone_in_occupancy():
    """ROADMAP (d): with batch_rounds carried, the TPU model charges
    the lockstep chain once and occupancy-weighted compute per live
    query-round — strictly monotone in rounds_active_weight."""
    cm = TPU_HBM_SEGMENT
    assert cm.t_round > 0 and cm.t_round_comp > 0
    agg = IOStats.from_device_batch([10, 4], [3, 1], [6, 8], [2, 0], 8)
    base = cm.latency_us(agg)
    denser = dataclasses.replace(
        agg, rounds_active_weight=agg.rounds_active_weight * 2)
    assert cm.latency_us(denser) > base
    br = cm.breakdown(agg)
    assert br["t_round_chain_us"] == pytest.approx(8 * cm.t_round)
    assert br["t_round_comp_us"] == pytest.approx(
        8 * agg.rounds_active_weight * cm.t_round_comp)
    # in the round-granular regime cold DMAs stream at bandwidth: the
    # io term is chain + DMAs x t_batch_block + broadcast touches
    dma = agg.cache_misses - agg.dedup_saved_fetches
    assert br["t_io_us"] == pytest.approx(
        8 * cm.t_round + dma * cm.t_batch_block
        + agg.dedup_saved_fetches * cm.t_dedup_hit
        + agg.tier0_hits * cm.t_tier0_hit)


# ------------- cross-round speculative pipeline (ISSUE 9, DESIGN.md §9)

def test_from_device_clamps_spec_hits_to_paying_gathers():
    """spec_hits counts paying gathers the speculative pipeline
    pre-issued, so it can never exceed ``io - dedup_saved``; the
    builder clamps rather than trusting the caller, and the flag
    travels only when speculation actually ran."""
    s = IOStats.from_device(10, 0, 5, 4, 8, spec_hits=9, spec_wasted=3,
                            speculative=True)
    assert s.spec_hits == 6                    # clamped to io - saved
    assert s.spec_wasted == 3
    assert s.dma_speculative == 1
    off = IOStats.from_device(10, 0, 5, 4, 8)
    assert off.spec_hits == 0 and off.spec_wasted == 0
    assert off.dma_speculative == 0


def test_spec_counters_merge_additive_flag_by_max():
    """Hits and waste are per-query work counts (additive across a
    fold); dma_speculative is a batch-level regime flag (max-merge,
    like dma_pipelined)."""
    a = IOStats.from_device(10, 0, 5, 2, 8, spec_hits=3, spec_wasted=1,
                            speculative=True)
    b = IOStats.from_device(6, 0, 4, 1, 8, spec_hits=2, spec_wasted=4,
                            speculative=True)
    a.merge(b)
    assert a.spec_hits == 5 and a.spec_wasted == 5
    assert a.dma_speculative == 1


def test_from_device_batch_folds_spec_columns():
    io, t0 = [10, 4, 0], [3, 1, 0]
    hops, sv, cx = [6, 8, 0], [2, 1, 0], [1, 1, 0]
    sh, sw = [3, 1, 0], [2, 0, 0]
    agg = IOStats.from_device_batch(io, t0, hops, sv, 8, cx, False,
                                    sh, sw, True)
    assert agg.spec_hits == 4 and agg.spec_wasted == 2
    assert agg.dma_speculative == 1
    # pre-speculation callers (short folds) zero the columns
    legacy = IOStats.from_device_batch(io, t0, hops, sv, 8, cx)
    assert legacy.spec_hits == 0 and legacy.spec_wasted == 0
    assert legacy.dma_speculative == 0


def test_speculative_pipelined_pricing_max_chain():
    """DESIGN.md §9 pricing: with both flags set the round chain pays
    ``max(stream x (1 - h), compute)`` — the spec-hit share of the
    stream left this round's critical path — plus the serial
    mis-speculation surcharge. h = 0 reduces exactly to the PR-8
    pipelined form; waste is a pure additive penalty."""
    cm = TPU_HBM_SEGMENT
    cols = ([10, 4], [3, 1], [6, 8], [2, 0], 8)
    piped = IOStats.from_device_batch(*cols, pipelined=True)
    spec = IOStats.from_device_batch(*cols, pipelined=True,
                                     spec_hits=[4, 2], spec_wasted=[0, 0],
                                     speculative=True)
    t_piped, t_spec = cm.latency_us(piped), cm.latency_us(spec)
    br = cm.breakdown(spec)
    stream, rcomp = br["t_dma_stream_us"], br["t_round_comp_us"]
    h, waste = br["spec_hit_frac"], br["t_spec_waste_us"]
    assert 0 < h <= 1 and waste == 0
    # reconstruct the §9 form from the serial components
    serial = IOStats.from_device_batch(*cols)
    t_serial = cm.latency_us(serial)
    assert t_spec == pytest.approx(
        t_serial - stream - rcomp + max(stream * (1 - h), rcomp))
    # pre-issuing paying gathers never makes the batch slower
    assert t_spec <= t_piped
    # h = 0 (flag set, nothing speculated) is exactly the PR-8 price
    h0 = IOStats.from_device_batch(*cols, pipelined=True,
                                   spec_hits=[0, 0], spec_wasted=[0, 0],
                                   speculative=True)
    assert cm.latency_us(h0) == pytest.approx(t_piped)
    # waste surcharges serially at the bandwidth rate
    wasted = IOStats.from_device_batch(*cols, pipelined=True,
                                       spec_hits=[4, 2],
                                       spec_wasted=[3, 2],
                                       speculative=True)
    assert cm.latency_us(wasted) == pytest.approx(
        t_spec + 5 * cm.t_batch_block)
    # the outer §5.1 pipeline is untouched by the flags
    assert cm.latency_us(spec, pipeline=True) == pytest.approx(
        cm.latency_us(serial, pipeline=True))


def test_speculative_only_pricing_discounts_stream_share():
    """Speculation without the double-buffered gather: the pre-issued
    share of the stream overlapped the PREVIOUS round's compute, so it
    simply leaves the serial io term."""
    cm = TPU_HBM_SEGMENT
    cols = ([10, 4], [3, 1], [6, 8], [2, 0], 8)
    serial = IOStats.from_device_batch(*cols)
    spec = IOStats.from_device_batch(*cols, spec_hits=[4, 2],
                                     spec_wasted=[1, 0],
                                     speculative=True)
    br = cm.breakdown(spec)
    stream, h = br["t_dma_stream_us"], br["spec_hit_frac"]
    assert cm.latency_us(spec) == pytest.approx(
        cm.latency_us(serial) - stream * h + br["t_spec_waste_us"])
    # flags are regime-gated: a round-less speculative stat prices
    # exactly like its plain twin (hops-granular seed pricing)
    flat = IOStats.from_device(6, 2, 6, 0, 0, spec_hits=3,
                               speculative=True)
    assert cm.latency_us(flat) == pytest.approx(
        cm.latency_us(IOStats.from_device(6, 2, 6, 0, 0)))


# ------------------- batch-stats schema (ISSUE 9 satellite: spec cols)

def test_batch_stat_keys_carry_spec_columns():
    """The wire schema between targets and consumers includes the
    speculation outcome columns, and the adapter zero-fills them for a
    legacy 6-key emitter — consumers always fold the full schema."""
    from repro.serving import target as T

    assert "spec_hits" in T.BATCH_STAT_KEYS
    assert "spec_wasted" in T.BATCH_STAT_KEYS

    class Legacy:
        offset, num_vectors = 0, 8

        def search(self, q, k=None):           # pragma: no cover
            raise NotImplementedError

        def batch_stats(self):
            io = np.array([3, 1, 0, 2])
            return {"io": io, "tier0_hits": io * 0, "hops": io,
                    "dedup_saved": io * 0, "dedup_cross": io * 0,
                    "rounds": 5}

    bs = T.batch_stats(Legacy())
    assert set(T.BATCH_STAT_KEYS) <= set(bs)
    np.testing.assert_array_equal(bs["spec_hits"], np.zeros(4))
    np.testing.assert_array_equal(bs["spec_wasted"], np.zeros(4))

    class Broken(Legacy):
        def batch_stats(self):
            return {"io": np.array([1.0]), "spec_hits": np.zeros(1),
                    "spec_wasted": np.zeros(1)}

    with pytest.raises(ValueError, match="travel together"):
        T.batch_stats(Broken())


def test_coordinator_stats_schema_has_spec_totals():
    """QueryCoordinator.search always emits the speculation totals
    (zero when nothing speculates) — dashboards key on a fixed
    schema."""
    from repro.serving.coordinator import QueryCoordinator
    assert "total_spec_hits" in QueryCoordinator.STATS_SCHEMA
    assert "total_spec_wasted" in QueryCoordinator.STATS_SCHEMA


def test_round_granular_is_opt_in():
    """Stats without a round count (host paths) and models without
    t_round (the NVMe segment) price exactly as before."""
    host = IOStats(block_reads=5, cache_misses=5, io_round_trips=5,
                   hops=5)
    assert NVME_SEGMENT.latency_us(host) == pytest.approx(
        5 * NVME_SEGMENT.t_block_io + 5 * NVME_SEGMENT.t_hop_other)
    # TPU model on round-less stats: hops-granular (the seed pricing)
    dev = IOStats.from_device(6, 2, 6, 0, 0)
    assert TPU_HBM_SEGMENT.latency_us(dev) == pytest.approx(
        6 * TPU_HBM_SEGMENT.t_block_io
        + 2 * TPU_HBM_SEGMENT.t_tier0_hit
        + 6 * TPU_HBM_SEGMENT.t_hop_other)
    # NVMe model ignores batch_rounds entirely (t_round unset)
    rdev = IOStats.from_device(6, 2, 6, 0, 9)
    assert NVME_SEGMENT.latency_us(rdev) == pytest.approx(
        NVME_SEGMENT.latency_us(dataclasses.replace(
            rdev, batch_rounds=0)))
