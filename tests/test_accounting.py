"""Space/byte accounting: Example 2, Eq. 8/10, segment budgets (§2.2,
§4.1, §6.4)."""
import numpy as np
import pytest

from repro.configs.starling_segment import PAPER_DATASETS
from repro.core.params import LayoutParams


@pytest.mark.parametrize("name", list(PAPER_DATASETS))
def test_example2_block_math(name):
    """Reproduce the paper's per-dataset (gamma, eps, rho) exactly
    (Example 2 + Tab. 16)."""
    n, dim, dtype_b, lam, eta, eps_want, rho_want = PAPER_DATASETS[name]
    lp = LayoutParams(block_kb=eta)
    eps = lp.verts_per_block(dim, lam, dtype_bytes=dtype_b)
    assert eps == eps_want
    rho = lp.num_blocks(n, dim, lam, dtype_bytes=dtype_b)
    assert rho == rho_want


def test_bigann_example2_exact_numbers():
    """BIGANN: gamma = (128 + 4 + 31*4)/1024 KB -> eps=16, rho=2,062,500."""
    lp = LayoutParams(block_kb=4)
    gamma_bytes = 128 * 1 + 4 + 31 * 4
    assert gamma_bytes == 256
    assert lp.verts_per_block(128, 31, dtype_bytes=1) == 16
    assert lp.num_blocks(33_000_000, 128, 31, dtype_bytes=1) == 2_062_500


def test_segment_budget_accounting(small_segment):
    seg = small_segment
    # Eq. 10 components all positive and memory < disk
    mem = seg.memory_bytes()
    disk = seg.disk_bytes()
    assert 0 < mem < disk
    ok = seg.check_budget()
    assert ok["memory_ok"] and ok["disk_ok"]
    # mapping charge is exactly 8 bytes/vertex (block id + slot, int32)
    assert seg.view.layout.mapping_bytes() == seg.num_vectors * 8


def test_tier0_budget_charged_into_eq10(small_segment):
    """ISSUE 3 acceptance: the device hot-tile budget is a C_tier0 term
    of Eq. 10 and is capped by the VMEM budget."""
    import dataclasses
    from repro.core.params import CacheParams
    seg = small_segment
    base_mem = seg.memory_bytes()
    assert seg.tier0_bytes() == 0
    seg10 = dataclasses.replace(
        seg, params=dataclasses.replace(
            seg.params, cache=CacheParams(tier0_frac=0.10)))
    want = int(0.10 * seg.disk_bytes())
    assert seg10.tier0_bytes() == want
    assert seg10.memory_bytes() == base_mem + want
    ok = seg10.check_budget()
    assert ok["tier0_ok"] and ok["memory_ok"]
    # the packed device arrays respect the same budget (block-rounded)
    from repro.core import device_search as DS
    ds = DS.from_segment(seg10)
    assert 0 < DS.tier0_bytes(ds) <= want
    assert DS.tier0_bytes(ds) <= seg.params.budget.tier0_vmem_bytes


def test_disk_bytes_are_block_aligned(small_segment):
    seg = small_segment
    store = seg.view.store
    assert seg.disk_bytes() == int(store.num_blocks * store.block_kb
                                   * 1024)


def test_build_times_recorded(small_segment):
    t = small_segment.build_times
    for key in ("disk_graph_s", "shuffling_s", "memory_graph_s", "pq_s"):
        assert key in t and t[key] >= 0
    # paper: shuffling is a small fraction of graph construction
    assert t["shuffling_s"] < t["disk_graph_s"]


def test_save_load_roundtrip(small_segment, tmp_path, small_data):
    from repro.core.segment import load_segment, save_segment
    from repro.core.search import anns
    x, q = small_data
    path = str(tmp_path / "seg.npz")
    save_segment(small_segment, path)
    seg2 = load_segment(path, small_segment.params)
    ids1, _, _ = anns(small_segment.view, q[:4], 5,
                      small_segment.params.search)
    ids2, _, _ = anns(seg2.view, q[:4], 5, small_segment.params.search)
    np.testing.assert_array_equal(ids1, ids2)
