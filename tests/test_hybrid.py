"""Hot/cold hybrid tier (ISSUE 10): hotset ranking bugfixes, the
in-memory hot tier, the mutable delta segment, and the serving swap.

The two regression tests at the top pin the ``hotset`` bugfixes and
fail on the pre-fix code:

  * ``hot_block_ranking`` used to reset its visited set every BFS
    level, so cyclic graphs re-counted earlier-level vertices at lower
    weight and could flip the ranking order;
  * ``fill_to``/``plan_tier0`` used to pass observed block ids ≥
    ``total_blocks`` (stale demand after a compaction shrank the
    segment) straight into the pack plan, which
    ``device_search._tier0_pack`` then indexed out of range.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.io import hotset


# ---------------------------------------------------- hotset bugfixes

def test_hot_block_ranking_cycle_regression():
    """Cross-level visited set: a 2-cycle must not re-count its block.

    Graph (one seed s in block 0):

        s -> r1 -> r2 -> r1        (block 1: a 2-cycle)
        s -> c1 -> c2 -> c3        (block 2: an acyclic chain)

    With hops=3 the weights are 8/4/2/1. Correct counts: block0 = 8,
    block1 = 4+2 = 6 (the cycle ends the R side at level 2), block2 =
    4+2+1 = 7, so the ranking is [0, 2, 1]. The pre-fix per-level
    visited reset re-enters r1 at level 3 (+1 to block 1), tying the
    counts at 7 and flipping the order to [0, 1, 2].
    """
    adj = np.array([[1, 3], [2, -1], [1, -1],
                    [4, -1], [5, -1], [-1, -1]], np.int32)
    deg = np.array([2, 1, 1, 1, 1, 0], np.int32)
    block_of = np.array([0, 1, 1, 2, 2, 2], np.int32)
    ranking = hotset.hot_block_ranking(block_of, adj, deg,
                                       seed_ids=[0], hops=3)
    assert ranking == [0, 2, 1], \
        f"cycle double-count regressed: {ranking}"


def test_fill_to_filters_stale_block_ids():
    """Stale ids ≥ total_blocks (or negative) never reach the pack."""
    # 5 and 9 are stale (total_blocks shrank to 4 after a compaction)
    out = hotset.fill_to([5, 9, 1, 0], 3, 4)
    assert out == [1, 0, 2], f"stale ids leaked into the pack: {out}"
    assert all(0 <= b < 4 for b in out)
    # negative ids are equally out of range
    out = hotset.fill_to([-3, 2, 0], 2, 3)
    assert out == [2, 0]
    # prefix nesting survives the filter: growing budgets nest strictly
    stale = [7, 1, 9, 0, 2]
    fills = [hotset.fill_to(stale, n, 3) for n in (1, 2, 3)]
    for small, big in zip(fills, fills[1:]):
        assert small == big[: len(small)]


def test_plan_tier0_filters_stale_observed_ids():
    """Observed demand for since-compacted blocks is dropped, not
    planned: the plan stays inside the (new, smaller) layout."""
    plan = hotset.plan_tier0(ranking=[0, 1, 2],
                             observed={7: 100, 2: 5},
                             num_blocks=2, total_blocks=3)
    assert plan == [2, 0], f"stale observed id leaked: {plan}"
    assert all(0 <= b < 3 for b in plan)


# ------------------------------------------------------------ fixtures

N, DIM, K = 600, 24, 10


@pytest.fixture(scope="module")
def hybrid_setup():
    from repro.core.params import SegmentParams, HotTierParams
    from repro.core.segment import build_segment
    rng = np.random.default_rng(7)
    x = rng.standard_normal((N, DIM)).astype(np.float32)
    q = rng.standard_normal((12, DIM)).astype(np.float32)
    seg = build_segment(x, SegmentParams())
    d2 = ((q[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    truth = np.argsort(d2, axis=1)[:, :K]
    return x, q, seg, truth


# ------------------------------------------------------- hot tier unit

def test_hot_tier_build_budget_and_membership(hybrid_setup):
    from repro.core.params import HotTierParams
    from repro.io.hottier import build_hot_tier
    x, q, seg, truth = hybrid_setup
    p = HotTierParams(budget_frac=0.10)
    hot = build_hot_tier(seg, p)
    # whole-block admission: at least the budget, at most one block over
    eps = seg.view.store.verts_per_block
    assert N * p.budget_frac <= hot.size < N * p.budget_frac + eps
    assert hot.base_size == N
    # members are exactly the vectors of the top-ranked blocks, and the
    # resident copy is the store's copy bit-for-bit
    for li in range(hot.size):
        gid = int(hot.ids[li])
        b, s = (int(seg.view.layout.block_of[gid]),
                int(seg.view.layout.slot_of[gid]))
        assert int(seg.view.store.vid[b, s]) == gid
        assert np.array_equal(hot.vectors[li], seg.view.store.vecs[b, s])
    assert hot.memory_bytes() > 0


def test_hot_tier_route_exits_and_hits(hybrid_setup):
    from repro.core.params import HotTierParams
    from repro.io.hottier import build_hot_tier
    x, q, seg, truth = hybrid_setup
    hot = build_hot_tier(seg, HotTierParams(budget_frac=0.10))
    r = hot.route(q, K)
    assert r.ids.shape == (12, K) and r.exits.shape == (12, 4)
    # every exit is a cold-graph id (exists on disk), every hit count
    # covers at least the converged beam
    valid = r.exits >= 0
    assert valid.any(axis=1).all()
    assert (r.exits[valid] < N).all()
    assert (r.hot_hits >= 1).all()
    # routed results are real hot members with exact distances
    for qi in range(12):
        for j in range(K):
            g = int(r.ids[qi, j])
            if g < 0:
                continue
            d = float(((q[qi] - x[g]) ** 2).sum())
            assert abs(d - float(r.dists[qi, j])) < 1e-3


def test_hot_tier_insert_delete_route(hybrid_setup):
    from repro.core.params import HotTierParams
    from repro.io.hottier import build_hot_tier
    x, q, seg, truth = hybrid_setup
    hot = build_hot_tier(seg, HotTierParams(budget_frac=0.10))
    size0, cap0 = hot.size, hot.vectors.shape[0]
    # insert enough to force at least one append-region growth
    rng = np.random.default_rng(11)
    extra = rng.standard_normal((cap0 - size0 + 5, DIM)).astype(np.float32)
    gids = np.arange(N, N + extra.shape[0])
    hot.insert(extra, gids)
    assert hot.size == size0 + extra.shape[0]
    assert hot.vectors.shape[0] > cap0
    # an inserted vector is findable by exact-match query
    r = hot.route(extra[:1], 3)
    assert int(r.ids[0, 0]) == N and float(r.dists[0, 0]) == 0.0
    # ...until tombstoned
    assert hot.delete(N)
    r = hot.route(extra[:1], 3)
    assert N not in r.ids[0]
    # appended ids never leak into the exit frontier (no disk identity)
    assert (r.exits < hot.base_size).all()
    # deleting a non-resident id is a no-op report
    assert not hot.delete(10 ** 9)


# ------------------------------------------------- seed-override paths

def test_host_seed_override_matches_entry_points(hybrid_setup):
    """seeds == the nav entry points the search would pick itself →
    bit-identical results; all-(-1) seeds fall back to nav entries."""
    from repro.core.search import anns, _entry_points
    x, q, seg, truth = hybrid_setup
    p = seg.params.search
    base_ids, base_d, _ = anns(seg.view, q, K, p)
    seeds = np.stack([_entry_points(seg.view, qq, p) for qq in q])
    s_ids, s_d, _ = anns(seg.view, q, K, p, seeds=seeds)
    assert np.array_equal(base_ids, s_ids)
    assert np.array_equal(base_d, s_d)
    f_ids, f_d, _ = anns(seg.view, q, K, p,
                         seeds=np.full((12, 3), -1, np.int64))
    assert np.array_equal(base_ids, f_ids)


def test_device_seed_override_matches_entry_points(hybrid_setup):
    """Device path: seeding with the exact nav-entry frontier the
    kernel would derive itself is bit-identical to not seeding."""
    jax = pytest.importorskip("jax")
    from repro.core import device_search as DS
    from repro.configs.starling_segment import DEVICE_SEARCH_BATCH
    x, q, seg, truth = hybrid_setup
    ds = DS.from_segment(seg, tier0_frac=0.1)
    p = dataclasses.replace(DEVICE_SEARCH_BATCH, k=K)
    qj = jnp.asarray(q)
    base = DS.device_anns(ds, qj, p)
    entry = DS.nav_entry_points(ds, qj, beam=p.nav_beam, hops=p.nav_hops,
                                num=p.entry_points, metric="l2")
    seeded = DS.device_anns(ds, qj, p, seeds=entry)
    assert np.array_equal(np.asarray(base.ids), np.asarray(seeded.ids))
    assert np.array_equal(np.asarray(base.dists),
                          np.asarray(seeded.dists))
    assert np.array_equal(np.asarray(base.io), np.asarray(seeded.io))


# ------------------------------------------------------- delta segment

def test_delta_insert_delete_search(hybrid_setup):
    from repro.core.params import HotTierParams
    from repro.core import delta as DL
    x, q, seg, truth = hybrid_setup
    d = DL.DeltaSegment.wrap(seg, HotTierParams(budget_frac=0.10))
    p = seg.params.search
    rng = np.random.default_rng(3)
    new = rng.standard_normal((4, DIM)).astype(np.float32)
    gids = d.insert(new)
    assert list(gids) == [N, N + 1, N + 2, N + 3]
    # an inserted vector answers its own query through the hybrid path
    ids, dists, _ = d.search(new[:1], 3, p)
    assert int(ids[0, 0]) == N and float(dists[0, 0]) == 0.0
    # delete a base id + an appended id; neither ever surfaces again
    victim = int(truth[0, 0])
    assert d.delete(victim) and d.delete(int(gids[1]))
    assert not d.delete(victim)          # double delete reports False
    ids, _, _ = d.search(q, K, p)
    assert victim not in ids and int(gids[1]) not in ids
    assert d.live_count == N + 4 - 2
    # stats carry the memory charge
    _, _, stats = d.search(q[:2], K, p)
    assert all(s.hot_tier_hits > 0 for s in stats)


def test_delta_compact_bit_identical(hybrid_setup):
    """insert → delete → compact() ≡ a fresh build of the live set."""
    from repro.core.params import HotTierParams
    from repro.core.segment import build_segment
    from repro.core import delta as DL
    x, q, seg, truth = hybrid_setup
    d = DL.DeltaSegment.wrap(seg, HotTierParams(budget_frac=0.10))
    rng = np.random.default_rng(5)
    new = rng.standard_normal((6, DIM)).astype(np.float32)
    gids = d.insert(new)
    for g in (0, 17, int(gids[2])):
        assert d.delete(g)
    compacted, live_gids = d.compact()
    # the live set, rebuilt from the block store + append region
    keep = np.ones(N, bool)
    keep[[0, 17]] = False
    x_live = np.concatenate(
        [x[keep], new[[0, 1, 3, 4, 5]]], axis=0).astype(np.float32)
    assert live_gids.shape[0] == x_live.shape[0]
    fresh = build_segment(x_live, seg.params)
    assert np.array_equal(compacted.view.store.vid, fresh.view.store.vid)
    assert np.array_equal(compacted.view.store.vecs,
                          fresh.view.store.vecs)
    assert np.array_equal(compacted.graph.adj, fresh.graph.adj)
    assert np.array_equal(compacted.view.layout.blocks,
                          fresh.view.layout.blocks)
    assert np.array_equal(compacted.view.pq_codes, fresh.view.pq_codes)


# ------------------------------------------------- accounting plumbing

def test_iostats_hot_tier_hits_merge_and_pricing():
    from repro.core.iostats import IOStats, NVME_SEGMENT
    a = IOStats(block_reads=4, hot_tier_hits=30)
    b = IOStats(block_reads=2, hot_tier_hits=12)
    a.merge(b)
    assert a.hot_tier_hits == 42
    cm = NVME_SEGMENT
    base = dataclasses.replace(cm, t_hot_tier_hit=0.0)
    s = IOStats(block_reads=4, hot_tier_hits=100)
    # hot visits price into compute, never into the I/O half
    assert cm.breakdown(s)["t_io_us"] == base.breakdown(s)["t_io_us"]
    assert cm.latency_us(s) == pytest.approx(
        base.latency_us(s) + 100 * cm.t_hot_tier_hit)
    assert cm.breakdown(s)["hot_tier_hits"] == 100


# ------------------------------- scheduler swap (satellite 2, serving)

def test_scheduler_drops_stale_window_on_layout_swap(hybrid_setup):
    """Compaction shrinks the layout; the scheduler's demand window
    must drop entries past the new block count and the next forced
    repack must plan strictly in-range (pre-fix: ``_tier0_pack``
    indexed out of range on the stale plan)."""
    jax = pytest.importorskip("jax")
    from repro.core.params import HotTierParams, RepackParams
    from repro.core import delta as DL
    from repro.core import device_search as DS
    from repro.serving.coordinator import SegmentServer
    from repro.serving.scheduler import RepackScheduler
    x, q, seg, truth = hybrid_setup
    ds = DS.from_segment(seg, tier0_frac=0.2)
    server = SegmentServer(segment=ds, offset=0, num_vectors=N, host=seg)
    sched = RepackScheduler(RepackParams(min_observed=1))
    sched.attach_target(server)
    old_total = int(seg.view.store.num_blocks)
    # hot demand parked on the TAIL blocks of the old layout
    sched._window.update({b: 50 for b in range(old_total - 4, old_total)})
    # delete half the base, compact, swap under the serving target
    d = DL.DeltaSegment.wrap(seg, HotTierParams(budget_frac=0.10))
    for g in range(0, N, 2):
        d.delete(g)
    compacted, _ = d.compact()
    new_total = int(compacted.view.store.num_blocks)
    assert new_total < old_total
    DL.swap_into_device_server(server, compacted, scheduler=sched,
                               tier0_frac=0.2)
    assert all(0 <= b < new_total for b in sched._window)
    decision = sched.maybe_repack(force=True)
    assert decision is not None
    for b in DS.hot_pack_blocks(server.segment):
        assert 0 <= b < new_total


def test_hybrid_server_batch_stats_column(hybrid_setup):
    """The hybrid server's hot_tier_hits ride the batch-stat schema and
    fold through the scheduler's IOStats path."""
    jax = pytest.importorskip("jax")
    from repro.core.params import HotTierParams
    from repro.core import device_search as DS
    from repro.io.hottier import build_hot_tier
    from repro.serving.coordinator import SegmentServer, QueryCoordinator
    from repro.serving import target as tgt_mod
    x, q, seg, truth = hybrid_setup
    ds = DS.from_segment(seg, tier0_frac=0.1)
    hot = build_hot_tier(seg, HotTierParams(budget_frac=0.10))
    tomb = np.zeros(N, bool)
    victim = int(truth[0, 0])
    tomb[victim] = True
    hot.delete(victim)
    server = SegmentServer(segment=ds, offset=0, num_vectors=N,
                           host=seg, hot_tier=hot, tombstones=tomb)
    ids, dists, io = server.search(q, K)
    assert victim not in ids
    bs = tgt_mod.batch_stats(server)
    assert (np.asarray(bs["hot_tier_hits"]) > 0).all()
    co = QueryCoordinator([server])
    gi, gd, st = co.search(q, K)
    assert st["total_hot_tier_hits"] == int(
        np.asarray(bs["hot_tier_hits"]).sum())
    assert victim not in gi
