"""Quickstart: build a Starling segment, search it, compare against the
DiskANN-style baseline and brute force.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs.starling_segment import SEGMENT_BENCH
from repro.core import baseline as B
from repro.core import distances as D
from repro.core.iostats import NVME_SEGMENT
from repro.core.search import anns, range_search, recall_at_k, \
    average_precision
from repro.core.segment import build_segment
from repro.data.vectors import clustered_vectors, query_set


def main():
    print("== Starling quickstart ==")
    x = clustered_vectors(5000, 64, num_clusters=32, seed=0)
    q = query_set(x, 20, seed=1)
    truth = D.brute_force_knn(x, q, 10)

    print("building segment (graph + BNF shuffle + nav graph + PQ) ...")
    seg = build_segment(x, SEGMENT_BENCH)
    print(f"  vectors={seg.num_vectors}  OR(G)={seg.overlap_ratio:.3f}")
    print(f"  memory={seg.memory_bytes()/1e6:.1f}MB  "
          f"disk={seg.disk_bytes()/1e6:.1f}MB  budget ok="
          f"{seg.check_budget()}")
    for k, v in seg.build_times.items():
        print(f"  {k:16s} {v:6.2f}s")

    print("\n-- ANNS (top-10) --")
    ids, dists, stats = anns(seg.view, q, 10, seg.params.search)
    io = np.mean([s.block_reads for s in stats])
    xi = np.mean([s.vertex_utilization for s in stats])
    lat = np.mean([NVME_SEGMENT.latency_us(s, pipeline=True)
                   for s in stats])
    print(f"starling  recall={recall_at_k(ids, truth):.3f} "
          f"mean_io={io:.1f} xi={xi:.3f} modeled_latency={lat:.0f}us")

    p_base = dataclasses.replace(seg.params.search,
                                 use_block_search=False,
                                 use_nav_graph=False)
    ids_b, _, stats_b = B.vertex_anns(seg.view, q, 10, p_base)
    io_b = np.mean([s.block_reads for s in stats_b])
    xi_b = np.mean([s.vertex_utilization for s in stats_b])
    lat_b = np.mean([NVME_SEGMENT.latency_us(s, pipeline=False)
                     for s in stats_b])
    print(f"baseline  recall={recall_at_k(ids_b, truth):.3f} "
          f"mean_io={io_b:.1f} xi={xi_b:.3f} modeled_latency={lat_b:.0f}us")
    print(f"==> I/O reduction {io_b/io:.2f}x, modeled speedup "
          f"{lat_b/lat:.2f}x")

    print("\n-- Range search --")
    radius = float(np.quantile(D.pairwise(q, x), 0.002))
    gt = D.brute_force_range(x, q, radius)
    res, st = range_search(seg.view, q, radius, seg.params.search)
    print(f"AP={average_precision(res, gt):.3f} "
          f"mean_io={np.mean([s.block_reads for s in st]):.1f}")


if __name__ == "__main__":
    main()
