"""End-to-end serving driver (the paper's kind of workload): a machine
hosting multiple Starling segments behind a query coordinator + request
batcher, serving batched ANNS requests with the device-side (jit'd,
batched while_loop) search path.

  PYTHONPATH=src python examples/serve_segments.py
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs.starling_segment import SEGMENT_BENCH
from repro.core import device_search as DS
from repro.core import distances as D
from repro.core.search import recall_at_k
from repro.core.segment import build_segment
from repro.data.vectors import clustered_vectors, query_set
from repro.serving import QueryCoordinator, RequestBatcher, SegmentServer
from repro.serving.coordinator import SERVE_DEVICE_SEARCH


def main():
    print("== multi-segment serving demo ==")
    num_segments, n_per, dim = 3, 2000, 48
    servers, xs, off = [], [], 0
    for s in range(num_segments):
        x = clustered_vectors(n_per, dim, num_clusters=16, seed=s)
        print(f"building segment {s} ({n_per} vectors) ...")
        seg = build_segment(x, SEGMENT_BENCH)
        servers.append(SegmentServer(
            segment=DS.from_segment(seg), offset=off, num_vectors=n_per,
            params=dataclasses.replace(SERVE_DEVICE_SEARCH,
                                       candidates=48)))
        xs.append(x)
        off += n_per
    union = np.concatenate(xs, axis=0)
    coord = QueryCoordinator(servers)
    batcher = RequestBatcher(dim=dim, buckets=(8, 32))

    # clients submit single-query requests
    queries = query_set(union, 24, seed=9)
    rids = [batcher.submit(qq) for qq in queries]
    print(f"submitted {len(rids)} requests")

    results = {}
    t0 = time.perf_counter()
    while batcher.queue:
        qbatch, ids, n = batcher.next_batch()
        gi, gd, stats = coord.search(qbatch[:n], k=10)
        for i, rid in enumerate(ids):
            results[rid] = (gi[i], gd[i])
        print(f"  served batch of {n} "
              f"(segments={stats['segments_searched']}, "
              f"mean block reads/query="
              f"{stats['mean_block_reads_per_query']:.1f})")
    wall = time.perf_counter() - t0

    got = np.stack([results[r][0] for r in rids])
    truth = D.brute_force_knn(union, queries, 10)
    print(f"recall@10 over {num_segments} segments: "
          f"{recall_at_k(got, truth):.3f}")
    print(f"wall (CPU, interpret-mode kernels): {wall:.2f}s")


if __name__ == "__main__":
    main()
