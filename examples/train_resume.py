"""Fault-tolerant training demo: train an assigned arch (reduced config),
kill mid-run, resume from the latest checkpoint, verify the loss curve
continues seamlessly.

  PYTHONPATH=src python examples/train_resume.py --arch rwkv6-1.6b
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.pipeline import TokenPipeline
from repro.ft.checkpoint import CheckpointManager
from repro.launch.train import default_optimizer, make_train_step
from repro.models import lm
from repro.optim import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--crash-at", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    print(f"== fault-tolerant training: {cfg.name} ==")
    step_fn = jax.jit(make_train_step(cfg, default_optimizer()))
    ckpt = CheckpointManager(tempfile.mkdtemp(prefix="repro_ckpt_"),
                             keep=2)
    pipe = TokenPipeline(cfg.vocab_size, batch=4, seq=32, seed=0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)

    losses = []
    print(f"training to step {args.crash_at}, then 'crashing' ...")
    for step in range(args.crash_at):
        params, opt, m = step_fn(params, opt, pipe.next_batch(cfg))
        losses.append(float(m["loss"]))
        if (step + 1) % 6 == 0:
            ckpt.save(step + 1, params, opt, pipe.get_state())
            print(f"  step {step+1}: loss={losses[-1]:.4f} [checkpoint]")

    print("simulated node failure — restarting from latest checkpoint")
    params2 = lm.init_params(cfg, jax.random.PRNGKey(0))   # fresh proc
    opt2 = adamw_init(params2)
    params2, opt2, pipe_state, start = ckpt.restore(params2, opt2)
    pipe2 = TokenPipeline(cfg.vocab_size, batch=4, seq=32, seed=0)
    pipe2.set_state(pipe_state)
    print(f"resumed at step {start}")
    for step in range(start, args.steps):
        params2, opt2, m = step_fn(params2, opt2, pipe2.next_batch(cfg))
        losses.append(float(m["loss"]))
    print("loss curve:", " ".join(f"{l:.3f}" for l in losses))
    assert losses[-1] < losses[0], "loss should decrease"
    print("resume OK — loss continued decreasing across the restart")


if __name__ == "__main__":
    main()
