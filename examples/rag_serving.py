"""RAG bridge: an assigned-architecture LM decodes while querying a
Starling segment index for nearest-neighbor context every few steps —
the integration point between the paper's technique and the LM serving
substrate (DESIGN.md §Arch-applicability).

  PYTHONPATH=src python examples/rag_serving.py --arch gemma3-1b
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.starling_segment import SEGMENT_BENCH_DEVICE
from repro.core import device_search as DS
from repro.core.params import DeviceSearchParams
from repro.core.segment import build_segment
from repro.data.vectors import clustered_vectors
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--retrieve-every", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    print(f"== RAG serving: {cfg.name} + Starling segment ==")

    # corpus embeddings at the LM's width; the segment indexes them
    corpus = clustered_vectors(2000, cfg.d_model, num_clusters=16, seed=0)
    seg = build_segment(corpus, SEGMENT_BENCH_DEVICE)
    ds = DS.from_segment(seg)       # packs the tier-0 VMEM hot set
    print(f"segment ready: OR(G)={seg.overlap_ratio:.3f} "
          f"tier0={DS.tier0_bytes(ds)}B")

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    b, prompt_len, max_len = 2, 8, 8 + args.gen
    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (b, prompt_len), 0, cfg.vocab_size)
    logits, cache = lm.prefill(cfg, params, prompt, max_len)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)

    total_io = total_t0 = 0
    for step in range(args.gen - 1):
        logits, cache = lm.decode_step(cfg, params, cache, tok)
        # every few tokens, embed the hidden query (here: the pre-logit
        # representation proxy = embedding of the argmax token) and
        # retrieve neighbors from the segment
        if (step + 1) % args.retrieve_every == 0:
            q = np.asarray(
                params["embed"])[np.asarray(tok[:, 0])].astype(np.float32)
            r = DS.device_anns(
                ds, jnp.asarray(q),
                DeviceSearchParams(k=4, candidates=32, max_hops=64))
            total_io += int(np.asarray(r.io).sum())
            total_t0 += int(np.asarray(r.tier0_hits).sum())
            print(f"  step {step+1}: retrieved ctx ids "
                  f"{np.asarray(r.ids)[0].tolist()} "
                  f"(cold DMAs {np.asarray(r.io).tolist()}, "
                  f"tier-0 hits {np.asarray(r.tier0_hits).tolist()})")
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    print(f"decoded {args.gen} tokens x {b} seqs; total retrieval "
          f"block touches: {total_io + total_t0} "
          f"({total_io} cold DMAs + {total_t0} tier-0 hits)")


if __name__ == "__main__":
    main()
