from repro.data.pipeline import TokenPipeline
from repro.data.vectors import clustered_vectors, query_set
