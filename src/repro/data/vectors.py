"""Synthetic vector datasets with BIGANN/DEEP-like cluster structure.

Real segment data is clustered (embeddings concentrate on manifolds);
uniform random vectors make graph search artificially hard and PQ
artificially bad. ``clustered_vectors`` mixes Gaussian clusters with
heavy-tailed scales + a uniform background — enough structure for
recall/IO trade-offs to behave like the paper's datasets.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def clustered_vectors(n: int, dim: int, num_clusters: int = 64,
                      seed: int = 0, background: float = 0.05,
                      dtype=np.float32) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((num_clusters, dim)).astype(np.float32)
    centers *= 4.0
    scales = (0.5 + rng.gamma(2.0, 0.5, size=num_clusters)).astype(
        np.float32)
    weights = rng.dirichlet(np.ones(num_clusters) * 2.0)
    assign = rng.choice(num_clusters, size=n, p=weights)
    x = (centers[assign]
         + rng.standard_normal((n, dim)).astype(np.float32)
         * scales[assign][:, None])
    nb = int(n * background)
    if nb:
        idx = rng.choice(n, size=nb, replace=False)
        x[idx] = rng.standard_normal((nb, dim)).astype(np.float32) * 6.0
    return x.astype(dtype)


def query_set(x: np.ndarray, num: int, in_db: bool = False,
              seed: int = 1, jitter: float = 0.1) -> np.ndarray:
    """Queries near the data manifold. ``in_db=True`` returns exact rows
    (the §6.8 in-database workload)."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(x.shape[0], size=num, replace=False)
    q = x[idx].astype(np.float32).copy()
    if not in_db:
        q += rng.standard_normal(q.shape).astype(np.float32) * (
            jitter * np.abs(q).mean())
    return q
