"""Deterministic, checkpointable token pipeline.

Synthetic LM data with Zipfian unigram structure + induced bigram
correlations, so training losses actually decrease. The pipeline state
(a counter) is tiny and exact: restoring ``get_state()`` resumes the
stream bit-for-bit — the property the fault-tolerance tests assert.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


class TokenPipeline:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.step = 0
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        # fixed "grammar": token t prefers successor succ[t]
        self._succ = rng.permutation(vocab).astype(np.int64)

    def get_state(self) -> Dict:
        return {"step": int(self.step), "seed": self.seed}

    def set_state(self, state: Dict) -> None:
        assert state["seed"] == self.seed, "pipeline seed mismatch"
        self.step = int(state["step"])

    def _tokens(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        draws = rng.choice(self.vocab, size=(self.batch, self.seq),
                           p=self._probs)
        follow = rng.random((self.batch, self.seq)) < 0.5
        out = draws.copy()
        for t in range(1, self.seq):
            out[:, t] = np.where(follow[:, t], self._succ[out[:, t - 1]],
                                 draws[:, t])
        return out.astype(np.int32)

    def next_batch(self, cfg=None) -> Dict[str, np.ndarray]:
        toks = self._tokens(self.step)
        self.step += 1
        batch = {"tokens": toks,
                 "labels": np.concatenate(
                     [toks[:, 1:], np.full((self.batch, 1), -1,
                                           np.int32)], axis=1)}
        if cfg is not None and getattr(cfg, "family", "") == "vlm":
            rng = np.random.default_rng((self.seed, self.step, 7))
            batch["patch_embeds"] = rng.standard_normal(
                (self.batch, cfg.patch_tokens, cfg.d_model)).astype(
                np.float32)
        if cfg is not None and getattr(cfg, "family", "") == "audio":
            rng = np.random.default_rng((self.seed, self.step, 11))
            batch["frames"] = rng.standard_normal(
                (self.batch, cfg.num_mem_tokens, cfg.d_model)).astype(
                np.float32)
        return batch
