"""Fused block-tile ranking kernel (the §5.1 block-search inner loop).

Input is the gathered block tile per query — exactly what one HBM->VMEM
DMA delivers in the TPU mapping of a 4 KB disk read. The kernel
exact-ranks all eps resident vertices against the query and selects the
top-m slots (block pruning keeps the (eps-1)*sigma closest) without
leaving VMEM: distances via dot, selection via m iterations of
masked-argmin (eps is small, ~4-16, so iterative select beats a sort).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128


def _rank_kernel(q_ref, t_ref, d_ref, i_ref, *, top_m: int, metric: str):
    q = q_ref[...].astype(jnp.float32)              # [BQ, D]
    t = t_ref[...].astype(jnp.float32)              # [BQ, eps, D]
    if metric == "ip":
        d = -jnp.einsum("qd,qed->qe", q, t)
    else:
        dot = jnp.einsum("qd,qed->qe", q, t)
        tt = jnp.sum(t * t, axis=-1)
        qq = jnp.sum(q * q, axis=-1, keepdims=True)
        d = jnp.maximum(tt + qq - 2.0 * dot, 0.0)
    d_ref[...] = d

    work = d
    eps = d.shape[1]
    cols = jax.lax.broadcasted_iota(jnp.int32, (d.shape[0], eps), 1)
    for m in range(top_m):
        bidx = jnp.argmin(work, axis=1)
        i_ref[:, m] = bidx.astype(jnp.int32)
        work = jnp.where(cols == bidx[:, None], 3.0e38, work)


def block_topk(queries: jnp.ndarray, tiles: jnp.ndarray, top_m: int,
               metric: str = "l2", interpret: bool = True,
               bq: int = BQ):
    """queries [Q, D]; tiles [Q, eps, D] -> (dists [Q, eps] f32,
    top_idx [Q, top_m] int32)."""
    qn, d = queries.shape
    _, eps, _ = tiles.shape
    assert qn % bq == 0, (qn, bq)
    grid = (qn // bq,)
    return pl.pallas_call(
        functools.partial(_rank_kernel, top_m=top_m, metric=metric),
        grid=grid,
        in_specs=[pl.BlockSpec((bq, d), lambda i: (i, 0)),
                  pl.BlockSpec((bq, eps, d), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((bq, eps), lambda i: (i, 0)),
                   pl.BlockSpec((bq, top_m), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((qn, eps), jnp.float32),
                   jax.ShapeDtypeStruct((qn, top_m), jnp.int32)],
        interpret=interpret,
    )(queries, tiles)
