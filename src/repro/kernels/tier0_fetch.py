"""Fused tier-0 probe + gather + rank kernels (DESIGN.md §3.2, §4).

Two generations of the device search's fetch stage live here:

``tier0_fetch_rank`` (ISSUE 3) — for the F block ids one round trip
targets per query, probe the tier-0 hot-slot map, gather each block's
vector tile from the VMEM-resident hot pack on a hit or from the HBM
block store on a miss (the DMA the cost model prices), and exact-rank
all F*eps resident vertices against the query — one kernel, so hot hits
never round-trip through HBM between probe and rank.

``fused_round`` (ISSUE 4) — the whole per-round fetch pipeline of the
*divergence-aware batched* search in one pass: derive the target blocks
from the picked candidates, union the per-query requests of the tile
into a sorted-unique block list (cross-query dedup — each distinct
block's tile is gathered from HBM/the hot pack ONCE and broadcast to
every requesting query), compute exact distances, and per-query
top-``n_expand``-rank the masked selection key (the block-pruning order
the search loop expands in). A tile whose queries are all converged
(every ``u`` slot is -1 — what active-query compaction clusters) skips
the gather+rank body entirely and writes masked sentinels.

Distances use the same f32 sum-of-squared-differences (or negated IP)
form as the pure-jnp fetch stage, keeping the fused and reference
implementations bit-identical; the hot pack holds exact copies of the
packed blocks, so tier-0 budget never changes (ids, dists) — only which
source tier served the tile (the returned hit mask feeds the
``IOStats.tier0_hits`` / DMA counters).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128


def _probe_kernel(q_ref, b_ref, slot_ref, hot_ref, cold_ref,
                  d_ref, hit_ref, *, metric: str):
    q = q_ref[...].astype(jnp.float32)            # [BQ, D]
    b = b_ref[...]                                # [BQ, F] i32
    slot = slot_ref[...][b]                       # probe: [BQ, F]
    hit = slot >= 0
    hot_t = hot_ref[...][jnp.maximum(slot, 0)]    # [BQ, F, eps, D]
    cold_t = cold_ref[...][b]                     # the modeled HBM DMA
    t = jnp.where(hit[:, :, None, None], hot_t, cold_t)
    bq, f, eps, d_dim = t.shape
    t = t.reshape(bq, f * eps, d_dim).astype(jnp.float32)
    if metric == "ip":
        d = -jnp.einsum("qd,qed->qe", q, t)
    else:
        d = jnp.sum(jnp.square(t - q[:, None, :]), axis=-1)
    d_ref[...] = d
    hit_ref[...] = hit.astype(jnp.int32)


def _round_kernel(q_ref, u_ref, bof_ref, slot_ref, hotv_ref, hotid_ref,
                  hotn_ref, vecs_ref, vid_ref, nbrs_ref,
                  d_ref, vout_ref, nout_ref, hit_ref, ord_ref,
                  *, metric: str, n_expand: int):
    u = u_ref[...]                                # [BQ, F] i32, -1 = idle
    bq, f = u.shape
    eps, d_dim = vecs_ref.shape[1], vecs_ref.shape[2]
    lam = nbrs_ref.shape[2]

    @pl.when((u >= 0).any())
    def _live_tile():
        q = q_ref[...].astype(jnp.float32)        # [BQ, D]
        valid = u >= 0
        b = bof_ref[...][jnp.maximum(u, 0)]       # [BQ, F] target blocks
        # --- cross-query dedup: sorted-unique union of the tile's block
        # requests; every distinct block is gathered ONCE (ranks past
        # the unique count gather a placeholder no slot maps to)
        flat = b.reshape(-1)                      # [R]
        r = flat.shape[0]
        sort_idx = jnp.argsort(flat)              # stable
        sb = flat[sort_idx]
        first = jnp.concatenate(
            [jnp.ones((1,), bool), sb[1:] != sb[:-1]])
        rank = jnp.cumsum(first) - 1              # [R] slot -> unique rank
        # duplicates write equal values, so the scatters are deterministic
        blk_of_rank = jnp.zeros((r,), jnp.int32).at[rank].set(sb)
        req_rank = jnp.zeros((r,), jnp.int32).at[sort_idx].set(
            rank.astype(jnp.int32))               # flat slot -> unique rank
        # --- tier-0 probe + the once-per-distinct-block gather
        s = slot_ref[...][blk_of_rank]            # [R] hot slot (-1 = cold)
        hot_u = s >= 0
        s_safe = jnp.maximum(s, 0)
        tiles_u = jnp.where(hot_u[:, None, None],
                            hotv_ref[...][s_safe],
                            vecs_ref[...][blk_of_rank])      # [R, eps, D]
        vid_u = jnp.where(hot_u[:, None], hotid_ref[...][s_safe],
                          vid_ref[...][blk_of_rank])         # [R, eps]
        nbrs_u = jnp.where(hot_u[:, None, None],
                           hotn_ref[...][s_safe],
                           nbrs_ref[...][blk_of_rank])       # [R, eps, Lam]
        # --- broadcast each distinct tile to its requesting slots
        tiles = tiles_u[req_rank].reshape(bq, f * eps, d_dim)
        vid = vid_u[req_rank].reshape(bq, f * eps)
        nbrs = nbrs_u[req_rank].reshape(bq, f * eps, lam)
        hit = hot_u[req_rank].reshape(bq, f)
        # --- exact rank (same f32 form as the jnp reference)
        t32 = tiles.astype(jnp.float32)
        if metric == "ip":
            dd = -jnp.einsum("qd,qed->qe", q, t32)
        else:
            dd = jnp.sum(jnp.square(t32 - q[:, None, :]), axis=-1)
        # --- per-query top-M expansion order over the masked selection
        # key (targets first, then nearest residents; same tie-breaking
        # as the search loop: stable argsort)
        f_valid = jnp.repeat(valid, eps, axis=1)
        slot_valid = (vid >= 0) & f_valid
        dd_m = jnp.where(slot_valid, dd, jnp.inf)
        is_target = (vid[:, :, None] == u[:, None, :]).any(-1) & (vid >= 0)
        sel_key = jnp.where(is_target, -jnp.inf, dd_m)
        order = jnp.argsort(sel_key, axis=1)[:, :n_expand]
        d_ref[...] = dd
        vout_ref[...] = vid
        nout_ref[...] = nbrs
        hit_ref[...] = hit.astype(jnp.int32)
        ord_ref[...] = order.astype(jnp.int32)

    @pl.when(~(u >= 0).any())
    def _idle_tile():
        # a fully-converged tile (what compaction clusters): skip the
        # gather + rank entirely, emit masked sentinels the search loop
        # never consumes (every downstream use is gated on u >= 0)
        d_ref[...] = jnp.zeros((bq, f * eps), jnp.float32)
        vout_ref[...] = jnp.full((bq, f * eps), -1, jnp.int32)
        nout_ref[...] = jnp.full((bq, f * eps, lam), -1, jnp.int32)
        hit_ref[...] = jnp.zeros((bq, f), jnp.int32)
        ord_ref[...] = jnp.zeros((bq, n_expand), jnp.int32)


def fused_round(queries: jnp.ndarray, u: jnp.ndarray,
                block_of: jnp.ndarray, hot_slot_of: jnp.ndarray,
                hot_vecs: jnp.ndarray, hot_vid: jnp.ndarray,
                hot_nbrs: jnp.ndarray, vecs: jnp.ndarray,
                vid: jnp.ndarray, nbrs: jnp.ndarray, n_expand: int,
                metric: str = "l2", interpret: bool = True,
                bq: int = BQ):
    """One search round's fetch pipeline, fused (see module docstring).

    queries [Q, D]; u [Q, F] i32 picked candidate ids (-1 = converged /
    empty slot); block_of [N]; hot_slot_of [rho]; hot pack [H, eps, ...];
    cold store [rho, eps, ...] ->
    (dists [Q, F*eps] f32, vid [Q, F*eps] i32, nbrs [Q, F*eps, Lam] i32,
    hit [Q, F] i32, order [Q, n_expand] i32)."""
    qn, d = queries.shape
    _, f = u.shape
    n = block_of.shape[0]
    rho, eps, _ = vecs.shape
    h = hot_vecs.shape[0]
    lam = nbrs.shape[2]
    assert qn % bq == 0, (qn, bq)
    grid = (qn // bq,)
    return pl.pallas_call(
        functools.partial(_round_kernel, metric=metric,
                          n_expand=n_expand),
        grid=grid,
        in_specs=[pl.BlockSpec((bq, d), lambda i: (i, 0)),
                  pl.BlockSpec((bq, f), lambda i: (i, 0)),
                  pl.BlockSpec((n,), lambda i: (0,)),
                  pl.BlockSpec((rho,), lambda i: (0,)),
                  pl.BlockSpec((h, eps, d), lambda i: (0, 0, 0)),
                  pl.BlockSpec((h, eps), lambda i: (0, 0)),
                  pl.BlockSpec((h, eps, lam), lambda i: (0, 0, 0)),
                  pl.BlockSpec((rho, eps, d), lambda i: (0, 0, 0)),
                  pl.BlockSpec((rho, eps), lambda i: (0, 0)),
                  pl.BlockSpec((rho, eps, lam), lambda i: (0, 0, 0))],
        out_specs=[pl.BlockSpec((bq, f * eps), lambda i: (i, 0)),
                   pl.BlockSpec((bq, f * eps), lambda i: (i, 0)),
                   pl.BlockSpec((bq, f * eps, lam), lambda i: (i, 0, 0)),
                   pl.BlockSpec((bq, f), lambda i: (i, 0)),
                   pl.BlockSpec((bq, n_expand), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((qn, f * eps), jnp.float32),
                   jax.ShapeDtypeStruct((qn, f * eps), jnp.int32),
                   jax.ShapeDtypeStruct((qn, f * eps, lam), jnp.int32),
                   jax.ShapeDtypeStruct((qn, f), jnp.int32),
                   jax.ShapeDtypeStruct((qn, n_expand), jnp.int32)],
        interpret=interpret,
    )(queries, u, block_of, hot_slot_of, hot_vecs, hot_vid, hot_nbrs,
      vecs, vid, nbrs)


def tier0_fetch_rank(queries: jnp.ndarray, blocks: jnp.ndarray,
                     hot_slot_of: jnp.ndarray, hot_vecs: jnp.ndarray,
                     cold_vecs: jnp.ndarray, metric: str = "l2",
                     interpret: bool = True, bq: int = BQ):
    """queries [Q, D]; blocks [Q, F] i32; hot_slot_of [rho] i32 (-1 =
    not packed); hot_vecs [H, eps, D]; cold_vecs [rho, eps, D] ->
    (dists [Q, F*eps] f32, hit [Q, F] i32)."""
    qn, d = queries.shape
    _, f = blocks.shape
    rho, eps, _ = cold_vecs.shape
    h = hot_vecs.shape[0]
    assert qn % bq == 0, (qn, bq)
    grid = (qn // bq,)
    return pl.pallas_call(
        functools.partial(_probe_kernel, metric=metric),
        grid=grid,
        in_specs=[pl.BlockSpec((bq, d), lambda i: (i, 0)),
                  pl.BlockSpec((bq, f), lambda i: (i, 0)),
                  pl.BlockSpec((rho,), lambda i: (0,)),
                  pl.BlockSpec((h, eps, d), lambda i: (0, 0, 0)),
                  pl.BlockSpec((rho, eps, d), lambda i: (0, 0, 0))],
        out_specs=[pl.BlockSpec((bq, f * eps), lambda i: (i, 0)),
                   pl.BlockSpec((bq, f), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((qn, f * eps), jnp.float32),
                   jax.ShapeDtypeStruct((qn, f), jnp.int32)],
        interpret=interpret,
    )(queries, blocks, hot_slot_of, hot_vecs, cold_vecs)
