"""Fused tier-0 probe + gather + rank kernel (DESIGN.md §3.2).

The fetch stage of the device block search (``device_search``): for the
F block ids one round trip targets per query, probe the tier-0 hot-slot
map, gather each block's vector tile from the VMEM-resident hot pack on
a hit or from the HBM block store on a miss (the DMA the cost model
prices), and exact-rank all F*eps resident vertices against the query —
one kernel, so hot hits never round-trip through HBM between probe and
rank.

Distances use the same f32 sum-of-squared-differences (or negated IP)
form as the pure-jnp fetch stage, keeping the fused and reference
implementations bit-identical; the hot pack holds exact copies of the
packed blocks, so tier-0 budget never changes (ids, dists) — only which
source tier served the tile (the returned hit mask feeds the
``IOStats.tier0_hits`` / DMA counters).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128


def _probe_kernel(q_ref, b_ref, slot_ref, hot_ref, cold_ref,
                  d_ref, hit_ref, *, metric: str):
    q = q_ref[...].astype(jnp.float32)            # [BQ, D]
    b = b_ref[...]                                # [BQ, F] i32
    slot = slot_ref[...][b]                       # probe: [BQ, F]
    hit = slot >= 0
    hot_t = hot_ref[...][jnp.maximum(slot, 0)]    # [BQ, F, eps, D]
    cold_t = cold_ref[...][b]                     # the modeled HBM DMA
    t = jnp.where(hit[:, :, None, None], hot_t, cold_t)
    bq, f, eps, d_dim = t.shape
    t = t.reshape(bq, f * eps, d_dim).astype(jnp.float32)
    if metric == "ip":
        d = -jnp.einsum("qd,qed->qe", q, t)
    else:
        d = jnp.sum(jnp.square(t - q[:, None, :]), axis=-1)
    d_ref[...] = d
    hit_ref[...] = hit.astype(jnp.int32)


def tier0_fetch_rank(queries: jnp.ndarray, blocks: jnp.ndarray,
                     hot_slot_of: jnp.ndarray, hot_vecs: jnp.ndarray,
                     cold_vecs: jnp.ndarray, metric: str = "l2",
                     interpret: bool = True, bq: int = BQ):
    """queries [Q, D]; blocks [Q, F] i32; hot_slot_of [rho] i32 (-1 =
    not packed); hot_vecs [H, eps, D]; cold_vecs [rho, eps, D] ->
    (dists [Q, F*eps] f32, hit [Q, F] i32)."""
    qn, d = queries.shape
    _, f = blocks.shape
    rho, eps, _ = cold_vecs.shape
    h = hot_vecs.shape[0]
    assert qn % bq == 0, (qn, bq)
    grid = (qn // bq,)
    return pl.pallas_call(
        functools.partial(_probe_kernel, metric=metric),
        grid=grid,
        in_specs=[pl.BlockSpec((bq, d), lambda i: (i, 0)),
                  pl.BlockSpec((bq, f), lambda i: (i, 0)),
                  pl.BlockSpec((rho,), lambda i: (0,)),
                  pl.BlockSpec((h, eps, d), lambda i: (0, 0, 0)),
                  pl.BlockSpec((rho, eps, d), lambda i: (0, 0, 0))],
        out_specs=[pl.BlockSpec((bq, f * eps), lambda i: (i, 0)),
                   pl.BlockSpec((bq, f), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((qn, f * eps), jnp.float32),
                   jax.ShapeDtypeStruct((qn, f), jnp.int32)],
        interpret=interpret,
    )(queries, blocks, hot_slot_of, hot_vecs, cold_vecs)
