"""Fused tier-0 probe + gather + rank kernels (DESIGN.md §3.2, §4, §8).

Two generations of the device search's fetch stage live here:

``tier0_fetch_rank`` (ISSUE 3) — for the F block ids one round trip
targets per query, probe the tier-0 hot-slot map, gather each block's
vector tile from the VMEM-resident hot pack on a hit or from the HBM
block store on a miss (the DMA the cost model prices), and exact-rank
all F*eps resident vertices against the query — one kernel, so hot hits
never round-trip through HBM between probe and rank.

``fused_round`` (ISSUE 4, reworked batch-scope in ISSUE 8) — the whole
per-round fetch pipeline of the *divergence-aware batched* search as a
two-pass batch-scope pipeline:

  * **pass 1** (plain jnp, traced into the surrounding jit): derive the
    target blocks from the picked candidates and union them into the
    whole-batch sorted-unique block list via the shared
    ``kernels.dedup`` helper — one list for ALL Q x F requests, not one
    per kernel query tile — plus the flat-slot -> unique-rank map every
    query tile carries into pass 2 (an SMEM-sized i32 [BQ, F] block);
  * **pass 2a** (``gather_unique``, grid over unique-block chunks):
    copy each distinct block's cold payload (vectors / ids / neighbor
    rows) out of the HBM block store exactly ONCE batch-wide — the
    modeled DMAs. When ``pipeline_dma`` is on (and the kernel is
    compiled, not interpreted) the copies run the classic Pallas
    ``make_async_copy`` double buffer: block j+1's HBM->VMEM copy is
    in flight while block j's tile is written, and across grid steps
    the Pallas pipeline prefetches chunk i+1 during chunk i's compute
    — the overlap ``CostModel`` prices as ``max(dma, compute)``.
    Under ``interpret=True`` a straight-line fallback gathers the
    chunk in one vector select — bit-identical payloads either way;
  * **pass 2b** (``_rank_kernel``, grid over query tiles): probe the
    tier-0 hot-slot map for the unique list, select each distinct
    block's tile from the VMEM hot pack (hit — no DMA happened) or
    the pass-2a cold copy, broadcast to requesting slots through the
    rank map, compute exact distances, and per-query
    top-``n_expand``-rank the masked selection key. A tile whose
    queries are all converged (every ``u`` slot is -1 — what
    active-query compaction clusters) skips the broadcast+rank body
    entirely and writes masked sentinels.

ISSUE 9 fuses pass 1 into pass 2a: ``gather_union`` computes the same
whole-batch union INSIDE the gather kernel via the sort-free
``dedup.union_slot_map`` twin, stages the flat-slot -> unique-rank map
through SMEM scratch, and emits the identical five pass-2b inputs — so
the first cold DMA (double-buffered or speculative) can issue without a
host-visible pass-1 boundary. ``fused_round(fuse_union=True)`` selects
it; the two-pass path stays as the bit-identity oracle twin.

Distances use the same f32 sum-of-squared-differences (or negated IP)
form as the pure-jnp fetch stage, keeping the fused and reference
implementations bit-identical; the hot pack holds exact copies of the
packed blocks, so neither tier-0 budget nor dedup scope ever changes
(ids, dists) — only which source tier served a tile and which counter
(``io`` / ``tier0_hits`` / ``dedup_saved``) a touch lands in.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import dedup

BQ = 128   # query-tile size of the rank pass
RB = 128   # unique-block chunk size of the cold-gather pass


# -------------------------------------------- pass 2a: unique cold gather

def _gather_unique_kernel(uniq_ref, vecs_ref, vid_ref, nbrs_ref,
                          tv_ref, ti_ref, tn_ref):
    """Straight-line chunk gather (the ``interpret=True`` fallback and
    the ``pipeline_dma=False`` path): copy the chunk's distinct blocks
    out of the cold store in one vector gather."""
    u = uniq_ref[...]                             # [RB] distinct blocks
    tv_ref[...] = vecs_ref[...][u]
    ti_ref[...] = vid_ref[...][u]
    tn_ref[...] = nbrs_ref[...][u]


def _double_buffered_gather(u, vecs_ref, vid_ref, nbrs_ref,
                            tv_ref, ti_ref, tn_ref,
                            vscr, iscr, nscr, sems):
    """The classic two-slot ``make_async_copy`` schedule, shared by the
    chunked and fused-union DMA kernels: while distinct block j's
    payload is written to the output tile, the HBM copies of block
    j+1's vector / id / neighbor rows are already in flight into the
    other scratch slot. Payload-identical to a straight-line gather;
    only the schedule differs."""
    from jax.experimental.pallas import tpu as pltpu

    rb = u.shape[0]

    def cold_dma(slot, j):
        blk = u[j]
        return (pltpu.make_async_copy(vecs_ref.at[pl.ds(blk, 1)],
                                      vscr.at[slot], sems.at[slot, 0]),
                pltpu.make_async_copy(vid_ref.at[pl.ds(blk, 1)],
                                      iscr.at[slot], sems.at[slot, 1]),
                pltpu.make_async_copy(nbrs_ref.at[pl.ds(blk, 1)],
                                      nscr.at[slot], sems.at[slot, 2]))

    for c in cold_dma(0, 0):                      # warm up slot 0
        c.start()

    def body(j, carry):
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < rb)
        def _start_next():                        # overlap j's write
            for c in cold_dma(1 - slot, j + 1):
                c.start()

        for c in cold_dma(slot, j):
            c.wait()
        tv_ref[pl.ds(j, 1)] = vscr[slot]
        ti_ref[pl.ds(j, 1)] = iscr[slot]
        tn_ref[pl.ds(j, 1)] = nscr[slot]
        return carry

    jax.lax.fori_loop(0, rb, body, 0)


def _gather_unique_dma_kernel(uniq_ref, vecs_ref, vid_ref, nbrs_ref,
                              tv_ref, ti_ref, tn_ref,
                              vscr, iscr, nscr, sems):
    """Double-buffered cold gather over a precomputed unique chunk:
    across grid steps the Pallas pipeline additionally prefetches chunk
    i+1's operands during chunk i, so the fetch overlaps the rank
    pass's distance+expansion compute."""
    _double_buffered_gather(uniq_ref[...], vecs_ref, vid_ref, nbrs_ref,
                            tv_ref, ti_ref, tn_ref,
                            vscr, iscr, nscr, sems)


def gather_unique(uniq: jnp.ndarray, vecs: jnp.ndarray,
                  vid: jnp.ndarray, nbrs: jnp.ndarray,
                  interpret: bool = True, pipeline_dma: bool = False,
                  rb: int = RB, _force_dma: bool = False):
    """Pass 2a: copy every distinct block's cold payload exactly once.

    uniq [R] i32 (the whole-batch sorted-unique union, 0-padded) ->
    (tiles [R, eps, D], vid [R, eps] i32, nbrs [R, eps, Lam] i32).
    The double-buffered DMA schedule runs when ``pipeline_dma`` is set
    on a compiled (non-interpret) call; ``interpret=True`` takes the
    straight-line fallback unless ``_force_dma`` exercises the DMA
    path under the interpreter (the emulation tests)."""
    r = uniq.shape[0]
    rho, eps, d = vecs.shape
    lam = nbrs.shape[2]
    assert r % rb == 0, (r, rb)
    grid = (r // rb,)
    use_dma = _force_dma or (pipeline_dma and not interpret)
    kernel = (_gather_unique_dma_kernel if use_dma
              else _gather_unique_kernel)
    scratch = []
    if use_dma:
        from jax.experimental.pallas import tpu as pltpu
        scratch = [pltpu.VMEM((2, 1, eps, d), vecs.dtype),
                   pltpu.VMEM((2, 1, eps), jnp.int32),
                   pltpu.VMEM((2, 1, eps, lam), jnp.int32),
                   pltpu.SemaphoreType.DMA((2, 3))]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rb,), lambda i: (i,)),
                  pl.BlockSpec((rho, eps, d), lambda i: (0, 0, 0)),
                  pl.BlockSpec((rho, eps), lambda i: (0, 0)),
                  pl.BlockSpec((rho, eps, lam), lambda i: (0, 0, 0))],
        out_specs=[pl.BlockSpec((rb, eps, d), lambda i: (i, 0, 0)),
                   pl.BlockSpec((rb, eps), lambda i: (i, 0)),
                   pl.BlockSpec((rb, eps, lam), lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((r, eps, d), vecs.dtype),
                   jax.ShapeDtypeStruct((r, eps), jnp.int32),
                   jax.ShapeDtypeStruct((r, eps, lam), jnp.int32)],
        scratch_shapes=scratch,
        interpret=interpret,
    )(uniq, vecs, vid, nbrs)


# ----------------------------- fused pass 1+2a: in-kernel union + gather

def _union_into_smem(b_ref, uniq_ref, rank_ref, slot_scr):
    """Compute the whole-batch sorted-unique union INSIDE the kernel
    (the sort-free ``dedup.union_slot_map`` twin of pass 1) and stage
    the flat-slot -> unique-rank map through SMEM scratch — scalar
    memory, where per-slot ranks that drive control/addressing belong —
    before emitting both union outputs for pass 2b. Returns the in-
    register ``uniq`` vector the gather below consumes."""
    flat = b_ref[...].reshape(-1)                 # [R] target blocks
    uniq, rank = dedup.union_slot_map(flat)
    slot_scr[...] = rank                          # SMEM-shared slot map
    uniq_ref[...] = uniq
    rank_ref[...] = slot_scr[...].reshape(b_ref.shape)
    return uniq


def _gather_union_kernel(b_ref, vecs_ref, vid_ref, nbrs_ref,
                         uniq_ref, rank_ref, tv_ref, ti_ref, tn_ref,
                         slot_scr):
    """Fused union + straight-line cold gather (the ``interpret=True``
    fallback and the ``pipeline_dma=False`` path)."""
    uniq = _union_into_smem(b_ref, uniq_ref, rank_ref, slot_scr)
    tv_ref[...] = vecs_ref[...][uniq]
    ti_ref[...] = vid_ref[...][uniq]
    tn_ref[...] = nbrs_ref[...][uniq]


def _gather_union_dma_kernel(b_ref, vecs_ref, vid_ref, nbrs_ref,
                             uniq_ref, rank_ref, tv_ref, ti_ref, tn_ref,
                             slot_scr, vscr, iscr, nscr, sems):
    """Fused union + double-buffered cold gather: the first speculative
    / pipelined DMA can start as soon as the in-kernel union resolves —
    no host-visible pass-1 boundary between union and gather."""
    uniq = _union_into_smem(b_ref, uniq_ref, rank_ref, slot_scr)
    _double_buffered_gather(uniq, vecs_ref, vid_ref, nbrs_ref,
                            tv_ref, ti_ref, tn_ref,
                            vscr, iscr, nscr, sems)


def gather_union(b: jnp.ndarray, vecs: jnp.ndarray,
                 vid: jnp.ndarray, nbrs: jnp.ndarray,
                 interpret: bool = True, pipeline_dma: bool = False,
                 _force_dma: bool = False):
    """Fused pass 1+2a: in-kernel whole-batch union, then copy every
    distinct block's cold payload exactly once.

    b [Q, F] i32 target blocks (idle slots already folded onto block
    0) -> (uniq [R], rank2d [Q, F] i32, tiles [R, eps, D],
    vid [R, eps] i32, nbrs [R, eps, Lam] i32) with R = Q*F — the same
    five values the two-pass path hands pass 2b, bit-identical.

    The union needs the whole-batch view, so this runs as a single
    kernel invocation (no RB chunking); the O(R^2) union masks stay
    comfortably in VMEM at search-round sizes (R is a few hundred).
    The slot map is staged through an SMEM scratch buffer; DMA
    schedule selection matches ``gather_unique``."""
    from jax.experimental.pallas import tpu as pltpu

    qn, f = b.shape
    r = qn * f
    rho, eps, d = vecs.shape
    lam = nbrs.shape[2]
    use_dma = _force_dma or (pipeline_dma and not interpret)
    kernel = (_gather_union_dma_kernel if use_dma
              else _gather_union_kernel)
    scratch = [pltpu.SMEM((r,), jnp.int32)]
    if use_dma:
        scratch += [pltpu.VMEM((2, 1, eps, d), vecs.dtype),
                    pltpu.VMEM((2, 1, eps), jnp.int32),
                    pltpu.VMEM((2, 1, eps, lam), jnp.int32),
                    pltpu.SemaphoreType.DMA((2, 3))]
    return pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct((r,), b.dtype),
                   jax.ShapeDtypeStruct((qn, f), jnp.int32),
                   jax.ShapeDtypeStruct((r, eps, d), vecs.dtype),
                   jax.ShapeDtypeStruct((r, eps), jnp.int32),
                   jax.ShapeDtypeStruct((r, eps, lam), jnp.int32)],
        scratch_shapes=scratch,
        interpret=interpret,
    )(b, vecs, vid, nbrs)


# ------------------------------------------- pass 2b: broadcast and rank

def _rank_kernel(q_ref, u_ref, rank_ref, uniq_ref, slot_ref, hotv_ref,
                 hotid_ref, hotn_ref, tv_ref, ti_ref, tn_ref,
                 d_ref, vout_ref, nout_ref, hit_ref, ord_ref,
                 *, metric: str, n_expand: int):
    u = u_ref[...]                                # [BQ, F] i32, -1 = idle
    bq, f = u.shape
    eps, d_dim = tv_ref.shape[1], tv_ref.shape[2]
    lam = tn_ref.shape[2]

    @pl.when((u >= 0).any())
    def _live_tile():
        q = q_ref[...].astype(jnp.float32)        # [BQ, D]
        valid = u >= 0
        # --- tier-0 probe of the batch-unique list + hot/cold select:
        # a hot block's tile comes from the VMEM pack (its pass-2a DMA
        # never needed to happen), a cold one from the once-per-
        # distinct-block copy pass 2a made
        s = slot_ref[...][uniq_ref[...]]          # [R] hot slot (-1=cold)
        hot_u = s >= 0
        ss = jnp.maximum(s, 0)
        tiles_u = jnp.where(hot_u[:, None, None], hotv_ref[...][ss],
                            tv_ref[...])          # [R, eps, D]
        vid_u = jnp.where(hot_u[:, None], hotid_ref[...][ss],
                          ti_ref[...])            # [R, eps]
        nbrs_u = jnp.where(hot_u[:, None, None], hotn_ref[...][ss],
                           tn_ref[...])           # [R, eps, Lam]
        # --- broadcast each distinct tile to its requesting slots
        # through the flat-slot -> unique-rank map pass 1 carried in
        rk = rank_ref[...].reshape(-1)            # [BQ*F] unique ranks
        tiles = tiles_u[rk].reshape(bq, f * eps, d_dim)
        vid = vid_u[rk].reshape(bq, f * eps)
        nbrs = nbrs_u[rk].reshape(bq, f * eps, lam)
        hit = hot_u[rk].reshape(bq, f)
        # --- exact rank (same f32 form as the jnp reference)
        t32 = tiles.astype(jnp.float32)
        if metric == "ip":
            dd = -jnp.einsum("qd,qed->qe", q, t32)
        else:
            dd = jnp.sum(jnp.square(t32 - q[:, None, :]), axis=-1)
        # --- per-query top-M expansion order over the masked selection
        # key (targets first, then nearest residents; same tie-breaking
        # as the search loop: stable argsort)
        f_valid = jnp.repeat(valid, eps, axis=1)
        slot_valid = (vid >= 0) & f_valid
        dd_m = jnp.where(slot_valid, dd, jnp.inf)
        is_target = (vid[:, :, None] == u[:, None, :]).any(-1) & (vid >= 0)
        sel_key = jnp.where(is_target, -jnp.inf, dd_m)
        order = jnp.argsort(sel_key, axis=1)[:, :n_expand]
        d_ref[...] = dd
        vout_ref[...] = vid
        nout_ref[...] = nbrs
        hit_ref[...] = hit.astype(jnp.int32)
        ord_ref[...] = order.astype(jnp.int32)

    @pl.when(~(u >= 0).any())
    def _idle_tile():
        # a fully-converged tile (what compaction clusters): skip the
        # broadcast + rank entirely, emit masked sentinels the search
        # loop never consumes (every downstream use is gated on u >= 0)
        d_ref[...] = jnp.zeros((bq, f * eps), jnp.float32)
        vout_ref[...] = jnp.full((bq, f * eps), -1, jnp.int32)
        nout_ref[...] = jnp.full((bq, f * eps, lam), -1, jnp.int32)
        hit_ref[...] = jnp.zeros((bq, f), jnp.int32)
        ord_ref[...] = jnp.zeros((bq, n_expand), jnp.int32)


def fused_round(queries: jnp.ndarray, u: jnp.ndarray,
                block_of: jnp.ndarray, hot_slot_of: jnp.ndarray,
                hot_vecs: jnp.ndarray, hot_vid: jnp.ndarray,
                hot_nbrs: jnp.ndarray, vecs: jnp.ndarray,
                vid: jnp.ndarray, nbrs: jnp.ndarray, n_expand: int,
                metric: str = "l2", interpret: bool = True,
                bq: int = BQ, pipeline_dma: bool = False,
                fuse_union: bool = False, _force_dma: bool = False):
    """One search round's fetch pipeline, fused, batch-scope (see
    module docstring).

    queries [Q, D]; u [Q, F] i32 picked candidate ids (-1 = converged /
    empty slot); block_of [N]; hot_slot_of [rho]; hot pack [H, eps, ...];
    cold store [rho, eps, ...] ->
    (dists [Q, F*eps] f32, vid [Q, F*eps] i32, nbrs [Q, F*eps, Lam] i32,
    hit [Q, F] i32, order [Q, n_expand] i32).

    Dedup scope is the WHOLE batch: every distinct block across all
    Q x F requests is gathered once and broadcast — a request in tile 3
    rides a copy tile 0's requests triggered. ``pipeline_dma``
    double-buffers the cold gather on compiled calls (interpret always
    takes the straight-line fallback unless ``_force_dma``).
    ``fuse_union`` moves the pass-1 union into the gather kernel
    (``gather_union``: SMEM-staged slot map, no host-visible pass-1
    intermediates) — bit-identical to the two-pass path, which stays
    available as the conformance oracle twin."""
    qn, d = queries.shape
    _, f = u.shape
    assert qn % bq == 0, (qn, bq)

    # --- pass 1: whole-batch sorted-unique union + slot -> rank map.
    # Idle slots (u = -1) fold onto block 0's rank — harmless, their
    # outputs are masked/skipped downstream; ranks past the distinct
    # count keep the 0 placeholder no slot maps to.
    b = block_of[jnp.maximum(u, 0)]               # [Q, F] target blocks

    if fuse_union:
        # fused pass 1+2a: the union resolves inside the gather kernel
        # (sort-free twin, SMEM slot map) and the first cold DMA starts
        # without a host-visible pass-1 boundary
        uniq, rank2d, tv, ti, tn = gather_union(
            b, vecs, vid, nbrs, interpret=interpret,
            pipeline_dma=pipeline_dma, _force_dma=_force_dma)
        r = uniq.shape[0]
    else:
        uniq, req_rank = dedup.sorted_unique_ranks(b.reshape(-1))
        rank2d = req_rank.reshape(qn, f)

        # --- pass 2a: copy each distinct block's cold payload once
        r = uniq.shape[0]
        rb = min(RB, r)
        pad = (-r) % rb
        uniq_p = uniq if pad == 0 else jnp.pad(uniq, (0, pad))
        tv, ti, tn = gather_unique(
            uniq_p, vecs, vid, nbrs, interpret=interpret,
            pipeline_dma=pipeline_dma, rb=rb, _force_dma=_force_dma)
        tv, ti, tn = tv[:r], ti[:r], tn[:r]

    # --- pass 2b: probe + hot/cold select + broadcast + rank per tile
    n = block_of.shape[0]
    rho, eps, _ = vecs.shape
    h = hot_vecs.shape[0]
    lam = nbrs.shape[2]
    grid = (qn // bq,)
    return pl.pallas_call(
        functools.partial(_rank_kernel, metric=metric,
                          n_expand=n_expand),
        grid=grid,
        in_specs=[pl.BlockSpec((bq, d), lambda i: (i, 0)),
                  pl.BlockSpec((bq, f), lambda i: (i, 0)),
                  pl.BlockSpec((bq, f), lambda i: (i, 0)),
                  pl.BlockSpec((r,), lambda i: (0,)),
                  pl.BlockSpec((rho,), lambda i: (0,)),
                  pl.BlockSpec((h, eps, d), lambda i: (0, 0, 0)),
                  pl.BlockSpec((h, eps), lambda i: (0, 0)),
                  pl.BlockSpec((h, eps, lam), lambda i: (0, 0, 0)),
                  pl.BlockSpec((r, eps, d), lambda i: (0, 0, 0)),
                  pl.BlockSpec((r, eps), lambda i: (0, 0)),
                  pl.BlockSpec((r, eps, lam), lambda i: (0, 0, 0))],
        out_specs=[pl.BlockSpec((bq, f * eps), lambda i: (i, 0)),
                   pl.BlockSpec((bq, f * eps), lambda i: (i, 0)),
                   pl.BlockSpec((bq, f * eps, lam), lambda i: (i, 0, 0)),
                   pl.BlockSpec((bq, f), lambda i: (i, 0)),
                   pl.BlockSpec((bq, n_expand), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((qn, f * eps), jnp.float32),
                   jax.ShapeDtypeStruct((qn, f * eps), jnp.int32),
                   jax.ShapeDtypeStruct((qn, f * eps, lam), jnp.int32),
                   jax.ShapeDtypeStruct((qn, f), jnp.int32),
                   jax.ShapeDtypeStruct((qn, n_expand), jnp.int32)],
        interpret=interpret,
    )(queries, u, rank2d, uniq, hot_slot_of, hot_vecs, hot_vid,
      hot_nbrs, tv, ti, tn)


def tier0_fetch_rank(queries: jnp.ndarray, blocks: jnp.ndarray,
                     hot_slot_of: jnp.ndarray, hot_vecs: jnp.ndarray,
                     cold_vecs: jnp.ndarray, metric: str = "l2",
                     interpret: bool = True, bq: int = BQ):
    """queries [Q, D]; blocks [Q, F] i32; hot_slot_of [rho] i32 (-1 =
    not packed); hot_vecs [H, eps, D]; cold_vecs [rho, eps, D] ->
    (dists [Q, F*eps] f32, hit [Q, F] i32)."""
    qn, d = queries.shape
    _, f = blocks.shape
    rho, eps, _ = cold_vecs.shape
    h = hot_vecs.shape[0]
    assert qn % bq == 0, (qn, bq)
    grid = (qn // bq,)
    return pl.pallas_call(
        functools.partial(_probe_kernel, metric=metric),
        grid=grid,
        in_specs=[pl.BlockSpec((bq, d), lambda i: (i, 0)),
                  pl.BlockSpec((bq, f), lambda i: (i, 0)),
                  pl.BlockSpec((rho,), lambda i: (0,)),
                  pl.BlockSpec((h, eps, d), lambda i: (0, 0, 0)),
                  pl.BlockSpec((rho, eps, d), lambda i: (0, 0, 0))],
        out_specs=[pl.BlockSpec((bq, f * eps), lambda i: (i, 0)),
                   pl.BlockSpec((bq, f), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((qn, f * eps), jnp.float32),
                   jax.ShapeDtypeStruct((qn, f), jnp.int32)],
        interpret=interpret,
    )(queries, blocks, hot_slot_of, hot_vecs, cold_vecs)


def _probe_kernel(q_ref, b_ref, slot_ref, hot_ref, cold_ref,
                  d_ref, hit_ref, *, metric: str):
    q = q_ref[...].astype(jnp.float32)            # [BQ, D]
    b = b_ref[...]                                # [BQ, F] i32
    slot = slot_ref[...][b]                       # probe: [BQ, F]
    hit = slot >= 0
    hot_t = hot_ref[...][jnp.maximum(slot, 0)]    # [BQ, F, eps, D]
    cold_t = cold_ref[...][b]                     # the modeled HBM DMA
    t = jnp.where(hit[:, :, None, None], hot_t, cold_t)
    bq, f, eps, d_dim = t.shape
    t = t.reshape(bq, f * eps, d_dim).astype(jnp.float32)
    if metric == "ip":
        d = -jnp.einsum("qd,qed->qe", q, t)
    else:
        d = jnp.sum(jnp.square(t - q[:, None, :]), axis=-1)
    d_ref[...] = d
    hit_ref[...] = hit.astype(jnp.int32)
