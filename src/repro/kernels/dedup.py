"""Shared sorted-unique block-dedup primitives (DESIGN.md §8).

The fused round kernel's batch-union pass (``kernels.tier0_fetch``)
and the search loop's accounting mirror
(``core.device_search._dedup_joins``) must group duplicate block
requests IDENTICALLY: the kernel decides which gather a request rides,
the mirror decides which counter (``io`` vs ``dedup_saved``) the
request lands in, and the bit-exact ``fold_round_log`` <-> ``IOStats``
tie depends on the two groupings never disagreeing. Both used to
hand-roll the same argsort/cumsum idiom; this module is the single
implementation so kernel and reference accounting cannot drift.

All helpers are plain jnp and run unchanged inside ``jit`` or
eagerly; ``union_slot_map`` additionally lowers inside a Pallas
kernel body (no sort/scatter), which is how the fused-union round
kernel (DESIGN.md §9) computes the same union without the two
host-visible pass-1 intermediates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sorted_unique_ranks(flat: jnp.ndarray):
    """Sorted-unique union of ``flat`` [R] int keys, plus the slot map.

    Returns ``(uniq [R], rank [R] i32)``:

      * ``uniq[j]`` is the j-th distinct key in ascending order;
        entries at or past the distinct count keep the 0 placeholder —
        no slot's ``rank`` ever points at them, so a gather pass may
        touch them harmlessly (or bound its loop by the distinct
        count);
      * ``rank[i]`` maps flat slot ``i`` to its key's unique rank:
        ``uniq[rank[i]] == flat[i]`` for every slot.

    The sort is stable, so among slots sharing a key the earliest
    flat-order slot defines the group — the same "first requester pays
    the DMA" order ``join_mask`` marks joiners against.
    """
    r = flat.shape[0]
    sort_idx = jnp.argsort(flat)                  # stable
    sb = flat[sort_idx]
    first = jnp.concatenate([jnp.ones((1,), bool), sb[1:] != sb[:-1]])
    rank = jnp.cumsum(first) - 1                  # sorted pos -> rank
    # duplicates write equal values, so the scatters are deterministic
    uniq = jnp.zeros((r,), flat.dtype).at[rank].set(sb)
    req_rank = jnp.zeros((r,), jnp.int32).at[sort_idx].set(
        rank.astype(jnp.int32))
    return uniq, req_rank


def union_slot_map(flat: jnp.ndarray):
    """Sort-free ``sorted_unique_ranks`` twin for in-kernel union fusion.

    Bit-identical to :func:`sorted_unique_ranks` — same ascending
    ``uniq`` with 0 placeholders past the distinct count, same
    ``rank`` slot map — but formulated as O(R^2) branch-free
    comparisons instead of argsort+scatter, so it lowers inside a
    Pallas kernel body (Mosaic has no stable sort / scatter
    primitive).  Per distinct key:

      * ``first[j]``: no earlier flat slot carries an equal key
        (the "first requester" that pays the gather);
      * ``rank[j]``: number of distinct keys strictly smaller than
        ``flat[j]`` — equals the cumsum-of-first rank in sorted
        order, duplicate slots share their group's rank;
      * ``uniq[r]``: the key whose rank is ``r`` (one-hot select and
        sum); ranks past the distinct count select nothing and keep
        the 0 placeholder, matching the scatter zeros.

    Assumes non-negative keys (block ids); R up to a few hundred
    keeps the R^2 masks comfortably in VMEM.
    """
    r = flat.shape[0]
    # 2-D iotas (TPU requires >= 2-D); axis 0 = i (earlier/selector),
    # axis 1 = j (slot under test)
    ii = jax.lax.broadcasted_iota(jnp.int32, (r, r), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (r, r), 1)
    eq = flat[:, None] == flat[None, :]           # eq[i, j]
    first = ~jnp.any(eq & (ii < jj), axis=0)      # no earlier equal
    smaller = flat[:, None] < flat[None, :]       # flat[i] < flat[j]
    rank = jnp.sum((first[:, None] & smaller).astype(jnp.int32),
                   axis=0)                        # distinct-smaller count
    sel = first[None, :] & (rank[None, :] == ii)  # sel[r, j]
    uniq = jnp.sum(jnp.where(sel, flat[None, :], 0),
                   axis=1).astype(flat.dtype)
    return uniq, rank.astype(jnp.int32)


def join_mask(keys: jnp.ndarray) -> jnp.ndarray:
    """Mark slots whose key an earlier slot in the same row already
    carries.

    ``keys`` [T, R] int -> joined [T, R] bool: True where some earlier
    (flat-order) slot of the same row has the same key — the earliest
    requester of each duplicate group stays False (it pays the gather);
    every later one is a join. Rows are independent dedup scopes (one
    row = one kernel tile, or one row = the whole batch); slots that
    must never join (non-cold requests, padding) should carry unique
    negative sentinel keys.
    """
    t, r = keys.shape
    order = jnp.argsort(keys, axis=1)             # stable
    sk = jnp.take_along_axis(keys, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((t, 1), bool), sk[:, 1:] == sk[:, :-1]], axis=1)
    return jnp.zeros((t, r), bool).at[
        jnp.arange(t)[:, None], order].set(dup)
