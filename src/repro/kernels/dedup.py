"""Shared sorted-unique block-dedup primitives (DESIGN.md §8).

The fused round kernel's batch-union pass (``kernels.tier0_fetch``)
and the search loop's accounting mirror
(``core.device_search._dedup_joins``) must group duplicate block
requests IDENTICALLY: the kernel decides which gather a request rides,
the mirror decides which counter (``io`` vs ``dedup_saved``) the
request lands in, and the bit-exact ``fold_round_log`` <-> ``IOStats``
tie depends on the two groupings never disagreeing. Both used to
hand-roll the same argsort/cumsum idiom; this module is the single
implementation so kernel and reference accounting cannot drift.

Both helpers are plain jnp and run unchanged inside a Pallas kernel
body (interpret or compiled), inside ``jit``, or eagerly.
"""
from __future__ import annotations

import jax.numpy as jnp


def sorted_unique_ranks(flat: jnp.ndarray):
    """Sorted-unique union of ``flat`` [R] int keys, plus the slot map.

    Returns ``(uniq [R], rank [R] i32)``:

      * ``uniq[j]`` is the j-th distinct key in ascending order;
        entries at or past the distinct count keep the 0 placeholder —
        no slot's ``rank`` ever points at them, so a gather pass may
        touch them harmlessly (or bound its loop by the distinct
        count);
      * ``rank[i]`` maps flat slot ``i`` to its key's unique rank:
        ``uniq[rank[i]] == flat[i]`` for every slot.

    The sort is stable, so among slots sharing a key the earliest
    flat-order slot defines the group — the same "first requester pays
    the DMA" order ``join_mask`` marks joiners against.
    """
    r = flat.shape[0]
    sort_idx = jnp.argsort(flat)                  # stable
    sb = flat[sort_idx]
    first = jnp.concatenate([jnp.ones((1,), bool), sb[1:] != sb[:-1]])
    rank = jnp.cumsum(first) - 1                  # sorted pos -> rank
    # duplicates write equal values, so the scatters are deterministic
    uniq = jnp.zeros((r,), flat.dtype).at[rank].set(sb)
    req_rank = jnp.zeros((r,), jnp.int32).at[sort_idx].set(
        rank.astype(jnp.int32))
    return uniq, req_rank


def join_mask(keys: jnp.ndarray) -> jnp.ndarray:
    """Mark slots whose key an earlier slot in the same row already
    carries.

    ``keys`` [T, R] int -> joined [T, R] bool: True where some earlier
    (flat-order) slot of the same row has the same key — the earliest
    requester of each duplicate group stays False (it pays the gather);
    every later one is a join. Rows are independent dedup scopes (one
    row = one kernel tile, or one row = the whole batch); slots that
    must never join (non-cold requests, padding) should carry unique
    negative sentinel keys.
    """
    t, r = keys.shape
    order = jnp.argsort(keys, axis=1)             # stable
    sk = jnp.take_along_axis(keys, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((t, 1), bool), sk[:, 1:] == sk[:, :-1]], axis=1)
    return jnp.zeros((t, r), bool).at[
        jnp.arange(t)[:, None], order].set(dup)
