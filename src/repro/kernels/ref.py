"""Pure-jnp oracles for every kernel (the allclose reference)."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_l2_ref(q: jnp.ndarray, x: jnp.ndarray,
                    metric: str = "l2") -> jnp.ndarray:
    """[Q, D] x [N, D] -> [Q, N]; squared L2 or negated IP."""
    dot = q.astype(jnp.float32) @ x.astype(jnp.float32).T
    if metric == "ip":
        return -dot
    qq = jnp.sum(q.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    xx = jnp.sum(x.astype(jnp.float32) ** 2, axis=1)
    return jnp.maximum(qq + xx[None, :] - 2.0 * dot, 0.0)


def pq_adc_ref(luts: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """luts [B, M, K] f32, codes [N, M] int -> [B, N] ADC distances."""
    m = luts.shape[1]
    gather = luts[:, jnp.arange(m)[None, :], codes.astype(jnp.int32)]
    return gather.sum(axis=-1)


def tier0_fetch_rank_ref(queries: jnp.ndarray, blocks: jnp.ndarray,
                         hot_slot_of: jnp.ndarray, hot_vecs: jnp.ndarray,
                         cold_vecs: jnp.ndarray, metric: str = "l2"):
    """Oracle for the fused tier-0 probe+gather+rank kernel.

    queries [Q, D]; blocks [Q, F]; hot_slot_of [rho] (-1 = cold);
    hot_vecs [H, eps, D]; cold_vecs [rho, eps, D] ->
    (dists [Q, F*eps] f32, hit [Q, F] i32)."""
    slot = hot_slot_of[blocks]
    hit = slot >= 0
    tiles = jnp.where(hit[:, :, None, None],
                      hot_vecs[jnp.maximum(slot, 0)],
                      cold_vecs[blocks])
    qn, f, eps, d_dim = tiles.shape
    t32 = tiles.reshape(qn, f * eps, d_dim).astype(jnp.float32)
    q32 = queries.astype(jnp.float32)
    if metric == "ip":
        d = -jnp.einsum("qd,qed->qe", q32, t32)
    else:
        d = jnp.sum(jnp.square(t32 - q32[:, None, :]), axis=-1)
    return d, hit.astype(jnp.int32)


def fused_round_ref(queries: jnp.ndarray, u: jnp.ndarray,
                    block_of: jnp.ndarray, hot_slot_of: jnp.ndarray,
                    hot_vecs: jnp.ndarray, hot_vid: jnp.ndarray,
                    hot_nbrs: jnp.ndarray, vecs: jnp.ndarray,
                    vid: jnp.ndarray, nbrs: jnp.ndarray, n_expand: int,
                    metric: str = "l2"):
    """Oracle for the fused per-round kernel (``fused_round``).

    Straight per-request gathers — no dedup route — because dedup only
    changes *which gather produced* a tile, never its payload: the
    kernel must match this bitwise. queries [Q, D]; u [Q, F] picked
    candidate ids (-1 = converged/empty) ->
    (dists [Q, F*eps], vid [Q, F*eps], nbrs [Q, F*eps, Lam],
    hit [Q, F] i32, order [Q, n_expand])."""
    qn, f = u.shape
    eps = vecs.shape[1]
    b = block_of[jnp.maximum(u, 0)]                          # [Q, F]
    slot = hot_slot_of[b]
    hit = slot >= 0
    s_safe = jnp.maximum(slot, 0)
    tiles = jnp.where(hit[:, :, None, None], hot_vecs[s_safe],
                      vecs[b])
    vid_g = jnp.where(hit[:, :, None], hot_vid[s_safe],
                      vid[b]).reshape(qn, f * eps)
    nbrs_g = jnp.where(hit[:, :, None, None], hot_nbrs[s_safe],
                       nbrs[b]).reshape(qn, f * eps, -1)
    t32 = tiles.reshape(qn, f * eps, -1).astype(jnp.float32)
    q32 = queries.astype(jnp.float32)
    if metric == "ip":
        dd = -jnp.einsum("qd,qed->qe", q32, t32)
    else:
        dd = jnp.sum(jnp.square(t32 - q32[:, None, :]), axis=-1)
    f_valid = jnp.repeat(u >= 0, eps, axis=1)
    slot_valid = (vid_g >= 0) & f_valid
    dd_m = jnp.where(slot_valid, dd, jnp.inf)
    is_target = (vid_g[:, :, None] == u[:, None, :]).any(-1) \
        & (vid_g >= 0)
    sel_key = jnp.where(is_target, -jnp.inf, dd_m)
    order = jnp.argsort(sel_key, axis=1)[:, :n_expand]
    return (dd, vid_g, nbrs_g, hit.astype(jnp.int32),
            order.astype(jnp.int32))


def block_rank_ref(queries: jnp.ndarray, tiles: jnp.ndarray,
                   top_m: int, metric: str = "l2"):
    """queries [Q, D]; tiles [Q, eps, D] (the gathered block per query).
    Returns (dists [Q, eps], top_idx [Q, top_m]) — top_m slot indices by
    ascending distance."""
    q32 = queries.astype(jnp.float32)
    t32 = tiles.astype(jnp.float32)
    if metric == "ip":
        d = -jnp.einsum("qd,qed->qe", q32, t32)
    else:
        d = jnp.sum((t32 - q32[:, None, :]) ** 2, axis=-1)
    idx = jnp.argsort(d, axis=1)[:, :top_m]
    return d, idx.astype(jnp.int32)
