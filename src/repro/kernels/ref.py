"""Pure-jnp oracles for every kernel (the allclose reference)."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_l2_ref(q: jnp.ndarray, x: jnp.ndarray,
                    metric: str = "l2") -> jnp.ndarray:
    """[Q, D] x [N, D] -> [Q, N]; squared L2 or negated IP."""
    dot = q.astype(jnp.float32) @ x.astype(jnp.float32).T
    if metric == "ip":
        return -dot
    qq = jnp.sum(q.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    xx = jnp.sum(x.astype(jnp.float32) ** 2, axis=1)
    return jnp.maximum(qq + xx[None, :] - 2.0 * dot, 0.0)


def pq_adc_ref(luts: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """luts [B, M, K] f32, codes [N, M] int -> [B, N] ADC distances."""
    m = luts.shape[1]
    gather = luts[:, jnp.arange(m)[None, :], codes.astype(jnp.int32)]
    return gather.sum(axis=-1)


def tier0_fetch_rank_ref(queries: jnp.ndarray, blocks: jnp.ndarray,
                         hot_slot_of: jnp.ndarray, hot_vecs: jnp.ndarray,
                         cold_vecs: jnp.ndarray, metric: str = "l2"):
    """Oracle for the fused tier-0 probe+gather+rank kernel.

    queries [Q, D]; blocks [Q, F]; hot_slot_of [rho] (-1 = cold);
    hot_vecs [H, eps, D]; cold_vecs [rho, eps, D] ->
    (dists [Q, F*eps] f32, hit [Q, F] i32)."""
    slot = hot_slot_of[blocks]
    hit = slot >= 0
    tiles = jnp.where(hit[:, :, None, None],
                      hot_vecs[jnp.maximum(slot, 0)],
                      cold_vecs[blocks])
    qn, f, eps, d_dim = tiles.shape
    t32 = tiles.reshape(qn, f * eps, d_dim).astype(jnp.float32)
    q32 = queries.astype(jnp.float32)
    if metric == "ip":
        d = -jnp.einsum("qd,qed->qe", q32, t32)
    else:
        d = jnp.sum(jnp.square(t32 - q32[:, None, :]), axis=-1)
    return d, hit.astype(jnp.int32)


def block_rank_ref(queries: jnp.ndarray, tiles: jnp.ndarray,
                   top_m: int, metric: str = "l2"):
    """queries [Q, D]; tiles [Q, eps, D] (the gathered block per query).
    Returns (dists [Q, eps], top_idx [Q, top_m]) — top_m slot indices by
    ascending distance."""
    q32 = queries.astype(jnp.float32)
    t32 = tiles.astype(jnp.float32)
    if metric == "ip":
        d = -jnp.einsum("qd,qed->qe", q32, t32)
    else:
        d = jnp.sum((t32 - q32[:, None, :]) ** 2, axis=-1)
    idx = jnp.argsort(d, axis=1)[:, :top_m]
    return d, idx.astype(jnp.int32)
