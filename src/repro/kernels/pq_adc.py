"""Batched PQ asymmetric-distance (ADC) kernel.

TPU adaptation (DESIGN.md §2): the CPU implementation is a per-subspace
table *gather*, which the TPU vector unit does poorly. Instead each code
tile is expanded to a one-hot [BN, M*K] matrix in VMEM and multiplied
against the flattened LUTs [M*K, B] on the MXU — one matmul scores a tile
of database codes against *all* queries in the batch.

Grid over code tiles; LUTs stay VMEM-resident across the grid
(index_map pins block (0, 0)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 256


def _adc_kernel(codes_ref, luts_ref, o_ref, *, num_centroids: int):
    codes = codes_ref[...].astype(jnp.int32)        # [BN, M]
    luts = luts_ref[...]                            # [M*K, B] f32
    bn, m = codes.shape
    k = num_centroids
    # one-hot over the flattened (M, K) axis: row i has ones at
    # positions j*K + codes[i, j]
    flat_idx = codes + (jnp.arange(m, dtype=jnp.int32) * k)[None, :]
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, m, k), 2) \
        + (jnp.arange(m, dtype=jnp.int32) * k)[None, :, None]
    onehot = (iota == flat_idx[:, :, None]).astype(jnp.float32)
    onehot = onehot.reshape(bn, m * k)
    o_ref[...] = jax.lax.dot_general(
        onehot, luts, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # [BN, B]


def pq_adc(codes: jnp.ndarray, luts: jnp.ndarray,
           interpret: bool = True, bn: int = BN) -> jnp.ndarray:
    """codes [N, M] uint8, luts [B, M, K] f32 -> [N, B] distances."""
    n, m = codes.shape
    b, m2, k = luts.shape
    assert m == m2 and n % bn == 0, (n, m, m2, bn)
    luts_flat = jnp.moveaxis(luts.reshape(b, m * k), 0, 1)  # [M*K, B]
    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_adc_kernel, num_centroids=k),
        grid=grid,
        in_specs=[pl.BlockSpec((bn, m), lambda i: (i, 0)),
                  pl.BlockSpec((m * k, b), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bn, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        interpret=interpret,
    )(codes, luts_flat)
