# Pallas TPU kernels for the paper's compute hot-spots (§5.1):
#   l2_tile     — tiled exact L2/IP distance (MXU): brute force, build, rank
#   pq_adc      — batched PQ asymmetric-distance via one-hot MXU matmul
#   block_topk  — fused block-tile ranking: distances + top-m select (VPU)
#   tier0_fetch — fused tier-0 probe + gather + rank: the device search's
#                 ISSUE-3 fetch stage (VMEM hot-tile hit or HBM block DMA)
#                 + fused_round, the divergence-aware batched round:
#                 whole-batch sorted-unique dedup + once-per-distinct-
#                 block gather (double-buffered DMA when compiled) +
#                 per-tile broadcast + rank + top-M expansion order
#   dedup       — the shared sorted-unique / join-mask helpers both the
#                 kernel's union pass and the search loop's accounting
#                 mirror group duplicates with (they must never drift)
# Each kernel: <name>.py (pl.pallas_call + BlockSpec) with a pure-jnp
# oracle in ref.py and the jit'd dispatch wrapper in ops.py.
from repro.kernels.dedup import join_mask, sorted_unique_ranks
from repro.kernels.ops import (pairwise_l2, pq_adc_batch, block_rank,
                               tier0_rank, fused_round, round_tile,
                               set_interpret, interpret_default)
