"""Tiled exact-distance kernel (MXU).

Grid over (query tiles, base tiles); each program computes one
[BQ, BN] distance tile from VMEM-resident [BQ, D] and [BN, D] blocks.
The -2*q@x.T term is the MXU matmul; the norms ride along on the VPU.
BQ/BN default to 128/512 — MXU-aligned (multiples of 128) and well under
VMEM (~128 KiB + 256 KiB + 256 KiB at D=128 f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128
BN = 512


def _l2_kernel(q_ref, x_ref, o_ref, *, metric: str):
    q = q_ref[...].astype(jnp.float32)          # [BQ, D]
    x = x_ref[...].astype(jnp.float32)          # [BN, D]
    dot = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    if metric == "ip":
        o_ref[...] = -dot
    else:
        qq = jnp.sum(q * q, axis=1, keepdims=True)
        xx = jnp.sum(x * x, axis=1)[None, :]
        o_ref[...] = jnp.maximum(qq + xx - 2.0 * dot, 0.0)


def l2_tile(q: jnp.ndarray, x: jnp.ndarray, metric: str = "l2",
            interpret: bool = True, bq: int = BQ, bn: int = BN
            ) -> jnp.ndarray:
    """[Q, D] x [N, D] -> [Q, N] (f32). Q % bq == 0 and N % bn == 0 is
    handled by padding in ops.pairwise_l2."""
    qn, d = q.shape
    n = x.shape[0]
    assert qn % bq == 0 and n % bn == 0, (qn, n, bq, bn)
    grid = (qn // bq, n // bn)
    return pl.pallas_call(
        functools.partial(_l2_kernel, metric=metric),
        grid=grid,
        in_specs=[pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((bn, d), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, n), jnp.float32),
        interpret=interpret,
    )(q, x)
