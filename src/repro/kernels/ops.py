"""jit'd dispatch wrappers around the Pallas kernels.

On this CPU container the kernels execute with ``interpret=True`` (the
kernel body runs in Python under the Pallas interpreter — bit-faithful to
the TPU lowering semantics); on TPU ``set_interpret(False)`` compiles the
real Mosaic kernels. Wrappers pad inputs to tile multiples and strip the
padding from outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import block_topk as _bt
from repro.kernels import l2_tile as _l2
from repro.kernels import pq_adc as _adc
from repro.kernels import tier0_fetch as _t0

_INTERPRET = True


def set_interpret(flag: bool) -> None:
    global _INTERPRET
    _INTERPRET = flag


def interpret_default() -> bool:
    return _INTERPRET


def _pad_rows(a: jnp.ndarray, mult: int) -> jnp.ndarray:
    n = a.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return a
    return jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))


@functools.partial(jax.jit,
                   static_argnames=("metric", "interpret", "bq", "bn"))
def pairwise_l2(q: jnp.ndarray, x: jnp.ndarray, metric: str = "l2",
                interpret: bool = None, bq: int = None, bn: int = None
                ) -> jnp.ndarray:
    """[Q, D] x [N, D] -> [Q, N] distances via the l2_tile kernel."""
    interpret = _INTERPRET if interpret is None else interpret
    bq = bq or min(_l2.BQ, max(8, q.shape[0]))
    bn = bn or min(_l2.BN, max(8, x.shape[0]))
    qp, xp = _pad_rows(q, bq), _pad_rows(x, bn)
    out = _l2.l2_tile(qp, xp, metric=metric, interpret=interpret,
                      bq=bq, bn=bn)
    out = out[: q.shape[0], : x.shape[0]]
    if metric == "l2":
        return out
    # padded base rows are zero vectors -> -0.0 for ip; harmless, sliced.
    return out


@functools.partial(jax.jit, static_argnames=("interpret", "bn"))
def pq_adc_batch(codes: jnp.ndarray, luts: jnp.ndarray,
                 interpret: bool = None, bn: int = None) -> jnp.ndarray:
    """codes [N, M] uint8 x luts [B, M, K] -> [B, N] ADC distances."""
    interpret = _INTERPRET if interpret is None else interpret
    bn = bn or min(_adc.BN, max(8, codes.shape[0]))
    cp = _pad_rows(codes, bn)
    out = _adc.pq_adc(cp, luts.astype(jnp.float32), interpret=interpret,
                      bn=bn)
    return jnp.moveaxis(out, 0, 1)[:, : codes.shape[0]]


@functools.partial(jax.jit,
                   static_argnames=("top_m", "metric", "interpret", "bq"))
def block_rank(queries: jnp.ndarray, tiles: jnp.ndarray, top_m: int,
               metric: str = "l2", interpret: bool = None,
               bq: int = None):
    """queries [Q, D] x gathered tiles [Q, eps, D] ->
    (dists [Q, eps], top_idx [Q, top_m])."""
    interpret = _INTERPRET if interpret is None else interpret
    bq = bq or min(_bt.BQ, max(8, queries.shape[0]))
    qp = _pad_rows(queries, bq)
    tp = _pad_rows(tiles, bq)
    d, idx = _bt.block_topk(qp, tp, top_m, metric=metric,
                            interpret=interpret, bq=bq)
    return d[: queries.shape[0]], idx[: queries.shape[0]]


def round_tile(qn: int, cap: int = 0) -> int:
    """The query-tile size the fused round kernel's rank pass runs at
    for a batch of ``qn`` (``cap`` > 0 overrides the ``BQ`` ceiling —
    ``DeviceSearchParams.round_tile_cap``, the knob the cross-tile
    sweeps/tests force multi-tile batches with). Since the batch-scope
    rework (DESIGN.md §8) dedup spans the WHOLE batch; the tile is only
    the idle-skip / compaction granularity and the intra- vs cross-tile
    boundary of the split ``dedup_saved`` accounting."""
    lim = cap if cap > 0 else _t0.BQ
    return min(lim, max(8, qn))


@functools.partial(jax.jit,
                   static_argnames=("n_expand", "metric", "interpret",
                                    "bq", "pipeline_dma", "fuse_union",
                                    "_force_dma"))
def fused_round(queries: jnp.ndarray, u: jnp.ndarray,
                block_of: jnp.ndarray, hot_slot_of: jnp.ndarray,
                hot_vecs: jnp.ndarray, hot_vid: jnp.ndarray,
                hot_nbrs: jnp.ndarray, vecs: jnp.ndarray,
                vid: jnp.ndarray, nbrs: jnp.ndarray, n_expand: int,
                metric: str = "l2", interpret: bool = None,
                bq: int = None, pipeline_dma: bool = False,
                fuse_union: bool = False, _force_dma: bool = False):
    """Fused per-round fetch pipeline of the batched device search:
    whole-batch sorted-unique dedup (pass 1, fused into the gather
    kernel's SMEM slot map when ``fuse_union`` is set),
    once-per-distinct-block gather — double-buffered when
    ``pipeline_dma`` is on and the kernels compile (pass 2a) — then
    per-tile broadcast + exact distances + per-query top-``n_expand``
    expansion order (pass 2b). Padded query rows carry ``u = -1``
    (converged), so all-pad tiles take the rank kernel's skip path;
    their outputs are sliced off."""
    interpret = _INTERPRET if interpret is None else interpret
    bq = bq or round_tile(queries.shape[0])
    qp = _pad_rows(queries, bq)
    pad = (-u.shape[0]) % bq
    up = u if pad == 0 else jnp.pad(u, ((0, pad), (0, 0)),
                                    constant_values=-1)
    outs = _t0.fused_round(qp, up, block_of, hot_slot_of, hot_vecs,
                           hot_vid, hot_nbrs, vecs, vid, nbrs,
                           n_expand, metric=metric,
                           interpret=interpret, bq=bq,
                           pipeline_dma=pipeline_dma,
                           fuse_union=fuse_union,
                           _force_dma=_force_dma)
    return tuple(o[: queries.shape[0]] for o in outs)


@functools.partial(jax.jit, static_argnames=("metric", "interpret", "bq"))
def tier0_rank(queries: jnp.ndarray, blocks: jnp.ndarray,
               hot_slot_of: jnp.ndarray, hot_vecs: jnp.ndarray,
               cold_vecs: jnp.ndarray, metric: str = "l2",
               interpret: bool = None, bq: int = None):
    """Fused tier-0 probe + gather + rank (the device fetch stage):
    queries [Q, D] x target blocks [Q, F] -> (dists [Q, F*eps] over the
    gathered tiles, hit [Q, F] tier-0 mask). Padded rows probe block 0;
    their outputs are sliced off."""
    interpret = _INTERPRET if interpret is None else interpret
    bq = bq or min(_t0.BQ, max(8, queries.shape[0]))
    qp = _pad_rows(queries, bq)
    bp = _pad_rows(blocks, bq)
    d, hit = _t0.tier0_fetch_rank(qp, bp, hot_slot_of, hot_vecs,
                                  cold_vecs, metric=metric,
                                  interpret=interpret, bq=bq)
    return d[: queries.shape[0]], hit[: queries.shape[0]]
