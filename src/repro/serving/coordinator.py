"""Vector-DB serving plane (Fig. 1(b)): segment servers + coordinator.

A machine holds multiple independent segments (each with its own
Starling index); the coordinator scatters a query batch to the relevant
segments (all by default; a partition-pruning hook mirrors the
query-dispatch optimizations of Pyramid/LANNS), gathers per-segment
top-k and merges hierarchically — exactly the structure the on-mesh
``make_search_step`` reproduces with shard_map (segments <-> model
ranks, merge <-> all-gather).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.device_search import DeviceSegment, device_anns
from repro.core.iostats import IOStats


def merge_topk(ids: Sequence[np.ndarray], dists: Sequence[np.ndarray],
               offsets: Sequence[int], k: int
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Merge per-segment results into global top-k.

    ids[i]/dists[i]: [Q, k_i] from segment i; offsets[i]: id-space base
    of segment i. Invalid slots: id < 0 / dist inf."""
    gids = np.concatenate(
        [np.where(i >= 0, i + off, -1) for i, off in zip(ids, offsets)],
        axis=1)
    gd = np.concatenate(dists, axis=1)
    gd = np.where(gids >= 0, gd, np.inf)
    order = np.argsort(gd, axis=1)[:, :k]
    return (np.take_along_axis(gids, order, axis=1),
            np.take_along_axis(gd, order, axis=1))


@dataclasses.dataclass
class SegmentServer:
    """One segment + its device arrays + search knobs."""
    segment: DeviceSegment
    offset: int                   # base of this segment's id space
    num_vectors: int
    k_default: int = 10
    candidates: int = 64
    max_hops: int = 256
    metric: str = "l2"
    fetch_width: int = 2          # blocks fetched per DMA round-trip
    #                               (see EXPERIMENTS §Perf cell 3)

    def search(self, queries: np.ndarray, k: Optional[int] = None
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        import jax.numpy as jnp
        k = k or self.k_default
        ids, dists, io, _ = device_anns(
            self.segment, jnp.asarray(queries, jnp.float32), k=k,
            candidates=self.candidates, max_hops=self.max_hops,
            metric=self.metric, fetch_width=self.fetch_width)
        return np.asarray(ids), np.asarray(dists), np.asarray(io)


class QueryCoordinator:
    """Scatter -> per-segment search -> hierarchical merge."""

    def __init__(self, servers: List[SegmentServer],
                 prune_fn: Optional[Callable] = None):
        self.servers = servers
        self.prune_fn = prune_fn          # (queries) -> segment indices

    def search(self, queries: np.ndarray, k: int = 10
               ) -> Tuple[np.ndarray, np.ndarray, Dict]:
        targets = (self.prune_fn(queries) if self.prune_fn
                   else list(range(len(self.servers))))
        ids, dists, offs, total_io = [], [], [], 0
        for si in targets:
            s = self.servers[si]
            i, d, io = s.search(queries, k)
            ids.append(i)
            dists.append(d)
            offs.append(s.offset)
            total_io += int(io.sum())
        gi, gd = merge_topk(ids, dists, offs, k)
        stats = {"segments_searched": len(targets),
                 "total_block_reads": total_io,
                 "mean_block_reads_per_query":
                     total_io / max(queries.shape[0], 1)}
        return gi, gd, stats
