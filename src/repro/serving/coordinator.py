"""Vector-DB serving plane (Fig. 1(b)): segment servers + coordinator.

A machine holds multiple independent segments (each with its own
Starling index); the coordinator scatters a query batch to the relevant
segments (all by default; a partition-pruning hook mirrors the
query-dispatch optimizations of Pyramid/LANNS), gathers per-segment
top-k and merges hierarchically — exactly the structure the on-mesh
``make_search_step`` reproduces with shard_map (segments <-> model
ranks, merge <-> all-gather).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.device_search import DeviceSegment, device_anns
from repro.core.iostats import IOStats
from repro.core.params import DeviceSearchParams, SearchParams
from repro.core.search import SegmentView, anns
from repro.io.async_fetch import AsyncFetchQueue
from repro.io.cached_store import CachedBlockStore
from repro.io.hottier import merge_hot_cold
from repro.serving import target as tgt

# serving default: the divergence-aware batched preset (wide fetch +
# cross-query dedup + active-query compaction) at the paper's Γ;
# tier-0 budget rides on the segment arrays themselves
# (``from_segment``), not on these search knobs
from repro.configs.starling_segment import DEVICE_SEARCH_BATCH

SERVE_DEVICE_SEARCH = dataclasses.replace(DEVICE_SEARCH_BATCH,
                                          candidates=64)


def merge_topk(ids: Sequence[np.ndarray], dists: Sequence[np.ndarray],
               offsets: Sequence[int], k: int
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Merge per-segment results into global top-k.

    ids[i]/dists[i]: [Q, k_i] from segment i; offsets[i]: id-space base
    of segment i. Invalid slots: id < 0 / dist inf.

    Ordering is (dist, global id) — ties broken by the smaller global
    id, with invalid slots keyed past every real id. This matches the
    device-side shard merge (``device_search.merge_shard_topk``)
    exactly, so a host-merged and a device-merged fan-out over the
    same shards return bit-identical ``(ids, dists)`` regardless of
    segment arrival order or placement."""
    gids = np.concatenate(
        [np.where(i >= 0, i + off, -1) for i, off in zip(ids, offsets)],
        axis=1).astype(np.int64)
    gd = np.concatenate(dists, axis=1)
    gd = np.where(gids >= 0, gd, np.inf)
    key_id = np.where(gids >= 0, gids, np.iinfo(np.int64).max)
    # lexsort: last key is primary -> sort by dist, break ties by id
    order = np.lexsort((key_id, gd), axis=1)[:, :k]
    return (np.take_along_axis(gids, order, axis=1),
            np.take_along_axis(gd, order, axis=1))


@dataclasses.dataclass
class SegmentServer:
    """One segment + its device arrays + search knobs.

    ``params`` bundles every online knob (``DeviceSearchParams``); a
    per-request ``k`` override replaces just that field. When the
    segment was packed with a tier-0 budget (``from_segment``), hot
    touches land in ``last_tier0_hits`` instead of the io column;
    cold touches that joined another query's same-round gather (the
    batched path's cross-query dedup) land in ``last_dedup_saved`` —
    actual DMAs for the batch = io - dedup_saved.

    ``host`` (optional) keeps the host ``Segment`` the device arrays
    were packed from; the serving ``RepackScheduler`` needs it to
    rebuild the tier-0 pack online (``repack``). Servers without it
    simply cannot be repack targets.

    ``hot_tier`` (optional, a ``repro.io.hottier.HotTier``) turns the
    server hybrid: queries route hot-first on the host, the device
    search is seeded from the exit frontier (``device_anns``'s
    ``seeds`` override), results merge by ``(dist, id)`` with
    ``tombstones`` masked from both sides, and the memory work lands
    in the ``hot_tier_hits`` batch column."""
    segment: DeviceSegment
    offset: int                   # base of this segment's id space
    num_vectors: int
    k_default: int = 10
    params: DeviceSearchParams = SERVE_DEVICE_SEARCH
    metric: str = "l2"
    host: Optional[object] = None  # the host Segment (repack source)
    hot_tier: Optional[object] = None   # repro.io.hottier.HotTier
    tombstones: Optional[np.ndarray] = None  # [num_vectors] bool

    def search(self, queries: np.ndarray, k: Optional[int] = None
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        import jax.numpy as jnp
        k = k or self.k_default
        queries = np.ascontiguousarray(queries, np.float32)
        n_dead = (int(self.tombstones.sum())
                  if self.tombstones is not None else 0)
        route = None
        seeds = None
        k_cold = k
        candidates = max(self.params.candidates, k)
        if self.hot_tier is not None:
            route = self.hot_tier.route(queries, k)
            exits = route.exits.astype(np.int32)
            # union with the nav entries: the exits start the device
            # beam where memory converged, the nav entries keep basin
            # diversity; the hot tier absorbed the early exploration so
            # the cold beam narrows (cold_gamma_frac) at equal recall
            nav = self.host.view.nav if self.host is not None else None
            if nav is not None:
                nav_seeds = nav.entry_points(
                    queries, beam=self.params.nav_beam,
                    num=self.params.entry_points).astype(np.int32)
                exits = np.concatenate([exits, nav_seeds], axis=1)
            seeds = jnp.asarray(exits, jnp.int32)
            # over-fetch so the cold top-k survives the tombstone mask
            k_cold = k + min(n_dead, k)
            candidates = max(k_cold, int(round(
                self.params.candidates
                * self.hot_tier.params.cold_gamma_frac)))
        # a per-request k above the configured beam widens Γ with it
        # (DeviceSearchParams requires candidates >= k)
        p = dataclasses.replace(
            self.params, k=k_cold, candidates=max(candidates, k_cold))
        r = device_anns(self.segment, jnp.asarray(queries, jnp.float32),
                        p, metric=self.metric, seeds=seeds)
        self.last_io = np.asarray(r.io)
        self.last_tier0_hits = np.asarray(r.tier0_hits)
        self.last_hops = np.asarray(r.hops)
        self.last_dedup_saved = np.asarray(r.dedup_saved)
        self.last_dedup_cross = np.asarray(r.dedup_cross)
        self.last_spec_hits = np.asarray(r.spec_hits)
        self.last_spec_wasted = np.asarray(r.spec_wasted)
        self.last_rounds = int(r.rounds)
        # per-round trace buffer (params.trace_rounds; repro.obs) —
        # None when tracing is off
        self.last_round_log = (np.asarray(r.round_log)
                               if r.round_log is not None else None)
        cold_ids = np.asarray(r.ids)
        cold_dists = np.asarray(r.dists)
        if route is None:
            self.last_hot_tier_hits = np.zeros(queries.shape[0], np.int64)
            return cold_ids, cold_dists, np.asarray(r.io)
        self.last_hot_tier_hits = route.hot_hits.astype(np.int64)
        ci = cold_ids.astype(np.int64)
        cd = cold_dists.astype(np.float32)
        if self.tombstones is not None:
            dead = (ci >= 0) & self.tombstones[np.maximum(ci, 0)]
            ci = np.where(dead, -1, ci)
            cd = np.where(dead, np.inf, cd)
        out_i = np.full((queries.shape[0], k), -1, np.int64)
        out_d = np.full((queries.shape[0], k), np.inf, np.float32)
        for qi in range(queries.shape[0]):
            out_i[qi], out_d[qi] = merge_hot_cold(
                k, route.ids[qi], route.dists[qi], ci[qi], cd[qi])
        return out_i, out_d, np.asarray(r.io)

    def repack(self, observed, plan=None) -> int:
        """Swap the tier-0 pack for one re-ranked by ``observed``
        per-block demand counts (same budget, same compiled
        executable; results stay bit-identical — exact copies either
        way). ``plan`` short-circuits selection when the caller (the
        scheduler) already planned the pack to price its drift.
        Returns the number of pack slots that changed."""
        if self.host is None:
            raise ValueError("SegmentServer.host is unset — build the "
                             "server with its host Segment to repack")
        from repro.core.device_search import repack_tier0
        self.segment, changed = repack_tier0(self.segment, self.host,
                                             observed, plan=plan)
        return changed

    # ------------------------------------- SegmentTarget capability hooks
    def batch_stats(self) -> Dict[str, object]:
        """Device columns of the last served batch (the exact
        ``IOStats.from_device_batch`` inputs); {} before any batch."""
        if getattr(self, "last_tier0_hits", None) is None:
            return {}
        return {"io": self.last_io, "tier0_hits": self.last_tier0_hits,
                "hops": self.last_hops,
                "dedup_saved": self.last_dedup_saved,
                "dedup_cross": self.last_dedup_cross,
                "spec_hits": self.last_spec_hits,
                "spec_wasted": self.last_spec_wasted,
                "hot_tier_hits": self.last_hot_tier_hits,
                "rounds": self.last_rounds,
                "dma_pipelined": (self.params.pipeline_dma
                                  and self.params.fetch_impl == "fused"),
                "dma_speculative": self.params.speculate}

    def repack_source(self):
        return self.host

    def attach_obs(self, tracer, metrics) -> None:
        if self.hot_tier is not None and \
                (tracer is not None or metrics is not None):
            self.hot_tier.attach_obs(tracer, metrics,
                                     target=f"seg{self.offset}")


@dataclasses.dataclass
class HostSegmentServer:
    """Host-path segment server with ONE block cache shared across all
    queries it serves (repro.io deployment, Fig. 1(b)).

    ``view.store`` should be a ``CachedBlockStore`` (build the segment
    with ``SegmentParams.cache`` enabled); because the store object is
    shared, residency survives between requests and the hit rate comes
    from inter-query locality on the entry neighborhood. With an
    uncached view this degrades gracefully to the seed behavior.
    """
    view: SegmentView
    params: SearchParams
    offset: int                   # base of this segment's id space
    num_vectors: int
    k_default: int = 10
    tracer: Optional[object] = None  # repro.obs.trace.Tracer (optional)

    @classmethod
    def from_segment(cls, seg, offset: int) -> "HostSegmentServer":
        return cls(view=seg.view, params=seg.params.search, offset=offset,
                   num_vectors=seg.num_vectors)

    def search(self, queries: np.ndarray, k: Optional[int] = None
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        if self.tracer is not None:
            with self.tracer.span("host.search", cat="serve",
                                  track=f"seg{self.offset}",
                                  n_queries=int(queries.shape[0]),
                                  k=int(k or self.k_default)) as sp:
                ids, dists, io = self._search(queries, k)
                sp["block_reads"] = int(io.sum())
            return ids, dists, io
        return self._search(queries, k)

    def _search(self, queries: np.ndarray, k: Optional[int]
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        ids, dists, stats = anns(self.view, queries,
                                 k or self.k_default, self.params)
        self.last_stats = stats
        io = np.asarray([s.block_reads for s in stats], np.int64)
        return ids, dists, io

    def cache_stats(self) -> Dict[str, float]:
        """Lifetime cache counters of the shared store (empty if
        uncached). When the store carries a metrics registry
        (``CachedBlockStore.attach_obs``), the same counters are
        republished through it first, so this dict is a view of what
        the registry reports."""
        store = self.view.store
        if not isinstance(store, CachedBlockStore):
            return {}
        store.publish_metrics()
        t = store.total
        return {"cache_hits": t.cache_hits,
                "tier2_hits": t.tier2_hits,
                "cache_misses": t.cache_misses,
                "io_round_trips": t.io_round_trips,
                "prefetched_blocks": t.prefetched_blocks,
                "queue_fetches": t.queue_fetches,
                "inflight_peak": t.inflight_peak,
                "inflight_joins": t.inflight_joins,
                "completion_reorders": t.completion_reorders,
                "hit_rate": t.cache_hit_rate}

    # ------------------------------------- SegmentTarget capability hooks
    def lifetime_stats(self) -> Dict[str, float]:
        return self.cache_stats()

    def demand_feed(self):
        store = self.view.store
        return store if isinstance(store, CachedBlockStore) else None

    def attach_obs(self, tracer, metrics) -> None:
        if tracer is not None and self.tracer is None:
            self.tracer = tracer
        store = self.view.store
        if isinstance(store, CachedBlockStore) and \
                (tracer is not None or metrics is not None):
            store.attach_obs(tracer, metrics, target=f"seg{self.offset}")


def attach_shared_fetch_queue(servers: Sequence["HostSegmentServer"],
                              depth: int = 8,
                              scheduler=None) -> AsyncFetchQueue:
    """Share ONE AsyncFetchQueue across every cache-fronted server view.

    This is the serving-plane half of the async subsystem: with a
    common queue, concurrent queries (and co-located segments backed by
    the same store) dedup in-flight fetches of the same block — a
    demand read arriving while the block is still in flight joins the
    existing ticket (``IOStats.inflight_joins``) instead of issuing a
    new round trip. Returns the queue so callers can inspect its
    lifetime counters (``submitted``/``delivered``/``reorders``/
    ``inflight_peak``).

    ``scheduler`` (a ``repro.serving.RepackScheduler``) additionally
    registers every attached store as a demand feed, so a shared-queue
    deployment's tier-0 repacks select from the *union* of what all
    its stores observed — the same cross-query scope the queue dedups
    fetches in.

    Discovery goes through the ``SegmentTarget`` protocol: any target
    whose ``demand_feed()`` yields a ``CachedBlockStore`` is attached,
    so routers and future remote proxies participate without this
    function knowing their concrete type."""
    q = AsyncFetchQueue(depth=depth)
    attached = 0
    for s in servers:
        store = tgt.demand_feed(s)
        if isinstance(store, CachedBlockStore):
            # drains any private queue first so its in-flight fetches
            # are delivered, not orphaned
            store.attach_queue(q)
            if scheduler is not None:
                scheduler.attach_feed(store)
            attached += 1
    if attached == 0:
        raise ValueError("no cache-fronted serving targets to attach "
                         "the shared fetch queue to")
    return q


class QueryCoordinator:
    """Scatter -> per-segment search -> hierarchical merge.

    ``scheduler`` (a ``repro.serving.RepackScheduler``) turns the
    coordinator into the adaptive serving plane's control point: any
    target whose ``repack_source()`` yields a host ``Segment``
    registers as a repack target, any whose ``demand_feed()`` yields a
    cached store as a demand feed, and after every served batch the
    coordinator notes the device columns and lets the scheduler
    evaluate — so tier-0 packs follow the query stream with no extra
    plumbing at call sites.

    The coordinator speaks ONLY the ``SegmentTarget`` protocol (via
    the ``serving.target`` adapters): host servers, device servers and
    the mesh ``MeshQueryRouter`` are interchangeable entries of
    ``servers``."""

    def __init__(self, servers: List[tgt.SegmentTarget],
                 prune_fn: Optional[Callable] = None,
                 scheduler=None, tracer=None, metrics=None):
        self.servers = servers
        self.prune_fn = prune_fn          # (queries) -> segment indices
        self.scheduler = scheduler
        self.tracer = tracer              # repro.obs: coord.batch /
        #                                   coord.segment spans
        self.metrics = metrics            # repro.obs.MetricsRegistry the
        #                                   stats dict is re-expressed
        #                                   through (same keys, same
        #                                   values — snapshot() is the
        #                                   dashboard view of it)
        self._cache_seen: Dict[int, Tuple[int, int]] = {}  # per-server
        #   (hits, misses) lifetime watermark for per-call delta reporting
        for s in servers:
            if scheduler is not None:
                if tgt.repack_source(s) is not None:
                    scheduler.attach_target(s)
                feed = tgt.demand_feed(s)
                if feed is not None:
                    scheduler.attach_feed(feed)
            # wire the target (its store, fetch queue, ranks, ...) into
            # the same observability plane the coordinator reports
            # through
            if tracer is not None or metrics is not None:
                tgt.attach_obs(s, tracer, metrics)
        if scheduler is not None and tracer is not None and \
                getattr(scheduler, "tracer", None) is None:
            scheduler.tracer = tracer

    # every search() stats dict carries ALL of these keys, zeros
    # included — downstream consumers (dashboards, the obs bench) must
    # never KeyError on a cold batch. "repack" additionally appears on
    # batches where the scheduler evaluated.
    STATS_SCHEMA = ("segments_searched", "total_block_reads",
                    "mean_block_reads_per_query", "total_tier0_hits",
                    "total_dedup_saved", "total_dedup_cross",
                    "total_spec_hits", "total_spec_wasted",
                    "total_hot_tier_hits", "deduped_block_reads",
                    "cache_hits", "cache_misses", "cache_hit_rate")

    def search(self, queries: np.ndarray, k: int = 10
               ) -> Tuple[np.ndarray, np.ndarray, Dict]:
        if self.tracer is not None:
            with self.tracer.span("coord.batch", cat="serve",
                                  track="coord",
                                  n_queries=int(queries.shape[0]),
                                  k=int(k)) as sp:
                gi, gd, stats = self._search(queries, k)
                sp["block_reads"] = stats["total_block_reads"]
                sp["segments"] = stats["segments_searched"]
            return gi, gd, stats
        return self._search(queries, k)

    def _search(self, queries: np.ndarray, k: int
                ) -> Tuple[np.ndarray, np.ndarray, Dict]:
        targets = (self.prune_fn(queries) if self.prune_fn
                   else list(range(len(self.servers))))
        ids, dists, offs = [], [], []
        total_io, total_t0, total_saved, total_cross = 0, 0, 0, 0
        total_spec_h, total_spec_w, total_hot = 0, 0, 0
        for si in targets:
            s = self.servers[si]
            if self.tracer is not None:
                with self.tracer.span("coord.segment", cat="serve",
                                      track="coord",
                                      target=f"seg{s.offset}") as sp:
                    i, d, io = s.search(queries, k)
                    sp["block_reads"] = int(io.sum())
            else:
                i, d, io = s.search(queries, k)
            ids.append(i)
            dists.append(d)
            offs.append(s.offset)
            seg_io = int(io.sum())
            total_io += seg_io
            bs = tgt.batch_stats(s)
            if bs:
                total_t0 += int(np.asarray(bs["tier0_hits"]).sum())
                total_saved += int(np.asarray(bs["dedup_saved"]).sum())
                total_cross += int(np.asarray(bs["dedup_cross"]).sum())
                total_spec_h += int(np.asarray(bs["spec_hits"]).sum())
                total_spec_w += int(np.asarray(bs["spec_wasted"]).sum())
                total_hot += int(np.asarray(bs["hot_tier_hits"]).sum())
            if self.metrics is not None:
                # per-target attribution: which segment the reads hit
                self.metrics.counter("serve.block_reads",
                                     f"seg{s.offset}").inc(seg_io)
        gi, gd = merge_topk(ids, dists, offs, k)
        stats = {"segments_searched": len(targets),
                 "total_block_reads": total_io,
                 "mean_block_reads_per_query":
                     total_io / max(queries.shape[0], 1),
                 # device tier-0: block touches the VMEM hot-tile pack
                 # absorbed (they are not in total_block_reads)
                 "total_tier0_hits": total_t0,
                 # cross-query dedup: cold touches that rode another
                 # query's same-round gather — deduped_block_reads is
                 # what the device actually issued
                 "total_dedup_saved": total_saved,
                 # the cross-tile subset of the joins — what batch-scope
                 # dedup saved beyond the old per-tile kernel's scope
                 "total_dedup_cross": total_cross,
                 # cross-round speculation (DESIGN.md §9): paying
                 # gathers the previous round pre-fetched, and
                 # speculative gathers nothing consumed — zeros
                 # whenever no target speculates
                 "total_spec_hits": total_spec_h,
                 "total_spec_wasted": total_spec_w,
                 # hybrid hot tier (DESIGN.md §10): vertex visits the
                 # in-memory answering graph absorbed before the block
                 # search even started — memory-priced, never I/O
                 "total_hot_tier_hits": total_hot,
                 "deduped_block_reads": total_io - total_saved}
        # repro.io: aggregate shared-cache counters from servers that
        # expose them, as deltas so every key in the dict is per-call
        # (the cache itself stays warm across calls — only the
        # reporting is scoped to this batch)
        hits = misses = 0
        for si in targets:
            cs = tgt.lifetime_stats(self.servers[si])
            before = self._cache_seen.get(si, (0, 0))
            # tier-2 summary hits count as hits: they avoid the disk trip
            now = (cs.get("cache_hits", 0) + cs.get("tier2_hits", 0),
                   cs.get("cache_misses", 0))
            self._cache_seen[si] = now
            hits += now[0] - before[0]
            misses += now[1] - before[1]
        stats["cache_hits"] = hits
        stats["cache_misses"] = misses
        stats["cache_hit_rate"] = (hits / (hits + misses)
                                   if hits or misses else 0.0)
        if self.metrics is not None:
            self._publish_metrics(queries.shape[0], stats)
        # adaptive serving plane: fold this batch's device columns into
        # the scheduler window and let it evaluate on its own cadence.
        # The repack (if any) lands AFTER this batch returned, so a
        # request never observes a pack swap mid-flight.
        if self.scheduler is not None:
            self.scheduler.note_batch([self.servers[si] for si in targets])
            decision = self.scheduler.maybe_repack()
            if decision is not None:
                stats["repack"] = {
                    "repacked": decision.repacked,
                    "changed_slots": decision.changed_slots,
                    "max_drift": decision.max_drift,
                    "tier0_hit_rate": decision.tier0_hit_rate,
                    "modeled_step_us": decision.modeled_step_us}
                if self.metrics is not None:
                    self.metrics.counter("sched.evals").inc()
                    self.metrics.counter("sched.repacks").inc(
                        decision.repacked)
        return gi, gd, stats

    def _publish_metrics(self, n_queries: int, stats: Dict) -> None:
        """Re-express the batch stats through the metrics registry —
        the same numbers the stats dict returns, under ``serve.*``
        names, so a dashboard scraping ``metrics.snapshot()`` and a
        caller reading the dict can never disagree."""
        m = self.metrics
        m.counter("serve.batches").inc()
        m.counter("serve.queries").inc(n_queries)
        m.counter("serve.total_block_reads").inc(
            stats["total_block_reads"])
        m.counter("serve.total_tier0_hits").inc(
            stats["total_tier0_hits"])
        m.counter("serve.total_dedup_saved").inc(
            stats["total_dedup_saved"])
        m.counter("serve.total_dedup_cross").inc(
            stats["total_dedup_cross"])
        m.counter("serve.total_spec_hits").inc(
            stats["total_spec_hits"])
        m.counter("serve.total_spec_wasted").inc(
            stats["total_spec_wasted"])
        m.counter("serve.total_hot_tier_hits").inc(
            stats["total_hot_tier_hits"])
        m.counter("serve.cache_hits").inc(stats["cache_hits"])
        m.counter("serve.cache_misses").inc(stats["cache_misses"])
        m.gauge("serve.cache_hit_rate").set(stats["cache_hit_rate"])
        m.histogram("serve.batch_block_reads").observe(
            stats["total_block_reads"])
        m.histogram("serve.batch_mean_reads_per_query").observe(
            stats["mean_block_reads_per_query"])
