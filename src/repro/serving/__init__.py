from repro.serving.coordinator import (HostSegmentServer, QueryCoordinator,
                                       SegmentServer, merge_topk)
from repro.serving.batcher import RequestBatcher
