from repro.serving.coordinator import (QueryCoordinator, SegmentServer,
                                       merge_topk)
from repro.serving.batcher import RequestBatcher
