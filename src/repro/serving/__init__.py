from repro.serving.coordinator import (HostSegmentServer, QueryCoordinator,
                                       SegmentServer,
                                       attach_shared_fetch_queue,
                                       merge_topk)
from repro.serving.batcher import RequestBatcher
from repro.serving.router import MeshQueryRouter
from repro.serving.scheduler import RepackDecision, RepackScheduler
from repro.serving.target import SegmentTarget, is_target
