"""The ``SegmentTarget`` protocol: ONE abstraction for everything the
serving plane can point a query batch at (DESIGN.md §7).

Host segments (``HostSegmentServer``), device segments
(``SegmentServer``) and mesh-sharded segment groups
(``router.MeshQueryRouter``) are interchangeable behind this surface:
the ``QueryCoordinator`` scatters/merges over it, the
``RepackScheduler`` registers feeds/targets through it, and
``attach_shared_fetch_queue`` discovers cache-fronted stores with it —
none of them reach into concrete server attributes anymore.

The protocol has a small REQUIRED core and optional capability hooks:

  required   ``offset``, ``num_vectors``, ``search(queries, k)``
  stats      ``batch_stats()`` — the last served batch's device
             columns (``io``/``tier0_hits``/``hops``/``dedup_saved``/
             ``dedup_cross``/``spec_hits``/``spec_wasted`` arrays +
             scalar ``rounds``; the speculation columns are
             zero-filled by the adapter for targets that do not emit
             them), empty for targets without
             device telemetry; ``lifetime_stats()`` — lifetime
             counters (cache tiers, router ranks)
  range      ``range_search(queries, radius, k_cap)``
  repack     ``repack(observed, plan=None)`` + ``repack_source()``
             (the host ``Segment`` a tier-0 repack selects from; None
             means the target cannot be a repack target)
  io plane   ``demand_feed()`` — the ``CachedBlockStore`` whose
             ``block_freq`` feeds the repack scheduler (None if
             uncached/deviceless)
  obs        ``attach_obs(tracer, metrics)`` — wire the target (and
             whatever it owns) into the observability plane

Consumers MUST go through the module-level adapter functions
(``batch_stats(t)``, ``demand_feed(t)``, ...) rather than calling the
hooks directly: the adapters supply the documented default for targets
that implement only the required core (a duck-typed test fake, a
minimal remote proxy), so every optional capability degrades to "not
present" instead of ``AttributeError``.
"""
from __future__ import annotations

from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

# the batch_stats() keys a device-telemetry-bearing target must emit
# together — the exact columns ``IOStats.from_device_batch`` folds
# (``dedup_cross`` is the cross-tile subset of ``dedup_saved``;
# ``spec_hits``/``spec_wasted`` are the speculation outcome columns,
# zero whenever the target does not speculate; ``hot_tier_hits`` is the
# in-memory hot tier's per-query visit column, zero for targets with no
# hot tier attached)
BATCH_STAT_KEYS = ("io", "tier0_hits", "hops", "dedup_saved",
                   "dedup_cross", "rounds", "spec_hits", "spec_wasted",
                   "hot_tier_hits")

# keys the adapter zero-fills for a target that predates (or opts out
# of) speculation / hybrid hot-tier routing — a legacy 6-key emitter
# keeps working; the schema a CONSUMER sees is always the full
# BATCH_STAT_KEYS
_ZERO_DEFAULT_KEYS = ("spec_hits", "spec_wasted", "hot_tier_hits")


@runtime_checkable
class SegmentTarget(Protocol):
    """Structural type of a serving target (see module docstring)."""

    offset: int                   # base of the target's global id space
    num_vectors: int

    def search(self, queries: np.ndarray, k: Optional[int] = None
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Serve a batch: ``(ids [Q, k], dists [Q, k], io [Q])`` with
        ids already in the target's global id space minus ``offset``
        (the coordinator adds ``offset`` when merging)."""
        ...

    # ---- optional capability hooks (use the module adapters) --------
    def batch_stats(self) -> Dict[str, object]: ...
    def lifetime_stats(self) -> Dict[str, float]: ...
    def repack_source(self): ...
    def repack(self, observed, plan=None) -> int: ...
    def demand_feed(self): ...
    def attach_obs(self, tracer, metrics) -> None: ...


def is_target(obj) -> bool:
    """Required-core check: anything searchable with an id-space
    offset serves as a ``SegmentTarget``."""
    return (hasattr(obj, "search") and hasattr(obj, "offset")
            and hasattr(obj, "num_vectors"))


# --------------------------------------------------- protocol adapters

def batch_stats(target) -> Dict[str, object]:
    """Device columns of the target's last served batch, or ``{}`` for
    targets without device telemetry. A non-empty dict carries every
    ``BATCH_STAT_KEYS`` entry (validated here so a half-implemented
    target fails loudly at the seam, not deep in a fold)."""
    fn = getattr(target, "batch_stats", None)
    stats = fn() if callable(fn) else {}
    if stats and any(k not in stats for k in _ZERO_DEFAULT_KEYS):
        # speculation columns default to zero arrays shaped like the
        # batch's io column: every consumer fold then sees the full
        # schema without caring whether the target speculates
        io = np.asarray(stats["io"]) if "io" in stats else np.zeros(0)
        stats = dict(stats)
        for k in _ZERO_DEFAULT_KEYS:
            stats.setdefault(k, np.zeros_like(io))
    if stats and any(k not in stats for k in BATCH_STAT_KEYS):
        missing = [k for k in BATCH_STAT_KEYS if k not in stats]
        raise ValueError(
            f"batch_stats() of {type(target).__name__} is missing "
            f"{missing} — device columns travel together")
    return stats


def lifetime_stats(target) -> Dict[str, float]:
    """Lifetime counters (cache tiers, rank loads); ``{}`` default."""
    fn = getattr(target, "lifetime_stats", None)
    return fn() if callable(fn) else {}


def repack_source(target):
    """The host ``Segment`` a tier-0 repack rebuilds from, or None —
    the scheduler's can-this-be-a-repack-target test."""
    fn = getattr(target, "repack_source", None)
    return fn() if callable(fn) else None


def demand_feed(target):
    """The target's cache-fronted ``CachedBlockStore`` (the repack
    scheduler's demand signal and the shared-queue attach point), or
    None for device-only / uncached targets."""
    fn = getattr(target, "demand_feed", None)
    return fn() if callable(fn) else None


def attach_obs(target, tracer, metrics) -> None:
    """Wire the target into the observability plane (no-op default)."""
    fn = getattr(target, "attach_obs", None)
    if callable(fn):
        fn(tracer, metrics)
