"""Request batcher: collect single-query requests into device batches.

TPU search is a batched beam (DESIGN.md §2.2); the batcher pads the
pending queue to the nearest compiled batch-size bucket so jit caches a
handful of shapes instead of one per request count. Buckets are coerced
to multiples of the fused round kernel's query-tile granularity
(``tile``, default the kernel's 8-row minimum) so a padded batch fills
whole kernel tiles: pad rows converge immediately (their candidate set
is drained in the first rounds) and — under active-query compaction —
cluster into all-idle tiles the kernel skips. Padding never changes
results: per-query state is row-independent (the ragged-batch
regression test asserts bit-identity against singleton searches).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class PendingRequest:
    request_id: int
    query: np.ndarray


class RequestBatcher:
    """``max_wait`` is a deadline in scheduler ticks: each ``ready()``
    poll with a non-empty queue counts one tick, so a partial batch is
    flushed after at most ``max_wait`` polls instead of waiting forever
    for the largest bucket to fill."""

    def __init__(self, dim: int, buckets: Sequence[int] = (8, 32, 128),
                 max_wait: int = 64, tile: int = 8):
        if tile < 1:
            raise ValueError("tile must be >= 1")
        self.dim = dim
        self.tile = tile
        # round every bucket up to the kernel tile multiple (dedup sets
        # coincide with kernel invocations only on whole tiles)
        self.buckets = tuple(sorted({-(-int(b) // tile) * tile
                                     for b in buckets}))
        self.max_wait = max_wait
        self.queue: List[PendingRequest] = []
        self._next_id = 0
        self._waited = 0
        self.batches_emitted = 0   # lifetime batches handed out —
        #                            serving-loop telemetry (note: the
        #                            RepackScheduler keeps its own count
        #                            of batches it was actually shown)

    def submit(self, query: np.ndarray) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append(PendingRequest(rid, np.asarray(
            query, np.float32)))
        return rid

    def ready(self) -> bool:
        """True when the largest bucket can be filled, or when pending
        requests have waited ``max_wait`` polls (deadline flush)."""
        if not self.queue:
            self._waited = 0
            return False
        if len(self.queue) >= self.buckets[-1]:
            return True
        self._waited += 1
        return self._waited >= self.max_wait

    def next_batch(self) -> Tuple[np.ndarray, List[int], int]:
        """Returns (padded queries [B, D], request ids, valid count)."""
        n = min(len(self.queue), self.buckets[-1])
        bucket = next(b for b in self.buckets if b >= n)
        take, self.queue = self.queue[:n], self.queue[n:]
        self._waited = 0
        self.batches_emitted += 1
        q = np.zeros((bucket, self.dim), np.float32)
        ids = []
        for i, r in enumerate(take):
            q[i] = r.query
            ids.append(r.request_id)
        return q, ids, n
