"""Mesh-scale sharded serving: the shard_map fan-out router
(DESIGN.md §7).

``MeshQueryRouter`` turns a set of single-segment device servers into
ONE ``SegmentTarget``: a query batch fans out over mesh ranks (one
``DeviceSegment`` shard per rank on the ``model`` axis, the Fig. 1(b)
segments <-> ranks layout of ``core.device_search.make_search_step``),
each rank runs the batched block search on its shard, and per-shard
top-k merges **on device** via ``merge_shard_topk`` — the same
(dist, global id) total order the host ``merge_topk`` sorts by, so a
routed+merged batch is bit-identical to the concatenated single-target
path over the same segments.

Replica groups: with more ranks than segments, hot segments get extra
replicas (``distributed.elastic.plan_placement`` — load-proportional,
largest remainder, every segment >= 1 rank). Each replica group
partitions the query batch into contiguous slices sized inversely to
the windowed per-rank load (``rounds_active_weight`` occupancy fold),
so a lagging replica is handed fewer rows next batch. Every (query,
segment) pair is owned by exactly ONE rank — non-owned rows mask to
the -1/inf sentinels before the all-gather — which keeps accounting
exact and the merge bit-identical: a replica runs the identical
batched search its siblings run, so its owned rows equal the
single-target rows no matter how the slices are drawn.

Elastic rebalance: the router keeps a sliding window of per-rank
``IOStats`` folds (``IOStats.fold_rank_batches`` — THE shared fold
``mesh_qps_estimate`` and the ``RepackScheduler`` price). When the
windowed rank-load skew sustains past ``RouterParams.skew_threshold``,
``elastic.plan_rebalance`` re-plans placement and the router restacks
the shard tree — same shapes, so the step reuses the same compiled
executable (the mesh analogue of ``repack_tier0``'s in-place pack
swap). A settled or balanced stream plans zero moves (idempotence).

Observability: ``router.route`` spans per batch, ``coord.shard`` spans
per rank (per-rank timeline in the Perfetto export),
``router.rebalance`` spans on firing evaluations, and ``(name,
target="rank<r>")`` metrics through ``repro.obs``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.iostats import IOStats, TPU_HBM_SEGMENT, CostModel
from repro.core.params import DeviceSearchParams, RouterParams
from repro.distributed import elastic
from repro.distributed.sharding import SEGMENT_SERVE_RULES, logical_spec


def _make_mesh(model: int):
    import jax
    return jax.make_mesh((1, model), ("data", "model"))


class MeshQueryRouter:
    """Fan a query batch over sharded ``DeviceSegment``s; one
    ``SegmentTarget`` whose id space is the union of its members'.

    ``servers``: single-segment device targets (``SegmentServer``-like:
    ``segment``/``offset``/``num_vectors``; ``host`` optional, needed
    only to repack). All member segments must be shape-identical
    (``stack_segments`` enforces it) and share search params + metric —
    one compiled step serves every placement. Member ``offset``s are
    GLOBAL bases; the router's own ``offset`` is 0 because its results
    already carry global ids (the coordinator's merge adds ``offset``,
    which must be a no-op here)."""

    def __init__(self, servers: Sequence, *, mesh=None,
                 params: RouterParams = RouterParams(),
                 cost_model: Optional[CostModel] = None,
                 tracer=None, metrics=None):
        import jax
        if not servers:
            raise ValueError("MeshQueryRouter needs at least one "
                             "segment server")
        self.servers = list(servers)
        p0 = self.servers[0].params
        m0 = getattr(self.servers[0], "metric", "l2")
        for s in self.servers[1:]:
            if s.params != p0 or getattr(s, "metric", "l2") != m0:
                raise ValueError(
                    "mesh members must share DeviceSearchParams and "
                    "metric — one compiled step serves every rank")
        self.params = params
        self.search_params: DeviceSearchParams = p0
        self.metric = m0
        self.k_default = getattr(self.servers[0], "k_default", 10)
        self.offset = 0
        self.num_vectors = sum(s.num_vectors for s in self.servers)
        if cost_model is None:
            from repro.obs.calibrate import load_calibrated
            cost_model = load_calibrated(TPU_HBM_SEGMENT)
        self.cost_model = cost_model
        self.tracer = tracer
        self.metrics = metrics

        self.mesh = mesh if mesh is not None else _make_mesh(
            jax.device_count())
        self.world = int(self.mesh.shape["model"])
        for ax, n in self.mesh.shape.items():
            if ax != "model" and n != 1:
                raise ValueError(
                    f"router meshes shard segments over 'model' only; "
                    f"axis {ax!r} has size {n} (want 1)")
        if self.world < len(self.servers):
            raise ValueError(
                f"{self.world} mesh ranks cannot hold "
                f"{len(self.servers)} segments at >= 1 replica each")

        # initial placement: uniform loads -> round-robin-ish replicas
        self._placement: List[int] = elastic.plan_placement(
            [1.0] * len(self.servers), self.world)
        self._restack()
        self._steps: Dict[int, object] = {}     # k -> compiled step
        # sliding window of (rank_loads [W], seg_loads [S], rank_queries
        # [W]) — the rebalance evidence and the replica-slice weights
        self._window = deque(maxlen=params.window_batches)
        self._since_eval = 0
        self.batches = 0
        self.rebalances = 0
        self.last_per_rank: Dict[int, IOStats] = {}
        self.last_stats: Optional[IOStats] = None
        self.last_plan: Optional[elastic.PlacementPlan] = None

    # ------------------------------------------------------------ stacking
    def _restack(self) -> None:
        """(Re)build the [W, ...] shard tree + per-rank offsets from the
        current placement. Shapes never change across restacks, so the
        compiled step executable is reused."""
        from repro.core.device_search import stack_segments
        self._seg_stack = stack_segments(
            [self.servers[si].segment for si in self._placement])
        self._offsets = np.asarray(
            [self.servers[si].offset for si in self._placement],
            np.int32)

    @property
    def placement(self) -> Tuple[int, ...]:
        return tuple(self._placement)

    def _seg_ranks(self) -> Dict[int, List[int]]:
        """segment index -> its replica ranks (ascending)."""
        out: Dict[int, List[int]] = {}
        for r, si in enumerate(self._placement):
            out.setdefault(si, []).append(r)
        return out

    # ------------------------------------------------------------- the step
    def _build_step(self, k: int):
        import inspect

        import jax
        import jax.numpy as jnp
        try:
            from jax import shard_map
        except ImportError:                    # older jax releases
            from jax.experimental.shard_map import shard_map

        from repro.core.device_search import (device_anns,
                                              merge_shard_topk)

        mesh = self.mesh
        p = dataclasses.replace(
            self.search_params, k=k,
            candidates=max(self.search_params.candidates, k))
        metric = self.metric

        def local(seg, queries, meta):
            seg = jax.tree.map(lambda a: a[0], seg)  # strip shard dim
            meta = meta[0]                           # [3] this rank's
            #                                          (offset, lo, hi)
            r = device_anns(seg, queries, p, metric=metric)
            q = queries.shape[0]
            qidx = jnp.arange(q, dtype=jnp.int32)
            own = (qidx >= meta[1]) & (qidx < meta[2])   # [Q]
            # non-owned rows mask to the invalid sentinels BEFORE the
            # gather: every (query, segment) pair then reaches the
            # merge from exactly one rank — replica slices never
            # double-count and never change the merged result (each
            # replica ran the identical batch, so owned rows equal the
            # single-target rows)
            gid = jnp.where((r.ids >= 0) & own[:, None],
                            r.ids + meta[0], -1)
            gd = jnp.where(gid >= 0, r.dists, jnp.inf)
            gids = jax.lax.all_gather(gid, "model")      # [W, Q, k]
            gds = jax.lax.all_gather(gd, "model")
            mi, md = merge_shard_topk(gids, gds, k)
            owni = own.astype(jnp.int32)
            col = jnp.ones((1, 1), jnp.int32)
            # per-rank device columns, masked to owned rows — the
            # fold_rank_batches inputs (rounds stays whole-batch: the
            # rank's loop really ran that many rounds)
            return (mi, md,
                    (r.io * owni)[:, None] * col,
                    (r.hops * owni)[:, None] * col,
                    (r.tier0_hits * owni)[:, None] * col,
                    (r.dedup_saved * owni)[:, None] * col,
                    (r.dedup_cross * owni)[:, None] * col,
                    (r.spec_hits * owni)[:, None] * col,
                    (r.spec_wasted * owni)[:, None] * col,
                    r.rounds[None])

        def leaf_spec(a):
            axes = ("segment",) + (None,) * (a.ndim - 1)
            return logical_spec((self.world,) + a.shape[1:], axes,
                                SEGMENT_SERVE_RULES, mesh)

        seg_specs = jax.tree.map(leaf_spec, self._seg_stack)
        from jax.sharding import PartitionSpec as P
        in_specs = (seg_specs, P(), P("model"))
        out_specs = (P(), P(), P(None, "model"), P(None, "model"),
                     P(None, "model"), P(None, "model"),
                     P(None, "model"), P(None, "model"),
                     P(None, "model"), P("model"))
        flag = ("check_vma" if "check_vma"
                in inspect.signature(shard_map).parameters
                else "check_rep")
        fn = shard_map(local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, **{flag: False})
        return jax.jit(fn)

    def _get_step(self, k: int):
        if k not in self._steps:
            self._steps[k] = self._build_step(k)
        return self._steps[k]

    # ------------------------------------------------------------- routing
    def _rank_weights(self) -> np.ndarray:
        """Inverse windowed per-rank load — the slice weights. Uniform
        until the window has data."""
        w = np.ones(self.world)
        if self._window:
            load = np.zeros(self.world)
            for rank_loads, _, _ in self._window:
                load += rank_loads
            w = 1.0 / (1.0 + load)
        return w

    def _rank_meta(self, q: int) -> np.ndarray:
        """[W, 3] int32 (offset, q_lo, q_hi) per rank: each segment's
        replica group partitions [0, q) into contiguous slices sized by
        the inverse-load weights (largest remainder, rank order)."""
        meta = np.zeros((self.world, 3), np.int32)
        meta[:, 0] = self._offsets
        weights = self._rank_weights()
        for si, ranks in self._seg_ranks().items():
            w = weights[ranks]
            quota = w / w.sum() * q
            sizes = np.floor(quota).astype(np.int64)
            short = q - int(sizes.sum())
            order = sorted(range(len(ranks)),
                           key=lambda i: (-(quota[i] - sizes[i]), i))
            for i in order[:short]:
                sizes[i] += 1
            lo = 0
            for r, size in zip(ranks, sizes):
                meta[r, 1], meta[r, 2] = lo, lo + size
                lo += int(size)
            assert lo == q, (lo, q)
        return meta

    def route(self, queries: np.ndarray, k: Optional[int] = None
              ) -> Tuple[np.ndarray, np.ndarray, Dict]:
        """Serve one batch across the mesh. Returns global ``(ids
        [Q, k], dists [Q, k], stats)`` — stats carries the rank-keyed
        ``IOStats`` fold, their ``merge_ranks`` total, and (when due)
        the rebalance plan."""
        import jax.numpy as jnp
        k = k or self.k_default
        q = np.asarray(queries, np.float32)
        meta = self._rank_meta(q.shape[0])
        step = self._get_step(k)
        if self.tracer is not None:
            with self.tracer.span("router.route", cat="serve",
                                  track="router",
                                  n_queries=int(q.shape[0]), k=int(k),
                                  ranks=self.world) as sp:
                out = step(self._seg_stack, jnp.asarray(q),
                           jnp.asarray(meta))
                ids, dists, stats = self._account(out, meta)
                sp["block_reads"] = stats["total_block_reads"]
                sp["rounds_max"] = stats["rounds_max"]
        else:
            out = step(self._seg_stack, jnp.asarray(q),
                       jnp.asarray(meta))
            ids, dists, stats = self._account(out, meta)
        plan = self.maybe_rebalance()
        if plan is not None:
            stats["rebalance"] = {
                "fired": plan.fired, "moves": len(plan.moves),
                "skew": plan.skew,
                "placement": list(plan.placement)}
        return ids, dists, stats

    def _account(self, out, meta) -> Tuple[np.ndarray, np.ndarray, Dict]:
        (ids, dists, io_c, hops_c, t0_c, sv_c, cx_c, sh_c, sw_c,
         rounds) = [np.asarray(x) for x in out]
        w = self.world
        # THE shared mesh fold (DESIGN.md §7): per-rank IOStats from
        # the masked device columns; totals are defined ONLY as the
        # merge of the per-rank folds (rounds_active_weight is not
        # additive across ranks with different round counts)
        pipelined = (self.search_params.pipeline_dma
                     and self.search_params.fetch_impl == "fused")
        speculative = self.search_params.speculate
        per_rank = IOStats.fold_rank_batches(
            {r: (io_c[:, r], t0_c[:, r], hops_c[:, r], sv_c[:, r],
                 int(rounds[r]), cx_c[:, r], pipelined,
                 sh_c[:, r], sw_c[:, r], speculative)
             for r in range(w)})
        total = IOStats.merge_ranks(per_rank)
        self.last_per_rank = per_rank
        self.last_stats = total
        self._last_cols = (io_c, t0_c, hops_c, sv_c, cx_c, sh_c, sw_c,
                           rounds)
        self.batches += 1
        self._since_eval += 1

        rank_loads = np.asarray(
            [per_rank[r].rounds_active_weight for r in range(w)])
        rank_queries = np.asarray(
            [int(meta[r, 2] - meta[r, 1]) for r in range(w)], float)
        seg_loads = np.zeros(len(self.servers))
        for r, si in enumerate(self._placement):
            seg_loads[si] += rank_loads[r]
        self._window.append((rank_loads, seg_loads, rank_queries))

        per_rank_us = {r: self.cost_model.latency_us(per_rank[r])
                       for r in range(w)}
        if self.tracer is not None or self.metrics is not None:
            for r in range(w):
                s = per_rank[r]
                if self.tracer is not None:
                    with self.tracer.span(
                            "coord.shard", cat="serve", track="router",
                            target=f"rank{r}",
                            segment=int(self._placement[r])) as sp:
                        sp["block_reads"] = s.block_reads
                        sp["rounds"] = s.batch_rounds
                        sp["occupancy"] = s.rounds_active_weight
                        sp["modeled_step_us"] = per_rank_us[r]
                if self.metrics is not None:
                    m = self.metrics
                    m.counter("router.block_reads", f"rank{r}").inc(
                        s.block_reads)
                    m.counter("router.tier0_hits", f"rank{r}").inc(
                        s.tier0_hits)
                    m.gauge("router.occupancy", f"rank{r}").set(
                        s.rounds_active_weight)
                    m.gauge("router.modeled_step_us", f"rank{r}").set(
                        per_rank_us[r])
            if self.metrics is not None:
                self.metrics.counter("router.batches").inc()

        stats = {
            "ranks": w,
            "segments": len(self.servers),
            "placement": list(self._placement),
            "per_rank": per_rank,
            "total": total,
            "total_block_reads": total.block_reads,
            "total_tier0_hits": total.tier0_hits,
            "total_dedup_saved": total.dedup_saved_fetches,
            "total_dedup_cross": total.dedup_cross_tile,
            "total_spec_hits": total.spec_hits,
            "total_spec_wasted": total.spec_wasted,
            "rounds_max": total.batch_rounds,
            "per_rank_modeled_us": per_rank_us,
            # the mesh step is gated by its slowest rank — exactly the
            # figure mesh_qps_estimate models from the same fold
            "modeled_step_us": max(per_rank_us.values()),
        }
        return ids, dists, stats

    # ----------------------------------------------------------- rebalance
    def window_rank_loads(self) -> np.ndarray:
        load = np.zeros(self.world)
        for rank_loads, _, _ in self._window:
            load += rank_loads
        return load

    def window_seg_loads(self) -> np.ndarray:
        load = np.zeros(len(self.servers))
        for _, seg_loads, _ in self._window:
            load += seg_loads
        return load

    def maybe_rebalance(self, force: bool = False
                        ) -> Optional[elastic.PlacementPlan]:
        """Evaluate placement once per ``rebalance_interval`` routed
        batches (or on ``force``), with at least ``min_window`` steps
        of evidence. Returns the plan (fired or not), or None when not
        yet due. A firing plan restacks the shard tree in place —
        same shapes, same compiled executable."""
        p = self.params
        if not force and (self._since_eval < p.rebalance_interval
                          or len(self._window) < p.min_window):
            return None
        self._since_eval = 0
        plan = elastic.plan_rebalance(
            self._placement, self.window_seg_loads().tolist(),
            self.window_rank_loads().tolist(),
            skew_threshold=p.skew_threshold)
        self.last_plan = plan
        if plan.fired:
            if self.tracer is not None:
                with self.tracer.span("router.rebalance", cat="serve",
                                      track="router",
                                      moves=len(plan.moves),
                                      skew=float(plan.skew)) as sp:
                    self._placement = list(plan.placement)
                    self._restack()
                    sp["placement"] = ",".join(
                        str(s) for s in plan.placement)
            else:
                self._placement = list(plan.placement)
                self._restack()
            self.rebalances += 1
            # moved segments invalidate the window's rank attribution
            self._window.clear()
            if self.metrics is not None:
                self.metrics.counter("router.rebalances").inc()
        return plan

    # ------------------------------------- SegmentTarget capability hooks
    def search(self, queries: np.ndarray, k: Optional[int] = None
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``SegmentTarget`` surface: global ids (offset 0), merged
        dists, per-query cold block touches summed across ranks."""
        ids, dists, _ = self.route(queries, k)
        # per-query cold touches: the owned-row columns sum across
        # ranks to exactly one contribution per (query, segment)
        io = self._last_cols[0].sum(axis=1).astype(np.int64)
        return ids, dists, io

    def batch_stats(self) -> Dict[str, object]:
        """The last routed step's device columns summed across ranks,
        with the slowest rank's round count — the slowest-rank-gated
        view a mesh step presents to per-batch pricing consumers
        (``RepackScheduler.note_batch``). Exact per-rank folds live in
        ``last_per_rank``; totals in ``last_stats`` (their
        ``merge_ranks``)."""
        if self._last_cols is None:
            return {}
        (io_c, t0_c, hops_c, sv_c, cx_c, sh_c, sw_c,
         rounds) = self._last_cols
        return {"io": io_c.sum(axis=1), "tier0_hits": t0_c.sum(axis=1),
                "hops": hops_c.sum(axis=1),
                "dedup_saved": sv_c.sum(axis=1),
                "dedup_cross": cx_c.sum(axis=1),
                "spec_hits": sh_c.sum(axis=1),
                "spec_wasted": sw_c.sum(axis=1),
                "rounds": int(rounds.max()),
                "dma_pipelined": (self.search_params.pipeline_dma
                                  and self.search_params.fetch_impl
                                  == "fused"),
                "dma_speculative": self.search_params.speculate}

    _last_cols = None

    def lifetime_stats(self) -> Dict[str, float]:
        out: Dict[str, float] = {"batches": float(self.batches),
                                 "rebalances": float(self.rebalances)}
        for r, load in enumerate(self.window_rank_loads()):
            out[f"rank{r}_window_load"] = float(load)
        return out

    def repack_source(self):
        return None          # member packs are repacked via repack()

    def repack(self, observed, plan=None) -> int:
        """Repack every member's tier-0 pack from ``observed`` demand
        and restack the shard tree (same shapes, same executable).
        Members without a host ``Segment`` are skipped."""
        changed = 0
        for s in self.servers:
            if getattr(s, "host", None) is not None:
                changed += s.repack(observed, plan=plan)
        self._restack()
        return changed

    def demand_feed(self):
        return None

    def attach_obs(self, tracer, metrics) -> None:
        if tracer is not None and self.tracer is None:
            self.tracer = tracer
        if metrics is not None and self.metrics is None:
            self.metrics = metrics
