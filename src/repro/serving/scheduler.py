"""Adaptive serving plane: the feedback-driven tier-0 repack scheduler
(DESIGN.md §5).

PR 4 left two read-only telemetry paths open: host stores count
per-block demand (``CachedBlockStore.block_freq``) and the device
search reports per-query tier-0/dedup/occupancy columns — but nothing
*acted* on either. ``RepackScheduler`` closes the loop:

  * **demand feeds** — every cache-fronted ``HostSegmentServer`` view
    (and any other ``CachedBlockStore``) registers as a feed; the
    scheduler folds the *union* of their windowed ``freq_delta``
    counters, so a shared-queue deployment (``attach_shared_fetch_queue
    (..., scheduler=...)``) repacks from what the whole serving plane
    observed, not one store's slice;
  * **device telemetry** — after each served batch the coordinator
    notes the tier-0/io/dedup/hops columns of its device servers; the
    scheduler prices them through the round-granular cost model
    (``IOStats.from_device_batch`` + ``CostModel.latency_us`` — the
    SAME fold ``paper_tables.mesh_qps_estimate`` reports, so the
    control loop optimizes exactly the modeled QPS the benchmarks
    measure) and derives the observed tier-0 hit rate;
  * **decision** — every ``interval_batches`` batches, plan the pack
    each target WOULD select under the union demand
    (``hotset.plan_tier0``) and compare it to the live pack
    (``hotset.pack_drift``). A repack fires only when the drift
    reaches ``hysteresis`` AND the observed hit rate sits below
    ``hit_rate_ceiling`` — so a no-op repack is free (nothing is
    rebuilt, nothing re-jitted), the loop cannot oscillate between
    near-equal packs, and a pack that already absorbs the stream is
    left alone;
  * **repack** — ``device_search.repack_tier0`` swaps H block tiles in
    place (same budget, same shapes, same compiled executable). The
    pack holds exact copies, so a repack NEVER changes ``(ids,
    dists)`` — only the io/tier0_hits split moves (the invariant the
    conformance and property suites pin down).

The hysteresis invariant: for any observed-frequency window whose
planned pack differs from the live pack in fewer than ``hysteresis x
H`` slots, ``maybe_repack`` performs zero repacks and zero array
builds. Idempotence follows: planning is deterministic, so the window
that just fired plans the live pack next time (drift 0) until traffic
moves again.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.device_search import hot_pack_blocks
from repro.core.iostats import IOStats, TPU_HBM_SEGMENT, CostModel
from repro.core.params import RepackParams
from repro.io import hotset
from repro.io.cached_store import CachedBlockStore
from repro.serving import target as tgt


@dataclasses.dataclass
class RepackDecision:
    """One scheduler evaluation (returned by ``maybe_repack``)."""
    evaluated: int                # targets whose drift was priced
    repacked: int                 # targets actually repacked
    changed_slots: int            # pack slots moved across all repacks
    max_drift: float              # largest planned drift seen
    tier0_hit_rate: float         # observed device hit rate this window
    modeled_step_us: float        # round-granular modeled step time of
    #                               the window's device traffic (the
    #                               objective; 0 with no device batches)
    observed_blocks: int          # distinct blocks in the union window


class RepackScheduler:
    """Periodic, hysteresis-gated tier-0 repack from observed demand.

    Wire-up (the ``QueryCoordinator`` does all three per batch when
    constructed with ``scheduler=``):

        sched = RepackScheduler(RepackParams())
        sched.attach_feed(host_server.view.store)   # demand signal
        sched.attach_target(device_server)          # pack to steer
        ...
        sched.note_batch([device_server, ...])      # device columns
        decision = sched.maybe_repack()             # every interval
    """

    def __init__(self, params: RepackParams = RepackParams(),
                 cost_model: Optional[CostModel] = None,
                 tracer=None):
        self.params = params
        if cost_model is None:
            # default pricing: the TPU-HBM preset with any calibrated
            # constants from results/CALIB_*.json applied on top
            # (backend mismatch / missing file -> the hardcoded preset)
            from repro.obs.calibrate import load_calibrated
            cost_model = load_calibrated(TPU_HBM_SEGMENT)
        self.cost_model = cost_model
        self.tracer = tracer            # repro.obs: sched.eval /
        #                                 sched.repack events, None-guarded
        self._feeds: List[CachedBlockStore] = []
        self._marks: List[Counter] = []     # per-feed freq watermarks
        self._targets: List = []            # SegmentServers with .host
        self._rankings: List[List[int]] = []  # build-time ranking/target
        self._window: Counter = Counter()   # union demand since the
        #                                     last full repack (or start)
        self._server_stats: Dict[int, IOStats] = {}  # id(server) ->
        #                                     device columns this window
        self._step_us_sum = 0.0             # Σ per-batch modeled step
        self._step_batches = 0              #   times (priced at note
        #                                     time, so the mean stays a
        #                                     per-batch figure)
        self.batches = 0                    # batches noted since last eval
        self.evals = 0
        self.repacks = 0                    # repacks fired (lifetime)
        self.skipped = 0                    # hysteresis/ceiling no-ops
        self.last_decision: Optional[RepackDecision] = None

    # ------------------------------------------------------------ wiring
    def attach_feed(self, store: CachedBlockStore) -> None:
        """Register a host store's ``block_freq`` as a demand feed."""
        if not isinstance(store, CachedBlockStore):
            raise TypeError("demand feeds must be CachedBlockStores "
                            f"(got {type(store).__name__})")
        if any(s is store for s in self._feeds):
            return
        self._feeds.append(store)
        self._marks.append(Counter(store.block_freq))

    def attach_target(self, server) -> None:
        """Register a serving target whose tier-0 pack this scheduler
        steers. The target's ``repack_source()`` must yield the host
        ``Segment`` the device pack is rebuilt from (``SegmentTarget``
        protocol; ``SegmentServer.host`` for the concrete server)."""
        seg = tgt.repack_source(server)
        if seg is None:
            raise ValueError(
                "repack targets need a repack_source() host Segment "
                "(SegmentServer.host for device servers) — the device "
                "pack is rebuilt from host arrays")
        if any(t is server for t in self._targets):
            return
        v = seg.view
        self._targets.append(server)
        self._rankings.append(hotset.hot_block_ranking(
            v.layout.block_of, seg.graph.adj, seg.graph.deg,
            hotset.view_seed_ids(v)))

    def note_layout_swap(self, server) -> None:
        """A compaction swapped a fresh ``Segment`` under ``server``
        (DESIGN.md §10 swap protocol): re-derive the target's
        build-time ranking from the NEW layout and drop demand-window
        entries that index past the new block count — stale demand for
        since-compacted blocks must never reach a pack plan
        (``hotset.fill_to``'s range filter backstops feeds this
        scheduler never hears about). The window otherwise survives:
        still-valid demand keeps accumulating drift."""
        seg = tgt.repack_source(server)
        for i, t in enumerate(self._targets):
            if t is server and seg is not None:
                v = seg.view
                self._rankings[i] = hotset.hot_block_ranking(
                    v.layout.block_of, seg.graph.adj, seg.graph.deg,
                    hotset.view_seed_ids(v))
                break
        if seg is not None:
            total = int(seg.view.store.num_blocks)
            self._window = Counter(
                {b: c for b, c in self._window.items()
                 if 0 <= int(b) < total})
        # the swapped target's telemetry window restarts with its layout
        self._server_stats.pop(id(server), None)
        if self.tracer is not None:
            self.tracer.event(
                "sched.layout_swap", cat="sched", track="sched",
                target=str(getattr(server, "offset", -1)),
                window_blocks=len(self._window))

    # --------------------------------------------------------- telemetry
    def note_batch(self, servers: Sequence = ()) -> None:
        """Fold one served batch's device columns into the window:
        per-server merged counters (so the hit-rate gate judges each
        target on its own traffic) and the batch's modeled step time
        (priced immediately, so the objective stays a per-batch figure
        comparable to ``mesh_qps_estimate``'s per-rank step)."""
        self.batches += 1
        for s in servers:
            bs = tgt.batch_stats(s)
            if not bs:
                continue
            batch = IOStats.from_device_batch(
                np.asarray(bs["io"]), np.asarray(bs["tier0_hits"]),
                np.asarray(bs["hops"]), np.asarray(bs["dedup_saved"]),
                int(bs["rounds"]),
                np.asarray(bs["dedup_cross"]),
                bool(bs.get("dma_pipelined", False)),
                np.asarray(bs["spec_hits"]),
                np.asarray(bs["spec_wasted"]),
                bool(bs.get("dma_speculative", False)),
                np.asarray(bs["hot_tier_hits"]))
            self._server_stats.setdefault(id(s), IOStats()).merge(batch)
            self._step_us_sum += self.cost_model.latency_us(batch)
            self._step_batches += 1

    def demand_union(self) -> Counter:
        """The union windowed demand signal across every feed."""
        u = Counter()
        for store, mark in zip(self._feeds, self._marks):
            u.update(store.freq_delta(mark))
        # window survives across below-threshold evaluations, so drift
        # accumulates until it clears the hysteresis gate
        return self._window + u

    def _advance_marks(self) -> None:
        for i, store in enumerate(self._feeds):
            self._marks[i] = Counter(store.block_freq)

    @staticmethod
    def _hit_rate(s: Optional[IOStats]) -> float:
        """Tier-0 hit rate of one window's counters. 0.0 with no
        traffic: missing telemetry must never *suppress* a repack (the
        ceiling gate exists to protect a pack KNOWN to absorb the
        stream — an unobserved one gets no such pass)."""
        if s is None:
            return 0.0
        touched = s.tier0_hits + s.cache_misses
        if touched == 0:
            return 0.0
        return s.tier0_hits / touched

    @property
    def window_hit_rate(self) -> float:
        """Observed tier-0 hit rate across ALL device traffic this
        window (per-target rates gate the repack decision; this is the
        dashboard aggregate)."""
        agg = IOStats()
        for s in self._server_stats.values():
            agg.merge(s)
        return self._hit_rate(agg if self._server_stats else None)

    def modeled_step_us(self) -> float:
        """Mean modeled step time per served batch this window, priced
        batch-by-batch with the round-granular model — the scheduler's
        objective, comparable 1:1 with ``mesh_qps_estimate``'s
        per-rank step figure (same ``IOStats.from_device_batch`` +
        ``CostModel.latency_us`` fold per batch)."""
        if self._step_batches == 0:
            return 0.0
        return self._step_us_sum / self._step_batches

    # ---------------------------------------------------------- decision
    def due(self) -> bool:
        return self.batches >= self.params.interval_batches

    def maybe_repack(self, force: bool = False
                     ) -> Optional[RepackDecision]:
        """Evaluate once per ``interval_batches`` noted batches (or on
        ``force``); returns the decision, or None when not yet due."""
        if not force and not self.due():
            return None
        p = self.params
        union = self.demand_union()
        self._window = union
        self._advance_marks()
        # one noise-floored view for BOTH the drift plan and the repack
        # itself — they must select identically or hysteresis lies
        obs = Counter({b: c for b, c in union.items()
                       if c >= p.min_observed})
        hit_rate = self.window_hit_rate
        step_us = self.modeled_step_us()
        evaluated = repacked = changed = 0
        max_drift = 0.0
        for i, server in enumerate(self._targets):
            ds = getattr(server, "segment", None)
            if ds is None:
                continue                    # no device pack to steer
            current = hot_pack_blocks(ds)
            if not current:
                continue                    # tier 0 disabled: nothing to steer
            evaluated += 1
            plan = hotset.plan_tier0(
                self._rankings[i], obs, len(current),
                int(ds.hot_slot_of.shape[0]))
            drift = hotset.pack_drift(current, plan)
            max_drift = max(max_drift, drift)
            # each target is judged on ITS OWN observed hit rate — one
            # well-packed target must not shield a drifted sibling
            own_rate = self._hit_rate(self._server_stats.get(id(server)))
            if drift < p.hysteresis or own_rate >= p.hit_rate_ceiling:
                continue                    # no-op repack: free by design
            moved = server.repack(obs, plan=plan)
            changed += moved
            repacked += 1
            if self.tracer is not None:
                self.tracer.event(
                    "sched.repack", cat="sched", track="sched",
                    target=str(getattr(server, "offset", i)),
                    changed_slots=moved, drift=drift,
                    tier0_hit_rate=own_rate)
            # the repacked target's telemetry restarts; siblings keep
            # their window counters
            self._server_stats.pop(id(server), None)
        if repacked:
            self.repacks += repacked
        if repacked == evaluated and repacked > 0:
            # every target moved: a fresh pack starts a fresh window so
            # post-repack traffic alone drives the next decision. With
            # a below-threshold sibling still waiting, the window
            # SURVIVES — its drift must keep accumulating or hysteresis
            # would starve slow drifters (the documented invariant).
            self._window = Counter()
            self._step_us_sum, self._step_batches = 0.0, 0
        if repacked < evaluated:
            self.skipped += evaluated - repacked
        self.evals += 1
        self.batches = 0
        self.last_decision = RepackDecision(
            evaluated=evaluated, repacked=repacked, changed_slots=changed,
            max_drift=max_drift, tier0_hit_rate=hit_rate,
            modeled_step_us=step_us, observed_blocks=len(union))
        if self.tracer is not None:
            self.tracer.event(
                "sched.eval", cat="sched", track="sched",
                evaluated=evaluated, repacked=repacked,
                changed_slots=changed, max_drift=max_drift,
                tier0_hit_rate=hit_rate, modeled_step_us=step_us)
        return self.last_decision

    def stats(self) -> Dict[str, float]:
        """Lifetime control-loop counters (for serving dashboards)."""
        return {"evals": self.evals, "repacks": self.repacks,
                "skipped": self.skipped,
                "window_blocks": len(self._window),
                "window_hit_rate": self.window_hit_rate,
                "modeled_step_us": self.modeled_step_us()}
