"""Cache-fronted block store (repro.io).

``CachedBlockStore`` is a drop-in for ``BlockStore``: same
``read_block`` signature, same array attributes (``vid``/``vecs``/
``meta``/``packed()`` delegate to the wrapped store), so every existing
consumer — the host search, the DiskANN baseline, ``save_segment``,
``device_search.from_segment`` — works unchanged. What it adds is
accounting and batching:

  * every demand read is a cache lookup; tier-1 hits cost memory
    latency in the cost model, tier-2 hits (``TieredBlockCache``) serve
    from a compressed PQ-space summary at ``t_tier2_hit`` with *no*
    disk trip, misses fetch from "disk" and ``admit`` the block;
  * synchronous path (no queue): a miss issues exactly one I/O round
    trip and speculative prefetch targets coalesce into that same trip
    (``read_demand`` with ``prefetch=...``); a trip carrying *only*
    speculative blocks (demand hit + prefetch) still counts — its first
    block pays the full ``t_block_io`` in the cost model, a trip is
    never cheaper than the queue submission it models;
  * asynchronous path (``queue`` set): ``read_demand`` becomes
    submit/wait against the shared ``AsyncFetchQueue`` — speculative
    targets go in flight *before* the demand wait so they overlap its
    service window, completions deliver (admit + account) out of
    submission order, and a demand read of a block already in flight
    joins the existing ticket instead of issuing a new trip;
  * ``io_round_trips <= block_reads`` holds structurally on both paths:
    at most one trip per demand read (hits, tier-2 hits and joins issue
    none);
  * per-query counters flow into the ``IOStats`` passed to
    ``read_demand`` (or the ``stats_sink`` attribute for drop-in
    ``read_block`` callers); lifetime totals accumulate in ``.total`` so
    a serving plane sharing one store across queries can report a
    cache hit rate.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.blockstore import BlockStore
from repro.core.iostats import IOStats
from repro.io.async_fetch import AsyncFetchQueue, FetchTicket
from repro.io.cache import BlockCache, TieredBlockCache
from repro.io.hotset import hot_block_pin_set, view_seed_ids


class CachedBlockStore:
    def __init__(self, base: BlockStore,
                 cache: Union[BlockCache, TieredBlockCache],
                 prefetch_width: int = 0,
                 queue: Optional[AsyncFetchQueue] = None,
                 record_fetches: bool = False):
        self.base = base
        self.cache = cache
        self.prefetch_width = int(prefetch_width)
        self.queue = queue
        self.stats_sink: Optional[IOStats] = None
        self.total = IOStats()          # lifetime counters across queries
        # lifetime demand-read count per block: the observed-frequency
        # feed for dynamic hot-set admission (hotset.
        # repack_from_frequencies / device_search.from_segment(observed=))
        self.block_freq: Counter = Counter()
        # (kind, block) log of disk fetches, kind in {"miss", "prefetch"};
        # test hook for the never-fetch-twice invariant.
        self.fetch_log: Optional[List[Tuple[str, int]]] = \
            [] if record_fetches else None
        # observability (repro.obs): both optional and None-guarded on
        # the hot path; set here (not lazily) because __getattr__
        # forwards unknown attributes to the base store. ``obs_target``
        # is the per-target attribution label metrics publish under.
        self.tracer = None              # repro.obs.trace.Tracer
        self.metrics = None             # repro.obs.metrics.MetricsRegistry
        self.obs_target: str = ""

    def attach_obs(self, tracer=None, metrics=None,
                   target: str = "") -> None:
        """Wire the store into the observability plane: ``io.read``
        spans on ``tracer`` (and fetch submit/complete events on the
        attached queue), lifetime counters published to ``metrics``
        under ``target``."""
        self.tracer = tracer
        self.metrics = metrics
        self.obs_target = target
        if self.queue is not None and tracer is not None and \
                getattr(self.queue, "tracer", None) is None:
            self.queue.tracer = tracer

    def publish_metrics(self) -> None:
        """Re-express the lifetime cache counters through the metrics
        registry (gauges under ``io.*``, attributed to ``obs_target``)
        — the registry view and ``total`` can never disagree because
        this *is* ``total``, republished."""
        if self.metrics is None:
            return
        t = self.total
        for name, val in (
                ("io.block_reads", t.block_reads),
                ("io.cache_hits", t.cache_hits),
                ("io.tier2_hits", t.tier2_hits),
                ("io.cache_misses", t.cache_misses),
                ("io.round_trips", t.io_round_trips),
                ("io.prefetched_blocks", t.prefetched_blocks),
                ("io.queue_fetches", t.queue_fetches),
                ("io.inflight_peak", t.inflight_peak),
                ("io.inflight_joins", t.inflight_joins),
                ("io.completion_reorders", t.completion_reorders),
                ("io.hit_rate", t.cache_hit_rate)):
            self.metrics.gauge(name, self.obs_target).set(val)

    # ------------------------------------------------------- delegation
    def __getattr__(self, name):
        # only consulted for attributes not set on self: num_blocks,
        # verts_per_block, dim, vid, vecs, meta, packed, disk_bytes, ...
        return getattr(self.base, name)

    def memory_bytes(self) -> int:
        """Eq. 10 charge of the cache (full reserved budget, all tiers)."""
        return self.cache.memory_bytes()

    # ------------------------------------------------------------ reads
    def _lookup_tier(self, b: int) -> int:
        """1 = full-block hit, 2 = compressed-summary hit, 0 = miss —
        both cache classes speak the lookup_tier protocol."""
        return self.cache.lookup_tier(b)

    def read_block(self, b: int):
        """Drop-in demand read; accounts into ``stats_sink`` if set."""
        return self.read_demand(b, self.stats_sink)

    def read_demand(self, b: int, stats: Optional[IOStats] = None,
                    prefetch: Sequence[int] = ()):
        """Demand-read block ``b``; speculate ``prefetch`` blocks
        (already filtered to non-resident ids). Dispatches to the async
        submit/wait path when an ``AsyncFetchQueue`` is attached,
        otherwise coalesces the speculation into the demand round trip.
        """
        if self.tracer is not None:
            # residency peeked via ``in`` (side-effect-free — a
            # lookup_tier here would double-touch LRU recency and
            # tier-2 promotion, breaking trace-on/off identity)
            with self.tracer.span("io.read", cat="io",
                                  track=self.obs_target or "io",
                                  block=int(b),
                                  cached=bool(b in self.cache)):
                return self._read_demand(b, stats, prefetch)
        return self._read_demand(b, stats, prefetch)

    def _read_demand(self, b: int, stats: Optional[IOStats],
                     prefetch: Sequence[int] = ()):
        self.block_freq[int(b)] += 1
        if self.queue is not None:
            return self._read_async(b, stats, prefetch)
        tier = self._lookup_tier(b)
        targets = [p for p in prefetch if p != b and p not in self.cache]
        trip = (tier == 0) or bool(targets)
        self._account(stats, tier=tier, trip=trip,
                      prefetched=len(targets))
        if tier == 0:
            self.cache.admit(b)
            self._log("miss", b)
        for p in targets:
            self.cache.admit(p)
            self._log("prefetch", p)
        return self.base.read_block(b)

    # ------------------------------------------------------- async path
    def _key(self, b: int) -> tuple:
        """In-flight identity on a shared queue: namespaced by the
        backing store, so equal block ids of *different* segments never
        conflate, while views over the same base dedup as intended."""
        return (id(self.base), b)

    def _read_async(self, b: int, stats: Optional[IOStats],
                    prefetch: Sequence[int] = ()):
        """Submit/wait demand read against the shared fetch queue.

        Order matters: speculative targets are submitted *before* the
        demand wait so their service windows overlap it (§5.1 — the
        occupancy the cost model prices). A block already in flight —
        from this query's speculation or another query on the shared
        queue — is joined, not re-fetched."""
        q = self.queue
        tier = self._lookup_tier(b)
        if tier:
            self._account(stats, tier=tier, trip=False, prefetched=0)
            self._speculate(prefetch, b, stats)
            self._deliver(q.poll(), stats)
            return self.base.read_block(b)
        ticket = q.get(b, key=self._key(b))
        joined = ticket is not None
        residual = ticket.residual(q.clock) if joined else 0.0
        if not joined:
            while q.free_slots <= 0:
                self._deliver(q.wait_any(), stats)
            ticket, _ = q.submit(b, kind="demand", key=self._key(b),
                                 owner=self)
            self._log("miss", b)
        self._bump(stats, "queue_fetches", 0 if joined else 1)
        self._account(stats, tier=0, trip=not joined, prefetched=0,
                      joined=joined, residual=residual)
        self._speculate(prefetch, b, stats)
        self._deliver(q.wait(ticket), stats)
        # a joined ticket delivers into its submitter's cache; this
        # store received the payload too, so it admits as well
        self.cache.admit(b)
        return self.base.read_block(b)

    def _speculate(self, prefetch: Sequence[int], demand: int,
                   stats: Optional[IOStats]) -> None:
        q = self.queue
        for p in prefetch:
            if q.free_slots <= 0:
                break
            if (p == demand or p in self.cache
                    or q.in_flight(p, key=self._key(p))):
                continue
            _, occ = q.submit(p, kind="speculative", key=self._key(p),
                              owner=self)
            self._log("prefetch", p)
            for s in (stats, self.total):
                if s is None:
                    continue
                s.queue_fetches += 1
                s.queue_occ_weight += 1.0 / occ
                s.inflight_peak = max(s.inflight_peak, occ)

    def _deliver(self, completions: List[FetchTicket],
                 stats: Optional[IOStats]) -> None:
        """Consume queue completions: admit each block into its
        *submitter's* cache (tickets from other stores sharing the
        queue complete here too) and account out-of-order deliveries
        against the stats of whoever drove the clock."""
        for t in completions:
            target = t.owner if t.owner is not None else self
            target.cache.admit(t.block)
            if t.reordered:
                for s in (stats, self.total):
                    if s is not None:
                        s.completion_reorders += 1

    def attach_queue(self, queue: Optional[AsyncFetchQueue]) -> None:
        """Switch to a (shared) fetch queue, first draining any private
        one so its in-flight blocks are still admitted and accounted —
        silently orphaning tickets would re-fetch them later."""
        if self.queue is not None and self.queue is not queue:
            self._deliver(self.queue.drain(), None)
        self.queue = queue
        if queue is not None and self.tracer is not None and \
                getattr(queue, "tracer", None) is None:
            queue.tracer = self.tracer

    # ------------------------------------------------------- accounting
    def _log(self, kind: str, b: int) -> None:
        if self.fetch_log is not None:
            self.fetch_log.append((kind, b))

    def _bump(self, stats: Optional[IOStats], field: str, n: int) -> None:
        for s in (stats, self.total):
            if s is not None:
                setattr(s, field, getattr(s, field) + n)

    def _account(self, stats: Optional[IOStats], tier: int, trip: bool,
                 prefetched: int, joined: bool = False,
                 residual: float = 0.0) -> None:
        for s in (stats, self.total):
            if s is None:
                continue
            s.block_reads += 1
            if tier == 1:
                s.cache_hits += 1
            elif tier == 2:
                s.tier2_hits += 1
            else:
                s.cache_misses += 1
            if trip:
                s.io_round_trips += 1
            if joined:
                s.inflight_joins += 1
                s.join_residual += residual
            s.prefetched_blocks += prefetched
            if self.queue is not None:
                s.inflight_peak = max(s.inflight_peak, len(self.queue))

    # ------------------------------------------------------------ stats
    @property
    def hit_rate(self) -> float:
        return self.total.cache_hit_rate

    def freq_delta(self, since: Optional[Counter] = None) -> Counter:
        """Demand-read counts accumulated since ``since`` (an earlier
        snapshot of ``block_freq``; None = lifetime).

        The per-interval drift signal the serving ``RepackScheduler``
        folds: lifetime counts would let a long-dead workload anchor
        the pack forever, so the scheduler windows each decision on the
        traffic since its last one. ``block_freq`` itself keeps
        accumulating — snapshots are the caller's watermark, the store
        never forgets."""
        if since is None:
            return Counter(self.block_freq)
        out = Counter()
        for b, c in self.block_freq.items():
            d = c - since.get(b, 0)
            if d > 0:
                out[b] = d
        return out


def make_cached_store(store: BlockStore, cache_params,
                      block_of: Optional[np.ndarray] = None,
                      adj: Optional[np.ndarray] = None,
                      deg: Optional[np.ndarray] = None,
                      seed_ids: Optional[Sequence[int]] = None,
                      queue: Optional[AsyncFetchQueue] = None,
                      record_fetches: bool = False) -> CachedBlockStore:
    """Wrap ``store`` per ``CacheParams``: resolve the byte budget,
    split it across tiers (``tier2_frac`` > 0 → ``TieredBlockCache``
    with compressed PQ-space summaries), pin the build-time hot set
    (needs ``block_of``/``adj``/``deg``/``seed_ids``; skipped when
    absent), pick the eviction policy, and attach the async fetch queue
    (``queue_depth`` > 0, or a shared ``queue`` from the serving
    plane)."""
    budget = cache_params.resolve_budget(store.disk_bytes())
    block_bytes = max(int(store.block_kb * 1024), 1)
    tier2_bytes = int(budget * getattr(cache_params, "tier2_frac", 0.0))
    tier1_bytes = budget - tier2_bytes
    pinned: Sequence[int] = ()
    if (cache_params.pin_fraction > 0 and block_of is not None
            and adj is not None and deg is not None
            and seed_ids is not None and len(seed_ids) > 0):
        pin_blocks = int(cache_params.pin_fraction
                         * (tier1_bytes // block_bytes))
        pinned = hot_block_pin_set(block_of, adj, deg, seed_ids,
                                   max_blocks=pin_blocks)
    if tier2_bytes > 0:
        cache = TieredBlockCache(
            tier1_bytes, tier2_bytes, block_bytes,
            compression=cache_params.tier2_compression,
            policy=cache_params.policy, pinned=pinned)
    else:
        cache = BlockCache(budget, block_bytes,
                           policy=cache_params.policy, pinned=pinned)
    if queue is None and cache_params.queue_depth > 0:
        queue = AsyncFetchQueue(depth=cache_params.queue_depth)
    return CachedBlockStore(store, cache,
                            prefetch_width=cache_params.prefetch_width,
                            queue=queue,
                            record_fetches=record_fetches)


def cached_view(view, graph, cache_params,
                queue: Optional[AsyncFetchQueue] = None,
                record_fetches: bool = False):
    """The one way to cache-front a ``SegmentView`` (used by the segment
    builder, the serving plane, benchmarks, and tests alike).

    Seeds the build-time hot set from the navigation-graph sample — the
    entry neighborhood every query traverses first — falling back to the
    static entry when navigation is off (``hotset.view_seed_ids``, the
    same seeds the device tier-0 pack selects from). ``view`` is
    duck-typed (kept untyped to avoid a circular import with
    ``core.search``).
    """
    seeds = view_seed_ids(view)
    store = make_cached_store(view.store, cache_params,
                              block_of=view.layout.block_of,
                              adj=graph.adj, deg=graph.deg,
                              seed_ids=seeds,
                              queue=queue,
                              record_fetches=record_fetches)
    return dataclasses.replace(view, store=store)
