"""Cache-fronted block store (repro.io).

``CachedBlockStore`` is a drop-in for ``BlockStore``: same
``read_block`` signature, same array attributes (``vid``/``vecs``/
``meta``/``packed()`` delegate to the wrapped store), so every existing
consumer — the host search, the DiskANN baseline, ``save_segment``,
``device_search.from_segment`` — works unchanged. What it adds is
accounting and batching:

  * every demand read is a cache ``lookup``; hits cost memory latency in
    the cost model, misses fetch from "disk" and ``admit`` the block;
  * a miss issues exactly one I/O round trip, and speculative prefetch
    targets can be coalesced into that same trip (``read_demand`` with
    ``prefetch=...``), which is what finally populates
    ``IOStats.io_round_trips`` (≤ ``block_reads`` by construction:
    at most one trip per demand read);
  * per-query counters flow into the ``IOStats`` passed to
    ``read_demand`` (or the ``stats_sink`` attribute for drop-in
    ``read_block`` callers); lifetime totals accumulate in ``.total`` so
    a serving plane sharing one store across queries can report a
    cache hit rate.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.blockstore import BlockStore
from repro.core.iostats import IOStats
from repro.io.cache import BlockCache, hot_block_pin_set


class CachedBlockStore:
    def __init__(self, base: BlockStore, cache: BlockCache,
                 prefetch_width: int = 0,
                 record_fetches: bool = False):
        self.base = base
        self.cache = cache
        self.prefetch_width = int(prefetch_width)
        self.stats_sink: Optional[IOStats] = None
        self.total = IOStats()          # lifetime counters across queries
        # (kind, block) log of disk fetches, kind in {"miss", "prefetch"};
        # test hook for the never-fetch-twice invariant.
        self.fetch_log: Optional[List[Tuple[str, int]]] = \
            [] if record_fetches else None

    # ------------------------------------------------------- delegation
    def __getattr__(self, name):
        # only consulted for attributes not set on self: num_blocks,
        # verts_per_block, dim, vid, vecs, meta, packed, disk_bytes, ...
        return getattr(self.base, name)

    def memory_bytes(self) -> int:
        """Eq. 10 charge of the cache (full reserved budget)."""
        return self.cache.memory_bytes()

    # ------------------------------------------------------------ reads
    def read_block(self, b: int):
        """Drop-in demand read; accounts into ``stats_sink`` if set."""
        return self.read_demand(b, self.stats_sink)

    def read_demand(self, b: int, stats: Optional[IOStats] = None,
                    prefetch: Sequence[int] = ()):
        """Demand-read block ``b``; coalesce ``prefetch`` blocks (already
        filtered to non-resident ids) into the same round trip.

        At most one round trip is issued per demand read, so
        ``io_round_trips <= block_reads`` holds structurally.
        """
        hit = self.cache.lookup(b)
        targets = [p for p in prefetch if p != b and p not in self.cache]
        trip = (not hit) or bool(targets)
        self._account(stats, hit=hit, trip=trip,
                      prefetched=len(targets))
        if not hit:
            self.cache.admit(b)
            if self.fetch_log is not None:
                self.fetch_log.append(("miss", b))
        for p in targets:
            self.cache.admit(p)
            if self.fetch_log is not None:
                self.fetch_log.append(("prefetch", p))
        return self.base.read_block(b)

    def _account(self, stats: Optional[IOStats], hit: bool, trip: bool,
                 prefetched: int) -> None:
        for s in (stats, self.total):
            if s is None:
                continue
            s.block_reads += 1
            if hit:
                s.cache_hits += 1
            else:
                s.cache_misses += 1
            if trip:
                s.io_round_trips += 1
            s.prefetched_blocks += prefetched

    # ------------------------------------------------------------ stats
    @property
    def hit_rate(self) -> float:
        return self.total.cache_hit_rate


def make_cached_store(store: BlockStore, cache_params,
                      block_of: Optional[np.ndarray] = None,
                      adj: Optional[np.ndarray] = None,
                      deg: Optional[np.ndarray] = None,
                      seed_ids: Optional[Sequence[int]] = None,
                      record_fetches: bool = False) -> CachedBlockStore:
    """Wrap ``store`` per ``CacheParams``: resolve the byte budget, pin
    the build-time hot set (needs ``block_of``/``adj``/``deg``/
    ``seed_ids``; skipped when absent), pick the eviction policy."""
    budget = cache_params.resolve_budget(store.disk_bytes())
    block_bytes = max(int(store.block_kb * 1024), 1)
    pinned: Sequence[int] = ()
    if (cache_params.pin_fraction > 0 and block_of is not None
            and adj is not None and deg is not None
            and seed_ids is not None and len(seed_ids) > 0):
        pin_blocks = int(cache_params.pin_fraction
                         * (budget // block_bytes))
        pinned = hot_block_pin_set(block_of, adj, deg, seed_ids,
                                   max_blocks=pin_blocks)
    cache = BlockCache(budget, block_bytes,
                       policy=cache_params.policy, pinned=pinned)
    return CachedBlockStore(store, cache,
                            prefetch_width=cache_params.prefetch_width,
                            record_fetches=record_fetches)


def cached_view(view, graph, cache_params, record_fetches: bool = False):
    """The one way to cache-front a ``SegmentView`` (used by the segment
    builder, the serving plane, benchmarks, and tests alike).

    Seeds the build-time hot set from the navigation-graph sample — the
    entry neighborhood every query traverses first — falling back to the
    static entry when navigation is off. ``view`` is duck-typed (kept
    untyped to avoid a circular import with ``core.search``).
    """
    seeds = (view.nav.sample_ids if view.nav is not None
             else np.asarray([view.entry], np.int64))
    store = make_cached_store(view.store, cache_params,
                              block_of=view.layout.block_of,
                              adj=graph.adj, deg=graph.deg,
                              seed_ids=seeds,
                              record_fetches=record_fetches)
    return dataclasses.replace(view, store=store)
