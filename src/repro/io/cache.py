"""Memory-budgeted block cache with pluggable eviction (repro.io).

The cache holds *block ids* — block payloads already live in the host
arrays of ``BlockStore``, so residency here models which η-KB blocks a
real segment server would keep in its DRAM pool. Capacity is expressed
in bytes and charged against the segment's Eq. 10 memory budget
(C_graph + C_mapping + C_PQ&others + C_cache); see
``Segment.memory_bytes``.

Eviction policies:
  * ``lru`` — least-recently-used (default; matches the access locality
    the BNF/BNS shuffles create).
  * ``lfu`` — least-frequently-used with LRU tie-break (GoVector-style
    frequency retention for skewed query streams).
  * static pinning — ``pinned`` blocks are preloaded at build time and
    never evicted; the pin set is the top of the tier-shared
    ``repro.io.hotset`` ranking (traversal frequency around the
    navigation-graph entry neighborhood — the same ranking the device
    tier-0 hot-tile pack selects from, so "hot" means the same thing in
    every tier).

``TieredBlockCache`` stacks two ``BlockCache`` instances: tier 1 holds
full η-KB blocks, tier 2 holds compressed PQ-space block summaries at
~1/16 the bytes, so tight Eq. 10 budgets keep a much larger fraction of
the segment reachable without a disk trip (the GoVector argument,
arXiv:2508.15694).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Iterable, List

# build-time hot-set selection moved to the tier-shared repro.io.hotset
# module (the device tier-0 pack uses the same ranking); re-exported
# here for existing importers
from repro.io.hotset import hot_block_pin_set  # noqa: F401


class EvictionPolicy:
    """Tracks non-pinned residents and picks eviction victims."""

    def on_insert(self, b: int) -> None:
        raise NotImplementedError

    def on_access(self, b: int) -> None:
        raise NotImplementedError

    def victim(self) -> int:
        raise NotImplementedError

    def remove(self, b: int) -> None:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError


class LRUPolicy(EvictionPolicy):
    def __init__(self):
        self._order: "OrderedDict[int, None]" = OrderedDict()

    def on_insert(self, b: int) -> None:
        self._order[b] = None
        self._order.move_to_end(b)

    def on_access(self, b: int) -> None:
        if b in self._order:
            self._order.move_to_end(b)

    def victim(self) -> int:
        return next(iter(self._order))

    def remove(self, b: int) -> None:
        self._order.pop(b, None)

    def __len__(self) -> int:
        return len(self._order)


class LFUPolicy(EvictionPolicy):
    """Least-frequently-used; ties broken by least-recent access."""

    def __init__(self):
        self._freq: Dict[int, int] = {}
        self._tick_of: Dict[int, int] = {}
        self._tick = 0

    def _touch(self, b: int) -> None:
        self._tick += 1
        self._tick_of[b] = self._tick

    def on_insert(self, b: int) -> None:
        self._freq[b] = self._freq.get(b, 0) + 1
        self._touch(b)

    def on_access(self, b: int) -> None:
        if b in self._freq:
            self._freq[b] += 1
            self._touch(b)

    def victim(self) -> int:
        return min(self._freq,
                   key=lambda b: (self._freq[b], self._tick_of[b]))

    def remove(self, b: int) -> None:
        self._freq.pop(b, None)
        self._tick_of.pop(b, None)

    def __len__(self) -> int:
        return len(self._freq)


POLICIES = {"lru": LRUPolicy, "lfu": LFUPolicy}


class TieredBlockCache:
    """Two-tier residency: full blocks over compressed PQ-space summaries.

    Tier 1 holds full η-KB blocks (exactly the single-tier
    ``BlockCache``); tier 2 holds compressed PQ-space block summaries at
    ``block_bytes // compression`` each (GoVector-style), so the same
    byte budget covers ~``compression``× more blocks. A tier-2 hit
    re-ranks the block's candidates from the summary without a disk
    trip — priced at ``CostModel.t_tier2_hit`` — and promotes the block
    into tier 1. Tier-1 evictions demote their victim into tier 2;
    tier-2 evictions fall out of the hierarchy.

    Both tiers' capacities are reserved DRAM and charge into the Eq. 10
    segment memory budget via ``memory_bytes()``.
    """

    def __init__(self, tier1_bytes: int, tier2_bytes: int,
                 block_bytes: int, compression: int = 16,
                 policy: str = "lru", pinned: Iterable[int] = ()):
        if compression < 1:
            raise ValueError("compression must be >= 1")
        self.tier1 = BlockCache(tier1_bytes, block_bytes,
                                policy=policy, pinned=pinned)
        self.tier2 = BlockCache(tier2_bytes,
                                max(block_bytes // compression, 1),
                                policy=policy)
        self.compression = int(compression)
        self.tier2_admits = 0       # demotions on tier-1 eviction
        self.tier2_promotions = 0   # tier-2 hits promoted into tier 1

    # -------------------------------------------------------------- state
    @property
    def pinned(self) -> set:
        return self.tier1.pinned

    @property
    def evictions(self) -> int:
        """Blocks that left the hierarchy entirely (tier-2 evictions)."""
        return self.tier2.evictions

    def __contains__(self, b: int) -> bool:
        return b in self.tier1 or b in self.tier2

    def __len__(self) -> int:
        return len(self.tier1) + len(self.tier2)

    def resident_bytes(self) -> int:
        return self.tier1.resident_bytes() + self.tier2.resident_bytes()

    def memory_bytes(self) -> int:
        """Eq. 10 charge: both tiers' reserved budgets."""
        return self.tier1.memory_bytes() + self.tier2.memory_bytes()

    # ------------------------------------------------------------- access
    def lookup_tier(self, b: int) -> int:
        """Demand access: 1 = full-block hit, 2 = summary hit (promoted
        into tier 1), 0 = miss."""
        if self.tier1.lookup(b):
            return 1
        if self.tier2.lookup(b):
            if self.tier1.can_admit(b):
                # the summary is decompressed into a tier-1 slot; any
                # tier-1 victim demotes into the slot tier 2 just freed
                self.tier2.remove(b)
                self._admit_tier1(b)
                self.tier2_promotions += 1
            return 2
        return 0

    def lookup(self, b: int) -> bool:
        """BlockCache-compatible any-tier demand access."""
        return self.lookup_tier(b) > 0

    def admit(self, b: int) -> List[int]:
        """Insert a freshly fetched full block into tier 1; the fetch
        supersedes any stale tier-2 summary. Returns blocks that left
        the hierarchy (tier-2 evictions)."""
        if b in self.tier1:
            return []
        if not self.tier1.can_admit(b):
            # degenerate tier 1 (zero capacity, or fully pinned with no
            # evictable victim): summarize the fetched block straight
            # into tier 2 rather than dropping it
            if b in self.tier2:
                return []
            return self.tier2.admit(b)
        self.tier2.remove(b)
        return self._admit_tier1(b)

    def _admit_tier1(self, b: int) -> List[int]:
        dropped: List[int] = []
        for v in self.tier1.admit(b):
            dropped.extend(self.tier2.admit(v))
            self.tier2_admits += 1
        return dropped


class BlockCache:
    """Byte-budgeted set of resident block ids.

    ``capacity_bytes // block_bytes`` blocks fit; ``pinned`` blocks are
    preloaded (a build-time warm-up, not query-time I/O) and never
    evicted. The dynamic remainder of the capacity is managed by the
    eviction policy.
    """

    def __init__(self, capacity_bytes: int, block_bytes: int,
                 policy: str = "lru",
                 pinned: Iterable[int] = ()):
        if block_bytes <= 0:
            raise ValueError("block_bytes must be positive")
        if policy not in POLICIES:
            raise ValueError(f"unknown eviction policy {policy!r}; "
                             f"choose from {sorted(POLICIES)}")
        self.capacity_bytes = int(capacity_bytes)
        self.block_bytes = int(block_bytes)
        self.capacity_blocks = max(self.capacity_bytes // self.block_bytes,
                                   0)
        self.policy_name = policy
        self._policy: EvictionPolicy = POLICIES[policy]()
        self.pinned = set(list(dict.fromkeys(int(b) for b in pinned))
                          [: self.capacity_blocks])
        self._resident = set(self.pinned)
        self.evictions = 0

    # -------------------------------------------------------------- state
    def __contains__(self, b: int) -> bool:
        return b in self._resident

    def __len__(self) -> int:
        return len(self._resident)

    @property
    def resident(self) -> frozenset:
        return frozenset(self._resident)

    def resident_bytes(self) -> int:
        return len(self._resident) * self.block_bytes

    def memory_bytes(self) -> int:
        """Eq. 10 charge: the full budget is reserved, not just residency."""
        return self.capacity_bytes

    # ------------------------------------------------------------- access
    def lookup(self, b: int) -> bool:
        """Demand access: True on hit (and refreshes the policy)."""
        if b in self._resident:
            self._policy.on_access(b)
            return True
        return False

    def lookup_tier(self, b: int) -> int:
        """Tier-protocol demand access (shared with TieredBlockCache —
        and any future tier-0 device cache): 1 on hit, 0 on miss."""
        return 1 if self.lookup(b) else 0

    def can_admit(self, b: int) -> bool:
        """Whether ``admit(b)`` would leave ``b`` resident: capacity
        exists and is either free or reclaimable (pinned blocks are not
        victims, so a fully pinned cache admits nothing new)."""
        if self.capacity_blocks == 0:
            return False
        return (b in self._resident
                or len(self._resident) < self.capacity_blocks
                or len(self._policy) > 0)

    def admit(self, b: int) -> List[int]:
        """Insert a fetched block, evicting victims if over capacity.

        Returns the evicted block ids (empty when nothing was displaced)
        so a tiered cache can demote them into its next tier."""
        if self.capacity_blocks == 0 or b in self._resident:
            return []
        # pinned blocks are resident from construction and never evicted,
        # so b is always un-pinned here
        evicted: List[int] = []
        while (len(self._resident) >= self.capacity_blocks
               and len(self._policy) > 0):
            v = self._policy.victim()
            self._policy.remove(v)
            self._resident.discard(v)
            self.evictions += 1
            evicted.append(v)
        if len(self._resident) < self.capacity_blocks:
            self._resident.add(b)
            self._policy.on_insert(b)
        return evicted

    def remove(self, b: int) -> bool:
        """Drop a non-pinned resident (tier promotion/supersession)."""
        if b not in self._resident or b in self.pinned:
            return False
        self._resident.discard(b)
        self._policy.remove(b)
        return True
