"""Memory-budgeted block cache with pluggable eviction (repro.io).

The cache holds *block ids* — block payloads already live in the host
arrays of ``BlockStore``, so residency here models which η-KB blocks a
real segment server would keep in its DRAM pool. Capacity is expressed
in bytes and charged against the segment's Eq. 10 memory budget
(C_graph + C_mapping + C_PQ&others + C_cache); see
``Segment.memory_bytes``.

Eviction policies:
  * ``lru`` — least-recently-used (default; matches the access locality
    the BNF/BNS shuffles create).
  * ``lfu`` — least-frequently-used with LRU tie-break (GoVector-style
    frequency retention for skewed query streams).
  * static pinning — ``pinned`` blocks are preloaded at build time and
    never evicted; ``hot_block_pin_set`` measures traversal frequency
    around the navigation-graph entry neighborhood, since every query's
    first hops land there (Fig. 10: entry points come from the μ-sample).
"""
from __future__ import annotations

from collections import Counter, OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


class EvictionPolicy:
    """Tracks non-pinned residents and picks eviction victims."""

    def on_insert(self, b: int) -> None:
        raise NotImplementedError

    def on_access(self, b: int) -> None:
        raise NotImplementedError

    def victim(self) -> int:
        raise NotImplementedError

    def remove(self, b: int) -> None:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError


class LRUPolicy(EvictionPolicy):
    def __init__(self):
        self._order: "OrderedDict[int, None]" = OrderedDict()

    def on_insert(self, b: int) -> None:
        self._order[b] = None
        self._order.move_to_end(b)

    def on_access(self, b: int) -> None:
        if b in self._order:
            self._order.move_to_end(b)

    def victim(self) -> int:
        return next(iter(self._order))

    def remove(self, b: int) -> None:
        self._order.pop(b, None)

    def __len__(self) -> int:
        return len(self._order)


class LFUPolicy(EvictionPolicy):
    """Least-frequently-used; ties broken by least-recent access."""

    def __init__(self):
        self._freq: Dict[int, int] = {}
        self._tick_of: Dict[int, int] = {}
        self._tick = 0

    def _touch(self, b: int) -> None:
        self._tick += 1
        self._tick_of[b] = self._tick

    def on_insert(self, b: int) -> None:
        self._freq[b] = self._freq.get(b, 0) + 1
        self._touch(b)

    def on_access(self, b: int) -> None:
        if b in self._freq:
            self._freq[b] += 1
            self._touch(b)

    def victim(self) -> int:
        return min(self._freq,
                   key=lambda b: (self._freq[b], self._tick_of[b]))

    def remove(self, b: int) -> None:
        self._freq.pop(b, None)
        self._tick_of.pop(b, None)

    def __len__(self) -> int:
        return len(self._freq)


POLICIES = {"lru": LRUPolicy, "lfu": LFUPolicy}


class BlockCache:
    """Byte-budgeted set of resident block ids.

    ``capacity_bytes // block_bytes`` blocks fit; ``pinned`` blocks are
    preloaded (a build-time warm-up, not query-time I/O) and never
    evicted. The dynamic remainder of the capacity is managed by the
    eviction policy.
    """

    def __init__(self, capacity_bytes: int, block_bytes: int,
                 policy: str = "lru",
                 pinned: Iterable[int] = ()):
        if block_bytes <= 0:
            raise ValueError("block_bytes must be positive")
        if policy not in POLICIES:
            raise ValueError(f"unknown eviction policy {policy!r}; "
                             f"choose from {sorted(POLICIES)}")
        self.capacity_bytes = int(capacity_bytes)
        self.block_bytes = int(block_bytes)
        self.capacity_blocks = max(self.capacity_bytes // self.block_bytes,
                                   0)
        self.policy_name = policy
        self._policy: EvictionPolicy = POLICIES[policy]()
        self.pinned = set(list(dict.fromkeys(int(b) for b in pinned))
                          [: self.capacity_blocks])
        self._resident = set(self.pinned)
        self.evictions = 0

    # -------------------------------------------------------------- state
    def __contains__(self, b: int) -> bool:
        return b in self._resident

    def __len__(self) -> int:
        return len(self._resident)

    @property
    def resident(self) -> frozenset:
        return frozenset(self._resident)

    def resident_bytes(self) -> int:
        return len(self._resident) * self.block_bytes

    def memory_bytes(self) -> int:
        """Eq. 10 charge: the full budget is reserved, not just residency."""
        return self.capacity_bytes

    # ------------------------------------------------------------- access
    def lookup(self, b: int) -> bool:
        """Demand access: True on hit (and refreshes the policy)."""
        if b in self._resident:
            self._policy.on_access(b)
            return True
        return False

    def admit(self, b: int) -> None:
        """Insert a fetched block, evicting a victim if over capacity."""
        if self.capacity_blocks == 0 or b in self._resident:
            return
        # pinned blocks are resident from construction and never evicted,
        # so b is always un-pinned here
        while (len(self._resident) >= self.capacity_blocks
               and len(self._policy) > 0):
            v = self._policy.victim()
            self._policy.remove(v)
            self._resident.discard(v)
            self.evictions += 1
        if len(self._resident) < self.capacity_blocks:
            self._resident.add(b)
            self._policy.on_insert(b)


def hot_block_pin_set(block_of: np.ndarray, adj: np.ndarray,
                      deg: np.ndarray,
                      seed_ids: Sequence[int],
                      max_blocks: int,
                      hops: int = 1) -> List[int]:
    """Build-time hot set: blocks by traversal frequency around the
    navigation-graph entry neighborhood.

    ``seed_ids`` are the vertices queries enter through (the nav-graph
    μ-sample, or the medoid when navigation is off). Every search's first
    expansions read the seeds' blocks and their disk-graph neighbors'
    blocks, so we count those touches — seeds weighted above neighbors —
    and pin the ``max_blocks`` most frequent.
    """
    if max_blocks <= 0 or len(seed_ids) == 0:
        return []
    counts: Counter = Counter()
    frontier = [int(v) for v in seed_ids]
    weight = 1 << hops
    for _ in range(hops + 1):
        for v in frontier:
            counts[int(block_of[v])] += weight
        if weight == 1:
            break
        nxt: List[int] = []
        seen = set(frontier)
        for v in frontier:
            for w in adj[v, : deg[v]]:
                w = int(w)
                if w >= 0 and w not in seen:
                    seen.add(w)
                    nxt.append(w)
        frontier = nxt
        weight >>= 1
    return [b for b, _ in counts.most_common(max_blocks)]
