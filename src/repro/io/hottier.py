"""repro.io.hottier — the in-memory hot tier above the block hierarchy
(DESIGN.md §10).

Tiers 0/1/2 cache *blocks* of one static disk graph; the hot tier is a
small navigable in-memory graph over the hot-set *vectors* (selected by
the same ``repro.io.hotset`` ranking every block tier admits from) that
*answers* at memory latency. Hybrid routing runs a query on the hot
graph to convergence first, then seeds the cold block search from the
hot tier's exit frontier (the seed-override paths of
``core.search.block_search_query`` / ``core.device_search.device_anns``)
— so the disk graph starts where memory already converged. The memory
work is charged as ``IOStats.hot_tier_hits`` (one exact distance +
queue op per visited vertex) and priced by ``CostModel.t_hot_tier_hit``,
never as block I/O.

The hot tier is also the *mutable* region of a segment
(``core.delta.DeltaSegment``): inserts land in its append region via
incremental graph insertion, deletes are tombstones masked at route
time, and ``compact()`` folds everything back into a fresh disk layout.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core import graph as G
from repro.core import navgraph as NG
from repro.core.params import HotTierParams
from repro.io import hotset


@dataclasses.dataclass
class HotRoute:
    """One batch's hot-tier routing output."""
    ids: np.ndarray       # [Q, k] global ids, −1-padded, tombstone-masked
    dists: np.ndarray     # [Q, k] exact distances (inf on pad)
    exits: np.ndarray     # [Q, exit_width] int32 cold-graph seed ids
    #                       (−1-padded): the exit frontier handed to the
    #                       block search's seed-override path
    hot_hits: np.ndarray  # [Q] int32 vertices visited — the memory
    #                       charge (IOStats.hot_tier_hits)


def merge_hot_cold(k: int,
                   hot_ids: np.ndarray, hot_dists: np.ndarray,
                   cold_ids: np.ndarray, cold_dists: np.ndarray):
    """Merge one query's hot + cold candidate rows into top-k.

    Dedup by id keeping the smaller distance (the hot tier scores
    exact f32 on host; the cold path may differ in the last ulp for
    the same vertex), then order by ``(dist, id)`` — the same tiebreak
    as ``coordinator.merge_topk`` / ``device_search.merge_shard_topk``,
    so hybrid results stay deterministic under any arrival order.
    Inputs are −1/inf padded rows; output is ([k] ids, [k] dists)."""
    ids = np.concatenate([hot_ids, cold_ids]).astype(np.int64)
    ds = np.concatenate([hot_dists, cold_dists]).astype(np.float32)
    best: Dict[int, float] = {}
    for i, d in zip(ids, ds):
        i = int(i)
        if i < 0 or not np.isfinite(d):
            continue
        if i not in best or d < best[i]:
            best[i] = float(d)
    order = sorted(best.items(), key=lambda t: (t[1], t[0]))[:k]
    out_i = np.full(k, -1, np.int64)
    out_d = np.full(k, np.inf, np.float32)
    for m, (i, d) in enumerate(order):
        out_i[m] = i
        out_d[m] = d
    return out_i, out_d


@dataclasses.dataclass
class HotTier:
    """A navigable in-memory graph over the hot set, with a mutable
    append region.

    Arrays are capacity-allocated; ``size`` is the live prefix. Local
    ids index the arrays; ``ids`` maps local → global. Global ids <
    ``base_size`` exist in the disk segment too (valid cold seeds);
    ids ≥ ``base_size`` are appended vectors that live ONLY here until
    a compaction."""
    vectors: np.ndarray            # [cap, D] float32
    ids: np.ndarray                # [cap] int64 global ids (−1 free)
    adj: np.ndarray                # [cap, Λ] int32 local adjacency
    deg: np.ndarray                # [cap] int32
    size: int
    base_size: int
    dead: np.ndarray               # [cap] bool local tombstones
    params: HotTierParams
    metric: str = "l2"
    entry: int = 0                 # local entry vertex
    tracer: Optional[object] = None
    metrics: Optional[object] = None
    _local_of: Dict[int, int] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------- accounting

    def memory_bytes(self) -> int:
        """The hot tier's Eq. 10 memory charge: resident vectors +
        adjacency + ids + tombstones, at full capacity (the append
        region is reserved memory whether used or not)."""
        return (self.vectors.nbytes + self.adj.nbytes + self.deg.nbytes
                + self.ids.nbytes + self.dead.nbytes)

    @property
    def live_count(self) -> int:
        return int(self.size - self.dead[: self.size].sum())

    def attach_obs(self, tracer=None, metrics=None,
                   target: str = "hot") -> None:
        """Wire the observability plane: ``route()`` records a
        ``hot.route`` span and hit counters against ``target``."""
        self.tracer = tracer
        if metrics is not None:
            self.metrics = metrics
            metrics.gauge("hot.size", target).set(float(self.size))
            metrics.gauge("hot.memory_bytes", target).set(
                float(self.memory_bytes()))
        self._obs_target = target

    # ------------------------------------------------------------ route

    def route(self, queries: np.ndarray, k: int) -> HotRoute:
        """Run the batch on the hot graph to convergence (memory cost).

        Returns the hot top-k (tombstones masked), the exit frontier
        (cold-graph seed ids for the block search), and per-query
        visit counts — the ``hot_tier_hits`` charge."""
        queries = np.ascontiguousarray(queries, np.float32)
        qn = queries.shape[0]
        p = self.params
        beam = max(p.search_beam, k, p.exit_width)
        span = (self.tracer.span("hot.route", cat="serve", track="hot",
                                 queries=qn)
                if self.tracer is not None else None)
        if span is not None:
            span.__enter__()
        ids_l, d, visited = G.greedy_search_batch(
            self.vectors[: self.size], self.adj[: self.size],
            self.deg[: self.size], self.entry, queries, beam=beam,
            metric=self.metric)
        hot_hits = np.asarray([len(v) for v in visited], np.int32)
        valid = ids_l >= 0
        safe = np.maximum(ids_l, 0)
        gids = np.where(valid, self.ids[safe], -1)
        is_dead = np.where(valid, self.dead[safe], True)

        out_i = np.full((qn, k), -1, np.int64)
        out_d = np.full((qn, k), np.inf, np.float32)
        exits = np.full((qn, p.exit_width), -1, np.int32)
        for b in range(qn):
            # results: live beam entries in distance order
            m = 0
            for j in range(beam):
                if valid[b, j] and not is_dead[b, j] and m < k:
                    out_i[b, m] = gids[b, j]
                    out_d[b, m] = d[b, j]
                    m += 1
            # exit frontier: best beam entries the COLD graph knows
            # (tombstoned vertices still navigate; appended ids don't
            # exist on disk and are skipped)
            e = 0
            for j in range(beam):
                if valid[b, j] and gids[b, j] < self.base_size \
                        and e < p.exit_width:
                    exits[b, e] = gids[b, j]
                    e += 1
        if span is not None:
            span.__exit__(None, None, None)
        if self.metrics is not None:
            tgt = getattr(self, "_obs_target", "hot")
            self.metrics.counter("hot.routed_queries", tgt).inc(qn)
            self.metrics.counter("hot.route_hits", tgt).inc(
                float(hot_hits.sum()))
        return HotRoute(ids=out_i, dists=out_d, exits=exits,
                        hot_hits=hot_hits)

    # ------------------------------------------------------- mutability

    def _grow(self) -> None:
        cap = self.vectors.shape[0]
        new_cap = max(cap * 2, cap + 8)
        for name in ("vectors", "ids", "adj", "deg", "dead"):
            a = getattr(self, name)
            shape = (new_cap,) + a.shape[1:]
            fill = -1 if a.dtype.kind == "i" else 0
            b = np.full(shape, fill, a.dtype) if a.dtype.kind == "i" \
                else np.zeros(shape, a.dtype)
            b[:cap] = a
            setattr(self, name, b)

    def insert(self, vecs: np.ndarray, gids: np.ndarray) -> None:
        """Incremental graph insertion into the append region: greedy
        search for each new vector's neighborhood, connect to the top
        ``max_degree``, add reverse edges (farthest-replacement when a
        neighbor is full) — HNSW-style, deterministic."""
        vecs = np.atleast_2d(np.asarray(vecs, np.float32))
        gids = np.atleast_1d(np.asarray(gids, np.int64))
        lam = self.adj.shape[1]
        for vec, gid in zip(vecs, gids):
            if self.size == self.vectors.shape[0]:
                self._grow()
            li = self.size
            self.vectors[li] = vec
            self.ids[li] = gid
            self.dead[li] = False
            if li == 0:
                self.deg[li] = 0
                self.entry = 0
            else:
                ids_l, _, _ = G.greedy_search_batch(
                    self.vectors[: li], self.adj[: li], self.deg[: li],
                    self.entry, vec[None, :],
                    beam=max(self.params.build_beam, lam),
                    metric=self.metric)
                nn = [int(v) for v in ids_l[0] if v >= 0][: lam]
                self.adj[li, :] = -1
                self.adj[li, : len(nn)] = nn
                self.deg[li] = len(nn)
                for v in nn:
                    if self.deg[v] < lam:
                        self.adj[v, self.deg[v]] = li
                        self.deg[v] += 1
                    else:
                        nbrs = self.adj[v, : lam]
                        dd = ((self.vectors[nbrs] - self.vectors[v]) ** 2
                              ).sum(axis=1)
                        worst = int(np.argmax(dd))
                        d_new = float(((vec - self.vectors[v]) ** 2
                                       ).sum())
                        if d_new < float(dd[worst]):
                            self.adj[v, worst] = li
            self.size += 1
            self._local_of[int(gid)] = li
        if self.metrics is not None:
            tgt = getattr(self, "_obs_target", "hot")
            self.metrics.gauge("hot.size", tgt).set(float(self.size))

    def delete(self, gid: int) -> bool:
        """Tombstone a global id if it is hot-resident. Returns whether
        the id was found here (the caller still tombstones the cold
        tier's bitmap either way)."""
        li = self._local_of.get(int(gid))
        if li is None:
            return False
        self.dead[li] = True
        return True


def build_hot_tier(seg, p: HotTierParams = HotTierParams(),
                   metric: Optional[str] = None) -> HotTier:
    """Build the hot tier of a ``Segment`` from the shared hot-set
    ranking: take blocks in ranking order until ``budget_frac`` of the
    segment's vectors are covered (whole blocks — the ranking's unit),
    gather their vectors out of the block store, and build a navigable
    graph over them with the ``core.navgraph`` machinery."""
    view = seg.view
    metric = metric or view.metric
    store, lay = view.store, view.layout
    block_of = np.asarray(lay.block_of)
    n = int(block_of.shape[0])
    ranking = hotset.hot_block_ranking(
        block_of, seg.graph.adj, seg.graph.deg,
        hotset.view_seed_ids(view), hops=p.hops)
    order = hotset.fill_to(ranking, store.num_blocks, store.num_blocks)
    budget = max(int(math.ceil(p.budget_frac * n)), 1)
    hot_ids: List[int] = []
    hot_vecs: List[np.ndarray] = []
    for b in order:
        vid = np.asarray(store.vid[b])
        live = vid >= 0
        hot_ids.extend(int(v) for v in vid[live])
        hot_vecs.append(np.asarray(store.vecs[b])[live])
        if len(hot_ids) >= budget:
            break
    ids = np.asarray(hot_ids, np.int64)
    xs = np.ascontiguousarray(np.concatenate(hot_vecs, axis=0),
                              np.float32)
    nav = NG.subset_navgraph(None, ids, max_degree=p.max_degree,
                             build_beam=p.build_beam, metric=metric,
                             algo="nsg", seed=p.seed, vectors=xs)
    built = ids.shape[0]
    cap = built + int(math.ceil(p.append_slack * built))
    lam = nav.graph.adj.shape[1]
    vectors = np.zeros((cap, xs.shape[1]), np.float32)
    vectors[:built] = nav.vectors
    gids = np.full((cap,), -1, np.int64)
    gids[:built] = ids
    adj = np.full((cap, lam), -1, np.int32)
    adj[:built] = nav.graph.adj
    deg = np.zeros((cap,), np.int32)
    deg[:built] = nav.graph.deg
    return HotTier(vectors=vectors, ids=gids, adj=adj, deg=deg,
                   size=built, base_size=n,
                   dead=np.zeros((cap,), bool), params=p, metric=metric,
                   entry=int(nav.graph.entry),
                   _local_of={int(g): i for i, g in enumerate(ids)})
