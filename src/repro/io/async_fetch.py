"""Event-clock async fetch queue (repro.io.async_fetch).

PR 1 made every fetch synchronous-but-coalesced: a demand miss and its
speculative piggybacks complete instantly, in submission order. The
paper's §5.1 pipeline — and the queue-depth argument of
arXiv:2509.25487 — only pays off when multiple fetches are genuinely
*in flight*: the search ranks the current block while outstanding
fetches complete in whatever order the device finishes them.

``AsyncFetchQueue`` models exactly that with an abstract event clock
(ticks, not microseconds — hardware pricing stays in ``CostModel``):

  * ``submit`` puts a block fetch in flight and returns a
    ``FetchTicket``; completion time is the submit tick plus a fixed
    service window plus a deterministic per-block jitter, so
    completions interleave out of submission order (delivery order is
    reproducible run-to-run, and never affects search *results* — only
    residency timing and counters; see the permutation property test).
  * ``wait(ticket)`` advances the clock to that fetch's completion and
    delivers every fetch completing no later, in completion order.
    Deliveries that overtake an earlier-submitted outstanding fetch are
    counted as ``reorders`` (→ ``IOStats.completion_reorders``).
  * the in-flight table doubles as cross-query dedup: a demand read of
    a block already in flight *joins* the existing ticket instead of
    issuing a new round trip (→ ``IOStats.inflight_joins``), which is
    what the serving plane's shared queue exploits
    (``serving.coordinator.attach_shared_fetch_queue``).

The queue is deliberately payload-free: block bytes live in the host
arrays of ``BlockStore``, so "delivery" means cache admission +
accounting, mirroring how ``BlockCache`` models residency.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

# Abstract event-clock constants. Only *ratios* matter: SERVICE_TICKS is
# one modeled device service window, JITTER_TICKS the spread that makes
# completions interleave, SUBMIT_TICKS the doorbell cost that keeps
# submission order meaningful within a burst.
SERVICE_TICKS = 64.0
JITTER_TICKS = 24.0
SUBMIT_TICKS = 1.0


def default_jitter(block: int, salt: int = 0) -> float:
    """Deterministic per-block completion jitter in [0, JITTER_TICKS)."""
    h = (block * 2654435761 + salt * 40503 + 12345) & 0xFFFFFFFF
    h ^= h >> 16
    return (h % 4096) / 4096.0 * JITTER_TICKS


@dataclasses.dataclass
class FetchTicket:
    block: int
    seq: int                  # submission order
    submitted_at: float
    complete_at: float
    kind: str                 # "demand" | "speculative"
    key: object = None        # in-flight identity: (namespace, block) so
    #                           a shared queue never conflates equal block
    #                           ids of different backing stores
    owner: object = None      # the submitting CachedBlockStore — delivery
    #                           admits into *its* cache, whichever store's
    #                           wait drove the clock past completion
    done: bool = False
    reordered: bool = False   # delivered while an earlier-seq fetch
    #                           was still outstanding

    def residual(self, clock: float) -> float:
        """Remaining service fraction at ``clock`` (join pricing)."""
        if self.done:
            return 0.0
        rem = (self.complete_at - clock) / SERVICE_TICKS
        return min(max(rem, 0.0), 1.0)


class AsyncFetchQueue:
    """Bounded in-flight fetch window with completion-order delivery.

    ``depth`` is the modeled device queue depth: at most ``depth``
    fetches in flight. Speculative submissions are dropped when the
    window is full; demand submissions make room by waiting out the
    earliest completion (a full submission queue blocks the submitter).

    One queue may be shared by many ``CachedBlockStore``s (the serving
    plane shares one per host), so all counters here are lifetime
    totals; per-query shares flow into ``IOStats`` via the stores.
    """

    def __init__(self, depth: int = 8,
                 jitter_fn: Optional[Callable[[int], float]] = None,
                 jitter_salt: int = 0):
        if depth < 1:
            raise ValueError("queue depth must be >= 1")
        self.depth = int(depth)
        self._jitter = (jitter_fn if jitter_fn is not None
                        else lambda b: default_jitter(b, jitter_salt))
        self.clock = 0.0
        self._seq = 0
        self._inflight: Dict[int, FetchTicket] = {}
        self.submitted = 0
        self.delivered = 0
        self.reorders = 0
        self.inflight_peak = 0
        # optional repro.obs tracer: io.fetch_submit / io.fetch_complete
        # instants, None-guarded (the event clock stays in ticks — trace
        # timestamps come from the tracer's own injected clock)
        self.tracer = None

    # -------------------------------------------------------------- state
    def __len__(self) -> int:
        return len(self._inflight)

    @property
    def free_slots(self) -> int:
        return self.depth - len(self._inflight)

    def in_flight(self, b: int, key: object = None) -> bool:
        return (key if key is not None else b) in self._inflight

    def get(self, b: int, key: object = None) -> Optional[FetchTicket]:
        """The in-flight ticket for ``b`` (the cross-query join path).
        ``key`` namespaces the lookup when the queue is shared across
        stores with distinct block-id spaces."""
        return self._inflight.get(key if key is not None else b)

    # ------------------------------------------------------------- submit
    def submit(self, b: int, kind: str = "speculative",
               key: object = None, owner: object = None) -> tuple:
        """Put ``b`` in flight; returns ``(ticket, occupancy)`` where
        occupancy counts this fetch — the ``o`` of the 1/o serial-share
        pricing. ``key`` (default: the block id) is the in-flight
        identity a shared queue dedups on; ``owner`` is the submitting
        store, so delivery admits into its cache no matter whose wait
        consumes the completion. Callers must dedup via ``get``/
        ``in_flight`` first and respect ``free_slots`` (speculative) or
        make room (demand)."""
        key = key if key is not None else b
        if key in self._inflight:
            raise ValueError(f"block {b} already in flight (join it)")
        if len(self._inflight) >= self.depth:
            raise ValueError("fetch queue full — wait out a completion")
        self._seq += 1
        self.clock += SUBMIT_TICKS
        t = FetchTicket(block=b, seq=self._seq, submitted_at=self.clock,
                        complete_at=(self.clock + SERVICE_TICKS
                                     + self._jitter(b)),
                        kind=kind, key=key, owner=owner)
        self._inflight[key] = t
        self.submitted += 1
        occ = len(self._inflight)
        self.inflight_peak = max(self.inflight_peak, occ)
        if self.tracer is not None:
            self.tracer.event("io.fetch_submit", cat="io", track="queue",
                              block=int(b), kind=kind, occupancy=occ)
        return t, occ

    # ------------------------------------------------------------ deliver
    def _pop_completions(self, upto: float) -> List[FetchTicket]:
        ready = sorted((t for t in self._inflight.values()
                        if t.complete_at <= upto),
                       key=lambda t: (t.complete_at, t.seq))
        out: List[FetchTicket] = []
        for t in ready:
            del self._inflight[t.key]
            t.done = True
            self.delivered += 1
            if any(o.seq < t.seq for o in self._inflight.values()):
                t.reordered = True
                self.reorders += 1
            if self.tracer is not None:
                self.tracer.event("io.fetch_complete", cat="io",
                                  track="queue", block=int(t.block),
                                  kind=t.kind, reordered=t.reordered)
            out.append(t)
        return out

    def poll(self) -> List[FetchTicket]:
        """Consume whatever has completed by the current clock."""
        return self._pop_completions(self.clock)

    def wait(self, ticket: FetchTicket) -> List[FetchTicket]:
        """Advance the clock to ``ticket``'s completion; deliver it and
        everything completing no later, in completion order."""
        if ticket.done:
            return []
        self.clock = max(self.clock, ticket.complete_at)
        return self._pop_completions(self.clock)

    def wait_any(self) -> List[FetchTicket]:
        """Wait out the earliest outstanding completion (make room)."""
        if not self._inflight:
            return []
        first = min(self._inflight.values(),
                    key=lambda t: (t.complete_at, t.seq))
        return self.wait(first)

    def drain(self) -> List[FetchTicket]:
        """Deliver every outstanding fetch (shutdown / test epilogue)."""
        out: List[FetchTicket] = []
        while self._inflight:
            out.extend(self.wait_any())
        return out
