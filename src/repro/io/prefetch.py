"""Speculative batched prefetch for the block search (repro.io).

Starling's beam expands candidates in ascending key order, so the blocks
of the *top unvisited* entries of the candidate set C are — with high
probability — the very next demand reads. ``PrefetchEngine`` exploits
that: on each demand read it walks C front-to-back and collects up to
``width`` distinct non-resident blocks of unvisited candidates. On the
synchronous path they coalesce with the demand fetch into a single
batched I/O round trip (one NVMe queue submission / one strided HBM
DMA), priced at ``t_batch_block`` ≪ ``t_block_io`` — the page-aligned
batching argument of arXiv:2509.25487. On the async path
(``AsyncFetchQueue`` attached to the store) they are submitted as
in-flight fetches that overlap the demand service window, priced by
queue occupancy.

A block is never speculatively fetched twice: the engine keeps an
``issued`` set and skips anything already cache-resident (either tier)
or already in flight on the store's queue. The engine is constructed
per query inside ``block_search_query`` — that construction *is* the
per-query reset, which is why there is no ``begin_query`` method;
cross-query dedup is the job of the shared cache and fetch queue, not
of this engine.
"""
from __future__ import annotations

from typing import List, Optional, Set

import numpy as np

from repro.io.cached_store import CachedBlockStore


class PrefetchEngine:
    """Per-query speculative fetcher bound to one ``CachedBlockStore``.

    ``cand`` ducks as the search's ``_CandidateSet``: ordered parallel
    lists ``ids``/``visited`` sorted ascending by key.
    """

    def __init__(self, store: CachedBlockStore, block_of: np.ndarray,
                 width: Optional[int] = None):
        self.store = store
        self.block_of = block_of
        self.width = store.prefetch_width if width is None else int(width)
        self.issued: Set[int] = set()

    def targets(self, cand, exclude: Optional[int] = None) -> List[int]:
        """Blocks of the top-``width`` unvisited candidates that are
        neither resident, nor in flight, nor already speculatively
        fetched this query, nor the demand block itself."""
        if self.width <= 0:
            return []
        queue = self.store.queue
        width = self.width
        if queue is not None:
            # never mark more targets issued than the queue can take
            # (one slot reserved for the demand fetch itself)
            width = min(width, max(queue.free_slots - 1, 0))
        out: List[int] = []
        for i in range(len(cand.ids)):
            if len(out) >= width:
                break
            if cand.visited[i]:
                continue
            b = int(self.block_of[cand.ids[i]])
            if (b == exclude or b in self.issued or b in out
                    or b in self.store.cache
                    or (queue is not None
                        and queue.in_flight(b, key=self.store._key(b)))):
                continue
            out.append(b)
        self.issued.update(out)
        return out

    def read(self, b: int, cand, stats) -> tuple:
        """Demand-read ``b``, piggybacking speculative targets from
        ``cand`` — coalesced into the same round trip (sync) or put in
        flight ahead of the demand wait (async)."""
        return self.store.read_demand(b, stats,
                                      prefetch=self.targets(cand, b))
