"""Speculative batched prefetch for the block search (repro.io).

Starling's beam expands candidates in ascending key order, so the blocks
of the *top unvisited* entries of the candidate set C are — with high
probability — the very next demand reads. ``PrefetchEngine`` exploits
that: on each demand read it walks C front-to-back, collects up to
``width`` distinct non-resident blocks of unvisited candidates, and
coalesces them with the demand fetch into a single batched I/O round
trip (one NVMe queue submission / one strided HBM DMA). The cost model
prices the extras at ``t_batch_block`` ≪ ``t_block_io``, which is the
page-aligned-batching argument of arXiv:2509.25487.

A block is never speculatively fetched twice: the engine keeps a
per-query ``issued`` set and also skips anything already cache-resident.
"""
from __future__ import annotations

from typing import List, Optional, Set

import numpy as np

from repro.io.cached_store import CachedBlockStore


class PrefetchEngine:
    """Per-query speculative fetcher bound to one ``CachedBlockStore``.

    ``cand`` ducks as the search's ``_CandidateSet``: ordered parallel
    lists ``ids``/``visited`` sorted ascending by key.
    """

    def __init__(self, store: CachedBlockStore, block_of: np.ndarray,
                 width: Optional[int] = None):
        self.store = store
        self.block_of = block_of
        self.width = store.prefetch_width if width is None else int(width)
        self.issued: Set[int] = set()

    def begin_query(self) -> None:
        self.issued.clear()

    def targets(self, cand, exclude: Optional[int] = None) -> List[int]:
        """Blocks of the top-``width`` unvisited candidates that are
        neither resident, nor already speculatively fetched this query,
        nor the demand block itself."""
        if self.width <= 0:
            return []
        out: List[int] = []
        for i in range(len(cand.ids)):
            if len(out) >= self.width:
                break
            if cand.visited[i]:
                continue
            b = int(self.block_of[cand.ids[i]])
            if (b == exclude or b in self.issued or b in out
                    or b in self.store.cache):
                continue
            out.append(b)
        self.issued.update(out)
        return out

    def read(self, b: int, cand, stats) -> tuple:
        """Demand-read ``b``, piggybacking speculative targets from
        ``cand`` onto the same round trip."""
        return self.store.read_demand(b, stats,
                                      prefetch=self.targets(cand, b))
