"""repro.io — block-cache + batched-prefetch I/O subsystem.

Starling's segment cost model (Eq. 4) is I/O-bound: T_io = #I/Os ×
t_block_io dominates on NVMe. This package attacks #effective-I/Os at
*unchanged recall* — caching and batching never change which blocks the
search reads, only what each read costs:

  * ``BlockCache`` (``cache.py``) — a byte-budgeted resident set of
    block ids with LRU/LFU eviction and static pinning of the
    build-time hot set around the navigation-graph entry neighborhood.
    Its capacity is *memory*, so it is charged as a fourth term of the
    Eq. 10 segment memory budget (C_graph + C_mapping + C_PQ&others +
    C_cache) — see ``SegmentParams.cache`` and ``Segment.memory_bytes``.
  * ``CachedBlockStore`` (``cached_store.py``) — drop-in for
    ``BlockStore.read_block`` that accounts ``cache_hits`` /
    ``cache_misses`` / ``io_round_trips`` into ``IOStats``.
  * ``PrefetchEngine`` (``prefetch.py``) — speculatively fetches the
    blocks of the top unvisited candidates and coalesces them with the
    demand miss into one batched round trip.

The serving plane shares one ``CachedBlockStore`` per segment server
across queries (``serving.coordinator.HostSegmentServer``), which is
where the hit rate actually comes from: inter-query locality on the
entry neighborhood and cluster-hot blocks.
"""
from repro.io.cache import (BlockCache, EvictionPolicy, LFUPolicy,
                            LRUPolicy, hot_block_pin_set)
from repro.io.cached_store import (CachedBlockStore, cached_view,
                                   make_cached_store)
from repro.io.prefetch import PrefetchEngine

__all__ = [
    "BlockCache", "EvictionPolicy", "LRUPolicy", "LFUPolicy",
    "hot_block_pin_set", "CachedBlockStore", "cached_view",
    "make_cached_store", "PrefetchEngine",
]
