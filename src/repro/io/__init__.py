"""repro.io — block-cache + async batched-prefetch I/O subsystem.

Starling's segment cost model (Eq. 4) is I/O-bound: T_io = #I/Os ×
t_block_io dominates on NVMe. This package attacks #effective-I/Os at
*unchanged recall* — caching, batching and async overlap never change
which blocks the search reads, only what each read costs:

  * ``BlockCache`` (``cache.py``) — a byte-budgeted resident set of
    block ids with LRU/LFU eviction and static pinning of the
    build-time hot set around the navigation-graph entry neighborhood.
    Its capacity is *memory*, so it is charged as a fourth term of the
    Eq. 10 segment memory budget (C_graph + C_mapping + C_PQ&others +
    C_cache) — see ``SegmentParams.cache`` and ``Segment.memory_bytes``.
  * ``TieredBlockCache`` (``cache.py``) — tier 1 full η-KB blocks over
    tier 2 compressed PQ-space block summaries at ~1/16 the bytes
    (GoVector-style): a tier-2 hit re-ranks without a disk trip, so
    tight budgets keep far more of the segment reachable from memory.
  * ``CachedBlockStore`` (``cached_store.py``) — drop-in for
    ``BlockStore.read_block`` that accounts ``cache_hits`` /
    ``tier2_hits`` / ``cache_misses`` / ``io_round_trips`` into
    ``IOStats``.
  * ``hotset`` — the tier-shared build-time hot-set ranking (traversal
    frequency around the navigation-graph entry neighborhood): host
    tier-1 pinning and the device tier-0 VMEM hot-tile pack
    (``core.device_search.from_segment``) both select prefixes of this
    one ranking, so the whole hierarchy agrees on what "hot" means and
    budget sweeps are monotone by construction.
  * ``PrefetchEngine`` (``prefetch.py``) — speculatively fetches the
    blocks of the top unvisited candidates: coalesced into the demand
    round trip (sync) or put in flight ahead of the demand wait
    (async).
  * ``AsyncFetchQueue`` (``async_fetch.py``) — event-clock model of
    in-flight fetches with completion-order delivery: submissions
    return tickets, the search overlaps ranking with outstanding
    fetches and consumes completions out of submission order
    (``IOStats.completion_reorders``), and demand reads of blocks
    already in flight join the existing ticket
    (``IOStats.inflight_joins``) — the cross-query dedup the serving
    plane's shared queue provides.

The serving plane shares one ``CachedBlockStore`` per segment server
across queries (``serving.coordinator.HostSegmentServer``) and may
share one ``AsyncFetchQueue`` across servers
(``serving.coordinator.attach_shared_fetch_queue``), which is where
the hit rate and the in-flight dedup actually come from: inter-query
locality on the entry neighborhood and cluster-hot blocks.
"""
from repro.io.async_fetch import AsyncFetchQueue, FetchTicket
from repro.io.cache import (BlockCache, EvictionPolicy, LFUPolicy,
                            LRUPolicy, TieredBlockCache)
from repro.io.cached_store import (CachedBlockStore, cached_view,
                                   make_cached_store)
from repro.io.hotset import (fill_to, hot_block_pin_set,
                             hot_block_ranking, repack_from_frequencies,
                             view_seed_ids)
from repro.io.prefetch import PrefetchEngine

__all__ = [
    "AsyncFetchQueue", "FetchTicket",
    "BlockCache", "TieredBlockCache", "EvictionPolicy", "LRUPolicy",
    "LFUPolicy", "hot_block_pin_set", "hot_block_ranking", "fill_to",
    "repack_from_frequencies", "view_seed_ids", "CachedBlockStore",
    "cached_view", "make_cached_store", "PrefetchEngine",
]
