"""Shared build-time hot-set selection for every cache tier (repro.io).

One ranking feeds the whole hierarchy (DESIGN.md §3): blocks are scored
by traversal frequency around the navigation-graph entry neighborhood —
the seeds queries enter through (the μ-sample, or the medoid when
navigation is off) and their disk-graph neighbors, seeds weighted above
neighbors, since every search's first expansions land there (Fig. 10).

Consumers:

  * host tier 1 — ``cached_store.make_cached_store`` pins the top
    ``pin_fraction`` of its DRAM budget (``hot_block_pin_set``);
  * device tier 0 — ``device_search.from_segment`` packs the top
    ``tier0`` budget of blocks into the VMEM-resident hot-tile store
    (``hot_block_ranking`` + id-order fill, so growing budgets select
    strictly nested sets and the modeled DMA cut is monotone).

The selection is *build-time* and static: loading the hot set is a
warm-up cost, not query-time I/O, and its bytes are reserved memory
charged against the Eq. 10 segment budget. For workloads that drift
away from the build-time prior, ``repack_from_frequencies`` re-ranks
the same family from *observed* per-block demand counts (e.g. a
serving ``CachedBlockStore.block_freq``) — dynamic tier-0/tier-1
admission as a periodic repack rather than per-query churn.
"""
from __future__ import annotations

from collections import Counter
from typing import AbstractSet, List, Mapping, Optional, Sequence

import numpy as np


def hot_block_ranking(block_of: np.ndarray, adj: np.ndarray,
                      deg: np.ndarray, seed_ids: Sequence[int],
                      hops: int = 1) -> List[int]:
    """All touched blocks, most-traversed first.

    BFS out ``hops`` levels from ``seed_ids`` over the disk graph,
    counting each visited vertex's block with weight ``2^(hops-level)``
    (seeds dominate, fringe counts least). One visited set is carried
    across levels, so each vertex is counted exactly once at its first
    (heaviest) level — on cyclic graphs a per-level set would revisit
    earlier-level vertices and double-count their blocks at lower
    weight. Only blocks actually touched appear; callers needing a
    fixed-size set fill the tail themselves (see ``fill_to``).
    """
    if len(seed_ids) == 0:
        return []
    counts: Counter = Counter()
    frontier = [int(v) for v in seed_ids]
    seen = set(frontier)
    weight = 1 << hops
    for _ in range(hops + 1):
        for v in frontier:
            counts[int(block_of[v])] += weight
        if weight == 1:
            break
        nxt: List[int] = []
        for v in frontier:
            for w in adj[v, : deg[v]]:
                w = int(w)
                if w >= 0 and w not in seen:
                    seen.add(w)
                    nxt.append(w)
        frontier = nxt
        weight >>= 1
    return [b for b, _ in counts.most_common()]


def hot_block_pin_set(block_of: np.ndarray, adj: np.ndarray,
                      deg: np.ndarray,
                      seed_ids: Sequence[int],
                      max_blocks: int,
                      hops: int = 1) -> List[int]:
    """Top ``max_blocks`` of the shared ranking (the tier-1 pin set)."""
    if max_blocks <= 0:
        return []
    return hot_block_ranking(block_of, adj, deg, seed_ids, hops)[
        :max_blocks]


def repack_from_frequencies(ranking: Sequence[int],
                            observed: Mapping[int, int]) -> List[int]:
    """Re-rank a build-time hot-block ranking by observed traffic.

    ``observed`` maps block id -> demand-read count from a live query
    stream (``CachedBlockStore.block_freq``). Blocks actually touched
    sort first by descending count — ties broken by build-ranking
    position (then id, for blocks the build ranking never scored) —
    followed by the untouched remainder of the build ranking in its
    original order. With no observations this is the identity, so a
    cold repack never degrades the build-time selection; feeding the
    result to ``fill_to`` keeps budget sweeps prefix-nested exactly as
    before."""
    pos = {int(b): i for i, b in enumerate(ranking)}
    far = len(pos)
    seen = [int(b) for b, c in observed.items() if c > 0]
    seen.sort(key=lambda b: (-int(observed[b]), pos.get(b, far), b))
    hot = set(seen)
    return seen + [b for b in ranking if int(b) not in hot]


def plan_tier0(ranking: Sequence[int], observed: Mapping[int, int],
               num_blocks: int, total_blocks: int,
               min_observed: int = 1) -> List[int]:
    """The tier-0 pack a repack WOULD select, without building arrays.

    This is the planning half of dynamic admission: re-rank the
    build-time ``ranking`` by ``observed`` demand counts (entries below
    ``min_observed`` are noise-floored out) and fill to the budget —
    exactly the selection ``device_search._tier0_pack`` materializes,
    so the serving scheduler can price a repack's drift before paying
    for one (its hysteresis gate compares this plan against the live
    pack via ``pack_drift``). Observed ids outside
    ``[0, total_blocks)`` are stale demand (a compaction shrank the
    layout since the window was collected) and are dropped before
    re-ranking."""
    obs = {int(b): c for b, c in observed.items()
           if c >= min_observed and 0 <= int(b) < int(total_blocks)}
    if obs:
        ranking = repack_from_frequencies(ranking, obs)
    return fill_to(ranking, num_blocks, total_blocks)


def pack_drift(current: AbstractSet, planned: Sequence[int]) -> float:
    """Fraction of pack slots a repack would change — the hysteresis
    signal of the serving scheduler.

    For the equal-budget repacks the scheduler performs this is
    ``|planned - current| / |pack|``; the max() form also registers
    growing/shrinking plans. 0.0 means the plan IS the live pack (the
    no-op-repack-is-free invariant); 1.0 a full replacement."""
    planned_set = set(int(b) for b in planned)
    denom = max(len(current), len(planned_set))
    if denom == 0:
        return 0.0
    return max(len(planned_set - current),
               len(set(current) - planned_set)) / denom


def fill_to(ranking: Sequence[int], num_blocks: int,
            total_blocks: int) -> List[int]:
    """Extend ``ranking`` to ``num_blocks`` distinct block ids with the
    untouched remainder in id order (capped at ``total_blocks``).

    The result is a *prefix-nested* family: any larger budget's set
    strictly contains any smaller one, which makes budget sweeps
    monotone by construction (a hot block never turns cold as the
    budget grows). Ids outside ``[0, total_blocks)`` — stale demand for
    blocks a compaction removed — are filtered out before slicing, so
    the pack plan never indexes past the live layout."""
    total_blocks = int(total_blocks)
    num_blocks = min(int(num_blocks), total_blocks)
    if num_blocks <= 0:
        return []
    out: List[int] = []
    chosen = set()
    for b in ranking:
        b = int(b)
        if 0 <= b < total_blocks and b not in chosen:
            out.append(b)
            chosen.add(b)
            if len(out) == num_blocks:
                return out
    for b in range(total_blocks):
        if b not in chosen:
            out.append(b)
            if len(out) == num_blocks:
                break
    return out


def view_seed_ids(view) -> np.ndarray:
    """The entry seeds of a ``SegmentView``: the navigation-graph
    μ-sample when navigation is on, else the static entry (medoid) —
    the same seeds for every tier, so host pinning and the device pack
    agree on what "hot" means."""
    if getattr(view, "nav", None) is not None:
        return np.asarray(view.nav.sample_ids)
    return np.asarray([view.entry], np.int64)
