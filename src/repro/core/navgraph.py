"""In-memory navigation graph (§4.2).

Sample μ·N vertices, build a graph index over the sample with the *same*
algorithm family as the disk graph, and answer "give me entry points near q"
without any disk I/O. Returned ids are in the *full dataset* id space.

For the HNSW variant the upper layers of the disk HNSW play this role
(multi-layered navigation, Fig. 16(b)) — see ``from_hnsw_layers``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core import distances as D
from repro.core import graph as G
from repro.core.params import GraphParams, NavGraphParams


@dataclasses.dataclass
class NavGraph:
    graph: G.Graph
    sample_ids: np.ndarray      # [n'] global ids of sampled vertices
    vectors: np.ndarray         # [n', D] resident copies (the memory charge)

    def memory_bytes(self) -> int:
        """C_graph of Eq. 10: resident vectors + adjacency + degree."""
        return (self.vectors.nbytes + self.graph.adj.nbytes
                + self.graph.deg.nbytes + self.sample_ids.nbytes)

    def entry_points(self, queries: np.ndarray, beam: int,
                     num: int) -> np.ndarray:
        """[Q, num] global entry-point ids (query-aware, no disk I/O)."""
        ids, _, _ = G.greedy_search_batch(
            self.vectors, self.graph.adj, self.graph.deg, self.graph.entry,
            queries, beam=max(beam, num), metric=self.graph.metric)
        picked = ids[:, :num]
        picked = np.where(picked >= 0, picked, 0)
        return self.sample_ids[picked.astype(np.int64)]


def build_navgraph(x: np.ndarray, p: NavGraphParams, metric: str = "l2",
                   algo: str = "vamana") -> NavGraph:
    n = x.shape[0]
    rng = np.random.default_rng(p.seed)
    n_s = max(int(round(p.sample_ratio * n)), min(n, 8))
    ids = np.sort(rng.choice(n, size=n_s, replace=False)).astype(np.int32)
    sub = np.ascontiguousarray(x[ids], dtype=np.float32)
    gp = GraphParams(max_degree=p.max_degree,
                     build_beam=max(p.build_beam, p.max_degree),
                     algo=algo, seed=p.seed)
    g = G.build_graph(sub, gp, metric)
    return NavGraph(graph=g, sample_ids=ids, vectors=sub)


def subset_navgraph(x: Optional[np.ndarray], ids: np.ndarray,
                    max_degree: int, build_beam: int,
                    metric: str = "l2", algo: str = "nsg",
                    seed: int = 1,
                    vectors: Optional[np.ndarray] = None) -> NavGraph:
    """Build a ``NavGraph`` over an *explicit* vertex subset.

    Same machinery as ``build_navgraph`` but the caller chooses which
    global ids are resident instead of a uniform μ-sample — the hot
    tier (``repro.io.hottier``) passes the hot-set members selected by
    the shared ``repro.io.hotset`` ranking, so the in-memory answering
    graph covers exactly the vertices the block tiers already call hot.
    Pass the already-gathered ``vectors`` [len(ids), D] when no flat
    ``x`` exists (e.g. gathering out of a ``BlockStore``).
    """
    ids = np.asarray(ids, np.int64)
    sub = (np.ascontiguousarray(vectors, dtype=np.float32)
           if vectors is not None
           else np.ascontiguousarray(x[ids], dtype=np.float32))
    gp = GraphParams(max_degree=max_degree,
                     build_beam=max(build_beam, max_degree),
                     algo=algo, seed=seed)
    g = G.build_graph(sub, gp, metric)
    return NavGraph(graph=g, sample_ids=ids.astype(np.int32),
                    vectors=sub)


def from_hnsw_layers(x: np.ndarray, h: G.HNSWGraph,
                     p: NavGraphParams) -> NavGraph:
    """Starling-HNSW: upper layers stay in memory as the navigation
    structure. We flatten layers 1.. into one sampled graph (union of
    level-1+ vertices with the level-1 adjacency)."""
    if len(h.layers) < 2:
        # degenerate: no upper layer; sample instead
        return build_navgraph(x, p, h.metric, algo="nsg")
    ids = h.level_ids[1]
    g = h.layers[1]
    return NavGraph(graph=g, sample_ids=ids.astype(np.int32),
                    vectors=np.ascontiguousarray(x[ids], np.float32))
