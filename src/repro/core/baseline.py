"""DiskANN-style baseline framework (§2.2, §3.1, App. B).

Differences vs Starling, all reproduced here:
  * layout: ID-contiguous vertices per block (``layout_sequential``);
  * search: vertex-at-a-time — each hop reads the target's block and uses
    *only the target vertex* (ξ = 1/ε, Tab. 2);
  * entry point: fixed medoid (no query-aware navigation graph);
  * memory: optional *hot-vertex cache* (BFS-radius around the medoid, as in
    DiskANN's C_hot) — cached targets cost no I/O;
  * PQ routing: same as Starling (DiskANN introduced it).

Range search for the baseline is repeated-ANNS with doubling k (§6.2
"RS support is provided by calling ANNS iteratively on DiskANN").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core import distances as D
from repro.core.blockstore import BlockStore
from repro.core.iostats import IOStats
from repro.core.layout import BlockLayout
from repro.core.params import SearchParams
from repro.core.search import SegmentView, _CandidateSet, SearchResult
from repro.pq import adc_lut, adc_distance


def build_hot_cache(seg: SegmentView, ratio: float = 0.05) -> Dict[int, None]:
    """BFS from the medoid until ratio·N vertices are cached (C_hot)."""
    store, layout = seg.store, seg.layout
    n = layout.block_of.shape[0]
    budget = int(ratio * n)
    cache: Dict[int, None] = {}
    frontier = [seg.entry]
    seen = {seg.entry}
    while frontier and len(cache) < budget:
        nxt: List[int] = []
        for u in frontier:
            if len(cache) >= budget:
                break
            cache[u] = None
            b = int(layout.block_of[u])
            vids, _, degs, nbrs = store.read_block(b)
            s = int(layout.slot_of[u])
            for v in nbrs[s, : degs[s]]:
                v = int(v)
                if v >= 0 and v not in seen:
                    seen.add(v)
                    nxt.append(v)
        frontier = nxt
    return cache


def vertex_search_query(seg: SegmentView, q: np.ndarray, k: int,
                        p: SearchParams,
                        hot: Optional[Dict[int, None]] = None
                        ) -> SearchResult:
    """DiskANN beam search: PQ-keyed candidates, one block read per visited
    vertex, only the target consumed from each block."""
    store, layout = seg.store, seg.layout
    stats = IOStats()
    lut = adc_lut(q, seg.pq_cb)

    def route(ids: List[int]) -> np.ndarray:
        stats.pq_comps += len(ids)
        return adc_distance(lut, seg.pq_codes[np.asarray(ids, np.int64)])

    C = _CandidateSet(p.candidate_size)
    R: Dict[int, float] = {}
    d0 = route([seg.entry])
    C.push(float(d0[0]), seg.entry)

    while True:
        i = C.top_unvisited()
        if i is None:
            break
        u = C.ids[i]
        C.visited[i] = True
        stats.hops += 1

        bid = int(layout.block_of[u])
        slot = int(layout.slot_of[u])
        if hot is not None and u in hot:
            vids, vecs, degs, nbrs = store.read_block(bid)  # from memory
        else:
            vids, vecs, degs, nbrs = store.read_block(bid)  # DR
            stats.block_reads += 1
            stats.vertices_fetched += int((vids >= 0).sum())
            stats.vertices_used += 1
        # DC: only the target vertex is consumed (Problem 1)
        dd = D.point_to_points(q, vecs[slot][None, :], seg.metric)[0]
        stats.dist_comps += 1
        best_before = min(R.values()) if R else np.inf
        R.setdefault(u, float(dd))
        if float(dd) < best_before:
            stats.hops_to_best = stats.hops

        new_ids = [int(v) for v in nbrs[slot, : degs[slot]]
                   if int(v) >= 0 and int(v) not in C.member
                   and int(v) not in R]
        if new_ids:
            for v, nd in zip(new_ids, route(new_ids)):
                C.push(float(nd), v)
        if stats.hops >= p.max_hops:
            break

    items = sorted(R.items(), key=lambda kv: kv[1])[:k]
    return SearchResult(
        ids=np.asarray([i_ for i_, _ in items], np.int64),
        dists=np.asarray([d_ for _, d_ in items], np.float32),
        stats=stats)


def vertex_anns(seg: SegmentView, queries: np.ndarray, k: int,
                p: SearchParams, hot: Optional[Dict[int, None]] = None):
    Q = queries.shape[0]
    ids = np.full((Q, k), -1, np.int64)
    dd = np.full((Q, k), np.inf, np.float32)
    stats: List[IOStats] = []
    for qi in range(Q):
        r = vertex_search_query(seg, queries[qi], k, p, hot)
        m = r.ids.shape[0]
        ids[qi, :m] = r.ids
        dd[qi, :m] = r.dists
        stats.append(r.stats)
    return ids, dd, stats


def vertex_range_search_query(seg: SegmentView, q: np.ndarray, radius: float,
                              p: SearchParams,
                              hot: Optional[Dict[int, None]] = None,
                              max_rounds: int = 6) -> SearchResult:
    """Baseline RS: repeated ANNS with doubling k — revisits the same
    vertices every round (the inefficiency §5.3 calls out)."""
    stats = IOStats()
    k = max(p.candidate_size // 2, 10)
    last: Optional[SearchResult] = None
    for _ in range(max_rounds):
        pp = dataclasses.replace(p, candidate_size=max(p.candidate_size, k))
        r = vertex_search_query(seg, q, k, pp, hot)
        stats.merge(r.stats)
        in_range = r.dists <= radius
        last = SearchResult(ids=r.ids[in_range], dists=r.dists[in_range],
                            stats=stats)
        if in_range.sum() < k:      # found the boundary
            break
        k *= 2
    return SearchResult(ids=last.ids, dists=last.dists, stats=stats)


def vertex_range_search(seg: SegmentView, queries: np.ndarray, radius: float,
                        p: SearchParams,
                        hot: Optional[Dict[int, None]] = None):
    out, stats = [], []
    for qi in range(queries.shape[0]):
        r = vertex_range_search_query(seg, queries[qi], radius, p, hot)
        out.append(r.ids)
        stats.append(r.stats)
    return out, stats
