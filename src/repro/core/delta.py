"""repro.core.delta — the mutable delta segment (DESIGN.md §10).

A ``DeltaSegment`` wraps an immutable disk ``Segment`` with the two
mutable structures the hybrid tier provides:

  * the **hot tier** (``repro.io.hottier``): an in-memory answering
    graph over the hot set whose append region absorbs inserts, and
  * a **tombstone bitmap** over the base id space; deletes mark it and
    are masked out of both tiers at query time.

Queries run hot-first: the hot graph converges at memory cost, the
block search is seeded from its exit frontier (the ``seeds`` override
of ``core.search.anns``), and the two result sets merge by
``(dist, id)`` with dedup — identical ordering to the serving-plane
merges. The memory work lands in ``IOStats.hot_tier_hits``.

``compact()`` folds everything back to disk: gather the live vectors
(base minus tombstones, plus live appends), rebuild through the full
``core.segment.build_segment`` pipeline (graph, ``core/layout``
reordering, nav graph, PQ) and return a fresh ``Segment``. A compaction
of a delta whose live set equals some vector set X is bit-identical to
``build_segment(X, params)`` directly — there is no incremental
layout patching to drift from the offline build.

``swap_into_host_server`` / ``swap_into_device_server`` install the
compacted segment under a serving target and notify the
``RepackScheduler`` (``note_layout_swap``) so demand windows drop
entries for blocks that no longer exist.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.iostats import IOStats
from repro.core.params import HotTierParams, SearchParams
from repro.core.search import _entry_points, anns
from repro.core.segment import Segment, build_segment
from repro.io.hottier import HotTier, build_hot_tier, merge_hot_cold


@dataclasses.dataclass
class DeltaSegment:
    """An immutable base ``Segment`` + the hot tier's mutable delta.

    Global ids: ``[0, base_n)`` are the base segment's vertices;
    appended vectors take ids from ``base_n`` upward and exist only in
    the hot tier until a compaction."""
    base: Segment
    hot: HotTier
    tomb: np.ndarray              # [base_n] bool — deleted base ids
    appended: List[Tuple[int, np.ndarray]]  # (gid, vec) in insert order
    next_gid: int

    @classmethod
    def wrap(cls, seg: Segment, p: HotTierParams = HotTierParams(),
             metric: Optional[str] = None) -> "DeltaSegment":
        hot = build_hot_tier(seg, p, metric=metric)
        n = seg.num_vectors
        return cls(base=seg, hot=hot, tomb=np.zeros(n, bool),
                   appended=[], next_gid=n)

    # ----------------------------------------------------------- census

    @property
    def base_n(self) -> int:
        return int(self.tomb.shape[0])

    @property
    def num_deleted(self) -> int:
        return int(self.tomb.sum()) + sum(
            1 for gid, _ in self.appended if self._append_dead(gid))

    @property
    def live_count(self) -> int:
        return self.base_n + len(self.appended) - self.num_deleted

    def _append_dead(self, gid: int) -> bool:
        li = self.hot._local_of.get(int(gid))
        return li is None or bool(self.hot.dead[li])

    # ------------------------------------------------------- mutability

    def insert(self, vecs: np.ndarray) -> np.ndarray:
        """Append vectors; returns their new global ids. They are
        immediately searchable through the hot route (the cold tier
        does not know them until ``compact``)."""
        vecs = np.atleast_2d(np.asarray(vecs, np.float32))
        gids = np.arange(self.next_gid, self.next_gid + vecs.shape[0],
                         dtype=np.int64)
        self.hot.insert(vecs, gids)
        self.appended.extend(
            (int(g), np.array(v, np.float32)) for g, v in zip(gids, vecs))
        self.next_gid += vecs.shape[0]
        return gids

    def delete(self, gid: int) -> bool:
        """Tombstone a global id in both tiers. Returns False if the
        id does not exist (never assigned, or already deleted)."""
        gid = int(gid)
        if gid < 0 or gid >= self.next_gid:
            return False
        if gid < self.base_n:
            if self.tomb[gid]:
                return False
            self.tomb[gid] = True
            self.hot.delete(gid)   # may or may not be hot-resident
            return True
        # appended: lives only in the hot tier
        if self._append_dead(gid):
            return False
        return self.hot.delete(gid)

    # ------------------------------------------------------ compaction

    def live_vectors(self) -> Tuple[np.ndarray, np.ndarray]:
        """(x_live [M, D], gids_live [M]) — surviving base vectors in
        global-id order, then live appends in insert order. The base
        vectors are reconstructed from the block store (the store is
        the durable copy; there is no flat x to leak from build time)."""
        store = self.base.view.store
        vid = np.asarray(store.vid).reshape(-1)
        vecs = np.asarray(store.vecs)
        dim = vecs.shape[2]
        x = np.zeros((self.base_n, dim), np.float32)
        flat = vecs.reshape(-1, dim)
        valid = vid >= 0
        x[vid[valid]] = flat[valid]
        keep = np.flatnonzero(~self.tomb)
        xs = [x[keep]]
        gids = [keep.astype(np.int64)]
        for gid, vec in self.appended:
            if not self._append_dead(gid):
                xs.append(vec[None, :])
                gids.append(np.asarray([gid], np.int64))
        return (np.ascontiguousarray(np.concatenate(xs, axis=0),
                                     np.float32),
                np.concatenate(gids))

    def compact(self) -> Tuple[Segment, np.ndarray]:
        """Fold the delta back to disk: rebuild the full segment
        pipeline (graph, block shuffle via ``core/layout``, nav, PQ)
        over the live vectors. Returns ``(segment, gids)`` where
        ``gids[i]`` is the pre-compaction global id of the new
        segment's vertex ``i`` — bit-identical to
        ``build_segment(x_live, base.params)``."""
        x_live, gids = self.live_vectors()
        return build_segment(x_live, self.base.params), gids

    # ----------------------------------------------------------- search

    def search(self, queries: np.ndarray, k: int, p: SearchParams
               ) -> Tuple[np.ndarray, np.ndarray, List[IOStats]]:
        """Hybrid hot-first ANNS over the host block path.

        The hot route answers from memory; the block search is seeded
        from its exit frontier UNIONED with the nav entry points (the
        exits start the beam where memory converged, the nav entries
        keep the basin diversity a biased hot set would lose) and runs
        a ``cold_gamma_frac``-narrowed candidate beam — the hot tier
        already did the early exploration, so equal recall costs
        strictly fewer block reads. Results merge by ``(dist, id)``
        with tombstones masked from both sides; per-query stats carry
        the memory work in ``hot_tier_hits`` on top of the block
        search's I/O columns."""
        queries = np.ascontiguousarray(queries, np.float32)
        route = self.hot.route(queries, k)
        nav_seeds = np.stack([_entry_points(self.base.view, q, p)
                              for q in queries]).astype(np.int64)
        seeds = np.concatenate(
            [route.exits.astype(np.int64), nav_seeds], axis=1)
        # over-fetch so the cold top-k survives the tombstone mask
        k_cold = k + min(self.num_deleted, k)
        gamma = max(k_cold, int(round(
            p.candidate_size * self.hot.params.cold_gamma_frac)))
        p_cold = dataclasses.replace(p, candidate_size=gamma)
        ids_c, dists_c, stats = anns(self.base.view, queries, k_cold,
                                     p_cold, seeds=seeds)
        qn = queries.shape[0]
        out_i = np.full((qn, k), -1, np.int64)
        out_d = np.full((qn, k), np.inf, np.float32)
        for qi in range(qn):
            ci = ids_c[qi].astype(np.int64)
            cd = dists_c[qi].astype(np.float32)
            dead = (ci >= 0) & self.tomb[np.clip(ci, 0, self.base_n - 1)]
            ci = np.where(dead, -1, ci)
            cd = np.where(dead, np.inf, cd)
            out_i[qi], out_d[qi] = merge_hot_cold(
                k, route.ids[qi], route.dists[qi], ci, cd)
            stats[qi].hot_tier_hits += int(route.hot_hits[qi])
        return out_i, out_d, stats


# ------------------------------------------------- serving swap helpers

def swap_into_host_server(server, new_seg: Segment,
                          scheduler=None) -> None:
    """Install a compacted segment under a ``HostSegmentServer`` and
    drop scheduler state keyed to the old layout (demand-window
    entries for blocks past the new layout's end, the per-target
    ranking, batch-stat watermarks)."""
    server.view = new_seg.view
    server.params = new_seg.params.search
    server.num_vectors = new_seg.num_vectors
    if scheduler is not None:
        scheduler.note_layout_swap(server)


def swap_into_device_server(server, new_seg: Segment, scheduler=None,
                            **from_segment_kwargs) -> None:
    """Install a compacted segment under a device ``SegmentServer``:
    re-pack the device arrays from the new segment (same tier-0
    budget semantics as the original ``from_segment`` call via
    ``from_segment_kwargs``) and invalidate scheduler windows."""
    from repro.core import device_search as DS
    server.segment = DS.from_segment(new_seg, **from_segment_kwargs)
    server.host = new_seg
    server.num_vectors = new_seg.num_vectors
    if scheduler is not None:
        scheduler.note_layout_swap(server)
