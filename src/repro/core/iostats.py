"""I/O accounting and the latency cost model (Eq. 4).

Everything the paper measures flows through this module:

  * ``IOStats`` — per-query counters: block reads (mean I/Os), vertices
    fetched vs vertices used (vertex-utilization ξ, Tab. 2), hops (path
    length ℓ), distance computations, cache-tier hits, and the async
    fetch-queue counters (``inflight_peak``, ``tier2_hits``,
    ``completion_reorders``, ``inflight_joins``).
  * ``CostModel`` — T_total = T_io + T_comp + T_other (Eq. 4), with an
    overlap factor for the I/O–compute pipeline (§5.1). Two presets:
    the paper's NVMe segment and the TPU HBM-block regime of DESIGN.md §2 —
    latencies are *model parameters*, so every latency/QPS figure derived
    from them is clearly labeled modeled-not-measured on this CPU container.

Pricing summary (repro.io):

  * demand misses (and legacy uncached reads) pay a full ``t_block_io``
    round trip; tier-0 hits — device reads served by the VMEM hot-tile
    pack (``device_search``) — pay ``t_tier0_hit`` (no DMA); tier-1
    cache hits pay ``t_cache_hit``; tier-2 hits — demand reads served
    by a compressed PQ-space block summary — pay ``t_tier2_hit``
    (decompress + re-rank, no disk trip);
  * synchronous coalesced prefetch pays ``t_batch_block`` per extra
    block, except that a round trip with *no* demand miss (a cache hit
    whose trip exists only to carry speculative blocks) pays one full
    ``t_block_io`` for its first block — a trip cannot be cheaper than
    the queue submission it models;
  * asynchronous speculative fetches are priced by queue occupancy:
    a fetch submitted with ``o`` fetches in flight contributes
    ``t_batch_block / o`` of serial time (``queue_occ_weight`` sums the
    ``1/o`` terms), so deep queues amortize toward zero serial cost
    while shallow queues degrade to the flat synchronous price;
  * a demand read that joins an already-in-flight fetch
    (``inflight_joins``) pays only the modeled residual service time
    (``join_residual`` × ``t_block_io``) instead of a new round trip;
  * a cold block touch that joins another request's gather of the same
    block *in the same device round* (``dedup_saved_fetches`` — the
    batched device search unions per-round block requests across the
    WHOLE batch, DESIGN.md §8; ``dedup_cross_tile`` counts the subset
    joining across kernel query tiles) pays ``t_dedup_hit`` (a VMEM
    broadcast of the one DMA that did happen) instead of its own
    ``t_block_io``;
  * stats flagged ``dma_pipelined`` (the fused kernel's double-buffered
    cold gather) overlap the round-granular streaming-DMA term with the
    occupancy-weighted round compute — ``max(dma, compute)`` per round
    instead of their sum; unflagged stats price exactly as before;
  * stats flagged ``dma_speculative`` (the cross-round speculative
    pipeline, DESIGN.md §9) additionally move the ``spec_hits`` share
    of the streaming DMAs one round earlier — off the critical path —
    so the pipelined chain pays ``max(dma x (1 - hit_frac), compute)``
    per round, while every ``spec_wasted`` block (speculated but never
    consumed) is surcharged serially at the bandwidth rate;
  * stats that carry the batched loop's round count (``batch_rounds`` >
    0, set by ``from_device(rounds=...)``) switch a cost model with
    ``t_round`` > 0 into the *round-granular* regime (DESIGN.md §5):
    the lockstep round chain pays ``batch_rounds x t_round`` of DMA
    latency once for the whole batch, cold DMAs then stream at the
    ``t_batch_block`` bandwidth rate instead of each paying a full
    round trip, and compute is occupancy-weighted — ``batch_rounds x
    rounds_active_weight x t_round_comp``, so a converged query's idle
    rounds cost nothing. Stats without a round count (the host paths)
    price exactly as before.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class IOStats:
    block_reads: int = 0        # demand block accesses (the paper's I/Os)
    io_round_trips: int = 0     # batched fetches issued (≤ block_reads)
    tier0_hits: int = 0         # demand reads served by tier 0 (the
    #                             device VMEM hot-tile pack — no HBM DMA)
    cache_hits: int = 0         # demand reads served by tier 1 (full blocks)
    tier2_hits: int = 0         # demand reads served by tier 2 (compressed
    #                             PQ-space summaries — re-rank, no disk trip)
    cache_misses: int = 0       # demand reads that went to "disk"
    prefetched_blocks: int = 0  # sync speculative fetches coalesced into trips
    queue_fetches: int = 0      # fetches submitted through the async queue
    #                             (demand + speculative)
    queue_occ_weight: float = 0.0  # Σ 1/occupancy over async speculative
    #                                fetches (serial-share weight)
    inflight_peak: int = 0      # max fetches simultaneously in flight
    inflight_joins: int = 0     # demand misses that joined an in-flight
    #                             fetch (cross-query dedup wins)
    join_residual: float = 0.0  # Σ residual service fraction over joins
    completion_reorders: int = 0  # completions delivered out of submit order
    dedup_saved_fetches: int = 0  # cold device touches that joined another
    #                               request's same-round gather of the same
    #                               block (cross-query dedup — no own DMA).
    #                               Scope: the WHOLE device batch, the
    #                               union the fused kernel's pass 1 dedups
    #                               across (DESIGN.md §8) — NOT one kernel
    #                               query tile. Additive under merge, like
    #                               every join counter.
    dedup_cross_tile: int = 0   # the cross-tile SUBSET of
    #                             dedup_saved_fetches: joins whose paying
    #                             requester sits in a different round-
    #                             kernel query tile — what batch scope
    #                             wins over per-tile dedup (whose modeled
    #                             DMAs = cache_misses - (dedup_saved_fetches
    #                             - dedup_cross_tile)). Always <= the
    #                             total; additive under merge (both count
    #                             joins, so a sum of queries' splits is
    #                             the batch's split).
    dma_pipelined: int = 0      # 1 when the fused kernel ran its cold
    #                             gather double-buffered (params.
    #                             pipeline_dma): the CostModel then
    #                             overlaps the streaming cold-DMA term
    #                             with round compute — max(dma, compute)
    #                             per round. A flag, not a count: merged
    #                             by max (a batch is pipelined or not).
    spec_hits: int = 0          # cold DMAs this query paid for that the
    #                             cross-round speculative pipeline
    #                             (params.speculate, DESIGN.md §9) had
    #                             already issued one round early — their
    #                             latency hides behind round i's compute.
    #                             Subset of the paying requests
    #                             (cold & ~joined), so spec_hits <= the
    #                             full-read count. Additive under merge.
    spec_wasted: int = 0        # speculated blocks the next round never
    #                             requested cold — DMAs issued for
    #                             nothing (the mis-speculation price the
    #                             CostModel surcharges). Additive.
    dma_speculative: int = 0    # 1 when the batch ran the speculative
    #                             cross-round pipeline: the CostModel
    #                             then discounts the streaming-DMA term
    #                             by the spec hit fraction and charges
    #                             spec_wasted DMAs serially. A flag,
    #                             merged by max like dma_pipelined.
    rounds_active_weight: float = 0.0  # Σ hops / batch rounds: the share
    #                               of the batched loop's rounds this query
    #                               was live for (divergence occupancy)
    batch_rounds: int = 0       # rounds of the batched device loop this
    #                             query rode in (shared across the batch,
    #                             so merged by max — exact when merging
    #                             one batch's queries; across batches it
    #                             is the longest batch's chain)
    vertices_fetched: int = 0   # ε per block read
    vertices_used: int = 0      # distance-evaluated full-precision vertices
    hops: int = 0               # total expansions (== block reads)
    hops_to_best: int = 0       # ℓ: hop at which the final top-1 was
    #                             found (the paper's path length)
    dist_comps: int = 0         # full-precision distance computations
    pq_comps: int = 0           # ADC distance computations
    hot_tier_hits: int = 0      # vertex visits answered by the in-memory
    #                             hot tier (DESIGN.md §10) — the memory-
    #                             latency half of hybrid routing. Vertex-
    #                             granular (one exact distance + queue op
    #                             each), NOT block reads: the hot tier
    #                             sits *above* the block hierarchy, so
    #                             these never enter block_reads or the
    #                             cache_hit_rate denominator. Additive.

    # merged with max(), not +: peaks, hop marks, the (batch-shared)
    # round count and the pipelined/speculative flags are not additive
    _MAX_FIELDS = ("hops_to_best", "inflight_peak", "batch_rounds",
                   "dma_pipelined", "dma_speculative")

    def merge(self, other: "IOStats") -> None:
        new_trips = self.io_round_trips + other.io_round_trips
        new_reads = self.block_reads + other.block_reads
        if new_trips > new_reads:
            # validate before mutating so a caught error leaves the
            # accumulator untouched
            raise ValueError(
                f"io_round_trips ({new_trips}) would exceed block_reads "
                f"({new_reads}) after merge — a batched fetch path issued "
                "more round trips than demand reads")
        for f in dataclasses.fields(self):
            if f.name in self._MAX_FIELDS:
                setattr(self, f.name, max(getattr(self, f.name),
                                          getattr(other, f.name)))
                continue
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))

    @classmethod
    def from_device(cls, io, tier0_hits=0, hops=0, dedup_saved=0,
                    rounds=0, dedup_cross=0,
                    pipelined=False, spec_hits=0, spec_wasted=0,
                    speculative=False, hot_tier=0) -> "IOStats":
        """Counters of one query's device search (``device_anns``):
        ``io`` cold block touches, ``tier0_hits`` touches served by the
        VMEM hot-tile pack, ``hops`` DMA round trips, ``dedup_saved``
        cold touches that joined another request's same-round gather —
        batch scope (so only ``io - dedup_saved`` DMAs actually
        issued), ``dedup_cross`` its cross-tile subset, ``rounds``
        total loop rounds of the batch this query rode in,
        ``pipelined`` whether the kernel double-buffered its cold
        gather. ``spec_hits``/``spec_wasted``/``speculative`` carry the
        cross-round speculative pipeline's accounting (DESIGN.md §9):
        hits are paying DMAs that were pre-issued one round early
        (clamped to the paying count ``io - dedup_saved``), wasted are
        speculated blocks never consumed. Cold DMAs price as misses
        (one trip each — batched-width amortization is already in the
        hop count), hot touches at ``t_tier0_hit``, deduped touches at
        ``t_dedup_hit``. ``hot_tier`` counts the query's vertex visits
        in the in-memory hot tier before the cold search began (hybrid
        routing, DESIGN.md §10) — priced at ``t_hot_tier_hit``, kept
        out of the block-touch totals."""
        io, t0, h = int(io), int(tier0_hits), int(hops)
        saved = min(int(dedup_saved), io)
        cross = min(int(dedup_cross), saved)
        sh = min(int(spec_hits), io - saved)
        return cls(block_reads=io + t0, io_round_trips=io - saved,
                   cache_misses=io, tier0_hits=t0, hops=h,
                   hot_tier_hits=int(hot_tier),
                   dedup_saved_fetches=saved, dedup_cross_tile=cross,
                   dma_pipelined=int(bool(pipelined)),
                   spec_hits=sh, spec_wasted=int(spec_wasted),
                   dma_speculative=int(bool(speculative)),
                   batch_rounds=int(rounds),
                   rounds_active_weight=(h / int(rounds)
                                         if int(rounds) > 0 else 0.0))

    @classmethod
    def from_device_batch(cls, io, tier0_hits, hops, dedup_saved,
                          rounds, dedup_cross=None,
                          pipelined=False, spec_hits=None,
                          spec_wasted=None,
                          speculative=False,
                          hot_tier=None) -> "IOStats":
        """Fold one batch's per-query device columns (the arrays a
        ``DeviceSearchResult`` / ``make_search_step`` rank emits) into
        one merged ``IOStats``: counters sum, ``batch_rounds`` is the
        shared round count, ``rounds_active_weight`` becomes the mean
        number of live queries per round. ``dedup_cross`` (the
        cross-tile column) and the speculative columns
        (``spec_hits``/``spec_wasted``) default to zeros for pre-split
        callers. This is THE fold both the serving ``RepackScheduler``
        objective and the benchmark QPS model
        (``paper_tables.mesh_qps_estimate``) price — one modeled step
        time, two consumers."""
        if dedup_cross is None:
            dedup_cross = [0] * len(io)
        if spec_hits is None:
            spec_hits = [0] * len(io)
        if spec_wasted is None:
            spec_wasted = [0] * len(io)
        if hot_tier is None:
            hot_tier = [0] * len(io)
        agg = cls()
        for i, t0, h, sv, cx, sh, sw, ht in zip(io, tier0_hits, hops,
                                                dedup_saved, dedup_cross,
                                                spec_hits, spec_wasted,
                                                hot_tier):
            agg.merge(cls.from_device(i, t0, h, sv, rounds, cx,
                                      pipelined, sh, sw, speculative,
                                      ht))
        return agg

    @classmethod
    def fold_rank_batches(cls, columns) -> "dict[int, IOStats]":
        """Rank-keyed fold of a mesh-served step: ``columns[rank] =
        (io, tier0_hits, hops, dedup_saved, rounds[, dedup_cross
        [, pipelined[, spec_hits, spec_wasted[, speculative]]]])`` —
        each rank's per-query device columns, folded per rank with
        ``from_device_batch`` (5-tuples price the cross-tile column as
        zero; short tuples zero the speculative columns too). This is
        THE shared mesh fold: the router's windowed per-rank stats, the
        scheduler objective and ``mesh_qps_estimate`` all price these
        same per-rank IOStats, and ``merge_ranks`` defines the one
        correct total."""
        return {int(r): cls.from_device_batch(*cols)
                for r, cols in columns.items()}

    @staticmethod
    def merge_ranks(per_rank) -> "IOStats":
        """Mesh totals from a rank-keyed fold: counters sum across
        ranks, ``_MAX_FIELDS`` (incl. ``batch_rounds`` — the step is
        gated by the slowest rank's chain) merge by max. NOTE
        ``rounds_active_weight`` is a per-batch occupancy (Σ hops /
        that rank's rounds); summing it across ranks with different
        round counts is only meaningful through this merge — never
        re-fold summed columns."""
        total = IOStats()
        for r in sorted(per_rank):
            total.merge(per_rank[r])
        return total

    @property
    def cache_hit_rate(self) -> float:
        """Fraction of demand reads served by any cache tier."""
        hits = self.tier0_hits + self.cache_hits + self.tier2_hits
        tracked = hits + self.cache_misses
        if tracked == 0:
            return 0.0
        return hits / tracked

    @property
    def vertex_utilization(self) -> float:
        """ξ: fraction of fetched vertices actually used (Tab. 2)."""
        if self.vertices_fetched == 0:
            return 0.0
        return self.vertices_used / self.vertices_fetched


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Latency model; times in microseconds.

    Cache-aware I/O pricing (repro.io): demand reads served by the
    ``BlockCache`` cost ``t_cache_hit`` (memory latency) instead of
    ``t_block_io``; a batched round trip pays one full ``t_block_io``
    plus ``t_batch_block`` per extra coalesced block (queue-depth
    amortization on NVMe / contiguous DMA on TPU). Stats with no cache
    counters price every ``block_reads`` at ``t_block_io`` — the seed's
    behavior, so uncached figures are unchanged.
    """
    t_block_io: float           # one block fetch round trip
    t_dist: float               # one full-precision distance (D-dim)
    t_pq: float                 # one ADC distance
    t_hop_other: float = 0.2    # queue maintenance per hop
    t_cache_hit: float = 0.0    # demand read served from memory (tier 1)
    t_batch_block: float = 0.0  # extra block coalesced into a round trip
    #                             (0.0 → priced as a full t_block_io)
    t_tier2_hit: float = 0.0    # demand read served by a compressed
    #                             PQ-space summary (decompress + re-rank)
    t_tier0_hit: float = 0.0    # demand read served by the device VMEM
    #                             hot-tile pack (tier 0 — no HBM DMA)
    t_dedup_hit: float = 0.0    # cold touch that joined another query's
    #                             same-round gather (VMEM broadcast of a
    #                             DMA someone else already paid for)
    t_hot_tier_hit: float = 0.0  # one vertex visit in the in-memory hot
    #                              tier (DESIGN.md §10): an exact
    #                              distance + queue op at memory latency.
    #                              Compute-side — it never enters
    #                              ``_io_time``, so the modeled
    #                              memory-vs-disk split of hybrid
    #                              routing stays clean.
    t_round: float = 0.0        # round-granular regime (DESIGN.md §5):
    #                             lockstep cost per batched-loop round —
    #                             the gather issue + merge barrier every
    #                             live query waits on (0 → hops-granular
    #                             pricing, the pre-PR-5 behavior)
    t_round_comp: float = 0.0   # per live query per round compute share
    #                             (rank + merge of its fetched tiles) —
    #                             weighted by rounds_active_weight so
    #                             idle rounds of a converged query are
    #                             free
    name: str = "model"

    def _round_chain(self, s: IOStats) -> float:
        """The lockstep round chain: one DMA-latency + barrier unit per
        batched-loop round (0 outside the round-granular regime)."""
        if self.t_round <= 0.0 or s.batch_rounds <= 0:
            return 0.0
        return s.batch_rounds * self.t_round

    def _round_comp(self, s: IOStats) -> float:
        """Occupancy-weighted round compute: batch_rounds x
        rounds_active_weight = the query's live rounds (summed over a
        merged batch: total live query-rounds), each paying
        ``t_round_comp`` — monotone in ``rounds_active_weight``."""
        if self.t_round <= 0.0 or s.batch_rounds <= 0:
            return 0.0
        return s.batch_rounds * s.rounds_active_weight * self.t_round_comp

    def _io_time(self, s: IOStats) -> float:
        # Demand misses sit on the critical path: each pays a full round
        # trip. Synchronous speculative fetches coalesce into an already
        # paid-for trip at t_batch_block each — unless the trip carried
        # *only* speculative blocks (a cache hit with prefetch targets),
        # in which case its first block pays the full t_block_io the trip
        # itself costs. Async speculative fetches are priced by queue
        # occupancy: t_batch_block/o of serial time each (the 1/o terms
        # are pre-summed in queue_occ_weight), so depth amortizes them.
        # Joins of in-flight fetches pay only the modeled residual.
        # Hits are memory copies; tier-2 hits are decompress + re-rank.
        # Reads with no cache accounting (uncached paths, and the
        # uncached share of merged mixed stats) price as misses.
        t_batch = self.t_batch_block if self.t_batch_block else \
            self.t_block_io
        full_reads = max(s.block_reads - s.tier0_hits - s.cache_hits
                        - s.tier2_hits - s.inflight_joins
                        - s.dedup_saved_fetches, 0)
        # round-granular regime: the lockstep chain (``_round_chain``)
        # already pays the per-round DMA latency once for the whole
        # batch, so cold DMAs stream at the bandwidth rate instead of
        # each paying its own full round trip
        round_granular = self.t_round > 0.0 and s.batch_rounds > 0
        t_miss = t_batch if round_granular else self.t_block_io
        # trips beyond one-per-miss are speculative-only (hit + prefetch);
        # async demand submissions count one trip per non-joined miss, so
        # adding inflight_joins back keeps the sync surplus exact.
        spec_trips = min(max(s.io_round_trips - s.cache_misses
                            + s.inflight_joins, 0), s.prefetched_blocks)
        return (self._round_chain(s)
                + full_reads * t_miss
                + spec_trips * self.t_block_io
                + (s.prefetched_blocks - spec_trips) * t_batch
                + s.queue_occ_weight * t_batch
                + s.join_residual * self.t_block_io
                + s.dedup_saved_fetches * self.t_dedup_hit
                + s.tier0_hits * self.t_tier0_hit
                + s.cache_hits * self.t_cache_hit
                + s.tier2_hits * self.t_tier2_hit)

    def _stream_dma(self, s: IOStats) -> float:
        """The round-granular cold-DMA streaming term — the
        ``t_batch_block``-rate part of ``_io_time`` (0 outside that
        regime): what the double-buffered kernel puts in flight behind
        round compute when ``dma_pipelined`` is set."""
        if self.t_round <= 0.0 or s.batch_rounds <= 0:
            return 0.0
        t_batch = self.t_batch_block if self.t_batch_block else \
            self.t_block_io
        full_reads = max(s.block_reads - s.tier0_hits - s.cache_hits
                        - s.tier2_hits - s.inflight_joins
                        - s.dedup_saved_fetches, 0)
        return full_reads * t_batch

    def _spec_hit_frac(self, s: IOStats) -> float:
        """Fraction of the streaming cold DMAs the cross-round
        speculative pipeline pre-issued one round early (0 outside the
        round-granular speculative regime). spec_hits is clamped to the
        paying-request count at fold time, so the fraction is in
        [0, 1] by construction; the clamp here guards hand-built
        stats."""
        if not s.dma_speculative or self.t_round <= 0.0 \
                or s.batch_rounds <= 0:
            return 0.0
        t_batch = self.t_batch_block if self.t_batch_block else \
            self.t_block_io
        stream = self._stream_dma(s)
        if stream <= 0.0:
            return 0.0
        return min(s.spec_hits * t_batch / stream, 1.0)

    def _spec_waste(self, s: IOStats) -> float:
        """The mis-speculation surcharge: every speculated block the
        next round never consumed still streamed its DMA — charged
        serially at the bandwidth rate, so wasted speculation is
        visible in the modeled total (0 outside the regime)."""
        if not s.dma_speculative or self.t_round <= 0.0 \
                or s.batch_rounds <= 0:
            return 0.0
        t_batch = self.t_batch_block if self.t_batch_block else \
            self.t_block_io
        return s.spec_wasted * t_batch

    def _hot_time(self, s: IOStats) -> float:
        """The memory-latency half of hybrid routing: hot-tier vertex
        visits price as compute (exact distance + queue op each), never
        as I/O — keeping the memory-vs-disk split exact."""
        return s.hot_tier_hits * self.t_hot_tier_hit

    def latency_us(self, s: IOStats, pipeline: bool = False) -> float:
        t_io = self._io_time(s)
        t_comp = (s.dist_comps * self.t_dist + s.pq_comps * self.t_pq
                  + self._round_comp(s) + self._hot_time(s))
        t_other = s.hops * self.t_hop_other
        if pipeline:
            # §5.1: DR and DC run concurrently; serial residue is the max
            # plus the non-overlappable other time.
            return max(t_io, t_comp) + t_other
        round_granular = self.t_round > 0.0 and s.batch_rounds > 0
        if s.dma_pipelined and round_granular:
            # DESIGN.md §8: the double-buffered cold gather overlaps the
            # streaming DMA term with the occupancy-weighted round
            # compute — per round the kernel pays max(dma, compute),
            # never their sum. The lockstep chain (issue + barrier) and
            # every non-round term stay serial. Stats without the flag
            # (pipeline_dma off, per-tile kernels, host paths) price
            # exactly as before.
            #
            # DESIGN.md §9: the speculative cross-round pipeline moves
            # the spec-hit share of the stream one round earlier, where
            # it hides behind round i's compute regardless of the
            # within-round balance — only the UN-speculated residue
            # still races this round's compute, so the chain prices
            # max(stream x (1 - h), compute) + the wasted-DMA
            # surcharge. h = 0 (speculation off) reduces exactly to
            # the PR-8 pipelined form.
            stream = self._stream_dma(s)
            rcomp = self._round_comp(s)
            h = self._spec_hit_frac(s)
            return ((t_io - stream) + (t_comp - rcomp)
                    + max(stream * (1.0 - h), rcomp) + t_other
                    + self._spec_waste(s))
        if s.dma_speculative and round_granular:
            # speculative without the double-buffered gather: the
            # pre-issued share of the stream overlaps the previous
            # round's compute (it left the critical path entirely);
            # the rest of the pricing is the serial round-granular
            # form plus the wasted-DMA surcharge.
            stream = self._stream_dma(s)
            h = self._spec_hit_frac(s)
            return (t_io - stream * h) + t_comp + t_other \
                + self._spec_waste(s)
        return t_io + t_comp + t_other

    def breakdown(self, s: IOStats, pipeline: bool = False) -> dict:
        t_io = self._io_time(s)
        t_comp = (s.dist_comps * self.t_dist + s.pq_comps * self.t_pq
                  + self._round_comp(s) + self._hot_time(s))
        t_other = s.hops * self.t_hop_other
        total = self.latency_us(s, pipeline)
        return {"t_io_us": t_io, "t_comp_us": t_comp, "t_other_us": t_other,
                "total_us": total,
                # hybrid hot-tier terms (DESIGN.md §10): memory-latency
                # visits, priced inside t_comp — the memory half of the
                # hybrid memory-vs-disk split (t_io is the disk half)
                "hot_tier_hits": s.hot_tier_hits,
                "t_hot_tier_us": self._hot_time(s),
                # round-granular terms (0 outside that regime): the
                # lockstep chain, the occupancy-weighted compute and
                # the streaming cold-DMA share a dma_pipelined batch
                # overlaps with compute (max(dma, compute) per round)
                "t_round_chain_us": self._round_chain(s),
                "t_round_comp_us": self._round_comp(s),
                "t_dma_stream_us": self._stream_dma(s),
                "dma_pipelined": bool(s.dma_pipelined),
                # speculative cross-round pipeline terms (0/False
                # outside that regime): the pre-issued share of the
                # stream and the serial mis-speculation surcharge
                "dma_speculative": bool(s.dma_speculative),
                "spec_hit_frac": self._spec_hit_frac(s),
                "t_spec_waste_us": self._spec_waste(s),
                "io_frac": t_io / max(t_io + t_comp + t_other, 1e-9),
                # per-tier demand-read service counts (tier 0 = device
                # VMEM hot tiles, 1 = host full blocks, 2 = compressed
                # summaries) so hierarchy sweeps can report where reads
                # were absorbed
                "tier0_hits": s.tier0_hits, "tier1_hits": s.cache_hits,
                "tier2_hits": s.tier2_hits,
                "cache_misses": s.cache_misses}


# The paper's segment: NVMe 4KB random read ~90–100 µs per round-trip,
# ~0.05 µs per 128-d L2 on one core, ADC ~0.01 µs. A cache hit is a DRAM
# copy of one 4 KB block (~0.5 µs); an extra block coalesced into an
# in-flight round trip rides the same queue slot (~18 µs). A tier-2 hit
# decompresses a ~256 B PQ-space summary and re-ranks (~2.5 µs).
NVME_SEGMENT = CostModel(t_block_io=95.0, t_dist=0.055, t_pq=0.012,
                         t_cache_hit=0.5, t_batch_block=18.0,
                         t_tier2_hit=2.5, t_tier0_hit=0.5,
                         t_dedup_hit=0.5, t_hot_tier_hit=0.1,
                         name="nvme")

# TPU regime (DESIGN.md §2): 4 KB HBM→VMEM DMA ≈ 1.2 µs latency-bound,
# VPU block ranking ≈ 0.02 µs/vector amortized, ADC ≈ 0.002 µs via LUT
# tiles. A tier-1 hit is an HBM-resident tile copy; coalesced blocks
# stream at HBM bandwidth (~0.35 µs per extra 4 KB); a tier-2 hit is a
# VMEM LUT re-rank of the resident summary tile. A tier-0 hit reads the
# hot tile already *in VMEM* — no DMA at all, just the probe, ~10 ns.
# A dedup hit rides another query's same-round DMA: the tile lands in
# VMEM once and broadcasts, so it prices like a tier-0 hit.
# Round-granular terms (DESIGN.md §5, active only on stats that carry
# batch_rounds): one lockstep loop round costs the latency-bound DMA
# issue plus the candidate-merge barrier ≈ 1.5 µs, and each *live*
# query adds ≈ 0.15 µs of VPU rank + top-k merge for its tiles — idle
# rounds of a converged query are free (occupancy-weighted via
# rounds_active_weight).
# A hot-tier visit is one exact distance + queue op on an in-memory
# graph: ~DRAM-speed on the NVMe host (~0.1 µs incl. the queue push),
# ~one VPU distance on TPU (~0.02 µs).
TPU_HBM_SEGMENT = CostModel(t_block_io=1.2, t_dist=0.02, t_pq=0.002,
                            t_cache_hit=0.05, t_batch_block=0.35,
                            t_tier2_hit=0.08, t_tier0_hit=0.01,
                            t_dedup_hit=0.01, t_hot_tier_hit=0.02,
                            t_round=1.5,
                            t_round_comp=0.15, name="tpu-hbm")
