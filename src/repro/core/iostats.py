"""I/O accounting and the latency cost model (Eq. 4).

Everything the paper measures flows through this module:

  * ``IOStats`` — per-query counters: block reads (mean I/Os), vertices
    fetched vs vertices used (vertex-utilization ξ, Tab. 2), hops (path
    length ℓ), distance computations.
  * ``CostModel`` — T_total = T_io + T_comp + T_other (Eq. 4), with an
    overlap factor for the I/O–compute pipeline (§5.1). Two presets:
    the paper's NVMe segment and the TPU HBM-block regime of DESIGN.md §2 —
    latencies are *model parameters*, so every latency/QPS figure derived
    from them is clearly labeled modeled-not-measured on this CPU container.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class IOStats:
    block_reads: int = 0        # demand block accesses (the paper's I/Os)
    io_round_trips: int = 0     # batched fetches issued (≤ block_reads)
    cache_hits: int = 0         # demand reads served by the BlockCache
    cache_misses: int = 0       # demand reads that went to "disk"
    prefetched_blocks: int = 0  # speculative fetches coalesced into trips
    vertices_fetched: int = 0   # ε per block read
    vertices_used: int = 0      # distance-evaluated full-precision vertices
    hops: int = 0               # total expansions (== block reads)
    hops_to_best: int = 0       # ℓ: hop at which the final top-1 was
    #                             found (the paper's path length)
    dist_comps: int = 0         # full-precision distance computations
    pq_comps: int = 0           # ADC distance computations

    def merge(self, other: "IOStats") -> None:
        new_trips = self.io_round_trips + other.io_round_trips
        new_reads = self.block_reads + other.block_reads
        if new_trips > new_reads:
            # validate before mutating so a caught error leaves the
            # accumulator untouched
            raise ValueError(
                f"io_round_trips ({new_trips}) would exceed block_reads "
                f"({new_reads}) after merge — a batched fetch path issued "
                "more round trips than demand reads")
        for f in dataclasses.fields(self):
            if f.name == "hops_to_best":
                self.hops_to_best = max(self.hops_to_best,
                                        other.hops_to_best)
                continue
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))

    @property
    def cache_hit_rate(self) -> float:
        """Fraction of demand reads served by the block cache."""
        tracked = self.cache_hits + self.cache_misses
        if tracked == 0:
            return 0.0
        return self.cache_hits / tracked

    @property
    def vertex_utilization(self) -> float:
        """ξ: fraction of fetched vertices actually used (Tab. 2)."""
        if self.vertices_fetched == 0:
            return 0.0
        return self.vertices_used / self.vertices_fetched


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Latency model; times in microseconds.

    Cache-aware I/O pricing (repro.io): demand reads served by the
    ``BlockCache`` cost ``t_cache_hit`` (memory latency) instead of
    ``t_block_io``; a batched round trip pays one full ``t_block_io``
    plus ``t_batch_block`` per extra coalesced block (queue-depth
    amortization on NVMe / contiguous DMA on TPU). Stats with no cache
    counters price every ``block_reads`` at ``t_block_io`` — the seed's
    behavior, so uncached figures are unchanged.
    """
    t_block_io: float           # one block fetch round trip
    t_dist: float               # one full-precision distance (D-dim)
    t_pq: float                 # one ADC distance
    t_hop_other: float = 0.2    # queue maintenance per hop
    t_cache_hit: float = 0.0    # demand read served from memory
    t_batch_block: float = 0.0  # extra block coalesced into a round trip
    #                             (0.0 → priced as a full t_block_io)
    name: str = "model"

    def _io_time(self, s: IOStats) -> float:
        # Demand misses sit on the critical path: each pays a full round
        # trip. Speculative fetches are issued while the current block is
        # being ranked (§5.1 overlap) — they cost bandwidth, not latency:
        # t_batch_block per coalesced block. Hits are memory copies.
        # Reads with no cache accounting (uncached paths, and the
        # uncached share of merged mixed stats) price as misses, so
        # block_reads - cache_hits is the full-latency count either way.
        full_reads = max(s.block_reads - s.cache_hits, 0)
        t_batch = self.t_batch_block if self.t_batch_block else \
            self.t_block_io
        return (full_reads * self.t_block_io
                + s.prefetched_blocks * t_batch
                + s.cache_hits * self.t_cache_hit)

    def latency_us(self, s: IOStats, pipeline: bool = False) -> float:
        t_io = self._io_time(s)
        t_comp = s.dist_comps * self.t_dist + s.pq_comps * self.t_pq
        t_other = s.hops * self.t_hop_other
        if pipeline:
            # §5.1: DR and DC run concurrently; serial residue is the max
            # plus the non-overlappable other time.
            return max(t_io, t_comp) + t_other
        return t_io + t_comp + t_other

    def breakdown(self, s: IOStats, pipeline: bool = False) -> dict:
        t_io = self._io_time(s)
        t_comp = s.dist_comps * self.t_dist + s.pq_comps * self.t_pq
        t_other = s.hops * self.t_hop_other
        total = self.latency_us(s, pipeline)
        return {"t_io_us": t_io, "t_comp_us": t_comp, "t_other_us": t_other,
                "total_us": total,
                "io_frac": t_io / max(t_io + t_comp + t_other, 1e-9)}


# The paper's segment: NVMe 4KB random read ~90–100 µs per round-trip,
# ~0.05 µs per 128-d L2 on one core, ADC ~0.01 µs. A cache hit is a DRAM
# copy of one 4 KB block (~0.5 µs); an extra block coalesced into an
# in-flight round trip rides the same queue slot (~18 µs).
NVME_SEGMENT = CostModel(t_block_io=95.0, t_dist=0.055, t_pq=0.012,
                         t_cache_hit=0.5, t_batch_block=18.0,
                         name="nvme")

# TPU regime (DESIGN.md §2): 4 KB HBM→VMEM DMA ≈ 1.2 µs latency-bound,
# VPU block ranking ≈ 0.02 µs/vector amortized, ADC ≈ 0.002 µs via LUT
# tiles. A hit is a VMEM-resident tile; coalesced blocks stream at HBM
# bandwidth (~0.35 µs per extra 4 KB).
TPU_HBM_SEGMENT = CostModel(t_block_io=1.2, t_dist=0.02, t_pq=0.002,
                            t_cache_hit=0.05, t_batch_block=0.35,
                            name="tpu-hbm")
