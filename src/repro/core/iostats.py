"""I/O accounting and the latency cost model (Eq. 4).

Everything the paper measures flows through this module:

  * ``IOStats`` — per-query counters: block reads (mean I/Os), vertices
    fetched vs vertices used (vertex-utilization ξ, Tab. 2), hops (path
    length ℓ), distance computations.
  * ``CostModel`` — T_total = T_io + T_comp + T_other (Eq. 4), with an
    overlap factor for the I/O–compute pipeline (§5.1). Two presets:
    the paper's NVMe segment and the TPU HBM-block regime of DESIGN.md §2 —
    latencies are *model parameters*, so every latency/QPS figure derived
    from them is clearly labeled modeled-not-measured on this CPU container.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class IOStats:
    block_reads: int = 0        # number of block fetches (the paper's I/Os)
    io_round_trips: int = 0     # batched fetches issued (≤ block_reads)
    vertices_fetched: int = 0   # ε per block read
    vertices_used: int = 0      # distance-evaluated full-precision vertices
    hops: int = 0               # total expansions (== block reads)
    hops_to_best: int = 0       # ℓ: hop at which the final top-1 was
    #                             found (the paper's path length)
    dist_comps: int = 0         # full-precision distance computations
    pq_comps: int = 0           # ADC distance computations

    def merge(self, other: "IOStats") -> None:
        for f in dataclasses.fields(self):
            if f.name == "hops_to_best":
                self.hops_to_best = max(self.hops_to_best,
                                        other.hops_to_best)
                continue
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))

    @property
    def vertex_utilization(self) -> float:
        """ξ: fraction of fetched vertices actually used (Tab. 2)."""
        if self.vertices_fetched == 0:
            return 0.0
        return self.vertices_used / self.vertices_fetched


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Latency model; times in microseconds."""
    t_block_io: float           # one block fetch
    t_dist: float               # one full-precision distance (D-dim)
    t_pq: float                 # one ADC distance
    t_hop_other: float = 0.2    # queue maintenance per hop
    name: str = "model"

    def latency_us(self, s: IOStats, pipeline: bool = False) -> float:
        t_io = s.block_reads * self.t_block_io
        t_comp = s.dist_comps * self.t_dist + s.pq_comps * self.t_pq
        t_other = s.hops * self.t_hop_other
        if pipeline:
            # §5.1: DR and DC run concurrently; serial residue is the max
            # plus the non-overlappable other time.
            return max(t_io, t_comp) + t_other
        return t_io + t_comp + t_other

    def breakdown(self, s: IOStats, pipeline: bool = False) -> dict:
        t_io = s.block_reads * self.t_block_io
        t_comp = s.dist_comps * self.t_dist + s.pq_comps * self.t_pq
        t_other = s.hops * self.t_hop_other
        total = self.latency_us(s, pipeline)
        return {"t_io_us": t_io, "t_comp_us": t_comp, "t_other_us": t_other,
                "total_us": total,
                "io_frac": t_io / max(t_io + t_comp + t_other, 1e-9)}


# The paper's segment: NVMe 4KB random read ~90–100 µs per round-trip,
# ~0.05 µs per 128-d L2 on one core, ADC ~0.01 µs.
NVME_SEGMENT = CostModel(t_block_io=95.0, t_dist=0.055, t_pq=0.012,
                         name="nvme")

# TPU regime (DESIGN.md §2): 4 KB HBM→VMEM DMA ≈ 1.2 µs latency-bound,
# VPU block ranking ≈ 0.02 µs/vector amortized, ADC ≈ 0.002 µs via LUT tiles.
TPU_HBM_SEGMENT = CostModel(t_block_io=1.2, t_dist=0.02, t_pq=0.002,
                            name="tpu-hbm")
