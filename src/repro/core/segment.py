"""Segment build orchestration + space-budget accounting (§2.2, §6.4).

``build_segment`` runs the full offline pipeline of Eq. 8:
  T_disk_graph  — graph construction (Vamana/NSG/HNSW)
  T_shuffling   — block shuffling (BNP/BNF/BNS)
  T_memory_graph— in-memory navigation graph on the μ-sample
  T_PQ          — PQ codebook training + encoding

and returns a ``Segment`` whose ``memory_bytes()`` implements Eq. 10
(C_graph + C_mapping + C_PQ&others) and ``disk_bytes()`` the block file.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.core import graph as G
from repro.core import layout as L
from repro.core import navgraph as NG
from repro.core.blockstore import BlockStore, build_store
from repro.core.params import SegmentParams
from repro.core.search import SegmentView
from repro.io.cached_store import CachedBlockStore, cached_view
from repro.pq import PQCodebook, encode_pq, train_pq


@dataclasses.dataclass
class Segment:
    view: SegmentView
    graph: G.Graph
    params: SegmentParams
    build_times: Dict[str, float]
    overlap_ratio: float

    @property
    def num_vectors(self) -> int:
        return self.graph.num_vertices

    def memory_bytes(self) -> int:
        """Eq. 10: C_graph + C_mapping + C_PQ&others + C_cache + C_tier0.

        C_cache is the repro.io block-cache budget: reserved DRAM for
        η-KB block residency, charged whether or not it is full.
        C_tier0 is the device hot-tile pack budget (``CacheParams.
        tier0_*``): reserved VMEM, but reserved memory all the same —
        the unified hierarchy charges every tier into one budget."""
        c_graph = (self.view.nav.memory_bytes()
                   if self.view.nav is not None else 0)
        c_mapping = self.view.layout.mapping_bytes()
        c_pq = (self.view.pq_codes.nbytes + self.view.pq_cb.memory_bytes()
                if self.view.pq_codes is not None else 0)
        c_cache = (self.view.store.memory_bytes()
                   if isinstance(self.view.store, CachedBlockStore) else 0)
        return c_graph + c_mapping + c_pq + c_cache + self.tier0_bytes()

    def tier0_bytes(self) -> int:
        """C_tier0: the configured device hot-tile budget (0 when the
        device tier is off)."""
        return self.params.cache.resolve_tier0_budget(self.disk_bytes())

    def disk_bytes(self) -> int:
        return self.view.store.disk_bytes()

    def check_budget(self) -> Dict[str, bool]:
        b = self.params.budget
        return {"memory_ok": self.memory_bytes() <= b.memory_bytes,
                "disk_ok": self.disk_bytes() <= b.disk_bytes,
                "tier0_ok": self.tier0_bytes() <= b.tier0_vmem_bytes}


def build_segment(x: np.ndarray, params: SegmentParams,
                  graph: Optional[G.Graph] = None) -> Segment:
    x = np.ascontiguousarray(x, np.float32)
    times: Dict[str, float] = {}

    t0 = time.perf_counter()
    g = graph if graph is not None else G.build_graph(
        x, params.graph, params.metric)
    times["disk_graph_s"] = time.perf_counter() - t0

    eps = params.layout.verts_per_block(x.shape[1], g.max_degree)
    t0 = time.perf_counter()
    lay = L.make_layout(g, eps, params.layout.shuffle, x=x,
                        bnf_iters=params.layout.bnf_iters,
                        bns_iters=params.layout.bns_iters,
                        tau=params.layout.gain_tau)
    times["shuffling_s"] = time.perf_counter() - t0
    lay.validate()

    t0 = time.perf_counter()
    nav = (NG.build_navgraph(x, params.nav, params.metric,
                             algo="nsg")
           if params.search.use_nav_graph else None)
    times["memory_graph_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    cb = train_pq(x, params.pq, params.metric)
    codes = encode_pq(x, cb)
    times["pq_s"] = time.perf_counter() - t0

    store = build_store(x, g, lay, params.layout.block_kb)
    view = SegmentView(store=store, layout=lay, nav=nav,
                       pq_codes=codes, pq_cb=cb, metric=params.metric,
                       entry=g.entry)
    if params.cache.enabled:
        view = cached_view(view, g, params.cache)
    return Segment(view=view, graph=g, params=params, build_times=times,
                   overlap_ratio=L.overlap_ratio(g, lay))


def save_segment(seg: Segment, path: str) -> None:
    np.savez_compressed(
        path,
        adj=seg.graph.adj, deg=seg.graph.deg, entry=seg.graph.entry,
        blocks=seg.view.layout.blocks, block_of=seg.view.layout.block_of,
        slot_of=seg.view.layout.slot_of,
        vid=seg.view.store.vid, vecs=seg.view.store.vecs,
        meta=seg.view.store.meta,
        pq_codes=seg.view.pq_codes, pq_cent=seg.view.pq_cb.centroids,
        nav_ids=(seg.view.nav.sample_ids if seg.view.nav is not None
                 else np.zeros(0, np.int32)),
        nav_adj=(seg.view.nav.graph.adj if seg.view.nav is not None
                 else np.zeros((0, 1), np.int32)),
        nav_deg=(seg.view.nav.graph.deg if seg.view.nav is not None
                 else np.zeros(0, np.int32)),
        nav_entry=(seg.view.nav.graph.entry
                   if seg.view.nav is not None else 0),
        nav_vecs=(seg.view.nav.vectors if seg.view.nav is not None
                  else np.zeros((0, 1), np.float32)),
        metric=seg.params.metric, block_kb=seg.params.layout.block_kb,
        overlap=seg.overlap_ratio)


def load_segment(path: str, params: SegmentParams) -> Segment:
    z = np.load(path, allow_pickle=False)
    g = G.Graph(adj=z["adj"], deg=z["deg"], entry=int(z["entry"]),
                metric=str(z["metric"]))
    lay = L.BlockLayout(blocks=z["blocks"], block_of=z["block_of"],
                        slot_of=z["slot_of"])
    store = BlockStore(vid=z["vid"], vecs=z["vecs"], meta=z["meta"],
                       block_kb=float(z["block_kb"]))
    nav = None
    if z["nav_ids"].shape[0]:
        nav = NG.NavGraph(
            graph=G.Graph(adj=z["nav_adj"], deg=z["nav_deg"],
                          entry=int(z["nav_entry"]), metric=str(z["metric"])),
            sample_ids=z["nav_ids"], vectors=z["nav_vecs"])
    cb = PQCodebook(centroids=z["pq_cent"], dim=z["vecs"].shape[2],
                    metric=str(z["metric"]))
    view = SegmentView(store=store, layout=lay, nav=nav,
                       pq_codes=z["pq_codes"], pq_cb=cb,
                       metric=str(z["metric"]), entry=int(z["entry"]))
    if params.cache.enabled:
        view = cached_view(view, g, params.cache)
    return Segment(view=view, graph=g, params=params, build_times={},
                   overlap_ratio=float(z["overlap"]))
