"""Device-side batched Starling search (the TPU product of DESIGN.md §2).

The host implementation (``core/search.py``) is the per-query oracle; this
module is the batched, jit'd production path:

  * one ``lax.while_loop`` over hops for a whole query batch, carrying
    an explicit *active-query* view (``open_key``): converged queries
    stop contributing to the loop condition, request no blocks (their
    fetch slots carry the -1 sentinel the round kernel skips), and are
    excluded from every DMA/tier-0 counter;
  * each hop runs the fused *round stage* (``kernels.fused_round``):
    probe the tier-0 VMEM hot-tile pack first (a hit serves the block
    without the HBM->VMEM DMA that models one 4 KB disk read; counted
    in ``tier0_hits``), union the round's block requests across the
    query batch so each distinct cold block is gathered from HBM once
    and broadcast to all requesters (joins counted in ``dedup_saved``;
    the GoVector-style shared-I/O win, on device), exact-rank all
    resident vertices and order the sigma-pruned expansion targets —
    one kernel pass — then route new candidates by memory-resident
    PQ-ADC;
  * ``compact_frac`` > 0 adds divergence compaction: when the live
    fraction of the batch drops below the threshold, live queries are
    stably repacked to the front so converged queries cluster into
    whole kernel tiles the round kernel skips (the permutation is
    carried and inverted on exit — results are order-identical);
  * ``speculate`` (DESIGN.md §9) pipelines rounds: while round i's
    expansion/top-M maintenance still runs, the loop predicts round
    i+1's cold-block union from the candidates round i just PQ-routed
    and stages it in carried speculation state — the modeled
    speculative DMAs overlap round i's compute. The next round's
    authoritative fetch re-gathers anything mis-predicted (speculation
    is never wrong, only late), so (ids, dists) are bit-identical to
    speculation-off; consumed predictions land in ``spec_hits`` (DMAs
    off the critical path), dead ones in ``spec_wasted`` (bandwidth
    surcharge the cost model prices);
  * entry points come from an in-memory navigation-graph beam search;
  * per-query DMA / tier-0-hit / dedup-join / round-trip counters are
    carried exactly (the paper's "mean I/Os" splits across the
    hierarchy; actual DMAs issued = ``io - dedup_saved``).

Tier 0 (DESIGN.md §3): ``DeviceSegment`` carries a packed copy of the
hottest blocks — selected at build time from the same
``repro.io.hotset`` ranking that pins the host tier-1 cache — plus a
block->hot-slot index map. The pack holds exact copies, so tier-0
budget never changes (ids, dists); it only moves block touches from
the DMA counter to the tier-0 counter. Its bytes charge into the
Eq. 10 segment budget (``CacheParams.tier0_*``,
``SegmentBudget.tier0_vmem_bytes``).

Distribution (``make_search_step``): segment-parallel over the ``model``
mesh axis (each rank owns an independent sub-segment, Fig. 1(b)),
query-parallel over ``data`` (+ ``pod``); a top-k merge (all-gather +
sort over ``model``) combines per-segment results — the only collective
in the step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.params import DeviceSearchParams

Tree = dict

# per-round trace-buffer columns (p.trace_rounds) — the device-side
# twin of repro.obs.roundlog.ROUND_LOG_COLS (kept import-free here so
# core never depends on the obs plane; equality is pinned by a test).
# ``joins`` is ALL dedup joins in the round (batch scope, the kernel's
# union pass); ``joins_x`` is the cross-tile subset of them.
# ``spec_hits``/``spec_wasted`` are the round's consumed speculation
# outcomes (DESIGN.md §9) — always present, zero when ``p.speculate``
# is off, so the fold schema never varies with the knob.
_ROUND_LOG_COLS = ("live", "cold", "tier0", "joins", "joins_x",
                   "compacted", "spec_hits", "spec_wasted")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeviceSegment:
    """One segment shard, fully device-resident.

    ``hot_*`` is the tier-0 hot-tile pack: exact copies of the ``H``
    most-traversed blocks (``repro.io.hotset`` ranking), VMEM-resident
    in the TPU regime; ``hot_slot_of[b]`` maps block -> hot slot (-1 =
    cold). ``H >= 1`` always — a disabled tier 0 is one zeroed sentinel
    slot that ``hot_slot_of`` never points at."""
    vecs: jnp.ndarray          # [rho, eps, D]
    vid: jnp.ndarray           # [rho, eps] i32 (-1 pad)
    deg: jnp.ndarray           # [rho, eps] i32
    nbrs: jnp.ndarray          # [rho, eps, Lam] i32 (-1 pad)
    block_of: jnp.ndarray      # [N] i32
    pq_codes: jnp.ndarray      # [N, M] u8
    pq_cent: jnp.ndarray       # [M, K, dsub] f32
    nav_vecs: jnp.ndarray      # [n', D]
    nav_adj: jnp.ndarray       # [n', deg'] i32 (-1 pad)
    nav_ids: jnp.ndarray       # [n'] i32 global ids
    nav_entry: jnp.ndarray     # scalar i32 (nav-local)
    hot_vecs: jnp.ndarray      # [H, eps, D] tier-0 packed tiles
    hot_vid: jnp.ndarray       # [H, eps] i32
    hot_nbrs: jnp.ndarray      # [H, eps, Lam] i32
    hot_slot_of: jnp.ndarray   # [rho] i32 block -> hot slot (-1 = cold)


class DeviceSearchResult(NamedTuple):
    """Per-query outputs of ``device_anns``."""
    ids: jnp.ndarray           # [Q, k]
    dists: jnp.ndarray         # [Q, k]
    io: jnp.ndarray            # [Q] cold block touches (pre-dedup DMAs)
    hops: jnp.ndarray          # [Q] DMA round trips (fetch_width blocks each)
    tier0_hits: jnp.ndarray    # [Q] block touches served by the VMEM pack
    dedup_saved: jnp.ndarray   # [Q] cold touches that joined another
    #                            request's same-round gather — BATCH
    #                            scope, the union the kernel actually
    #                            dedups across (actual DMAs issued for
    #                            this query = io - dedup_saved)
    dedup_cross: jnp.ndarray   # [Q] the cross-tile subset of
    #                            ``dedup_saved``: joins onto a gather
    #                            first requested in ANOTHER round-kernel
    #                            query tile — what the batch-scope
    #                            rework (DESIGN.md §8) wins over
    #                            per-tile dedup (whose modeled DMAs =
    #                            io - (dedup_saved - dedup_cross))
    spec_hits: jnp.ndarray     # [Q] paying cold gathers (io -
    #                            dedup_saved) whose block the previous
    #                            round's speculative prediction already
    #                            put in flight (p.speculate, DESIGN.md
    #                            §9) — the DMA left the critical path;
    #                            zero when speculation is off
    spec_wasted: jnp.ndarray   # [Q] speculative gathers no request of
    #                            the next round consumed — extra DMA
    #                            bandwidth the cost model surcharges,
    #                            never a correctness event (the
    #                            authoritative round fetch re-gathers
    #                            misses: "never wrong, only late")
    rounds: jnp.ndarray        # scalar: loop rounds the batch ran
    #                            (hops / rounds = a query's occupancy)
    round_log: Optional[jnp.ndarray] = None
    #                            [max_hops, 8] i32 per-round trace buffer
    #                            (p.trace_rounds; repro.obs.roundlog —
    #                            cols live/cold/tier0/joins/joins_x/
    #                            compacted/spec_hits/spec_wasted; rows
    #                            >= ``rounds`` are unwritten). None when
    #                            tracing is off.


class DeviceRangeResult(NamedTuple):
    """Per-query outputs of ``device_range_search``."""
    ids: jnp.ndarray           # [Q, k_cap]
    dists: jnp.ndarray         # [Q, k_cap]
    in_range: jnp.ndarray      # [Q, k_cap] bool
    io: jnp.ndarray            # [Q] cold block touches across all rounds
    tier0_hits: jnp.ndarray    # [Q] tier-0 hits across all rounds
    dedup_saved: jnp.ndarray   # [Q] same-round dedup joins (batch
    #                            scope), all rounds
    dedup_cross: jnp.ndarray   # [Q] cross-tile subset of dedup_saved
    spec_hits: jnp.ndarray     # [Q] speculative pre-gathers consumed,
    #                            all RS rounds (the speculation state
    #                            drains at each RS re-entry)
    spec_wasted: jnp.ndarray   # [Q] speculative gathers never consumed
    rounds: jnp.ndarray        # scalar: total loop rounds, all RS rounds


def _tier0_pack(seg, num_blocks: int, observed=None, plan=None):
    """Select + pack the tier-0 hot set (host side, build time).

    ``observed`` (block id -> demand-read count, e.g. a serving
    ``CachedBlockStore.block_freq``) re-ranks the build-time selection
    by what the query stream actually touched — the dynamic-admission
    repack of a drifting workload. Selection goes through
    ``hotset.plan_tier0``, the same planner the serving scheduler
    prices drift with, so a plan and the pack it becomes can never
    diverge; a caller that already planned (the scheduler did, to gate
    on drift) passes ``plan`` and skips re-deriving the ranking."""
    from repro.io import hotset

    v = seg.view
    vecs = np.asarray(v.store.vecs)
    vid = np.asarray(v.store.vid)
    meta = np.asarray(v.store.meta)
    rho, eps = vid.shape
    hot: list = []
    if num_blocks > 0:
        if plan is not None:
            if len(plan) != min(num_blocks, rho):
                raise ValueError(
                    f"tier-0 plan selects {len(plan)} blocks for a "
                    f"{min(num_blocks, rho)}-slot budget")
            hot = [int(b) for b in plan]
        else:
            ranking = hotset.hot_block_ranking(
                v.layout.block_of, seg.graph.adj, seg.graph.deg,
                hotset.view_seed_ids(v))
            hot = hotset.plan_tier0(ranking, observed or {}, num_blocks,
                                    rho)
    slot_of = np.full(rho, -1, np.int32)
    if hot:
        hb = np.asarray(hot, np.int64)
        slot_of[hb] = np.arange(len(hot), dtype=np.int32)
        return (vecs[hb], vid[hb], meta[hb, :, 1:], slot_of)
    # sentinel pack: one zeroed slot the map never points at
    return (np.zeros((1,) + vecs.shape[1:], vecs.dtype),
            np.full((1, eps), -1, vid.dtype),
            np.full((1, eps, meta.shape[2] - 1), -1, meta.dtype),
            slot_of)


def from_segment(seg, tier0_blocks: Optional[int] = None,
                 tier0_frac: Optional[float] = None,
                 observed=None) -> DeviceSegment:
    """Host ``Segment`` -> device arrays.

    The tier-0 hot-tile budget comes from, in precedence order:
    ``tier0_blocks`` (explicit block count), ``tier0_frac`` (fraction
    of the block file), else ``seg.params.cache`` (the Eq. 10-charged
    configuration). Budget 0 packs the sentinel slot only — the search
    is then bit-identical to the seed's uncached device path *and* to
    any budgeted pack (the pack holds exact copies).

    ``observed`` re-ranks the pack from observed per-block demand
    frequencies (``hotset.repack_from_frequencies``) — dynamic tier-0
    admission for workloads that drifted away from the build-time
    entry-neighborhood prior. Results stay bit-identical for any pack
    (exact copies); only the io/tier0_hits split moves."""
    v = seg.view
    nav = v.nav
    if tier0_blocks is None:
        block_bytes = max(int(v.store.block_kb * 1024), 1)
        if tier0_frac is not None:
            tier0_blocks = int(tier0_frac * v.store.num_blocks)
        else:
            tier0_blocks = (seg.params.cache.resolve_tier0_budget(
                v.store.disk_bytes()) // block_bytes)
    hot_vecs, hot_vid, hot_nbrs, slot_of = _tier0_pack(
        seg, tier0_blocks, observed=observed)
    return DeviceSegment(
        vecs=jnp.asarray(v.store.vecs),
        vid=jnp.asarray(v.store.vid),
        deg=jnp.asarray(v.store.meta[:, :, 0]),
        nbrs=jnp.asarray(v.store.meta[:, :, 1:]),
        block_of=jnp.asarray(v.layout.block_of),
        pq_codes=jnp.asarray(v.pq_codes),
        pq_cent=jnp.asarray(v.pq_cb.centroids),
        nav_vecs=jnp.asarray(nav.vectors),
        nav_adj=jnp.asarray(nav.graph.adj),
        nav_ids=jnp.asarray(nav.sample_ids),
        nav_entry=jnp.asarray(nav.graph.entry, jnp.int32),
        hot_vecs=jnp.asarray(hot_vecs),
        hot_vid=jnp.asarray(hot_vid, jnp.int32),
        hot_nbrs=jnp.asarray(hot_nbrs, jnp.int32),
        hot_slot_of=jnp.asarray(slot_of, jnp.int32),
    )


def hot_pack_blocks(ds: DeviceSegment) -> set:
    """The block ids currently in the tier-0 pack (empty when tier 0 is
    disabled) — the one way every consumer (scheduler drift, repack,
    benches, tests) reads the pack, so the ``hot_slot_of`` sentinel
    encoding has a single point of truth."""
    return set(np.flatnonzero(np.asarray(ds.hot_slot_of) >= 0).tolist())


def repack_tier0(ds: DeviceSegment, seg, observed,
                 plan=None) -> Tuple[DeviceSegment, int]:
    """Rebuild ONLY the hot-tile pack of ``ds`` at its current budget,
    re-ranked by ``observed`` per-block demand counts (the serving
    scheduler's online repack, DESIGN.md §5). A caller that already
    ran ``hotset.plan_tier0`` (the scheduler, pricing drift) passes
    the ``plan`` to skip re-deriving the build ranking — an avoidable
    host-side BFS on the online path.

    Every other device array is reused as-is — a repack moves H block
    tiles, not the segment. Returns ``(new_ds, changed)`` where
    ``changed`` is the number of pack slots whose block differs from
    the old pack (the realized drift; 0 means the repack was a no-op
    and the returned segment holds the identical selection). The pack
    is exact copies either way, so results are bit-identical before
    and after — only the io/tier0_hits split moves."""
    old = hot_pack_blocks(ds)
    hot_vecs, hot_vid, hot_nbrs, slot_of = _tier0_pack(
        seg, len(old), observed=observed, plan=plan)
    new = set(np.flatnonzero(slot_of >= 0).tolist())
    out = dataclasses.replace(
        ds, hot_vecs=jnp.asarray(hot_vecs, ds.hot_vecs.dtype),
        hot_vid=jnp.asarray(hot_vid, jnp.int32),
        hot_nbrs=jnp.asarray(hot_nbrs, jnp.int32),
        hot_slot_of=jnp.asarray(slot_of, jnp.int32))
    return out, len(new - old)


def tier0_bytes(ds: DeviceSegment) -> int:
    """Bytes the hot-tile pack reserves on device (0 when disabled) —
    the C_tier0 the Eq. 10 accounting charges."""
    packed = int((np.asarray(ds.hot_slot_of) >= 0).sum())
    if packed == 0:
        return 0
    per_block = (ds.hot_vecs.nbytes + ds.hot_vid.nbytes
                 + ds.hot_nbrs.nbytes) // ds.hot_vecs.shape[0]
    return packed * int(per_block)


# ------------------------------------------------------------- utilities

def _dists(q: jnp.ndarray, x: jnp.ndarray, metric: str) -> jnp.ndarray:
    """q [Q, D] vs x [Q, E, D] -> [Q, E] (f32)."""
    q32, x32 = q.astype(jnp.float32), x.astype(jnp.float32)
    if metric == "ip":
        return -jnp.einsum("qd,qed->qe", q32, x32)
    return jnp.sum(jnp.square(x32 - q32[:, None, :]), axis=-1)


def _adc_lut(q: jnp.ndarray, cent: jnp.ndarray, metric: str) -> jnp.ndarray:
    """q [Q, D], cent [M, K, dsub] -> [Q, M, K]."""
    m, k, dsub = cent.shape
    qs = q.reshape(q.shape[0], m, 1, dsub).astype(jnp.float32)
    if metric == "ip":
        return -jnp.sum(cent[None] * qs, axis=-1)
    return jnp.sum(jnp.square(cent[None] - qs), axis=-1)


def _adc(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """lut [Q, M, K], codes [Q, I, M] -> [Q, I]."""
    idx = jnp.swapaxes(codes.astype(jnp.int32), 1, 2)      # [Q, M, I]
    got = jnp.take_along_axis(lut, idx, axis=2)            # [Q, M, I]
    return jnp.sum(got, axis=1)


def _merge_top(keys, ids, new_keys, new_ids, size: int, extra=None,
               new_extra=None):
    """Merge sorted-ish lists, dedupe by id, keep `size` smallest keys.

    keys/ids [Q, A], new_* [Q, B] -> [Q, size]. Invalid slots: id < 0,
    key = +inf. ``extra`` (optional int32 payload, e.g. visited flags)
    rides along."""
    k = jnp.concatenate([keys, new_keys], axis=1)
    i = jnp.concatenate([ids, new_ids], axis=1)
    e = (jnp.concatenate([extra, new_extra], axis=1)
         if extra is not None else None)
    # dedupe: sort by (id asc); duplicates adjacent; keep the first
    # occurrence with the *smallest key* -> sort by (id, key)
    order = jnp.lexsort((k, i))
    k = jnp.take_along_axis(k, order, axis=1)
    i = jnp.take_along_axis(i, order, axis=1)
    if e is not None:
        # keep the max extra among duplicates (visited wins): approximate
        # by taking the flag of the kept (first) occurrence after lexsort
        # with visited as secondary key desc would be ideal; visited
        # entries also carry +inf keys in our usage, so (id, key) order
        # already puts the live entry first.
        e = jnp.take_along_axis(e, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((i.shape[0], 1), bool), i[:, 1:] == i[:, :-1]], axis=1)
    dup |= i < 0
    k = jnp.where(dup, jnp.inf, k)
    i = jnp.where(dup, -1, i)
    order2 = jnp.argsort(k, axis=1)[:, :size]
    k = jnp.take_along_axis(k, order2, axis=1)
    i = jnp.take_along_axis(i, order2, axis=1)
    if e is not None:
        e = jnp.where(dup, 0, e)
        e = jnp.take_along_axis(e, order2, axis=1)
        return k, i, e
    return k, i


def _bit_get(mask: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """mask [Q, NB] u32, ids [Q, I] (>=0) -> [Q, I] bool."""
    word = jnp.take_along_axis(mask, (ids >> 5).astype(jnp.int32), axis=1)
    return ((word >> (ids & 31).astype(jnp.uint32)) & 1).astype(bool)


def _bit_set(mask: jnp.ndarray, ids: jnp.ndarray,
             on: jnp.ndarray) -> jnp.ndarray:
    """Set bits for ids [Q] where on [Q] (ids >= 0)."""
    q = mask.shape[0]
    word_idx = (ids >> 5).astype(jnp.int32)
    bit = (jnp.uint32(1) << (ids & 31).astype(jnp.uint32))
    bit = jnp.where(on, bit, 0).astype(jnp.uint32)
    cur = mask[jnp.arange(q), word_idx]
    return mask.at[jnp.arange(q), word_idx].set(cur | bit)


# -------------------------------------------------- navigation graph beam

def nav_entry_points(ds: DeviceSegment, queries: jnp.ndarray,
                     beam: int = 8, hops: int = 12, num: int = 4,
                     metric: str = "l2") -> jnp.ndarray:
    """Batched beam search on the in-memory navigation graph.
    Returns [Q, num] *global* entry ids (no block I/O involved)."""
    qn = queries.shape[0]
    d0 = _dists(queries, ds.nav_vecs[ds.nav_entry][None, None, :].repeat(
        qn, axis=0), metric)[:, 0]
    ids = jnp.full((qn, beam), -1, jnp.int32).at[:, 0].set(ds.nav_entry)
    keys = jnp.full((qn, beam), jnp.inf).at[:, 0].set(d0)
    expanded = jnp.zeros((qn, beam), bool)

    def body(_, state):
        ids, keys, expanded = state
        open_key = jnp.where(expanded | (ids < 0), jnp.inf, keys)
        pick = jnp.argmin(open_key, axis=1)                  # [Q]
        has_open = jnp.isfinite(
            jnp.take_along_axis(open_key, pick[:, None], axis=1))[:, 0]
        u = jnp.take_along_axis(ids, pick[:, None], axis=1)[:, 0]
        u_safe = jnp.maximum(u, 0)
        expanded = expanded.at[jnp.arange(qn), pick].set(
            expanded[jnp.arange(qn), pick] | has_open)
        nb = ds.nav_adj[u_safe]                              # [Q, deg']
        valid = (nb >= 0) & has_open[:, None]
        nb_safe = jnp.maximum(nb, 0)
        nd = _dists(queries, ds.nav_vecs[nb_safe], metric)
        nd = jnp.where(valid, nd, jnp.inf)
        nb_m = jnp.where(valid, nb, -1)
        keys, ids, expanded = _merge_top(
            keys, ids, nd, nb_m, beam,
            extra=expanded.astype(jnp.int32),
            new_extra=jnp.zeros(nb.shape, jnp.int32))
        return ids, keys, expanded.astype(bool)

    ids, keys, _ = jax.lax.fori_loop(0, hops, body, (ids, keys, expanded))
    top = ids[:, :num]
    return ds.nav_ids[jnp.maximum(top, 0)] * (top >= 0) + (-1) * (top < 0)


# ------------------------------------------------------ main block search

def _round_stage(ds: DeviceSegment, queries: jnp.ndarray, u: jnp.ndarray,
                 metric: str, impl: str, n_expand: int, tile: int,
                 pipeline_dma: bool, fuse_union: bool = False):
    """The fused per-round fetch pipeline (DR): tier-0 probe,
    batch-scope-deduped block gather, exact rank, and the per-query
    top-``n_expand`` expansion order — one pass.

    u [Q, F] picked candidate ids (-1 = converged/empty slot) ->
    (vid [Q, F*eps], nbrs [Q, F*eps, Lam], dists [Q, F*eps],
    hit [Q, F] i32, order [Q, n_expand]). ``impl='fused'`` runs the
    ``fused_round`` Pallas kernel (whole-batch deduped gather —
    double-buffered cold DMAs when ``pipeline_dma`` and compiled,
    in-kernel SMEM slot-map union when ``fuse_union`` —
    idle-tile skip at the ``tile`` granularity); ``'jnp'`` is the
    pure-jnp reference with straight per-request gathers —
    bit-identical payloads (dedup only changes which gather produced a
    tile, never its value; same f32 distance form, same stable-argsort
    tie-breaking)."""
    from repro import kernels as K

    if impl == "fused":
        dd, vid, nbrs, hit, order = K.fused_round(
            queries, u, ds.block_of, ds.hot_slot_of, ds.hot_vecs,
            ds.hot_vid, ds.hot_nbrs, ds.vecs, ds.vid, ds.nbrs,
            n_expand, metric=metric, bq=tile,
            pipeline_dma=pipeline_dma, fuse_union=fuse_union)
    else:
        from repro.kernels import ref
        dd, vid, nbrs, hit, order = ref.fused_round_ref(
            queries, u, ds.block_of, ds.hot_slot_of, ds.hot_vecs,
            ds.hot_vid, ds.hot_nbrs, ds.vecs, ds.vid, ds.nbrs,
            n_expand, metric=metric)
    return vid, nbrs, dd, hit, order


def _open_keys(cand_id: jnp.ndarray, cand_key: jnp.ndarray,
               visited: jnp.ndarray) -> jnp.ndarray:
    """Candidate keys with visited/invalid entries masked to +inf — the
    carried what's-still-expandable view; a query is *active* iff any
    entry is finite. Carrying it means the loop ``cond`` reads it for
    free instead of re-gathering the visited bitmask every round."""
    vis = _bit_get(visited, jnp.maximum(cand_id, 0)) | (cand_id < 0)
    return jnp.where(vis, jnp.inf, cand_key)


def _dedup_joins(b: jnp.ndarray, cold: jnp.ndarray, tile: int):
    """Mark cold block requests that join an earlier request's gather.

    b, cold [Q, F] -> (joined, joined_x) [Q, F] bool. ``joined`` is
    True where this round already gathers the block for an earlier
    (flat-order) cold request ANYWHERE in the batch — the whole-batch
    union scope the fused kernel's pass 1 dedups across; the first
    requester pays the DMA (stays in ``io``), joiners land in
    ``dedup_saved``. ``joined_x`` is the cross-tile subset: joins whose
    paying requester sits in a DIFFERENT round-kernel query tile
    (``kernels.round_tile``) — what batch scope wins over the old
    per-tile dedup. Both masks come from the same sentinel-keyed flat
    array through the shared ``kernels.dedup.join_mask`` (one row per
    tile for the intra mask, one whole-batch row for the total), so
    joined_x = joined & ~intra and intra ⊆ joined by the stable flat
    order — the accounting can never disagree with the kernel's union
    pass, which uses the same module."""
    from repro.kernels import dedup

    qn, fw = b.shape
    pad = (-qn) % tile
    bp = jnp.pad(b, ((0, pad), (0, 0)))
    cp = jnp.pad(cold, ((0, pad), (0, 0)))
    t = bp.shape[0] // tile
    r = tile * fw
    # non-cold slots get globally unique negative sentinels so they
    # never form duplicate groups in either scope
    flat = jnp.where(cp.reshape(-1), bp.reshape(-1),
                     -1 - jnp.arange(t * r, dtype=jnp.int32))
    intra = dedup.join_mask(flat.reshape(t, r)).reshape(-1)
    batch = dedup.join_mask(flat.reshape(1, t * r)).reshape(-1)
    cross = batch & ~intra
    return (batch[: qn * fw].reshape(qn, fw),
            cross[: qn * fw].reshape(qn, fw))


def _block_search_loop(ds: DeviceSegment, queries: jnp.ndarray, lut,
                       state, *, res_size: int, candidates: int,
                       sigma: float, max_hops: int, metric: str,
                       fetch_width: int, fetch_impl: str,
                       compact_frac: float = 0.0, trace: bool = False,
                       pipeline_dma: bool = False,
                       round_tile_cap: int = 0,
                       speculate: bool = False,
                       fuse_union: bool = False):
    """The batched best-first block search from a given carried state.

    ``state`` = (cand_id, cand_key, open_key, visited, res_id, res_key,
    io, t0, hops, saved, saved_x, t); the range-search driver re-enters with the
    previous round's ``visited``/result arrays so already-expanded
    vertices are never re-fetched (PR 2's host RS resume fix, device
    formulation). ``open_key`` (``_open_keys``) is the carried active
    view: the loop condition and the pick stage read it directly
    instead of re-probing the visited bitmask every round.

    ``compact_frac`` > 0 (jit-static) turns on divergence compaction:
    rounds whose live fraction fell below the threshold stably repack
    live queries to the front — converged queries then fill whole
    round-kernel tiles, which the fused kernel skips. The permuted
    ``queries``/``lut`` rows are *carried* in the loop state and every
    permutation gather lives behind a ``lax.cond`` on the compaction
    trigger, so a round with no repack does zero extra gathers (idle
    rounds are free — ROADMAP (a)); only the round that actually
    compacts pays the sort + re-gather. The permutation is inverted
    before returning, so callers see original query order either
    way.

    ``trace`` (jit-static) carries a ``[max_hops, 8] i32`` per-round
    buffer (``repro.obs.roundlog`` columns: live, cold, tier0, joins,
    joins_x, compacted, spec_hits, spec_wasted) written once per round
    from the same masks the counters
    sum — a lossless refinement, so the log's column sums equal the
    counter totals by construction. The buffer's round axis is never
    permuted by compaction (its rows are batch-level sums, which are
    permutation-invariant). Returns ``(state, round_log)``; the log is
    ``None`` when tracing is off, and the counters/results are
    bit-identical either way (the trace writes are pure additions to
    the dataflow).

    ``speculate`` (jit-static, DESIGN.md §9) carries two-slot
    speculation state in the loop — per-query hit/wasted counters plus
    the ``[Q, F]`` block prediction staged by the previous round. Each
    round first *consumes* the staged prediction against its
    authoritative requests (a paying cold gather whose block was
    predicted is a ``spec_hit``: its DMA was already in flight during
    the previous round's expansion/top-M maintenance; a predicted
    block no cold request of the query consumes is ``spec_wasted``),
    then *stages* the next round's prediction from the neighbors it
    just PQ-routed — before the merged candidate pool resolves, which
    is exactly why the prediction is fallible and why it overlaps the
    maintenance stage. Every speculation branch is pure accounting
    over the same masks the counters already sum: the authoritative
    fetch is untouched, so (ids, dists) and every other counter are
    bit-identical to ``speculate=False``, and the loop jaxpr without
    the knob is unchanged. The final round's staged prediction is
    dropped unconsumed (modeled as issued at the consume boundary —
    a search that ends never issues it, so it is not wasted DMA).

    ``fuse_union`` (jit-static) selects the in-kernel SMEM slot-map
    union of the round kernel (``kernels.tier0_fetch.gather_union``)
    over the two-pass pass-1 union — bit-identical either way."""
    qn = queries.shape[0]
    eps = ds.vid.shape[1]
    fw = max(fetch_width, 1)
    n_expand = fw * (1 + max(int(np.ceil((eps - 1) * sigma)), 0))
    from repro import kernels as K
    tile = K.round_tile(qn, round_tile_cap)
    compact = compact_frac > 0.0

    def cond(st):
        open_key, t = st[2], st[-1]
        return jnp.isfinite(open_key).any() & (t < max_hops)

    def body(st):
        (cand_id, cand_key, open_key, visited, res_id, res_key,
         io, t0, hops, saved, saved_x) = st[:11]
        pos = 11
        if speculate:
            spec_h, spec_w, spec_blk = st[11:14]
            pos = 14
        if compact:
            perm, q_r, lut_r = st[pos:pos + 3]
            pos += 3
        if trace:
            rlog = st[pos]
            pos += 1
        t = st[-1]

        # --- active mask + optional live-query compaction
        live = jnp.isfinite(open_key).any(axis=1)            # [Q]
        fired = jnp.asarray(False)
        if compact:
            frac = live.astype(jnp.float32).mean()
            # repack only when the live rows are no longer front-packed
            # (a dead row sits before a live one): once compacted, the
            # carried order STAYS compacted until another query
            # converges mid-front, so the sort + permutation gathers
            # run only on rounds that actually change the packing —
            # every other round takes the identity branch for free
            unpacked = (jnp.any(jnp.logical_not(live[:-1]) & live[1:])
                        if qn > 1 else jnp.asarray(False))
            fired = (frac < compact_frac) & unpacked
            # every carried array is per-query along axis 0 — the
            # speculation trio (when on) rides the same permutation,
            # so a staged prediction follows its query through a repack
            carried = (cand_id, cand_key, open_key, visited, res_id,
                       res_key, io, t0, hops, saved, saved_x) \
                + ((spec_h, spec_w, spec_blk) if speculate else ()) \
                + (perm, q_r, lut_r)

            def _repack(arrs):
                # stable: live first, original order within each group;
                # the carried q_r/lut_r rows ride the same permutation,
                # so no later round ever re-gathers queries[perm]
                ordr = jnp.argsort(jnp.logical_not(live))
                return tuple(jnp.take(a, ordr, axis=0) for a in arrs)

            carried = jax.lax.cond(fired, _repack,
                                   lambda arrs: arrs, carried)
            (cand_id, cand_key, open_key, visited, res_id, res_key,
             io, t0, hops, saved, saved_x) = carried[:11]
            if speculate:
                spec_h, spec_w, spec_blk = carried[11:14]
            perm, q_r, lut_r = carried[-3:]
        else:
            q_r, lut_r = queries, lut

        # --- pick the F best open candidates per query (converged
        # queries pick nothing: every slot carries the -1 sentinel)
        neg_top, picks = jax.lax.top_k(-open_key, fw)        # [Q, F]
        f_active = jnp.isfinite(-neg_top)                    # [Q, F]
        active = f_active[:, 0]
        u = jnp.take_along_axis(cand_id, picks, axis=1)      # [Q, F]
        u = jnp.where(f_active, u, -1)
        b = ds.block_of[jnp.maximum(u, 0)]                   # [Q, F]

        # --- DR round stage: probe tier 0, dedup + gather the round's
        # block union, rank, and order expansions — one fused pass
        vid, nbrs, dd, hit, order = _round_stage(
            ds, q_r, u, metric, fetch_impl, n_expand, tile,
            pipeline_dma, fuse_union)
        hot = hit.astype(bool) & f_active
        cold = f_active & ~hot
        joined, joined_x = _dedup_joins(b, cold, tile)       # [Q, F]
        io = io + cold.sum(axis=1).astype(jnp.int32)
        t0 = t0 + hot.sum(axis=1).astype(jnp.int32)
        saved = saved + joined.sum(axis=1).astype(jnp.int32)
        saved_x = saved_x + joined_x.sum(axis=1).astype(jnp.int32)
        hops = hops + active.astype(jnp.int32)               # round trips

        if speculate:
            # --- consume the prediction the previous round staged,
            # against this round's authoritative requests. A PAYING
            # cold gather (cold & ~joined — the DMAs the cost model
            # prices) whose block was predicted is a hit: its copy was
            # already in flight while the previous round's expansion /
            # top-M maintenance ran, so the DMA left the critical
            # path. A predicted block that matches NO cold request of
            # its query is wasted bandwidth (a matched-but-joined
            # request is neither: its gather was already someone
            # else's). Charged at consume time, so the trace row below
            # sums to exactly these per-query increments.
            pred_match = (b[:, :, None]
                          == spec_blk[:, None, :]).any(-1)   # [Q, F]
            hit_spec = cold & ~joined & pred_match
            used = ((spec_blk[:, :, None]
                     == jnp.where(cold, b, -1)[:, None, :]).any(-1)
                    & (spec_blk >= 0))                       # [Q, F]
            sh_r = hit_spec.sum(axis=1).astype(jnp.int32)
            sw_r = ((spec_blk >= 0) & ~used).sum(
                axis=1).astype(jnp.int32)
            spec_h = spec_h + sh_r
            spec_w = spec_w + sw_r

        if trace:
            # the round's row is the batch-level sum of exactly the
            # masks the per-query counters just accumulated, so the
            # log's column sums equal the counter totals identically
            # (the fold invariant tests/test_trace_roundlog.py pins);
            # sums are permutation-invariant, so compaction is moot
            spec_cols = ((sh_r.sum().astype(jnp.int32),
                          sw_r.sum().astype(jnp.int32)) if speculate
                         else (jnp.zeros((), jnp.int32),
                               jnp.zeros((), jnp.int32)))
            rlog = rlog.at[t].set(jnp.stack([
                active.sum().astype(jnp.int32),
                cold.sum().astype(jnp.int32),
                hot.sum().astype(jnp.int32),
                joined.sum().astype(jnp.int32),
                joined_x.sum().astype(jnp.int32),
                fired.astype(jnp.int32), *spec_cols]))

        # --- DC: fold the exact-ranked residents into results
        f_valid = jnp.repeat(f_active, eps, axis=1)
        slot_valid = (vid >= 0) & f_valid
        dd_m = jnp.where(slot_valid, dd, jnp.inf)
        res_key, res_id = _merge_top(res_key, res_id, dd_m,
                                     jnp.where(slot_valid, vid, -1),
                                     res_size)

        # --- block pruning: targets + top-((eps-1)*sigma), in the
        # expansion order the round stage already ranked
        is_target = (vid[:, :, None] == u[:, None, :]).any(-1) \
            & (vid >= 0)
        sel_key = jnp.where(is_target, -jnp.inf, dd_m)
        ex_id = jnp.take_along_axis(vid, order, axis=1)
        ex_valid = (jnp.take_along_axis(sel_key, order, axis=1)
                    < jnp.inf) & active[:, None] & (ex_id >= 0)
        ex_new = ex_valid & ~_bit_get(visited, jnp.maximum(ex_id, 0))
        for j in range(n_expand):                            # mark expanded
            visited = _bit_set(visited, jnp.maximum(ex_id[:, j], 0),
                               ex_new[:, j])

        # --- collect neighbors of expanded slots, route by PQ
        ex_nbrs = jnp.take_along_axis(
            nbrs, order[:, :, None], axis=1)                 # [Q, X, Lam]
        flat = ex_nbrs.reshape(qn, -1)
        f_valid = (flat >= 0) & ex_new.repeat(
            ex_nbrs.shape[2], axis=1) & active[:, None]
        f_safe = jnp.maximum(flat, 0)
        f_valid &= ~_bit_get(visited, f_safe)                # skip expanded
        f_codes = ds.pq_codes[f_safe]                        # [Q, F, M]
        f_key = jnp.where(f_valid, _adc(lut_r, f_codes), jnp.inf)
        f_id = jnp.where(f_valid, flat, -1)
        if speculate:
            # --- stage the NEXT round's prediction from the neighbors
            # this round just PQ-routed — before they merge into the
            # candidate pool, which is why the speculative gather can
            # overlap the top-M maintenance below (and why it can
            # miss: the merged pool may still prefer an older
            # candidate). Hot-pack blocks never issue a speculative
            # DMA (a tier-0 hit needs none), and duplicate slot
            # predictions collapse so one block never double-counts.
            neg_p, p_pick = jax.lax.top_k(-f_key, fw)        # [Q, F]
            p_id = jnp.take_along_axis(f_id, p_pick, axis=1)
            p_ok = (jnp.isfinite(-neg_p) & (p_id >= 0)
                    & active[:, None])
            p_blk = jnp.where(p_ok,
                              ds.block_of[jnp.maximum(p_id, 0)], -1)
            p_hot = ds.hot_slot_of[jnp.maximum(p_blk, 0)] >= 0
            p_blk = jnp.where(p_hot & (p_blk >= 0), -1, p_blk)
            dup = ((p_blk[:, :, None] == p_blk[:, None, :])
                   & (jnp.arange(fw)[None, :, None]
                      > jnp.arange(fw)[None, None, :])).any(-1)
            spec_blk = jnp.where(dup & (p_blk >= 0), -1,
                                 p_blk).astype(jnp.int32)

        cand_key, cand_id = _merge_top(cand_key, cand_id, f_key, f_id,
                                       candidates)
        open_key = _open_keys(cand_id, cand_key, visited)
        out = (cand_id, cand_key, open_key, visited, res_id, res_key,
               io, t0, hops, saved, saved_x)
        if speculate:
            out = out + (spec_h, spec_w, spec_blk)
        if compact:
            out = out + (perm, q_r, lut_r)
        if trace:
            out = out + (rlog,)
        return out + (t + 1,)

    # extended state: core11 + (spec_h, spec_w, spec_blk | speculate)
    #                        + (perm, queries, lut | compact)
    #                        + (round log | trace) + (t,)
    st = state[:-1]
    if speculate:
        st = st + (jnp.zeros((qn,), jnp.int32),
                   jnp.zeros((qn,), jnp.int32),
                   jnp.full((qn, fw), -1, jnp.int32))
    if compact:
        st = st + (jnp.arange(qn, dtype=jnp.int32), queries, lut)
    if trace:
        st = st + (jnp.zeros((max_hops, len(_ROUND_LOG_COLS)),
                             jnp.int32),)
    out = jax.lax.while_loop(cond, body, st + (state[-1],))
    nper = 14 if speculate else 11           # per-query carried arrays
    arrs = out[:nper]
    pos = nper
    if compact:
        perm = out[nper]
        pos = nper + 3
        inv = jnp.argsort(perm)              # undo the compaction order
        arrs = tuple(jnp.take(a, inv, axis=0) for a in arrs)
    rlog = None
    if trace:
        rlog = out[pos]                      # round axis: never permuted
    if speculate:
        # drop the final round's staged-but-unconsumed prediction (its
        # DMA is modeled as issued at the consume boundary, which a
        # finished search never reaches); keep the hit/wasted counters
        arrs = arrs[:13]
    return arrs + (out[-1],), rlog


DEFAULT_DEVICE_SEARCH = DeviceSearchParams()


@functools.partial(jax.jit, static_argnames=("p", "metric"))
def device_anns(ds: DeviceSegment, queries: jnp.ndarray,
                p: DeviceSearchParams = DEFAULT_DEVICE_SEARCH,
                metric: str = "l2",
                seeds: Optional[jnp.ndarray] = None
                ) -> DeviceSearchResult:
    """Batched Starling ANNS on one segment shard.

    ``p.fetch_width`` > 1 fetches the F best unvisited candidates'
    blocks per round-trip (beyond-paper: the paper's Central Assumption
    notes a few random reads per SSD/DMA round-trip cost about the same
    as one — this trades block-bandwidth for round-trip latency).

    ``seeds`` [Q, S] int32 (−1-padded) is the seed-override path
    (hot/cold hybrid routing, DESIGN.md §10): when given, the
    navigation-graph entry pick is skipped entirely and the search
    seeds from these vertex ids instead — the hot tier hands its exit
    frontier here, so the cold search resumes where the memory tier
    converged. Rows that are all −1 fall back to nowhere (the caller
    guarantees at least one live seed per query).

    Returns ``DeviceSearchResult(ids [Q, k], dists [Q, k], io [Q] cold
    block touches, hops [Q] round trips, tier0_hits [Q], dedup_saved
    [Q], rounds)``. Tier-0 budget moves touches from ``io`` to
    ``tier0_hits``; cross-query dedup moves actual DMAs from ``io`` to
    ``dedup_saved`` (``io`` still counts every cold touch, so its
    semantics — and the io+tier0 block-touch total — are unchanged);
    neither changes (ids, dists) — asserted in tests and the
    device_bench sweeps."""
    qn, d = queries.shape
    eps = ds.vid.shape[1]
    n = ds.block_of.shape[0]
    nb_words = -(-n // 32)
    fw = max(p.fetch_width, 1)
    res_size = p.k + 2 * eps * fw
    queries = queries.astype(jnp.float32)

    lut = _adc_lut(queries, ds.pq_cent, metric)              # [Q, M, K]
    if seeds is not None:
        entry = seeds.astype(jnp.int32)
    else:
        entry = nav_entry_points(ds, queries, beam=p.nav_beam,
                                 hops=p.nav_hops, num=p.entry_points,
                                 metric=metric)
    e_codes = ds.pq_codes[jnp.maximum(entry, 0)]
    e_key = jnp.where(entry >= 0, _adc(lut, e_codes), jnp.inf)

    cand_id = jnp.full((qn, p.candidates), -1, jnp.int32)
    cand_key = jnp.full((qn, p.candidates), jnp.inf)
    cand_key, cand_id = _merge_top(cand_key, cand_id, e_key, entry,
                                   p.candidates)
    visited = jnp.zeros((qn, nb_words), jnp.uint32)          # expanded set
    state = (cand_id, cand_key,
             _open_keys(cand_id, cand_key, visited),
             visited,
             jnp.full((qn, res_size), -1, jnp.int32),
             jnp.full((qn, res_size), jnp.inf),
             jnp.zeros((qn,), jnp.int32),                    # io
             jnp.zeros((qn,), jnp.int32),                    # tier-0 hits
             jnp.zeros((qn,), jnp.int32),                    # hops
             jnp.zeros((qn,), jnp.int32),                    # dedup joins
             jnp.zeros((qn,), jnp.int32),                    # cross-tile
             jnp.zeros((), jnp.int32))
    state, rlog = _block_search_loop(
        ds, queries, lut, state, res_size=res_size,
        candidates=p.candidates, sigma=p.sigma, max_hops=p.max_hops,
        metric=metric, fetch_width=fw, fetch_impl=p.fetch_impl,
        compact_frac=p.compact_frac, trace=p.trace_rounds,
        pipeline_dma=p.pipeline_dma,
        round_tile_cap=p.round_tile_cap,
        speculate=p.speculate, fuse_union=p.fuse_union)
    if p.speculate:
        (_, _, _, _, res_id, res_key, io, t0, hops, saved, saved_x,
         spec_h, spec_w, t) = state
    else:
        (_, _, _, _, res_id, res_key, io, t0, hops, saved, saved_x,
         t) = state
        spec_h = jnp.zeros((qn,), jnp.int32)
        spec_w = jnp.zeros((qn,), jnp.int32)
    return DeviceSearchResult(res_id[:, : p.k], res_key[:, : p.k], io,
                              hops, t0, saved, saved_x, spec_h, spec_w,
                              t, rlog)


# --------------------------------------------- production mesh search step

def merge_shard_topk(gids: jnp.ndarray, gd: jnp.ndarray,
                     k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Merge stacked per-shard results on device: ``gids``/``gd``
    [S, Q, kk] (global ids, -1 = invalid; dists, inf on invalid) ->
    ([Q, k], [Q, k]) global top-k.

    Ordering is (dist, global id) with invalid ids keyed past every
    real id — the SAME total order the host ``serving.merge_topk``
    sorts by, so a device-merged mesh fan-out and a host-merged concat
    over the same shards are bit-identical, independent of shard
    arrival order or placement (an argsort by position is NOT: moving
    a segment to another rank would reorder equal-distance ties)."""
    s, q, kk = gids.shape
    flat_i = jnp.moveaxis(gids, 0, 1).reshape(q, s * kk)
    flat_d = jnp.moveaxis(gd, 0, 1).reshape(q, s * kk)
    flat_d = jnp.where(flat_i >= 0, flat_d, jnp.inf)
    key_id = jnp.where(flat_i >= 0, flat_i,
                       jnp.iinfo(flat_i.dtype).max)
    # lexsort: last key is primary -> (dist, then id on ties)
    order = jnp.lexsort((key_id, flat_d))[:, :k]
    return (jnp.take_along_axis(flat_i, order, axis=1),
            jnp.take_along_axis(flat_d, order, axis=1))


def stack_segments(segments) -> DeviceSegment:
    """Stack same-shape segment shards along a new leading axis — the
    [W, ...] tree ``make_search_step``/the mesh router shard over the
    ``model`` axis (one shard per rank; replicas are repeated
    entries). All shards must agree on every array's shape and dtype
    so a restack after a rebalance reuses the same compiled
    executable (the mesh analogue of ``repack_tier0``'s same-shape
    in-place swap)."""
    if not segments:
        raise ValueError("stack_segments needs at least one shard")
    first = segments[0]
    for idx, seg in enumerate(segments[1:], 1):
        for f in dataclasses.fields(DeviceSegment):
            a, b = getattr(first, f.name), getattr(seg, f.name)
            if a.shape != b.shape or a.dtype != b.dtype:
                raise ValueError(
                    f"segment shard {idx} field {f.name!r} is "
                    f"{b.shape}/{b.dtype}, shard 0 has "
                    f"{a.shape}/{a.dtype} — mesh shards must be "
                    "shape-identical (pad segments to a common size)")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *segments)

def make_search_step(mesh, rules, *,
                     n_local: int = 1 << 21, dim: int = 128,
                     eps: int = 16, lam: int = 31, q_global: int = 4096,
                     pq_m: int = 16, pq_k: int = 256,
                     nav_frac: int = 64, nav_deg: int = 12,
                     search: Optional[DeviceSearchParams] = None):
    """Build (fn, arg ShapeDtypeStructs) for the segment-search dry-run.

    Layout: every ``model`` rank owns an independent sub-segment of
    ``n_local`` vectors (16 ranks x 2M = 33M vectors per pod row — the
    paper's segment scale); queries are sharded over ``data`` (x ``pod``)
    and replicated over ``model``. The step runs the local block search
    via shard_map and merges per-segment top-k with one all-gather over
    ``model``.

    ``search`` carries every online knob (today's production defaults
    when omitted): Γ, σ, fetch width, nav beam, compaction — and the
    tier-0 budget, which sizes the per-rank hot-tile pack in the
    argument specs. The step returns (gid, dists, io, hops,
    tier0_hits, dedup_saved, dedup_cross, spec_hits, spec_wasted); the
    per-rank io/hops/tier-0/dedup/speculation columns land in the
    ``(data, model)``-sharded
    outputs — the mesh-level QPS fold in ``benchmarks/paper_tables.py``
    consumes exactly these."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:                    # older jax releases
        from jax.experimental.shard_map import shard_map

    if search is None:
        search = DeviceSearchParams(candidates=64, max_hops=128)
    model_n = mesh.shape["model"]
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    rho = n_local // eps
    hot_n = max(int(search.tier0_frac * rho), 1)
    nav_n = n_local // nav_frac
    dsub = dim // pq_m

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(
            shape, dtype, sharding=NamedSharding(mesh, spec))

    seg_specs = DeviceSegment(
        vecs=sds((model_n, rho, eps, dim), jnp.bfloat16, P("model")),
        vid=sds((model_n, rho, eps), jnp.int32, P("model")),
        deg=sds((model_n, rho, eps), jnp.int32, P("model")),
        nbrs=sds((model_n, rho, eps, lam), jnp.int32, P("model")),
        block_of=sds((model_n, n_local), jnp.int32, P("model")),
        pq_codes=sds((model_n, n_local, pq_m), jnp.uint8, P("model")),
        pq_cent=sds((model_n, pq_m, pq_k, dsub), jnp.float32, P("model")),
        nav_vecs=sds((model_n, nav_n, dim), jnp.float32, P("model")),
        nav_adj=sds((model_n, nav_n, nav_deg), jnp.int32, P("model")),
        nav_ids=sds((model_n, nav_n), jnp.int32, P("model")),
        nav_entry=sds((model_n,), jnp.int32, P("model")),
        hot_vecs=sds((model_n, hot_n, eps, dim), jnp.bfloat16,
                     P("model")),
        hot_vid=sds((model_n, hot_n, eps), jnp.int32, P("model")),
        hot_nbrs=sds((model_n, hot_n, eps, lam), jnp.int32, P("model")),
        hot_slot_of=sds((model_n, rho), jnp.int32, P("model")),
    )
    q_specs = sds((q_global, dim), jnp.float32, P(data_axes))

    in_specs = (DeviceSegment(
        vecs=P("model"), vid=P("model"), deg=P("model"), nbrs=P("model"),
        block_of=P("model"), pq_codes=P("model"), pq_cent=P("model"),
        nav_vecs=P("model"), nav_adj=P("model"), nav_ids=P("model"),
        nav_entry=P("model"), hot_vecs=P("model"), hot_vid=P("model"),
        hot_nbrs=P("model"), hot_slot_of=P("model")), P(data_axes))
    out_specs = (P(data_axes), P(data_axes), P(data_axes, "model"),
                 P(data_axes, "model"), P(data_axes, "model"),
                 P(data_axes, "model"), P(data_axes, "model"),
                 P(data_axes, "model"), P(data_axes, "model"))

    def local_search(seg: DeviceSegment, queries):
        seg = jax.tree.map(lambda a: a[0], seg)      # strip shard dim
        seg = dataclasses.replace(
            seg, vecs=seg.vecs.astype(jnp.float32),
            hot_vecs=seg.hot_vecs.astype(jnp.float32))
        r = device_anns(seg, queries, search)
        ids, dists = r.ids, r.dists
        # hierarchical top-k merge over segment ranks: all-gather k
        # results per rank (O(k) bytes cross-rank, not O(Gamma)),
        # merged in the shared (dist, global id) order so the result
        # is placement-invariant and bit-identical to the host
        # ``serving.merge_topk`` concat over the same shards
        gids = jax.lax.all_gather(ids, "model")      # [S, Q, k]
        gd = jax.lax.all_gather(dists, "model")
        s, _, kk = gids.shape
        # global id = segment rank * n_local + local id
        seg_of = jnp.arange(s, dtype=jnp.int32)[:, None, None]
        glob = jnp.where(gids >= 0, seg_of * n_local + gids, -1)
        gid, out_d = merge_shard_topk(glob, gd, kk)
        col = jnp.ones((1, 1), jnp.int32)
        return (gid, out_d, r.io[:, None] * col, r.hops[:, None] * col,
                r.tier0_hits[:, None] * col,
                r.dedup_saved[:, None] * col,
                r.dedup_cross[:, None] * col,
                r.spec_hits[:, None] * col,
                r.spec_wasted[:, None] * col)

    import inspect
    flag = ("check_vma" if "check_vma"
            in inspect.signature(shard_map).parameters else "check_rep")
    fn = shard_map(local_search, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, **{flag: False})
    return fn, (seg_specs, q_specs)


# ---------------------------------------------------------- range search

@functools.partial(jax.jit, static_argnames=(
    "radius", "k_cap", "p", "metric", "rounds", "ratio"))
def device_range_search(ds: DeviceSegment, queries: jnp.ndarray,
                        radius: float, k_cap: int = 256,
                        p: DeviceSearchParams = DEFAULT_DEVICE_SEARCH,
                        metric: str = "l2",
                        rounds: int = 3, ratio: float = 0.5
                        ) -> DeviceRangeResult:
    """Batched RS (§5.3 semantics, device formulation): ANNS rounds with
    a doubling candidate set; stop growing a query's set once the
    in-range fraction of its results drops below ``ratio`` (handled by
    the ratio mask on the host serving layer — rounds are compile-time
    unrolled here).

    The ``visited`` bitmask and result arrays thread through the rounds
    (the device analogue of the host RS resume fix): a later round
    re-seeds its candidate set from the previous round's results but
    never re-expands — so never re-fetches, and never re-counts in
    ``io`` — a block whose vertex an earlier round already expanded.

    ``p.speculate`` carries through each inner ANNS loop; the staged
    prediction drains at every RS re-entry (the pipeline has a hard
    barrier at the doubling boundary — the next round's candidate set
    is re-seeded host-side), while the hit/wasted counters accumulate
    across rounds.
    """
    qn = queries.shape[0]
    n = ds.block_of.shape[0]
    eps = ds.vid.shape[1]
    nb_words = -(-n // 32)
    fw = max(p.fetch_width, 1)
    queries = queries.astype(jnp.float32)
    lut = _adc_lut(queries, ds.pq_cent, metric)

    entry = nav_entry_points(ds, queries, beam=p.nav_beam,
                             hops=p.nav_hops, num=p.entry_points,
                             metric=metric)
    e_codes = ds.pq_codes[jnp.maximum(entry, 0)]
    e_key = jnp.where(entry >= 0, _adc(lut, e_codes), jnp.inf)

    visited = jnp.zeros((qn, nb_words), jnp.uint32)
    res_id = jnp.zeros((qn, 0), jnp.int32)
    res_key = jnp.zeros((qn, 0), jnp.float32)
    io = jnp.zeros((qn,), jnp.int32)
    t0 = jnp.zeros((qn,), jnp.int32)
    hops = jnp.zeros((qn,), jnp.int32)
    saved = jnp.zeros((qn,), jnp.int32)
    saved_x = jnp.zeros((qn,), jnp.int32)
    spec_h = jnp.zeros((qn,), jnp.int32)
    spec_w = jnp.zeros((qn,), jnp.int32)
    total_rounds = jnp.zeros((), jnp.int32)
    seed_id, seed_key = entry, e_key

    c = p.candidates
    for rnd in range(rounds):
        k_r = min(k_cap, c)
        res_size = k_r + 2 * eps * fw
        cand_id = jnp.full((qn, c), -1, jnp.int32)
        cand_key = jnp.full((qn, c), jnp.inf)
        cand_key, cand_id = _merge_top(cand_key, cand_id, seed_key,
                                       seed_id, c)
        r_id = jnp.full((qn, res_size), -1, jnp.int32)
        r_key = jnp.full((qn, res_size), jnp.inf)
        if res_id.shape[1]:
            r_key, r_id = _merge_top(r_key, r_id, res_key, res_id,
                                     res_size)
        state = (cand_id, cand_key,
                 _open_keys(cand_id, cand_key, visited), visited,
                 r_id, r_key, io, t0, hops, saved, saved_x,
                 jnp.zeros((), jnp.int32))
        # trace stays off here: RS re-enters the loop per round, so a
        # stitched multi-round log has no single ``rounds`` to fold
        # against — the ANNS path is the traced one
        state, _ = _block_search_loop(
            ds, queries, lut, state, res_size=res_size, candidates=c,
            sigma=p.sigma, max_hops=p.max_hops, metric=metric,
            fetch_width=fw, fetch_impl=p.fetch_impl,
            compact_frac=p.compact_frac, trace=False,
            pipeline_dma=p.pipeline_dma,
            round_tile_cap=p.round_tile_cap,
            speculate=p.speculate, fuse_union=p.fuse_union)
        if p.speculate:
            (_, _, _, visited, res_id, res_key, io, t0, hops, saved,
             saved_x, sh_r, sw_r, t) = state
            spec_h = spec_h + sh_r
            spec_w = spec_w + sw_r
        else:
            (_, _, _, visited, res_id, res_key, io, t0, hops, saved,
             saved_x, t) = state
        total_rounds = total_rounds + t
        if c * 2 > k_cap:
            break
        c *= 2
        # next round resumes from this round's frontier: results whose
        # vertices were ranked but never expanded are live candidates
        # under the carried ``visited`` mask (expanded ones mask out)
        seed_id, seed_key = res_id, res_key

    ids, dists = res_id[:, :k_cap], res_key[:, :k_cap]
    pad = k_cap - ids.shape[1]
    if pad > 0:
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        dists = jnp.pad(dists, ((0, 0), (0, pad)),
                        constant_values=jnp.inf)
    return DeviceRangeResult(ids, dists, dists <= radius, io, t0,
                             saved, saved_x, spec_h, spec_w,
                             total_rounds)
