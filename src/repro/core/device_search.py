"""Device-side batched Starling search (the TPU product of DESIGN.md §2).

The host implementation (``core/search.py``) is the per-query oracle; this
module is the batched, jit'd production path:

  * one ``lax.while_loop`` over hops for a whole query batch;
  * each hop gathers one block tile per query (the HBM->VMEM DMA that
    models one 4 KB disk read), exact-ranks all resident vertices
    (the ``block_topk`` kernel semantics), expands the sigma-pruned best
    residents, and routes new candidates by memory-resident PQ-ADC;
  * entry points come from an in-memory navigation-graph beam search;
  * per-query block-DMA counters are carried exactly (the paper's
    "mean I/Os").

Distribution (``make_search_step``): segment-parallel over the ``model``
mesh axis (each rank owns an independent sub-segment, Fig. 1(b)),
query-parallel over ``data`` (+ ``pod``); a top-k merge (all-gather +
sort over ``model``) combines per-segment results — the only collective
in the step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = dict


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeviceSegment:
    """One segment shard, fully device-resident."""
    vecs: jnp.ndarray          # [rho, eps, D]
    vid: jnp.ndarray           # [rho, eps] i32 (-1 pad)
    deg: jnp.ndarray           # [rho, eps] i32
    nbrs: jnp.ndarray          # [rho, eps, Lam] i32 (-1 pad)
    block_of: jnp.ndarray      # [N] i32
    pq_codes: jnp.ndarray      # [N, M] u8
    pq_cent: jnp.ndarray       # [M, K, dsub] f32
    nav_vecs: jnp.ndarray      # [n', D]
    nav_adj: jnp.ndarray       # [n', deg'] i32 (-1 pad)
    nav_ids: jnp.ndarray       # [n'] i32 global ids
    nav_entry: jnp.ndarray     # scalar i32 (nav-local)


def from_segment(seg) -> DeviceSegment:
    """Host ``Segment`` -> device arrays."""
    v = seg.view
    nav = v.nav
    return DeviceSegment(
        vecs=jnp.asarray(v.store.vecs),
        vid=jnp.asarray(v.store.vid),
        deg=jnp.asarray(v.store.meta[:, :, 0]),
        nbrs=jnp.asarray(v.store.meta[:, :, 1:]),
        block_of=jnp.asarray(v.layout.block_of),
        pq_codes=jnp.asarray(v.pq_codes),
        pq_cent=jnp.asarray(v.pq_cb.centroids),
        nav_vecs=jnp.asarray(nav.vectors),
        nav_adj=jnp.asarray(nav.graph.adj),
        nav_ids=jnp.asarray(nav.sample_ids),
        nav_entry=jnp.asarray(nav.graph.entry, jnp.int32),
    )


# ------------------------------------------------------------- utilities

def _dists(q: jnp.ndarray, x: jnp.ndarray, metric: str) -> jnp.ndarray:
    """q [Q, D] vs x [Q, E, D] -> [Q, E] (f32)."""
    q32, x32 = q.astype(jnp.float32), x.astype(jnp.float32)
    if metric == "ip":
        return -jnp.einsum("qd,qed->qe", q32, x32)
    return jnp.sum(jnp.square(x32 - q32[:, None, :]), axis=-1)


def _adc_lut(q: jnp.ndarray, cent: jnp.ndarray, metric: str) -> jnp.ndarray:
    """q [Q, D], cent [M, K, dsub] -> [Q, M, K]."""
    m, k, dsub = cent.shape
    qs = q.reshape(q.shape[0], m, 1, dsub).astype(jnp.float32)
    if metric == "ip":
        return -jnp.sum(cent[None] * qs, axis=-1)
    return jnp.sum(jnp.square(cent[None] - qs), axis=-1)


def _adc(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """lut [Q, M, K], codes [Q, I, M] -> [Q, I]."""
    idx = jnp.swapaxes(codes.astype(jnp.int32), 1, 2)      # [Q, M, I]
    got = jnp.take_along_axis(lut, idx, axis=2)            # [Q, M, I]
    return jnp.sum(got, axis=1)


def _merge_top(keys, ids, new_keys, new_ids, size: int, extra=None,
               new_extra=None):
    """Merge sorted-ish lists, dedupe by id, keep `size` smallest keys.

    keys/ids [Q, A], new_* [Q, B] -> [Q, size]. Invalid slots: id < 0,
    key = +inf. ``extra`` (optional int32 payload, e.g. visited flags)
    rides along."""
    k = jnp.concatenate([keys, new_keys], axis=1)
    i = jnp.concatenate([ids, new_ids], axis=1)
    e = (jnp.concatenate([extra, new_extra], axis=1)
         if extra is not None else None)
    # dedupe: sort by (id asc); duplicates adjacent; keep the first
    # occurrence with the *smallest key* -> sort by (id, key)
    order = jnp.lexsort((k, i))
    k = jnp.take_along_axis(k, order, axis=1)
    i = jnp.take_along_axis(i, order, axis=1)
    if e is not None:
        # keep the max extra among duplicates (visited wins): approximate
        # by taking the flag of the kept (first) occurrence after lexsort
        # with visited as secondary key desc would be ideal; visited
        # entries also carry +inf keys in our usage, so (id, key) order
        # already puts the live entry first.
        e = jnp.take_along_axis(e, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((i.shape[0], 1), bool), i[:, 1:] == i[:, :-1]], axis=1)
    dup |= i < 0
    k = jnp.where(dup, jnp.inf, k)
    i = jnp.where(dup, -1, i)
    order2 = jnp.argsort(k, axis=1)[:, :size]
    k = jnp.take_along_axis(k, order2, axis=1)
    i = jnp.take_along_axis(i, order2, axis=1)
    if e is not None:
        e = jnp.where(dup, 0, e)
        e = jnp.take_along_axis(e, order2, axis=1)
        return k, i, e
    return k, i


def _bit_get(mask: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """mask [Q, NB] u32, ids [Q, I] (>=0) -> [Q, I] bool."""
    word = jnp.take_along_axis(mask, (ids >> 5).astype(jnp.int32), axis=1)
    return ((word >> (ids & 31).astype(jnp.uint32)) & 1).astype(bool)


def _bit_set(mask: jnp.ndarray, ids: jnp.ndarray,
             on: jnp.ndarray) -> jnp.ndarray:
    """Set bits for ids [Q] where on [Q] (ids >= 0)."""
    q = mask.shape[0]
    word_idx = (ids >> 5).astype(jnp.int32)
    bit = (jnp.uint32(1) << (ids & 31).astype(jnp.uint32))
    bit = jnp.where(on, bit, 0).astype(jnp.uint32)
    cur = mask[jnp.arange(q), word_idx]
    return mask.at[jnp.arange(q), word_idx].set(cur | bit)


# -------------------------------------------------- navigation graph beam

def nav_entry_points(ds: DeviceSegment, queries: jnp.ndarray,
                     beam: int = 8, hops: int = 12, num: int = 4,
                     metric: str = "l2") -> jnp.ndarray:
    """Batched beam search on the in-memory navigation graph.
    Returns [Q, num] *global* entry ids (no block I/O involved)."""
    qn = queries.shape[0]
    d0 = _dists(queries, ds.nav_vecs[ds.nav_entry][None, None, :].repeat(
        qn, axis=0), metric)[:, 0]
    ids = jnp.full((qn, beam), -1, jnp.int32).at[:, 0].set(ds.nav_entry)
    keys = jnp.full((qn, beam), jnp.inf).at[:, 0].set(d0)
    expanded = jnp.zeros((qn, beam), bool)

    def body(_, state):
        ids, keys, expanded = state
        open_key = jnp.where(expanded | (ids < 0), jnp.inf, keys)
        pick = jnp.argmin(open_key, axis=1)                  # [Q]
        has_open = jnp.isfinite(
            jnp.take_along_axis(open_key, pick[:, None], axis=1))[:, 0]
        u = jnp.take_along_axis(ids, pick[:, None], axis=1)[:, 0]
        u_safe = jnp.maximum(u, 0)
        expanded = expanded.at[jnp.arange(qn), pick].set(
            expanded[jnp.arange(qn), pick] | has_open)
        nb = ds.nav_adj[u_safe]                              # [Q, deg']
        valid = (nb >= 0) & has_open[:, None]
        nb_safe = jnp.maximum(nb, 0)
        nd = _dists(queries, ds.nav_vecs[nb_safe], metric)
        nd = jnp.where(valid, nd, jnp.inf)
        nb_m = jnp.where(valid, nb, -1)
        keys, ids, expanded = _merge_top(
            keys, ids, nd, nb_m, beam,
            extra=expanded.astype(jnp.int32),
            new_extra=jnp.zeros(nb.shape, jnp.int32))
        return ids, keys, expanded.astype(bool)

    ids, keys, _ = jax.lax.fori_loop(0, hops, body, (ids, keys, expanded))
    top = ids[:, :num]
    return ds.nav_ids[jnp.maximum(top, 0)] * (top >= 0) + (-1) * (top < 0)


# ------------------------------------------------------ main block search

@functools.partial(jax.jit, static_argnames=(
    "k", "candidates", "sigma", "max_hops", "metric", "nav_beam",
    "nav_hops", "entry_points", "fetch_width"))
def device_anns(ds: DeviceSegment, queries: jnp.ndarray, k: int = 10,
                candidates: int = 64, sigma: float = 0.3,
                max_hops: int = 256, metric: str = "l2",
                nav_beam: int = 8, nav_hops: int = 12,
                entry_points: int = 4, fetch_width: int = 1):
    """Batched Starling ANNS on one segment shard.

    ``fetch_width`` > 1 fetches the F best unvisited candidates' blocks
    per round-trip (beyond-paper: the paper's Central Assumption notes a
    few random reads per SSD/DMA round-trip cost about the same as one —
    this trades block-bandwidth for round-trip latency).

    Returns (ids [Q, k], dists [Q, k], io [Q] block reads,
    hops [Q] round trips)."""
    qn, d = queries.shape
    rho, eps = ds.vid.shape
    n = ds.block_of.shape[0]
    nb_words = -(-n // 32)
    fw = max(fetch_width, 1)
    res_size = k + 2 * eps * fw
    n_expand = fw * (1 + max(int(np.ceil((eps - 1) * sigma)), 0))
    queries = queries.astype(jnp.float32)

    lut = _adc_lut(queries, ds.pq_cent, metric)              # [Q, M, K]
    entry = nav_entry_points(ds, queries, beam=nav_beam, hops=nav_hops,
                             num=entry_points, metric=metric)
    e_codes = ds.pq_codes[jnp.maximum(entry, 0)]
    e_key = jnp.where(entry >= 0, _adc(lut, e_codes), jnp.inf)

    cand_id = jnp.full((qn, candidates), -1, jnp.int32)
    cand_key = jnp.full((qn, candidates), jnp.inf)
    cand_key, cand_id = _merge_top(cand_key, cand_id, e_key, entry,
                                   candidates)
    visited = jnp.zeros((qn, nb_words), jnp.uint32)          # expanded set
    res_id = jnp.full((qn, res_size), -1, jnp.int32)
    res_key = jnp.full((qn, res_size), jnp.inf)
    io = jnp.zeros((qn,), jnp.int32)
    hops = jnp.zeros((qn,), jnp.int32)

    def cond(state):
        cand_id, cand_key, visited, res_id, res_key, io, hops, t = state
        vis = _bit_get(visited, jnp.maximum(cand_id, 0)) | (cand_id < 0)
        live = jnp.isfinite(jnp.where(vis, jnp.inf, cand_key)).any()
        return live & (t < max_hops)

    def body(state):
        cand_id, cand_key, visited, res_id, res_key, io, hops, t = state
        vis = _bit_get(visited, jnp.maximum(cand_id, 0)) | (cand_id < 0)
        open_key = jnp.where(vis, jnp.inf, cand_key)
        neg_top, picks = jax.lax.top_k(-open_key, fw)        # [Q, F]
        f_active = jnp.isfinite(-neg_top)                    # [Q, F]
        active = f_active[:, 0]
        u = jnp.take_along_axis(cand_id, picks, axis=1)      # [Q, F]
        u = jnp.where(f_active, u, -1)
        u_safe = jnp.maximum(u, 0)

        # --- DR: F block DMAs per round trip (one per active candidate)
        b = ds.block_of[u_safe]                              # [Q, F]
        vid = ds.vid[b].reshape(qn, fw * eps)                # [Q, F*eps]
        vecs = ds.vecs[b].reshape(qn, fw * eps, -1)
        nbrs = ds.nbrs[b].reshape(qn, fw * eps, -1)
        io = io + f_active.sum(axis=1).astype(jnp.int32)
        hops = hops + active.astype(jnp.int32)               # round trips

        # --- DC: exact-rank all residents; fold into results
        dd = _dists(queries, vecs, metric)                   # [Q, F*eps]
        f_valid = jnp.repeat(f_active, eps, axis=1)
        slot_valid = (vid >= 0) & f_valid
        dd_m = jnp.where(slot_valid, dd, jnp.inf)
        res_key, res_id = _merge_top(res_key, res_id, dd_m,
                                     jnp.where(slot_valid, vid, -1),
                                     res_size)

        # --- block pruning: expand targets + top-((eps-1)*sigma)
        is_target = (vid[:, :, None] == u[:, None, :]).any(-1) \
            & (vid >= 0)
        sel_key = jnp.where(is_target, -jnp.inf, dd_m)
        order = jnp.argsort(sel_key, axis=1)[:, :n_expand]   # [Q, X]
        ex_id = jnp.take_along_axis(vid, order, axis=1)
        ex_valid = (jnp.take_along_axis(sel_key, order, axis=1)
                    < jnp.inf) & active[:, None] & (ex_id >= 0)
        ex_new = ex_valid & ~_bit_get(visited, jnp.maximum(ex_id, 0))
        for j in range(n_expand):                            # mark expanded
            visited = _bit_set(visited, jnp.maximum(ex_id[:, j], 0),
                               ex_new[:, j])

        # --- collect neighbors of expanded slots, route by PQ
        ex_nbrs = jnp.take_along_axis(
            nbrs, order[:, :, None], axis=1)                 # [Q, X, Lam]
        flat = ex_nbrs.reshape(qn, -1)
        f_valid = (flat >= 0) & ex_new.repeat(
            ex_nbrs.shape[2], axis=1) & active[:, None]
        f_safe = jnp.maximum(flat, 0)
        f_valid &= ~_bit_get(visited, f_safe)                # skip expanded
        f_codes = ds.pq_codes[f_safe]                        # [Q, F, M]
        f_key = jnp.where(f_valid, _adc(lut, f_codes), jnp.inf)
        f_id = jnp.where(f_valid, flat, -1)
        cand_key, cand_id = _merge_top(cand_key, cand_id, f_key, f_id,
                                       candidates)
        return (cand_id, cand_key, visited, res_id, res_key, io, hops,
                t + 1)

    state = (cand_id, cand_key, visited, res_id, res_key, io, hops,
             jnp.zeros((), jnp.int32))
    state = jax.lax.while_loop(cond, body, state)
    _, _, _, res_id, res_key, io, hops, _ = state
    return res_id[:, :k], res_key[:, :k], io, hops


# --------------------------------------------- production mesh search step

def make_search_step(mesh, rules, *,
                     n_local: int = 1 << 21, dim: int = 128,
                     eps: int = 16, lam: int = 31, q_global: int = 4096,
                     pq_m: int = 16, pq_k: int = 256,
                     nav_frac: int = 64, nav_deg: int = 12,
                     k: int = 10):
    """Build (fn, arg ShapeDtypeStructs) for the segment-search dry-run.

    Layout: every ``model`` rank owns an independent sub-segment of
    ``n_local`` vectors (16 ranks x 2M = 33M vectors per pod row — the
    paper's segment scale); queries are sharded over ``data`` (x ``pod``)
    and replicated over ``model``. The step runs the local block search
    via shard_map and merges per-segment top-k with one all-gather over
    ``model``.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from jax import shard_map

    model_n = mesh.shape["model"]
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    rho = n_local // eps
    nav_n = n_local // nav_frac
    dsub = dim // pq_m

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(
            shape, dtype, sharding=NamedSharding(mesh, spec))

    seg_specs = DeviceSegment(
        vecs=sds((model_n, rho, eps, dim), jnp.bfloat16, P("model")),
        vid=sds((model_n, rho, eps), jnp.int32, P("model")),
        deg=sds((model_n, rho, eps), jnp.int32, P("model")),
        nbrs=sds((model_n, rho, eps, lam), jnp.int32, P("model")),
        block_of=sds((model_n, n_local), jnp.int32, P("model")),
        pq_codes=sds((model_n, n_local, pq_m), jnp.uint8, P("model")),
        pq_cent=sds((model_n, pq_m, pq_k, dsub), jnp.float32, P("model")),
        nav_vecs=sds((model_n, nav_n, dim), jnp.float32, P("model")),
        nav_adj=sds((model_n, nav_n, nav_deg), jnp.int32, P("model")),
        nav_ids=sds((model_n, nav_n), jnp.int32, P("model")),
        nav_entry=sds((model_n,), jnp.int32, P("model")),
    )
    q_specs = sds((q_global, dim), jnp.float32, P(data_axes))

    in_specs = (DeviceSegment(
        vecs=P("model"), vid=P("model"), deg=P("model"), nbrs=P("model"),
        block_of=P("model"), pq_codes=P("model"), pq_cent=P("model"),
        nav_vecs=P("model"), nav_adj=P("model"), nav_ids=P("model"),
        nav_entry=P("model")), P(data_axes))
    out_specs = (P(data_axes), P(data_axes), P(data_axes, "model"))

    def local_search(seg: DeviceSegment, queries):
        seg = jax.tree.map(lambda a: a[0], seg)      # strip shard dim
        seg = dataclasses.replace(
            seg, vecs=seg.vecs.astype(jnp.float32))
        ids, dists, io, hops = device_anns(
            seg, queries, k=k, candidates=64, sigma=0.3, max_hops=128)
        # hierarchical top-k merge over segment ranks: all-gather k
        # results per rank (O(k) bytes cross-rank, not O(Gamma))
        rank = jax.lax.axis_index("model")
        gids = jax.lax.all_gather(ids, "model")      # [S, Q, k]
        gd = jax.lax.all_gather(dists, "model")
        s, q, _ = gids.shape
        flat_d = jnp.moveaxis(gd, 0, 1).reshape(q, s * k)
        flat_i = jnp.moveaxis(gids, 0, 1).reshape(q, s * k)
        seg_of = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None, :]
        order = jnp.argsort(flat_d, axis=1)[:, :k]
        out_d = jnp.take_along_axis(flat_d, order, axis=1)
        out_i = jnp.take_along_axis(flat_i, order, axis=1)
        out_seg = jnp.take_along_axis(
            jnp.broadcast_to(seg_of, flat_i.shape), order, axis=1)
        # global id = segment rank * n_local + local id
        gid = out_seg * n_local + out_i
        return gid, out_d, io[:, None] * jnp.ones((1, 1), jnp.int32)

    fn = shard_map(local_search, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return fn, (seg_specs, q_specs)


# ---------------------------------------------------------- range search

@functools.partial(jax.jit, static_argnames=(
    "radius", "k_cap", "candidates", "sigma", "max_hops", "metric",
    "rounds", "ratio"))
def device_range_search(ds: DeviceSegment, queries: jnp.ndarray,
                        radius: float, k_cap: int = 256,
                        candidates: int = 32, sigma: float = 0.3,
                        max_hops: int = 256, metric: str = "l2",
                        rounds: int = 3, ratio: float = 0.5):
    """Batched RS (§5.3 semantics, device formulation): run ANNS with a
    growing candidate set per round; stop growing a query's set once the
    in-range fraction of its results drops below ``ratio``. Returns
    (ids [Q, k_cap], dists, in_range mask, io)."""
    io_total = jnp.zeros((queries.shape[0],), jnp.int32)
    ids = dists = None
    c = candidates
    for _ in range(rounds):
        k_r = min(k_cap, c)
        ids, dists, io, _ = device_anns(
            ds, queries, k=k_r, candidates=c, sigma=sigma,
            max_hops=max_hops, metric=metric)
        io_total = io_total + io
        in_r = (dists <= radius).sum(axis=1)
        frac = in_r / jnp.maximum(k_r, 1)
        if c * 2 > k_cap:
            break
        c *= 2
        # (rounds are compile-time unrolled; per-query early-exit is
        # handled by the ratio mask on the host serving layer)
    pad = k_cap - ids.shape[1]
    if pad > 0:
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        dists = jnp.pad(dists, ((0, 0), (0, pad)),
                        constant_values=jnp.inf)
    return ids, dists, dists <= radius, io_total
