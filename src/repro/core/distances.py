"""Distance primitives.

Host-side (numpy, BLAS-backed) helpers for index construction and the
reference search implementation, plus jit'd chunked brute force used for
ground truth and KNN-graph seeding.

Conventions: ``l2`` returns *squared* Euclidean distance (monotone in the
true metric, as in DiskANN/Starling implementations); ``ip`` returns the
negated inner product so that smaller is always better.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def pairwise(a: np.ndarray, b: np.ndarray, metric: str = "l2") -> np.ndarray:
    """[Na, D] x [Nb, D] -> [Na, Nb] distance matrix (numpy, float32)."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    dot = a @ b.T
    if metric == "ip":
        return -dot
    na = np.sum(a * a, axis=1, keepdims=True)
    nb = np.sum(b * b, axis=1, keepdims=True)
    d = na + nb.T - 2.0 * dot
    return np.maximum(d, 0.0)


@functools.partial(jax.jit, static_argnames=("metric",))
def pairwise_jit(a: jnp.ndarray, b: jnp.ndarray, metric: str = "l2"):
    dot = a @ b.T
    if metric == "ip":
        return -dot
    na = jnp.sum(a * a, axis=1, keepdims=True)
    nb = jnp.sum(b * b, axis=1, keepdims=True)
    return jnp.maximum(na + nb.T - 2.0 * dot, 0.0)


def point_to_points(q: np.ndarray, x: np.ndarray, metric: str = "l2"
                    ) -> np.ndarray:
    """[D] x [N, D] -> [N]."""
    q = np.asarray(q, np.float32)
    x = np.asarray(x, np.float32)
    if metric == "ip":
        return -(x @ q)
    diff = x - q[None, :]
    return np.einsum("nd,nd->n", diff, diff)


def brute_force_knn(x: np.ndarray, q: np.ndarray, k: int,
                    metric: str = "l2", chunk: int = 4096) -> np.ndarray:
    """Exact top-k ids for each query row (ground truth). [Nq, k] int32."""
    x = np.asarray(x, np.float32)
    q = np.asarray(q, np.float32)
    out = np.empty((q.shape[0], k), np.int32)
    xj = jnp.asarray(x)
    for s in range(0, q.shape[0], chunk):
        d = pairwise_jit(jnp.asarray(q[s:s + chunk]), xj, metric=metric)
        _, idx = jax.lax.top_k(-d, k)
        out[s:s + chunk] = np.asarray(idx, np.int32)
    return out


def brute_force_range(x: np.ndarray, q: np.ndarray, radius: float,
                      metric: str = "l2", chunk: int = 2048):
    """Exact range-search ground truth: list of id arrays per query."""
    x = np.asarray(x, np.float32)
    out = []
    for s in range(0, q.shape[0], chunk):
        d = np.asarray(pairwise_jit(jnp.asarray(q[s:s + chunk]),
                                    jnp.asarray(x), metric=metric))
        for row in d:
            out.append(np.where(row <= radius)[0].astype(np.int32))
    return out


def knn_graph(x: np.ndarray, k: int, metric: str = "l2",
              chunk: int = 2048) -> np.ndarray:
    """Exact KNN graph over x (excluding self). [N, k] int32."""
    n = x.shape[0]
    ids = brute_force_knn(x, x, min(k + 1, n), metric=metric, chunk=chunk)
    out = np.empty((n, k), np.int32)
    for i in range(n):
        row = ids[i]
        row = row[row != i][:k]
        if row.shape[0] < k:  # degenerate duplicates; pad with self-exclusions
            pad = np.setdiff1d(np.arange(min(n, k + 2)), np.append(row, i))
            row = np.append(row, pad)[:k]
        out[i] = row
    return out
