"""Starling search strategy — host reference implementation (§5).

This is the *oracle* implementation: exact paper semantics with full I/O
accounting. The device-side batched implementation (``device_search.py``,
Pallas kernels) is validated against it.

ANNS  — Algorithm 2: PQ-keyed candidate set C (size Γ), exact-keyed result
        set R, block search with σ-pruned in-block expansion, I/O–compute
        pipeline (modeled via CostModel overlap on this CPU container).
RS    — §5.3: C doubles and the search restarts (resuming R, C and the
        kicked set P) while |R|/|C| ≥ φ.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import distances as D
from repro.core.blockstore import BlockStore
from repro.core.iostats import IOStats
from repro.core.layout import BlockLayout
from repro.core.navgraph import NavGraph
from repro.core.params import SearchParams
from repro.io.cached_store import CachedBlockStore
from repro.io.prefetch import PrefetchEngine
from repro.pq import PQCodebook, adc_lut, adc_distance


@dataclasses.dataclass
class SegmentView:
    """Everything the online search is allowed to touch."""
    store: BlockStore
    layout: BlockLayout
    nav: Optional[NavGraph]
    pq_codes: Optional[np.ndarray]       # [N, M] uint8, memory-resident
    pq_cb: Optional[PQCodebook]
    metric: str = "l2"
    entry: int = 0                        # static entry (medoid) fallback


class _CandidateSet:
    """Fixed-capacity ordered set keyed by (approx) distance.

    Mirrors the paper's C: sorted ascending, bounded to Γ, with a visited
    flag per element; evicted ('kicked') ids are reported for the RS kicked
    set P."""

    __slots__ = ("cap", "keys", "ids", "visited", "member")

    def __init__(self, cap: int):
        self.cap = cap
        self.keys: List[float] = []
        self.ids: List[int] = []
        self.visited: List[bool] = []
        self.member: Dict[int, int] = {}

    def _reindex(self, start: int = 0) -> None:
        for i in range(start, len(self.ids)):
            self.member[self.ids[i]] = i

    def push(self, key: float, vid: int) -> Optional[Tuple[float, int]]:
        """Insert; returns the kicked (key, id) if capacity overflowed."""
        if vid in self.member:
            return None
        i = bisect.bisect_right(self.keys, key)
        if i >= self.cap:
            return (key, vid)          # worse than everything retained
        self.keys.insert(i, key)
        self.ids.insert(i, vid)
        self.visited.insert(i, False)
        self._reindex(i)
        kicked = None
        if len(self.ids) > self.cap:
            kk, ki = self.keys.pop(), self.ids.pop()
            self.visited.pop()
            del self.member[ki]
            kicked = (kk, ki)
        return kicked

    def top_unvisited(self) -> Optional[int]:
        for i, v in enumerate(self.visited):
            if not v:
                return i
        return None

    def mark_visited_id(self, vid: int) -> None:
        i = self.member.get(vid)
        if i is not None:
            self.visited[i] = True

    def __contains__(self, vid: int) -> bool:
        return vid in self.member

    def __len__(self) -> int:
        return len(self.ids)

    def grow(self, new_cap: int) -> None:
        self.cap = new_cap


@dataclasses.dataclass
class SearchResult:
    ids: np.ndarray
    dists: np.ndarray
    stats: IOStats


def _entry_points(seg: SegmentView, q: np.ndarray, p: SearchParams
                  ) -> np.ndarray:
    if p.use_nav_graph and seg.nav is not None:
        return seg.nav.entry_points(q[None, :], beam=16, num=4)[0]
    return np.asarray([seg.entry], np.int64)


def block_search_query(seg: SegmentView, q: np.ndarray, k: int,
                       p: SearchParams,
                       cand: Optional[_CandidateSet] = None,
                       result: Optional[Dict[int, float]] = None,
                       kicked: Optional[List[Tuple[float, int]]] = None,
                       expanded: Optional[set] = None,
                       stats: Optional[IOStats] = None,
                       seeds: Optional[np.ndarray] = None) -> SearchResult:
    """One ANNS query via block search (Algorithm 2).

    ``cand``/``result``/``kicked``/``expanded`` allow the RS driver
    (§5.3) to resume a previous search without recomputation — the
    ``expanded`` set in particular must survive rounds, or reseeded
    kicked vertices re-read blocks already expanded earlier.

    ``seeds`` is the seed-override path (hot/cold hybrid routing,
    DESIGN.md §10): explicit entry vertex ids (−1 entries ignored) that
    replace the navigation-graph entry pick — the hot tier hands its
    exit frontier here so the cold search starts where the memory tier
    converged.
    """
    store, layout = seg.store, seg.layout
    eps = store.verts_per_block
    stats = stats if stats is not None else IOStats()
    use_pq = p.use_pq_routing and seg.pq_codes is not None
    lut = adc_lut(q, seg.pq_cb) if use_pq else None

    C = cand if cand is not None else _CandidateSet(p.candidate_size)
    R: Dict[int, float] = result if result is not None else {}
    P: List[Tuple[float, int]] = kicked if kicked is not None else []
    expanded = expanded if expanded is not None else set()

    # repro.io: when the view's store is cache-fronted, all block reads go
    # through it (hit/miss/round-trip accounting) and demand misses carry
    # speculative fetches of the top unvisited candidates' blocks — either
    # coalesced into the demand round trip (sync) or submitted to the
    # shared AsyncFetchQueue so they stay in flight while this block is
    # ranked, completing out of submission order (§5.1 pipeline).
    cached = store if isinstance(store, CachedBlockStore) else None
    prefetcher = (PrefetchEngine(cached, layout.block_of)
                  if cached is not None and cached.prefetch_width > 0
                  else None)

    def fetch(bid: int, speculate: bool = True):
        """One demand block read with unified I/O accounting."""
        if cached is None:
            out = store.read_block(bid)
            stats.block_reads += 1
            return out
        if prefetcher is not None and speculate:
            return prefetcher.read(bid, C, stats)
        return cached.read_demand(bid, stats)

    def route_dist(ids: np.ndarray) -> np.ndarray:
        """Candidate-queue key: ADC if PQ routing, else exact via block
        reads (the Fig. 11(c) ablation — prohibitively many I/Os)."""
        if use_pq:
            stats.pq_comps += len(ids)
            return adc_distance(lut, seg.pq_codes[ids])
        out = np.empty(len(ids), np.float32)
        for j, v in enumerate(ids):
            bid = int(layout.block_of[v])
            vids, vecs, _, _ = fetch(bid, speculate=False)
            stats.vertices_fetched += int((vids >= 0).sum())
            slot = int(layout.slot_of[v])
            out[j] = D.point_to_points(q, vecs[slot][None, :], seg.metric)[0]
            stats.dist_comps += 1
            stats.vertices_used += 1
        return out

    if seeds is not None:
        entry = np.asarray([int(v) for v in seeds if int(v) >= 0],
                           np.int64)
        if entry.size == 0:
            entry = _entry_points(seg, q, p)
    else:
        entry = _entry_points(seg, q, p)
    ed = route_dist(entry)
    for v, dd in zip(entry, ed):
        kk = C.push(float(dd), int(v))
        if kk is not None:
            P.append(kk)

    n_prune = max(int(math.ceil((eps - 1) * p.pruning_ratio)), 0)

    while True:
        i = C.top_unvisited()
        if i is None:
            break
        u = C.ids[i]
        C.visited[i] = True
        if u in expanded:
            continue
        stats.hops += 1

        bid = int(layout.block_of[u])
        vids, vecs, degs, nbrs = fetch(bid)              # DR
        valid = vids >= 0
        stats.vertices_fetched += int(valid.sum())

        # exact-rank every resident vertex (DC — pipelined with next DR)
        dd = D.point_to_points(q, vecs, seg.metric)
        stats.dist_comps += int(valid.sum())
        best_before = min(R.values()) if R else np.inf
        for s_ in np.where(valid)[0]:
            w = int(vids[s_])
            if w not in R:
                R[w] = float(dd[s_])
        if R and min(R.values()) < best_before:
            stats.hops_to_best = stats.hops      # ℓ: top-1 improved here

        # expand the target vertex u (Algorithm 2 lines 6–7)
        slot = int(layout.slot_of[u])
        to_expand = [slot]
        expanded.add(u)
        used = 1

        if p.use_block_search and eps > 1:
            # block pruning: top-((ε−1)·σ) non-target residents (line 8)
            others = [s_ for s_ in np.where(valid)[0] if s_ != slot]
            others.sort(key=lambda s_: dd[s_])
            for s_ in others[:n_prune]:
                w = int(vids[s_])
                if w in expanded:
                    continue
                to_expand.append(s_)
                expanded.add(w)
                C.mark_visited_id(w)
                used += 1
        stats.vertices_used += used

        new_ids: List[int] = []
        for s_ in to_expand:
            for v in nbrs[s_, : degs[s_]]:
                v = int(v)
                if v >= 0 and v not in C.member and v not in expanded:
                    new_ids.append(v)
        if new_ids:
            new_ids = list(dict.fromkeys(new_ids))
            ndist = route_dist(np.asarray(new_ids, np.int64))
            for v, nd in zip(new_ids, ndist):
                kk = C.push(float(nd), v)
                if kk is not None:
                    P.append(kk)
        if stats.hops >= p.max_hops:
            break

    items = sorted(R.items(), key=lambda kv: kv[1])[:k]
    ids = np.asarray([i for i, _ in items], np.int64)
    dvals = np.asarray([d_ for _, d_ in items], np.float32)
    return SearchResult(ids=ids, dists=dvals, stats=stats)


def anns(seg: SegmentView, queries: np.ndarray, k: int,
         p: SearchParams, seeds: Optional[np.ndarray] = None
         ) -> Tuple[np.ndarray, np.ndarray, List[IOStats]]:
    """Batch ANNS. Returns (ids [Q, k], dists [Q, k], per-query stats).

    ``seeds`` [Q, S] (−1-padded) overrides the per-query entry points —
    the hybrid hot-first router passes the hot tier's exit frontier."""
    Q = queries.shape[0]
    ids = np.full((Q, k), -1, np.int64)
    dd = np.full((Q, k), np.inf, np.float32)
    stats: List[IOStats] = []
    for qi in range(Q):
        r = block_search_query(
            seg, queries[qi], k, p,
            seeds=None if seeds is None else seeds[qi])
        m = r.ids.shape[0]
        ids[qi, :m] = r.ids
        dd[qi, :m] = r.dists
        stats.append(r.stats)
    return ids, dd, stats


def range_search_query(seg: SegmentView, q: np.ndarray, radius: float,
                       p: SearchParams) -> SearchResult:
    """Range search (§5.3): doubling candidate set with kicked-set reseed."""
    stats = IOStats()
    C = _CandidateSet(p.candidate_size)
    R: Dict[int, float] = {}
    P: List[Tuple[float, int]] = []
    E: set = set()    # expanded vertices survive rounds — reseeded
    #                   kicked vertices must not re-read their blocks

    block_search_query(seg, q, k=1, p=p, cand=C, result=R, kicked=P,
                       expanded=E, stats=stats)
    for _ in range(p.rs_max_rounds):
        in_range = sum(1 for d_ in R.values() if d_ <= radius)
        if in_range / max(C.cap, 1) < p.rs_ratio:       # Eq. 7 not met
            break
        C.grow(C.cap * 2)
        # reseed with closer kicked vertices (step 4)
        P.sort(key=lambda kv: kv[0])
        reseed, P = P[: C.cap], P[C.cap:]
        for kk, vv in reseed:
            C.push(kk, vv)
        block_search_query(seg, q, k=1, p=p, cand=C, result=R, kicked=P,
                           expanded=E, stats=stats)

    hits = [(v, d_) for v, d_ in R.items() if d_ <= radius]
    hits.sort(key=lambda kv: kv[1])
    ids = np.asarray([v for v, _ in hits], np.int64)
    dd = np.asarray([d_ for _, d_ in hits], np.float32)
    return SearchResult(ids=ids, dists=dd, stats=stats)


def range_search(seg: SegmentView, queries: np.ndarray, radius: float,
                 p: SearchParams):
    out, stats = [], []
    for qi in range(queries.shape[0]):
        r = range_search_query(seg, queries[qi], radius, p)
        out.append(r.ids)
        stats.append(r.stats)
    return out, stats


# ------------------------------------------------------------------ metrics

def recall_at_k(pred: np.ndarray, truth: np.ndarray) -> float:
    """Eq. 2, averaged over queries. pred/truth [Q, k]."""
    hits = 0
    for p_, t_ in zip(pred, truth):
        hits += len(set(int(i) for i in p_ if i >= 0)
                    & set(int(i) for i in t_))
    return hits / (truth.shape[0] * truth.shape[1])


def average_precision(pred_lists, truth_lists) -> float:
    """Eq. 3 averaged over queries with non-empty ground truth."""
    vals = []
    for p_, t_ in zip(pred_lists, truth_lists):
        if len(t_) == 0:
            continue
        vals.append(len(set(p_.tolist()) & set(t_.tolist())) / len(t_))
    return float(np.mean(vals)) if vals else 1.0
