# Starling core: the paper's primary contribution.
#   graph      — Vamana / NSG-flavour / HNSW-flavour construction
#   layout     — block-level layout + BNP/BNF/BNS shuffling + OR(G)
#   navgraph   — in-memory navigation graph (query-aware entry points)
#   blockstore — block-resident index file (the only online data path)
#   search     — block search, ANNS (Alg. 2), range search (§5.3)
#   baseline   — DiskANN-style vertex search + hot cache + repeated-ANNS RS
#   segment    — build orchestration + Eq. 8/10 cost accounting
#   iostats    — I/O counters and the Eq. 4 latency model
from repro.core.params import (GraphParams, LayoutParams, NavGraphParams,
                               PQParams, SearchParams, SegmentBudget,
                               SegmentParams)
from repro.core.segment import Segment, build_segment, load_segment, \
    save_segment
