"""Parameter dataclasses for the Starling segment index.

Notation follows the paper (§4.1):
  Λ (max_degree)   — max neighbor IDs stored per vertex
  λ                — actual neighbor count (stored inline, padded to Λ)
  γ (vertex_kb)    — KB per vertex on "disk" = D·dtype + 4 + Λ·4 bytes
  η (block_kb)     — block size in KB (smallest I/O unit)
  ε (verts_per_block) — ⌊η/γ⌋
  ρ (num_blocks)   — ⌈|V|/ε⌉
  σ (pruning_ratio)   — block-pruning ratio (§5.1), paper optimum 0.3
  μ (sample_ratio)    — navigation-graph sample ratio (§4.2)
  φ (rs_ratio)        — range-search doubling threshold (§5.3), paper 0.5
  Γ (candidate_size)  — search candidate-set size (App. M)
  β, τ             — shuffling iteration cap / OR-gain threshold (App. C)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class GraphParams:
    """Graph-index construction parameters (Vamana/NSG/HNSW-flavour)."""
    max_degree: int = 32          # Λ
    build_beam: int = 64          # L (candidate list during construction)
    alpha: float = 1.2            # Vamana robust-prune slack
    algo: str = "vamana"          # vamana | nsg | hnsw
    insert_batch: int = 256       # batched-insert chunk during build
    seed: int = 0

    def __post_init__(self):
        assert self.build_beam >= self.max_degree, "L must be >= Λ (App. L)"
        assert self.algo in ("vamana", "nsg", "hnsw")


@dataclasses.dataclass(frozen=True)
class LayoutParams:
    """Block-level layout parameters (§4.1)."""
    block_kb: float = 4.0         # η
    shuffle: str = "bnf"          # none | bnp | bnf | bns
    bnf_iters: int = 8            # β  (paper default 8, App. C)
    bns_iters: int = 2            # β for BNS (expensive; App. F)
    gain_tau: float = 0.01        # τ  (paper default 0.01, App. C)

    def verts_per_block(self, dim: int, max_degree: int,
                        dtype_bytes: int = 4) -> int:
        """ε = ⌊η/γ⌋ with γ = D·b + 4 (λ) + Λ·4 bytes (Example 2)."""
        gamma = dim * dtype_bytes + 4 + max_degree * 4
        eps = int(self.block_kb * 1024) // gamma
        if eps < 1:
            raise ValueError(
                f"vertex ({gamma}B) does not fit a {self.block_kb}KB block")
        return eps

    def num_blocks(self, n: int, dim: int, max_degree: int,
                   dtype_bytes: int = 4) -> int:
        eps = self.verts_per_block(dim, max_degree, dtype_bytes)
        return math.ceil(n / eps)


@dataclasses.dataclass(frozen=True)
class PQParams:
    """Product-quantization parameters for in-memory routing (§5.1)."""
    num_subspaces: int = 8        # M
    num_centroids: int = 256      # K (uint8 codes)
    train_iters: int = 12
    train_sample: int = 16384
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class NavGraphParams:
    """In-memory navigation graph (§4.2)."""
    sample_ratio: float = 0.1     # μ
    max_degree: int = 20          # Λ' (smaller than disk graph; Tab. 17)
    build_beam: int = 64
    search_beam: int = 16         # beam when finding entry points
    num_entry_points: int = 4     # entry points handed to the disk search
    seed: int = 1


@dataclasses.dataclass(frozen=True)
class HotTierParams:
    """The in-memory hot tier above the block hierarchy (DESIGN.md §10).

    A navigable graph over the hot-set *vectors* (selected by the
    shared ``repro.io.hotset`` ranking, same prior as tiers 0/1/2) that
    *answers* at memory latency; the cold block search is seeded from
    its exit frontier. Also the home of the mutable delta: inserts land
    in the hot tier's append region until ``compact()`` folds them into
    a fresh disk layout."""
    budget_frac: float = 0.10     # share of segment vectors resident hot
    max_degree: int = 16          # hot-graph degree (HNSW-style, small)
    build_beam: int = 48
    search_beam: int = 16         # beam for the hot route (to convergence)
    exit_width: int = 4           # exit-frontier seeds handed to cold search
    cold_gamma_frac: float = 0.85  # hybrid's cold Γ as a share of the
    #                                configured candidate size — the hot
    #                                tier absorbs the early exploration,
    #                                so the seeded block search runs a
    #                                narrower beam at equal recall
    append_slack: float = 0.5     # append-region capacity / built size
    hops: int = 1                 # BFS depth of the hot-set ranking
    seed: int = 1

    def __post_init__(self):
        if not 0.0 < self.budget_frac <= 1.0:
            raise ValueError("budget_frac must be in (0, 1]")
        if not 0.0 < self.cold_gamma_frac <= 1.0:
            raise ValueError("cold_gamma_frac must be in (0, 1]")
        if self.exit_width < 1:
            raise ValueError("exit_width must be >= 1")
        if self.append_slack < 0.0:
            raise ValueError("append_slack must be >= 0")
        if self.search_beam < self.exit_width:
            raise ValueError("search_beam must cover exit_width")


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Online search parameters (§5)."""
    candidate_size: int = 64      # Γ
    pruning_ratio: float = 0.3    # σ
    use_pq_routing: bool = True
    use_nav_graph: bool = True
    use_block_search: bool = True  # False → vertex-at-a-time (baseline strat)
    pipeline: bool = True          # I/O–compute overlap (modeled on CPU)
    rs_ratio: float = 0.5          # φ
    rs_max_rounds: int = 6         # cap on candidate-set doublings
    max_hops: int = 4096           # safety valve


@dataclasses.dataclass(frozen=True)
class CacheParams:
    """Block-cache + prefetch knobs (the repro.io subsystem).

    The cache budget is memory reserved for block residency and is
    charged as C_cache against the Eq. 10 segment memory budget. Either
    give an absolute ``budget_bytes`` or a ``budget_frac`` of the block
    file (``BlockStore.disk_bytes()``); both zero disables caching and
    the search path behaves exactly as the seed.

    ``tier2_frac`` carves a share of the budget into a second tier of
    compressed PQ-space block summaries at ``block_bytes //
    tier2_compression`` each (a tier-2 hit re-ranks without a disk
    trip); ``queue_depth`` > 0 switches the fetch path from
    synchronous-coalesced to the event-clock ``AsyncFetchQueue`` with
    that many fetches in flight.

    ``tier0_bytes`` / ``tier0_frac`` budget the *device* tier 0 — the
    VMEM-resident hot-tile pack of ``device_search.DeviceSegment``.
    Tier-0 bytes are separate from the host budget (they live on the
    accelerator, not in segment DRAM) but are charged into Eq. 10 all
    the same (``Segment.memory_bytes``) and capped by
    ``SegmentBudget.tier0_vmem_bytes``: reserved memory is reserved
    memory, whichever tier holds it.
    """
    budget_bytes: int = 0         # absolute cache budget
    budget_frac: float = 0.0      # fraction of disk_bytes (if bytes == 0)
    policy: str = "lru"           # lru | lfu
    pin_fraction: float = 0.25    # share of tier-1 capacity pinned to the
    #                               build-time entry-neighborhood hot set
    prefetch_width: int = 4       # speculative blocks per demand read:
    #                               coalesced into the round trip (sync)
    #                               or put in flight (async); 0 → none
    tier2_frac: float = 0.0       # share of the budget reserved for the
    #                               compressed summary tier (0 → 1 tier)
    tier2_compression: int = 16   # full-block bytes per summary byte
    queue_depth: int = 0          # max in-flight fetches on the async
    #                               queue (0 → synchronous fetch path)
    tier0_bytes: int = 0          # absolute device hot-tile (VMEM) budget
    tier0_frac: float = 0.0       # fraction of disk_bytes (if bytes == 0)

    def __post_init__(self):
        # ValueError (not assert) so invalid configs fail under -O too,
        # matching BlockCache's own validation
        if self.policy not in ("lru", "lfu"):
            raise ValueError(
                f"unknown eviction policy {self.policy!r} (lru | lfu)")
        if not (0.0 <= self.pin_fraction <= 1.0
                and 0.0 <= self.budget_frac <= 1.0
                and self.budget_bytes >= 0 and self.prefetch_width >= 0):
            raise ValueError(
                "CacheParams out of range: pin_fraction/budget_frac in "
                "[0, 1], budget_bytes/prefetch_width >= 0")
        if not (0.0 <= self.tier2_frac < 1.0):
            raise ValueError("tier2_frac must be in [0, 1): tier 1 "
                             "needs a non-empty share of the budget")
        if self.tier2_compression < 1 or self.queue_depth < 0:
            raise ValueError(
                "tier2_compression must be >= 1 and queue_depth >= 0")
        if not (0.0 <= self.tier0_frac <= 1.0) or self.tier0_bytes < 0:
            raise ValueError(
                "tier0_frac must be in [0, 1] and tier0_bytes >= 0")

    @property
    def enabled(self) -> bool:
        return self.budget_bytes > 0 or self.budget_frac > 0.0

    @property
    def tier0_enabled(self) -> bool:
        return self.tier0_bytes > 0 or self.tier0_frac > 0.0

    def resolve_budget(self, disk_bytes: int) -> int:
        if self.budget_bytes > 0:
            return self.budget_bytes
        return int(self.budget_frac * disk_bytes)

    def resolve_tier0_budget(self, disk_bytes: int) -> int:
        """Device hot-tile budget in bytes (Eq. 10's C_tier0 charge)."""
        if self.tier0_bytes > 0:
            return self.tier0_bytes
        return int(self.tier0_frac * disk_bytes)


@dataclasses.dataclass(frozen=True)
class RepackParams:
    """Knobs of the serving-plane tier-0 repack scheduler
    (``repro.serving.scheduler.RepackScheduler``, DESIGN.md §5).

    The scheduler folds the host stores' observed per-block demand
    (``CachedBlockStore.block_freq``) and the device search's tier-0 /
    dedup columns into a periodic repack decision for the VMEM hot-tile
    pack. ``hysteresis`` is the control-loop damper: a repack fires
    only when at least that fraction of the pack's slots would change,
    so a below-threshold drift costs nothing (the no-op invariant the
    property tests pin down) and the loop cannot oscillate between two
    near-equal packs.
    """
    interval_batches: int = 8     # evaluate every N served batches
    hysteresis: float = 0.25      # min fraction of pack slots that must
    #                               change for a repack to fire (0 =
    #                               repack on any drift)
    min_observed: int = 1         # ignore blocks with fewer demand reads
    #                               (noise floor of the drift signal)
    hit_rate_ceiling: float = 0.95  # skip repacks while the observed
    #                               tier-0 hit rate is already above
    #                               this (the pack absorbs the stream;
    #                               churn buys nothing)

    def __post_init__(self):
        if self.interval_batches < 1:
            raise ValueError("interval_batches must be >= 1")
        if not (0.0 <= self.hysteresis <= 1.0
                and 0.0 <= self.hit_rate_ceiling <= 1.0):
            raise ValueError(
                "hysteresis and hit_rate_ceiling must be in [0, 1]")
        if self.min_observed < 1:
            raise ValueError("min_observed must be >= 1")


@dataclasses.dataclass(frozen=True)
class RouterParams:
    """Knobs of the mesh serving router
    (``repro.serving.router.MeshQueryRouter``, DESIGN.md §7).

    The router keeps a sliding window of per-rank load folds (the
    ``rounds_active_weight`` occupancy of each rank's served step) and
    every ``rebalance_interval`` routed batches compares the windowed
    per-segment loads against the current placement. A rebalance fires
    only when the window holds at least ``min_window`` steps AND the
    rank-load skew (max/mean) reaches ``skew_threshold`` AND the
    re-planned placement actually moves a segment — so a settled,
    balanced stream never restacks (the idempotence invariant the mesh
    tests pin down), mirroring the ``RepackParams`` hysteresis for
    tier 0.
    """
    window_batches: int = 16      # per-rank load folds kept in the
    #                               sliding window (older steps age out)
    rebalance_interval: int = 8   # evaluate placement every N batches
    min_window: int = 4           # steps the window must hold before a
    #                               rebalance may fire (cold-start guard)
    skew_threshold: float = 1.5   # min max/mean windowed rank load for
    #                               a rebalance to fire (1.0 = any skew)

    def __post_init__(self):
        if self.window_batches < 1 or self.rebalance_interval < 1 \
                or self.min_window < 1:
            raise ValueError("window_batches, rebalance_interval and "
                             "min_window must be >= 1")
        if self.min_window > self.window_batches:
            raise ValueError("min_window cannot exceed window_batches")
        if self.skew_threshold < 1.0:
            raise ValueError("skew_threshold must be >= 1.0 "
                             "(max/mean load is never below 1)")


@dataclasses.dataclass(frozen=True)
class SegmentBudget:
    """Per-segment space budget (§2.2: ≤2 GB DRAM, ≤10 GB disk;
    DESIGN.md §3: plus a device VMEM cap for the tier-0 hot-tile pack —
    VMEM is ~16 MB/core and the search step needs most of it for
    working tiles, so tier 0 gets a small carve-out)."""
    memory_bytes: int = 2 << 30
    disk_bytes: int = 10 << 30
    tier0_vmem_bytes: int = 4 << 20


@dataclasses.dataclass(frozen=True)
class DeviceSearchParams:
    """Batched device-search knobs (``device_search.device_anns`` /
    ``make_search_step``) — the TPU analogue of ``SearchParams``.

    Frozen and hashable, so it rides through ``jax.jit`` as a static
    argument: one compiled executable per distinct parameter set.

    ``fetch_width`` (F) fetches the F best unvisited candidates' blocks
    per DMA round trip (beyond-paper: the Central Assumption prices a
    few random reads per round-trip like one). ``tier0_frac`` sizes the
    VMEM hot-tile pack for ``make_search_step``'s specs; segments built
    through ``from_segment`` take the (equivalent) budget from
    ``CacheParams`` so host and device agree. ``fetch_impl`` picks the
    fused Pallas round kernel (probe + deduped gather + rank) or the
    pure-jnp reference fetch stage — both bit-identical.

    ``compact_frac`` > 0 enables active-query compaction: when the live
    fraction of the batch drops below the threshold, the round repacks
    live queries to the front (a stable permutation, inverted on exit)
    so converged queries cluster into whole kernel tiles the fused
    round kernel skips. 0 disables compaction; results are identical
    either way — only which tile a query lands in (and thus the dedup
    grouping of its block requests) moves.
    """
    k: int = 10                   # results per query
    candidates: int = 64          # Γ (candidate-set size)
    sigma: float = 0.3            # σ (block-pruning ratio)
    max_hops: int = 128           # round-trip cap (safety valve)
    fetch_width: int = 1          # F: blocks fetched per round trip
    nav_beam: int = 8             # navigation-graph beam width
    nav_hops: int = 12            # navigation-graph beam iterations
    entry_points: int = 4         # entries handed to the block search
    tier0_frac: float = 0.0       # VMEM hot-tile share of the block file
    fetch_impl: str = "fused"     # fused (Pallas kernel) | jnp (reference)
    compact_frac: float = 0.0     # repack live queries to the front when
    #                               the active fraction falls below this
    #                               (0 = never compact)
    trace_rounds: bool = False    # carry the per-round trace buffer
    #                               (repro.obs.roundlog) through the loop
    #                               and return it on the result; (ids,
    #                               dists) and every counter are
    #                               bit-identical on or off
    pipeline_dma: bool = True     # double-buffer the fused kernel's
    #                               cold-block gather (make_async_copy
    #                               two-slot schedule) on compiled runs;
    #                               interpret always takes the straight-
    #                               line fallback, and the jnp fetch
    #                               stage ignores it. Payloads are
    #                               bit-identical on or off — only the
    #                               DMA schedule (and the cost model's
    #                               max(dma, compute) overlap pricing,
    #                               via IOStats.dma_pipelined) moves.
    round_tile_cap: int = 0       # cap on the round kernel's query-tile
    #                               size (0 = the kernel's BQ ceiling).
    #                               Dedup is batch-scope regardless; the
    #                               tile is the idle-skip/compaction
    #                               granularity and the intra- vs
    #                               cross-tile accounting boundary —
    #                               tests/benches shrink it to exercise
    #                               multi-tile batches cheaply.
    speculate: bool = False       # cross-round speculative pipeline
    #                               (DESIGN.md §9): predict round i+1's
    #                               cold-block union from round i's
    #                               ranked expansion candidates and
    #                               issue its gather while round i's
    #                               top-M maintenance runs. Never wrong,
    #                               only late — a mis-speculated block
    #                               is re-gathered by the authoritative
    #                               round fetch, so (ids, dists) and
    #                               every existing counter are
    #                               bit-identical on or off; only the
    #                               spec_hits/spec_wasted accounting
    #                               (and the CostModel's speculative
    #                               overlap pricing) move.
    fuse_union: bool = True       # fuse the pass-1 sorted-unique block
    #                               union into the round kernel's pass 2
    #                               (SMEM-staged slot map) instead of
    #                               running it as jnp ops between the
    #                               pallas_calls. Payload-bit-identical
    #                               either way — the union math is the
    #                               shared kernels.dedup formulation in
    #                               both placements.

    def __post_init__(self):
        if self.k < 1 or self.candidates < self.k:
            raise ValueError("need candidates >= k >= 1")
        if not (0.0 <= self.sigma <= 1.0
                and 0.0 <= self.tier0_frac <= 1.0):
            raise ValueError("sigma and tier0_frac must be in [0, 1]")
        if self.fetch_width < 1 or self.max_hops < 1:
            raise ValueError("fetch_width and max_hops must be >= 1")
        if self.fetch_impl not in ("fused", "jnp"):
            raise ValueError(
                f"unknown fetch_impl {self.fetch_impl!r} (fused | jnp)")
        if not (0.0 <= self.compact_frac <= 1.0):
            raise ValueError("compact_frac must be in [0, 1]")
        if self.round_tile_cap < 0:
            raise ValueError("round_tile_cap must be >= 0 (0 = BQ)")


@dataclasses.dataclass(frozen=True)
class SegmentParams:
    graph: GraphParams = dataclasses.field(default_factory=GraphParams)
    layout: LayoutParams = dataclasses.field(default_factory=LayoutParams)
    pq: PQParams = dataclasses.field(default_factory=PQParams)
    nav: NavGraphParams = dataclasses.field(default_factory=NavGraphParams)
    search: SearchParams = dataclasses.field(default_factory=SearchParams)
    cache: CacheParams = dataclasses.field(default_factory=CacheParams)
    budget: SegmentBudget = dataclasses.field(default_factory=SegmentBudget)
    metric: str = "l2"            # l2 | ip

    def __post_init__(self):
        assert self.metric in ("l2", "ip")
