"""Block-resident storage of the graph index (Fig. 2(c) left; DESIGN.md §2).

The store is the only path the online search may use to touch vectors or
adjacency — every access is a *block* fetch, mirroring o_direct 4 KB reads
(paper) / HBM→VMEM DMA tiles (TPU mapping). Byte accounting follows
Example 2: γ = D·b + 4 + Λ·4 per vertex, ε = ⌊η/γ⌋ vertices per block.

Layout in memory:
  vid  [ρ, ε]        int32  vertex id per slot (-1 pad)
  vecs [ρ, ε, D]     f32    full-precision vectors
  meta [ρ, ε, 1+Λ]   int32  degree ‖ neighbor ids (-1 pad)

``packed()`` returns the single fused [ρ, ε·(D+1+Λ)] f32 tensor (ids
bit-cast) used by the device-side search and the Pallas kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.graph import Graph
from repro.core.layout import BlockLayout


@dataclasses.dataclass
class BlockStore:
    vid: np.ndarray
    vecs: np.ndarray
    meta: np.ndarray
    block_kb: float
    dtype_bytes: int = 4

    @property
    def num_blocks(self) -> int:
        return self.vid.shape[0]

    @property
    def verts_per_block(self) -> int:
        return self.vid.shape[1]

    @property
    def dim(self) -> int:
        return self.vecs.shape[2]

    @property
    def max_degree(self) -> int:
        return self.meta.shape[2] - 1

    def vertex_bytes(self) -> int:
        """γ in bytes (Example 2)."""
        return self.dim * self.dtype_bytes + 4 + self.max_degree * 4

    def disk_bytes(self) -> int:
        """Total 'disk' footprint: ρ blocks of η KB."""
        return int(self.num_blocks * self.block_kb * 1024)

    def read_block(self, b: int) -> Tuple[np.ndarray, np.ndarray,
                                          np.ndarray, np.ndarray]:
        """One I/O: (ids [ε], vecs [ε, D], deg [ε], nbrs [ε, Λ])."""
        return (self.vid[b], self.vecs[b],
                self.meta[b, :, 0], self.meta[b, :, 1:])

    def packed(self) -> np.ndarray:
        """[ρ, ε·(D+1+Λ)] f32 fused tile (ids bit-cast to f32)."""
        rho, eps, d = self.vecs.shape
        meta_f = self.meta.view(np.float32).reshape(rho, eps, -1)
        return np.concatenate([self.vecs, meta_f], axis=2).reshape(rho, -1)


def build_store(x: np.ndarray, g: Graph, layout: BlockLayout,
                block_kb: float, dtype_bytes: int = 4) -> BlockStore:
    n, d = x.shape
    rho, eps = layout.blocks.shape
    vid = layout.blocks.copy()
    vecs = np.zeros((rho, eps, d), np.float32)
    meta = np.full((rho, eps, 1 + g.max_degree), -1, np.int32)
    meta[:, :, 0] = 0
    valid = vid >= 0
    ids = vid[valid].astype(np.int64)
    vecs[valid] = x[ids]
    meta[valid, 0] = g.deg[ids]
    meta[valid, 1:] = g.adj[ids]
    return BlockStore(vid=vid, vecs=vecs, meta=meta, block_kb=block_kb,
                      dtype_bytes=dtype_bytes)
