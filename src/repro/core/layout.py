"""Block-level graph layout and block shuffling (§4.1).

A layout assigns |V| vertices to ρ blocks of capacity ε. The objective is to
maximize the overlap ratio

    OR(u) = |B(u) ∩ N(u)| / (|B(u)| − 1)        (Eq. 5)
    OR(G) = mean_u OR(u)

which Theorem 4.1 shows is NP-hard to optimize (no finite-factor poly-time
approximation unless P=NP). We implement the paper's three heuristics:

  * BNP — Block Neighbor Padding (one pass, Example 4)
  * BNF — Block Neighbor Frequency (Algorithm 1)
  * BNS — Block Neighbor Swap (Algorithm 3, Lemma 4.2 monotone)

plus the DiskANN baseline (ID-contiguous), a k-means packer (the §7
"naive strategy" comparison), and a GP3-style prioritized-gain restreaming
variant (App. G) for the graph-partitioning comparison.

All of these are pure integer/statistics passes over the adjacency — no
vector-distance computation — exactly as the paper stresses for its
"Time cost" analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass
class BlockLayout:
    """blocks[b] lists vertex ids in block b (-1 padded);
    block_of[u] / slot_of[u] invert the map (the C_mapping of Eq. 10)."""
    blocks: np.ndarray        # [ρ, ε] int32, -1 padded
    block_of: np.ndarray      # [N] int32
    slot_of: np.ndarray       # [N] int32

    @property
    def num_blocks(self) -> int:
        return self.blocks.shape[0]

    @property
    def verts_per_block(self) -> int:
        return self.blocks.shape[1]

    def validate(self) -> None:
        """Layout must be a bijection V -> (block, slot)."""
        n = self.block_of.shape[0]
        flat = self.blocks[self.blocks >= 0]
        assert flat.shape[0] == n, "every vertex assigned exactly once"
        assert np.array_equal(np.sort(flat), np.arange(n)), "permutation"
        assert np.all(
            self.blocks[self.block_of, self.slot_of] == np.arange(n))

    def mapping_bytes(self) -> int:
        """C_mapping memory charge (Eq. 10): block id + slot per vertex."""
        return self.block_of.nbytes + self.slot_of.nbytes


def _from_block_of(block_of: np.ndarray, rho: int, eps: int) -> BlockLayout:
    n = block_of.shape[0]
    blocks = np.full((rho, eps), -1, np.int32)
    slot_of = np.empty(n, np.int32)
    fill = np.zeros(rho, np.int32)
    for u in range(n):
        b = block_of[u]
        blocks[b, fill[b]] = u
        slot_of[u] = fill[b]
        fill[b] += 1
    return BlockLayout(blocks=blocks, block_of=block_of.astype(np.int32),
                       slot_of=slot_of)


def _neighbor_keys(g: Graph) -> np.ndarray:
    """Sorted u*N+v keys of all directed edges, for O(log E) membership."""
    e = g.edges().astype(np.int64)
    return np.sort(e[:, 0] * g.num_vertices + e[:, 1])


def overlap_ratio(g: Graph, layout: BlockLayout,
                  keys: Optional[np.ndarray] = None) -> float:
    """OR(G) (Eq. 5), fully vectorized."""
    return float(per_vertex_overlap(g, layout, keys).mean())


def per_vertex_overlap(g: Graph, layout: BlockLayout,
                       keys: Optional[np.ndarray] = None) -> np.ndarray:
    n = g.num_vertices
    keys = _neighbor_keys(g) if keys is None else keys
    members = layout.blocks[layout.block_of]          # [N, ε]
    valid = (members >= 0) & (members != np.arange(n)[:, None])
    pair = np.arange(n, dtype=np.int64)[:, None] * n + members
    idx = np.searchsorted(keys, pair.ravel())
    idx = np.minimum(idx, keys.shape[0] - 1)
    hit = (keys[idx] == pair.ravel()).reshape(n, -1) & valid
    sizes = (members >= 0).sum(axis=1)
    denom = np.maximum(sizes - 1, 1)
    orr = hit.sum(axis=1) / denom
    orr[sizes <= 1] = 0.0
    return orr.astype(np.float32)


# ---------------------------------------------------------------- baseline

def layout_sequential(g: Graph, eps: int) -> BlockLayout:
    """DiskANN baseline: ID-contiguous vertices per block (Fig. 2(a))."""
    n = g.num_vertices
    rho = -(-n // eps)
    block_of = (np.arange(n) // eps).astype(np.int32)
    return _from_block_of(block_of, rho, eps)


# --------------------------------------------------------------------- BNP

def layout_bnp(g: Graph, eps: int) -> BlockLayout:
    """Block Neighbor Padding: scan ids ascending; place each unassigned
    vertex then pad the block with its unassigned neighbors."""
    n = g.num_vertices
    rho = -(-n // eps)
    block_of = np.full(n, -1, np.int32)
    cur, fill = 0, 0
    for u in range(n):
        if block_of[u] >= 0:
            continue
        if fill >= eps:
            cur, fill = cur + 1, 0
        block_of[u] = cur
        fill += 1
        for v in g.adj[u, : g.deg[u]]:
            if fill >= eps:
                break
            if block_of[v] < 0:
                block_of[v] = cur
                fill += 1
        if fill >= eps:
            cur, fill = cur + 1, 0
    return _from_block_of(block_of, rho, eps)


# --------------------------------------------------------------------- BNF

def layout_bnf(g: Graph, eps: int, iters: int = 8, tau: float = 0.01,
               init: Optional[BlockLayout] = None,
               gain_order: bool = False) -> Tuple[BlockLayout, list]:
    """Block Neighbor Frequency (Algorithm 1).

    Each round: snapshot D = vertex→block; clear blocks; re-stream vertices,
    assigning each to the non-full block holding most of its neighbors
    (under D); overflow goes to the emptiest block. Stops when the OR(G)
    gain between rounds falls below τ or after β rounds.

    ``gain_order=True`` re-streams vertices by descending best-block
    neighbor count — the GP3 prioritized-restreaming variant of App. G.
    Otherwise vertices are re-streamed grouped by their previous block
    (cohorts arrive together, so a cohesive block can re-claim its slots
    before filling up with strangers — the restreaming-partitioner order).

    Returns (best_layout, [OR(G) after each round]).
    """
    n = g.num_vertices
    rho = -(-n // eps)
    layout = init if init is not None else layout_bnp(g, eps)
    keys = _neighbor_keys(g)
    history = [overlap_ratio(g, layout, keys)]
    best, best_or = layout, history[0]
    prev = layout.block_of.copy()

    # Symmetrized adjacency: placing u with a vertex w improves OR through
    # *either* direction (u→w raises OR(u); w→u raises OR(w)), so the
    # neighbor-frequency signal must count in- and out-edges. CSR form.
    e = g.edges().astype(np.int64)
    sym = np.concatenate([e, e[:, ::-1]], axis=0)
    sym = sym[np.argsort(sym[:, 0], kind="stable")]
    starts = np.searchsorted(sym[:, 0], np.arange(n + 1))
    sym_dst = sym[:, 1].astype(np.int32)

    for _ in range(iters):
        if gain_order:
            gains = np.zeros(n, np.int32)
            for u in range(n):
                row = prev[sym_dst[starts[u]:starts[u + 1]]]
                if row.size:
                    gains[u] = np.bincount(row).max(initial=0)
            order = np.argsort(-gains, kind="stable")
        else:
            order = np.argsort(prev, kind="stable")
        new = np.full(n, -1, np.int32)
        fill = np.zeros(rho, np.int32)
        spill_ptr = 0
        for u in order:
            row = prev[sym_dst[starts[u]:starts[u + 1]]]
            placed = False
            if row.size:
                cnt = np.bincount(row)
                cand = np.argsort(-cnt, kind="stable")
                for b in cand:
                    if cnt[b] == 0:
                        break
                    if fill[b] < eps:
                        new[u] = b
                        fill[b] += 1
                        placed = True
                        break
            if not placed:                       # lines 13–14: spill
                while fill[spill_ptr] >= eps:
                    spill_ptr += 1
                new[u] = spill_ptr
                fill[spill_ptr] += 1
        layout = _from_block_of(new, rho, eps)
        cur = overlap_ratio(g, layout, keys)
        gain = cur - history[-1]
        history.append(cur)
        prev = new
        if cur > best_or:
            best, best_or = layout, cur
        if gain < tau:
            break
    return best, history


# --------------------------------------------------------------------- BNS

def layout_bns(g: Graph, eps: int, iters: int = 2, tau: float = 0.01,
               init: Optional[BlockLayout] = None,
               rng_seed: int = 0) -> Tuple[BlockLayout, list]:
    """Block Neighbor Swap (Algorithm 3).

    For each vertex u and each pair (a, e) of its neighbors living in
    different blocks, swap the min-OR vertices of B(a) and B(e) iff the
    summed OR of the two blocks strictly increases — hence OR(G) is
    monotone non-decreasing in β (Lemma 4.2).

    O(β·o³·ε·|V|): intended for small/medium segments (App. F runs it on
    1M vectors with hours of budget; we keep it exact and let callers
    choose scale).
    """
    n = g.num_vertices
    rho = -(-n // eps)
    layout = init if init is not None else layout_bnp(g, eps)
    keys = _neighbor_keys(g)
    block_of = layout.block_of.copy()
    blocks = [list(layout.blocks[b][layout.blocks[b] >= 0])
              for b in range(rho)]
    nbr_sets = [set(g.adj[u, : g.deg[u]].tolist())
                for u in range(n)]

    def or_of_vertex(u: int, members) -> float:
        others = [m for m in members if m != u]
        if not others:
            return 0.0
        return sum(1 for m in others if m in nbr_sets[u]) / len(others)

    def or_of_block(members) -> float:
        if not members:
            return 0.0
        return sum(or_of_vertex(u, members) for u in members) / len(members)

    history = [overlap_ratio(g, layout, keys)]
    for _ in range(iters):
        improved = 0.0
        for u in range(n):
            nb = g.adj[u, : g.deg[u]]
            for i in range(nb.shape[0]):
                for j in range(i + 1, nb.shape[0]):
                    a, e = int(nb[i]), int(nb[j])
                    ba, be = block_of[a], block_of[e]
                    if ba == be:
                        continue
                    ma, me = blocks[ba], blocks[be]
                    x = min(ma, key=lambda v: or_of_vertex(v, ma))
                    y = min(me, key=lambda v: or_of_vertex(v, me))
                    old = or_of_block(ma) + or_of_block(me)
                    ma2 = [v for v in ma if v != x] + [y]
                    me2 = [v for v in me if v != y] + [x]
                    new = or_of_block(ma2) + or_of_block(me2)
                    if new > old + 1e-12:
                        blocks[ba], blocks[be] = ma2, me2
                        block_of[x], block_of[y] = be, ba
                        improved += new - old
        lay = _pack(blocks, rho, eps, n)
        cur = overlap_ratio(g, lay, keys)
        history.append(cur)
        if cur - history[-2] < tau:
            break
    return _pack(blocks, rho, eps, n), history


def _pack(block_lists, rho, eps, n) -> BlockLayout:
    blocks = np.full((rho, eps), -1, np.int32)
    block_of = np.empty(n, np.int32)
    slot_of = np.empty(n, np.int32)
    for b, mem in enumerate(block_lists):
        for s, u in enumerate(mem):
            blocks[b, s] = u
            block_of[u] = b
            slot_of[u] = s
    return BlockLayout(blocks=blocks, block_of=block_of, slot_of=slot_of)


# ----------------------------------------------------- comparison packers

def layout_kmeans(x: np.ndarray, g: Graph, eps: int, iters: int = 8,
                  seed: int = 0) -> BlockLayout:
    """§7 'naive strategy that assigns vertices to blocks by k-means':
    balanced k-means packer — cluster, then greedily fill blocks from
    cluster-ordered vertices."""
    from repro.core import distances as D
    n = x.shape[0]
    rho = -(-n // eps)
    rng = np.random.default_rng(seed)
    k = max(rho // 4, 1)
    cent = x[rng.choice(n, size=k, replace=False)].astype(np.float32)
    for _ in range(iters):
        assign = np.argmin(D.pairwise(x, cent), axis=1)
        for c in range(k):
            m = assign == c
            if m.any():
                cent[c] = x[m].mean(axis=0)
    order = np.argsort(assign, kind="stable")
    block_of = np.empty(n, np.int32)
    block_of[order] = (np.arange(n) // eps).astype(np.int32)
    return _from_block_of(block_of, rho, eps)


def make_layout(g: Graph, eps: int, scheme: str,
                x: Optional[np.ndarray] = None,
                bnf_iters: int = 8, bns_iters: int = 2,
                tau: float = 0.01) -> BlockLayout:
    if scheme == "none":
        return layout_sequential(g, eps)
    if scheme == "bnp":
        return layout_bnp(g, eps)
    if scheme == "bnf":
        return layout_bnf(g, eps, iters=bnf_iters, tau=tau)[0]
    if scheme == "bns":
        init, _ = layout_bnf(g, eps, iters=bnf_iters, tau=tau)
        return layout_bns(g, eps, iters=bns_iters, tau=tau, init=init)[0]
    if scheme == "kmeans":
        assert x is not None
        return layout_kmeans(x, g, eps)
    if scheme == "gp3":
        return layout_bnf(g, eps, iters=bnf_iters, tau=tau,
                          gain_order=True)[0]
    raise ValueError(scheme)
