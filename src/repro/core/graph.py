"""Graph-index construction (Vamana / NSG-flavour / HNSW-flavour).

The paper deliberately reuses existing graph algorithms (§4: "We do not focus
on developing a specific graph index algorithm") — Starling's contribution is
the *layout* and *search strategy* around them. We therefore implement the
standard constructions:

  * ``vamana`` — DiskANN's graph [35]: iterative insertion, greedy search for
    candidates, RobustPrune(α), reverse-edge insertion. Insertions are batched
    (as in the parallel DiskANN build) for single-core throughput.
  * ``nsg``    — NSG-flavour [25]: exact KNN seed graph + MRNG-style prune
    (RobustPrune with α=1) from the medoid + connectivity fix.
  * ``hnsw``   — HNSW-flavour [49]: geometric level assignment; each level is
    a pruned KNN graph over its subset; level 0 is the disk graph and upper
    levels form the in-memory multi-layer navigation structure (Fig. 16(b)).

Adjacency is stored dense: ``adj [N, Λ] int32`` padded with -1 and
``deg [N] int32`` — exactly the on-disk vertex format (vector ‖ λ ‖ Λ ids).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core import distances as D
from repro.core.params import GraphParams


@dataclasses.dataclass
class Graph:
    adj: np.ndarray          # [N, Λ] int32, -1 padded
    deg: np.ndarray          # [N] int32
    entry: int               # medoid / entry vertex id
    metric: str = "l2"

    @property
    def num_vertices(self) -> int:
        return self.adj.shape[0]

    @property
    def max_degree(self) -> int:
        return self.adj.shape[1]

    def neighbors(self, u: int) -> np.ndarray:
        return self.adj[u, : self.deg[u]]

    def avg_degree(self) -> float:
        return float(self.deg.mean())

    def edges(self) -> np.ndarray:
        """[(u, v)] edge list, [E, 2] int32 (deg-masked: slots past
        deg[u] are ignored even if non-negative)."""
        mask = (np.arange(self.max_degree)[None, :] < self.deg[:, None])
        mask &= self.adj >= 0
        u = np.repeat(np.arange(self.num_vertices, dtype=np.int32),
                      mask.sum(axis=1))
        v = self.adj[mask]
        return np.stack([u, v.astype(np.int32)], axis=1)


def medoid(x: np.ndarray, metric: str = "l2") -> int:
    mean = x.mean(axis=0)
    return int(np.argmin(D.point_to_points(mean, x, metric)))


def robust_prune(u: int, cand_ids: np.ndarray, cand_dist: np.ndarray,
                 x: np.ndarray, max_degree: int, alpha: float,
                 metric: str = "l2") -> np.ndarray:
    """DiskANN RobustPrune: keep v only if no kept w has
    α·dist(w, v) <= dist(u, v). Returns selected ids (≤ Λ)."""
    order = np.argsort(cand_dist, kind="stable")
    ids = cand_ids[order]
    dist_u = cand_dist[order]
    keep = ~(ids == u)
    ids, dist_u = ids[keep], dist_u[keep]
    # dedupe, stable
    _, first = np.unique(ids, return_index=True)
    sel_mask = np.zeros(ids.shape[0], bool)
    sel_mask[np.sort(first)] = True
    ids, dist_u = ids[sel_mask], dist_u[sel_mask]

    selected: List[int] = []
    alive = np.ones(ids.shape[0], bool)
    for i in range(ids.shape[0]):
        if not alive[i]:
            continue
        v = int(ids[i])
        selected.append(v)
        if len(selected) >= max_degree:
            break
        rest = np.where(alive)[0]
        rest = rest[rest > i]
        if rest.size:
            dv = D.point_to_points(x[v], x[ids[rest]], metric)
            alive[rest[alpha * dv <= dist_u[rest]]] = False
    return np.asarray(selected, np.int32)


def greedy_search_batch(x: np.ndarray, adj: np.ndarray, deg: np.ndarray,
                        entry: int, queries: np.ndarray, beam: int,
                        metric: str = "l2", max_hops: int = 512,
                        ) -> Tuple[np.ndarray, np.ndarray, List[dict]]:
    """Batched best-first (beam) search on the current graph.

    Returns (ids [B, beam], dists [B, beam], visited list-of-dicts
    {id: dist}) — visited sets feed RobustPrune during construction.
    Vectorized across the batch; the hop loop is host-level (as in any
    CPU graph build).
    """
    B = queries.shape[0]
    INF = np.float32(np.inf)
    cand_ids = np.full((B, beam), -1, np.int64)
    cand_dist = np.full((B, beam), INF, np.float32)
    expanded = np.zeros((B, beam), bool)
    d0 = D.pairwise(queries, x[entry][None, :], metric)[:, 0]
    cand_ids[:, 0] = entry
    cand_dist[:, 0] = d0
    visited = [{int(entry): float(d0[b])} for b in range(B)]

    for _ in range(max_hops):
        # pick first unexpanded candidate per query
        open_mask = (~expanded) & (cand_ids >= 0)
        has_open = open_mask.any(axis=1)
        if not has_open.any():
            break
        first_open = np.where(open_mask, np.arange(beam)[None, :], beam)
        pick = first_open.min(axis=1)          # [B]
        rows = np.where(has_open)[0]
        picks = pick[rows]
        expanded[rows, picks] = True
        cur = cand_ids[rows, picks].astype(np.int64)

        nbr = adj[cur]                          # [R, Λ]
        valid = nbr >= 0
        # distances for all (row, neighbor) pairs in one BLAS call
        flat_ids = nbr[valid]
        if flat_ids.size == 0:
            continue
        row_of = np.repeat(np.arange(rows.size), valid.sum(axis=1))
        dists = np.einsum(
            "nd,nd->n",
            x[flat_ids] - queries[rows][row_of],
            x[flat_ids] - queries[rows][row_of]) if metric == "l2" else \
            -np.einsum("nd,nd->n", x[flat_ids], queries[rows][row_of])

        # merge per row (python loop over batch rows; candidate arrays tiny)
        ptr = 0
        counts = valid.sum(axis=1)
        for ri, b in enumerate(rows):
            cnt = counts[ri]
            ids_r = flat_ids[ptr:ptr + cnt]
            d_r = dists[ptr:ptr + cnt]
            ptr += cnt
            vb = visited[b]
            new_mask = np.fromiter((int(i) not in vb for i in ids_r),
                                   bool, cnt)
            if not new_mask.any():
                continue
            ids_n, d_n = ids_r[new_mask], d_r[new_mask]
            for i, dd in zip(ids_n, d_n):
                vb[int(i)] = float(dd)
            merged_ids = np.concatenate([cand_ids[b], ids_n])
            merged_d = np.concatenate([cand_dist[b], d_n])
            merged_e = np.concatenate([expanded[b],
                                       np.zeros(ids_n.shape[0], bool)])
            order = np.argsort(merged_d, kind="stable")[:beam]
            cand_ids[b] = merged_ids[order]
            cand_dist[b] = merged_d[order]
            expanded[b] = merged_e[order]
    return cand_ids, cand_dist, visited


def _add_reverse_edges(x, adj, deg, batch_ids, max_degree, alpha, metric):
    """After inserting batch vertices, add reverse edges u->v => v->u with
    RobustPrune on overflow (DiskANN insert step 3)."""
    pending: dict = {}
    for u in batch_ids:
        for v in adj[u, : deg[u]]:
            pending.setdefault(int(v), []).append(int(u))
    for v, new_in in pending.items():
        room = max_degree - deg[v]
        uniq = [w for w in dict.fromkeys(new_in)
                if w not in set(adj[v, : deg[v]].tolist())]
        if not uniq:
            continue
        if len(uniq) <= room:
            adj[v, deg[v]: deg[v] + len(uniq)] = uniq
            deg[v] += len(uniq)
        else:
            cand = np.concatenate([adj[v, : deg[v]],
                                   np.asarray(uniq, np.int32)])
            cd = D.point_to_points(x[v], x[cand], metric)
            sel = robust_prune(v, cand, cd, x, max_degree, alpha, metric)
            adj[v] = -1
            adj[v, : sel.shape[0]] = sel
            deg[v] = sel.shape[0]


def build_vamana(x: np.ndarray, p: GraphParams, metric: str = "l2") -> Graph:
    """Batched-insertion Vamana (DiskANN §Algorithm 1–3)."""
    n = x.shape[0]
    L, R, alpha = p.build_beam, p.max_degree, p.alpha
    rng = np.random.default_rng(p.seed)
    adj = np.full((n, R), -1, np.int32)
    deg = np.zeros(n, np.int32)
    ep = medoid(x, metric)

    order = rng.permutation(n)
    # seed: connect a small bootstrap clique around the medoid
    boot = order[: min(R + 1, n)]
    for i, u in enumerate(boot):
        others = np.delete(boot, i)[: R]
        dd = D.point_to_points(x[u], x[others], metric)
        sel = robust_prune(int(u), others.astype(np.int32),
                           dd.astype(np.float32), x, R, alpha, metric)
        adj[u, : sel.shape[0]] = sel
        deg[u] = sel.shape[0]

    done = set(int(b) for b in boot)
    todo = [int(u) for u in order if int(u) not in done]
    for s in range(0, len(todo), p.insert_batch):
        batch = np.asarray(todo[s: s + p.insert_batch], np.int64)
        _, _, visited = greedy_search_batch(
            x, adj, deg, ep, x[batch], beam=L, metric=metric)
        for bi, u in enumerate(batch):
            vis = visited[bi]
            ids = np.fromiter(vis.keys(), np.int32, len(vis))
            dd = np.fromiter(vis.values(), np.float32, len(vis))
            # fold in any reverse edges already attached to u so they
            # survive its own insertion prune
            if deg[u]:
                prev = adj[u, : deg[u]]
                ids = np.concatenate([ids, prev])
                dd = np.concatenate(
                    [dd, D.point_to_points(x[u], x[prev], metric)])
            sel = robust_prune(int(u), ids, dd, x, R, alpha, metric)
            adj[u] = -1                       # clear stale slots
            adj[u, : sel.shape[0]] = sel
            deg[u] = sel.shape[0]
        _add_reverse_edges(x, adj, deg, batch, R, alpha, metric)
    g = Graph(adj=adj, deg=deg, entry=ep, metric=metric)
    _ensure_reachable(x, g)
    return g


def build_nsg(x: np.ndarray, p: GraphParams, metric: str = "l2") -> Graph:
    """NSG-flavour: exact KNN seed + α=1 prune + connectivity fix."""
    n = x.shape[0]
    R = p.max_degree
    k = min(max(2 * R, p.build_beam), n - 1)
    knn = D.knn_graph(x, k, metric)
    adj = np.full((n, R), -1, np.int32)
    deg = np.zeros(n, np.int32)
    for u in range(n):
        cand = knn[u]
        cd = D.point_to_points(x[u], x[cand], metric)
        sel = robust_prune(u, cand, cd, x, R, 1.0, metric)
        adj[u, : sel.shape[0]] = sel
        deg[u] = sel.shape[0]
    g = Graph(adj=adj, deg=deg, entry=medoid(x, metric), metric=metric)
    _ensure_reachable(x, g)
    return g


def _reachable(g: Graph) -> np.ndarray:
    seen = np.zeros(g.num_vertices, bool)
    stack = [g.entry]
    seen[g.entry] = True
    while stack:
        u = stack.pop()
        for v in g.adj[u, : g.deg[u]]:
            if not seen[v]:
                seen[v] = True
                stack.append(int(v))
    return seen


def _ensure_reachable(x: np.ndarray, g: Graph, max_rounds: int = 16
                      ) -> None:
    """Attach unreachable vertices to their nearest reachable vertex
    (NSG spanning-tree fix). Hosts with spare degree get a new edge;
    full hosts sacrifice their last slot — which can orphan a previously
    reachable vertex, so reachability is re-verified until it converges.
    """
    n = g.num_vertices
    for _ in range(max_rounds):
        seen = _reachable(g)
        missing = np.where(~seen)[0]
        if missing.size == 0:
            return
        reach = np.where(seen)[0]
        used_slots: set = set()
        for u in missing:
            dd = D.point_to_points(x[u], x[reach], g.metric)
            order = np.argsort(dd)
            placed = False
            for oi in order[:8]:               # prefer a near host w/room
                h = int(reach[oi])
                if g.deg[h] < g.max_degree:
                    g.adj[h, g.deg[h]] = u
                    g.deg[h] += 1
                    placed = True
                    break
            if not placed:                     # any reachable host w/room
                room = g.deg[reach] < g.max_degree
                if room.any():
                    cand = reach[room]
                    h = int(cand[np.argmin(
                        D.point_to_points(x[u], x[cand], g.metric))])
                    g.adj[h, g.deg[h]] = u
                    g.deg[h] += 1
                    placed = True
            if not placed:                     # overwrite a full host's
                for oi in order:               # last slot (once/round)
                    h = int(reach[oi])
                    slot = g.deg[h] - 1
                    if (h, slot) not in used_slots:
                        g.adj[h, slot] = u
                        used_slots.add((h, slot))
                        break
    assert _reachable(g).all(), "connectivity fix did not converge"


@dataclasses.dataclass
class HNSWGraph:
    """Multi-layer structure; ``layers[0]`` is the (disk) base graph and
    ``layers[1:]`` + ``level_ids`` form the in-memory upper layers."""
    layers: List[Graph]
    level_ids: List[np.ndarray]   # global ids of vertices on each level
    metric: str = "l2"

    @property
    def base(self) -> Graph:
        return self.layers[0]


def build_hnsw(x: np.ndarray, p: GraphParams, metric: str = "l2",
               level_mult: Optional[float] = None) -> HNSWGraph:
    n = x.shape[0]
    rng = np.random.default_rng(p.seed)
    m = p.max_degree
    level_mult = level_mult or 1.0 / np.log(max(m, 2))
    levels = np.minimum(
        (-np.log(rng.uniform(size=n) + 1e-12) * level_mult).astype(np.int32),
        6)
    max_level = int(levels.max())
    layers: List[Graph] = []
    level_ids: List[np.ndarray] = []
    for lv in range(max_level + 1):
        ids = np.where(levels >= lv)[0].astype(np.int32)
        if ids.size < 2:
            break
        sub = x[ids]
        deg_cap = m if lv == 0 else max(m // 2, 4)
        gp = dataclasses.replace(p, max_degree=deg_cap,
                                 build_beam=max(p.build_beam, deg_cap))
        g = (build_vamana(sub, gp, metric) if lv == 0 and ids.size > 512
             else build_nsg(sub, gp, metric))
        layers.append(g)
        level_ids.append(ids)
    return HNSWGraph(layers=layers, level_ids=level_ids, metric=metric)


def build_graph(x: np.ndarray, p: GraphParams, metric: str = "l2") -> Graph:
    if p.algo == "vamana":
        return build_vamana(x, p, metric)
    if p.algo == "nsg":
        return build_nsg(x, p, metric)
    if p.algo == "hnsw":
        return build_hnsw(x, p, metric).base
    raise ValueError(p.algo)
