from repro.optim.adamw import (AdamW, adamw_init, adamw_update,
                               cosine_schedule, linear_warmup,
                               global_norm, clip_by_global_norm)
