"""AdamW with global-norm clipping and LR schedules.

State moments are fp32 and inherit the parameter sharding (ZeRO-style:
whatever dims FSDP shards on params are sharded identically on m/v, so
optimizer memory scales down with the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Tree = Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5
                      * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def linear_warmup(peak: float, warmup: int) -> Callable:
    def lr(step):
        return peak * jnp.minimum(step.astype(jnp.float32) / warmup, 1.0)
    return lr


def global_norm(tree: Tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: Tree, max_norm: float
                        ) -> Tuple[Tree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def adamw_init(params: Tree) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(opt: AdamW, grads: Tree, state: Dict, params: Tree
                 ) -> Tuple[Tree, Dict, Dict]:
    grads, gnorm = clip_by_global_norm(grads, opt.clip_norm)
    step = state["step"] + 1
    lr = opt.lr(step)
    b1, b2 = opt.b1, opt.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        delta = (m2 / c1) / (jnp.sqrt(v2 / c2) + opt.eps)
        p2 = (p.astype(jnp.float32) * (1.0 - lr * opt.weight_decay)
              - lr * delta)
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
