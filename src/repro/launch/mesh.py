"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module cannot touch jax device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first
jax init; smoke tests and benchmarks see the 1 real CPU device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import (AxisRules, MULTI_POD_RULES,
                                        SINGLE_POD_RULES)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def rules_for(mesh: Mesh) -> AxisRules:
    return MULTI_POD_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES


def make_debug_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over however many devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
