"""Serving steps: LM decode (``serve_step``) and prefill, plus the
Starling segment-search service entrypoint.

Run as a script for a small end-to-end serving demo:
  python -m repro.launch.serve --arch whisper-base --smoke
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig

Tree = Any


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, cache, tokens [B,1]) -> (logits, cache')."""
    def serve_step(params, cache, tokens):
        return lm.decode_step(cfg, params, cache, tokens)
    return serve_step


def make_prefill(cfg: ModelConfig, max_len: int):
    def prefill_fn(params, batch):
        return lm.prefill(cfg, params, batch["tokens"], max_len,
                          patch_embeds=batch.get("patch_embeds"),
                          frames=batch.get("frames"))
    return prefill_fn


def greedy_decode(cfg: ModelConfig, params: Tree, prompt: jnp.ndarray,
                  steps: int, max_len: int, **kw) -> jnp.ndarray:
    """Batched greedy decoding loop (demo / tests)."""
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    logits, cache = lm.prefill(cfg, params, prompt, max_len, **kw)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    for _ in range(steps - 1):
        logits, cache = serve(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.patch_tokens, cfg.d_model))
    if cfg.family == "audio":
        kw["frames"] = jax.random.normal(
            key, (args.batch, cfg.num_mem_tokens, cfg.d_model))
    t0 = time.time()
    toks = greedy_decode(cfg, params, prompt, args.gen,
                         args.prompt_len + args.gen, **kw)
    dt = time.time() - t0
    print(f"decoded {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(toks[0])


if __name__ == "__main__":
    main()
