"""AOT input specs: ShapeDtypeStruct stand-ins (with NamedShardings) for
every model input — weak-type-correct, shardable, no device allocation.

``step_specs(arch, shape, mesh)`` returns (fn_name, kwargs-of-SDS) for the
function the dry-run lowers:
  train_*    -> train_step(params, opt_state, batch)
  prefill_*  -> prefill_fn(params, tokens [, patch/frames])
  decode_*   -> serve_step(params, cache, tokens)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.shapes import SHAPES, Shape
from repro.distributed.sharding import (AxisRules, logical_spec,
                                        spec_tree_to_shape_dtype)
from repro.launch.mesh import rules_for
from repro.models import lm
from repro.models.config import ModelConfig

Tree = Any


def _sds(shape, dtype, mesh, rules, axes):
    sh = NamedSharding(mesh, logical_spec(shape, axes, rules, mesh))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def params_specs(cfg: ModelConfig, mesh: Mesh,
                 rules: Optional[AxisRules] = None) -> Tree:
    rules = rules or rules_for(mesh)
    return spec_tree_to_shape_dtype(lm.param_specs(cfg), rules, mesh)


def opt_specs(cfg: ModelConfig, mesh: Mesh,
              rules: Optional[AxisRules] = None) -> Tree:
    """AdamW m/v mirror the parameter sharding; fp32."""
    rules = rules or rules_for(mesh)
    p = spec_tree_to_shape_dtype(lm.param_specs(cfg), rules, mesh,
                                 dtype=jnp.float32)
    step = jax.ShapeDtypeStruct(
        (), jnp.int32, sharding=NamedSharding(mesh, PartitionSpec()))
    return {"m": p, "v": jax.tree.map(lambda x: x, p), "step": step}


def batch_specs(cfg: ModelConfig, shape: Shape, mesh: Mesh,
                rules: Optional[AxisRules] = None) -> Dict[str, Any]:
    rules = rules or rules_for(mesh)
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    tok_len = s
    if cfg.family == "vlm":
        tok_len = s - cfg.patch_tokens
        out["patch_embeds"] = _sds((b, cfg.patch_tokens, cfg.d_model),
                                   jnp.bfloat16, mesh, rules,
                                   ("batch", None, None))
    if cfg.family == "audio":
        out["frames"] = _sds((b, cfg.num_mem_tokens, cfg.d_model),
                             jnp.bfloat16, mesh, rules,
                             ("batch", None, None))
    out["tokens"] = _sds((b, tok_len), jnp.int32, mesh, rules,
                         ("batch", None))
    out["labels"] = _sds((b, tok_len), jnp.int32, mesh, rules,
                         ("batch", None))
    return out


def _cache_axes(cfg: ModelConfig, path: Tuple[str, ...], ndim: int,
                mesh: Mesh) -> Tuple[Optional[str], ...]:
    """Logical axes for a cache leaf (leading dim = stacked layers).

    KV tensors [L, B, S, Hkv, hd]: shard heads over model when divisible,
    else shard the cache sequence axis (decode sequence-parallelism for
    MQA archs). SSM states: batch + inner dims.
    """
    name = path[-1] if path else ""
    model_size = mesh.shape["model"]
    if name in ("k", "v", "attn_k", "attn_v"):
        if cfg.num_kv_heads % model_size == 0:
            return (None, "batch", None, "kv_heads", None)
        return (None, "batch", "kv_seq", "kv_heads", None)
    if name == "memory":
        return ("batch", None, None)
    if name == "len":
        return ()
    # SSM states: [.., B, ...] with trailing feature dims; shard batch +
    # the widest feature dim over model via "inner" when divisible.
    axes = [None] * ndim
    # find the batch dim: first dim whose size matches is handled by
    # caller passing shapes; here we rely on position: stacked layer dims
    # come first, batch next. ndim>=2 always.
    return tuple(axes)


def cache_specs(cfg: ModelConfig, shape: Shape, mesh: Mesh,
                rules: Optional[AxisRules] = None,
                cache_dtype=jnp.bfloat16) -> Tree:
    """ShapeDtypeStructs for the decode cache (shapes via eval_shape)."""
    rules = rules or rules_for(mesh)
    b = shape.global_batch

    shapes = jax.eval_shape(
        functools.partial(lm.init_cache, cfg, b, shape.seq_len,
                          cache_dtype))

    def annotate(path, leaf):
        names = tuple(str(getattr(p, "key", p)) for p in path)
        axes = list(_cache_axes(cfg, names, len(leaf.shape), mesh))
        # default batch sharding for SSM state leaves: the dim whose size
        # == batch gets the "batch" axis.
        if all(a is None for a in axes):
            for i, d in enumerate(leaf.shape):
                if d == b:
                    axes[i] = "batch"
                    break
        sh = NamedSharding(mesh,
                           logical_spec(leaf.shape, tuple(axes), rules,
                                        mesh))
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=sh)

    return jax.tree_util.tree_map_with_path(annotate, shapes)


def step_specs(cfg: ModelConfig, shape: Shape, mesh: Mesh
               ) -> Tuple[str, Tuple]:
    """(kind, args-of-SDS) for the function the dry-run lowers."""
    rules = rules_for(mesh)
    p = params_specs(cfg, mesh, rules)
    if shape.kind == "train":
        return "train", (p, opt_specs(cfg, mesh, rules),
                         batch_specs(cfg, shape, mesh, rules))
    if shape.kind == "prefill":
        bs = batch_specs(cfg, shape, mesh, rules)
        bs.pop("labels")
        return "prefill", (p, bs)
    if shape.kind == "decode":
        tok = _sds((shape.global_batch, 1), jnp.int32, mesh, rules,
                   ("batch", None))
        return "decode", (p, cache_specs(cfg, shape, mesh, rules), tok)
    raise ValueError(shape.kind)
