# Launch layer: production mesh, AOT input specs, train/serve steps,
# multi-pod dry-run driver. NOTE: dryrun.py must be the process
# entrypoint (it sets XLA_FLAGS before any jax import).
