import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:  jit(step).lower(*ShapeDtypeStructs).compile(), then record
memory_analysis(), cost_analysis() and the collective schedule parsed from
the compiled HLO — the §Dry-run / §Roofline inputs.

Results stream to a JSONL (one record per cell); completed cells are
skipped on re-run, so the full grid can be built incrementally:

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --starling   # search_step
"""
import argparse     # noqa: E402
import functools    # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (ARCH_IDS, SHAPES, get_config,     # noqa: E402
                           skip_reason)
from repro.distributed.hlo import analyze_hlo, collective_bytes  # noqa: E402,E501
from repro.distributed.sharding import use_rules             # noqa: E402
from repro.launch.mesh import make_production_mesh, rules_for  # noqa: E402
from repro.launch.specs import step_specs                    # noqa: E402
from repro.launch.train import default_optimizer, make_train_step  # noqa: E402,E501
from repro.launch.serve import make_prefill, make_serve_step  # noqa: E402

DEFAULT_OUT = os.path.join(os.path.dirname(__file__),
                           "../../../results/dryrun.jsonl")

# v5e hardware constants (roofline)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (ICI)


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def _save_hlo(arch: str, shape: str, multi_pod: bool, tag: str,
              hlo: str) -> str:
    """Persist compiled HLO (gzip) so roofline analysis is re-runnable
    without recompiling (see ``reanalyze``)."""
    import gzip
    d = os.path.join(os.path.dirname(os.path.abspath(DEFAULT_OUT)), "hlo")
    os.makedirs(d, exist_ok=True)
    name = f"{arch}_{shape}_{_mesh_tag(multi_pod)}"
    if tag:
        name += f"_{tag}"
    path = os.path.join(d, name + ".hlo.gz")
    with gzip.open(path, "wt") as f:
        f.write(hlo)
    return path


def reanalyze(out_path: str) -> None:
    """Rebuild roofline fields of every record from stored HLO."""
    import gzip
    recs = []
    with open(out_path) as f:
        for line in f:
            recs.append(json.loads(line))
    for rec in recs:
        p = rec.get("hlo_path")
        if rec.get("status") != "OK" or not p or not os.path.exists(p):
            continue
        with gzip.open(p, "rt") as f:
            hlo = f.read()
        tot = analyze_hlo(hlo)
        rec["hlo_flops"] = tot.flops
        rec["hlo_bytes_raw"] = tot.bytes_accessed
        rec["hlo_bytes"] = tot.bytes_fused
        rec["collective_bytes"] = int(tot.collective_bytes)
        rec["collectives"] = {
            k: {"count": int(v["count"]), "bytes": int(v["bytes"])}
            for k, v in tot.per_collective.items()}
        chips = rec["chips"]
        rec["roofline"] = {
            "compute_s": tot.flops / PEAK_FLOPS,
            "memory_s": tot.bytes_fused / HBM_BW,
            "collective_s": tot.collective_bytes / LINK_BW,
        }
        rec["memory_s_raw"] = tot.bytes_accessed / HBM_BW
        rec["dominant"] = max(rec["roofline"], key=rec["roofline"].get)
        total_hlo = tot.flops * chips
        rec["model_flops_ratio"] = (rec["model_flops"] / total_hlo
                                    if total_hlo else 0.0)
    with open(out_path, "w") as f:
        for rec in recs:
            f.write(json.dumps(rec) + "\n")
    print(f"reanalyzed {len(recs)} records")


def _cost_get(cost, key):
    try:
        return float(cost.get(key, 0.0))
    except Exception:
        return 0.0


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               extra_tag: str = "", step_override=None,
               overrides: dict = None) -> dict:
    """Lower + compile one cell; returns the JSONL record."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(mesh)
    rec = {"arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
           "kind": shape.kind, "tag": extra_tag}

    kind, args = step_specs(cfg, shape, mesh)
    donate = ()
    if step_override is not None:
        fn = step_override
    elif kind == "train":
        fn = make_train_step(cfg, default_optimizer())
        donate = (0, 1)          # params, opt_state
    elif kind == "prefill":
        fn = make_prefill(cfg, shape.seq_len)
    else:
        fn = make_serve_step(cfg)
        donate = (1,)            # kv cache / ssm state

    t0 = time.time()
    with use_rules(rules, mesh):
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)

    mem = compiled.memory_analysis()
    rec["bytes_per_device"] = {
        "argument": getattr(mem, "argument_size_in_bytes", 0),
        "output": getattr(mem, "output_size_in_bytes", 0),
        "temp": getattr(mem, "temp_size_in_bytes", 0),
        "alias": getattr(mem, "alias_size_in_bytes", 0),
        "peak": getattr(mem, "peak_memory_in_bytes", 0),
    }
    # live per-chip bytes: donated inputs alias outputs
    rec["bytes_per_device"]["total"] = (
        rec["bytes_per_device"]["argument"]
        + rec["bytes_per_device"]["temp"]
        - rec["bytes_per_device"]["alias"])

    # raw XLA cost analysis (counts while bodies ONCE — kept for
    # reference); the roofline uses the trip-count-aware HLO analyzer.
    cost = compiled.cost_analysis()
    rec["xla_flops_once"] = _cost_get(cost, "flops")
    rec["xla_bytes_once"] = _cost_get(cost, "bytes accessed")

    hlo = compiled.as_text()
    rec["hlo_path"] = _save_hlo(arch, shape_name, multi_pod, extra_tag,
                                hlo)
    tot = analyze_hlo(hlo)
    rec["hlo_flops"] = tot.flops
    rec["hlo_bytes_raw"] = tot.bytes_accessed   # every instruction
    rec["hlo_bytes"] = tot.bytes_fused          # TPU-fusion estimate
    rec["collective_bytes"] = int(tot.collective_bytes)
    rec["collectives"] = {
        k: {"count": int(v["count"]), "bytes": int(v["bytes"])}
        for k, v in tot.per_collective.items()}
    rec["hlo_chars"] = len(hlo)

    # roofline terms (per chip, seconds). The HLO analyzer totals are
    # per-device for SPMD modules; memory_s uses the fused-traffic
    # estimate (raw instruction traffic kept as memory_s_raw).
    chips = mesh.size
    rec["chips"] = chips
    rec["roofline"] = {
        "compute_s": rec["hlo_flops"] / PEAK_FLOPS,
        "memory_s": rec["hlo_bytes"] / HBM_BW,
        # per-chip collective bytes / per-chip link bandwidth (equals
        # the assignment's total/(chips*link_bw) formula)
        "collective_s": rec["collective_bytes"] / LINK_BW,
    }
    rec["memory_s_raw"] = rec["hlo_bytes_raw"] / HBM_BW
    terms = rec["roofline"]
    rec["dominant"] = max(terms, key=terms.get)

    # MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE); D = tokens
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        n = cfg.active_params()
        rec["model_flops"] = 6.0 * n * tokens
        total_hlo = rec["hlo_flops"] * chips
        rec["model_flops_ratio"] = (rec["model_flops"] / total_hlo
                                    if total_hlo else 0.0)
    else:
        tokens = (shape.global_batch * shape.seq_len
                  if shape.kind == "prefill" else shape.global_batch)
        rec["model_flops"] = 2.0 * cfg.active_params() * tokens
        total_hlo = rec["hlo_flops"] * chips
        rec["model_flops_ratio"] = (rec["model_flops"] / total_hlo
                                    if total_hlo else 0.0)
    return rec


def _load_done(path: str) -> set:
    done = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"],
                              r.get("tag", "")))
                except Exception:
                    pass
    return done


def run_cells(cells, out_path: str, force: bool = False,
              tag: str = "", overrides: dict = None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    done = set() if force else _load_done(out_path)
    for arch, shape_name, multi_pod in cells:
        key = (arch, shape_name, _mesh_tag(multi_pod), tag)
        if key in done:
            print(f"[skip-done] {key}")
            continue
        reason = skip_reason(arch, shape_name)
        rec = {"arch": arch, "shape": shape_name,
               "mesh": _mesh_tag(multi_pod), "tag": tag}
        if reason is not None:
            rec["status"] = "SKIP"
            rec["skip_reason"] = reason
            print(f"[SKIP] {key}: {reason}")
        else:
            print(f"[lower] {key} ...", flush=True)
            try:
                rec.update(lower_cell(arch, shape_name, multi_pod,
                                      extra_tag=tag,
                                      overrides=overrides))
                rec["status"] = "OK"
                r = rec["roofline"]
                print(f"  OK lower={rec['lower_s']}s "
                      f"compile={rec['compile_s']}s "
                      f"mem={rec['bytes_per_device']['total']/2**30:.2f}GiB "
                      f"comp={r['compute_s']*1e3:.2f}ms "
                      f"hbm={r['memory_s']*1e3:.2f}ms "
                      f"coll={r['collective_s']*1e3:.2f}ms "
                      f"dom={rec['dominant']}", flush=True)
            except Exception as e:
                rec["status"] = "FAIL"
                rec["error"] = f"{type(e).__name__}: {e}"
                rec["traceback"] = traceback.format_exc()[-2000:]
                print(f"  FAIL {rec['error']}", flush=True)
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")


def starling_cells(out_path: str, force: bool = False) -> None:
    """Dry-run the Starling segment search_step on the production mesh."""
    from repro.core.device_search import make_search_step
    for multi_pod in (False, True):
        mesh = make_production_mesh(multi_pod=multi_pod)
        rules = rules_for(mesh)
        key = ("starling-search", "segment", _mesh_tag(multi_pod), "")
        done = set() if force else _load_done(out_path)
        if key in done:
            print(f"[skip-done] {key}")
            continue
        rec = {"arch": "starling-search", "shape": "segment",
               "mesh": _mesh_tag(multi_pod), "tag": ""}
        try:
            fn, args = make_search_step(mesh, rules)
            t0 = time.time()
            with use_rules(rules, mesh):
                lowered = jax.jit(fn).lower(*args)
                compiled = lowered.compile()
            rec["lower_s"] = round(time.time() - t0, 1)
            mem = compiled.memory_analysis()
            rec["bytes_per_device"] = {
                "argument": getattr(mem, "argument_size_in_bytes", 0),
                "temp": getattr(mem, "temp_size_in_bytes", 0)}
            cost = compiled.cost_analysis()
            rec["hlo_flops"] = _cost_get(cost, "flops")
            rec["hlo_bytes"] = _cost_get(cost, "bytes accessed")
            cb, per = collective_bytes(compiled.as_text())
            rec["collective_bytes"] = cb
            rec["collectives"] = per
            rec["status"] = "OK"
            print(f"[starling] {key} OK coll={cb:,}B")
        except Exception as e:
            rec["status"] = "FAIL"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-2000:]
            print(f"[starling] FAIL {rec['error']}")
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--starling", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute roofline fields from stored HLO")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("True", "False"):
            v = v == "True"
        overrides[k] = v

    if args.reanalyze:
        reanalyze(args.out)
        return
    if args.starling:
        starling_cells(args.out, force=args.force)
        return

    pods = {"single": (False,), "multi": (True,),
            "both": (False, True)}[args.mesh]
    if args.all:
        cells = [(a, s, mp) for a in ARCH_IDS for s in SHAPES
                 for mp in pods]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, mp) for mp in pods]
    run_cells(cells, args.out, force=args.force, tag=args.tag,
              overrides=overrides)


if __name__ == "__main__":
    main()
