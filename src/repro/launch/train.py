"""Training step factory + end-to-end driver.

``make_train_step(cfg, opt)`` builds the jit-able
``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``
with microbatched gradient accumulation (``cfg.grad_accum``) — the
accumulation loop is a ``lax.scan`` so one microbatch of activations is
live at a time.

Run as a script for the real (reduced-scale) training driver with
checkpoint/restart:  python -m repro.launch.train --arch gemma3-1b --smoke
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.layers import P
from repro.optim import (AdamW, adamw_init, adamw_update, cosine_schedule)

Tree = Any


def _mixed_cast(cfg: ModelConfig, params: Tree) -> Tree:
    """fp32 master -> bf16 compute copy with the SAME sharding pinned on
    the bf16 tensors, so FSDP all-gathers and gradient all-reduces move
    bf16 (half the collective bytes of the f32 baseline)."""
    specs = lm.param_specs(cfg)

    def one(spec: P, p):
        if p.dtype != jnp.float32:
            return p
        return shard(p.astype(jnp.dtype(cfg.dtype)), *spec.axes)

    return jax.tree.map(one, specs, params,
                        is_leaf=lambda x: isinstance(x, P))


def make_train_step(cfg: ModelConfig, opt: AdamW):
    accum = max(cfg.grad_accum, 1)

    def grads_of(params, batch):
        def loss_of(p):
            if cfg.mixed_state:
                p = _mixed_cast(cfg, p)
            return lm.loss_fn(cfg, p, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(params)
        return loss, metrics, grads

    def train_step(params: Tree, opt_state: Dict,
                   batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[Tree, Dict, Dict]:
        if accum == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % accum == 0, (b, accum)
                return (x.reshape(accum, b // accum, *x.shape[1:])
                        if x.ndim > 0 else x)
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                tot_loss, tot_grads = carry
                loss, _, grads = grads_of(params, mb)
                return (tot_loss + loss,
                        jax.tree.map(jnp.add, tot_grads, grads)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grad_sum), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss_sum / accum
            grads = jax.tree.map(lambda g: g / accum, grad_sum)
            metrics = {}

        params, opt_state, opt_metrics = adamw_update(
            opt, grads, opt_state, params)
        out = {"loss": loss, **opt_metrics}
        out.update({k: v for k, v in metrics.items()})
        return params, opt_state, out

    return train_step


def default_optimizer(total_steps: int = 10_000) -> AdamW:
    return AdamW(lr=cosine_schedule(3e-4, warmup=100, total=total_steps))


# ----------------------------------------------------------------- driver

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.data.pipeline import TokenPipeline
    from repro.ft.checkpoint import CheckpointManager

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    opt = default_optimizer(args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))

    pipe = TokenPipeline(vocab=cfg.vocab_size, batch=args.batch,
                         seq=args.seq, seed=0)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        params, opt_state, pipe_state, start = ckpt.restore(
            params, opt_state)
        pipe.set_state(pipe_state)
        print(f"resumed from step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = pipe.next_batch(cfg)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time() - t0):.1f}s)")
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, params, opt_state, pipe.get_state())
    print("done")


if __name__ == "__main__":
    main()
