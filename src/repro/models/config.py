"""ModelConfig — one dataclass that spans the 10 assigned families.

Families:
  dense   — decoder-only transformer (stablelm-3b, minitron-8b, gemma3-1b,
            granite-20b; internvl2-1b backbone is dense too)
  moe     — dense attention + mixture-of-experts FFN (qwen3-moe, moonshot)
  vlm     — dense backbone; patch embeddings are prepended (frontend = stub)
  audio   — encoder–decoder (whisper); conv frontend = stub frame embeddings
  hybrid  — Mamba2 trunk + a *shared* attention block every k layers (zamba2)
  ssm     — attention-free RWKV6 (Finch) trunk

Every dimension knob used by any arch lives here; the per-arch files in
``repro/configs`` fill them in with the published numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads

    # --- attention flavour ---
    window: int = 0               # sliding-window size; 0 = global
    global_every: int = 0         # gemma3: every Nth layer is global (5:1)
    rope_theta: float = 10_000.0
    qk_norm: bool = False         # gemma3-style per-head RMS on q/k
    logit_softcap: float = 0.0    # final-logit soft capping
    tie_embeddings: bool = True

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0             # per-expert FFN width
    num_shared_experts: int = 0   # moonshot/deepseek-style shared expert
    router_aux_coef: float = 0.01
    moe_dispatch: str = "dense"   # dense | capacity (see layers.moe_block)
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # --- hybrid (zamba2) ---
    shared_attn_period: int = 0   # apply the shared attn block every k layers

    # --- RWKV6 ---
    rwkv_head_dim: int = 64
    rwkv_lora: int = 32           # ddlerp low-rank width
    rwkv_decay_lora: int = 64

    # --- encoder–decoder (whisper) ---
    encoder_layers: int = 0
    num_mem_tokens: int = 0       # encoder memory length (1500 audio frames)

    # --- VLM ---
    patch_tokens: int = 0         # prepended precomputed patch embeddings

    # --- numerics / training ---
    mixed_state: bool = False     # cast fp32 master -> sharded bf16 copy
    #                               inside train_step (bf16 collectives);
    #                               False = the recorded baseline
    scale_embed: bool = False     # gemma: multiply embeddings by sqrt(D)
    act: str = "silu"             # silu (SwiGLU) | gelu (GeGLU)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    grad_accum: int = 1           # microbatch count inside train_step
    scan_layers: bool = True

    def __post_init__(self):
        assert self.family in ("dense", "moe", "vlm", "audio", "hybrid",
                               "ssm")
        if self.family == "moe":
            assert self.num_experts > 0 and self.experts_per_token > 0
        if self.family == "hybrid":
            assert self.ssm_state > 0 and self.shared_attn_period > 0
        if self.family == "audio":
            assert self.encoder_layers > 0 and self.num_mem_tokens > 0
        if self.family == "vlm":
            assert self.patch_tokens > 0

    # ------------------------------------------------------- derived dims
    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a 512 multiple so the vocab dim shards
        over any TP degree up to 512 (Megatron-style); loss and decode
        mask the padded columns."""
        return -(-self.vocab_size // 512) * 512

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.hd

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer sliding-window size (0 = global attention).

        gemma3 pattern: ``global_every``−1 local layers then 1 global,
        repeating (5 local : 1 global), final layer global.
        """
        if self.window == 0:
            return tuple(0 for _ in range(self.num_layers))
        if self.global_every <= 0:
            return tuple(self.window for _ in range(self.num_layers))
        out = []
        for i in range(self.num_layers):
            is_global = (i + 1) % self.global_every == 0
            out.append(0 if is_global else self.window)
        return tuple(out)

    def num_params(self) -> int:
        """Analytic parameter count (used by MODEL_FLOPS = 6·N·D)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        n = v * d                                   # embedding
        if not self.tie_embeddings:
            n += v * d
        if self.family in ("dense", "moe", "vlm"):
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.family == "moe":
                mlp = 3 * d * self.moe_d_ff * self.num_experts
                mlp += 3 * d * self.moe_d_ff * self.num_shared_experts
                mlp += d * self.num_experts      # router
            else:
                mlp = 3 * d * ff
            n += self.num_layers * (attn + mlp + 2 * d)
        elif self.family == "audio":
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            mlp = 2 * d * ff                    # whisper MLP is non-gated
            n += self.encoder_layers * (attn + mlp + 2 * d)
            n += self.num_layers * (2 * attn + mlp + 3 * d)  # self+cross
        elif self.family == "hybrid":
            di, s, hh = self.d_inner, self.ssm_state, self.ssm_heads
            mamba = (d * (2 * di + 2 * s + hh)    # in_proj (z,x,B,C,dt)
                     + di * d + 3 * hh            # out_proj, A/D/dt_bias
                     + self.ssm_conv * (di + 2 * s))
            n += self.num_layers * (mamba + d)
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            n += attn + 3 * d * ff + 2 * d        # one shared block
        elif self.family == "ssm":
            lora, dl = self.rwkv_lora, self.rwkv_decay_lora
            tmix = (4 * d * d                     # r,k,v,out
                    + d * d                       # gate
                    + 5 * (d * lora + lora * d)   # ddlerp loras
                    + d * dl + dl * d             # decay lora
                    + 2 * d + 6 * d)              # u, w0, mus
            cmix = d * ff + ff * d + d * d + 2 * d
            n += self.num_layers * (tmix + cmix + 2 * d)
        return n

    def active_params(self) -> int:
        """Active parameter count per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.num_params()
        d = self.d_model
        dense_experts = self.experts_per_token + self.num_shared_experts
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        mlp = 3 * d * self.moe_d_ff * dense_experts + d * self.num_experts
        n = self.vocab_size * d + self.num_layers * (attn + mlp + 2 * d)
        if not self.tie_embeddings:
            n += self.vocab_size * d
        return n
