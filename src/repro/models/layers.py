"""Transformer primitives: RMSNorm, RoPE, GQA attention (sliding window,
QK-norm, KV cache), SwiGLU/GeGLU MLP, mixture-of-experts FFN.

All functions are pure: ``params`` dicts in, arrays out. Logical-axis
sharding annotations (``shard``) are no-ops outside a mesh context, so the
same code serves 1-device smoke tests and the 512-chip dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard


@dataclasses.dataclass(frozen=True)
class P:
    """Parameter spec: shape + logical axes + init style."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | custom key
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + gamma)).astype(dt)


# ------------------------------------------------------------------- RoPE

def rope_table(positions: jnp.ndarray, head_dim: int,
               theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions [*] -> (sin, cos) each [*, head_dim/2] float32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray,
               cos: jnp.ndarray) -> jnp.ndarray:
    """x [B, S, H, hd]; sin/cos [B?, S, hd/2] broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :].astype(x.dtype)
    cos = cos[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1)


# -------------------------------------------------------------- attention

# Blockwise (flash-style) attention kicks in above this many score
# elements per head — full S x S materialization is never compiled for
# the 4k-500k shapes. Chunk sizes are MXU-aligned.
_BLOCKWISE_THRESHOLD = 1 << 21
Q_CHUNK = 512
KV_CHUNK = 1024


def _attn_mask(q_pos, kv_pos, window, kv_len, causal):
    dist = q_pos[:, None] - kv_pos[None, :]            # [Sq, Sk]
    mask = dist >= 0 if causal else jnp.ones(dist.shape, bool)
    mask &= jnp.where(window > 0, dist < window, True)
    if kv_len is not None:
        mask &= kv_pos[None, :] < kv_len
    return mask


def _plain_attention(q, k, v, q_pos, kv_pos, kv_len, window, causal):
    b, sq, hkv, g, hd = q.shape
    scale = hd ** -0.5
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = _attn_mask(q_pos, kv_pos, window, kv_len, causal)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def _chunk_of(s: int, target: int) -> int:
    """Largest divisor of s that is <= target."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def _blockwise_attention(q, k, v, q_pos, kv_pos, kv_len, window, causal):
    """Online-softmax attention: scan over KV chunks inside a scan over Q
    chunks; live score tensor is [B, Hkv, G, Qc, KVc] only."""
    b, sq, hkv, g, hd = q.shape
    sk = k.shape[1]
    qc = _chunk_of(sq, Q_CHUNK)
    kc = _chunk_of(sk, KV_CHUNK)
    nq, nk = sq // qc, sk // kc
    scale = hd ** -0.5

    qb = jnp.moveaxis(q.reshape(b, nq, qc, hkv, g, hd), 1, 0)
    qp = q_pos.reshape(nq, qc)
    kb = jnp.moveaxis(k.reshape(b, nk, kc, hkv, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, kc, hkv, hd), 1, 0)
    kp = kv_pos.reshape(nk, kc)

    def q_body(_, q_in):
        qi, qpi = q_in

        @jax.checkpoint
        def kv_body(carry, kv_in):
            m, l, acc = carry
            ki, vi, kpi = kv_in
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            mask = _attn_mask(qpi, kpi, window, kv_len, causal)
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + p.sum(-1)
            acc_new = (acc * corr[..., None]
                       + jnp.einsum("bkgqs,bskd->bkgqd",
                                    p.astype(vi.dtype), vi))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (kb, vb, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, jnp.moveaxis(out, (1, 2), (2, 3))   # [B, qc, Hkv, G, hd]

    _, outs = jax.lax.scan(q_body, None, (qb, qp))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, hkv, g, hd)
    return out.astype(v.dtype)


def gqa_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  q_pos: jnp.ndarray, kv_pos: jnp.ndarray,
                  kv_len: Optional[jnp.ndarray], window: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """Grouped-query attention.

    q [B, Sq, H, hd]; k/v [B, Sk, Hkv, hd]; q_pos [Sq]; kv_pos [Sk];
    kv_len — number of valid cache entries (decode) or None (all valid);
    window — scalar int32: 0 = global, w = sliding window of size w.
    Softmax in f32. Dispatches to blockwise (flash-style) attention when
    the score tensor would be large. Returns [B, Sq, H, hd].
    """
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    q = q.reshape(b, sq, hkv, g, hd)
    if sq * k.shape[1] > _BLOCKWISE_THRESHOLD and sq >= 64:
        out = _blockwise_attention(q, k, v, q_pos, kv_pos, kv_len, window,
                                   causal)
    else:
        out = _plain_attention(q, k, v, q_pos, kv_pos, kv_len, window,
                               causal)
    return out.reshape(b, sq, h, hd)


def attention_block(params: Dict, x: jnp.ndarray, positions: jnp.ndarray,
                    cfg, window: jnp.ndarray,
                    cache: Optional[Dict] = None,
                    memory: Optional[jnp.ndarray] = None,
                    causal: bool = True) -> Tuple[jnp.ndarray,
                                                  Optional[Dict]]:
    """Full attention sub-block: norm -> qkv -> rope -> attn -> out-proj.

    ``cache`` (decode): {"k": [B, Smax, Hkv, hd], "v": ..., "len": scalar};
    new tokens are written at positions [len, len+Sq) and the updated cache
    is returned. ``memory`` (cross-attention): K/V come from memory and
    RoPE is skipped.
    """
    b, sq, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    q = shard(jnp.einsum("bsd,dhe->bshe", xn, params["wq"]),
              "batch", None, "heads", None)
    src = xn if memory is None else memory.astype(xn.dtype)
    k = jnp.einsum("bsd,dhe->bshe", src, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", src, params["wv"])

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)

    if memory is None:
        sin_q, cos_q = rope_table(positions, hd, cfg.rope_theta)
        q = apply_rope(q, sin_q, cos_q)
        k = apply_rope(k, sin_q, cos_q)

    kv_len = None
    if cache is not None and memory is None:
        start = cache["len"]
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, start, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, start, 0, 0))
        cache = {"k": ck, "v": cv, "len": start + sq}
        k, v = ck.astype(q.dtype), cv.astype(q.dtype)
        kv_pos = jnp.arange(cache["k"].shape[1])
        kv_len = cache["len"]
    else:
        kv_pos = (positions if memory is None
                  else jnp.arange(memory.shape[1]))

    out = gqa_attention(q, k, v, positions, kv_pos, kv_len, window,
                        causal=causal and memory is None)
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return shard(out, "batch", None, "embed"), cache


# -------------------------------------------------------------------- MLP

def mlp_block(params: Dict, x: jnp.ndarray, cfg,
              gated: bool = True) -> jnp.ndarray:
    """Gated (SwiGLU/GeGLU) or plain two-matrix FFN, pre-norm."""
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    up = jnp.einsum("bsd,df->bsf", xn, params["w_up"])
    if gated:
        gate = jnp.einsum("bsd,df->bsf", xn, params["w_gate"])
        hidden = act(gate) * up
    else:
        hidden = act(up)
    hidden = shard(hidden, "batch", None, "ff")
    out = jnp.einsum("bsf,fd->bsd", hidden, params["w_down"])
    return shard(out, "batch", None, "embed")


# -------------------------------------------------------------------- MoE

def _dense_dispatch(params: Dict, xn: jnp.ndarray, combine: jnp.ndarray,
                    cfg, act) -> jnp.ndarray:
    """Every expert on every token, masked by combine [B, S, E].

    The combine weights fold into the hidden BEFORE the down projection:
    out = sum_e c_e (h_e @ Wd_e) = sum_e (c_e h_e) @ Wd_e — the
    contraction runs over (e, f) jointly and the only EP collective is
    one all-reduce of [B, S, D] partial sums; no [B, S, E, D] expert
    output is ever materialized."""
    gate = jnp.einsum("bsd,edf->bsef", xn, params["w_gate"])
    up = jnp.einsum("bsd,edf->bsef", xn, params["w_up"])
    hidden = shard(act(gate) * up, "batch", None, "experts", None)
    hidden = hidden * combine[..., None]
    return jnp.einsum("bsef,efd->bsd", hidden, params["w_down"])


def _capacity_dispatch(params: Dict, xn: jnp.ndarray,
                       combine: jnp.ndarray, cfg, act) -> jnp.ndarray:
    """Capacity-based gather dispatch (GShard/Switch-style, dropping).

    Per sequence, each expert takes its top-C tokens
    (C = S*k*cf/E), gathered with batch-dim-preserving indexing so
    every op stays sharded over ``data`` (no token transport across
    chips — activations are replicated over ``model``). Compute is
    E*C = k*cf*S instead of dense dispatch's E_local*S per chip: a
    E/(k*cf*TP... ) ~ 13x FLOP cut for qwen3 at cf=1.25."""
    b, s, d = xn.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = min(s, int(np.ceil(s * k * cfg.moe_capacity_factor / e)))
    cap = max(cap, 1)
    # top-C tokens per (batch row, expert) by combine weight
    w_te = jnp.swapaxes(combine, 1, 2)                  # [B, E, S]
    top_w, top_s = jax.lax.top_k(w_te, cap)             # [B, E, C]
    top_w = shard(top_w, "batch", "experts", None)
    top_s = shard(top_s, "batch", "experts", None)
    # pin xn replicated over `model` so the expert-sharded gather is
    # chip-local (GSPMD otherwise re-shards and all-gathers activations)
    xn = shard(xn, "batch", None, None)
    rows = jnp.arange(b)[:, None, None]
    xg = xn[rows, top_s]                                # [B, E, C, D]
    xg = shard(xg, "batch", "experts", None, None)
    gate = jnp.einsum("becd,edf->becf", xg, params["w_gate"])
    up = jnp.einsum("becd,edf->becf", xg, params["w_up"])
    hidden = shard(act(gate) * up, "batch", "experts", None, None)
    hidden = hidden * top_w[..., None].astype(hidden.dtype)
    part = jnp.einsum("becf,efd->becd", hidden, params["w_down"])
    out = jnp.zeros((b, s, d), part.dtype).at[rows, top_s].add(part)
    return shard(out, "batch", None, "embed")


def _capacity_dispatch_ep(params: Dict, xn: jnp.ndarray,
                          combine: jnp.ndarray, cfg, act,
                          rules, mesh) -> jnp.ndarray:
    """shard_map expert parallelism: every rank runs the capacity
    dispatch for ITS experts on ITS (replicated-over-model) local batch;
    weights arrive FSDP-sharded and are all-gathered explicitly; the only
    other collective is the psum of [B, S, D] partial outputs over
    ``model``. Deterministic transport — GSPMD cannot re-shard the
    gather/scatter (which it otherwise does, all-gathering activations
    per layer; see EXPERIMENTS §Perf iteration 3)."""
    from jax import shard_map
    from repro.distributed.sharding import logical_spec

    b, s, d = xn.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = max(min(s, int(np.ceil(s * k * cfg.moe_capacity_factor / e))),
              1)
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]

    def spec(shape, axes):
        return logical_spec(shape, axes, rules, mesh)

    def local_fn(xn_l, comb_l, wg_l, wu_l, wd_l):
        # FSDP gather of this rank's expert weights (w_gate/w_up shard
        # d_model; w_down shards d_model on its output dim)
        wg_f = jax.lax.all_gather(wg_l, data_axes, axis=1, tiled=True)
        wu_f = jax.lax.all_gather(wu_l, data_axes, axis=1, tiled=True)
        wd_f = jax.lax.all_gather(wd_l, data_axes, axis=2, tiled=True)
        bl, sl, dl = xn_l.shape
        w_te = jnp.swapaxes(comb_l, 1, 2)             # [Bl, El, S]
        top_w, top_s = jax.lax.top_k(w_te, cap)       # [Bl, El, C]
        rows = jnp.arange(bl)[:, None, None]
        xg = xn_l[rows, top_s]                        # [Bl, El, C, D]
        gate = jnp.einsum("becd,edf->becf", xg, wg_f)
        up = jnp.einsum("becd,edf->becf", xg, wu_f)
        hidden = act(gate) * up
        hidden = hidden * top_w[..., None].astype(hidden.dtype)
        part = jnp.einsum("becf,efd->becd", hidden, wd_f)
        out = jnp.zeros((bl, sl, dl), part.dtype).at[rows, top_s].add(
            part)
        return jax.lax.psum(out, "model")

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(spec(xn.shape, ("batch", None, None)),
                  spec(combine.shape, ("batch", None, "experts")),
                  spec(wg.shape, ("experts", "fsdp", None)),
                  spec(wu.shape, ("experts", "fsdp", None)),
                  spec(wd.shape, ("experts", None, "fsdp"))),
        out_specs=spec(xn.shape, ("batch", None, None)),
        check_vma=False)
    return fn(xn, combine, wg, wu, wd)


def moe_block(params: Dict, x: jnp.ndarray, cfg
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k routed mixture of experts; experts sharded over ``model``
    (EP). Dispatch: ``cfg.moe_dispatch`` = "dense" (paper-agnostic TPU
    baseline: all experts on all tokens) or "capacity" (gather top-C
    tokens per expert; 'beyond' optimization, see EXPERIMENTS §Perf).
    Returns (out, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    logits = jnp.einsum("bsd,de->bse", xn.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # combine weights as a dense [B, S, E] tensor (0 for unrouted experts)
    combine = jnp.zeros((b, s, e), jnp.float32).at[
        jnp.arange(b)[:, None, None],
        jnp.arange(s)[None, :, None], top_i].set(top_p)
    combine = shard(combine.astype(x.dtype), "batch", None, "experts")

    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    if cfg.moe_dispatch == "capacity":
        from repro.distributed.sharding import current_rules
        rules, mesh = current_rules()
        if (mesh is not None and "model" in mesh.axis_names
                and e % mesh.shape["model"] == 0):
            out = _capacity_dispatch_ep(params, xn, combine, cfg, act,
                                        rules, mesh)
        else:
            out = _capacity_dispatch(params, xn, combine, cfg, act)
    else:
        out = _dense_dispatch(params, xn, combine, cfg, act)

    if cfg.num_shared_experts:
        sh_gate = jnp.einsum("bsd,df->bsf", xn, params["shared_w_gate"])
        sh_up = jnp.einsum("bsd,df->bsf", xn, params["shared_w_up"])
        out = out + jnp.einsum("bsf,fd->bsd", act(sh_gate) * sh_up,
                               params["shared_w_down"])

    # load-balancing aux loss (Switch-style): E * sum_e f_e * P_e
    frac_routed = jnp.mean(combine > 0, axis=(0, 1)).astype(jnp.float32)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_routed * mean_prob)
    return shard(out, "batch", None, "embed"), aux


def dense_layer(params: Dict, x: jnp.ndarray, positions: jnp.ndarray,
                cfg, window: jnp.ndarray, cache: Optional[Dict] = None,
                causal: bool = True) -> Tuple[jnp.ndarray, Optional[Dict],
                                              jnp.ndarray]:
    """One decoder layer: attention + FFN (residual, pre-norm).
    Returns (x, cache, aux_loss)."""
    a, cache = attention_block(params["attn"], x, positions, cfg, window,
                               cache=cache, causal=causal)
    x = x + a
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe" and "moe" in params:
        m, aux = moe_block(params["moe"], x, cfg)
    else:
        m = mlp_block(params["mlp"], x, cfg)
    return x + m, cache, aux
