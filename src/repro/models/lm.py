"""Model assembly: parameter specs/init, train forward + loss, prefill and
decode for every assigned architecture family.

Layer stacks are ``lax.scan``-based (stacked per-layer params, one traced
body) so the 94-layer MoE compiles as fast as the 6-layer whisper. Decode
threads per-layer KV caches / SSM states through the same scans.

Param trees are nested dicts of ``layers.P`` specs; ``init_params``
materializes them (smoke tests only — the full configs are lowered from
ShapeDtypeStructs and never allocated).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard
from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.layers import (P, attention_block, dense_layer, mlp_block,
                                 rms_norm)

Tree = Dict[str, Any]


# =====================================================================
# Parameter specs
# =====================================================================

def _attn_specs(cfg: ModelConfig) -> Tree:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    s = {"ln": P((d,), (None,), init="zeros"),
         "wq": P((d, h, hd), ("fsdp", "heads", None), scale=d ** -0.5),
         "wk": P((d, hkv, hd), ("fsdp", "kv_heads", None), scale=d ** -0.5),
         "wv": P((d, hkv, hd), ("fsdp", "kv_heads", None), scale=d ** -0.5),
         "wo": P((h, hd, d), ("heads", None, "fsdp"),
                 scale=(h * hd) ** -0.5)}
    if cfg.qk_norm:
        s["q_norm"] = P((hd,), (None,), init="zeros")
        s["k_norm"] = P((hd,), (None,), init="zeros")
    return s


def _mlp_specs(cfg: ModelConfig, gated: bool = True) -> Tree:
    d, f = cfg.d_model, cfg.d_ff
    s = {"ln": P((d,), (None,), init="zeros"),
         "w_up": P((d, f), ("fsdp", "ff"), scale=d ** -0.5),
         "w_down": P((f, d), ("ff", "fsdp"), scale=f ** -0.5)}
    if gated:
        s["w_gate"] = P((d, f), ("fsdp", "ff"), scale=d ** -0.5)
    return s


def _moe_specs(cfg: ModelConfig) -> Tree:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    s = {"ln": P((d,), (None,), init="zeros"),
         "router": P((d, e), (None, "experts"), scale=d ** -0.5),
         "w_gate": P((e, d, f), ("experts", "fsdp", None), scale=d ** -0.5),
         "w_up": P((e, d, f), ("experts", "fsdp", None), scale=d ** -0.5),
         "w_down": P((e, f, d), ("experts", None, "fsdp"),
                     scale=f ** -0.5)}
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        s["shared_w_gate"] = P((d, fs), ("fsdp", "ff"), scale=d ** -0.5)
        s["shared_w_up"] = P((d, fs), ("fsdp", "ff"), scale=d ** -0.5)
        s["shared_w_down"] = P((fs, d), ("ff", "fsdp"), scale=fs ** -0.5)
    return s


def _mamba_specs(cfg: ModelConfig) -> Tree:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj = 2 * di + 2 * n + h
    conv_c = di + 2 * n
    return {"ln": P((d,), (None,), init="zeros"),
            "in_proj": P((d, proj), ("fsdp", "inner"), scale=d ** -0.5),
            "conv_w": P((cfg.ssm_conv, conv_c), (None, "inner"),
                        scale=cfg.ssm_conv ** -0.5),
            "dt_bias": P((h,), (None,), init="ones", scale=0.01),
            "a_log": P((h,), (None,), init="ones", scale=0.5),
            "d_skip": P((h,), (None,), init="ones"),
            "gate_ln": P((di,), (None,), init="zeros"),
            "out_proj": P((di, d), ("inner", "fsdp"), scale=di ** -0.5)}


def _rwkv_specs(cfg: ModelConfig) -> Tree:
    d, f = cfg.d_model, cfg.d_ff
    lo, dl = cfg.rwkv_lora, cfg.rwkv_decay_lora
    tm = {"ln": P((d,), (None,), init="zeros"),
          "mu_base": P((d,), (None,), scale=0.5),
          "mu": P((5, d), (None, None), scale=0.5),
          "mix_wa": P((d, 5, lo), (None, None, None), scale=d ** -0.5),
          "mix_wb": P((5, lo, d), (None, None, None), scale=lo ** -0.5),
          "decay_wa": P((d, dl), (None, None), scale=d ** -0.5),
          "decay_wb": P((dl, d), (None, None), scale=dl ** -0.5),
          "w0": P((d,), (None,), init="ones", scale=0.5),
          "u": P((d,), (None,), scale=0.5),
          "wr": P((d, d), ("fsdp", "inner"), scale=d ** -0.5),
          "wk": P((d, d), ("fsdp", "inner"), scale=d ** -0.5),
          "wv": P((d, d), ("fsdp", "inner"), scale=d ** -0.5),
          "wg": P((d, d), ("fsdp", "inner"), scale=d ** -0.5),
          "gn_g": P((d,), (None,), init="zeros"),
          "gn_b": P((d,), (None,), init="zeros"),
          "wo": P((d, d), ("inner", "fsdp"), scale=d ** -0.5)}
    cm = {"ln": P((d,), (None,), init="zeros"),
          "mu_k": P((d,), (None,), scale=0.5),
          "mu_r": P((d,), (None,), scale=0.5),
          "wk": P((d, f), ("fsdp", "ff"), scale=d ** -0.5),
          "wv": P((f, d), ("ff", "fsdp"), scale=f ** -0.5),
          "wr": P((d, d), ("fsdp", "inner"), scale=d ** -0.5)}
    return {"tm": tm, "cm": cm}


def _stack(tree: Tree, n: int) -> Tree:
    """Prepend a stacked ``layers`` axis of length n to every spec."""
    def one(p: P) -> P:
        return P((n,) + p.shape, (None,) + p.axes, init=p.init,
                 scale=p.scale, dtype=p.dtype)
    return jax.tree.map(one, tree, is_leaf=lambda x: isinstance(x, P))


def param_specs(cfg: ModelConfig) -> Tree:
    d, v = cfg.d_model, cfg.padded_vocab
    specs: Tree = {
        "embed": P((v, d), ("vocab", "fsdp"), scale=0.02),
        "final_ln": P((d,), (None,), init="zeros"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P((d, v), ("fsdp", "vocab"), scale=d ** -0.5)

    if cfg.family in ("dense", "vlm"):
        layer = {"attn": _attn_specs(cfg), "mlp": _mlp_specs(cfg)}
        specs["layers"] = _stack(layer, cfg.num_layers)
    elif cfg.family == "moe":
        layer = {"attn": _attn_specs(cfg), "moe": _moe_specs(cfg)}
        specs["layers"] = _stack(layer, cfg.num_layers)
    elif cfg.family == "ssm":
        specs["layers"] = _stack(_rwkv_specs(cfg), cfg.num_layers)
    elif cfg.family == "hybrid":
        g, tail = _hybrid_groups(cfg)
        specs["groups"] = _stack(_stack(_mamba_specs(cfg),
                                        cfg.shared_attn_period), g)
        if tail:
            specs["tail"] = _stack(_mamba_specs(cfg), tail)
        specs["shared_attn"] = {"attn": _attn_specs(cfg),
                                "mlp": _mlp_specs(cfg)}
    elif cfg.family == "audio":
        enc = {"attn": _attn_specs(cfg), "mlp": _mlp_specs(cfg, gated=False)}
        dec = {"attn": _attn_specs(cfg), "cross": _attn_specs(cfg),
               "mlp": _mlp_specs(cfg, gated=False)}
        specs["enc_layers"] = _stack(enc, cfg.encoder_layers)
        specs["enc_final_ln"] = P((d,), (None,), init="zeros")
        specs["layers"] = _stack(dec, cfg.num_layers)
    if cfg.family == "vlm":
        specs["patch_proj"] = P((d, d), ("fsdp", None), scale=d ** -0.5)
    return specs


def _hybrid_groups(cfg: ModelConfig) -> Tuple[int, int]:
    """(num_groups, tail_layers): groups of ``shared_attn_period`` mamba
    layers each followed by the shared attention block; remainder = tail."""
    g = cfg.num_layers // cfg.shared_attn_period
    return g, cfg.num_layers - g * cfg.shared_attn_period


def init_params(cfg: ModelConfig, key: jax.Array) -> Tree:
    """Materialize parameters (reduced/smoke configs only)."""
    specs = param_specs(cfg)
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(cfg.param_dtype)

    def one(p: P, k):
        if p.init == "zeros":
            return jnp.zeros(p.shape, dtype)
        if p.init == "ones":
            return jnp.full(p.shape, p.scale, dtype)
        return jax.random.normal(k, p.shape, dtype) * p.scale

    return jax.tree.unflatten(treedef, [one(p, k) for p, k
                                        in zip(leaves, keys)])


# =====================================================================
# Forward (training / prefill / decode share the layer bodies)
# =====================================================================

def _cast_params(cfg: ModelConfig, params: Tree) -> Tree:
    """Master weights are fp32; compute runs in cfg.dtype. Norm scales and
    SSM decay/dt parameters are explicitly upcast at their use sites."""
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda p: p.astype(dt) if p.dtype == jnp.float32 else p, params)


def _embed(cfg: ModelConfig, params: Tree, tokens: jnp.ndarray
           ) -> jnp.ndarray:
    x = params["embed"].astype(jnp.dtype(cfg.dtype))[tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return shard(x, "batch", None, "embed")


def _vocab_mask(cfg: ModelConfig, logits: jnp.ndarray) -> jnp.ndarray:
    """Neutralize padded vocab columns (they carry random init rows)."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    return jnp.where(cols < cfg.vocab_size, logits,
                     jnp.asarray(-1e30, logits.dtype))


def _unembed(cfg: ModelConfig, params: Tree, x: jnp.ndarray) -> jnp.ndarray:
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return shard(_vocab_mask(cfg, logits), "batch", None, "vocab")


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _transformer_trunk(cfg: ModelConfig, params: Tree, x: jnp.ndarray,
                       positions: jnp.ndarray,
                       cache: Optional[Tree] = None
                       ) -> Tuple[jnp.ndarray, Optional[Tree], jnp.ndarray]:
    """Scan over dense/moe/vlm decoder layers. cache: {"k": [L,B,S,Hkv,hd],
    "v": ..., "len": scalar} or None."""
    windows = jnp.asarray(cfg.layer_windows(), jnp.int32)

    def body(carry, xs):
        h, aux = carry
        if cache is None:
            lp, w = xs
            h2, _, a = dense_layer(lp, h, positions, cfg, w, cache=None)
            return (h2, aux + a), None
        lp, w, ck, cv = xs
        layer_cache = {"k": ck, "v": cv, "len": cache["len"]}
        h2, nc, a = dense_layer(lp, h, positions, cfg, w,
                                cache=layer_cache)
        return (h2, aux + a), (nc["k"], nc["v"])

    aux0 = jnp.zeros((), jnp.float32)
    if cache is None:
        (x, aux), _ = jax.lax.scan(_maybe_remat(body, cfg), (x, aux0),
                                   (params["layers"], windows))
        return x, None, aux
    (x, aux), (nk, nv) = jax.lax.scan(body, (x, aux0),
                                      (params["layers"], windows,
                                       cache["k"], cache["v"]))
    new_cache = {"k": nk, "v": nv, "len": cache["len"] + x.shape[1]}
    return x, new_cache, aux


def _rwkv_trunk(cfg, params, x, cache):
    def body(carry, xs):
        if cache is None:
            h, _ = ssm.rwkv_layer(xs, carry, cfg, None)
            return h, None
        lp, st = xs
        h, ns = ssm.rwkv_layer(lp, carry, cfg, st)
        return h, ns

    if cache is None:
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
        return x, None, jnp.zeros((), jnp.float32)
    x, new_states = jax.lax.scan(body, x, (params["layers"], cache))
    return x, new_states, jnp.zeros((), jnp.float32)


def _hybrid_trunk(cfg, params, x, positions, cache):
    """Zamba2: groups of mamba layers, shared attn block between groups.

    cache: {"mamba_g": [G, period, ...] states, "mamba_t": [T, ...],
            "attn_k"/"attn_v": [G, B, S, Hkv, hd], "len": scalar}."""
    window = jnp.zeros((), jnp.int32)        # shared block: global attn
    g, tail = _hybrid_groups(cfg)

    def mamba_scan(h, lp_stack, st_stack):
        def body(carry, xs):
            if st_stack is None:
                h2, _ = ssm.mamba_mix(xs, carry, cfg, None)
                return carry + h2, None
            lp, st = xs
            h2, ns = ssm.mamba_mix(lp, carry, cfg, st)
            return carry + h2, ns
        if st_stack is None:
            h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, lp_stack)
            return h, None
        return jax.lax.scan(body, h, (lp_stack, st_stack))

    def group_body(carry, xs):
        h = carry
        if cache is None:
            gp = xs
            h, _ = mamba_scan(h, gp, None)
            a, _ = attention_block(params["shared_attn"]["attn"], h,
                                   positions, cfg, window)
            h = h + a
            h = h + mlp_block(params["shared_attn"]["mlp"], h, cfg)
            return h, None
        gp, st, ck, cv = xs
        h, ns = mamba_scan(h, gp, st)
        lc = {"k": ck, "v": cv, "len": cache["len"]}
        a, nc = attention_block(params["shared_attn"]["attn"], h,
                                positions, cfg, window, cache=lc)
        h = h + a
        h = h + mlp_block(params["shared_attn"]["mlp"], h, cfg)
        return h, (ns, nc["k"], nc["v"])

    if cache is None:
        x, _ = jax.lax.scan(group_body, x, params["groups"])
        if tail:
            x, _ = mamba_scan(x, params["tail"], None)
        return x, None, jnp.zeros((), jnp.float32)

    x, (n_mg, nk, nv) = jax.lax.scan(
        group_body, x,
        (params["groups"], cache["mamba_g"], cache["attn_k"],
         cache["attn_v"]))
    n_mt = None
    if tail:
        x, n_mt = mamba_scan(x, params["tail"], cache["mamba_t"])
    new_cache = {"mamba_g": n_mg, "mamba_t": n_mt, "attn_k": nk,
                 "attn_v": nv, "len": cache["len"] + x.shape[1]}
    return x, new_cache, jnp.zeros((), jnp.float32)


def _encoder(cfg, params, frames):
    """Whisper encoder over stub frame embeddings [B, T, D] (bidir attn)."""
    pos = jnp.arange(frames.shape[1])
    window = jnp.zeros((), jnp.int32)
    x = shard(frames.astype(jnp.dtype(cfg.dtype)), "batch", None, "embed")

    def body(h, lp):
        a, _ = attention_block(lp["attn"], h, pos, cfg, window,
                               causal=False)
        h = h + a
        h = h + mlp_block(lp["mlp"], h, cfg, gated=False)
        return h, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_layers"])
    return rms_norm(x, params["enc_final_ln"], cfg.norm_eps)


def _encdec_trunk(cfg, params, x, positions, memory, cache):
    """Whisper decoder: self-attn (cached) + cross-attn + plain MLP."""
    window = jnp.zeros((), jnp.int32)

    def body(carry, xs):
        h = carry
        if cache is None:
            lp = xs
            a, _ = attention_block(lp["attn"], h, positions, cfg, window)
            h = h + a
            c, _ = attention_block(lp["cross"], h, positions, cfg, window,
                                   memory=memory)
            h = h + c
            h = h + mlp_block(lp["mlp"], h, cfg, gated=False)
            return h, None
        lp, ck, cv = xs
        lc = {"k": ck, "v": cv, "len": cache["len"]}
        a, nc = attention_block(lp["attn"], h, positions, cfg, window,
                                cache=lc)
        h = h + a
        c, _ = attention_block(lp["cross"], h, positions, cfg, window,
                               memory=memory)
        h = h + c
        h = h + mlp_block(lp["mlp"], h, cfg, gated=False)
        return h, (nc["k"], nc["v"])

    if cache is None:
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
        return x, None, jnp.zeros((), jnp.float32)
    x, (nk, nv) = jax.lax.scan(body, x,
                               (params["layers"], cache["k"], cache["v"]))
    new_cache = {"k": nk, "v": nv, "len": cache["len"] + x.shape[1],
                 "memory": cache["memory"]}
    return x, new_cache, jnp.zeros((), jnp.float32)


def _forward_hidden(cfg: ModelConfig, params: Tree, tokens: jnp.ndarray,
                    patch_embeds: Optional[jnp.ndarray] = None,
                    frames: Optional[jnp.ndarray] = None,
                    cache: Optional[Tree] = None,
                    positions: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, Optional[Tree], jnp.ndarray]:
    """Trunk output before final norm/unembed (VLM patch rows dropped)."""
    params = _cast_params(cfg, params)
    x = _embed(cfg, params, tokens)
    if cfg.family == "vlm" and patch_embeds is not None:
        pe = jnp.einsum("bpd,de->bpe",
                        patch_embeds.astype(x.dtype), params["patch_proj"])
        x = jnp.concatenate([shard(pe, "batch", None, "embed"), x], axis=1)
    if positions is None:
        start = cache.get("len", 0) if cache is not None else 0
        positions = start + jnp.arange(x.shape[1])

    if cfg.family in ("dense", "moe", "vlm"):
        x, cache, aux = _transformer_trunk(cfg, params, x, positions, cache)
    elif cfg.family == "ssm":
        x, cache, aux = _rwkv_trunk(cfg, params, x, cache)
    elif cfg.family == "hybrid":
        x, cache, aux = _hybrid_trunk(cfg, params, x, positions, cache)
    elif cfg.family == "audio":
        memory = (cache["memory"] if cache is not None
                  else _encoder(cfg, params, frames))
        x, cache, aux = _encdec_trunk(cfg, params, x, positions, memory,
                                      cache)
    else:
        raise ValueError(cfg.family)

    if cfg.family == "vlm" and patch_embeds is not None:
        x = x[:, patch_embeds.shape[1]:]
    return x, cache, aux


def forward(cfg: ModelConfig, params: Tree, tokens: jnp.ndarray,
            patch_embeds: Optional[jnp.ndarray] = None,
            frames: Optional[jnp.ndarray] = None,
            cache: Optional[Tree] = None,
            positions: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, Optional[Tree], jnp.ndarray]:
    """Token logits for any family. Returns (logits, cache', aux_loss)."""
    x, cache, aux = _forward_hidden(cfg, params, tokens,
                                    patch_embeds=patch_embeds,
                                    frames=frames, cache=cache,
                                    positions=positions)
    return _unembed(cfg, params, x), cache, aux


def _ce_chunks(seq_len: int, vocab: int) -> int:
    """Sequence-chunked CE: keep live logits ~<= 2^24 elements per call."""
    if vocab < 16384:
        return 1
    target = max(1, (seq_len * vocab) // (1 << 24))
    nc = 1
    while nc < target and seq_len % (nc * 2) == 0:
        nc *= 2
    return nc


def _chunked_ce(cfg: ModelConfig, params: Tree, x: jnp.ndarray,
                labels: jnp.ndarray) -> jnp.ndarray:
    """Next-token CE without materializing full [B, S, V] logits: the
    unembed + logsumexp is computed per sequence chunk under remat, so the
    live working set is [B, S/nc, V]."""
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    w = w.astype(x.dtype)
    b, s, d = x.shape
    nc = _ce_chunks(s, w.shape[1])

    def chunk_ce(xc, lc):
        logits = jnp.einsum("bsd,dv->bsv", xc, w)
        if cfg.logit_softcap > 0:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        logits = _vocab_mask(cfg, logits)
        logits = shard(logits, "batch", None, "vocab").astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    if nc == 1:
        tot, cnt = chunk_ce(x, labels)
    else:
        xc = x.reshape(b, nc, s // nc, d).swapaxes(0, 1)
        lc = labels.reshape(b, nc, s // nc).swapaxes(0, 1)
        (tot, cnt), _ = jax.lax.scan(
            lambda c, args: ((c[0] + jax.checkpoint(chunk_ce)(*args)[0],
                              c[1] + (args[1] >= 0).sum().astype(
                                  jnp.float32)), None),
            (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ModelConfig, params: Tree, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Mean next-token cross-entropy (+ MoE aux)."""
    x, _, aux = _forward_hidden(
        cfg, params, batch["tokens"],
        patch_embeds=batch.get("patch_embeds"),
        frames=batch.get("frames"))
    ce = _chunked_ce(cfg, params, x, batch["labels"])
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux}


# =====================================================================
# Serving: cache init / prefill / decode
# =====================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Tree:
    zero = jnp.zeros((), jnp.int32)
    if cfg.family in ("dense", "moe", "vlm"):
        kv = jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads,
                        cfg.hd), dtype)
        return {"k": kv, "v": kv, "len": zero}
    if cfg.family == "ssm":
        st = ssm.init_rwkv_state(cfg, batch, dtype)
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l, (cfg.num_layers,) + l.shape), st)
    if cfg.family == "hybrid":
        g, tail = _hybrid_groups(cfg)
        mst = ssm.init_mamba_state(cfg, batch, dtype)
        per = cfg.shared_attn_period
        kv = jnp.zeros((g, batch, max_len, cfg.num_kv_heads, cfg.hd), dtype)
        return {"mamba_g": jax.tree.map(
                    lambda l: jnp.broadcast_to(l, (g, per) + l.shape), mst),
                "mamba_t": (jax.tree.map(
                    lambda l: jnp.broadcast_to(l, (tail,) + l.shape), mst)
                    if tail else None),
                "attn_k": kv, "attn_v": kv, "len": zero}
    if cfg.family == "audio":
        kv = jnp.zeros((cfg.num_layers, batch, max_len, cfg.num_kv_heads,
                        cfg.hd), dtype)
        mem = jnp.zeros((batch, cfg.num_mem_tokens, cfg.d_model), dtype)
        return {"k": kv, "v": kv, "len": zero, "memory": mem}
    raise ValueError(cfg.family)


def prefill(cfg: ModelConfig, params: Tree, tokens: jnp.ndarray,
            max_len: int, patch_embeds=None, frames=None,
            cache_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Tree]:
    cache = init_cache(cfg, tokens.shape[0], max_len, cache_dtype)
    if cfg.family == "audio":
        cache["memory"] = _encoder(cfg, _cast_params(cfg, params),
                                   frames).astype(cache_dtype)
    logits, cache, _ = forward(cfg, params, tokens,
                               patch_embeds=patch_embeds, cache=cache)
    return logits, cache


def decode_step(cfg: ModelConfig, params: Tree, cache: Tree,
                tokens: jnp.ndarray) -> Tuple[jnp.ndarray, Tree]:
    """One decode step: tokens [B, 1] -> (logits [B, 1, V], cache')."""
    logits, cache, _ = forward(cfg, params, tokens, cache=cache)
    return logits, cache
