"""State-space / linear-recurrence trunks: Mamba2 (SSD) and RWKV6 (Finch).

Both are implemented in *chunked* form — intra-chunk work is MXU-friendly
matmuls; the inter-chunk carry is a short ``lax.scan`` — plus O(1)-state
recurrent ``*_decode_step`` functions used by serving. The chunked and
recurrent paths are validated against each other in tests.

Numerics notes (model definition, applied consistently in both paths):
  * Mamba2 per-head decay alpha_t = exp(A * dt_t), A = -exp(A_log) < 0;
    pairwise intra-chunk exponents are <= 0, so the factored matmul form
    is safe in f32.
  * RWKV6 per-channel log-decay is clamped to >= -4 so the factored
    chunk form (exp(+cumsum) up to chunk length 16·4 = 64 < log(f32max))
    cannot overflow. Decay this fast (w < 0.018) is saturated anyway.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.layers import rms_norm

RWKV_CHUNK = 16
RWKV_LOGW_MIN = -4.0


def _scan_chunks(body, carry, xs, num_chunks: int):
    """scan with sqrt-checkpointing: when the chunk count is large, group
    chunks into sqrt(nc)-sized super-chunks and remat each group, so
    backward keeps O(sqrt(nc)) states instead of O(nc) (the inter-chunk
    state carry is large: [B, H, K, V])."""
    body = jax.checkpoint(body)
    if num_chunks <= 32:
        return jax.lax.scan(body, carry, xs)
    inner = 1
    while inner * inner < num_chunks:
        inner *= 2
    if num_chunks % inner:
        return jax.lax.scan(body, carry, xs)
    outer = num_chunks // inner

    def regroup(t):
        return t.reshape(outer, inner, *t.shape[1:])

    xs2 = jax.tree.map(regroup, xs)

    @jax.checkpoint
    def outer_body(c, x_in):
        return jax.lax.scan(body, c, x_in)

    carry, ys = jax.lax.scan(outer_body, carry, xs2)
    ys = jax.tree.map(lambda t: t.reshape(num_chunks, *t.shape[2:]), ys)
    return carry, ys


# =====================================================================
# Mamba2 (chunked SSD)
# =====================================================================

def _causal_conv(x: jnp.ndarray, kernel: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. x [B, S, C], kernel [K, C],
    state [B, K-1, C] (history) -> (y [B, S, C], new_state)."""
    k = kernel.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * kernel[i] for i in range(k))
    return y, xp[:, -(k - 1):]


def mamba_mix(params: Dict, x: jnp.ndarray, cfg,
              state: Optional[Dict] = None
              ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Mamba2 mixer: in_proj -> conv -> SSD scan -> gated norm -> out_proj.

    x [B, S, D]. ``state`` (decode): {"conv": [B, K-1, C], "ssm":
    [B, H, P, N]} — pass None for training (zero initial state).
    """
    b, s, _ = x.shape
    di, n, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    h = cfg.ssm_heads
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", xn, params["in_proj"])
    proj = shard(proj, "batch", None, "inner")
    z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)

    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(jax.nn.silu(xbc), params["conv_w"],
                                 conv_state)
    xs, bm, cm = jnp.split(xbc, [di, di + n], axis=-1)
    xs = xs.reshape(b, s, h, p)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])          # [B, S, H]
    log_a = -jnp.exp(params["a_log"].astype(jnp.float32)) * dt

    ssm_state = (state["ssm"] if state is not None
                 else jnp.zeros((b, h, p, n), jnp.float32))
    y, new_ssm = _ssd_chunked(xs, dt, log_a, bm.astype(jnp.float32),
                              cm.astype(jnp.float32), ssm_state,
                              cfg.ssm_chunk)
    y = y + params["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, di)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), params["gate_ln"],
                 cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    out = shard(out, "batch", None, "embed")
    new_state = ({"conv": new_conv.astype(state["conv"].dtype),
                  "ssm": new_ssm} if state is not None else None)
    return out, new_state


def _ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, log_a: jnp.ndarray,
                 bm: jnp.ndarray, cm: jnp.ndarray, s0: jnp.ndarray,
                 chunk: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    x [B, S, H, P]; dt/log_a [B, S, H]; bm/cm [B, S, N]; s0 [B, H, P, N].
    y_t = C_t^T S_t,  S_t = alpha_t S_{t-1} + dt_t B_t (x_t)^T.
    Returns (y [B, S, H, P] f32, final state).
    """
    b, s, h, p = x.shape
    n = bm.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    def r(t, width):                     # [B, S, ...] -> [Nc, B, Q, ...]
        return jnp.moveaxis(t.reshape(b, nc, q, *width), 1, 0)

    xc, dtc, lac = r(x, (h, p)), r(dt, (h,)), r(log_a, (h,))
    bc, cc = r(bm, (n,)), r(cm, (n,))

    def body(carry, inp):
        st = carry                                   # [B, H, P, N]
        xq, dq, laq, bq, cq = inp
        lcum = jnp.cumsum(laq, axis=1)               # [B, Q, H] inclusive
        # intra: M[t, s'] = (C_t.B_s') exp(Lt - Ls') dt_s'  (s' <= t)
        # (mask the exponent, not the product: exp of future-pair diffs
        # overflows and inf*0 poisons the backward pass)
        cb = jnp.einsum("bqn,bsn->bqs", cq, bq)
        mask = jnp.tril(jnp.ones((q, q), bool))[None, :, :, None]
        diff = lcum[:, :, None, :] - lcum[:, None, :, :]
        decay = jnp.exp(jnp.where(mask, diff, -jnp.inf))
        m = cb[..., None] * decay * dq[:, None, :, :]
        y_intra = jnp.einsum("bqsh,bshp->bqhp", m, xq)
        # inter: y += exp(Lt) C_t @ S_prev
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", cq, st, jnp.exp(lcum))
        # state: S' = exp(L_Q) S + sum_s exp(L_Q - L_s) dt_s B_s x_s^T
        tail = jnp.exp(lcum[:, -1:, :] - lcum) * dq   # [B, Q, H]
        s_new = (jnp.exp(lcum[:, -1])[:, :, None, None] * st
                 + jnp.einsum("bsn,bshp,bsh->bhpn", bq, xq, tail))
        return s_new, y_intra + y_inter

    s_fin, ys = _scan_chunks(body, s0, (xc, dtc, lac, bc, cc), nc)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y, s_fin


def mamba_decode_step(params: Dict, x: jnp.ndarray, cfg,
                      state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One-token recurrent step (S=1); exact recurrence, O(1) state."""
    return mamba_mix(params, x, cfg, state=state)


def init_mamba_state(cfg, batch: int, dtype=jnp.float32) -> Dict:
    c = cfg.d_inner + 2 * cfg.ssm_state      # conv acts on (x, B, C) only
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, c), dtype),
            "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32)}


# =====================================================================
# RWKV6 (Finch)
# =====================================================================

def rwkv_time_mix(params: Dict, x: jnp.ndarray, cfg,
                  state: Optional[Dict] = None
                  ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """RWKV6 time-mix block (WKV attention substitute).

    x [B, S, D]. ``state`` (decode): {"shift": [B, D] last input,
    "wkv": [B, H, K, V]} or None (training, zeros)."""
    b, s, d = x.shape
    h, hk = cfg.rwkv_heads, cfg.rwkv_head_dim
    xn = rms_norm(x, params["ln"], cfg.norm_eps)

    if state is not None:
        prev = jnp.concatenate(
            [state["shift"][:, None].astype(xn.dtype), xn[:, :-1]], 1)
    else:
        prev = jnp.pad(xn, ((0, 0), (1, 0), (0, 0)))[:, :-1]

    # data-dependent lerp for r, k, v, w, g
    xx = prev - xn
    xxx = xn + xx * params["mu_base"]
    lora = jnp.einsum("bsfl,fld->bsfd",
                      jnp.tanh(jnp.einsum("bsd,dfl->bsfl", xxx,
                                          params["mix_wa"])),
                      params["mix_wb"])                # [B, S, 5, D]
    mixed = xn[:, :, None] + xx[:, :, None] * (params["mu"] + lora)
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(5)]

    r = jnp.einsum("bsd,de->bse", xr, params["wr"]).reshape(b, s, h, hk)
    k = jnp.einsum("bsd,de->bse", xk, params["wk"]).reshape(b, s, h, hk)
    v = jnp.einsum("bsd,de->bse", xv, params["wv"]).reshape(b, s, h, hk)
    g = jnp.einsum("bsd,de->bse", xg, params["wg"])
    # per-channel log-decay, clamped (see module docstring)
    ww = (params["w0"]
          + jnp.einsum("bsl,ld->bsd",
                       jnp.tanh(jnp.einsum("bsd,dl->bsl", xw,
                                           params["decay_wa"])),
                       params["decay_wb"]))
    logw = jnp.clip(-jnp.exp(ww.astype(jnp.float32)), RWKV_LOGW_MIN, -1e-5)
    logw = logw.reshape(b, s, h, hk)
    u = params["u"].reshape(h, hk)

    wkv0 = (state["wkv"] if state is not None
            else jnp.zeros((b, h, hk, hk), jnp.float32))
    y, wkv_fin = _wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), logw, u, wkv0)

    # per-head group norm, gate, out-proj
    y = y.reshape(b, s, h, hk)
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5)
    y = (y * (1.0 + params["gn_g"].reshape(h, hk))
         + params["gn_b"].reshape(h, hk))
    y = y.reshape(b, s, d).astype(x.dtype) * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", y, params["wo"])
    out = shard(out, "batch", None, "embed")
    new_state = ({"shift": xn[:, -1].astype(state["shift"].dtype),
                  "wkv": wkv_fin} if state is not None else None)
    return out, new_state


def _wkv_chunked(r, k, v, logw, u, s0
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked WKV6: y_t = r_t.(diag(u) k_t v_t^T + S_{t-1});
    S_t = diag(w_t) S_{t-1} + k_t v_t^T (decays act on the K index).

    r/k/v [B, S, H, K]; logw same; u [H, K]; s0 [B, H, K, K(V)].
    Returns (y [B, S, H, K], final state). f32 throughout.
    """
    b, s, h, hk = r.shape
    q = min(RWKV_CHUNK, s)
    assert s % q == 0, (s, q)
    nc = s // q

    def rs(t):
        return jnp.moveaxis(t.reshape(b, nc, q, h, hk), 1, 0)

    rc, kc, vc, wc = rs(r), rs(k), rs(v), rs(logw)

    def body(carry, inp):
        st = carry                                    # [B, H, K, V]
        rq, kq, vq, lw = inp                          # [B, Q, H, K]
        wcum = jnp.cumsum(lw, axis=1)                 # inclusive
        wex = wcum - lw                               # exclusive
        # inter-chunk: y_t += (r_t * exp(Wex_t)) @ S_prev
        y_inter = jnp.einsum("bqhk,bhkv->bqhv", rq * jnp.exp(wex), st)
        # intra: A[t,s'] = sum_k r_tk k_s'k exp(Wex_t - Wc_s'), s' < t
        rr = rq * jnp.exp(wex)
        kk = kq * jnp.exp(-wcum)
        a = jnp.einsum("bqhk,bshk->bhqs", rr, kk)
        mask = jnp.tril(jnp.ones((q, q), bool), -1)
        a = jnp.where(mask[None, None], a, 0.0)
        # bonus diagonal: r_t.(u * k_t) v_t
        diag = jnp.einsum("bqhk,bqhk->bqh", rq, kq * u[None, None])
        y = (y_inter + jnp.einsum("bhqs,bshv->bqhv", a, vq)
             + diag[..., None] * vq)
        # state update: S' = exp(Wc_Q) S + sum_s exp(Wc_Q - Wc_s) k_s v_s^T
        tail = jnp.exp(wcum[:, -1:] - wcum)           # [B, Q, H, K]
        s_new = (jnp.exp(wcum[:, -1])[..., None] * st
                 + jnp.einsum("bshk,bshv->bhkv", kq * tail, vq))
        return s_new, y

    s_fin, ys = _scan_chunks(body, s0, (rc, kc, vc, wc), nc)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, hk)
    return y, s_fin


def rwkv_channel_mix(params: Dict, x: jnp.ndarray, cfg,
                     state: Optional[Dict] = None
                     ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """RWKV6 channel-mix (FFN substitute): squared-ReLU keyed FFN with
    receptance gate and token shift."""
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    if state is not None:
        prev = jnp.concatenate(
            [state["shift"][:, None].astype(xn.dtype), xn[:, :-1]], 1)
    else:
        prev = jnp.pad(xn, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xx = prev - xn
    xk = xn + xx * params["mu_k"]
    xr = xn + xx * params["mu_r"]
    kk = jnp.einsum("bsd,df->bsf", xk, params["wk"])
    kk = shard(jnp.square(jax.nn.relu(kk)), "batch", None, "ff")
    vv = jnp.einsum("bsf,fd->bsd", kk, params["wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, params["wr"]))
    out = shard(rr * vv, "batch", None, "embed")
    new_state = ({"shift": xn[:, -1].astype(state["shift"].dtype)}
                 if state is not None else None)
    return out, new_state


def rwkv_layer(params: Dict, x: jnp.ndarray, cfg,
               state: Optional[Dict] = None
               ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    tm_state = state["tm"] if state is not None else None
    cm_state = state["cm"] if state is not None else None
    a, tm_new = rwkv_time_mix(params["tm"], x, cfg, tm_state)
    x = x + a
    m, cm_new = rwkv_channel_mix(params["cm"], x, cfg, cm_state)
    x = x + m
    new = ({"tm": tm_new, "cm": cm_new} if state is not None else None)
    return x, new


def init_rwkv_state(cfg, batch: int, dtype=jnp.float32) -> Dict:
    d, h, hk = cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim
    return {"tm": {"shift": jnp.zeros((batch, d), dtype),
                   "wkv": jnp.zeros((batch, h, hk, hk), jnp.float32)},
            "cm": {"shift": jnp.zeros((batch, d), dtype)}}
