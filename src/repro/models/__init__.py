# Model zoo substrate: every assigned architecture family in pure JAX.
#   config   — ModelConfig covering dense / MoE / VLM / audio / hybrid / SSM
#   layers   — attention (GQA+RoPE+window+QK-norm+softcap), SwiGLU, MoE
#   ssm      — Mamba2 chunked SSD scan, RWKV6 chunked WKV scan (+decode steps)
#   lm       — param specs/init, train forward+loss, prefill, decode
from repro.models.config import ModelConfig
from repro.models.lm import (init_params, param_specs, loss_fn, forward,
                             prefill, decode_step, init_cache)
