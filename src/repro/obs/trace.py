"""Span/event tracing for the serving plane (DESIGN.md §6).

A ``Tracer`` records a flat list of ``TraceEvent``s — complete spans
(``ph="X"``: name, start, duration) and instant events (``ph="i"``) —
against an injected clock (``repro.obs.clock``). The event vocabulary
is deliberately tiny and maps 1:1 onto the Chrome trace-event /
Perfetto JSON format (``repro.obs.export``), so a recorded batch can
be dropped straight into ``ui.perfetto.dev``.

Instrumented call sites (``CachedBlockStore``, ``AsyncFetchQueue``,
``HostSegmentServer``, ``QueryCoordinator``, ``RepackScheduler``) all
take the tracer as an *optional* collaborator: the default is ``None``
and every hook is behind an ``if tracer is not None`` guard, so the
untraced hot path pays one attribute test — results and counters are
identical with tracing on or off (asserted in tests).

Naming conventions (DESIGN.md §6): event names are dotted
``plane.what`` — ``coord.batch``, ``coord.segment``, ``host.search``,
``io.read``, ``io.fetch_submit``, ``io.fetch_complete``,
``sched.eval``, ``sched.repack``, ``device.round``. Categories group
planes: ``serve`` | ``io`` | ``sched`` | ``device``.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Iterator, List, Optional

from repro.obs.clock import ManualClock, WallClock


@dataclasses.dataclass
class TraceEvent:
    """One Chrome-trace-event-shaped record (times in µs)."""
    name: str
    cat: str
    ph: str                 # "X" complete span | "i" instant
    ts_us: float            # start timestamp
    dur_us: float = 0.0     # span duration (X only)
    track: str = "main"     # rendered as the Chrome tid (one row each)
    args: Dict = dataclasses.field(default_factory=dict)


class Tracer:
    """Bounded in-memory trace buffer with span/event recording.

    ``max_events`` caps memory on long-lived serving processes: the
    buffer keeps the *first* ``max_events`` records and counts the
    rest in ``dropped`` (head-capture semantics — a trace documents a
    window, it is not a ring of the most recent past)."""

    def __init__(self, clock=None, max_events: int = 100_000):
        self.clock = clock if clock is not None else WallClock()
        self.max_events = int(max_events)
        self.events: List[TraceEvent] = []
        self.dropped = 0

    # ----------------------------------------------------------- record
    def _push(self, ev: TraceEvent) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def event(self, name: str, cat: str = "serve", track: str = "main",
              **args) -> None:
        """Record an instant event at the current clock."""
        self._push(TraceEvent(name=name, cat=cat, ph="i",
                              ts_us=self.clock.now_us(), track=track,
                              args=args))

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "serve", track: str = "main",
             **args) -> Iterator[Dict]:
        """Record a complete span around the ``with`` body.

        Yields the args dict so the body can attach outcomes
        (``sp["tier"] = 1``) that land in the finished event."""
        t0 = self.clock.now_us()
        try:
            yield args
        finally:
            t1 = self.clock.now_us()
            self._push(TraceEvent(name=name, cat=cat, ph="X", ts_us=t0,
                                  dur_us=max(t1 - t0, 0.0), track=track,
                                  args=args))

    def slice(self, name: str, ts_us: float, dur_us: float,
              cat: str = "device", track: str = "main", **args) -> None:
        """Record a span with *explicit* timing — used to render
        modeled timelines (e.g. the device round log priced through a
        ``CostModel``) where durations come from the model, not the
        clock."""
        self._push(TraceEvent(name=name, cat=cat, ph="X", ts_us=ts_us,
                              dur_us=dur_us, track=track, args=args))

    # ------------------------------------------------------------ access
    def __len__(self) -> int:
        return len(self.events)

    def clear(self) -> None:
        self.events.clear()
        self.dropped = 0

    def by_name(self, name: str) -> List[TraceEvent]:
        return [e for e in self.events if e.name == name]


def manual_tracer(auto_tick_us: float = 1.0) -> Tracer:
    """A tracer on a ``ManualClock`` — the deterministic test/CI
    configuration the clock-injection rule (DESIGN.md §6) prescribes."""
    return Tracer(clock=ManualClock(auto_tick_us=auto_tick_us))
