"""Serving-plane metrics registry (DESIGN.md §6).

Three instrument kinds — monotone ``Counter``s, last-value ``Gauge``s,
and windowed ``Histogram``s with p50/p95/p99 — keyed by
``(name, target)`` so one registry attributes the same metric to many
targets (segments, stores, schedulers). The ``QueryCoordinator``'s
per-batch stats dict and ``HostSegmentServer.cache_stats()`` are
re-expressed through a registry: the dicts they return are *views* of
registry state, so a dashboard scraping ``snapshot()`` and a caller
reading the stats dict can never disagree.

Naming conventions (DESIGN.md §6): dotted ``plane.metric`` names
(``serve.batches``, ``serve.block_reads``, ``io.cache_hits``,
``sched.repacks``); targets are short stable strings (``seg0``, the
segment offset, or ``""`` for plane-global). Units ride in the name
suffix where ambiguous (``_us``, ``_bytes``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, Optional, Tuple


class Counter:
    """Monotone counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up (use a Gauge)")
        self.value += n


class Gauge:
    """Last-written value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Sliding-window distribution with exact small-window quantiles.

    ``window`` bounds memory; the quantiles are computed over the most
    recent ``window`` observations (a serving dashboard wants *recent*
    p99, not lifetime). ``count``/``total`` are lifetime."""

    __slots__ = ("_win", "count", "total")

    def __init__(self, window: int = 1024):
        self._win: deque = deque(maxlen=int(window))
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self._win.append(v)
        self.count += 1
        self.total += v

    def quantile(self, q: float) -> float:
        """Exact quantile of the window (nearest-rank); 0 when empty."""
        if not self._win:
            return 0.0
        xs = sorted(self._win)
        i = min(int(q * len(xs)), len(xs) - 1)
        return xs[i]

    def summary(self) -> Dict[str, float]:
        n = len(self._win)
        return {"count": self.count,
                "mean": (self.total / self.count) if self.count else 0.0,
                "window": n,
                "p50": self.quantile(0.50),
                "p95": self.quantile(0.95),
                "p99": self.quantile(0.99),
                "max": max(self._win) if n else 0.0}


@dataclasses.dataclass(frozen=True)
class _Key:
    name: str
    target: str


class MetricsRegistry:
    """Create-on-first-use instrument store with per-target attribution.

    One registry per serving process; every instrument is identified by
    ``(name, target)``. Asking for an existing name with a different
    instrument kind is an error — a metric's kind is part of its
    schema."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, str], object] = {}

    def _get(self, name: str, target: str, kind, **kw):
        key = (name, target)
        m = self._metrics.get(key)
        if m is None:
            m = kind(**kw)
            self._metrics[key] = m
        elif not isinstance(m, kind):
            raise TypeError(
                f"metric {name!r} (target {target!r}) already registered "
                f"as {type(m).__name__}, not {kind.__name__}")
        return m

    def counter(self, name: str, target: str = "") -> Counter:
        return self._get(name, target, Counter)

    def gauge(self, name: str, target: str = "") -> Gauge:
        return self._get(name, target, Gauge)

    def histogram(self, name: str, target: str = "",
                  window: int = 1024) -> Histogram:
        return self._get(name, target, Histogram, window=window)

    # ------------------------------------------------------------- views
    def value(self, name: str, target: str = "") -> Optional[float]:
        m = self._metrics.get((name, target))
        if m is None:
            return None
        return m.value if not isinstance(m, Histogram) else m.count

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """``{name: {target: value | histogram summary}}`` — the
        dashboard/export view of everything registered."""
        out: Dict[str, Dict[str, object]] = {}
        for (name, target), m in sorted(self._metrics.items()):
            row = out.setdefault(name, {})
            row[target] = (m.summary() if isinstance(m, Histogram)
                           else m.value)
        return out

    def targets(self, name: str):
        return sorted(t for (n, t) in self._metrics if n == name)
