"""Per-round device search records (ROADMAP adaptive-plane v2, item 3).

``DeviceSearchParams.trace_rounds`` makes the batched while-loop in
``repro.core.device_search`` carry a bounded ``[max_hops, 8] int32``
buffer; row ``t`` is written once per round, *before* compaction
permutes the query rows, so every column is a batch-level sum or flag
that is permutation-invariant by construction:

  == ======================= ==========================================
  col name                    per-round meaning
  == ======================= ==========================================
  0  ``live``                 queries still active this round
  1  ``cold``                 cold block touches this round (pre-dedup)
  2  ``tier0``                tier-0 VMEM hot-tile hits
  3  ``joins``                cross-query dedup joins (gathers saved)
  4  ``joins_x``              cross-tile subset of ``joins``
  5  ``compacted``            1 if active-query compaction fired
  6  ``spec_hits``            paying gathers whose block the previous
                              round speculatively pre-fetched
                              (DESIGN.md §9; 0 when off)
  7  ``spec_wasted``          speculative gathers this round consumed
                              nothing of (0 when off)
  == ======================= ==========================================

The fold invariants (asserted in tests/test_trace_roundlog.py) tie the
log exactly to the coarse ``IOStats`` totals the serving plane already
accounts with: ``sum(live) == hops``, ``sum(cold) == io``,
``sum(tier0) == tier0_hits``, ``sum(joins) == dedup_saved``,
``sum(joins_x) == dedup_cross``, ``sum(spec_hits) == spec_hits``,
``sum(spec_wasted) == spec_wasted`` (both charged at consume time, so
the round a hit/waste lands in is the round its authoritative fetch
ran), and
``sum(live) / rounds == rounds_active_weight / batch_rounds`` — the
round log is a lossless refinement of ``IOStats.from_device_batch``,
not a second bookkeeping system that can drift from it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

ROUND_LOG_COLS = ("live", "cold", "tier0", "joins", "joins_x",
                  "compacted", "spec_hits", "spec_wasted")
N_ROUND_COLS = len(ROUND_LOG_COLS)


@dataclasses.dataclass(frozen=True)
class RoundRecord:
    """One lockstep round of a batched device search."""
    round: int
    live: int        # queries active this round
    cold: int        # cold block touches this round (pre-dedup)
    tier0: int       # tier-0 hot-tile hits
    joins: int       # dedup joins (whole-batch scope)
    joins_x: int     # cross-tile subset of ``joins``
    compacted: bool  # active-query compaction fired this round
    spec_hits: int = 0    # paying gathers the previous round's
    #                       speculation pre-fetched (consume-time)
    spec_wasted: int = 0  # speculative gathers nothing consumed


def fold_round_log(round_log, rounds: int) -> List[RoundRecord]:
    """Materialize the device buffer into exact per-round records.

    ``round_log`` is the ``[max_hops, 8]`` array off the device (any
    array-like); ``rounds`` is the loop's final trip count — rows at or
    beyond it are unwritten padding and are dropped."""
    log = np.asarray(round_log)
    if log.ndim != 2 or log.shape[1] != N_ROUND_COLS:
        raise ValueError(
            f"round_log must be [rounds, {N_ROUND_COLS}], got {log.shape}")
    rounds = int(rounds)
    out = []
    for t in range(min(rounds, log.shape[0])):
        (live, cold, tier0, joins, joins_x, compacted, spec_h,
         spec_w) = (int(v) for v in log[t])
        out.append(RoundRecord(round=t, live=live, cold=cold, tier0=tier0,
                               joins=joins, joins_x=joins_x,
                               compacted=bool(compacted),
                               spec_hits=spec_h, spec_wasted=spec_w))
    return out


def round_log_totals(records: Sequence[RoundRecord]) -> Dict[str, float]:
    """Sum a folded log back down to the ``IOStats``-comparable totals.

    Matches ``IOStats.from_device_batch`` exactly: ``hops`` = total
    query-rounds of liveness, ``io``/``tier0_hits``/``dedup_saved``/
    ``dedup_cross`` = column sums, ``rounds`` = record count, ``rounds_active_weight`` =
    mean live fraction numerator (sum of live, to be divided by the
    batch width by the caller that knows it)."""
    return {
        "rounds": len(records),
        "hops": sum(r.live for r in records),
        "io": sum(r.cold for r in records),
        "tier0_hits": sum(r.tier0 for r in records),
        "dedup_saved": sum(r.joins for r in records),
        "dedup_cross": sum(r.joins_x for r in records),
        "compactions": sum(1 for r in records if r.compacted),
        "spec_hits": sum(r.spec_hits for r in records),
        "spec_wasted": sum(r.spec_wasted for r in records),
        "live_weight": sum(r.live for r in records),
    }
