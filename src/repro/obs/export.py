"""Chrome-trace-event / Perfetto JSON export (DESIGN.md §6).

Converts a ``Tracer`` buffer into the Chrome trace-event JSON object
format (the dialect ``ui.perfetto.dev`` and ``chrome://tracing`` both
load): ``{"traceEvents": [...], "displayTimeUnit": "ms"}`` with
complete spans (``ph="X"``, µs timestamps/durations) and instants
(``ph="i"``). Tracks map to Chrome thread ids — one row per track,
named via ``"M"`` (metadata) events — so a coordinator batch, the
store's reads, the scheduler's decisions, and the modeled device
rounds each render on their own timeline row.

``validate_chrome_trace`` is the schema check the CI obs lane runs on
the smoke-search export: no external JSON-schema dependency, just the
structural rules the viewers actually require.

``timeline_from_round_log`` renders a folded device round log
(``repro.obs.roundlog``) as back-to-back *modeled* ``device.round``
slices priced through a ``CostModel`` — the per-round view of where a
batch's lockstep chain spent its modeled time (args carry the raw
counters so the viewer shows live/cold/tier0/joins per round).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence

from repro.obs.trace import TraceEvent, Tracer

PID = 1  # single-process traces; tracks are rendered as threads


def chrome_trace(tracer: Tracer,
                 metadata: Optional[Dict] = None) -> Dict:
    """``Tracer`` buffer -> Chrome trace-event JSON object format."""
    tids: Dict[str, int] = {}
    events: List[Dict] = []
    for ev in tracer.events:
        tid = tids.get(ev.track)
        if tid is None:
            tid = tids[ev.track] = len(tids) + 1
        rec = {"name": ev.name, "cat": ev.cat, "ph": ev.ph,
               "ts": ev.ts_us, "pid": PID, "tid": tid}
        if ev.ph == "X":
            rec["dur"] = ev.dur_us
        if ev.ph == "i":
            rec["s"] = "t"          # instant scope: thread
        if ev.args:
            rec["args"] = dict(ev.args)
        events.append(rec)
    # thread-name metadata first, so viewers label rows on load
    meta_events = [{"name": "thread_name", "ph": "M", "pid": PID,
                    "tid": tid, "args": {"name": track}}
                   for track, tid in tids.items()]
    out = {"traceEvents": meta_events + events, "displayTimeUnit": "ms"}
    if tracer.dropped:
        out["obs_dropped_events"] = tracer.dropped
    if metadata:
        out["metadata"] = dict(metadata)
    return out


def write_chrome_trace(path, tracer: Tracer,
                       metadata: Optional[Dict] = None) -> Dict:
    """Export + write to ``path``; returns the exported object."""
    obj = chrome_trace(tracer, metadata=metadata)
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return obj


def validate_chrome_trace(obj) -> List[str]:
    """Structural schema check; returns a list of problems (empty =
    valid). Covers the rules the Perfetto/Chrome loaders enforce:
    object format with a ``traceEvents`` list; every event has
    ``name``/``ph``/``pid``/``tid``; ``ph`` is one we emit; ``X``
    events carry numeric non-negative ``ts``+``dur``; instants carry
    ``ts``; args, when present, are JSON-serializable dicts."""
    problems: List[str] = []
    if not isinstance(obj, dict):
        return [f"top level must be an object, got {type(obj).__name__}"]
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents must be a list"]
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            problems.append(f"{where}: unsupported ph {ph!r}")
            continue
        for key in ("name", "pid", "tid"):
            if key not in ev:
                problems.append(f"{where}: missing {key!r}")
        if ph in ("X", "i"):
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)):
                problems.append(f"{where}: ts must be a number")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(
                    f"{where}: X event needs numeric dur >= 0")
        args = ev.get("args")
        if args is not None:
            if not isinstance(args, dict):
                problems.append(f"{where}: args must be an object")
            else:
                try:
                    json.dumps(args)
                except (TypeError, ValueError) as e:
                    problems.append(f"{where}: args not serializable: {e}")
    return problems


def timeline_from_round_log(records: Sequence, cost_model,
                            tracer: Optional[Tracer] = None,
                            track: str = "device", t0_us: float = 0.0,
                            batch: int = 0,
                            dma_track: bool = False) -> Tracer:
    """Render folded ``RoundRecord``s as modeled back-to-back
    ``device.round`` slices.

    Each round's modeled duration is its share of the round-granular
    regime: the lockstep ``t_round`` chain unit, the occupancy-weighted
    compute (``live x t_round_comp``), and its cold DMAs streaming at
    ``t_batch_block`` (falling back to ``t_block_io`` when the model
    has no streaming rate — matching ``CostModel._io_time``). Durations
    are *modeled*, so the slices go in with explicit timing
    (``Tracer.slice``), not the tracer's clock.

    ``dma_track=True`` additionally renders the gather stream on its
    own ``<track>.dma`` row so the ``max(dma, compute)`` overlap the
    cost model prices is visible in Perfetto instead of serialized
    into the round slice: each round's demand stream
    (``cold - joins - spec_hits`` blocks) starts WITH the round slice
    (overlapping its compute), and a round's speculatively consumed +
    wasted blocks (``spec_hits + spec_wasted``) render as a
    ``device.dma.spec`` slice back in the PREVIOUS round — where their
    copies were actually in flight, overlapping that round's
    expansion/top-M compute (DESIGN.md §9). Round boundaries (and the
    round slices themselves) are unchanged either way, so the default
    rendering stays bit-compatible."""
    from repro.obs.trace import manual_tracer

    tr = tracer if tracer is not None else manual_tracer(auto_tick_us=0.0)
    t_stream = (cost_model.t_batch_block if cost_model.t_batch_block
                else cost_model.t_block_io)
    t = float(t0_us)
    prev_t = float(t0_us)
    for r in records:
        spec_h = getattr(r, "spec_hits", 0)
        spec_w = getattr(r, "spec_wasted", 0)
        dur = (cost_model.t_round
               + r.live * cost_model.t_round_comp
               + (r.cold - r.joins) * t_stream
               + r.tier0 * cost_model.t_tier0_hit
               + r.joins * cost_model.t_dedup_hit)
        args = {"live": r.live, "cold": r.cold, "tier0": r.tier0,
                "joins": r.joins, "joins_x": r.joins_x,
                "compacted": r.compacted, "spec_hits": spec_h,
                "spec_wasted": spec_w}
        if batch:
            args["batch"] = batch
        tr.slice("device.round", ts_us=t, dur_us=max(dur, 0.0),
                 cat="device", track=track, **args)
        if dma_track:
            demand = max(r.cold - r.joins - spec_h, 0)
            if demand > 0:
                tr.slice("device.dma", ts_us=t,
                         dur_us=demand * t_stream, cat="device",
                         track=f"{track}.dma", blocks=demand,
                         round=r.round)
            spec_blocks = spec_h + spec_w
            if spec_blocks > 0:
                # issued while the PREVIOUS round's expansion/top-M
                # maintenance ran — render it there, overlapping
                tr.slice("device.dma.spec", ts_us=prev_t,
                         dur_us=spec_blocks * t_stream, cat="device",
                         track=f"{track}.dma", spec_hits=spec_h,
                         spec_wasted=spec_w, round=r.round)
        prev_t = t
        t += max(dur, 0.0)
    return tr
