"""Measured-vs-modeled cost calibration (ROADMAP adaptive-plane v2,
item 4; DESIGN.md §6).

Every latency/QPS figure the repo reports flows through ``CostModel``
constants that were, until now, assumed. This module closes the loop:
replay a workload, record wall-clock per batch alongside the
``IOStats`` the same batch produced, and fit the constants so the
model *predicts* the measurement.

The trick that keeps this dependency-free: within one pricing regime
(host hops-granular vs device round-granular — the switch is
``t_round > 0 and batch_rounds > 0``), ``CostModel.latency_us`` is
*affine* in the constants. This includes the speculative regimes
(``IOStats.dma_speculative``, DESIGN.md §9): the ``max(dma, compute)``
overlap chain and the wasted-DMA surcharge are priced from the same
``t_batch_block``/``t_round_comp`` constants scaled by per-batch
counters, so speculation introduces NO new calibration constants —
a preset fit without speculation prices speculative runs too. So each sample row's coefficient vector is
recovered exactly by finite differences at the base model (bump one
constant by 1.0, re-price, subtract), and the fit is one least-squares
solve. Constants whose coefficient column is all-zero on the given
workload (e.g. ``t_round`` on host samples) are unidentifiable there
and keep their base values — reported as ``unfit`` so a preset never
silently claims to have measured what it could not see.

Presets are stored as JSON (``CalibrationPreset.save``/``load``) and
applied with ``preset.apply(base)`` — a ``dataclasses.replace`` onto
the shipped base model, so unfit constants keep the documented
defaults and the preset file stays a small, reviewable diff.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.iostats import CostModel, IOStats

# the constants calibration targets by default — the ones the bench
# regimes actually exercise (DMA round trip, streamed block, lockstep
# round chain, occupancy-weighted round compute)
DEFAULT_FIELDS = ("t_block_io", "t_batch_block", "t_round",
                  "t_round_comp")


@dataclasses.dataclass(frozen=True)
class CalibrationSample:
    """One replayed batch: the stats the model prices, the wall-clock
    the clock measured (µs; same scope — whole batch), and the pricing
    mode used when comparing."""
    stats: IOStats
    measured_us: float
    pipeline: bool = False


def _coefficients(base: CostModel, s: CalibrationSample,
                  fields: Sequence[str]) -> Tuple[np.ndarray, float]:
    """Affine decomposition of one sample's modeled latency:
    ``latency(c) = coeffs . c + intercept`` over ``fields`` (exact
    within a regime — latency is linear in each constant)."""
    l0 = base.latency_us(s.stats, s.pipeline)
    coeffs = np.zeros(len(fields))
    for j, f in enumerate(fields):
        bumped = dataclasses.replace(base, **{f: getattr(base, f) + 1.0})
        coeffs[j] = bumped.latency_us(s.stats, s.pipeline) - l0
    intercept = l0 - float(
        coeffs @ np.array([getattr(base, f) for f in fields]))
    return coeffs, intercept


def _error_report(model: CostModel,
                  samples: Sequence[CalibrationSample]) -> Dict[str, float]:
    measured = np.array([s.measured_us for s in samples], float)
    modeled = np.array([model.latency_us(s.stats, s.pipeline)
                        for s in samples], float)
    denom = np.maximum(np.abs(measured), 1e-9)
    rel = np.abs(modeled - measured) / denom
    return {"mean_abs_rel_err": float(rel.mean()),
            "max_abs_rel_err": float(rel.max()),
            "mean_measured_us": float(measured.mean()),
            "mean_modeled_us": float(modeled.mean())}


def fit_cost_model(base: CostModel,
                   samples: Sequence[CalibrationSample],
                   fields: Sequence[str] = DEFAULT_FIELDS,
                   ) -> Tuple[CostModel, Dict]:
    """Least-squares fit of ``fields`` to the measured latencies.

    Returns ``(fitted_model, report)`` where the report carries the
    fitted constants, which fields were unidentifiable (``unfit``), and
    modeled-vs-measured error before and after the fit. Fitted values
    are clipped at 0 (a negative latency constant is a fit artifact,
    not physics)."""
    if not samples:
        raise ValueError("calibration needs at least one sample")
    rows = [_coefficients(base, s, fields) for s in samples]
    a = np.stack([c for c, _ in rows])                 # [S, F]
    b = np.array([s.measured_us for s in samples]) \
        - np.array([i for _, i in rows])               # [S]

    identifiable = [j for j in range(len(fields))
                    if np.abs(a[:, j]).max() > 0]
    unfit = [fields[j] for j in range(len(fields))
             if j not in identifiable]
    fitted: Dict[str, float] = {}
    if identifiable:
        sol, *_ = np.linalg.lstsq(a[:, identifiable], b, rcond=None)
        for j, col in enumerate(identifiable):
            fitted[fields[col]] = float(max(sol[j], 0.0))
    model = dataclasses.replace(base, **fitted) if fitted else base
    report = {
        "backend": base.name,
        "n_samples": len(samples),
        "fields": list(fields),
        "fitted": fitted,
        "unfit": unfit,
        "base": {f: getattr(base, f) for f in fields},
        "error_before": _error_report(base, samples),
        "error_after": _error_report(model, samples),
    }
    return model, report


@dataclasses.dataclass
class CalibrationPreset:
    """A stored per-backend calibration: the fitted constants plus the
    provenance needed to trust them (sample count, residual error)."""
    backend: str                       # base CostModel name it fits
    constants: Dict[str, float]        # fitted constants only
    unfit: List[str]                   # requested but unidentifiable
    n_samples: int
    error: Dict[str, float]            # post-fit modeled-vs-measured
    source: str = ""                   # workload that produced it

    def apply(self, base: CostModel) -> CostModel:
        """Overlay the fitted constants on ``base``; unfit constants
        keep the base's documented defaults."""
        if base.name != self.backend:
            raise ValueError(
                f"preset calibrates backend {self.backend!r}, "
                f"got model {base.name!r}")
        return dataclasses.replace(base, **self.constants)

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(dataclasses.asdict(self), f, indent=2,
                      sort_keys=True)

    @classmethod
    def load(cls, path) -> "CalibrationPreset":
        with open(path) as f:
            raw = json.load(f)
        return cls(**raw)

    @classmethod
    def from_report(cls, report: Dict,
                    source: str = "") -> "CalibrationPreset":
        return cls(backend=report["backend"],
                   constants=dict(report["fitted"]),
                   unfit=list(report["unfit"]),
                   n_samples=int(report["n_samples"]),
                   error=dict(report["error_after"]),
                   source=source)


def load_calibrated(base: CostModel, results_dir=None) -> CostModel:
    """The shipped preset with any stored calibration applied on top.

    Looks for ``results/CALIB_<base.name>.json`` (the file ``make
    bench-obs`` fits and CI uploads) and overlays its fitted constants
    via ``CalibrationPreset.apply``. Any way the preset cannot be
    honored — file missing, unparseable, or fitted for a different
    backend — falls back to ``base`` unchanged, so callers
    (``RepackScheduler``, ``mesh_qps_estimate``, the router) can use
    this as their default pricing unconditionally."""
    import os
    if results_dir is None:
        # src/repro/obs/calibrate.py -> repo root / results
        here = os.path.dirname(os.path.abspath(__file__))
        results_dir = os.path.join(here, "..", "..", "..", "results")
    path = os.path.join(results_dir, f"CALIB_{base.name}.json")
    if not os.path.exists(path):
        return base
    try:
        return CalibrationPreset.load(path).apply(base)
    except (ValueError, KeyError, TypeError, json.JSONDecodeError,
            OSError):
        return base


def calibrate(base: CostModel, samples: Sequence[CalibrationSample],
              fields: Sequence[str] = DEFAULT_FIELDS,
              source: str = "",
              preset_path: Optional[str] = None,
              ) -> Tuple[CostModel, CalibrationPreset, Dict]:
    """Fit + package + (optionally) store — the one-call harness the
    obs bench uses per backend regime."""
    model, report = fit_cost_model(base, samples, fields)
    preset = CalibrationPreset.from_report(report, source=source)
    if preset_path is not None:
        preset.save(preset_path)
    return model, preset, report
