"""Injectable clocks for the observability plane (DESIGN.md §6).

Every timestamp `repro.obs` records comes from a ``Clock`` so the same
tracing code is deterministic in tests and wall-clock in benches:

  * ``WallClock`` — monotonic wall time (``time.perf_counter_ns``), the
    bench/serving default; the only place the repo reads real time for
    observability purposes.
  * ``ManualClock`` — a counter advanced explicitly by the test; spans
    get exact, reproducible durations, so trace goldens are stable.

The clock-injection rule (DESIGN.md §6): *library* code never calls
``time.*`` directly — it asks the tracer, and the tracer asks its
clock. Units are microseconds throughout (the Chrome trace-event
native unit), as floats.
"""
from __future__ import annotations

import time


class WallClock:
    """Monotonic wall time in microseconds."""

    def now_us(self) -> float:
        return time.perf_counter_ns() / 1e3


class ManualClock:
    """Deterministic test clock: time moves only when told to."""

    def __init__(self, start_us: float = 0.0, auto_tick_us: float = 0.0):
        self._now = float(start_us)
        # auto_tick_us > 0 advances the clock on every read, so two
        # consecutive events never collapse onto one timestamp even
        # when the test does not advance explicitly
        self.auto_tick_us = float(auto_tick_us)

    def now_us(self) -> float:
        t = self._now
        self._now += self.auto_tick_us
        return t

    def advance(self, us: float) -> float:
        if us < 0:
            raise ValueError("clocks only move forward")
        self._now += float(us)
        return self._now

    def set(self, us: float) -> None:
        if us < self._now:
            raise ValueError("clocks only move forward")
        self._now = float(us)
