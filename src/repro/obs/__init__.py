"""repro.obs — the observability plane (DESIGN.md §6).

Three layers over the search/serving stack:

  * round-granular device tracing (``roundlog`` +
    ``DeviceSearchParams.trace_rounds``) — exact per-round records of
    the batched while-loop, a lossless refinement of ``IOStats``;
  * host span/event tracing (``trace``, injectable ``clock``) and the
    serving ``metrics`` registry the coordinator/stores report through;
  * ``export`` (Chrome-trace-event / Perfetto JSON) and ``calibrate``
    (measured-vs-modeled ``CostModel`` fitting into stored presets).
"""
from repro.obs.calibrate import (CalibrationPreset, CalibrationSample,
                                 calibrate, fit_cost_model,
                                 load_calibrated)
from repro.obs.clock import ManualClock, WallClock
from repro.obs.export import (chrome_trace, timeline_from_round_log,
                              validate_chrome_trace, write_chrome_trace)
from repro.obs.metrics import (Counter, Gauge, Histogram,
                               MetricsRegistry)
from repro.obs.roundlog import (N_ROUND_COLS, ROUND_LOG_COLS,
                                RoundRecord, fold_round_log,
                                round_log_totals)
from repro.obs.trace import TraceEvent, Tracer, manual_tracer

__all__ = [
    "CalibrationPreset", "CalibrationSample", "calibrate",
    "fit_cost_model", "load_calibrated", "ManualClock", "WallClock",
    "chrome_trace",
    "timeline_from_round_log", "validate_chrome_trace",
    "write_chrome_trace", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "N_ROUND_COLS", "ROUND_LOG_COLS", "RoundRecord",
    "fold_round_log", "round_log_totals", "TraceEvent", "Tracer",
    "manual_tracer",
]
