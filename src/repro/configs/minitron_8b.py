"""minitron-8b — width-pruned Nemotron dense decoder [arXiv:2407.14679].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=16384, vocab_size=256_000,
    rope_theta=10_000.0, act="silu", tie_embeddings=False,
    grad_accum=4,
)

SMOKE = ModelConfig(
    name="minitron-8b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=192, vocab_size=512, tie_embeddings=False, remat=False,
)
