"""gemma3-1b — dense decoder, 5:1 local:global attention
[hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144; sliding window 512
on local layers, every 6th layer global; QK-norm; scaled embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1,
    head_dim=256, d_ff=6912, vocab_size=262_144,
    window=512, global_every=6, qk_norm=True, scale_embed=True,
    rope_theta=1_000_000.0, act="gelu", tie_embeddings=True,
    grad_accum=2,
)

SMOKE = ModelConfig(
    name="gemma3-1b-smoke", family="dense",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=160, vocab_size=512, window=16, global_every=2, qk_norm=True,
    scale_embed=True, act="gelu", remat=False,
)
