"""The paper's own workload: segment configurations per dataset (Tab. 1,
Tab. 16-18) scaled to this container, plus full-size parameter sets used
by the analytic cost accounting.

``SEGMENT_BENCH`` is the container-scale segment every benchmark runs
(10^4-10^5 vectors); ``SEGMENT_FULL_*`` mirror the paper's per-dataset
parameters (Λ, η, ε, ρ) for the Example-2 style accounting tests.
"""
from __future__ import annotations

import dataclasses

from repro.core.params import (CacheParams, DeviceSearchParams,
                               GraphParams, LayoutParams, NavGraphParams,
                               PQParams, RepackParams, SearchParams,
                               SegmentParams)

# container-scale segment used by benchmarks: same knob values as the
# paper's BIGANN column wherever scale-independent (σ=0.3, φ=0.5, β=8,
# τ=0.01, μ≈0.1, PQ codes in memory)
SEGMENT_BENCH = SegmentParams(
    graph=GraphParams(max_degree=24, build_beam=64, alpha=1.2,
                      algo="vamana"),
    layout=LayoutParams(block_kb=4.0, shuffle="bnf", bnf_iters=8,
                        gain_tau=0.001),
    pq=PQParams(num_subspaces=8, num_centroids=256, train_iters=12),
    nav=NavGraphParams(sample_ratio=0.1, max_degree=12, build_beam=32,
                       search_beam=16, num_entry_points=4),
    search=SearchParams(candidate_size=48, pruning_ratio=0.3,
                        rs_ratio=0.5),
    metric="l2",
)

# the same segment with the repro.io block cache on: 10% of the block
# file as cache budget (a quarter pinned to the entry-neighborhood hot
# set), LRU dynamics, 4-wide batched prefetch. Segments built from this
# config get a cache-fronted view; benchmarks/io_bench.py sweeps around
# these values, and a HostSegmentServer over such a segment shares the
# cache across queries.
SEGMENT_BENCH_CACHED = dataclasses.replace(
    SEGMENT_BENCH,
    cache=CacheParams(budget_frac=0.10, policy="lru", pin_fraction=0.25,
                      prefetch_width=4),
)

# the async + tiered deployment at the SAME 10% memory budget: a quarter
# of the budget becomes a compressed PQ-space summary tier (~16x more
# blocks per byte; a tier-2 hit re-ranks without a disk trip), and
# fetches go through an 8-deep event-clock AsyncFetchQueue — speculative
# reads stay in flight while the current block is ranked, complete out
# of submission order, and concurrent queries dedup in-flight fetches of
# the same block. benchmarks/io_bench.py sweeps queue depth and tier-2
# share around this point against the synchronous SEGMENT_BENCH_CACHED.
SEGMENT_BENCH_ASYNC = dataclasses.replace(
    SEGMENT_BENCH,
    cache=CacheParams(budget_frac=0.10, policy="lru", pin_fraction=0.25,
                      prefetch_width=4, tier2_frac=0.25,
                      tier2_compression=16, queue_depth=8),
)

# the device deployment: the SAME segment with the tier-0 VMEM hot-tile
# pack budgeted at 10% of the block file (selected from the shared
# repro.io.hotset ranking; exact copies, so results stay bit-identical
# to the uncached device path) and charged into Eq. 10 as C_tier0.
# device_bench.py sweeps the tier-0 budget around this point.
SEGMENT_BENCH_DEVICE = dataclasses.replace(
    SEGMENT_BENCH,
    cache=CacheParams(tier0_frac=0.10),
)

# the batched device-search knobs the benchmarks/serving dry-runs use:
# the bench segment's Γ, paper σ, deep safety valve. DEVICE_SEARCH_WIDE
# adds 2-wide DMA fetch (EXPERIMENTS §Perf cell 3 — fewer round trips,
# same recall). DEVICE_SEARCH_BATCH is the divergence-aware serving
# point (ISSUE 4): wide fetch + active-query compaction once the live
# fraction of the batch falls under 25% — cross-query block dedup is
# always on (it only moves DMAs into the dedup_saved counter).
DEVICE_SEARCH_BENCH = DeviceSearchParams(candidates=48, max_hops=256)
DEVICE_SEARCH_WIDE = dataclasses.replace(DEVICE_SEARCH_BENCH,
                                         fetch_width=2)
DEVICE_SEARCH_BATCH = dataclasses.replace(DEVICE_SEARCH_WIDE,
                                          compact_frac=0.25)

# the adaptive serving plane's repack control loop (ISSUE 5): evaluate
# every 4 served batches, fire only when >= 25% of the tier-0 pack
# would change (the hysteresis damper — below that a repack moves too
# few tiles to matter and the loop would churn), and leave a pack alone
# while it already absorbs >= 95% of block touches. device_bench's
# drift sweep runs exactly this preset.
SERVE_REPACK = RepackParams(interval_batches=4, hysteresis=0.25,
                            min_observed=1, hit_rate_ceiling=0.95)

# the paper's full-size per-dataset index parameters (Tab. 16): used by
# the byte-accounting tests (γ, ε, ρ must reproduce Example 2 exactly)
PAPER_DATASETS = {
    # name: (n_vectors, dim, dtype_bytes, Λ, η_kb, ε, ρ)
    "bigann": (33_000_000, 128, 1, 31, 4, 16, 2_062_500),
    "deep": (11_000_000, 96, 4, 48, 4, 7, 1_571_429),
    "ssnpp": (16_000_000, 256, 1, 48, 4, 9, 1_777_778),
    "text2image": (5_000_000, 200, 4, 54, 4, 4, 1_250_000),
}
