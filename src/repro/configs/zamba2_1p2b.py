"""zamba2-1.2b — hybrid Mamba2 trunk + shared attention block
[arXiv:2411.15242].

38 Mamba2 layers d_model=2048, ssm_state=64; one *shared* attention block
(32H, kv=32, d_ff=8192 SwiGLU) applied every 6 layers; vocab=32000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32_000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    shared_attn_period=6,
    rope_theta=10_000.0, act="silu", tie_embeddings=True,
    grad_accum=4,   # §Perf: fits 16GB (25.1 -> 12.6 GiB/chip)
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_conv=4,
    shared_attn_period=2, remat=False,
)
