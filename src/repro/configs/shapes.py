"""Assigned input shapes and the 40-cell (arch x shape) grid.

  train_4k     seq 4,096   batch 256  — train_step
  prefill_32k  seq 32,768  batch 32   — prefill (inference)
  decode_32k   seq 32,768  batch 128  — serve_step: 1 new token, 32k cache
  long_500k    seq 524,288 batch 1    — serve_step: 1 new token, 500k cache

``long_500k`` requires sub-quadratic attention: it runs only for the
SSM / hybrid / mostly-local archs (rwkv6, zamba2, gemma3 — gemma3's local
layers are O(w); its 1-in-6 global layers attend the full cache at O(S)
per decoded token, which is linear, noted in DESIGN.md). Pure
full-attention archs record a SKIP for this cell.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.configs.registry import ARCH_IDS, get_config


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}

# archs allowed to run long_500k (sub-quadratic decode memory/compute)
_LONG_OK = ("zamba2-1.2b", "rwkv6-1.6b", "gemma3-1b")


def skip_reason(arch: str, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and arch not in _LONG_OK:
        return ("full-attention arch: long_500k requires sub-quadratic "
                "attention (see DESIGN.md §Arch-applicability)")
    return None


def cell_supported(arch: str, shape_name: str) -> bool:
    return skip_reason(arch, shape_name) is None


def all_cells() -> List[Tuple[str, str]]:
    """The 40 (arch, shape) cells, including skipped ones."""
    return [(a, s) for a in ARCH_IDS for s in SHAPES]
