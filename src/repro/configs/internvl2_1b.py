"""internvl2-1b — VLM backbone (Qwen2-0.5B LM trunk) [arXiv:2404.16821].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655. The InternViT
frontend is a STUB: ``input_specs`` supplies 256 precomputed patch
embeddings per image, prepended to the token sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151_655, patch_tokens=256,
    rope_theta=1_000_000.0, act="silu", tie_embeddings=True,
    grad_accum=2,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    num_layers=2, d_model=56, num_heads=7, num_kv_heads=1,
    d_ff=128, vocab_size=512, patch_tokens=8, remat=False,
)
