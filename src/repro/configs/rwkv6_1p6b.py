"""rwkv6-1.6b — Finch: attention-free, data-dependent decay
[arXiv:2404.05892].

24L d_model=2048 (32 WKV heads x 64) d_ff=7168 vocab=65536.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=7168, vocab_size=65_536,
    rwkv_head_dim=64, rwkv_lora=32, rwkv_decay_lora=64,
    act="silu", tie_embeddings=False, grad_accum=4,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
    d_ff=128, vocab_size=512,
    rwkv_head_dim=32, rwkv_lora=8, rwkv_decay_lora=8,
    tie_embeddings=False, remat=False,
)
