"""whisper-base — encoder-decoder ASR backbone [arXiv:2212.04356].

6L(enc)+6L(dec) d_model=512 8H d_ff=2048 vocab=51865; plain GELU MLP.
The conv frame frontend is a STUB: ``input_specs`` supplies 1500
precomputed frame embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, encoder_layers=6, num_mem_tokens=1500,
    d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51_865,
    act="gelu", tie_embeddings=True, grad_accum=1,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    num_layers=2, encoder_layers=2, num_mem_tokens=12,
    d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, act="gelu", remat=False,
)
