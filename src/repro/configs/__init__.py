# Assigned architectures (exact published configs) + reduced smoke
# variants + the paper's own segment workload. ``get_config(name)`` /
# ``get_smoke_config(name)`` / ``ARCH_IDS`` / shapes in ``shapes.py``.
from repro.configs.registry import (ARCH_IDS, get_config, get_smoke_config,
                                    CONFIGS, SMOKE_CONFIGS)
from repro.configs.shapes import (SHAPES, Shape, cell_supported,
                                  all_cells, skip_reason)
