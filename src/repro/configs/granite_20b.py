"""granite-20b — llama-arch code model, MQA [arXiv:2405.04324].

52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24_576, vocab_size=49_152,
    rope_theta=10_000.0, act="silu", tie_embeddings=False,
    grad_accum=8,
)

SMOKE = ModelConfig(
    name="granite-20b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=1,
    d_ff=256, vocab_size=512, tie_embeddings=False, remat=False,
)
