"""moonshot-v1-16b-a3b — Moonlight (DeepSeek-style) MoE, 64 experts top-6
with shared experts [hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (GQA kv=16) moe_d_ff=1408 vocab=163840.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163_840,
    num_experts=64, experts_per_token=6, moe_d_ff=1408,
    num_shared_experts=2,
    rope_theta=50_000.0, act="silu", tie_embeddings=False,
    grad_accum=4, moe_dispatch="capacity", mixed_state=True,
)

SMOKE = ModelConfig(
    name="moonshot-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=96, vocab_size=512, num_experts=8, experts_per_token=2,
    moe_d_ff=96, num_shared_experts=1, tie_embeddings=False, remat=False,
)
