"""qwen3-moe-235b-a22b — MoE decoder, 128 experts top-8
[hf:Qwen/Qwen3-235B family].

94L d_model=4096 64H (GQA kv=4, head_dim=128) moe_d_ff=1536
vocab=151936; QK-norm.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    head_dim=128, d_ff=1536, vocab_size=151_936,
    num_experts=128, experts_per_token=8, moe_d_ff=1536,
    qk_norm=True, rope_theta=1_000_000.0, act="silu",
    tie_embeddings=False, grad_accum=16,
    # §Perf iteration 5: shard_map EP capacity dispatch + bf16 state
    # (multi-pod runs use grad_accum=8 so the microbatch shards 32-way)
    moe_dispatch="capacity", mixed_state=True,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=512, num_experts=8, experts_per_token=2,
    moe_d_ff=96, qk_norm=True, tie_embeddings=False, remat=False,
)
