"""Architecture registry: ``--arch <id>`` lookup for full and smoke
configs."""
from __future__ import annotations

from repro.configs import (stablelm_3b, minitron_8b, gemma3_1b, granite_20b,
                           qwen3_moe_235b, moonshot_16b, internvl2_1b,
                           whisper_base, zamba2_1p2b, rwkv6_1p6b)
from repro.models.config import ModelConfig

_MODULES = {
    "stablelm-3b": stablelm_3b,
    "minitron-8b": minitron_8b,
    "gemma3-1b": gemma3_1b,
    "granite-20b": granite_20b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b,
    "moonshot-v1-16b-a3b": moonshot_16b,
    "internvl2-1b": internvl2_1b,
    "whisper-base": whisper_base,
    "zamba2-1.2b": zamba2_1p2b,
    "rwkv6-1.6b": rwkv6_1p6b,
}

ARCH_IDS = tuple(_MODULES)
CONFIGS = {k: m.CONFIG for k, m in _MODULES.items()}
SMOKE_CONFIGS = {k: m.SMOKE for k, m in _MODULES.items()}


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    return CONFIGS[name]


def get_smoke_config(name: str) -> ModelConfig:
    if name not in SMOKE_CONFIGS:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    return SMOKE_CONFIGS[name]
