# Distribution substrate:
#   sharding — logical-axis rules -> PartitionSpec/NamedSharding (GSPMD)
#   hlo      — collective-bytes parser over lowered/compiled HLO text
#   compress — int8 gradient all-reduce with error feedback
#   elastic  — re-mesh planner for node loss (shrink data axis, keep batch)
from repro.distributed.sharding import (AxisRules, SINGLE_POD_RULES,
                                        MULTI_POD_RULES, logical_spec,
                                        shard, set_rules, current_rules,
                                        param_sharding_tree)
